//! Minimal vendored stand-in for the `anyhow` crate.
//!
//! Provides the subset of the real API this repository uses — `Error`,
//! `Result`, the `anyhow!`/`bail!`/`ensure!` macros, and the `Context`
//! extension trait — so the workspace builds with no network access.
//! Errors are stored as a flattened message chain (no downcasting); the
//! `{:#}` alternate format prints the whole chain like real anyhow.

use std::error::Error as StdError;
use std::fmt;

/// `Result<T, anyhow::Error>` with the error type defaulted.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// A string-chain error: `chain[0]` is the outermost message, later
/// entries are the causes (outermost first).
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Construct from a single displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { chain: vec![message.to_string()] }
    }

    /// Prepend a new outermost context message.
    pub fn context<C: fmt::Display>(mut self, context: C) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }
}

impl<E: StdError + Send + Sync + 'static> From<E> for Error {
    fn from(err: E) -> Error {
        let mut chain = vec![err.to_string()];
        let mut source = err.source();
        while let Some(s) = source {
            chain.push(s.to_string());
            source = s.source();
        }
        Error { chain }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            f.write_str(&self.chain.join(": "))
        } else {
            f.write_str(&self.chain[0])
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.chain[0])?;
        if self.chain.len() > 1 {
            f.write_str("\n\nCaused by:")?;
            for cause in &self.chain[1..] {
                write!(f, "\n    {cause}")?;
            }
        }
        Ok(())
    }
}

/// Extension trait adding `.context(..)` / `.with_context(..)`.
pub trait Context<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: StdError + Send + Sync + 'static> Context<T, E> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| Error::from(e).context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::from(e).context(f()))
    }
}

impl<T> Context<T, std::convert::Infallible> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string or a displayable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => { $crate::Error::msg(format!($msg)) };
    ($err:expr $(,)?) => { $crate::Error::msg(format!("{}", $err)) };
    ($fmt:expr, $($arg:tt)*) => { $crate::Error::msg(format!($fmt, $($arg)*)) };
}

/// Return early with an [`Error`].
#[macro_export]
macro_rules! bail {
    ($($tt:tt)*) => { return Err($crate::anyhow!($($tt)*)) };
}

/// Return early with an [`Error`] if a condition does not hold.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return Err($crate::anyhow!(concat!("condition failed: ", stringify!($cond))));
        }
    };
    ($cond:expr, $($tt:tt)+) => {
        if !($cond) {
            return Err($crate::anyhow!($($tt)+));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing file")
    }

    #[test]
    fn display_shows_outermost_alternate_shows_chain() {
        let e: Error = Result::<(), _>::Err(io_err()).context("loading config").unwrap_err();
        assert_eq!(format!("{e}"), "loading config");
        assert_eq!(format!("{e:#}"), "loading config: missing file");
    }

    #[test]
    fn macros_compose() {
        fn inner(flag: bool) -> Result<u32> {
            ensure!(flag, "flag was {flag}");
            if !flag {
                bail!("unreachable");
            }
            Ok(7)
        }
        assert_eq!(inner(true).unwrap(), 7);
        assert_eq!(format!("{}", inner(false).unwrap_err()), "flag was false");
        let e = anyhow!("x = {}", 3);
        assert_eq!(format!("{e}"), "x = 3");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn f() -> Result<String> {
            let s = std::str::from_utf8(&[0xff])?;
            Ok(s.to_string())
        }
        assert!(f().is_err());
    }
}
