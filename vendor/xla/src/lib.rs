//! Build-time stub of the `xla` crate (PJRT CPU bindings).
//!
//! The real crate links `xla_extension` and executes the AOT-compiled HLO
//! artifacts produced by `python/compile/aot.py`. This stub provides the
//! same type surface so the runtime layer compiles without the native
//! library, and fails at [`PjRtClient::cpu`] — the coordinator's
//! `auto_engine` then falls back to the pure-rust native mirror, which
//! implements identical math. Swap this path dependency for the real
//! bindings to enable the PJRT engine.

use std::fmt;
use std::rc::Rc;

#[derive(Debug)]
pub struct XlaError(String);

impl fmt::Display for XlaError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for XlaError {}

pub type Result<T> = std::result::Result<T, XlaError>;

fn unavailable() -> XlaError {
    XlaError("PJRT runtime unavailable: built against the vendored `xla` stub".into())
}

/// Element types accepted by device buffers.
pub trait ElementType: Copy {}
impl ElementType for f32 {}
impl ElementType for f64 {}

/// PJRT client handle. Like the real crate's client it is `Rc`-based and
/// not `Send`; executor threads each create their own.
pub struct PjRtClient {
    _not_send: Rc<()>,
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(unavailable())
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable())
    }

    pub fn buffer_from_host_buffer<T: ElementType>(
        &self,
        _data: &[T],
        _dims: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer> {
        Err(unavailable())
    }
}

pub struct PjRtLoadedExecutable(());

impl PjRtLoadedExecutable {
    pub fn execute_b(&self, _args: &[&PjRtBuffer]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable())
    }
}

pub struct PjRtBuffer(());

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable())
    }
}

pub struct Literal(());

impl Literal {
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        Err(unavailable())
    }

    pub fn array_shape(&self) -> Result<ArrayShape> {
        Err(unavailable())
    }

    pub fn to_vec<T: ElementType>(&self) -> Result<Vec<T>> {
        Err(unavailable())
    }
}

pub struct ArrayShape {
    dims: Vec<i64>,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }
}

pub struct HloModuleProto(());

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(unavailable())
    }
}

pub struct XlaComputation(());

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation(())
    }
}
