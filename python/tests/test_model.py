"""L2 correctness: the fused step ops implement Algorithm 1's forward step."""

import numpy as np
import jax.numpy as jnp
import pytest

from compile import model
from compile.kernels.ref import lsq_grad_obj_ref, logistic_grad_obj_ref

RNG = np.random.default_rng(11)


def make(n, d, binary=False):
    x = jnp.array(RNG.normal(size=(n, d)), jnp.float32)
    y = (
        jnp.array((RNG.random(n) > 0.5).astype(np.float32))
        if binary
        else jnp.array(RNG.normal(size=(n,)), jnp.float32)
    )
    w = jnp.array(RNG.normal(size=(d,)), jnp.float32)
    m = jnp.ones(n, jnp.float32)
    return x, y, w, m


class TestStepOps:
    @pytest.mark.parametrize("eta", [0.0, 1e-4, 0.01])
    def test_lsq_step_is_w_minus_eta_grad(self, eta):
        x, y, w, m = make(128, 20)
        u, obj = model.lsq_step(x, y, w, m, jnp.array([eta], jnp.float32))
        g, o_ref = lsq_grad_obj_ref(x, y, w, m)
        np.testing.assert_allclose(np.asarray(u), np.asarray(w - eta * g), rtol=1e-3, atol=1e-3)
        np.testing.assert_allclose(float(obj[0]), float(o_ref), rtol=1e-4)

    @pytest.mark.parametrize("eta", [0.0, 0.05])
    def test_logistic_step_is_w_minus_eta_grad(self, eta):
        x, y, w, m = make(128, 20, binary=True)
        u, obj = model.logistic_step(x, y, w, m, jnp.array([eta], jnp.float32))
        g, o_ref = logistic_grad_obj_ref(x, y, w, m)
        np.testing.assert_allclose(np.asarray(u), np.asarray(w - eta * g), rtol=1e-3, atol=1e-3)
        np.testing.assert_allclose(float(obj[0]), float(o_ref), rtol=1e-4, atol=1e-4)

    def test_zero_eta_returns_w(self):
        x, y, w, m = make(128, 10)
        u, _ = model.lsq_step(x, y, w, m, jnp.array([0.0], jnp.float32))
        np.testing.assert_array_equal(np.asarray(u), np.asarray(w))

    def test_step_decreases_lsq_objective(self):
        """One gradient step with a safe η must not increase the loss."""
        x, y, w, m = make(256, 15)
        lip = 2.0 * float(jnp.linalg.norm(x, 2)) ** 2
        eta = jnp.array([1.0 / lip], jnp.float32)
        u, obj0 = model.lsq_step(x, y, w, m, eta)
        _, obj1 = model.lsq_step(x, y, u, m, eta)
        assert float(obj1[0]) <= float(obj0[0]) + 1e-5

    def test_grad_ops_match_step_ops(self):
        x, y, w, m = make(128, 12)
        g, o1 = model.lsq_grad(x, y, w, m)
        eta = 0.01
        u, o2 = model.lsq_step(x, y, w, m, jnp.array([eta], jnp.float32))
        np.testing.assert_allclose(np.asarray(u), np.asarray(w - eta * g), rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(float(o1[0]), float(o2[0]), rtol=1e-6)


class TestGradientDescentConvergence:
    def test_gd_with_step_op_converges_on_consistent_system(self):
        """Repeatedly applying lsq_step drives w to the planted solution."""
        n, d = 256, 8
        x = jnp.array(RNG.normal(size=(n, d)), jnp.float32)
        w_star = jnp.array(RNG.normal(size=(d,)), jnp.float32)
        y = x @ w_star
        m = jnp.ones(n, jnp.float32)
        lip = 2.0 * float(jnp.linalg.norm(x, 2)) ** 2
        eta = jnp.array([1.0 / lip], jnp.float32)
        w = jnp.zeros(d, jnp.float32)
        for _ in range(300):
            w, _ = model.lsq_step(x, y, w, m, eta)
        assert float(jnp.linalg.norm(w - w_star)) < 1e-2
