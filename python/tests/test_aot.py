"""AOT pipeline: lowering produces loadable, custom-call-free HLO text and a
well-formed manifest."""

import json
import os
import subprocess
import sys

import pytest

from compile import aot


class TestLowering:
    @pytest.mark.parametrize(
        "op,dims",
        [
            ("lsq_step", (128, 50)),
            ("lsq_grad", (128, 28)),
            ("logistic_step", (128, 10)),
            ("logistic_grad", (128, 50)),
            ("prox_l21", (128, 8)),
        ],
    )
    def test_lower_one_emits_hlo_text(self, op, dims):
        text, sig = aot.lower_one(op, dims)
        assert text.startswith("HloModule")
        assert "ENTRY" in text
        assert "inputs" in sig and "outputs" in sig

    def test_no_custom_calls(self):
        """xla_extension 0.5.1 cannot execute typed-FFI custom calls; every
        artifact must lower to plain HLO (this is why SVT lives in rust)."""
        for op, dims in [("lsq_step", (128, 50)), ("logistic_step", (128, 10)), ("prox_l21", (128, 8))]:
            text, _ = aot.lower_one(op, dims)
            assert "custom_call" not in text, f"{op} contains a custom call"

    def test_step_artifact_has_five_params(self):
        text, _ = aot.lower_one("lsq_step", (128, 50))
        entry = [l for l in text.splitlines() if l.startswith("ENTRY")]
        assert len(entry) == 1
        # x, y, w, mask, eta
        assert entry[0].count("parameter") >= 0  # parameters appear in body
        params = [l for l in text.splitlines() if " parameter(" in l and "ENTRY" not in l]
        # The entry computation has exactly 5 parameters (sub-computations may add more).
        nums = {l.split("parameter(")[1].split(")")[0] for l in params}
        assert {"0", "1", "2", "3", "4"} <= nums


class TestManifest:
    def test_quick_table_covers_all_ops(self):
        table = aot.shape_table(quick=True)
        assert set(table) == {"lsq_step", "lsq_grad", "logistic_step", "logistic_grad", "prox_l21"}

    def test_full_table_covers_experiment_buckets(self):
        table = aot.shape_table(quick=False)
        lsq = set(table["lsq_step"])
        # Fig 3a/b/table I buckets
        for n in (128, 512, 1024, 8192, 16384):
            assert (n, 50) in lsq
        # Fig 3c d-sweep
        for d in (10, 25, 100, 200, 400):
            assert (128, d) in lsq
        # School buckets
        assert (128, 28) in lsq and (256, 28) in lsq
        # MNIST / MTFL logistic buckets
        logi = set(table["logistic_step"])
        assert (16384, 100) in logi
        for n in (4096, 8192, 16384):
            assert (n, 10) in logi

    def test_all_ns_are_tile_multiples(self):
        from compile.kernels import TILE_N, TILE_D

        table = aot.shape_table(quick=False)
        for op, shapes in table.items():
            for dims in shapes:
                if op == "prox_l21":
                    assert dims[0] % TILE_D == 0
                else:
                    assert dims[0] % TILE_N == 0

    def test_cli_quick_writes_manifest(self, tmp_path):
        out = tmp_path / "arts"
        subprocess.run(
            [sys.executable, "-m", "compile.aot", "--quick", "--out-dir", str(out)],
            check=True,
            cwd=os.path.dirname(os.path.dirname(os.path.abspath(aot.__file__))),
            timeout=300,
        )
        manifest = json.loads((out / "manifest.json").read_text())
        assert manifest["version"] == 1
        assert manifest["tile_n"] == 128
        for e in manifest["entries"]:
            assert (out / e["file"]).exists()
            assert set(e) >= {"op", "n", "d", "t", "file", "inputs", "outputs", "sha256"}
