"""L1 correctness: Pallas kernels vs pure-jnp oracles.

Fixed-shape smoke tests plus hypothesis sweeps over shapes, masks and value
scales. The hypothesis sweeps are the CORE correctness signal for the
kernels: every (n, d) with n a TILE_N multiple must agree with the literal
math in ref.py.
"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import lsq_grad_obj, logistic_grad_obj, prox_l21, TILE_N, TILE_D
from compile.kernels.ref import (
    lsq_grad_obj_ref,
    logistic_grad_obj_ref,
    prox_l21_ref,
)

RNG = np.random.default_rng(7)


def make_task(n, d, scale=1.0, mask_frac=1.0, binary=False, seed=None):
    rng = np.random.default_rng(seed) if seed is not None else RNG
    x = jnp.array(rng.normal(scale=scale, size=(n, d)), jnp.float32)
    if binary:
        y = jnp.array((rng.random(n) > 0.5).astype(np.float32))
    else:
        y = jnp.array(rng.normal(scale=scale, size=(n,)), jnp.float32)
    w = jnp.array(rng.normal(size=(d,)), jnp.float32)
    m = jnp.array((rng.random(n) < mask_frac).astype(np.float32))
    return x, y, w, m


def assert_close(a, b, rtol=2e-4, atol=2e-4):
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=rtol, atol=atol)


# ---------------------------------------------------------------- lsq kernel

class TestLsqKernel:
    @pytest.mark.parametrize("n,d", [(128, 1), (128, 7), (128, 50), (256, 28), (384, 13), (512, 64)])
    def test_matches_ref(self, n, d):
        x, y, w, m = make_task(n, d)
        g, o = lsq_grad_obj(x, y, w, m)
        gr, orr = lsq_grad_obj_ref(x, y, w, m)
        assert_close(g, gr, rtol=1e-3, atol=1e-3)
        assert_close(o, orr, rtol=1e-4)

    def test_full_mask_equals_unmasked_math(self):
        x, y, w, _ = make_task(128, 10)
        m = jnp.ones(128, jnp.float32)
        g, o = lsq_grad_obj(x, y, w, m)
        r = np.asarray(x) @ np.asarray(w) - np.asarray(y)
        assert_close(g, 2 * np.asarray(x).T @ r, rtol=1e-3, atol=1e-3)
        assert_close(o, np.sum(r * r), rtol=1e-4)

    def test_zero_mask_gives_zero(self):
        x, y, w, _ = make_task(256, 20)
        m = jnp.zeros(256, jnp.float32)
        g, o = lsq_grad_obj(x, y, w, m)
        assert float(jnp.abs(g).max()) == 0.0
        assert float(o) == 0.0

    def test_padding_rows_are_exact(self):
        """Zero rows + zero mask ≡ the unpadded problem (bucket correctness)."""
        n, d, n_pad = 100, 12, 128
        x, y, w, _ = make_task(n, d)
        xp = jnp.zeros((n_pad, d), jnp.float32).at[:n].set(x)
        yp = jnp.zeros((n_pad,), jnp.float32).at[:n].set(y)
        mp = jnp.zeros((n_pad,), jnp.float32).at[:n].set(1.0)
        g, o = lsq_grad_obj(xp, yp, w, mp)
        gr, orr = lsq_grad_obj_ref(x, y, w, jnp.ones(n, jnp.float32))
        assert_close(g, gr, rtol=1e-3, atol=1e-3)
        assert_close(o, orr, rtol=1e-4)

    def test_padding_cols_are_exact(self):
        """Zero feature cols + zero w entries produce exactly zero grad there."""
        n, d, d_pad = 128, 10, 16
        x, y, w, m = make_task(n, d)
        xp = jnp.zeros((n, d_pad), jnp.float32).at[:, :d].set(x)
        wp = jnp.zeros((d_pad,), jnp.float32).at[:d].set(w)
        g, o = lsq_grad_obj(xp, y, wp, m)
        gr, orr = lsq_grad_obj_ref(x, y, w, m)
        assert_close(g[:d], gr, rtol=1e-3, atol=1e-3)
        assert float(jnp.abs(g[d:]).max()) == 0.0
        assert_close(o, orr, rtol=1e-4)

    def test_gradient_at_optimum_is_zero(self):
        """For consistent y = Xw*, gradient at w* vanishes."""
        n, d = 128, 5
        x, _, w, _ = make_task(n, d)
        y = x @ w
        m = jnp.ones(n, jnp.float32)
        g, o = lsq_grad_obj(x, y, w, m)
        assert float(jnp.abs(g).max()) < 1e-3
        assert float(o) < 1e-6

    @settings(max_examples=25, deadline=None)
    @given(
        tiles=st.integers(1, 3),
        d=st.integers(1, 64),
        scale=st.sampled_from([0.01, 1.0, 10.0]),
        mask_frac=st.floats(0.0, 1.0),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_hypothesis_sweep(self, tiles, d, scale, mask_frac, seed):
        n = tiles * TILE_N
        x, y, w, m = make_task(n, d, scale=scale, mask_frac=mask_frac, seed=seed)
        g, o = lsq_grad_obj(x, y, w, m)
        gr, orr = lsq_grad_obj_ref(x, y, w, m)
        tol = 2e-3 * max(1.0, scale * scale)
        assert_close(g, gr, rtol=tol, atol=tol * 10)
        assert_close(o, orr, rtol=1e-3, atol=1e-3)


# ----------------------------------------------------------- logistic kernel

class TestLogisticKernel:
    @pytest.mark.parametrize("n,d", [(128, 1), (128, 50), (256, 28), (512, 10)])
    def test_matches_ref(self, n, d):
        x, y, w, m = make_task(n, d, binary=True)
        g, o = logistic_grad_obj(x, y, w, m)
        gr, orr = logistic_grad_obj_ref(x, y, w, m)
        assert_close(g, gr, rtol=1e-3, atol=1e-3)
        assert_close(o, orr, rtol=1e-4, atol=1e-4)

    def test_objective_nonnegative(self):
        x, y, w, m = make_task(256, 30, binary=True)
        _, o = logistic_grad_obj(x, y, w, m)
        assert float(o) >= 0.0

    def test_extreme_logits_stay_finite(self):
        """softplus must not overflow for |z| ~ 1e3."""
        x, y, w, m = make_task(128, 4, scale=30.0, binary=True)
        g, o = logistic_grad_obj(x, y, w, m)
        assert np.isfinite(np.asarray(g)).all()
        assert np.isfinite(float(o))
        gr, orr = logistic_grad_obj_ref(x, y, w, m)
        assert_close(g, gr, rtol=1e-3, atol=1e-3)
        assert_close(o, orr, rtol=1e-4, atol=1e-3)

    def test_padding_rows_are_exact(self):
        """σ(0) − 0 = 0.5 ≠ 0, so the mask is load-bearing for logistic."""
        n, d, n_pad = 77, 8, 128
        x, y, w, _ = make_task(n, d, binary=True)
        xp = jnp.zeros((n_pad, d), jnp.float32).at[:n].set(x)
        yp = jnp.zeros((n_pad,), jnp.float32).at[:n].set(y)
        mp = jnp.zeros((n_pad,), jnp.float32).at[:n].set(1.0)
        g, o = logistic_grad_obj(xp, yp, w, mp)
        gr, orr = logistic_grad_obj_ref(x, y, w, jnp.ones(n, jnp.float32))
        assert_close(g, gr, rtol=1e-3, atol=1e-3)
        assert_close(o, orr, rtol=1e-4, atol=1e-4)

    @settings(max_examples=25, deadline=None)
    @given(
        tiles=st.integers(1, 3),
        d=st.integers(1, 64),
        mask_frac=st.floats(0.0, 1.0),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_hypothesis_sweep(self, tiles, d, mask_frac, seed):
        n = tiles * TILE_N
        x, y, w, m = make_task(n, d, mask_frac=mask_frac, binary=True, seed=seed)
        g, o = logistic_grad_obj(x, y, w, m)
        gr, orr = logistic_grad_obj_ref(x, y, w, m)
        assert_close(g, gr, rtol=2e-3, atol=2e-3)
        assert_close(o, orr, rtol=1e-3, atol=1e-3)


# ------------------------------------------------------------ prox_l21 kernel

class TestProxL21:
    @pytest.mark.parametrize("d,t", [(128, 1), (128, 8), (256, 16), (384, 5)])
    def test_matches_ref(self, d, t):
        w = jnp.array(RNG.normal(size=(d, t)), jnp.float32)
        th = jnp.array([1.5], jnp.float32)
        assert_close(prox_l21(w, th), prox_l21_ref(w, 1.5), rtol=1e-5, atol=1e-6)

    def test_zero_threshold_is_identity(self):
        w = jnp.array(RNG.normal(size=(128, 4)), jnp.float32)
        out = prox_l21(w, jnp.array([0.0], jnp.float32))
        assert_close(out, w, rtol=1e-6, atol=1e-7)

    def test_large_threshold_kills_all_rows(self):
        w = jnp.array(RNG.normal(size=(128, 4)), jnp.float32)
        out = prox_l21(w, jnp.array([1e6], jnp.float32))
        assert float(jnp.abs(out).max()) == 0.0

    def test_zero_rows_stay_zero(self):
        w = jnp.zeros((128, 4), jnp.float32)
        out = prox_l21(w, jnp.array([0.5], jnp.float32))
        assert float(jnp.abs(out).max()) == 0.0

    def test_shrinks_row_norms_exactly(self):
        w = jnp.array(RNG.normal(size=(128, 6)), jnp.float32)
        th = 0.7
        out = np.asarray(prox_l21(w, jnp.array([th], jnp.float32)))
        before = np.linalg.norm(np.asarray(w), axis=1)
        after = np.linalg.norm(out, axis=1)
        expect = np.maximum(before - th, 0.0)
        np.testing.assert_allclose(after, expect, rtol=1e-4, atol=1e-5)

    def test_padded_cols_are_exact(self):
        """Zero columns (bucketed T) neither perturb row norms nor outputs."""
        w = jnp.array(RNG.normal(size=(128, 5)), jnp.float32)
        wp = jnp.zeros((128, 8), jnp.float32).at[:, :5].set(w)
        out_p = prox_l21(wp, jnp.array([0.9], jnp.float32))
        out = prox_l21_ref(w, 0.9)
        assert_close(out_p[:, :5], out, rtol=1e-5, atol=1e-6)
        assert float(jnp.abs(out_p[:, 5:]).max()) == 0.0

    @settings(max_examples=20, deadline=None)
    @given(
        tiles=st.integers(1, 3),
        t=st.integers(1, 24),
        th=st.floats(0.0, 5.0),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_hypothesis_sweep(self, tiles, t, th, seed):
        rng = np.random.default_rng(seed)
        w = jnp.array(rng.normal(size=(tiles * TILE_D, t)), jnp.float32)
        out = prox_l21(w, jnp.array([th], jnp.float32))
        assert_close(out, prox_l21_ref(w, th), rtol=1e-4, atol=1e-5)

    def test_nonexpansive(self):
        """prox of a convex function is non-expansive (a KM-iteration
        prerequisite the AMTL convergence proof leans on)."""
        a = jnp.array(RNG.normal(size=(128, 6)), jnp.float32)
        b = jnp.array(RNG.normal(size=(128, 6)), jnp.float32)
        th = jnp.array([1.1], jnp.float32)
        pa, pb = prox_l21(a, th), prox_l21(b, th)
        assert float(jnp.linalg.norm(pa - pb)) <= float(jnp.linalg.norm(a - b)) + 1e-5
