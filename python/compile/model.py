"""Layer-2 JAX model: the per-task AMTL computations, composed from the
Layer-1 Pallas kernels.

Entry points (each is AOT-lowered per shape bucket by :mod:`aot`):

``lsq_step(x, y, w, mask, eta)  -> (u, obj)``
    The fused forward step of Algorithm 1 for a least-squares task:
    ``u = w − η ∇ℓ(w)`` with ``∇ℓ(w) = 2 Xᵀ(m ∘ (Xw − y))``, plus the loss
    value at ``w`` (free — the residual is already in VMEM).

``logistic_step(x, y, w, mask, eta) -> (u, obj)``
    Same for a logistic task.

``lsq_grad / logistic_grad (x, y, w, mask) -> (g, obj)``
    Raw gradient + objective, used by the centralized FISTA baseline and by
    integration tests.

``prox_l21(w, thresh) -> w'``
    Server-side backward step for the ℓ2,1 regularizer (the nuclear-norm SVT
    runs natively in rust — its SVD does not lower to executable HLO on the
    CPU plugin, see DESIGN.md).

``eta`` and ``thresh`` are runtime scalars (shape-``(1,)`` inputs) so one
artifact per data shape serves every step-size/regularization setting.
"""

import jax
import jax.numpy as jnp

from .kernels import lsq_grad_obj, logistic_grad_obj, prox_l21 as _prox_l21


def lsq_step(x, y, w, mask, eta):
    g, obj = lsq_grad_obj(x, y, w, mask)
    return w - eta[0] * g, jnp.reshape(obj, (1,))


def logistic_step(x, y, w, mask, eta):
    g, obj = logistic_grad_obj(x, y, w, mask)
    return w - eta[0] * g, jnp.reshape(obj, (1,))


def lsq_grad(x, y, w, mask):
    g, obj = lsq_grad_obj(x, y, w, mask)
    return g, jnp.reshape(obj, (1,))


def logistic_grad(x, y, w, mask):
    g, obj = logistic_grad_obj(x, y, w, mask)
    return g, jnp.reshape(obj, (1,))


def prox_l21(w, thresh):
    return (_prox_l21(w, thresh),)


def data_specs(n: int, d: int, dtype=jnp.float32):
    """Example-arg specs for the per-task entry points at bucket ``(n, d)``."""
    return (
        jax.ShapeDtypeStruct((n, d), dtype),  # x
        jax.ShapeDtypeStruct((n,), dtype),  # y
        jax.ShapeDtypeStruct((d,), dtype),  # w
        jax.ShapeDtypeStruct((n,), dtype),  # mask
    )


def scalar_spec(dtype=jnp.float32):
    return jax.ShapeDtypeStruct((1,), dtype)


# op name -> (callable, spec builder). Spec builders take the bucket dims.
STEP_OPS = {
    "lsq_step": lsq_step,
    "logistic_step": logistic_step,
}
GRAD_OPS = {
    "lsq_grad": lsq_grad,
    "logistic_grad": logistic_grad,
}
