"""AOT compile path: lower every (op, shape-bucket) pair to HLO *text* and
write ``artifacts/manifest.json`` for the rust runtime.

HLO text — not a serialized ``HloModuleProto`` — is the interchange format:
jax ≥ 0.5 emits protos with 64-bit instruction ids that the rust side's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Shape buckets: XLA programs are shape-static, so each per-task op is lowered
once per ``(n, d)`` bucket with ``n`` a multiple of TILE_N=128; the rust
runtime zero-pads each task's data up to the nearest bucket and passes a row
mask (padding is exact — DESIGN.md §Shape-buckets).

Usage:  python -m compile.aot [--out-dir ../artifacts] [--quick]
"""

import argparse
import hashlib
import json
import os

import jax
from jax._src.lib import xla_client as xc

from . import model
from .kernels import TILE_N, TILE_D


def to_hlo_text(lowered) -> str:
    """StableHLO MLIR → XlaComputation → HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


# ---------------------------------------------------------------------------
# Bucket tables. One entry per artifact; every bench/example shape in
# DESIGN.md's experiment index maps into one of these buckets.
# ---------------------------------------------------------------------------

def shape_table(quick: bool):
    """Returns {op: [(n, d) or (d, t)]} for the full or quick artifact set."""
    if quick:
        return {
            "lsq_step": [(128, 50), (256, 28)],
            "lsq_grad": [(128, 50)],
            "logistic_step": [(128, 50)],
            "logistic_grad": [(128, 50)],
            "prox_l21": [(128, 8)],
        }
    lsq_step = []
    # Fig 3a/3b, Table I, Fig 4, Tables IV–VI: d=50, n swept / bucketed.
    for n in (128, 256, 512, 1024, 2048, 4096, 8192, 16384):
        lsq_step.append((n, 50))
    # Fig 3c: d swept at n=100→128. d=128 additionally matches the
    # prox_l21 artifact tile (full-PJRT ℓ2,1 path).
    for d in (10, 25, 100, 128, 200, 400):
        lsq_step.append((128, d))
    # School (Table III): d=28, n ∈ 22–251 → buckets 128, 256.
    lsq_step += [(128, 28), (256, 28)]
    logistic_step = [
        (16384, 100),  # MNIST-sim: 5 binary tasks, n ≤ 14702, d=100
        (4096, 10),    # MTFL-sim: 4 binary tasks, n ∈ 2224–10000, d=10
        (8192, 10),
        (16384, 10),
        (128, 50),     # tests / small demos
    ]
    return {
        "lsq_step": lsq_step,
        "lsq_grad": [(128, 50), (128, 28), (256, 28), (256, 50)],
        "logistic_step": logistic_step,
        "logistic_grad": [(128, 50)],
        "prox_l21": [(128, 8), (128, 16), (128, 32)],
    }


STEP_SIG = {
    "inputs": ["x[n,d]", "y[n]", "w[d]", "mask[n]", "eta[1]"],
    "outputs": ["u[d]", "obj[1]"],
}
GRAD_SIG = {
    "inputs": ["x[n,d]", "y[n]", "w[d]", "mask[n]"],
    "outputs": ["g[d]", "obj[1]"],
}
PROX_SIG = {"inputs": ["w[d,t]", "thresh[1]"], "outputs": ["w[d,t]"]}


def lower_one(op: str, dims):
    if op in model.STEP_OPS:
        n, d = dims
        fn = model.STEP_OPS[op]
        args = (*model.data_specs(n, d), model.scalar_spec())
        sig = STEP_SIG
    elif op in model.GRAD_OPS:
        n, d = dims
        fn = model.GRAD_OPS[op]
        args = model.data_specs(n, d)
        sig = GRAD_SIG
    elif op == "prox_l21":
        d, t = dims
        fn = model.prox_l21
        args = (
            jax.ShapeDtypeStruct((d, t), "float32"),
            model.scalar_spec(),
        )
        sig = PROX_SIG
    else:
        raise ValueError(f"unknown op {op}")
    lowered = jax.jit(fn).lower(*args)
    return to_hlo_text(lowered), sig


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--quick", action="store_true", help="small artifact set for CI")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    table = shape_table(args.quick)
    entries = []
    for op, shapes in table.items():
        for dims in shapes:
            text, sig = lower_one(op, dims)
            if op == "prox_l21":
                d, t = dims
                name = f"{op}_d{d}_t{t}.hlo.txt"
                meta = {"op": op, "n": 0, "d": d, "t": t}
            else:
                n, d = dims
                name = f"{op}_n{n}_d{d}.hlo.txt"
                meta = {"op": op, "n": n, "d": d, "t": 0}
            path = os.path.join(args.out_dir, name)
            with open(path, "w") as f:
                f.write(text)
            entries.append(
                {
                    **meta,
                    "file": name,
                    "sha256": hashlib.sha256(text.encode()).hexdigest()[:16],
                    **sig,
                }
            )
            print(f"  wrote {name}  ({len(text)} chars)")

    manifest = {
        "version": 1,
        "tile_n": TILE_N,
        "tile_d": TILE_D,
        "entries": entries,
    }
    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"manifest: {len(entries)} artifacts -> {args.out_dir}/manifest.json")


if __name__ == "__main__":
    main()
