"""Pure-jnp oracles for every Pallas kernel — the correctness ground truth.

These are deliberately written in the most literal form of the math (no
tiling, no masking tricks beyond the definition) so that a bug in the kernels
and a bug in the oracle are maximally unlikely to coincide. pytest/hypothesis
sweep shapes and compare kernel vs oracle with `assert_allclose`.
"""

import jax
import jax.numpy as jnp

from .common import softplus


def lsq_grad_obj_ref(x, y, w, mask):
    r = (x @ w - y) * mask
    g = 2.0 * (x.T @ r)
    obj = jnp.sum(r * r)
    return g, obj


def logistic_grad_obj_ref(x, y, w, mask):
    z = x @ w
    g = x.T @ ((jax.nn.sigmoid(z) - y) * mask)
    obj = jnp.sum(mask * (softplus(z) - y * z))
    return g, obj


def prox_l21_ref(w, thresh):
    nrm = jnp.linalg.norm(w, axis=1, keepdims=True)
    scale = jnp.where(nrm > 0, jnp.maximum(nrm - thresh, 0.0) / jnp.maximum(nrm, 1e-30), 0.0)
    return w * scale


def prox_nuclear_ref(w, thresh):
    """SVT oracle — used to validate the rust-native Jacobi-SVD prox."""
    u, s, vt = jnp.linalg.svd(w, full_matrices=False)
    s = jnp.maximum(s - thresh, 0.0)
    return (u * s[None, :]) @ vt
