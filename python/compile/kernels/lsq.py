"""Masked least-squares gradient + objective as a single-pass Pallas kernel.

For task data ``X ∈ R^{n×d}``, ``y ∈ R^n``, model ``w ∈ R^d`` and a row mask
``m ∈ {0,1}^n`` (1 for real rows, 0 for shape-bucket padding), computes in a
single streaming pass over ``X``:

    g   = 2 · Xᵀ (m ∘ (X w − y))        — gradient of  Σ_i m_i (x_i·w − y_i)²
    obj = Σ_i m_i (x_i·w − y_i)²

The fused objective is free: the residual tile is already in VMEM for the
gradient contraction. The grid walks ``n / TILE_N`` row slabs; the gradient
accumulator lives in the output ref (revisited at every grid step, block
index pinned to 0), which is the standard Pallas reduction idiom.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import TILE_N, tile_n_for


def _lsq_kernel(x_ref, y_ref, w_ref, m_ref, g_ref, obj_ref):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        g_ref[...] = jnp.zeros_like(g_ref)
        obj_ref[...] = jnp.zeros_like(obj_ref)

    x = x_ref[...]  # (TILE_N, d) slab, staged through VMEM
    r = (x @ w_ref[...] - y_ref[...]) * m_ref[...]  # masked residual tile
    g_ref[...] += 2.0 * (r @ x)  # (TILE_N,)·(TILE_N,d) → (d,) MXU contraction
    obj_ref[...] += jnp.sum(r * r)[None]  # m ∈ {0,1} ⇒ (m·r)² = m·r²


@functools.partial(jax.jit, static_argnames=("interpret",))
def lsq_grad_obj(x, y, w, mask, interpret=True):
    """Returns ``(g, obj)`` for the masked least-squares loss.

    ``x.shape[0]`` must be a multiple of ``TILE_N`` (the AOT shape buckets
    guarantee this; tests pad explicitly).
    """
    n, d = x.shape
    assert n % TILE_N == 0, f"n={n} must be a multiple of TILE_N={TILE_N}"
    tile = tile_n_for(n, d)
    grid = (n // tile,)
    g, obj = pl.pallas_call(
        _lsq_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile, d), lambda i: (i, 0)),
            pl.BlockSpec((tile,), lambda i: (i,)),
            pl.BlockSpec((d,), lambda i: (0,)),
            pl.BlockSpec((tile,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((d,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((d,), x.dtype),
            jax.ShapeDtypeStruct((1,), x.dtype),
        ],
        interpret=interpret,
    )(x, y, w, mask)
    return g, obj[0]
