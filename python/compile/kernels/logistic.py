"""Masked logistic-regression gradient + objective as a Pallas kernel.

Labels are ``y ∈ {0,1}``. For ``z = X w``:

    g   = Xᵀ (m ∘ (σ(z) − y))
    obj = Σ_i m_i (softplus(z_i) − y_i z_i)

Same streaming structure as :mod:`lsq` — one pass over ``(TILE_N, d)`` slabs,
``d``-sized accumulator pinned in the output ref. ``softplus`` is the stable
form ``max(z,0) + log1p(exp(−|z|))`` so padded rows (z=0) stay finite, and
the row mask zeroes their contribution exactly.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import TILE_N, softplus, tile_n_for


def _logistic_kernel(x_ref, y_ref, w_ref, m_ref, g_ref, obj_ref):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        g_ref[...] = jnp.zeros_like(g_ref)
        obj_ref[...] = jnp.zeros_like(obj_ref)

    x = x_ref[...]
    y = y_ref[...]
    m = m_ref[...]
    z = x @ w_ref[...]
    r = (jax.nn.sigmoid(z) - y) * m
    g_ref[...] += r @ x
    obj_ref[...] += jnp.sum(m * (softplus(z) - y * z))[None]


@functools.partial(jax.jit, static_argnames=("interpret",))
def logistic_grad_obj(x, y, w, mask, interpret=True):
    """Returns ``(g, obj)`` for the masked logistic loss."""
    n, d = x.shape
    assert n % TILE_N == 0, f"n={n} must be a multiple of TILE_N={TILE_N}"
    tile = tile_n_for(n, d)
    grid = (n // tile,)
    g, obj = pl.pallas_call(
        _logistic_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile, d), lambda i: (i, 0)),
            pl.BlockSpec((tile,), lambda i: (i,)),
            pl.BlockSpec((d,), lambda i: (0,)),
            pl.BlockSpec((tile,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((d,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((d,), x.dtype),
            jax.ShapeDtypeStruct((1,), x.dtype),
        ],
        interpret=interpret,
    )(x, y, w, mask)
    return g, obj[0]
