"""Row-wise ℓ2,1 proximal operator (group soft-threshold) as a Pallas kernel.

For the joint-feature-learning regularizer ``g(W) = ||W||_{2,1}`` the
backward step is separable over rows of ``W ∈ R^{d×T}``:

    prox(w_i) = w_i · max(0, 1 − t / ||w_i||₂)

This is the one MTL prox that *is* block-separable, so it can run as an L1
kernel on the server path (the nuclear-norm SVT is not — it runs natively in
rust, see DESIGN.md). The grid walks ``d / TILE_D`` row slabs; ``T`` is
carried whole in the minor dimension. Zero-padded columns (bucketed T) do not
perturb row norms and map to zero outputs — padding is exact.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import TILE_D


def _l21_kernel(w_ref, t_ref, o_ref):
    w = w_ref[...]  # (TILE_D, T)
    nrm = jnp.sqrt(jnp.sum(w * w, axis=1, keepdims=True))
    # max(0, 1 - t/||w||) with a guarded divide; rows with ||w|| <= t → 0.
    scale = jnp.maximum(nrm - t_ref[0], 0.0) / jnp.maximum(nrm, 1e-30)
    o_ref[...] = w * scale


@functools.partial(jax.jit, static_argnames=("interpret",))
def prox_l21(w, thresh, interpret=True):
    """Row-wise group soft-threshold of ``w`` (shape ``(d, T)``) at ``thresh``.

    ``thresh`` is a shape-``(1,)`` array so it stays a runtime input in the
    AOT artifact (the rust side passes ``η·λ`` per call).
    """
    d, t = w.shape
    assert d % TILE_D == 0, f"d={d} must be a multiple of TILE_D={TILE_D}"
    grid = (d // TILE_D,)
    return pl.pallas_call(
        _l21_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((TILE_D, t), lambda i: (i, 0)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((TILE_D, t), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((d, t), w.dtype),
        interpret=interpret,
    )(w, thresh)
