"""Shared tiling configuration and helpers for the Pallas kernels.

Tiling rationale (TPU mapping, estimated analytically since we execute under
``interpret=True`` on CPU):

* ``TILE_N = 128`` rows per grid step. A slab ``(128, d)`` of ``X`` in f32
  occupies ``128 * d * 4`` bytes of VMEM — 25.6 KB at d=50, 256 KB at d=512,
  comfortably inside the ~16 MB VMEM budget together with the ``d``-sized
  accumulator, ``w``, and the residual tile.
* The two contractions per slab — ``X_tile @ w`` (``(128,d)×(d,)``) and
  ``r @ X_tile`` (``(128,)×(128,d)``) — are MXU-shaped matvecs; at d≥128 the
  systolic array is fully occupied along one dimension (utilization estimate
  in DESIGN.md §Perf).
* ``TILE_D = 128`` rows per grid step for the row-wise prox kernel.
"""

import jax.numpy as jnp

TILE_N = 128
TILE_D = 128

# VMEM budget for one (tile, d) f32 slab of X. With double buffering the
# HBM→VMEM pipeline holds 2 slabs + the residual tile + the d-sized
# accumulator; a 2 MB slab keeps the total well under a 16 MB VMEM.
SLAB_BYTES = 2 * 1024 * 1024


def tile_n_for(n: int, d: int) -> int:
    """Adaptive row-tile: the largest power-of-two tile that divides ``n``
    while keeping the f32 slab ``(tile, d)`` within :data:`SLAB_BYTES`.

    Perf note (EXPERIMENTS.md §Perf): with a fixed TILE_N=128, an
    ``n=16384`` bucket lowers to a 128-trip grid loop; under the CPU
    interpret path each trip pays dynamic-slice overhead, and on TPU each
    trip is a separate HBM→VMEM transfer of a thin slab. Scaling the tile
    to the VMEM budget cut the measured per-step latency ~17x on the
    MNIST bucket (147 ms → 8.7 ms, see EXPERIMENTS.md §Perf).
    """
    max_tile = max(TILE_N, SLAB_BYTES // (4 * max(d, 1)))
    t = TILE_N
    while t * 2 <= min(n, max_tile) and n % (t * 2) == 0:
        t *= 2
    return t


def round_up(x: int, m: int) -> int:
    """Smallest multiple of ``m`` that is >= ``x``."""
    return ((x + m - 1) // m) * m


def softplus(z):
    """Numerically stable ``log(1 + exp(z))``."""
    return jnp.maximum(z, 0.0) + jnp.log1p(jnp.exp(-jnp.abs(z)))
