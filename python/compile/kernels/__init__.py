"""Layer-1 Pallas kernels for AMTL.

The compute hot spot of every AMTL iteration is the per-task forward step:
a masked gradient of the task loss over the task's local data ``(x_t, y_t)``.
Each kernel streams ``(TILE_N, d)`` slabs of ``X`` through VMEM with a
``d``-sized accumulator, which is the TPU-idiomatic shape for an
``X^T(residual)`` contraction (see DESIGN.md §Hardware-adaptation).

All kernels are lowered with ``interpret=True``: the CPU PJRT plugin cannot
execute Mosaic custom-calls, and interpret mode lowers to plain HLO that the
rust runtime's CPU client runs directly.
"""

from .lsq import lsq_grad_obj
from .logistic import logistic_grad_obj
from .prox import prox_l21
from .common import TILE_N, TILE_D

__all__ = ["lsq_grad_obj", "logistic_grad_obj", "prox_l21", "TILE_N", "TILE_D"]
