//! The chaos smoke/soak storm driver: many-node fault storms with
//! machine-checked invariants, reproducible from one printed seed.
//!
//! ```text
//! cargo run --release --example chaos_run -- --quick            # CI smoke
//! cargo run --release --example chaos_run                       # full sweep
//! cargo run --release --example chaos_run -- --seed 4242        # repro a failure
//! cargo run --release --example chaos_run -- --out /tmp/chaos   # artifact dir
//! ```
//!
//! Each storm runs a seed-reproducible [`ChaosPlan`] — a correlated
//! crash/restart wave, per-activation commit drops, and straggler links —
//! next to an undisturbed reference run, then machine-checks four
//! invariant families over the obs traces and results: exactly-once
//! commit application, convergence within tolerance, balanced
//! eviction/re-register bookkeeping, and (under semisync) the staleness
//! bound. Any violation prints the storm's repro line and exits nonzero;
//! the JSONL traces stay in the artifact directory for CI upload.

use amtl::chaos::{run_resumed_storm, run_storm, ChaosPlan, ScheduleChoice, StormReport};
use amtl::coordinator::MtlProblem;
use amtl::data::synthetic;
use amtl::obs::{Collector, HealthRules};
use amtl::optim::prox::RegularizerKind;
use amtl::transport::wire::MetricsReport;
use amtl::transport::TransportKind;
use amtl::util::Rng;
use std::path::{Path, PathBuf};

fn problem(seed: u64, nodes: usize) -> MtlProblem {
    let mut rng = Rng::new(seed);
    let ds = synthetic::lowrank_regression(&vec![40; nodes], 8, 3, 0.1, &mut rng);
    MtlProblem::new(ds, RegularizerKind::Nuclear, 0.3, 0.5, &mut rng)
}

fn run_plan(
    label: &str,
    plan: &ChaosPlan,
    out: &Path,
    resumed: bool,
) -> anyhow::Result<StormReport> {
    println!(
        "== {label}: {} nodes, {} iters, schedule {}, seed {} ==",
        plan.nodes,
        plan.iters_per_node,
        plan.schedule.name(),
        plan.seed
    );
    let p = problem(plan.seed, plan.nodes);
    let report = if resumed {
        run_resumed_storm(&p, plan, out)?
    } else {
        run_storm(&p, plan, out)?
    };
    println!("   {}", report.summary());
    if !report.passed() {
        for v in &report.violations {
            println!("   VIOLATION {v}");
        }
        println!("   {}", report.repro_line());
    }
    Ok(report)
}

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let quick = args.iter().any(|a| a == "--quick");
    let seed = args
        .iter()
        .position(|a| a == "--seed")
        .and_then(|i| args.get(i + 1))
        .map(|s| s.parse::<u64>())
        .transpose()?
        .unwrap_or(90210);
    let out = args
        .iter()
        .position(|a| a == "--out")
        .and_then(|i| args.get(i + 1))
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("target/chaos"));

    // Every storm in the sweep derives from the one root seed, so the
    // whole run reproduces from a single integer.
    let mut reports = Vec::new();

    // In-proc swarm under bounded staleness: the hardest schedule to keep
    // live under a flap wave, and the only one whose fourth invariant
    // (the staleness bound over the never-flapped cohort) is non-vacuous.
    let mut semisync =
        ChaosPlan::new(if quick { 64 } else { 128 }, if quick { 40 } else { 64 }, seed);
    semisync.schedule = ScheduleChoice::SemiSync { staleness_bound: 6 };
    reports.push(run_plan("in-proc semisync storm", &semisync, &out, false)?);

    // A smaller swarm over real loopback sockets: the same storm crosses
    // the versioned wire protocol, heartbeats and all.
    let mut tcp = ChaosPlan::new(if quick { 8 } else { 16 }, if quick { 24 } else { 32 }, seed + 1);
    tcp.transport = TransportKind::Tcp;
    reports.push(run_plan("tcp async storm", &tcp, &out, false)?);

    if !quick {
        // Free-running swarm at full width.
        let wide = ChaosPlan::new(128, 64, seed + 2);
        reports.push(run_plan("in-proc async storm", &wide, &out, false)?);

        // The same invariants checked *across* a checkpoint/WAL restart:
        // two server lifetimes, one evidence stream.
        let resumed = ChaosPlan::new(32, 40, seed + 3);
        reports.push(run_plan("resumed async storm", &resumed, &out, true)?);
    }

    // Cross-check the fleet health rules against the storms we just ran:
    // the correlated flap wave is, by construction, an eviction storm,
    // so `HealthRules` over this process's own registry MUST flag it.
    // The storms ran in-process, so the global registry accumulated
    // their evictions; a single-sample collector window reads the
    // absolute count (the window began at process start).
    let report = MetricsReport::from_snapshot(
        MetricsReport::ROLE_TRAINER,
        amtl::obs::log::uptime_ms(),
        amtl::obs::global().snapshot(),
    );
    let mut collector = Collector::new(&["chaos-storms"]);
    collector.observe(0, 0, Some(report));
    let fired: Vec<&str> =
        HealthRules::default().evaluate(&collector).iter().map(|v| v.rule).collect();
    if fired.contains(&"eviction_storm") {
        println!("health cross-check passed: eviction_storm flagged ({fired:?})");
    } else {
        println!(
            "health cross-check FAILED: the flap wave evicted nodes but the \
             eviction_storm rule stayed quiet (fired: {fired:?})"
        );
        std::process::exit(1);
    }

    let failed: Vec<&StormReport> = reports.iter().filter(|r| !r.passed()).collect();
    if failed.is_empty() {
        println!(
            "chaos sweep passed: {} storm(s), all four invariant families held (traces in {})",
            reports.len(),
            out.display()
        );
        Ok(())
    } else {
        println!(
            "chaos sweep FAILED: {} of {} storm(s) violated invariants:",
            failed.len(),
            reports.len()
        );
        for r in &failed {
            println!("  {}", r.repro_line());
        }
        std::process::exit(1);
    }
}
