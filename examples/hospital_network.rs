//! End-to-end driver: a hospital-network scenario (the paper's motivating
//! application, Fig. 1) at full scale, on the **open formulation API**.
//!
//! 139 "hospitals" (the School-sim task family: 139 regression tasks,
//! d=28, 22–251 records each) sit behind heterogeneous network links —
//! some fast, some 10x slower (stragglers). The coupling is the
//! **graph-Laplacian relationship regularizer** (`--reg graph` in the
//! CLI): hospitals are grouped into regions, strongly coupled inside a
//! region and weakly coupled to the neighboring regions — exactly the
//! kind of task-relationship prior the nuclear norm cannot express. The
//! run logs the objective curve, compares AMTL vs SMTL wall-clock under
//! identical networks, and reports effectiveness vs single-task learning
//! (no coupling).
//!
//! ```text
//! cargo run --release --example hospital_network [-- --quick]
//! ```

use amtl::coordinator::{Async, MtlProblem, Session, Synchronized};
use amtl::data::public;
use amtl::experiments::{auto_engine, ExpConfig};
use amtl::linalg::Mat;
use amtl::net::DelayModel;
use amtl::optim::coupling::TaskGraph;
use amtl::optim::prox::RegularizerKind;
use amtl::optim::FormulationSpec;
use amtl::util::json::Json;
use amtl::util::Rng;
use std::time::Duration;

/// Regional similarity graph: hospitals `[r·size, (r+1)·size)` form region
/// `r`; full coupling (weight 1) inside a region, weak coupling (0.25)
/// between each hospital and its counterpart in the next region.
fn regional_graph(t: usize, region_size: usize) -> anyhow::Result<TaskGraph> {
    let mut w = Mat::zeros(t, t);
    for i in 0..t {
        for j in (i + 1)..t {
            if i / region_size == j / region_size {
                w.set(i, j, 1.0);
                w.set(j, i, 1.0);
            }
        }
        let twin = i + region_size;
        if twin < t {
            w.set(i, twin, 0.25);
            w.set(twin, i, 0.25);
        }
    }
    TaskGraph::from_weights(w)
}

fn main() -> anyhow::Result<()> {
    let quick = std::env::args().any(|a| a == "--quick");
    let mut rng = Rng::new(2016);

    // --- The federation: 139 hospitals, private data stays local. -------
    let dataset = if quick {
        public::by_name("school-small", &mut rng).unwrap()
    } else {
        public::by_name("school", &mut rng).unwrap()
    };
    let t_count = dataset.t();
    println!("federation: {}", dataset.describe());

    // --- The formulation: graph-coupled MTL through the open registry. --
    let graph = regional_graph(t_count, (t_count / 10).max(2))?;
    let spec = FormulationSpec::parse("graph")?.with_graph(graph);
    let problem = MtlProblem::try_new(dataset, spec, 0.5, 0.5, &mut rng)?;
    println!("formulation: {} (regional similarity graph)", problem.reg_name());
    let (engine, pool) = auto_engine(1);
    println!("engine: {engine:?}");

    // --- Heterogeneous network: every 7th hospital is behind a slow link.
    let time_scale = Duration::from_millis(10);
    let fast = DelayModel::OffsetJitter {
        offset: time_scale.mul_f64(0.5),
        jitter: time_scale.mul_f64(0.5),
    };
    let slow = DelayModel::OffsetJitter {
        offset: time_scale.mul_f64(5.0),
        jitter: time_scale.mul_f64(5.0),
    };
    let per_node: Vec<Box<DelayModel>> = (0..t_count)
        .map(|i| Box::new(if i % 7 == 0 { slow.clone() } else { fast.clone() }))
        .collect();
    let network = DelayModel::PerNode { per_node };

    let iters = if quick { 5 } else { 20 };
    let base = ExpConfig {
        iters,
        time_scale,
        prox_every: (t_count as u64 / 4).max(1),
        record_every: (t_count as u64 * iters as u64 / 20).max(1),
        dynamic_step: true, // compensate straggler hospitals (§III.D)
        ..Default::default()
    };

    // --- AMTL (the paper's method) on the graph formulation. ------------
    let amtl_run = Session::builder(&problem)
        .engine(engine)
        .pool(pool.as_ref())
        .config(base.run_config())
        .delay(network.clone())
        .schedule(Async)
        .build()?
        .run()?;

    println!("\nAMTL objective curve (F = sum of hospital losses + lambda*tr(W L W^T)):");
    let curve = amtl_run.compute_objectives(|w| problem.objective(w), |v| problem.prox_map(v));
    for (secs, ver, obj) in &curve {
        println!("  t={secs:7.3}s  updates={ver:6}  F={obj:.4}");
    }

    // --- SMTL under the identical network (the straggler tax). ----------
    let smtl_run = Session::builder(&problem)
        .engine(engine)
        .pool(pool.as_ref())
        .config(base.run_config())
        .delay(network)
        .schedule(Synchronized)
        .build()?
        .run()?;

    // --- Single-task learning baseline (no coupling => no transfer). ----
    let mut stl_problem = MtlProblem::new(
        problem.dataset.clone(),
        RegularizerKind::None,
        0.0,
        0.5,
        &mut rng,
    );
    stl_problem.eta = problem.eta;
    let stl_run = Session::builder(&stl_problem)
        .engine(engine)
        .pool(pool.as_ref())
        .config(base.run_config())
        .delay(DelayModel::None)
        .schedule(Async)
        .build()?
        .run()?;

    // --- Report. ---------------------------------------------------------
    let f_amtl = problem.objective(&amtl_run.w_final);
    let f_smtl = problem.objective(&smtl_run.w_final);
    let rmse_amtl = problem.train_rmse(&amtl_run.w_final);
    let rmse_stl = problem.train_rmse(&stl_run.w_final);
    println!("\n{}", amtl_run.summary());
    println!("{}", smtl_run.summary());
    println!("objective: AMTL {f_amtl:.4} | SMTL {f_smtl:.4}");
    println!(
        "wall-clock: AMTL {:.2}s vs SMTL {:.2}s -> {:.2}x (barrier pays every straggler)",
        amtl_run.wall_time.as_secs_f64(),
        smtl_run.wall_time.as_secs_f64(),
        smtl_run.wall_time.as_secs_f64() / amtl_run.wall_time.as_secs_f64().max(1e-12)
    );
    println!(
        "effectiveness: train RMSE AMTL {rmse_amtl:.4} vs STL {rmse_stl:.4} \
         (same per-node budget; lower is better)"
    );
    // The graph prior pulls same-region hospitals together: their models
    // should end up closer than cross-region pairs.
    let w = &amtl_run.w_final;
    let region = (t_count / 10).max(2);
    let col_dist = |a: usize, b: usize| -> f64 {
        w.col(a)
            .iter()
            .zip(w.col(b))
            .map(|(x, y)| (x - y) * (x - y))
            .sum::<f64>()
            .sqrt()
    };
    let mut same = (0.0, 0usize);
    let mut cross = (0.0, 0usize);
    for i in 0..t_count {
        for j in (i + 1)..t_count {
            if i / region == j / region {
                same = (same.0 + col_dist(i, j), same.1 + 1);
            } else {
                cross = (cross.0 + col_dist(i, j), cross.1 + 1);
            }
        }
    }
    let same_mean = same.0 / same.1.max(1) as f64;
    let cross_mean = cross.0 / cross.1.max(1) as f64;
    println!(
        "coupling: mean same-region model distance {same_mean:.4} vs cross-region {cross_mean:.4} \
         ({:.0}% tighter inside a region)",
        100.0 * (1.0 - same_mean / cross_mean.max(1e-12))
    );

    // --- Persist the run record (machine-readable, like BENCH_*.json). --
    let record = Json::obj(vec![
        ("scenario", Json::Str("hospital_network".into())),
        ("formulation", Json::Str(problem.reg_name().into())),
        ("tasks", Json::Num(t_count as f64)),
        ("engine", Json::Str(format!("{engine:?}"))),
        ("amtl_wall_s", Json::Num(amtl_run.wall_time.as_secs_f64())),
        ("smtl_wall_s", Json::Num(smtl_run.wall_time.as_secs_f64())),
        ("amtl_objective", Json::Num(f_amtl)),
        ("smtl_objective", Json::Num(f_smtl)),
        ("amtl_rmse", Json::Num(rmse_amtl)),
        ("stl_rmse", Json::Num(rmse_stl)),
        ("same_region_dist", Json::Num(same_mean)),
        ("cross_region_dist", Json::Num(cross_mean)),
        (
            "curve",
            Json::Arr(
                curve
                    .iter()
                    .map(|(s, v, f)| {
                        Json::obj(vec![
                            ("t", Json::Num(*s)),
                            ("k", Json::Num(*v as f64)),
                            ("F", Json::Num(*f)),
                        ])
                    })
                    .collect(),
            ),
        ),
    ]);
    std::fs::write("hospital_network_run.json", record.to_string())?;
    println!("run record -> hospital_network_run.json");
    Ok(())
}
