//! End-to-end driver: a hospital-network scenario (the paper's motivating
//! application, Fig. 1) at full scale.
//!
//! 139 "hospitals" (the School-sim task family: 139 regression tasks,
//! d=28, 22–251 records each) sit behind heterogeneous network links —
//! some fast, some 10x slower (stragglers). The full three-layer stack
//! runs: rust coordinator -> PJRT executor -> AOT-compiled Pallas/JAX
//! forward steps. The run logs the objective curve, compares AMTL vs SMTL
//! wall-clock under identical networks, and reports effectiveness vs
//! single-task learning (no coupling). Results are recorded in
//! docs/ARCHITECTURE.md (the two data paths).
//!
//! ```text
//! cargo run --release --example hospital_network [-- --quick]
//! ```

use amtl::coordinator::{Async, MtlProblem, Session, Synchronized};
use amtl::data::public;
use amtl::experiments::{auto_engine, ExpConfig};
use amtl::net::DelayModel;
use amtl::optim::prox::RegularizerKind;
use amtl::util::json::Json;
use amtl::util::Rng;
use std::time::Duration;

fn main() -> anyhow::Result<()> {
    let quick = std::env::args().any(|a| a == "--quick");
    let mut rng = Rng::new(2016);

    // --- The federation: 139 hospitals, private data stays local. -------
    let dataset = if quick {
        public::by_name("school-small", &mut rng).unwrap()
    } else {
        public::by_name("school", &mut rng).unwrap()
    };
    let t_count = dataset.t();
    println!("federation: {}", dataset.describe());

    let problem = MtlProblem::new(dataset, RegularizerKind::Nuclear, 2.0, 0.5, &mut rng);
    let (engine, pool) = auto_engine(1);
    println!("engine: {engine:?}");

    // --- Heterogeneous network: every 7th hospital is behind a slow link.
    let time_scale = Duration::from_millis(10);
    let fast = DelayModel::OffsetJitter {
        offset: time_scale.mul_f64(0.5),
        jitter: time_scale.mul_f64(0.5),
    };
    let slow = DelayModel::OffsetJitter {
        offset: time_scale.mul_f64(5.0),
        jitter: time_scale.mul_f64(5.0),
    };
    let per_node: Vec<Box<DelayModel>> = (0..t_count)
        .map(|i| Box::new(if i % 7 == 0 { slow.clone() } else { fast.clone() }))
        .collect();
    let network = DelayModel::PerNode { per_node };

    let iters = if quick { 5 } else { 20 };
    let base = ExpConfig {
        iters,
        time_scale,
        prox_every: (t_count as u64 / 4).max(1),
        record_every: (t_count as u64 * iters as u64 / 20).max(1),
        dynamic_step: true, // compensate straggler hospitals (§III.D)
        ..Default::default()
    };

    // --- AMTL (the paper's method). -------------------------------------
    let amtl_run = Session::builder(&problem)
        .engine(engine)
        .pool(pool.as_ref())
        .config(base.run_config())
        .delay(network.clone())
        .schedule(Async)
        .build()?
        .run()?;

    println!("\nAMTL objective curve (F = sum of hospital losses + lambda*||W||_*):");
    let curve = amtl_run.compute_objectives(|w| problem.objective(w), |v| problem.prox_map(v));
    for (secs, ver, obj) in &curve {
        println!("  t={secs:7.3}s  updates={ver:6}  F={obj:.4}");
    }

    // --- SMTL under the identical network (the straggler tax). ----------
    let smtl_run = Session::builder(&problem)
        .engine(engine)
        .pool(pool.as_ref())
        .config(base.run_config())
        .delay(network)
        .schedule(Synchronized)
        .build()?
        .run()?;

    // --- Single-task learning baseline (no coupling => no transfer). ----
    let mut stl_problem = MtlProblem::new(
        problem.dataset.clone(),
        RegularizerKind::None,
        0.0,
        0.5,
        &mut rng,
    );
    stl_problem.eta = problem.eta;
    let stl_run = Session::builder(&stl_problem)
        .engine(engine)
        .pool(pool.as_ref())
        .config(base.run_config())
        .delay(DelayModel::None)
        .schedule(Async)
        .build()?
        .run()?;

    // --- Report. ---------------------------------------------------------
    let f_amtl = problem.objective(&amtl_run.w_final);
    let f_smtl = problem.objective(&smtl_run.w_final);
    let rmse_amtl = problem.train_rmse(&amtl_run.w_final);
    let rmse_stl = problem.train_rmse(&stl_run.w_final);
    println!("\n{}", amtl_run.summary());
    println!("{}", smtl_run.summary());
    println!("objective: AMTL {f_amtl:.4} | SMTL {f_smtl:.4}");
    println!(
        "wall-clock: AMTL {:.2}s vs SMTL {:.2}s -> {:.2}x (barrier pays every straggler)",
        amtl_run.wall_time.as_secs_f64(),
        smtl_run.wall_time.as_secs_f64(),
        smtl_run.wall_time.as_secs_f64() / amtl_run.wall_time.as_secs_f64().max(1e-12)
    );
    println!(
        "effectiveness: train RMSE AMTL {rmse_amtl:.4} vs STL {rmse_stl:.4} \
         (same per-node budget; lower is better)"
    );
    let svd = amtl::optim::svd::Svd::jacobi(&amtl_run.w_final);
    let energy_top4: f64 = svd.sigma.iter().take(4).sum::<f64>()
        / svd.sigma.iter().sum::<f64>().max(1e-12);
    println!("shared structure: top-4 singular values carry {:.0}% of spectrum", 100.0 * energy_top4);

    // --- Persist the run record (machine-readable, like BENCH_*.json). --
    let record = Json::obj(vec![
        ("scenario", Json::Str("hospital_network".into())),
        ("tasks", Json::Num(t_count as f64)),
        ("engine", Json::Str(format!("{engine:?}"))),
        ("amtl_wall_s", Json::Num(amtl_run.wall_time.as_secs_f64())),
        ("smtl_wall_s", Json::Num(smtl_run.wall_time.as_secs_f64())),
        ("amtl_objective", Json::Num(f_amtl)),
        ("smtl_objective", Json::Num(f_smtl)),
        ("amtl_rmse", Json::Num(rmse_amtl)),
        ("stl_rmse", Json::Num(rmse_stl)),
        (
            "curve",
            Json::Arr(
                curve
                    .iter()
                    .map(|(s, v, f)| {
                        Json::obj(vec![
                            ("t", Json::Num(*s)),
                            ("k", Json::Num(*v as f64)),
                            ("F", Json::Num(*f)),
                        ])
                    })
                    .collect(),
            ),
        ),
    ]);
    std::fs::write("hospital_network_run.json", record.to_string())?;
    println!("run record -> hospital_network_run.json");
    Ok(())
}
