//! Load generator for the serving tier: hammer a read replica with
//! concurrent predict traffic while training runs live, and emit
//! `BENCH_serve.json` (client-observed p50/p99/max latency, request
//! throughput, and the worst replica lag seen during the run).
//!
//! ```text
//! # self-contained: spins up a durable TCP trainer + replica, then loads it
//! cargo run --release --example load_gen
//! cargo run --release --example load_gen -- --quick
//!
//! # external: hammer an already-running `amtl --replica <addr> --follow <dir>`
//! cargo run --release --example load_gen -- --connect 127.0.0.1:7272 --quick
//! ```
//!
//! Options: `--clients N` concurrent connections, `--duration-secs S`
//! load window, `--quick` (or `AMTL_BENCH_QUICK=1`) for the CI-sized
//! run. Latencies are measured at the *client* (request write to
//! response decode), so they include the wire — the replica's own
//! server-side histogram is also sampled via `FetchStats` and reported
//! alongside. Exits nonzero if any request errored: the acceptance bar
//! for the tier is a replica that never refuses a well-formed predict,
//! even mid-hot-swap.

use amtl::config::Opts;
use amtl::coordinator::step_size::{KmSchedule, StepController};
use amtl::coordinator::worker::{run_worker, WorkerCtx};
use amtl::coordinator::{MtlProblem, RunConfig};
use amtl::data::synthetic;
use amtl::experiments::BenchLog;
use amtl::net::{DelayModel, FaultModel};
use amtl::optim::prox::RegularizerKind;
use amtl::runtime::Engine;
use amtl::serve::{ModelReplica, PredictClient, ReplicaServer};
use amtl::transport::{TcpClient, TcpOptions, TcpServer};
use amtl::util::Rng;
use anyhow::bail;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

const TIMEOUT: Duration = Duration::from_secs(5);

/// What one load window measured.
struct LoadReport {
    latencies_us: Vec<u64>,
    requests: u64,
    errors: u64,
    max_lag: u64,
    final_lag: u64,
    elapsed_secs: f64,
    tasks: u32,
    dim: u32,
}

fn main() -> anyhow::Result<()> {
    let opts = Opts::from_env()?;
    let quick = opts.flag("quick") || std::env::var_os("AMTL_BENCH_QUICK").is_some();
    let clients = opts.get_usize("clients", if quick { 4 } else { 8 })?;
    let secs = opts.get_f64("duration-secs", if quick { 2.0 } else { 8.0 })?;
    let external = opts.get("connect").map(|s| s.to_string());
    opts.reject_unknown()?;

    let (label, report) = match external {
        Some(addr) => {
            println!("loading external replica at {addr}: {clients} clients x {secs}s");
            ("external", hammer(&addr, clients, secs)?)
        }
        None => ("local-cluster", local_cluster(clients, secs, quick)?),
    };

    let p = |q: f64| quantile_us(&report.latencies_us, q);
    let req_per_sec = report.requests as f64 / report.elapsed_secs.max(1e-9);
    println!(
        "load done: {} requests in {:.2}s ({:.0} req/s), {} errors",
        report.requests, report.elapsed_secs, req_per_sec, report.errors
    );
    println!(
        "  client latency: p50 {}us  p99 {}us  max {}us",
        p(0.50),
        p(0.99),
        report.latencies_us.iter().max().copied().unwrap_or(0)
    );
    println!("  replica lag: max {} commits, final {}", report.max_lag, report.final_lag);

    let mut log = BenchLog::new("serve");
    log.record_kv(
        label,
        &[
            ("clients", clients as f64),
            ("duration_secs", report.elapsed_secs),
            ("requests", report.requests as f64),
            ("errors", report.errors as f64),
            ("req_per_sec", req_per_sec),
            ("p50_us", p(0.50) as f64),
            ("p99_us", p(0.99) as f64),
            ("max_us", report.latencies_us.iter().max().copied().unwrap_or(0) as f64),
            ("max_lag", report.max_lag as f64),
            ("final_lag", report.final_lag as f64),
            ("tasks", report.tasks as f64),
            ("dim", report.dim as f64),
        ],
    );
    let path = log.write()?;
    println!("wrote {}", path.display());

    if report.errors > 0 {
        eprintln!("FAIL: {} predict requests errored (the replica must never refuse one)", report.errors);
        std::process::exit(1);
    }
    Ok(())
}

/// Exact quantile over the collected client latencies (sorted copy;
/// nearest-rank). Returns 0 when nothing was collected.
fn quantile_us(latencies: &[u64], q: f64) -> u64 {
    if latencies.is_empty() {
        return 0;
    }
    let mut sorted = latencies.to_vec();
    sorted.sort_unstable();
    let idx = ((q * (sorted.len() - 1) as f64).round() as usize).min(sorted.len() - 1);
    sorted[idx]
}

/// Spin up the whole tier in one process — a durable TCP trainer, one
/// worker thread per task, and a replica following the trainer's
/// checkpoint directory — then run the load window while training is
/// live. Afterwards, cut a final checkpoint, let the replica drain, and
/// report how far its model sits from the trainer's own serving state.
fn local_cluster(clients: usize, secs: f64, quick: bool) -> anyhow::Result<LoadReport> {
    let dir = std::env::temp_dir().join(format!("amtl_load_gen_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let iters = if quick { 800 } else { 4000 };

    let mut rng = Rng::new(11);
    let dataset = synthetic::lowrank_regression(&[80; 4], 24, 3, 0.3, &mut rng);
    println!("dataset: {}", dataset.describe());
    let problem = MtlProblem::new(dataset, RegularizerKind::Nuclear, 0.5, 0.5, &mut rng);

    let cfg = RunConfig {
        iters_per_node: iters,
        record_every: 1_000_000,
        checkpoint_dir: Some(dir.clone()),
        // Small stride so keep-2 rotation prunes WALs *during* the load
        // window and the replica's hot-swap path is actually exercised.
        checkpoint_every: 64,
        ..Default::default()
    };
    let (state, server, recorder) = cfg.build_server(&problem)?;
    let mut handle = TcpServer::spawn("127.0.0.1:0", Arc::clone(&server), Some(recorder))?;
    let addr = handle.addr();
    println!("trainer on {addr}, checkpointing to {} every 64 commits", dir.display());

    let replica = ModelReplica::follow(&dir, Duration::from_millis(20));
    let rep_handle = ReplicaServer::spawn("127.0.0.1:0", &replica)?;
    let rep_addr = rep_handle.addr().to_string();
    println!("replica on {rep_addr}, following {}", dir.display());

    let mut computes = problem.build_computes(Engine::Native, None)?;
    let controller = Arc::new(StepController::new(KmSchedule::fixed(0.9), false, problem.t(), 5));
    let mut root = Rng::new(11);
    println!("loading replica while training runs: {clients} clients x {secs}s");
    let report = std::thread::scope(|s| -> anyhow::Result<LoadReport> {
        for (t, compute) in computes.iter_mut().enumerate() {
            let client = TcpClient::connect(addr, TcpOptions::default())?;
            let ctx = WorkerCtx {
                t,
                iters,
                transport: Box::new(client),
                controller: Arc::clone(&controller),
                delay: DelayModel::None,
                faults: FaultModel::None,
                sgd_fraction: None,
                time_scale: Duration::from_millis(100),
                sink: None,
                rng: root.fork(t as u64),
                gate: None,
                heartbeat: None,
                resume: false,
                trace: None,
                metrics_stride: None,
            };
            s.spawn(move || {
                run_worker(ctx, compute.as_mut()).expect("worker failed");
            });
        }
        hammer(&rep_addr, clients, secs)
    })?;
    println!("training finished: {} updates committed", state.version());

    // Final durability cut, then give the replica a bounded window to
    // drain to the trainer's horizon before comparing models.
    server.sync_persist()?;
    if let Some(cp) = server.checkpointer() {
        cp.checkpoint_now(&server)?;
    }
    let deadline = Instant::now() + Duration::from_secs(10);
    while replica.stats().lag() > 0 && Instant::now() < deadline {
        std::thread::sleep(Duration::from_millis(20));
    }
    let final_lag = replica.stats().lag();
    if let Some(m) = replica.serving() {
        let diff = m.w.max_abs_diff(&server.serving_w());
        println!(
            "drained: replica at seq {} (lag {}), max |replica W - trainer W| = {diff:.3e}",
            m.seq, final_lag
        );
        if final_lag == 0 && diff != 0.0 {
            bail!("replica drained to the trainer's horizon but its model diverged ({diff:.3e})");
        }
    }
    handle.shutdown();
    drop(rep_handle);
    drop(replica);
    let _ = std::fs::remove_dir_all(&dir);
    Ok(LoadReport { final_lag, ..report })
}

/// The load window itself: wait (bounded) for the replica to bootstrap,
/// discover the model shape from its stats frame, then run `clients`
/// connections of back-to-back predicts for `secs` seconds while a
/// poller thread tracks the worst lag the replica admits to.
fn hammer(addr: &str, clients: usize, secs: f64) -> anyhow::Result<LoadReport> {
    let mut probe = PredictClient::connect(addr, TIMEOUT)?;
    let bootstrap_deadline = Instant::now() + Duration::from_secs(30);
    let shape = loop {
        let s = probe.stats()?;
        if s.tasks > 0 {
            break s;
        }
        if Instant::now() > bootstrap_deadline {
            bail!("replica at {addr} did not bootstrap a model within 30s");
        }
        std::thread::sleep(Duration::from_millis(50));
    };
    let (tasks, dim) = (shape.tasks, shape.dim);
    println!("replica serves {tasks} tasks x {dim} features (model seq {})", shape.model_seq);

    let started = Instant::now();
    let window = Duration::from_secs_f64(secs);
    let max_lag = Arc::new(AtomicU64::new(0));

    // Lag poller: samples FetchStats through its own connection for the
    // whole window, then reports the final lag it saw.
    let poller = {
        let max_lag = Arc::clone(&max_lag);
        std::thread::spawn(move || -> u64 {
            let mut last = 0u64;
            while started.elapsed() < window {
                if let Ok(s) = probe.stats() {
                    last = s.lag();
                    max_lag.fetch_max(last, Ordering::Relaxed);
                }
                std::thread::sleep(Duration::from_millis(25));
            }
            if let Ok(s) = probe.stats() {
                last = s.lag();
                max_lag.fetch_max(last, Ordering::Relaxed);
            }
            let _ = probe.close();
            last
        })
    };

    let mut workers = Vec::new();
    for c in 0..clients {
        let addr = addr.to_string();
        workers.push(std::thread::spawn(move || -> (Vec<u64>, u64) {
            let mut rng = Rng::new(0xC0FFEE ^ (c as u64).wrapping_mul(0x9E37));
            let mut latencies = Vec::new();
            let mut errors = 0u64;
            let mut client = match PredictClient::connect(addr.as_str(), TIMEOUT) {
                Ok(c) => c,
                Err(_) => return (latencies, 1),
            };
            while started.elapsed() < window {
                let t = rng.below(tasks as u64) as usize;
                let x = rng.normal_vec(dim as usize);
                let t0 = Instant::now();
                match client.predict(t, &x) {
                    Ok((y, _model_seq)) => {
                        latencies.push(t0.elapsed().as_micros() as u64);
                        if !y.is_finite() {
                            // A non-finite score means a partially-applied
                            // column leaked through — count it as an error.
                            errors += 1;
                        }
                    }
                    Err(_) => {
                        errors += 1;
                        // The socket may be dead; one reconnect per failure,
                        // give up on the connection if even that fails.
                        match PredictClient::connect(addr.as_str(), TIMEOUT) {
                            Ok(fresh) => client = fresh,
                            Err(_) => break,
                        }
                    }
                }
            }
            let _ = client.close();
            (latencies, errors)
        }));
    }

    let mut latencies_us = Vec::new();
    let mut errors = 0u64;
    for w in workers {
        let (lat, err) = w.join().expect("load client panicked");
        latencies_us.extend(lat);
        errors += err;
    }
    let elapsed_secs = started.elapsed().as_secs_f64();
    let final_lag = poller.join().expect("lag poller panicked");

    Ok(LoadReport {
        requests: latencies_us.len() as u64 + errors,
        latencies_us,
        errors,
        max_lag: max_lag.load(Ordering::Relaxed),
        final_lag,
        elapsed_secs,
        tasks,
        dim,
    })
}
