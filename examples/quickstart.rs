//! Quickstart: solve a 5-task low-rank MTL problem with AMTL and compare
//! against the synchronized baseline.
//!
//! ```text
//! cargo run --release --example quickstart
//! ```
//!
//! Uses the PJRT engine when `artifacts/` exists (`make artifacts`),
//! otherwise the native mirror.

use amtl::coordinator::{Async, MtlProblem, SemiSync, Synchronized};
use amtl::data::synthetic;
use amtl::experiments::{auto_engine, run_once, ExpConfig};
use amtl::optim::prox::RegularizerKind;
use amtl::util::Rng;

fn main() -> anyhow::Result<()> {
    // 1. Data: 5 related regression tasks whose models share a rank-3
    //    subspace (the structure the nuclear norm exploits).
    let mut rng = Rng::new(7);
    let dataset = synthetic::lowrank_regression(&[100; 5], 50, 3, 0.5, &mut rng);
    println!("dataset: {}", dataset.describe());

    // 2. Problem: least squares + nuclear-norm coupling (Eq. IV.1).
    let problem = MtlProblem::new(dataset, RegularizerKind::Nuclear, 1.0, 0.5, &mut rng);
    println!(
        "eta = {:.3e} (L = {:.3e}), lambda = {}",
        problem.eta, problem.l_max, problem.lambda
    );

    // 3. Engine: PJRT artifacts if built, else the native mirror.
    let (engine, pool) = auto_engine(1);
    println!("engine: {engine:?}");

    // 4. One problem, one config, three schedules under the same simulated
    //    network (offset 5 paper-seconds, scaled 100x -> 50 ms per
    //    activation): fully asynchronous (the paper's method), bounded
    //    staleness, and the synchronized baseline.
    let cfg = ExpConfig { iters: 20, offset_units: 5.0, record_every: 20, ..Default::default() };
    let amtl_run = run_once(&problem, engine, pool.as_ref(), &cfg, Async)?;
    let semi_run = run_once(
        &problem,
        engine,
        pool.as_ref(),
        &cfg,
        SemiSync { staleness_bound: 4 },
    )?;
    let smtl_run = run_once(&problem, engine, pool.as_ref(), &cfg, Synchronized)?;

    println!("\n{}", amtl_run.summary());
    println!("{}", semi_run.summary());
    println!("{}", smtl_run.summary());
    println!(
        "\nobjective: AMTL {:.4} | SemiSync {:.4} | SMTL {:.4}",
        problem.objective(&amtl_run.w_final),
        problem.objective(&semi_run.w_final),
        problem.objective(&smtl_run.w_final)
    );
    println!(
        "wall-clock: AMTL {:.2}s vs SMTL {:.2}s  ->  {:.2}x speedup from asynchrony",
        amtl_run.wall_time.as_secs_f64(),
        smtl_run.wall_time.as_secs_f64(),
        smtl_run.wall_time.as_secs_f64() / amtl_run.wall_time.as_secs_f64().max(1e-12)
    );

    // 5. The learned model matrix is low-rank (knowledge was shared).
    let svd = amtl::optim::svd::Svd::jacobi(&amtl_run.w_final);
    let sigmas: Vec<String> = svd.sigma.iter().map(|s| format!("{s:.3}")).collect();
    println!("singular values of W: [{}]", sigmas.join(", "));
    Ok(())
}
