//! Dynamic step size (§III.D) demo: under heavy, heterogeneous delays the
//! Eq. III.6 multiplier `c_{t,k} = log(max(nu_bar, 10))` lets slow nodes take
//! larger steps and reach a lower objective within the same iteration
//! budget.
//!
//! ```text
//! cargo run --release --example dynamic_step_size
//! ```

use amtl::coordinator::{Async, MtlProblem};
use amtl::data::synthetic;
use amtl::experiments::{auto_engine, run_once, ExpConfig, Table};
use amtl::optim::prox::RegularizerKind;
use amtl::util::Rng;

fn main() -> anyhow::Result<()> {
    let (engine, pool) = auto_engine(1);
    println!("engine: {engine:?}");
    println!("10 AMTL iterations per node, 10-task synthetic, d=50, nuclear norm\n");

    let mut table = Table::new(&["offset (paper s)", "fixed-step F", "dynamic-step F", "gain"]);
    for offset in [5.0, 10.0, 15.0, 20.0] {
        let mut objs = [0.0f64; 2];
        for (i, dynamic) in [false, true].into_iter().enumerate() {
            let mut rng = Rng::new(99);
            let ds = synthetic::lowrank_regression(&[100; 10], 50, 3, 0.5, &mut rng);
            let problem = MtlProblem::new(ds, RegularizerKind::Nuclear, 1.0, 0.5, &mut rng);
            let cfg = ExpConfig {
                iters: 10,
                offset_units: offset,
                eta_k: 0.3,
                dynamic_step: dynamic,
                ..Default::default()
            };
            let r = run_once(&problem, engine, pool.as_ref(), &cfg, Async)?;
            objs[i] = problem.objective(&r.w_final);
        }
        table.row(vec![
            format!("{offset:.0}"),
            format!("{:.2}", objs[0]),
            format!("{:.2}", objs[1]),
            format!("{:+.1}%", 100.0 * (objs[1] - objs[0]) / objs[0]),
        ]);
    }
    table.print();
    println!("\nnegative gain = dynamic step reached a lower objective (paper Tables IV-VI)");
    Ok(())
}
