//! Convergence study: AMTL and SMTL against the centralized FISTA optimum.
//!
//! Validates the paper's Theorem 1 empirically: the asynchronous iterates
//! converge to the same optimal objective value `F*` that a centralized
//! solver reaches, despite inconsistent reads and delayed updates.
//!
//! ```text
//! cargo run --release --example convergence_study
//! ```

use amtl::coordinator::{Async, MtlProblem, Synchronized};
use amtl::data::synthetic;
use amtl::experiments::{auto_engine, run_once, ExpConfig, Table};
use amtl::optim::fista::fista;
use amtl::optim::prox::RegularizerKind;
use amtl::util::Rng;

fn main() -> anyhow::Result<()> {
    let mut rng = Rng::new(21);
    let ds = synthetic::lowrank_regression(&[100; 6], 30, 3, 0.3, &mut rng);
    let problem = MtlProblem::new(ds, RegularizerKind::Nuclear, 1.0, 0.5, &mut rng);
    let (engine, pool) = auto_engine(1);
    println!("dataset: {}", problem.dataset.describe());
    println!("engine: {engine:?}\n");

    // Centralized reference optimum (data-centralized FISTA — the thing the
    // paper's distributed setting cannot afford to do with real hospitals).
    let tasks = problem.fista_tasks();
    let mut reg = problem.regularizer();
    let reference = fista(&tasks, &mut reg, problem.l_max, 3000, 1e-12);
    let f_star = *reference.history.last().unwrap();
    println!("centralized FISTA: F* = {f_star:.6} ({} iterations)", reference.iterations);

    // Distributed runs at increasing budgets.
    let mut table = Table::new(&["iters/node", "AMTL F-F*", "SMTL F-F*", "AMTL s", "SMTL s"]);
    for iters in [10usize, 40, 160, 640] {
        let cfg = ExpConfig { iters, offset_units: 0.2, eta_k: 0.9, ..Default::default() };
        let a = run_once(&problem, engine, pool.as_ref(), &cfg, Async)?;
        let s = run_once(&problem, engine, pool.as_ref(), &cfg, Synchronized)?;
        table.row(vec![
            iters.to_string(),
            format!("{:.4}", problem.objective(&a.w_final) - f_star),
            format!("{:.4}", problem.objective(&s.w_final) - f_star),
            format!("{:.2}", a.wall_time.as_secs_f64()),
            format!("{:.2}", s.wall_time.as_secs_f64()),
        ]);
    }
    table.print();
    println!("\nboth gaps shrink toward 0: the asynchronous iterates reach the centralized optimum");
    Ok(())
}
