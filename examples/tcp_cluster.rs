//! A miniature distributed AMTL cluster over real sockets, in one process:
//! the `--serve` / `--node` topology of the CLI, runnable as an example.
//!
//! ```text
//! cargo run --release --example tcp_cluster
//! ```
//!
//! A standalone TCP server hosts the shared model `V` and the proximal
//! (backward) step; one worker per task connects through its own socket,
//! holding only its own task's data. Every backward fetch and every KM
//! commit crosses the versioned, checksummed wire protocol
//! (`rust/src/transport/wire.rs`) — task data `(X_t, y_t)` has no frame
//! type and cannot cross. The run is then compared against the plain
//! in-proc session on the same seeds: same algorithm, same answer.

use amtl::coordinator::server::CentralServer;
use amtl::coordinator::state::SharedState;
use amtl::coordinator::step_size::{KmSchedule, StepController};
use amtl::coordinator::worker::{run_worker, WorkerCtx};
use amtl::coordinator::{MtlProblem, Session};
use amtl::data::synthetic;
use amtl::net::{DelayModel, FaultModel};
use amtl::optim::prox::RegularizerKind;
use amtl::runtime::Engine;
use amtl::transport::{TcpClient, TcpOptions, TcpServer};
use amtl::util::Rng;
use std::sync::Arc;
use std::time::Duration;

fn main() -> anyhow::Result<()> {
    let iters = 120;
    let mut rng = Rng::new(7);
    let dataset = synthetic::lowrank_regression(&[100; 4], 30, 3, 0.3, &mut rng);
    println!("dataset: {}", dataset.describe());
    let problem = MtlProblem::new(dataset, RegularizerKind::Nuclear, 0.5, 0.5, &mut rng);

    // --- the "serve" side: shared state + prox server + TCP listener ----
    let state = Arc::new(SharedState::zeros(problem.d(), problem.t()));
    let server = Arc::new(CentralServer::new(
        Arc::clone(&state),
        problem.regularizer(),
        problem.eta,
    ));
    let mut handle = TcpServer::spawn("127.0.0.1:0", Arc::clone(&server), None)?;
    println!("central node listening on {}", handle.addr());

    // --- the "node" side: one worker per task, each with its own socket -
    let mut computes = problem.build_computes(Engine::Native, None)?;
    let controller = Arc::new(StepController::new(
        KmSchedule::fixed(0.9),
        false,
        problem.t(),
        5,
    ));
    let mut root = Rng::new(7);
    let addr = handle.addr();
    std::thread::scope(|s| -> anyhow::Result<()> {
        for (t, compute) in computes.iter_mut().enumerate() {
            let client = TcpClient::connect(addr, TcpOptions::default())?;
            let ctx = WorkerCtx {
                t,
                iters,
                transport: Box::new(client),
                controller: Arc::clone(&controller),
                delay: DelayModel::None,
                faults: FaultModel::None,
                sgd_fraction: None,
                time_scale: Duration::from_millis(100),
                sink: None,
                rng: root.fork(t as u64),
                gate: None,
                heartbeat: None,
                resume: false,
                trace: None,
                metrics_stride: None,
            };
            s.spawn(move || {
                let stats = run_worker(ctx, compute.as_mut()).expect("worker failed");
                println!(
                    "node {t}: {} updates, backward wait {:.3}s",
                    stats.updates, stats.backward_wait_secs
                );
            });
        }
        Ok(())
    })?;
    handle.shutdown();

    let f_tcp = problem.objective(&server.final_w());
    println!(
        "cluster done: {} updates over TCP, objective {f_tcp:.6}",
        state.version()
    );

    // --- reference: the same run through the in-proc session ------------
    let reference = Session::builder(&problem)
        .iters_per_node(iters)
        .eta_k(0.9)
        .record_every(1_000_000)
        .build()?
        .run()?;
    let f_inproc = problem.objective(&reference.w_final);
    println!("in-proc reference objective {f_inproc:.6}");
    println!(
        "relative gap {:.4}% — the transport changes the plumbing, not the math",
        100.0 * (f_tcp - f_inproc).abs() / f_inproc.max(1e-9)
    );
    Ok(())
}
