//! Server-side PJRT prox: runs the `prox_l21` Pallas artifact for the
//! ℓ2,1 backward step.
//!
//! The ℓ2,1 prox is the one MTL backward step that *is* expressible as an
//! L1 kernel (row-separable — unlike the nuclear-norm SVT, whose SVD can't
//! lower to executable HLO here — see `optim`'s module docs). With this
//! enabled the
//! **entire** AMTL data path — forward steps at the task nodes *and* the
//! backward step at the central server — executes through AOT-compiled
//! Pallas kernels.
//!
//! Shape contract: artifacts exist per `(d, t_bucket)`; `W` is padded with
//! zero columns up to the bucket (padding is exact for row norms: see the
//! kernel's docstring and `test_padded_cols_are_exact` in pytest).

use super::manifest::OpKey;
use super::pool::{new_static_id, ComputePool, InputArg};
use super::tensor::HostTensor;
use crate::linalg::Mat;
use anyhow::Result;
use std::sync::Arc;

/// The ℓ2,1 backward step as an AOT-compiled artifact call.
pub struct PjrtL21Prox {
    pool: ComputePool,
    key: OpKey,
    d: usize,
    t: usize,
    static_id: u64,
}

impl PjrtL21Prox {
    /// Resolve the `(d, t)` bucket; errors if no artifact covers it.
    pub fn new(pool: &ComputePool, d: usize, t: usize) -> Result<PjrtL21Prox> {
        let key = pool.manifest().prox_bucket_for("prox_l21", d, t)?;
        Ok(PjrtL21Prox {
            pool: pool.clone(),
            key,
            d,
            t,
            static_id: new_static_id(),
        })
    }

    /// The artifact bucket serving this shape.
    pub fn bucket(&self) -> &OpKey {
        &self.key
    }

    /// `W ← prox_{τ‖·‖2,1}(W)` via the artifact.
    pub fn apply(&self, w: &mut Mat, tau: f64) -> Result<()> {
        debug_assert_eq!(w.rows(), self.d);
        debug_assert_eq!(w.cols(), self.t);
        let bt = self.key.t;
        // Artifact layout is row-major (d, bucket_t); Mat is column-major.
        let mut data = vec![0.0f32; self.d * bt];
        for c in 0..self.t {
            let col = w.col(c);
            for r in 0..self.d {
                data[r * bt + c] = col[r] as f32;
            }
        }
        let args = vec![
            InputArg::Dyn(HostTensor::new(vec![self.d, bt], data)),
            InputArg::Dyn(HostTensor::scalar1(tau as f32)),
        ];
        let out = self
            .pool
            .execute(&self.key, self.static_id, Arc::new(vec![]), args)?;
        anyhow::ensure!(out.len() == 1, "prox_l21 returns one tensor");
        let res = &out[0];
        for c in 0..self.t {
            let col = w.col_mut(c);
            for r in 0..self.d {
                col[r] = res.data[r * bt + c] as f64;
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    // PJRT-dependent tests live in rust/tests/integration_runtime.rs (they
    // need built artifacts); here we only check the padding layout logic
    // indirectly through the column-major/row-major round trip contract.
    use crate::linalg::Mat;

    #[test]
    fn row_major_round_trip_layout() {
        let d = 3;
        let t = 2;
        let bt = 4;
        let m = Mat::from_fn(d, t, |r, c| (10 * r + c) as f64);
        let mut data = vec![0.0f32; d * bt];
        for c in 0..t {
            for r in 0..d {
                data[r * bt + c] = m.get(r, c) as f32;
            }
        }
        // Padded columns stay zero; real entries land at [r*bt + c].
        assert_eq!(data[0 * bt + 0], 0.0);
        assert_eq!(data[1 * bt + 1], 11.0);
        assert_eq!(data[2 * bt + 1], 21.0);
        assert_eq!(data[0 * bt + 2], 0.0); // padding
    }
}
