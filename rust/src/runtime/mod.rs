//! PJRT runtime: loads the AOT artifacts produced by `python/compile/aot.py`
//! and executes them from the coordinator's hot path. Python is never
//! involved at runtime — the HLO text is compiled by the in-process XLA CPU
//! client (`xla` crate / xla_extension PJRT).
//!
//! Architecture:
//!
//! * [`manifest`] — parses `artifacts/manifest.json`, resolves shape
//!   buckets (`n` rounded up to a compiled bucket for the task's `d`).
//! * [`pool`] — a pool of **executor threads**, each owning its own
//!   `PjRtClient` and executable cache (the `xla` crate's client is
//!   `Rc`-based and not `Send`; per-thread clients give real parallelism
//!   with zero unsafe). Static per-task inputs (X, y, mask) are uploaded
//!   once per executor and cached **device-resident**; only `w` and `η`
//!   cross the host boundary per step — exactly the paper's communication
//!   pattern (models move, data does not). The same module hosts
//!   [`WorkerPool`], the generic CPU pool behind the blocked
//!   [`linalg::par`](crate::linalg::par) kernels.
//! * [`task_compute`] — the [`TaskCompute`] abstraction the coordinator
//!   calls: a PJRT-backed implementation (pads task data to the bucket) and
//!   a pure-rust native implementation (oracle / fallback when artifacts
//!   are absent), cross-checked in tests.

pub mod manifest;
pub mod pool;
pub mod prox_compute;
pub mod task_compute;
pub mod tensor;

pub use manifest::{Manifest, OpKey};
pub use pool::{ComputePool, PoolConfig, WorkerPool};
pub use prox_compute::PjrtL21Prox;
pub use task_compute::{make_task_computes, Engine, NativeTaskCompute, TaskCompute};
pub use tensor::HostTensor;
