//! [`TaskCompute`]: the per-task forward-step abstraction the coordinator
//! calls. Two engines:
//!
//! * [`Engine::Pjrt`] — executes the AOT artifacts (`lsq_step` /
//!   `logistic_step`) through the [`ComputePool`]; the task's data is padded
//!   to the manifest's shape bucket once at construction and cached
//!   device-resident by the executors.
//! * [`Engine::Native`] — the pure-rust mirror in [`crate::optim::losses`];
//!   used when artifacts are absent, for fast unit tests, and as a
//!   cross-check oracle (integration tests assert PJRT ≡ native).

use super::manifest::OpKey;
use super::pool::{new_static_id, ComputePool, InputArg};
use super::tensor::HostTensor;
use crate::data::TaskDataset;
use crate::optim::losses::{Loss, RowMat};
use crate::util::Rng;
use anyhow::Result;
use std::sync::Arc;

/// Which compute engine backs the task nodes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Engine {
    /// AOT-compiled JAX/Pallas artifacts executed through PJRT.
    Pjrt,
    /// Pure-rust mirror of the same math (oracle / fallback).
    Native,
}

impl Engine {
    /// Parse a CLI value (`"pjrt"` | `"native"`).
    pub fn parse(s: &str) -> Option<Engine> {
        match s {
            "pjrt" | "xla" => Some(Engine::Pjrt),
            "native" | "rust" => Some(Engine::Native),
            _ => None,
        }
    }
}

/// The forward step of Algorithm 1 for one task, plus objective evaluation.
pub trait TaskCompute: Send {
    /// `u = w − η ∇ℓ_t(w)`, returning `(u, ℓ_t(w))`.
    fn step(&mut self, w: &[f64], eta: f64) -> Result<(Vec<f64>, f64)>;

    /// Stochastic forward step (the paper's stated future work): the same
    /// fused op evaluated over a random minibatch. The kernels' row-mask
    /// input doubles as the batch selector — `mask[i] ∈ {0, 1/frac}` keeps
    /// the gradient estimator unbiased with no new artifacts.
    fn step_minibatch(&mut self, w: &[f64], eta: f64, frac: f64, rng: &mut Rng)
        -> Result<(Vec<f64>, f64)>;

    /// `ℓ_t(w)` only.
    fn obj(&mut self, w: &[f64]) -> Result<f64> {
        Ok(self.step(w, 0.0)?.1)
    }

    /// Feature dimension.
    fn dim(&self) -> usize;
}

/// Sample an SGD mask over `n` real rows: each selected row carries weight
/// `1/frac` (importance-corrected Bernoulli subsampling).
fn sgd_mask(n: usize, frac: f64, rng: &mut Rng) -> Vec<f64> {
    let frac = frac.clamp(1e-6, 1.0);
    let w = 1.0 / frac;
    (0..n).map(|_| if rng.bool(frac) { w } else { 0.0 }).collect()
}

// ---------------------------------------------------------------- native

/// Pure-rust engine: mirrors the Pallas kernels exactly.
pub struct NativeTaskCompute {
    x: RowMat,
    y: Vec<f64>,
    mask: Vec<f64>,
    loss: Loss,
}

impl NativeTaskCompute {
    /// A native compute over one task's data.
    pub fn new(task: &TaskDataset) -> NativeTaskCompute {
        NativeTaskCompute {
            x: task.x.clone(),
            y: task.y.clone(),
            mask: vec![1.0; task.n()],
            loss: task.loss,
        }
    }
}

impl TaskCompute for NativeTaskCompute {
    fn step(&mut self, w: &[f64], eta: f64) -> Result<(Vec<f64>, f64)> {
        Ok(self.loss.step(&self.x, &self.y, w, &self.mask, eta))
    }

    fn step_minibatch(
        &mut self,
        w: &[f64],
        eta: f64,
        frac: f64,
        rng: &mut Rng,
    ) -> Result<(Vec<f64>, f64)> {
        let mask = sgd_mask(self.x.rows, frac, rng);
        Ok(self.loss.step(&self.x, &self.y, w, &mask, eta))
    }

    fn dim(&self) -> usize {
        self.x.cols
    }
}

// ---------------------------------------------------------------- pjrt

/// PJRT engine: one instance per task node, holding the padded static
/// inputs and the resolved shape bucket.
pub struct PjrtTaskCompute {
    pool: ComputePool,
    key: OpKey,
    static_id: u64,
    static_inputs: Arc<Vec<HostTensor>>,
    d: usize,
    /// Number of real (unpadded) rows — the SGD mask only samples these.
    real_n: usize,
}

impl PjrtTaskCompute {
    /// Pad `task`'s data to the smallest compiled bucket and register it as
    /// a static input set (uploaded device-side once per executor).
    pub fn new(pool: &ComputePool, task: &TaskDataset) -> Result<PjrtTaskCompute> {
        let (n, d) = (task.n(), task.d());
        let key = pool.manifest().bucket_for(task.loss.step_op(), n, d)?;
        let bn = key.n;

        // Zero-pad X row-wise; mask marks the real rows.
        let mut x = vec![0.0f32; bn * d];
        for i in 0..n {
            for (j, &v) in task.x.row(i).iter().enumerate() {
                x[i * d + j] = v as f32;
            }
        }
        let mut y = vec![0.0f32; bn];
        for (yi, &v) in y.iter_mut().zip(&task.y) {
            *yi = v as f32;
        }
        let mut mask = vec![0.0f32; bn];
        for m in mask.iter_mut().take(n) {
            *m = 1.0;
        }

        let static_inputs = Arc::new(vec![
            HostTensor::new(vec![bn, d], x),
            HostTensor::new(vec![bn], y),
            HostTensor::new(vec![bn], mask),
        ]);
        Ok(PjrtTaskCompute {
            pool: pool.clone(),
            key,
            static_id: new_static_id(),
            static_inputs,
            d,
            real_n: n,
        })
    }

    /// The artifact bucket serving this task's shape.
    pub fn bucket(&self) -> &OpKey {
        &self.key
    }
}

impl PjrtTaskCompute {
    fn run(&mut self, args: Vec<InputArg>) -> Result<(Vec<f64>, f64)> {
        let out = self.pool.execute(
            &self.key,
            self.static_id,
            Arc::clone(&self.static_inputs),
            args,
        )?;
        anyhow::ensure!(out.len() == 2, "expected (u, obj), got {} outputs", out.len());
        let u = out[0].to_f64();
        let obj = out[1].data[0] as f64;
        Ok((u, obj))
    }
}

impl TaskCompute for PjrtTaskCompute {
    fn step(&mut self, w: &[f64], eta: f64) -> Result<(Vec<f64>, f64)> {
        debug_assert_eq!(w.len(), self.d);
        // Entry-parameter order of the *_step artifacts: x, y, w, mask, eta.
        let args = vec![
            InputArg::Static(0),
            InputArg::Static(1),
            InputArg::Dyn(HostTensor::from_f64(vec![self.d], w)),
            InputArg::Static(2),
            InputArg::Dyn(HostTensor::scalar1(eta as f32)),
        ];
        self.run(args)
    }

    fn step_minibatch(
        &mut self,
        w: &[f64],
        eta: f64,
        frac: f64,
        rng: &mut Rng,
    ) -> Result<(Vec<f64>, f64)> {
        // The bucket's full mask is static input 2; here the mask becomes a
        // dynamic input: 0 on padded rows, {0, 1/frac} on real rows.
        let bn = self.key.n;
        let mut mask = vec![0.0f64; bn];
        let weighted = sgd_mask(self.real_n, frac, rng);
        mask[..self.real_n].copy_from_slice(&weighted);
        let args = vec![
            InputArg::Static(0),
            InputArg::Static(1),
            InputArg::Dyn(HostTensor::from_f64(vec![self.d], w)),
            InputArg::Dyn(HostTensor::from_f64(vec![bn], &mask)),
            InputArg::Dyn(HostTensor::scalar1(eta as f32)),
        ];
        self.run(args)
    }

    fn dim(&self) -> usize {
        self.d
    }
}

/// Build one [`TaskCompute`] per task with the selected engine.
pub fn make_task_computes(
    engine: Engine,
    pool: Option<&ComputePool>,
    tasks: &[TaskDataset],
) -> Result<Vec<Box<dyn TaskCompute>>> {
    tasks
        .iter()
        .map(|t| -> Result<Box<dyn TaskCompute>> {
            match engine {
                Engine::Native => Ok(Box::new(NativeTaskCompute::new(t))),
                Engine::Pjrt => {
                    let pool =
                        pool.ok_or_else(|| anyhow::anyhow!("pjrt engine requires a pool"))?;
                    Ok(Box::new(PjrtTaskCompute::new(pool, t)?))
                }
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;
    use crate::util::Rng;

    #[test]
    fn native_step_matches_losses_module() {
        let mut rng = Rng::new(90);
        let ds = synthetic::random_regression(1, 30, 7, &mut rng);
        let mut tc = NativeTaskCompute::new(&ds.tasks[0]);
        let w = rng.normal_vec(7);
        let (u, obj) = tc.step(&w, 0.01).unwrap();
        let (want_u, want_obj) =
            Loss::Squared.step(&ds.tasks[0].x, &ds.tasks[0].y, &w, &vec![1.0; 30], 0.01);
        assert_eq!(u, want_u);
        assert_eq!(obj, want_obj);
        assert_eq!(tc.dim(), 7);
    }

    #[test]
    fn native_obj_is_step_at_zero_eta() {
        let mut rng = Rng::new(91);
        let ds = synthetic::random_regression(1, 20, 5, &mut rng);
        let mut tc = NativeTaskCompute::new(&ds.tasks[0]);
        let w = rng.normal_vec(5);
        assert_eq!(tc.obj(&w).unwrap(), tc.step(&w, 0.0).unwrap().1);
    }

    #[test]
    fn engine_parse() {
        assert_eq!(Engine::parse("pjrt"), Some(Engine::Pjrt));
        assert_eq!(Engine::parse("native"), Some(Engine::Native));
        assert_eq!(Engine::parse("tpu"), None);
    }

    #[test]
    fn make_native_computes_for_all_tasks() {
        let mut rng = Rng::new(92);
        let ds = synthetic::random_regression(4, 10, 3, &mut rng);
        let tcs = make_task_computes(Engine::Native, None, &ds.tasks).unwrap();
        assert_eq!(tcs.len(), 4);
    }

    #[test]
    fn pjrt_engine_without_pool_errors() {
        let mut rng = Rng::new(93);
        let ds = synthetic::random_regression(1, 10, 3, &mut rng);
        assert!(make_task_computes(Engine::Pjrt, None, &ds.tasks).is_err());
    }
}
