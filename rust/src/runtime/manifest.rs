//! `artifacts/manifest.json` parsing and shape-bucket resolution.

use crate::util::json::Json;
use anyhow::{anyhow, bail, Context, Result};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// Identity of one compiled artifact: op name + static shape.
#[derive(Clone, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct OpKey {
    /// Operation name (e.g. `step_lsq`, `prox_l21`).
    pub op: String,
    /// Sample-count bucket the artifact was lowered for.
    pub n: usize,
    /// Feature dimension.
    pub d: usize,
    /// Task count (0 for per-task ops).
    pub t: usize,
}

impl std::fmt::Display for OpKey {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}(n={},d={},t={})", self.op, self.n, self.d, self.t)
    }
}

#[derive(Clone, Debug)]
/// One artifact: its identity plus the HLO text file backing it.
pub struct ManifestEntry {
    /// Which op/shape this artifact implements.
    pub key: OpKey,
    /// Path to the HLO text file.
    pub file: PathBuf,
}

/// Parsed artifact manifest with bucket lookup.
#[derive(Clone, Debug)]
pub struct Manifest {
    /// The artifact directory the manifest was loaded from.
    pub dir: PathBuf,
    /// Sample-count tiling stride used at lowering time.
    pub tile_n: usize,
    /// Feature-dimension tiling stride.
    pub tile_d: usize,
    entries: BTreeMap<OpKey, ManifestEntry>,
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {} (run `make artifacts`)", path.display()))?;
        let v = Json::parse(&text).map_err(|e| anyhow!("{e}"))?;
        let tile_n = v
            .get("tile_n")
            .and_then(Json::as_usize)
            .ok_or_else(|| anyhow!("manifest missing tile_n"))?;
        let tile_d = v.get("tile_d").and_then(Json::as_usize).unwrap_or(tile_n);
        let mut entries = BTreeMap::new();
        for e in v
            .get("entries")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("manifest missing entries"))?
        {
            let get_usize = |k: &str| {
                e.get(k)
                    .and_then(Json::as_usize)
                    .ok_or_else(|| anyhow!("entry missing {k}"))
            };
            let key = OpKey {
                op: e
                    .get("op")
                    .and_then(Json::as_str)
                    .ok_or_else(|| anyhow!("entry missing op"))?
                    .to_string(),
                n: get_usize("n")?,
                d: get_usize("d")?,
                t: get_usize("t")?,
            };
            let file = dir.join(
                e.get("file")
                    .and_then(Json::as_str)
                    .ok_or_else(|| anyhow!("entry missing file"))?,
            );
            if !file.exists() {
                bail!("artifact {} listed in manifest but missing", file.display());
            }
            entries.insert(key.clone(), ManifestEntry { key, file });
        }
        Ok(Manifest { dir: dir.to_path_buf(), tile_n, tile_d, entries })
    }

    /// Number of artifacts.
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    /// True when the manifest lists no artifacts.
    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Exact-key lookup.
    pub fn get(&self, key: &OpKey) -> Option<&ManifestEntry> {
        self.entries.get(key)
    }

    /// All artifact keys, in sorted order.
    pub fn keys(&self) -> impl Iterator<Item = &OpKey> {
        self.entries.keys()
    }

    /// Resolve the smallest compiled bucket for `op` with exact `d` and
    /// `bucket_n >= n`. Returns the key the caller should pad to.
    pub fn bucket_for(&self, op: &str, n: usize, d: usize) -> Result<OpKey> {
        let mut best: Option<&OpKey> = None;
        for key in self.entries.keys() {
            if key.op == op && key.d == d && key.n >= n {
                if best.map(|b| key.n < b.n).unwrap_or(true) {
                    best = Some(key);
                }
            }
        }
        best.cloned().ok_or_else(|| {
            anyhow!(
                "no artifact bucket for op={op} n>={n} d={d}; available: {:?}",
                self.entries
                    .keys()
                    .filter(|k| k.op == op)
                    .map(|k| (k.n, k.d))
                    .collect::<Vec<_>>()
            )
        })
    }

    /// Resolve a prox artifact for exact `d` and `bucket_t >= t`.
    pub fn prox_bucket_for(&self, op: &str, d: usize, t: usize) -> Result<OpKey> {
        let mut best: Option<&OpKey> = None;
        for key in self.entries.keys() {
            if key.op == op && key.d == d && key.t >= t {
                if best.map(|b| key.t < b.t).unwrap_or(true) {
                    best = Some(key);
                }
            }
        }
        best.cloned()
            .ok_or_else(|| anyhow!("no prox artifact for op={op} d={d} t>={t}"))
    }
}

/// The default artifacts directory: `$AMTL_ARTIFACTS` or `./artifacts`.
pub fn default_dir() -> PathBuf {
    std::env::var_os("AMTL_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("artifacts"))
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;

    fn write_manifest(dir: &Path, entries: &[(&str, usize, usize, usize)]) {
        let mut items = Vec::new();
        for (op, n, d, t) in entries {
            let file = format!("{op}_n{n}_d{d}_t{t}.hlo.txt");
            std::fs::File::create(dir.join(&file))
                .unwrap()
                .write_all(b"HloModule fake")
                .unwrap();
            items.push(format!(
                r#"{{"op":"{op}","n":{n},"d":{d},"t":{t},"file":"{file}"}}"#
            ));
        }
        let json = format!(
            r#"{{"version":1,"tile_n":128,"tile_d":128,"entries":[{}]}}"#,
            items.join(",")
        );
        std::fs::write(dir.join("manifest.json"), json).unwrap();
    }

    fn tmpdir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("amtl_manifest_test_{tag}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    #[test]
    fn loads_and_indexes() {
        let dir = tmpdir("load");
        write_manifest(&dir, &[("lsq_step", 128, 50, 0), ("lsq_step", 256, 50, 0)]);
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.len(), 2);
        assert_eq!(m.tile_n, 128);
        let key = OpKey { op: "lsq_step".into(), n: 128, d: 50, t: 0 };
        assert!(m.get(&key).is_some());
    }

    #[test]
    fn bucket_picks_smallest_sufficient() {
        let dir = tmpdir("bucket");
        write_manifest(
            &dir,
            &[("lsq_step", 128, 50, 0), ("lsq_step", 256, 50, 0), ("lsq_step", 1024, 50, 0)],
        );
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.bucket_for("lsq_step", 100, 50).unwrap().n, 128);
        assert_eq!(m.bucket_for("lsq_step", 128, 50).unwrap().n, 128);
        assert_eq!(m.bucket_for("lsq_step", 129, 50).unwrap().n, 256);
        assert_eq!(m.bucket_for("lsq_step", 300, 50).unwrap().n, 1024);
        assert!(m.bucket_for("lsq_step", 2000, 50).is_err());
        assert!(m.bucket_for("lsq_step", 100, 51).is_err());
        assert!(m.bucket_for("nope", 100, 50).is_err());
    }

    #[test]
    fn prox_bucket_by_t() {
        let dir = tmpdir("prox");
        write_manifest(&dir, &[("prox_l21", 0, 128, 8), ("prox_l21", 0, 128, 32)]);
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.prox_bucket_for("prox_l21", 128, 5).unwrap().t, 8);
        assert_eq!(m.prox_bucket_for("prox_l21", 128, 9).unwrap().t, 32);
        assert!(m.prox_bucket_for("prox_l21", 128, 33).is_err());
    }

    #[test]
    fn missing_artifact_file_is_an_error() {
        let dir = tmpdir("missing");
        write_manifest(&dir, &[("lsq_step", 128, 50, 0)]);
        std::fs::remove_file(dir.join("lsq_step_n128_d50_t0.hlo.txt")).unwrap();
        assert!(Manifest::load(&dir).is_err());
    }

    #[test]
    fn missing_manifest_mentions_make_artifacts() {
        let dir = tmpdir("nomanifest");
        let err = format!("{:#}", Manifest::load(&dir).unwrap_err());
        assert!(err.contains("make artifacts"), "{err}");
    }
}
