//! Executor pool: the compute plane of the runtime.
//!
//! The `xla` crate's `PjRtClient` is `Rc`-based (not `Send`), so executables
//! cannot be shared across threads. Instead, the pool spawns `executors`
//! threads, each of which creates **its own** PJRT CPU client and lazily
//! compiles artifacts on first use (cached per `OpKey`). Coordinator threads
//! submit [`Request`]s over a channel and block on a rendezvous reply.
//!
//! Static per-task inputs (the task's `X`, `y`, `mask`) are identified by a
//! `static_id` and uploaded to device memory **once per executor**, then
//! referenced by `execute_b` on every subsequent call — only the model
//! vector `w` and scalar `η` move per step, mirroring the paper's
//! "models move, data stays" communication pattern.

use super::manifest::{Manifest, OpKey};
use super::tensor::HostTensor;
use anyhow::{anyhow, Context, Result};
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

/// How each entry-parameter of the artifact is supplied.
#[derive(Clone, Debug)]
pub enum InputArg {
    /// Index into the request's static input set (device-cached).
    Static(usize),
    /// Uploaded fresh on every call (e.g. `w`, `η`).
    Dyn(HostTensor),
}

struct Request {
    key: OpKey,
    /// Unique id of the static input set (device-buffer cache key).
    static_id: u64,
    static_inputs: Arc<Vec<HostTensor>>,
    args: Vec<InputArg>,
    resp: mpsc::SyncSender<Result<Vec<HostTensor>>>,
}

#[derive(Clone, Debug)]
pub struct PoolConfig {
    /// Number of executor threads (PJRT clients).
    pub executors: usize,
    /// Directory containing `manifest.json` + HLO artifacts.
    pub artifacts_dir: std::path::PathBuf,
}

impl Default for PoolConfig {
    fn default() -> Self {
        let executors = std::thread::available_parallelism()
            .map(|p| p.get().clamp(1, 4))
            .unwrap_or(2);
        PoolConfig { executors, artifacts_dir: super::manifest::default_dir() }
    }
}

/// Handle to the executor pool. Cloneable; dropping the last handle shuts
/// the executors down.
#[derive(Clone)]
pub struct ComputePool {
    tx: mpsc::Sender<Request>,
    manifest: Arc<Manifest>,
    inner: Arc<PoolInner>,
}

struct PoolInner {
    handles: Mutex<Vec<JoinHandle<()>>>,
}

static NEXT_STATIC_ID: AtomicU64 = AtomicU64::new(1);

/// Allocate a fresh id for a static input set.
pub fn new_static_id() -> u64 {
    NEXT_STATIC_ID.fetch_add(1, Ordering::Relaxed)
}

impl ComputePool {
    pub fn new(config: PoolConfig) -> Result<ComputePool> {
        let manifest = Arc::new(Manifest::load(&config.artifacts_dir)?);
        Self::with_manifest(config, manifest)
    }

    pub fn with_manifest(config: PoolConfig, manifest: Arc<Manifest>) -> Result<ComputePool> {
        assert!(config.executors >= 1);
        let (tx, rx) = mpsc::channel::<Request>();
        let rx = Arc::new(Mutex::new(rx));
        let mut handles = Vec::new();
        for i in 0..config.executors {
            let rx = Arc::clone(&rx);
            let manifest = Arc::clone(&manifest);
            handles.push(
                std::thread::Builder::new()
                    .name(format!("pjrt-exec-{i}"))
                    .spawn(move || executor_loop(rx, manifest))
                    .context("spawning executor")?,
            );
        }
        Ok(ComputePool {
            tx,
            manifest,
            inner: Arc::new(PoolInner { handles: Mutex::new(handles) }),
        })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Execute artifact `key`. `args` lists every entry parameter in order;
    /// `Static(i)` entries resolve into `static_inputs[i]` (device-cached
    /// under `static_id`). Blocks until the result is ready.
    pub fn execute(
        &self,
        key: &OpKey,
        static_id: u64,
        static_inputs: Arc<Vec<HostTensor>>,
        args: Vec<InputArg>,
    ) -> Result<Vec<HostTensor>> {
        let (resp_tx, resp_rx) = mpsc::sync_channel(1);
        self.tx
            .send(Request {
                key: key.clone(),
                static_id,
                static_inputs,
                args,
                resp: resp_tx,
            })
            .map_err(|_| anyhow!("compute pool is shut down"))?;
        resp_rx
            .recv()
            .map_err(|_| anyhow!("executor dropped the request (thread died?)"))?
    }

    /// Wait for all executor threads to exit (after the last sender drops).
    pub fn join(&self) {
        let mut handles = self.inner.handles.lock().unwrap();
        for h in handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// One executor: owns a PJRT client, an executable cache and a device-
/// resident static-input cache. Exits when the request channel closes.
fn executor_loop(rx: Arc<Mutex<mpsc::Receiver<Request>>>, manifest: Arc<Manifest>) {
    let client = match xla::PjRtClient::cpu() {
        Ok(c) => c,
        Err(e) => {
            eprintln!("executor: failed to create PJRT client: {e}");
            return;
        }
    };
    let mut executables: HashMap<OpKey, xla::PjRtLoadedExecutable> = HashMap::new();
    let mut static_buffers: HashMap<u64, Vec<xla::PjRtBuffer>> = HashMap::new();

    loop {
        let req = {
            let guard = rx.lock().unwrap();
            match guard.recv() {
                Ok(r) => r,
                Err(_) => return, // all senders dropped: shut down
            }
        };
        let result = serve(&client, &manifest, &mut executables, &mut static_buffers, &req);
        let _ = req.resp.send(result);
    }
}

fn serve(
    client: &xla::PjRtClient,
    manifest: &Manifest,
    executables: &mut HashMap<OpKey, xla::PjRtLoadedExecutable>,
    static_buffers: &mut HashMap<u64, Vec<xla::PjRtBuffer>>,
    req: &Request,
) -> Result<Vec<HostTensor>> {
    // 1. Executable (compile HLO text on first use).
    if !executables.contains_key(&req.key) {
        let entry = manifest
            .get(&req.key)
            .ok_or_else(|| anyhow!("no artifact for {}", req.key))?;
        let path = entry
            .file
            .to_str()
            .ok_or_else(|| anyhow!("non-utf8 artifact path"))?;
        let proto = xla::HloModuleProto::from_text_file(path)
            .map_err(|e| anyhow!("parsing {}: {e}", entry.file.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client
            .compile(&comp)
            .map_err(|e| anyhow!("compiling {}: {e}", req.key))?;
        executables.insert(req.key.clone(), exe);
    }
    let exe = &executables[&req.key];

    // 2. Static inputs: upload once, reuse device buffers.
    if !static_buffers.contains_key(&req.static_id) {
        let bufs = req
            .static_inputs
            .iter()
            .map(|t| upload(client, t))
            .collect::<Result<Vec<_>>>()?;
        static_buffers.insert(req.static_id, bufs);
    }

    // 3. Assemble the argument list in entry-parameter order.
    let statics = &static_buffers[&req.static_id];
    let mut dyn_bufs: Vec<xla::PjRtBuffer> = Vec::new();
    // Two passes: upload dynamics first (borrow rules), then build refs.
    for arg in &req.args {
        if let InputArg::Dyn(t) = arg {
            dyn_bufs.push(upload(client, t)?);
        }
    }
    let mut dyn_iter = dyn_bufs.iter();
    let mut ordered: Vec<&xla::PjRtBuffer> = Vec::with_capacity(req.args.len());
    for arg in &req.args {
        match arg {
            InputArg::Static(i) => ordered.push(
                statics
                    .get(*i)
                    .ok_or_else(|| anyhow!("static index {i} out of range"))?,
            ),
            InputArg::Dyn(_) => ordered.push(dyn_iter.next().unwrap()),
        }
    }

    // 4. Run. Artifacts are lowered with return_tuple=True: one tuple output.
    let outputs = exe
        .execute_b(&ordered)
        .map_err(|e| anyhow!("executing {}: {e}", req.key))?;
    let lit = outputs[0][0]
        .to_literal_sync()
        .map_err(|e| anyhow!("fetching result of {}: {e}", req.key))?;
    let parts = lit.to_tuple().map_err(|e| anyhow!("untupling: {e}"))?;
    parts
        .into_iter()
        .map(|p| {
            let shape = p
                .array_shape()
                .map_err(|e| anyhow!("output shape: {e}"))?;
            let dims: Vec<usize> = shape.dims().iter().map(|&x| x as usize).collect();
            let data = p.to_vec::<f32>().map_err(|e| anyhow!("output data: {e}"))?;
            Ok(HostTensor::new(dims, data))
        })
        .collect()
}

fn upload(client: &xla::PjRtClient, t: &HostTensor) -> Result<xla::PjRtBuffer> {
    client
        .buffer_from_host_buffer::<f32>(&t.data, &t.shape, None)
        .map_err(|e| anyhow!("uploading tensor {:?}: {e}", t.shape))
}
