//! Executor pool: the compute plane of the runtime.
//!
//! The `xla` crate's `PjRtClient` is `Rc`-based (not `Send`), so executables
//! cannot be shared across threads. Instead, the pool spawns `executors`
//! threads, each of which creates **its own** PJRT CPU client and lazily
//! compiles artifacts on first use (cached per `OpKey`). Coordinator threads
//! submit [`Request`]s over a channel and block on a rendezvous reply.
//!
//! Static per-task inputs (the task's `X`, `y`, `mask`) are identified by a
//! `static_id` and uploaded to device memory **once per executor**, then
//! referenced by `execute_b` on every subsequent call — only the model
//! vector `w` and scalar `η` move per step, mirroring the paper's
//! "models move, data stays" communication pattern.

use super::manifest::{Manifest, OpKey};
use super::tensor::HostTensor;
use anyhow::{anyhow, Context, Result};
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

/// How each entry-parameter of the artifact is supplied.
#[derive(Clone, Debug)]
pub enum InputArg {
    /// Index into the request's static input set (device-cached).
    Static(usize),
    /// Uploaded fresh on every call (e.g. `w`, `η`).
    Dyn(HostTensor),
}

struct Request {
    key: OpKey,
    /// Unique id of the static input set (device-buffer cache key).
    static_id: u64,
    static_inputs: Arc<Vec<HostTensor>>,
    args: Vec<InputArg>,
    resp: mpsc::SyncSender<Result<Vec<HostTensor>>>,
}

/// Configuration for [`ComputePool::new`].
#[derive(Clone, Debug)]
pub struct PoolConfig {
    /// Number of executor threads (PJRT clients).
    pub executors: usize,
    /// Directory containing `manifest.json` + HLO artifacts.
    pub artifacts_dir: std::path::PathBuf,
}

impl Default for PoolConfig {
    fn default() -> Self {
        let executors = std::thread::available_parallelism()
            .map(|p| p.get().clamp(1, 4))
            .unwrap_or(2);
        PoolConfig { executors, artifacts_dir: super::manifest::default_dir() }
    }
}

/// Handle to the executor pool. Cloneable; dropping the last handle shuts
/// the executors down.
#[derive(Clone)]
pub struct ComputePool {
    tx: mpsc::Sender<Request>,
    manifest: Arc<Manifest>,
    inner: Arc<PoolInner>,
}

struct PoolInner {
    handles: Mutex<Vec<JoinHandle<()>>>,
}

static NEXT_STATIC_ID: AtomicU64 = AtomicU64::new(1);

/// Allocate a fresh id for a static input set.
pub fn new_static_id() -> u64 {
    NEXT_STATIC_ID.fetch_add(1, Ordering::Relaxed)
}

impl ComputePool {
    /// Spawn the executor pool, loading the manifest from `config`.
    pub fn new(config: PoolConfig) -> Result<ComputePool> {
        let manifest = Arc::new(Manifest::load(&config.artifacts_dir)?);
        Self::with_manifest(config, manifest)
    }

    /// Spawn the executor pool over an already-loaded manifest.
    pub fn with_manifest(config: PoolConfig, manifest: Arc<Manifest>) -> Result<ComputePool> {
        assert!(config.executors >= 1);
        let (tx, rx) = mpsc::channel::<Request>();
        let rx = Arc::new(Mutex::new(rx));
        let mut handles = Vec::new();
        for i in 0..config.executors {
            let rx = Arc::clone(&rx);
            let manifest = Arc::clone(&manifest);
            handles.push(
                std::thread::Builder::new()
                    .name(format!("pjrt-exec-{i}"))
                    .spawn(move || executor_loop(rx, manifest))
                    .context("spawning executor")?,
            );
        }
        Ok(ComputePool {
            tx,
            manifest,
            inner: Arc::new(PoolInner { handles: Mutex::new(handles) }),
        })
    }

    /// The artifact manifest the executors serve from.
    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Execute artifact `key`. `args` lists every entry parameter in order;
    /// `Static(i)` entries resolve into `static_inputs[i]` (device-cached
    /// under `static_id`). Blocks until the result is ready.
    pub fn execute(
        &self,
        key: &OpKey,
        static_id: u64,
        static_inputs: Arc<Vec<HostTensor>>,
        args: Vec<InputArg>,
    ) -> Result<Vec<HostTensor>> {
        let (resp_tx, resp_rx) = mpsc::sync_channel(1);
        self.tx
            .send(Request {
                key: key.clone(),
                static_id,
                static_inputs,
                args,
                resp: resp_tx,
            })
            .map_err(|_| anyhow!("compute pool is shut down"))?;
        resp_rx
            .recv()
            .map_err(|_| anyhow!("executor dropped the request (thread died?)"))?
    }

    /// Wait for all executor threads to exit (after the last sender drops).
    pub fn join(&self) {
        let mut handles = self.inner.handles.lock().unwrap();
        for h in handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// One executor: owns a PJRT client, an executable cache and a device-
/// resident static-input cache. Exits when the request channel closes.
fn executor_loop(rx: Arc<Mutex<mpsc::Receiver<Request>>>, manifest: Arc<Manifest>) {
    let client = match xla::PjRtClient::cpu() {
        Ok(c) => c,
        Err(e) => {
            crate::log_error!("executor", "failed to create PJRT client: {e}");
            return;
        }
    };
    let mut executables: HashMap<OpKey, xla::PjRtLoadedExecutable> = HashMap::new();
    let mut static_buffers: HashMap<u64, Vec<xla::PjRtBuffer>> = HashMap::new();

    loop {
        let req = {
            let guard = rx.lock().unwrap();
            match guard.recv() {
                Ok(r) => r,
                Err(_) => return, // all senders dropped: shut down
            }
        };
        let result = serve(&client, &manifest, &mut executables, &mut static_buffers, &req);
        let _ = req.resp.send(result);
    }
}

fn serve(
    client: &xla::PjRtClient,
    manifest: &Manifest,
    executables: &mut HashMap<OpKey, xla::PjRtLoadedExecutable>,
    static_buffers: &mut HashMap<u64, Vec<xla::PjRtBuffer>>,
    req: &Request,
) -> Result<Vec<HostTensor>> {
    // 1. Executable (compile HLO text on first use).
    if !executables.contains_key(&req.key) {
        let entry = manifest
            .get(&req.key)
            .ok_or_else(|| anyhow!("no artifact for {}", req.key))?;
        let path = entry
            .file
            .to_str()
            .ok_or_else(|| anyhow!("non-utf8 artifact path"))?;
        let proto = xla::HloModuleProto::from_text_file(path)
            .map_err(|e| anyhow!("parsing {}: {e}", entry.file.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client
            .compile(&comp)
            .map_err(|e| anyhow!("compiling {}: {e}", req.key))?;
        executables.insert(req.key.clone(), exe);
    }
    let exe = &executables[&req.key];

    // 2. Static inputs: upload once, reuse device buffers.
    if !static_buffers.contains_key(&req.static_id) {
        let bufs = req
            .static_inputs
            .iter()
            .map(|t| upload(client, t))
            .collect::<Result<Vec<_>>>()?;
        static_buffers.insert(req.static_id, bufs);
    }

    // 3. Assemble the argument list in entry-parameter order.
    let statics = &static_buffers[&req.static_id];
    let mut dyn_bufs: Vec<xla::PjRtBuffer> = Vec::new();
    // Two passes: upload dynamics first (borrow rules), then build refs.
    for arg in &req.args {
        if let InputArg::Dyn(t) = arg {
            dyn_bufs.push(upload(client, t)?);
        }
    }
    let mut dyn_iter = dyn_bufs.iter();
    let mut ordered: Vec<&xla::PjRtBuffer> = Vec::with_capacity(req.args.len());
    for arg in &req.args {
        match arg {
            InputArg::Static(i) => ordered.push(
                statics
                    .get(*i)
                    .ok_or_else(|| anyhow!("static index {i} out of range"))?,
            ),
            InputArg::Dyn(_) => ordered.push(dyn_iter.next().unwrap()),
        }
    }

    // 4. Run. Artifacts are lowered with return_tuple=True: one tuple output.
    let outputs = exe
        .execute_b(&ordered)
        .map_err(|e| anyhow!("executing {}: {e}", req.key))?;
    let lit = outputs[0][0]
        .to_literal_sync()
        .map_err(|e| anyhow!("fetching result of {}: {e}", req.key))?;
    let parts = lit.to_tuple().map_err(|e| anyhow!("untupling: {e}"))?;
    parts
        .into_iter()
        .map(|p| {
            let shape = p
                .array_shape()
                .map_err(|e| anyhow!("output shape: {e}"))?;
            let dims: Vec<usize> = shape.dims().iter().map(|&x| x as usize).collect();
            let data = p.to_vec::<f32>().map_err(|e| anyhow!("output data: {e}"))?;
            Ok(HostTensor::new(dims, data))
        })
        .collect()
}

fn upload(client: &xla::PjRtClient, t: &HostTensor) -> Result<xla::PjRtBuffer> {
    client
        .buffer_from_host_buffer::<f32>(&t.data, &t.shape, None)
        .map_err(|e| anyhow!("uploading tensor {:?}: {e}", t.shape))
}

// ------------------------------------------------------------------------
// Generic CPU worker pool (the host-side counterpart of the PJRT executor
// pool above). Used by `linalg::par` for blocked matmul/gram/axpy kernels.

/// One unit of pool work: a boxed closure with its lifetime erased (see the
/// safety argument in [`WorkerPool::scope`]).
type PoolJob = Box<dyn FnOnce() + Send + 'static>;

std::thread_local! {
    /// True on threads owned by a [`WorkerPool`]. [`WorkerPool::scope`]
    /// consults this to run nested submissions inline instead of
    /// deadlocking the pool against itself.
    static IN_WORKER_POOL: std::cell::Cell<bool> = std::cell::Cell::new(false);
}

/// A fixed-size pool of host threads for CPU-bound, data-parallel kernels
/// (blocked matmul, Gram columns, long axpy spans).
///
/// Unlike [`ComputePool`], which owns per-thread PJRT clients and speaks a
/// request/response protocol, this pool runs plain closures: callers hand
/// [`WorkerPool::scope`] a batch of jobs over *disjoint* slices of one
/// output buffer and block until every job has finished. Workers never
/// submit to their own pool (nested scopes run inline), so the pool cannot
/// deadlock against itself.
pub struct WorkerPool {
    tx: mpsc::Sender<PoolJob>,
    threads: usize,
    handles: Vec<JoinHandle<()>>,
}

impl WorkerPool {
    /// Spawn a pool of `threads` workers (clamped to at least 1).
    pub fn new(threads: usize) -> WorkerPool {
        let threads = threads.max(1);
        let (tx, rx) = mpsc::channel::<PoolJob>();
        let rx = Arc::new(Mutex::new(rx));
        let handles = (0..threads)
            .map(|i| {
                let rx = Arc::clone(&rx);
                std::thread::Builder::new()
                    .name(format!("pallas-linalg-{i}"))
                    .spawn(move || {
                        IN_WORKER_POOL.with(|f| f.set(true));
                        loop {
                            // Hold the receiver lock only while dequeuing.
                            let job = { rx.lock().unwrap().recv() };
                            match job {
                                Ok(job) => job(),
                                Err(_) => return, // all senders gone: shut down
                            }
                        }
                    })
                    .expect("spawning linalg worker thread")
            })
            .collect();
        WorkerPool { tx, threads, handles }
    }

    /// Number of worker threads.
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Run `jobs` on the pool and block until every one has completed.
    ///
    /// Jobs may borrow from the caller's stack (disjoint `&mut` chunks of
    /// an output buffer, `&` views of the inputs). A job that panics is
    /// caught on the worker (the thread survives) and the panic is
    /// re-raised here after the remaining jobs drain.
    ///
    /// Called from *inside* a pool worker, the jobs run inline on the
    /// current thread instead — submitting to the own pool while every
    /// worker is blocked in `scope` would deadlock.
    pub fn scope<'s>(&self, jobs: Vec<Box<dyn FnOnce() + Send + 's>>) {
        if jobs.is_empty() {
            return;
        }
        if IN_WORKER_POOL.with(|f| f.get()) {
            for job in jobs {
                job();
            }
            return;
        }
        let latch = Arc::new(Latch::new(jobs.len()));
        for job in jobs {
            // SAFETY: the job may borrow data with lifetime 's from the
            // caller's frame. We block on `latch.wait()` below until every
            // job has run to completion (panic included — the catch path
            // also counts down), so no borrow is used after this call
            // returns. The transmute only erases the lifetime; the layout
            // of the fat Box pointer is unchanged.
            let job: PoolJob = unsafe {
                std::mem::transmute::<Box<dyn FnOnce() + Send + 's>, PoolJob>(job)
            };
            let latch = Arc::clone(&latch);
            let wrapped: PoolJob = Box::new(move || {
                let outcome = std::panic::catch_unwind(std::panic::AssertUnwindSafe(job));
                latch.complete(outcome.err());
            });
            self.tx.send(wrapped).expect("worker pool channel closed");
        }
        if let Some(payload) = latch.wait() {
            std::panic::resume_unwind(payload);
        }
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        // Closing the channel lets workers drain and exit; join them so no
        // job outlives borrows owned by the dropping thread.
        drop(std::mem::replace(&mut self.tx, mpsc::channel().0));
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// Count-down latch for [`WorkerPool::scope`]: tracks outstanding jobs and
/// carries the first panic payload back to the submitting thread.
struct Latch {
    state: Mutex<LatchState>,
    cv: std::sync::Condvar,
}

struct LatchState {
    remaining: usize,
    panic: Option<Box<dyn std::any::Any + Send>>,
}

impl Latch {
    fn new(jobs: usize) -> Latch {
        Latch {
            state: Mutex::new(LatchState { remaining: jobs, panic: None }),
            cv: std::sync::Condvar::new(),
        }
    }

    fn complete(&self, panic: Option<Box<dyn std::any::Any + Send>>) {
        let mut s = self.state.lock().unwrap();
        s.remaining -= 1;
        if let Some(p) = panic {
            s.panic.get_or_insert(p);
        }
        if s.remaining == 0 {
            self.cv.notify_all();
        }
    }

    fn wait(&self) -> Option<Box<dyn std::any::Any + Send>> {
        let mut s = self.state.lock().unwrap();
        while s.remaining > 0 {
            s = self.cv.wait(s).unwrap();
        }
        s.panic.take()
    }
}

#[cfg(test)]
mod worker_pool_tests {
    use super::WorkerPool;

    #[test]
    fn scope_runs_every_job_over_borrowed_chunks() {
        let pool = WorkerPool::new(4);
        let mut out = vec![0u64; 64];
        let jobs: Vec<Box<dyn FnOnce() + Send + '_>> = out
            .chunks_mut(16)
            .enumerate()
            .map(|(i, chunk)| {
                Box::new(move || {
                    for (j, x) in chunk.iter_mut().enumerate() {
                        *x = (i * 16 + j) as u64;
                    }
                }) as Box<dyn FnOnce() + Send + '_>
            })
            .collect();
        pool.scope(jobs);
        for (i, x) in out.iter().enumerate() {
            assert_eq!(*x, i as u64);
        }
    }

    #[test]
    fn scope_with_more_jobs_than_threads_completes() {
        let pool = WorkerPool::new(2);
        let counter = std::sync::atomic::AtomicUsize::new(0);
        let jobs: Vec<Box<dyn FnOnce() + Send + '_>> = (0..50)
            .map(|_| {
                Box::new(|| {
                    counter.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                }) as Box<dyn FnOnce() + Send + '_>
            })
            .collect();
        pool.scope(jobs);
        assert_eq!(counter.load(std::sync::atomic::Ordering::Relaxed), 50);
    }

    #[test]
    fn panicking_job_propagates_and_pool_survives() {
        let pool = WorkerPool::new(2);
        let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            pool.scope(vec![Box::new(|| panic!("kernel bug")) as Box<dyn FnOnce() + Send>]);
        }));
        assert!(caught.is_err(), "panic must cross scope()");
        // The worker that caught the panic is still alive and serving.
        let flag = std::sync::atomic::AtomicUsize::new(0);
        pool.scope(vec![Box::new(|| {
            flag.store(7, std::sync::atomic::Ordering::Relaxed);
        }) as Box<dyn FnOnce() + Send + '_>]);
        assert_eq!(flag.load(std::sync::atomic::Ordering::Relaxed), 7);
    }

    #[test]
    fn nested_scope_runs_inline_without_deadlock() {
        let pool = std::sync::Arc::new(WorkerPool::new(1));
        let inner_ran = std::sync::atomic::AtomicUsize::new(0);
        let p2 = std::sync::Arc::clone(&pool);
        let inner_ref = &inner_ran;
        pool.scope(vec![Box::new(move || {
            // Submitting from a worker of the same (fully busy) pool:
            // must run inline, not deadlock.
            p2.scope(vec![Box::new(|| {
                inner_ref.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
            }) as Box<dyn FnOnce() + Send + '_>]);
        }) as Box<dyn FnOnce() + Send + '_>]);
        assert_eq!(inner_ran.load(std::sync::atomic::Ordering::Relaxed), 1);
    }
}
