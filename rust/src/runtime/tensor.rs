//! Host-side tensor: the hand-off format between coordinator threads and
//! PJRT executor threads (f32, the artifact dtype).

/// A dense row-major f32 tensor with explicit shape.
#[derive(Clone, Debug, PartialEq)]
pub struct HostTensor {
    /// Dimensions (row-major).
    pub shape: Vec<usize>,
    /// Flat row-major values (the artifact dtype is f32).
    pub data: Vec<f32>,
}

impl HostTensor {
    /// A tensor from shape + flat data (length-checked).
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> HostTensor {
        assert_eq!(shape.iter().product::<usize>(), data.len(), "shape/data mismatch");
        HostTensor { shape, data }
    }

    /// A rank-1 single-element tensor (artifact scalars are `[1]`).
    pub fn scalar1(v: f32) -> HostTensor {
        HostTensor { shape: vec![1], data: vec![v] }
    }

    /// A rank-1 tensor over `data`.
    pub fn vec1(data: Vec<f32>) -> HostTensor {
        HostTensor { shape: vec![data.len()], data }
    }

    /// Downcast host f64 data into an artifact-dtype tensor.
    pub fn from_f64(shape: Vec<usize>, data: &[f64]) -> HostTensor {
        HostTensor::new(shape, data.iter().map(|&x| x as f32).collect())
    }

    /// Upcast back to host f64.
    pub fn to_f64(&self) -> Vec<f64> {
        self.data.iter().map(|&x| x as f64).collect()
    }

    /// Total element count.
    pub fn len(&self) -> usize {
        self.data.len()
    }

    /// True for zero elements.
    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn new_checks_shape() {
        let t = HostTensor::new(vec![2, 3], vec![0.0; 6]);
        assert_eq!(t.len(), 6);
    }

    #[test]
    #[should_panic(expected = "shape/data mismatch")]
    fn new_rejects_mismatch() {
        HostTensor::new(vec![2, 3], vec![0.0; 5]);
    }

    #[test]
    fn f64_roundtrip() {
        let t = HostTensor::from_f64(vec![3], &[1.5, -2.0, 0.25]);
        assert_eq!(t.to_f64(), vec![1.5, -2.0, 0.25]);
    }

    #[test]
    fn constructors() {
        assert_eq!(HostTensor::scalar1(2.0).shape, vec![1]);
        assert_eq!(HostTensor::vec1(vec![1.0, 2.0]).shape, vec![2]);
    }
}
