//! Run metrics: objective trajectories, update accounting, timing.
//!
//! Objective evaluation requires a full data pass, so it is **never** done
//! on the update path: the trajectory recorder stores (time, iteration,
//! V-snapshot) triples during the run, and objectives are computed
//! afterwards by [`RunResult::compute_objectives`].

use crate::linalg::Mat;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::{Duration, Instant};

/// A recorded point on the optimization trajectory.
#[derive(Clone, Debug)]
pub struct TrajectoryPoint {
    /// Wall-clock since run start.
    pub elapsed: Duration,
    /// Global update count when the snapshot was taken.
    pub version: u64,
    /// Snapshot of the auxiliary variable `V` (prox not yet applied).
    pub v: Mat,
}

/// Points held before the recorder downsamples (kept deliberately small
/// relative to snapshot size: each point owns a full `d × T` copy of `V`).
const DEFAULT_CAPACITY: usize = 512;

/// Thread-safe trajectory recorder sampled every `every` updates.
///
/// Memory is **bounded**: a long run (or a small stride against a huge
/// budget) cannot grow the point vector without limit. On reaching the
/// capacity the recorder halves its density — every other interior point
/// is dropped (the first and newest points always survive) and the
/// sampling stride doubles, so the kept trajectory stays evenly spaced
/// over the whole run instead of truncating its tail.
pub struct Recorder {
    start: Instant,
    every: AtomicU64,
    cap: usize,
    points: Mutex<Vec<TrajectoryPoint>>,
    last_version: Mutex<u64>,
}

impl Recorder {
    /// A recorder sampling every `every` updates (clamped to ≥ 1), with
    /// the default capacity bound.
    pub fn new(every: u64) -> Recorder {
        Recorder::with_capacity(every, DEFAULT_CAPACITY)
    }

    /// A recorder with an explicit capacity bound (clamped to ≥ 4 so the
    /// first/last points and some interior always fit).
    pub fn with_capacity(every: u64, cap: usize) -> Recorder {
        Recorder {
            start: Instant::now(),
            every: AtomicU64::new(every.max(1)),
            cap: cap.max(4),
            points: Mutex::new(Vec::new()),
            last_version: Mutex::new(0),
        }
    }

    /// The current sampling stride (doubles on each downsampling pass).
    pub fn stride(&self) -> u64 {
        self.every.load(Ordering::Relaxed)
    }

    /// Record if `version` crossed the sampling stride since the last
    /// recorded point. `snapshot` is only invoked when recording happens.
    pub fn maybe_record(&self, version: u64, snapshot: impl FnOnce() -> Mat) {
        let every = self.every.load(Ordering::Relaxed);
        let mut last = self.last_version.lock().unwrap();
        if version < *last + every {
            return;
        }
        *last = version;
        drop(last);
        let p = TrajectoryPoint {
            elapsed: self.start.elapsed(),
            version,
            v: snapshot(),
        };
        let mut points = self.points.lock().unwrap();
        points.push(p);
        if points.len() >= self.cap {
            Recorder::halve_density(&mut points);
            self.every.store(every.saturating_mul(2), Ordering::Relaxed);
        }
    }

    /// Drop every other interior point, keeping the first and the newest.
    fn halve_density(points: &mut Vec<TrajectoryPoint>) {
        let last = points.len() - 1;
        let mut i = 0;
        points.retain(|_| {
            let keep = i == 0 || i == last || i % 2 == 0;
            i += 1;
            keep
        });
    }

    /// Unconditionally record (used at run start/end).
    pub fn record_now(&self, version: u64, v: Mat) {
        self.points.lock().unwrap().push(TrajectoryPoint {
            elapsed: self.start.elapsed(),
            version,
            v,
        });
    }

    /// Consume the recorder, yielding the points in record order.
    pub fn into_points(self) -> Vec<TrajectoryPoint> {
        self.points.into_inner().unwrap()
    }

    /// The instant the recorder (and so the run clock) started.
    pub fn start_instant(&self) -> Instant {
        self.start
    }
}

/// Outcome of one coordinator run (any schedule).
#[derive(Debug)]
pub struct RunResult {
    /// The schedule's name: "amtl", "smtl", "semisync", ...
    pub method: String,
    /// Total wall-clock of the optimization loop.
    pub wall_time: Duration,
    /// Final auxiliary variable `V`.
    pub v_final: Mat,
    /// Final primal iterate `W = Prox(V)`.
    pub w_final: Mat,
    /// Total KM updates applied.
    pub updates: u64,
    /// Per-node update counts.
    pub updates_per_node: Vec<u64>,
    /// Number of proximal mappings actually computed by the server.
    pub prox_count: u64,
    /// Same-task commits the server coalesced before folding them into
    /// the formulation's incremental state (0 on the exact path, or for
    /// formulations without an incremental form).
    pub coalesced_updates: u64,
    /// Exact refreshes of the formulation's incremental state — Jacobi
    /// re-anchors of the online SVD, re-centres of the mean formulation's
    /// running centroid (0 on the exact path).
    pub svd_refreshes: u64,
    /// Recorded trajectory (V snapshots).
    pub trajectory: Vec<TrajectoryPoint>,
    /// Mean observed per-activation injected delay, in seconds.
    pub mean_delay_secs: f64,
    /// Updates lost to injected faults.
    pub dropped_updates: u64,
    /// Nodes that crashed before finishing their budget.
    pub crashed_nodes: Vec<usize>,
    /// Total wall-clock spent in forward (gradient) compute across nodes.
    pub compute_secs: f64,
    /// Total wall-clock nodes spent waiting on the server's backward step.
    pub backward_wait_secs: f64,
    /// Total wall-clock nodes spent committing updates (the KM push
    /// round-trip; includes WAL fsync when durability is on).
    pub commit_wait_secs: f64,
    /// Mean commit staleness τ (versions): for each applied commit, the
    /// global updates that landed between its fetch and its apply.
    pub mean_staleness: f64,
    /// Median commit staleness (versions; conservative log₂-bucket edge).
    pub staleness_p50: u64,
    /// 99th-percentile commit staleness (versions).
    pub staleness_p99: u64,
    /// Largest commit staleness observed (exact).
    pub staleness_max: u64,
    /// Snapshots the server wrote during the run (0 without durability).
    pub checkpoints_written: u64,
    /// WAL entries replayed into the server by `--resume` recovery (0 on
    /// a fresh start).
    pub wal_replayed: u64,
    /// Nodes evicted by heartbeat timeout (empty without membership).
    pub evicted_nodes: Vec<usize>,
}

impl RunResult {
    /// Evaluate the MTL objective `F(W) = Σ ℓ_t(w_t) + λg(W)` along the
    /// trajectory, applying the backward map `W = Prox(V)` to each snapshot
    /// first (objectives are reported at the primal iterate, like the
    /// paper's Fig. 4 / Tables IV–VI).
    pub fn compute_objectives(
        &self,
        objective: impl Fn(&Mat) -> f64,
        prox: impl Fn(&Mat) -> Mat,
    ) -> Vec<(f64, u64, f64)> {
        self.trajectory
            .iter()
            .map(|p| {
                let w = prox(&p.v);
                (p.elapsed.as_secs_f64(), p.version, objective(&w))
            })
            .collect()
    }

    /// Paper-style one-line summary.
    pub fn summary(&self) -> String {
        let mut s = format!(
            "{}: wall={:.2}s updates={} prox={} coalesced={} mean_delay={:.3}s",
            self.method,
            self.wall_time.as_secs_f64(),
            self.updates,
            self.prox_count,
            self.coalesced_updates,
            self.mean_delay_secs,
        );
        s.push_str(&format!(
            " staleness(mean={:.2} p99={} max={})",
            self.mean_staleness, self.staleness_p99, self.staleness_max
        ));
        if self.checkpoints_written > 0 || self.wal_replayed > 0 {
            s.push_str(&format!(
                " checkpoints={} wal_replayed={}",
                self.checkpoints_written, self.wal_replayed
            ));
        }
        if !self.evicted_nodes.is_empty() {
            s.push_str(&format!(" evicted={:?}", self.evicted_nodes));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn recorder_samples_at_stride() {
        let r = Recorder::new(10);
        let mut snaps = 0;
        for v in 1..=100u64 {
            r.maybe_record(v, || {
                snaps += 1;
                Mat::zeros(1, 1)
            });
        }
        assert_eq!(snaps, 10, "one snapshot per 10 versions");
        let pts = r.into_points();
        assert_eq!(pts.len(), 10);
        assert!(pts.windows(2).all(|w| w[0].version < w[1].version));
    }

    #[test]
    fn recorder_every_one_records_all() {
        let r = Recorder::new(1);
        for v in 1..=5u64 {
            r.maybe_record(v, || Mat::zeros(1, 1));
        }
        assert_eq!(r.into_points().len(), 5);
    }

    #[test]
    fn recorder_bounds_memory_by_stride_doubling() {
        let r = Recorder::with_capacity(1, 8);
        for v in 1..=1000u64 {
            r.maybe_record(v, || Mat::zeros(1, 1));
        }
        let stride = r.stride();
        assert!(stride > 1, "stride doubled under pressure: {stride}");
        let pts = r.into_points();
        assert!(pts.len() <= 8, "bounded at capacity, got {}", pts.len());
        assert_eq!(pts[0].version, 1, "the first point always survives");
        let tail = pts.last().unwrap().version;
        assert!(tail + 2 * stride > 1000, "tail lags ≤ ~2 strides: v={tail} stride={stride}");
        assert!(pts.windows(2).all(|w| w[0].version < w[1].version), "order preserved");
    }

    #[test]
    fn compute_objectives_applies_prox_first() {
        let mut v = Mat::zeros(1, 1);
        v.set(0, 0, 3.0);
        let result = RunResult {
            method: "amtl".into(),
            wall_time: Duration::from_secs(1),
            v_final: v.clone(),
            w_final: v.clone(),
            updates: 1,
            updates_per_node: vec![1],
            prox_count: 1,
            coalesced_updates: 0,
            svd_refreshes: 0,
            trajectory: vec![TrajectoryPoint {
                elapsed: Duration::from_millis(500),
                version: 1,
                v,
            }],
            mean_delay_secs: 0.0,
            dropped_updates: 0,
            crashed_nodes: vec![],
            compute_secs: 0.0,
            backward_wait_secs: 0.0,
            commit_wait_secs: 0.0,
            mean_staleness: 0.0,
            staleness_p50: 0,
            staleness_p99: 0,
            staleness_max: 0,
            checkpoints_written: 0,
            wal_replayed: 0,
            evicted_nodes: vec![],
        };
        let objs = result.compute_objectives(
            |w| w.get(0, 0),           // objective = the entry itself
            |v| {
                let mut w = v.clone(); // prox = halve it
                w.set(0, 0, v.get(0, 0) / 2.0);
                w
            },
        );
        assert_eq!(objs.len(), 1);
        assert_eq!(objs[0].2, 1.5);
        assert_eq!(objs[0].1, 1);
    }

    #[test]
    fn summary_contains_method_and_counts() {
        let result = RunResult {
            method: "smtl".into(),
            wall_time: Duration::from_secs(2),
            v_final: Mat::zeros(1, 1),
            w_final: Mat::zeros(1, 1),
            updates: 42,
            updates_per_node: vec![21, 21],
            prox_count: 7,
            coalesced_updates: 0,
            svd_refreshes: 0,
            trajectory: vec![],
            mean_delay_secs: 0.1,
            dropped_updates: 0,
            crashed_nodes: vec![],
            compute_secs: 0.0,
            backward_wait_secs: 0.0,
            commit_wait_secs: 0.0,
            mean_staleness: 0.0,
            staleness_p50: 0,
            staleness_p99: 0,
            staleness_max: 0,
            checkpoints_written: 0,
            wal_replayed: 0,
            evicted_nodes: vec![],
        };
        let s = result.summary();
        assert!(s.contains("smtl") && s.contains("42") && s.contains("7"));
    }
}
