//! Shared model state with task-block granularity.
//!
//! The auxiliary matrix `V ∈ R^{d×T}` of the backward-forward iteration
//! lives here. Each task block (column) has its own lock, so:
//!
//! * a task node updating `v_t` never contends with other task nodes;
//! * the server's full-matrix snapshot acquires one column lock at a time —
//!   concurrent updates can land between columns, which is exactly the
//!   *inconsistent read* the paper describes in Fig. 2 ("there is no memory
//!   lock during reads") and that the ARock analysis accounts for.
//!
//! A global version counter (total KM updates, the `k` of Algorithm 1) and
//! per-column counters drive the prox cache and the metrics sampler.
//!
//! Each block (its lock + its version counter) is padded to a cache line
//! so concurrent commits to adjacent task ids — the layout the TCP server
//! produces under load — never false-share.

use crate::linalg::Mat;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

/// One task block, padded out to its own cache line so that task nodes
/// hammering adjacent columns (the common case: task ids are dense) never
/// false-share a line between their locks or their version counters.
#[repr(align(64))]
struct ColBlock {
    values: Mutex<Vec<f64>>,
    /// Updates applied to this block.
    version: AtomicU64,
}

/// The shared auxiliary matrix `V`, sharded by task block.
pub struct SharedState {
    d: usize,
    cols: Vec<ColBlock>,
    /// Total KM updates applied (the global iteration counter `k`).
    version: AtomicU64,
}

impl SharedState {
    /// Shared state initialized from `initial` (one block per column).
    pub fn new(initial: &Mat) -> SharedState {
        let cols = (0..initial.cols())
            .map(|c| ColBlock {
                values: Mutex::new(initial.col(c).to_vec()),
                version: AtomicU64::new(0),
            })
            .collect();
        SharedState { d: initial.rows(), cols, version: AtomicU64::new(0) }
    }

    /// All-zeros shared state (`d × t`).
    pub fn zeros(d: usize, t: usize) -> SharedState {
        SharedState::new(&Mat::zeros(d, t))
    }

    /// Rebuild shared state from a persisted snapshot: values *and*
    /// version counters, so a resumed run's prox cache keys, trajectory
    /// stride, and progress accounting continue where they left off.
    pub(crate) fn restore(initial: &Mat, col_versions: &[u64], version: u64) -> SharedState {
        assert_eq!(col_versions.len(), initial.cols());
        let cols = (0..initial.cols())
            .map(|c| ColBlock {
                values: Mutex::new(initial.col(c).to_vec()),
                version: AtomicU64::new(col_versions[c]),
            })
            .collect();
        SharedState { d: initial.rows(), cols, version: AtomicU64::new(version) }
    }

    /// Feature dimension `d`.
    pub fn d(&self) -> usize {
        self.d
    }

    /// Number of task blocks `T`.
    pub fn t(&self) -> usize {
        self.cols.len()
    }

    /// Total updates applied so far.
    pub fn version(&self) -> u64 {
        self.version.load(Ordering::Acquire)
    }

    /// Updates applied to block `t` so far.
    pub fn col_version(&self, t: usize) -> u64 {
        self.cols[t].version.load(Ordering::Acquire)
    }

    /// Copy of one task block.
    pub fn read_col(&self, t: usize) -> Vec<f64> {
        self.cols[t].values.lock().unwrap().clone()
    }

    /// Overwrite one task block (initialization / SMTL broadcast).
    pub fn write_col(&self, t: usize, v: &[f64]) {
        assert_eq!(v.len(), self.d);
        self.cols[t].values.lock().unwrap().copy_from_slice(v);
        self.cols[t].version.fetch_add(1, Ordering::AcqRel);
        self.version.fetch_add(1, Ordering::AcqRel);
    }

    /// Inconsistent full-matrix snapshot: columns are copied one lock at a
    /// time, so concurrent block updates may interleave (by design).
    pub fn snapshot(&self) -> Mat {
        let mut m = Mat::zeros(self.d, self.cols.len());
        for (c, col) in self.cols.iter().enumerate() {
            let guard = col.values.lock().unwrap();
            m.col_mut(c).copy_from_slice(&guard);
        }
        m
    }

    /// The KM relaxation update of Algorithm 1 (Eq. III.4/III.5):
    /// `v_t ← v_t + step · (u − v_t)`, atomically w.r.t. block `t`.
    /// Returns the new global version.
    pub fn km_update(&self, t: usize, u: &[f64], step: f64) -> u64 {
        assert_eq!(u.len(), self.d);
        {
            let mut guard = self.cols[t].values.lock().unwrap();
            for (v, ui) in guard.iter_mut().zip(u) {
                *v += step * (ui - *v);
            }
        }
        self.cols[t].version.fetch_add(1, Ordering::AcqRel);
        self.version.fetch_add(1, Ordering::AcqRel) + 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::forall;
    use std::sync::Arc;

    #[test]
    fn snapshot_roundtrip() {
        let mut m = Mat::zeros(3, 2);
        m.set(0, 0, 1.0);
        m.set(2, 1, -4.0);
        let s = SharedState::new(&m);
        assert_eq!(s.snapshot(), m);
        assert_eq!(s.read_col(1), vec![0.0, 0.0, -4.0]);
    }

    #[test]
    fn km_update_math() {
        let s = SharedState::zeros(2, 1);
        s.write_col(0, &[1.0, 2.0]);
        // v + 0.5*(u - v) with u = [3, 4] → [2, 3]
        let ver = s.km_update(0, &[3.0, 4.0], 0.5);
        assert_eq!(s.read_col(0), vec![2.0, 3.0]);
        assert_eq!(ver, 2); // write_col bumped once, km_update once
        assert_eq!(s.col_version(0), 2);
    }

    #[test]
    fn km_update_step_one_replaces() {
        let s = SharedState::zeros(2, 1);
        s.km_update(0, &[5.0, -1.0], 1.0);
        assert_eq!(s.read_col(0), vec![5.0, -1.0]);
    }

    #[test]
    fn km_update_step_zero_is_noop_on_values() {
        let s = SharedState::zeros(2, 1);
        s.write_col(0, &[1.0, 1.0]);
        s.km_update(0, &[9.0, 9.0], 0.0);
        assert_eq!(s.read_col(0), vec![1.0, 1.0]);
    }

    #[test]
    fn concurrent_updates_to_distinct_blocks_all_land() {
        let s = Arc::new(SharedState::zeros(4, 8));
        let mut handles = Vec::new();
        for t in 0..8 {
            let s = Arc::clone(&s);
            handles.push(std::thread::spawn(move || {
                for _ in 0..1000 {
                    // step 1.0 with u = current + 1 ⇒ increments each entry.
                    let cur = s.read_col(t);
                    let u: Vec<f64> = cur.iter().map(|x| x + 1.0).collect();
                    s.km_update(t, &u, 1.0);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(s.version(), 8 * 1000);
        for t in 0..8 {
            assert_eq!(s.read_col(t), vec![1000.0; 4]);
            assert_eq!(s.col_version(t), 1000);
        }
    }

    #[test]
    fn concurrent_same_block_updates_serialize() {
        // Two threads each add +1 (via km step 1, u = v+1) 500 times to the
        // SAME block; the block lock must make all 1000 land.
        let s = Arc::new(SharedState::zeros(1, 1));
        let mut handles = Vec::new();
        for _ in 0..2 {
            let s = Arc::clone(&s);
            handles.push(std::thread::spawn(move || {
                for _ in 0..500 {
                    let guard_free_u;
                    loop {
                        let cur = s.read_col(0)[0];
                        guard_free_u = cur + 1.0;
                        // CAS-like retry: apply and verify the value moved by ≥1.
                        s.km_update(0, &[guard_free_u], 1.0);
                        break;
                    }
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        // Races on read-then-update can lose increments (that's the
        // inconsistent-read semantics!), but the version counter is exact.
        assert_eq!(s.version(), 1000);
    }

    #[test]
    fn snapshot_under_concurrent_writes_sees_valid_columns() {
        // Each column is only ever [k, k] for integer k (written under its
        // lock) — snapshots may mix versions across columns but never
        // within one.
        let s = Arc::new(SharedState::zeros(2, 4));
        let stop = Arc::new(std::sync::atomic::AtomicBool::new(false));
        let mut handles = Vec::new();
        for t in 0..4 {
            let s = Arc::clone(&s);
            let stop = Arc::clone(&stop);
            handles.push(std::thread::spawn(move || {
                let mut k = 0.0;
                while !stop.load(Ordering::Relaxed) {
                    k += 1.0;
                    s.write_col(t, &[k, k]);
                }
            }));
        }
        for _ in 0..200 {
            let snap = s.snapshot();
            for c in 0..4 {
                assert_eq!(snap.get(0, c), snap.get(1, c), "torn column read");
            }
        }
        stop.store(true, Ordering::Relaxed);
        for h in handles {
            h.join().unwrap();
        }
    }

    #[test]
    fn prop_km_update_is_convex_combination() {
        forall(
            "km update stays within segment [v, u]",
            100,
            |g| {
                let v = g.normal_vec(5);
                let u = g.normal_vec(5);
                let step = g.f64_in(0.0, 1.0);
                ((v, u), step)
            },
            |((v, u), step)| {
                let mut m = Mat::zeros(5, 1);
                m.col_mut(0).copy_from_slice(v);
                let s = SharedState::new(&m);
                s.km_update(0, u, *step);
                let got = s.read_col(0);
                got.iter().zip(v.iter().zip(u)).all(|(g, (vi, ui))| {
                    let lo = vi.min(*ui) - 1e-12;
                    let hi = vi.max(*ui) + 1e-12;
                    *g >= lo && *g <= hi
                })
            },
        );
    }
}
