//! The asynchronous AMTL driver — Algorithm 1 of the paper.
//!
//! Spawns one worker thread per task node; every node runs its activations
//! independently (no barrier anywhere). The central server's backward step
//! is the only shared computation, and it never blocks a node that is
//! sleeping on its network delay.

use super::metrics::{Recorder, RunResult};
use super::problem::MtlProblem;
use super::server::CentralServer;
use super::state::SharedState;
use super::step_size::{KmSchedule, StepController};
use super::worker::{run_worker, WorkerCtx};
use crate::net::{DelayModel, FaultModel};
use crate::runtime::TaskCompute;
use crate::util::Rng;
use anyhow::Result;
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Configuration of one AMTL run.
#[derive(Clone, Debug)]
pub struct AmtlConfig {
    /// Activations per task node ("iterations" in the paper's tables).
    pub iters_per_node: usize,
    /// Injected network-delay model.
    pub delay: DelayModel,
    /// Injected fault model (robustness experiments).
    pub faults: FaultModel,
    /// Minibatch fraction for stochastic forward steps (None = full batch).
    pub sgd_fraction: Option<f64>,
    /// Wall-clock duration of one paper delay-unit (DESIGN.md: 100 ms
    /// represents one paper "second").
    pub time_scale: Duration,
    /// KM relaxation step η_k.
    pub km: KmSchedule,
    /// Enable the §III.D dynamic step size.
    pub dynamic_step: bool,
    /// Delay-history window for Eq. III.6 (the paper uses 5).
    pub dyn_window: usize,
    /// Server re-prox stride (1 = after every update, the paper default).
    pub prox_every: u64,
    /// Trajectory sampling stride in updates.
    pub record_every: u64,
    /// Use the Brand online-SVD incremental prox (nuclear norm only).
    pub online_svd: bool,
    pub seed: u64,
}

impl Default for AmtlConfig {
    fn default() -> Self {
        AmtlConfig {
            iters_per_node: 10,
            delay: DelayModel::None,
            faults: FaultModel::None,
            sgd_fraction: None,
            time_scale: Duration::from_millis(100),
            km: KmSchedule::fixed(0.5),
            dynamic_step: false,
            dyn_window: 5,
            prox_every: 1,
            record_every: 1,
            online_svd: false,
            seed: 7,
        }
    }
}

impl AmtlConfig {
    /// The paper's AMTL-k network setting: delay offset of `k` paper-units.
    pub fn with_paper_offset(mut self, offset_units: f64) -> AmtlConfig {
        self.delay = DelayModel::paper_offset(self.time_scale.mul_f64(offset_units));
        self
    }
}

/// Run asynchronous MTL. `computes` must have one entry per task (built by
/// [`MtlProblem::build_computes`]).
pub fn run_amtl(
    problem: &MtlProblem,
    mut computes: Vec<Box<dyn TaskCompute>>,
    cfg: &AmtlConfig,
) -> Result<RunResult> {
    let t_count = problem.t();
    anyhow::ensure!(
        computes.len() == t_count,
        "need one compute per task ({} != {t_count})",
        computes.len()
    );

    let state = Arc::new(SharedState::zeros(problem.d(), t_count));
    let mut reg = problem.regularizer();
    if cfg.online_svd {
        reg = reg.with_online_svd(&state.snapshot());
    }
    let server = Arc::new(
        CentralServer::new(Arc::clone(&state), reg, problem.eta).with_prox_every(cfg.prox_every),
    );
    let controller = Arc::new(StepController::new(
        cfg.km,
        cfg.dynamic_step,
        t_count,
        cfg.dyn_window,
    ));
    let recorder = Arc::new(Recorder::new(cfg.record_every));
    recorder.record_now(0, state.snapshot());

    let mut root_rng = Rng::new(cfg.seed);
    let start = Instant::now();
    let mut stats = Vec::new();
    std::thread::scope(|s| -> Result<()> {
        let mut handles = Vec::new();
        for (t, compute) in computes.iter_mut().enumerate() {
            let ctx = WorkerCtx {
                t,
                iters: cfg.iters_per_node,
                server: Arc::clone(&server),
                controller: Arc::clone(&controller),
                delay: cfg.delay.clone(),
                faults: cfg.faults.clone(),
                sgd_fraction: cfg.sgd_fraction,
                time_scale: cfg.time_scale,
                recorder: Arc::clone(&recorder),
                rng: root_rng.fork(t as u64),
            };
            let handle = std::thread::Builder::new()
                .name(format!("amtl-worker-{t}"))
                .spawn_scoped(s, move || run_worker(ctx, compute.as_mut()))?;
            handles.push(handle);
        }
        for h in handles {
            stats.push(h.join().map_err(|_| anyhow::anyhow!("worker panicked"))??);
        }
        Ok(())
    })?;
    let wall_time = start.elapsed();

    let v_final = state.snapshot();
    recorder.record_now(state.version(), v_final.clone());
    let w_final = server.final_w();
    let updates_per_node: Vec<u64> = stats.iter().map(|s| s.updates).collect();
    let total_updates: u64 = updates_per_node.iter().sum();
    let mean_delay_secs = if total_updates > 0 {
        stats.iter().map(|s| s.total_delay_secs).sum::<f64>() / total_updates as f64
    } else {
        0.0
    };

    let recorder = Arc::try_unwrap(recorder)
        .map_err(|_| anyhow::anyhow!("recorder still referenced"))?;
    Ok(RunResult {
        method: "amtl".into(),
        wall_time,
        v_final,
        w_final,
        updates: total_updates,
        updates_per_node,
        prox_count: server.prox_count(),
        trajectory: recorder.into_points(),
        mean_delay_secs,
        dropped_updates: stats.iter().map(|s| s.dropped).sum(),
        crashed_nodes: stats
            .iter()
            .enumerate()
            .filter(|(_, s)| s.crashed)
            .map(|(i, _)| i)
            .collect(),
        compute_secs: stats.iter().map(|s| s.compute_secs).sum(),
        backward_wait_secs: stats.iter().map(|s| s.backward_wait_secs).sum(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;
    use crate::optim::prox::RegularizerKind;
    use crate::runtime::Engine;

    fn problem(seed: u64, t: usize, n: usize, d: usize) -> MtlProblem {
        let mut rng = Rng::new(seed);
        let ds = synthetic::lowrank_regression(&vec![n; t], d, 2, 0.05, &mut rng);
        MtlProblem::new(ds, RegularizerKind::Nuclear, 0.2, 0.5, &mut rng)
    }

    #[test]
    fn amtl_runs_and_counts_updates() {
        let p = problem(130, 4, 30, 6);
        let computes = p.build_computes(Engine::Native, None).unwrap();
        let cfg = AmtlConfig { iters_per_node: 5, ..Default::default() };
        let r = run_amtl(&p, computes, &cfg).unwrap();
        assert_eq!(r.updates, 20);
        assert_eq!(r.updates_per_node, vec![5; 4]);
        assert!(r.prox_count >= 1);
        assert_eq!(r.w_final.rows(), 6);
        assert_eq!(r.w_final.cols(), 4);
    }

    #[test]
    fn amtl_decreases_objective() {
        let p = problem(131, 5, 40, 8);
        let computes = p.build_computes(Engine::Native, None).unwrap();
        let cfg = AmtlConfig { iters_per_node: 60, km: KmSchedule::fixed(0.9), ..Default::default() };
        let obj0 = p.objective(&p.prox_map(&crate::linalg::Mat::zeros(8, 5)));
        let r = run_amtl(&p, computes, &cfg).unwrap();
        let obj1 = p.objective(&r.w_final);
        assert!(obj1 < 0.2 * obj0, "objective {obj0} -> {obj1}");
    }

    #[test]
    fn amtl_converges_to_fista_optimum() {
        let p = problem(132, 4, 50, 6);
        // FISTA reference optimum.
        let masks: Vec<Vec<f64>> = p.dataset.tasks.iter().map(|t| vec![1.0; t.n()]).collect();
        let tasks: Vec<crate::optim::fista::TaskData> = p
            .dataset
            .tasks
            .iter()
            .zip(&masks)
            .map(|(t, m)| crate::optim::fista::TaskData { x: &t.x, y: &t.y, mask: m, loss: t.loss })
            .collect();
        let mut reg = p.regularizer();
        let fista = crate::optim::fista::fista(&tasks, &mut reg, p.l_max, 2000, 1e-12);
        let f_star = *fista.history.last().unwrap();

        let computes = p.build_computes(Engine::Native, None).unwrap();
        let cfg = AmtlConfig {
            iters_per_node: 400,
            km: KmSchedule::fixed(0.9),
            record_every: 1_000_000,
            ..Default::default()
        };
        let r = run_amtl(&p, computes, &cfg).unwrap();
        let f_amtl = p.objective(&r.w_final);
        assert!(
            f_amtl <= f_star * 1.05 + 1e-6,
            "AMTL {f_amtl} vs FISTA {f_star}"
        );
    }

    #[test]
    fn amtl_is_deterministic_without_concurrency_effects() {
        // With a single task there is no interleaving: two runs must agree.
        let p = problem(133, 1, 30, 5);
        let cfg = AmtlConfig { iters_per_node: 20, ..Default::default() };
        let r1 = run_amtl(&p, p.build_computes(Engine::Native, None).unwrap(), &cfg).unwrap();
        let r2 = run_amtl(&p, p.build_computes(Engine::Native, None).unwrap(), &cfg).unwrap();
        assert!(r1.v_final.max_abs_diff(&r2.v_final) < 1e-15);
    }

    #[test]
    fn trajectory_is_recorded() {
        let p = problem(134, 3, 20, 4);
        let cfg = AmtlConfig { iters_per_node: 10, record_every: 5, ..Default::default() };
        let r = run_amtl(&p, p.build_computes(Engine::Native, None).unwrap(), &cfg).unwrap();
        // 30 updates / stride 5 = ~6 samples + initial + final.
        assert!(r.trajectory.len() >= 4, "only {} points", r.trajectory.len());
        let objs = r.compute_objectives(|w| p.objective(w), |v| p.prox_map(v));
        // Objectives broadly decreasing: last < first.
        assert!(objs.last().unwrap().2 < objs[0].2);
    }

    #[test]
    fn online_svd_run_matches_exact_run_approximately() {
        let p = problem(135, 3, 30, 6);
        let cfg = AmtlConfig { iters_per_node: 30, ..Default::default() };
        let r_exact = run_amtl(&p, p.build_computes(Engine::Native, None).unwrap(), &cfg).unwrap();
        let cfg_online = AmtlConfig { online_svd: true, ..cfg };
        let r_online =
            run_amtl(&p, p.build_computes(Engine::Native, None).unwrap(), &cfg_online).unwrap();
        let f_exact = p.objective(&r_exact.w_final);
        let f_online = p.objective(&r_online.w_final);
        assert!(
            (f_exact - f_online).abs() / f_exact.max(1e-9) < 0.2,
            "exact {f_exact} vs online {f_online}"
        );
    }
}
