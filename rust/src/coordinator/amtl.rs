//! Deprecated asynchronous entry point.
//!
//! The AMTL driver (Algorithm 1) now lives in the unified
//! [`Session`](super::session::Session) API as the
//! [`Async`](super::schedule::Async) schedule; this module survives as a
//! thin compatibility shim so existing callers keep compiling.

use super::metrics::RunResult;
use super::problem::MtlProblem;
use super::schedule::Async;
use super::session::{RunConfig, Session};
use crate::runtime::TaskCompute;
use anyhow::Result;

/// Old name of the unified [`RunConfig`] (the fields are identical).
#[deprecated(note = "use coordinator::RunConfig with Session")]
pub type AmtlConfig = RunConfig;

/// Run asynchronous MTL. `computes` must have one entry per task (built by
/// [`MtlProblem::build_computes`]).
#[deprecated(note = "use Session::builder(problem).schedule(Async)")]
pub fn run_amtl(
    problem: &MtlProblem,
    computes: Vec<Box<dyn TaskCompute>>,
    cfg: &RunConfig,
) -> Result<RunResult> {
    Session::builder(problem)
        .config(cfg.clone())
        .computes(computes)
        .schedule(Async)
        .build()?
        .run()
}

#[cfg(test)]
#[allow(deprecated)]
mod tests {
    use super::*;
    use crate::coordinator::step_size::KmSchedule;
    use crate::data::synthetic;
    use crate::optim::prox::RegularizerKind;
    use crate::runtime::Engine;
    use crate::util::Rng;

    fn problem(seed: u64, t: usize, n: usize, d: usize) -> MtlProblem {
        let mut rng = Rng::new(seed);
        let ds = synthetic::lowrank_regression(&vec![n; t], d, 2, 0.05, &mut rng);
        MtlProblem::new(ds, RegularizerKind::Nuclear, 0.2, 0.5, &mut rng)
    }

    #[test]
    fn amtl_runs_and_counts_updates() {
        let p = problem(130, 4, 30, 6);
        let computes = p.build_computes(Engine::Native, None).unwrap();
        let cfg = AmtlConfig { iters_per_node: 5, ..Default::default() };
        let r = run_amtl(&p, computes, &cfg).unwrap();
        assert_eq!(r.updates, 20);
        assert_eq!(r.updates_per_node, vec![5; 4]);
        assert!(r.prox_count >= 1);
        assert_eq!(r.w_final.rows(), 6);
        assert_eq!(r.w_final.cols(), 4);
    }

    #[test]
    fn amtl_decreases_objective() {
        let p = problem(131, 5, 40, 8);
        let computes = p.build_computes(Engine::Native, None).unwrap();
        let cfg = AmtlConfig { iters_per_node: 60, km: KmSchedule::fixed(0.9), ..Default::default() };
        let obj0 = p.objective(&p.prox_map(&crate::linalg::Mat::zeros(8, 5)));
        let r = run_amtl(&p, computes, &cfg).unwrap();
        let obj1 = p.objective(&r.w_final);
        assert!(obj1 < 0.2 * obj0, "objective {obj0} -> {obj1}");
    }

    #[test]
    fn amtl_converges_to_fista_optimum() {
        let p = problem(132, 4, 50, 6);
        // FISTA reference optimum.
        let tasks = p.fista_tasks();
        let mut reg = p.regularizer();
        let fista = crate::optim::fista::fista(&tasks, &mut reg, p.l_max, 2000, 1e-12);
        let f_star = *fista.history.last().unwrap();

        let computes = p.build_computes(Engine::Native, None).unwrap();
        let cfg = AmtlConfig {
            iters_per_node: 400,
            km: KmSchedule::fixed(0.9),
            record_every: 1_000_000,
            ..Default::default()
        };
        let r = run_amtl(&p, computes, &cfg).unwrap();
        let f_amtl = p.objective(&r.w_final);
        assert!(
            f_amtl <= f_star * 1.05 + 1e-6,
            "AMTL {f_amtl} vs FISTA {f_star}"
        );
    }

    #[test]
    fn amtl_is_deterministic_without_concurrency_effects() {
        // With a single task there is no interleaving: two runs must agree.
        let p = problem(133, 1, 30, 5);
        let cfg = AmtlConfig { iters_per_node: 20, ..Default::default() };
        let r1 = run_amtl(&p, p.build_computes(Engine::Native, None).unwrap(), &cfg).unwrap();
        let r2 = run_amtl(&p, p.build_computes(Engine::Native, None).unwrap(), &cfg).unwrap();
        assert!(r1.v_final.max_abs_diff(&r2.v_final) < 1e-15);
    }

    #[test]
    fn trajectory_is_recorded() {
        let p = problem(134, 3, 20, 4);
        let cfg = AmtlConfig { iters_per_node: 10, record_every: 5, ..Default::default() };
        let r = run_amtl(&p, p.build_computes(Engine::Native, None).unwrap(), &cfg).unwrap();
        // 30 updates / stride 5 = ~6 samples + initial + final.
        assert!(r.trajectory.len() >= 4, "only {} points", r.trajectory.len());
        let objs = r.compute_objectives(|w| p.objective(w), |v| p.prox_map(v));
        // Objectives broadly decreasing: last < first.
        assert!(objs.last().unwrap().2 < objs[0].2);
    }

    #[test]
    fn online_svd_run_matches_exact_run_approximately() {
        let p = problem(135, 3, 30, 6);
        let cfg = AmtlConfig { iters_per_node: 30, ..Default::default() };
        let r_exact = run_amtl(&p, p.build_computes(Engine::Native, None).unwrap(), &cfg).unwrap();
        let cfg_online = AmtlConfig { online_svd: true, ..cfg };
        let r_online =
            run_amtl(&p, p.build_computes(Engine::Native, None).unwrap(), &cfg_online).unwrap();
        let f_exact = p.objective(&r_exact.w_final);
        let f_online = p.objective(&r_online.w_final);
        assert!(
            (f_exact - f_online).abs() / f_exact.max(1e-9) < 0.2,
            "exact {f_exact} vs online {f_online}"
        );
    }
}
