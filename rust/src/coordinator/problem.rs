//! Problem definition: a multi-task dataset + regularized MTL formulation
//! (Eq. III.1), with derived constants (Lipschitz, step sizes) and the
//! exact objective evaluator used for reporting.
//!
//! The coupling regularizer is addressed through the open
//! [`formulation`](crate::optim::formulation) API: the problem carries a
//! [`FormulationSpec`] (a registered name + params, e.g. `nuclear` or
//! `graph:topology=ring`), resolves it once at construction, and hands
//! fresh [`SharedProx`] instances to whoever needs one (the central
//! server owns a mutable one; reporting paths use throwaway clones).

use crate::data::MultiTaskDataset;
use crate::linalg::Mat;
use crate::optim::formulation::{self, FormulationSpec, SharedProx};
use crate::optim::lipschitz::task_lipschitz;
use crate::runtime::{make_task_computes, ComputePool, Engine, TaskCompute};
use crate::util::Rng;
use anyhow::Result;

/// `min_W Σ_t ℓ_t(w_t) + λ g(W)` over a concrete dataset.
pub struct MtlProblem {
    /// The per-task data.
    pub dataset: MultiTaskDataset,
    /// Which coupling formulation the problem uses (resolved through the
    /// registry at construction).
    pub formulation: FormulationSpec,
    /// Regularization strength λ.
    pub lambda: f64,
    /// Forward/backward step size `η ∈ (0, 2/L)`.
    pub eta: f64,
    /// Max per-task Lipschitz constant (the `L` of the joint loss).
    pub l_max: f64,
    /// The resolved regularizer prototype; [`MtlProblem::regularizer`]
    /// clones it so the spec is validated exactly once.
    reg_proto: Box<dyn SharedProx>,
    /// Cached all-ones row masks, one per task (the loss kernels take a
    /// mask argument; reporting paths reuse these instead of allocating a
    /// fresh `vec![1.0; n]` per objective evaluation).
    ones_masks: Vec<Vec<f64>>,
}

impl MtlProblem {
    /// Build a problem, estimating `L` by power iteration and choosing
    /// `η = eta_scale · 2/L` (`eta_scale ∈ (0,1)`, typically 0.5).
    ///
    /// `reg` is anything that converts into a [`FormulationSpec`] — a
    /// classic [`RegularizerKind`](crate::optim::prox::RegularizerKind)
    /// or a parsed spec. Panics if the spec does not resolve (a classic
    /// kind always does); use [`MtlProblem::try_new`] for fallible specs
    /// such as CLI input or file-backed graphs.
    pub fn new(
        dataset: MultiTaskDataset,
        reg: impl Into<FormulationSpec>,
        lambda: f64,
        eta_scale: f64,
        rng: &mut Rng,
    ) -> MtlProblem {
        MtlProblem::try_new(dataset, reg, lambda, eta_scale, rng)
            .expect("formulation spec must resolve (use try_new for fallible specs)")
    }

    /// Fallible form of [`MtlProblem::new`]: errors when the formulation
    /// spec does not resolve against the registry (unknown params, graph
    /// that does not cover the task count, ...).
    pub fn try_new(
        dataset: MultiTaskDataset,
        reg: impl Into<FormulationSpec>,
        lambda: f64,
        eta_scale: f64,
        rng: &mut Rng,
    ) -> Result<MtlProblem> {
        let formulation = reg.into();
        // Default elastic-net ℓ2 weight; override per spec (`:gamma=G`).
        let reg_proto = formulation::resolve(&formulation, lambda, 1.0, dataset.t())?;
        let l_max = dataset
            .tasks
            .iter()
            .map(|t| task_lipschitz(t.loss, &t.x, rng))
            .fold(0.0, f64::max);
        let eta = crate::optim::lipschitz::forward_step_size(l_max, eta_scale);
        let ones_masks = dataset.tasks.iter().map(|t| vec![1.0; t.n()]).collect();
        Ok(MtlProblem {
            dataset,
            formulation,
            lambda,
            eta,
            l_max,
            reg_proto,
            ones_masks,
        })
    }

    /// Number of tasks.
    pub fn t(&self) -> usize {
        self.dataset.t()
    }

    /// Feature dimension.
    pub fn d(&self) -> usize {
        self.dataset.d()
    }

    /// A fresh regularizer instance (the server owns a mutable one).
    pub fn regularizer(&self) -> Box<dyn SharedProx> {
        self.reg_proto.clone_box()
    }

    /// Canonical name of the problem's coupling formulation.
    pub fn reg_name(&self) -> &'static str {
        self.reg_proto.id()
    }

    /// The cached all-ones mask for task `t` (full-batch evaluation).
    pub fn ones_mask(&self, t: usize) -> &[f64] {
        &self.ones_masks[t]
    }

    /// Task views for the centralized FISTA reference solver (full-batch
    /// masks from the ones cache).
    pub fn fista_tasks(&self) -> Vec<crate::optim::fista::TaskData<'_>> {
        self.dataset
            .tasks
            .iter()
            .enumerate()
            .map(|(t, task)| crate::optim::fista::TaskData {
                x: &task.x,
                y: &task.y,
                mask: &self.ones_masks[t],
                loss: task.loss,
            })
            .collect()
    }

    /// Exact objective `F(W) = Σ ℓ_t(w_t) + λ g(W)` (native f64 path —
    /// never on the update path).
    pub fn objective(&self, w: &Mat) -> f64 {
        self.loss_value(w) + self.reg_proto.value(w)
    }

    /// Smooth part only: `Σ_t ℓ_t(w_t)`.
    pub fn loss_value(&self, w: &Mat) -> f64 {
        self.dataset
            .tasks
            .iter()
            .enumerate()
            .map(|(t, task)| task.loss.obj(&task.x, &task.y, w.col(t), &self.ones_masks[t]))
            .sum()
    }

    /// The backward map `W = Prox_{ηλg}(V)` used when reporting objectives
    /// of trajectory snapshots.
    pub fn prox_map(&self, v: &Mat) -> Mat {
        let mut w = v.clone();
        self.regularizer().prox(&mut w, self.eta);
        w
    }

    /// Per-task compute engines for the workers.
    pub fn build_computes(
        &self,
        engine: Engine,
        pool: Option<&ComputePool>,
    ) -> Result<Vec<Box<dyn TaskCompute>>> {
        make_task_computes(engine, pool, &self.dataset.tasks)
    }

    /// Mean per-task test RMSE of a model matrix against held-out data
    /// generated from the same planted model (effectiveness reporting).
    pub fn train_rmse(&self, w: &Mat) -> f64 {
        let mut total = 0.0;
        let mut count = 0usize;
        for (t, task) in self.dataset.tasks.iter().enumerate() {
            let wt = w.col(t);
            for i in 0..task.n() {
                let z: f64 = task.x.row(i).iter().zip(wt).map(|(a, b)| a * b).sum();
                let r = z - task.y[i];
                total += r * r;
                count += 1;
            }
        }
        (total / count.max(1) as f64).sqrt()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;
    use crate::optim::prox::RegularizerKind;

    fn small_problem(seed: u64) -> MtlProblem {
        let mut rng = Rng::new(seed);
        let ds = synthetic::lowrank_regression(&[40; 4], 10, 2, 0.1, &mut rng);
        MtlProblem::new(ds, RegularizerKind::Nuclear, 0.5, 0.5, &mut rng)
    }

    #[test]
    fn eta_is_half_of_two_over_l() {
        let p = small_problem(110);
        assert!((p.eta - 1.0 / p.l_max).abs() < 1e-12);
        assert!(p.eta > 0.0 && p.eta < 2.0 / p.l_max);
    }

    #[test]
    fn objective_is_loss_plus_reg() {
        let p = small_problem(111);
        let mut rng = Rng::new(112);
        let w = Mat::randn(p.d(), p.t(), &mut rng);
        let want = p.loss_value(&w) + p.regularizer().value(&w);
        assert!((p.objective(&w) - want).abs() < 1e-9);
    }

    #[test]
    fn objective_at_planted_model_is_small() {
        let p = small_problem(113);
        let w = p.dataset.w_true.clone().unwrap();
        // noise=0.1 → loss ≈ Σ n·σ² = 160·0.01 ≈ 1.6, plus λ‖W‖*.
        let f = p.loss_value(&w);
        assert!(f < 10.0, "loss at planted model: {f}");
    }

    #[test]
    fn prox_map_matches_regularizer() {
        let p = small_problem(114);
        let mut rng = Rng::new(115);
        let v = Mat::randn(p.d(), p.t(), &mut rng);
        let w = p.prox_map(&v);
        let mut want = v.clone();
        p.regularizer().prox(&mut want, p.eta);
        assert!(w.max_abs_diff(&want) < 1e-12);
    }

    #[test]
    fn ones_mask_is_cached_per_task() {
        let p = small_problem(117);
        for t in 0..p.t() {
            assert_eq!(p.ones_mask(t).len(), p.dataset.tasks[t].n());
            assert!(p.ones_mask(t).iter().all(|&m| m == 1.0));
        }
    }

    #[test]
    fn train_rmse_zero_at_interpolation() {
        let mut rng = Rng::new(116);
        let ds = synthetic::lowrank_regression(&[30; 3], 8, 2, 0.0, &mut rng);
        let w = ds.w_true.clone().unwrap();
        let p = MtlProblem::new(ds, RegularizerKind::None, 0.0, 0.5, &mut rng);
        assert!(p.train_rmse(&w) < 1e-9);
    }

    #[test]
    fn problem_resolves_open_formulations_by_spec() {
        let mut rng = Rng::new(118);
        let ds = synthetic::lowrank_regression(&[20; 3], 6, 2, 0.1, &mut rng);
        let spec = FormulationSpec::parse("graph:topology=ring,weight=0.5").unwrap();
        let p = MtlProblem::try_new(ds, spec, 0.3, 0.5, &mut rng).unwrap();
        assert_eq!(p.reg_name(), "graph");
        assert_eq!(p.formulation.name(), "graph");
        assert_eq!(p.regularizer().lambda(), 0.3);
    }

    #[test]
    fn try_new_rejects_bad_specs() {
        let mut rng = Rng::new(119);
        let ds = synthetic::lowrank_regression(&[20; 2], 5, 2, 0.1, &mut rng);
        let spec = FormulationSpec::parse("mean:bogus=1").unwrap();
        assert!(MtlProblem::try_new(ds, spec, 0.3, 0.5, &mut rng).is_err());
    }
}
