//! Elastic task-node membership: who is in the run *right now*.
//!
//! The paper's premise is that task nodes are unreliable — the schedules
//! already tolerate a node that *reports* its crash (fault injection),
//! but a silently dead TCP peer used to stall anything waiting on it
//! forever. The [`NodeRegistry`] closes that gap with timeout-based
//! liveness: nodes `register` when they join, `heartbeat` while they
//! work, and `leave` when they are done; a `sweep` evicts any registered
//! node whose last sign of life is older than the timeout and fires the
//! eviction callbacks (`SemiSync` hooks its
//! [`StalenessGate`](super::schedule::StalenessGate) in here so a dead
//! straggler stops gating the federation, and the `--serve` wait loop
//! stops waiting for evicted nodes).
//!
//! Sweeps are opportunistic — every `register`/`heartbeat` sweeps first —
//! so any live traffic is enough to detect dead peers; pollers with no
//! traffic of their own (the serve loop) call [`NodeRegistry::sweep`]
//! directly. An evicted node that comes back is told so on its next
//! heartbeat (`live = false`) and rejoins by re-registering, which bumps
//! its membership generation.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::{Duration, Instant};

/// Membership state of one task node.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum NodeStatus {
    /// Never registered (a run may legitimately never start this node).
    Unseen,
    /// Registered and inside the liveness timeout.
    Live,
    /// Registered once, then silent past the timeout.
    Evicted,
    /// Departed politely via `leave`.
    Left,
}

struct Slot {
    status: NodeStatus,
    last_seen: Option<Instant>,
    generation: u64,
}

/// Timeout-based liveness table over the run's `T` task-node slots.
pub struct NodeRegistry {
    timeout: Duration,
    slots: Mutex<Vec<Slot>>,
    callbacks: Mutex<Vec<Box<dyn Fn(usize) + Send + Sync>>>,
    evictions: AtomicU64,
}

impl NodeRegistry {
    /// A registry for `t_count` nodes: a registered node silent for
    /// longer than `timeout` is evicted at the next sweep.
    pub fn new(t_count: usize, timeout: Duration) -> NodeRegistry {
        NodeRegistry {
            timeout,
            slots: Mutex::new(
                (0..t_count)
                    .map(|_| Slot { status: NodeStatus::Unseen, last_seen: None, generation: 0 })
                    .collect(),
            ),
            callbacks: Mutex::new(Vec::new()),
            evictions: AtomicU64::new(0),
        }
    }

    /// The eviction timeout.
    pub fn timeout(&self) -> Duration {
        self.timeout
    }

    /// Number of node slots.
    pub fn len(&self) -> usize {
        self.slots.lock().unwrap().len()
    }

    /// True when the registry tracks zero nodes.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Register (or re-register) node `t`, returning its membership
    /// generation — 1 on first join, incremented on every rejoin after an
    /// eviction, restart, or departure. Sweeps first.
    pub fn register(&self, t: usize) -> u64 {
        self.fire(self.sweep_internal());
        let mut slots = self.slots.lock().unwrap();
        let slot = &mut slots[t];
        slot.status = NodeStatus::Live;
        slot.last_seen = Some(Instant::now());
        slot.generation += 1;
        slot.generation
    }

    /// Record a sign of life from node `t`. Returns `true` while the node
    /// is a live member; `false` means it was evicted (or never joined)
    /// and must re-register. Sweeps first, so any node's traffic detects
    /// everyone else's silence.
    pub fn heartbeat(&self, t: usize) -> bool {
        self.fire(self.sweep_internal());
        let mut slots = self.slots.lock().unwrap();
        let slot = &mut slots[t];
        if slot.status == NodeStatus::Live {
            slot.last_seen = Some(Instant::now());
            true
        } else {
            false
        }
    }

    /// Polite departure of node `t` (the run stops waiting for it; not an
    /// eviction, so no callbacks fire). An already-evicted node stays
    /// `Evicted` — it is not a member, and the eviction record is part of
    /// the run's report.
    pub fn leave(&self, t: usize) {
        let mut slots = self.slots.lock().unwrap();
        if slots[t].status != NodeStatus::Evicted {
            slots[t].status = NodeStatus::Left;
        }
    }

    /// Evict every live node whose last sign of life is older than the
    /// timeout; fires the eviction callbacks and returns the newly
    /// evicted node ids.
    pub fn sweep(&self) -> Vec<usize> {
        let evicted = self.sweep_internal();
        self.fire(evicted.clone());
        evicted
    }

    /// Current status of node `t`.
    pub fn status(&self, t: usize) -> NodeStatus {
        self.slots.lock().unwrap()[t].status
    }

    /// True when node `t` has been evicted.
    pub fn is_evicted(&self, t: usize) -> bool {
        self.status(t) == NodeStatus::Evicted
    }

    /// Ids of all currently evicted nodes.
    pub fn evicted_nodes(&self) -> Vec<usize> {
        let slots = self.slots.lock().unwrap();
        slots
            .iter()
            .enumerate()
            .filter(|(_, s)| s.status == NodeStatus::Evicted)
            .map(|(t, _)| t)
            .collect()
    }

    /// Total evictions so far (rejoining does not subtract).
    pub fn eviction_count(&self) -> u64 {
        self.evictions.load(Ordering::Relaxed)
    }

    /// Run `cb(t)` whenever node `t` is evicted. Callbacks run outside
    /// the registry lock (they may take their own locks, e.g. a staleness
    /// gate's).
    pub fn on_evict(&self, cb: impl Fn(usize) + Send + Sync + 'static) {
        self.callbacks.lock().unwrap().push(Box::new(cb));
    }

    fn sweep_internal(&self) -> Vec<usize> {
        let now = Instant::now();
        let mut evicted = Vec::new();
        let mut slots = self.slots.lock().unwrap();
        for (t, slot) in slots.iter_mut().enumerate() {
            if slot.status == NodeStatus::Live {
                let stale = slot
                    .last_seen
                    .map(|seen| now.duration_since(seen) > self.timeout)
                    .unwrap_or(true);
                if stale {
                    slot.status = NodeStatus::Evicted;
                    evicted.push(t);
                }
            }
        }
        drop(slots);
        self.evictions.fetch_add(evicted.len() as u64, Ordering::Relaxed);
        evicted
    }

    fn fire(&self, evicted: Vec<usize>) {
        if evicted.is_empty() {
            return;
        }
        let callbacks = self.callbacks.lock().unwrap();
        for t in evicted {
            for cb in callbacks.iter() {
                cb(t);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;
    use std::sync::Arc;

    #[test]
    fn lifecycle_register_heartbeat_leave() {
        let reg = NodeRegistry::new(3, Duration::from_secs(60));
        assert_eq!(reg.status(0), NodeStatus::Unseen);
        assert_eq!(reg.register(0), 1);
        assert_eq!(reg.status(0), NodeStatus::Live);
        assert!(reg.heartbeat(0));
        reg.leave(0);
        assert_eq!(reg.status(0), NodeStatus::Left);
        assert!(!reg.heartbeat(0), "a departed node is no longer a member");
        assert_eq!(reg.register(0), 2, "rejoin bumps the generation");
    }

    #[test]
    fn unregistered_nodes_fail_heartbeats_but_are_not_evicted() {
        let reg = NodeRegistry::new(2, Duration::from_millis(1));
        assert!(!reg.heartbeat(1));
        std::thread::sleep(Duration::from_millis(5));
        assert!(reg.sweep().is_empty(), "Unseen nodes are not members, so never evicted");
        assert_eq!(reg.status(1), NodeStatus::Unseen);
    }

    #[test]
    fn silent_nodes_are_evicted_on_sweep() {
        let reg = NodeRegistry::new(2, Duration::from_millis(10));
        reg.register(0);
        reg.register(1);
        let hot = std::time::Instant::now();
        while hot.elapsed() < Duration::from_millis(25) {
            assert!(reg.heartbeat(0), "node 0 keeps heartbeating");
            std::thread::sleep(Duration::from_millis(2));
        }
        // Node 1 went silent: node 0's heartbeats swept it out.
        assert_eq!(reg.status(1), NodeStatus::Evicted);
        assert_eq!(reg.evicted_nodes(), vec![1]);
        assert!(reg.eviction_count() >= 1);
        assert!(!reg.heartbeat(1), "evicted node learns it must re-register");
        assert_eq!(reg.register(1), 2);
        assert_eq!(reg.status(1), NodeStatus::Live);
    }

    #[test]
    fn eviction_fires_callbacks_once_per_eviction() {
        let reg = NodeRegistry::new(2, Duration::from_millis(5));
        let count = Arc::new(AtomicUsize::new(0));
        let c = Arc::clone(&count);
        reg.on_evict(move |t| {
            assert_eq!(t, 1);
            c.fetch_add(1, Ordering::SeqCst);
        });
        reg.register(1);
        std::thread::sleep(Duration::from_millis(12));
        reg.sweep();
        reg.sweep(); // already evicted: no second firing
        assert_eq!(count.load(Ordering::SeqCst), 1);
    }

    #[test]
    fn len_reports_slot_count() {
        let reg = NodeRegistry::new(4, Duration::from_secs(1));
        assert_eq!(reg.len(), 4);
        assert!(!reg.is_empty());
    }
}
