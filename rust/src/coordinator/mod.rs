//! Layer-3 coordinator: the paper's contribution, behind one API.
//!
//! The regularized MTL problem (Eq. III.1) is solved by a backward
//! (proximal) step on the central server and forward (gradient) steps on
//! the task nodes; *when* those steps happen is a pluggable
//! [`Schedule`], and *how* the two sides talk is a pluggable
//! [`Transport`](crate::transport::Transport). A [`Session`] wires one
//! problem, one shared [`RunConfig`], one schedule, and one transport into
//! a run:
//!
//! ```no_run
//! # use amtl::coordinator::{MtlProblem, Session, SemiSync};
//! # use amtl::transport::TransportKind;
//! # fn demo(problem: &MtlProblem) -> anyhow::Result<()> {
//! let result = Session::builder(problem)
//!     .iters_per_node(100)
//!     .paper_offset(5.0)          // the paper's AMTL-5 network setting
//!     .transport(TransportKind::Tcp) // real sockets, same math
//!     .schedule(SemiSync { staleness_bound: 4 })
//!     .build()?
//!     .run()?;
//! # Ok(())
//! # }
//! ```
//!
//! Modules:
//!
//! * [`session`] — the [`Session`] builder, the shared [`RunConfig`], and
//!   the [`Orchestrator`](session::Orchestrator) surface schedules drive.
//! * [`schedule`] — the [`Schedule`] trait and its implementations:
//!   [`Async`] (Algorithm 1 / ARock, no barrier), [`Synchronized`]
//!   (§III.B barrier rounds), [`SemiSync`] (bounded staleness). Every
//!   schedule routes its backward fetches and KM commits through the
//!   transport layer, so all three run unchanged over shared memory or
//!   TCP.
//! * [`state`] — the central server's shared model matrix `V ∈ R^{d×T}`
//!   with per-task-block locking and *inconsistent* full-matrix snapshots
//!   (the lock-free-read semantics of §III.C / Fig. 2, which the ARock
//!   convergence analysis explicitly tolerates).
//! * [`server`] — the backward step: proximal mapping of the coupling
//!   regularizer — any [`SharedProx`](crate::optim::formulation::SharedProx)
//!   impl from the formulation registry — over a snapshot of `V` (or its
//!   snapshot-free incremental path), with a version-keyed cache, plus
//!   [`server::CentralServer::commit_update`], the single commit path
//!   both transports land updates through.
//! * [`worker`] — a task node: network delay → fetch its prox block
//!   through the transport → forward (gradient) step through
//!   [`crate::runtime::TaskCompute`] → KM relaxation commit of its own
//!   block (Eq. III.4 / III.5), again through the transport. A worker
//!   never touches the server directly, which is what makes the
//!   two-process deployment (`amtl --serve` / `amtl --node`) possible.
//! * [`step_size`] — Theorem 1 step bound and the dynamic multiplier
//!   `c_{t,k} = log(max(ν̄_{t,k}, 10))` of Eq. III.6.
//! * [`metrics`] — objective trajectories, update counts, timing.
//! * [`registry`] — elastic membership: register/heartbeat/leave with
//!   timeout-based eviction, so a silently dead task node stops gating
//!   every schedule and a restarted one rejoins mid-run (durability for
//!   the server side lives in [`crate::persist`]).
//!
//! ## Data paths (what crosses the worker↔server edge)
//!
//! In-proc: `fetch` hands the worker a copy of the cached prox column;
//! `push` is a direct call into the block-locked state. Over TCP the same
//! two operations are `FetchProxCol`/`PushUpdate` frames (see
//! [`crate::transport::wire`]): prox columns, update vectors, and scalars
//! (η, KM step, version). Task data `(X_t, y_t)` stays on its node in
//! both cases — the wire protocol has no frame that could carry it.

pub mod metrics;
pub mod problem;
pub mod registry;
pub mod schedule;
pub mod server;
pub mod session;
pub mod state;
pub mod step_size;
pub mod worker;

pub use metrics::RunResult;
pub use problem::MtlProblem;
pub use registry::{NodeRegistry, NodeStatus};
pub use schedule::{schedule_from_cli, Async, Schedule, SemiSync, StalenessGate, Synchronized};
pub use session::{DEFAULT_RESVD_EVERY, RunConfig, Session, SessionBuilder};
