//! Layer-3 coordinator: the paper's contribution, behind one API.
//!
//! The regularized MTL problem (Eq. III.1) is solved by a backward
//! (proximal) step on the central server and forward (gradient) steps on
//! the task nodes; *when* those steps happen is a pluggable
//! [`Schedule`]. A [`Session`] wires one problem, one shared
//! [`RunConfig`], and one schedule into a run:
//!
//! ```no_run
//! # use amtl::coordinator::{MtlProblem, Session, SemiSync};
//! # fn demo(problem: &MtlProblem) -> anyhow::Result<()> {
//! let result = Session::builder(problem)
//!     .iters_per_node(100)
//!     .paper_offset(5.0)          // the paper's AMTL-5 network setting
//!     .schedule(SemiSync { staleness_bound: 4 })
//!     .build()?
//!     .run()?;
//! # Ok(())
//! # }
//! ```
//!
//! Modules:
//!
//! * [`session`] — the [`Session`] builder, the shared [`RunConfig`], and
//!   the [`Orchestrator`](session::Orchestrator) surface schedules drive.
//! * [`schedule`] — the [`Schedule`] trait and its implementations:
//!   [`Async`] (Algorithm 1 / ARock, no barrier), [`Synchronized`]
//!   (§III.B barrier rounds), [`SemiSync`] (bounded staleness).
//! * [`state`] — the central server's shared model matrix `V ∈ R^{d×T}`
//!   with per-task-block locking and *inconsistent* full-matrix snapshots
//!   (the lock-free-read semantics of §III.C / Fig. 2, which the ARock
//!   convergence analysis explicitly tolerates).
//! * [`server`] — the backward step: proximal mapping of the coupling
//!   regularizer over a snapshot of `V`, with a version-keyed cache.
//! * [`worker`] — a task node: simulated network delay → fetch its prox
//!   block → forward (gradient) step through
//!   [`crate::runtime::TaskCompute`] → KM relaxation update of its own
//!   block (Eq. III.4 / III.5).
//! * [`step_size`] — Theorem 1 step bound and the dynamic multiplier
//!   `c_{t,k} = log(max(ν̄_{t,k}, 10))` of Eq. III.6.
//! * [`metrics`] — objective trajectories, update counts, timing.
//! * [`amtl`] / [`smtl`] — deprecated shims over the old forked entry
//!   points (`run_amtl` / `run_smtl`).

pub mod amtl;
pub mod metrics;
pub mod problem;
pub mod schedule;
pub mod server;
pub mod session;
pub mod smtl;
pub mod state;
pub mod step_size;
pub mod worker;

pub use metrics::RunResult;
pub use problem::MtlProblem;
pub use schedule::{Async, Schedule, SemiSync, StalenessGate, Synchronized};
pub use session::{RunConfig, Session, SessionBuilder};

#[allow(deprecated)]
pub use amtl::{run_amtl, AmtlConfig};
#[allow(deprecated)]
pub use smtl::{run_smtl, SmtlConfig};
