//! Layer-3 coordinator: the paper's contribution.
//!
//! * [`state`] — the central server's shared model matrix `V ∈ R^{d×T}`
//!   with per-task-block locking and *inconsistent* full-matrix snapshots
//!   (the lock-free-read semantics of §III.C / Fig. 2, which the ARock
//!   convergence analysis explicitly tolerates).
//! * [`server`] — the backward step: proximal mapping of the coupling
//!   regularizer over a snapshot of `V`, with a version-keyed cache
//!   (the paper notes the prox "can be applied after several gradient
//!   updates"; the cache collapses redundant proxes of an unchanged `V`).
//! * [`worker`] — a task node: simulated network delay → fetch its prox
//!   block → forward (gradient) step through [`crate::runtime::TaskCompute`]
//!   → KM relaxation update of its own block (Eq. III.4 / III.5).
//! * [`amtl`] — the asynchronous driver (Algorithm 1): workers never wait
//!   for each other.
//! * [`smtl`] — the synchronized baseline (§III.B): barrier per iteration.
//! * [`step_size`] — Theorem 1 step bound and the dynamic multiplier
//!   `c_{t,k} = log(max(ν̄_{t,k}, 10))` of Eq. III.6.
//! * [`metrics`] — objective trajectories, update counts, timing.

pub mod amtl;
pub mod metrics;
pub mod problem;
pub mod server;
pub mod smtl;
pub mod state;
pub mod step_size;
pub mod worker;

pub use amtl::{run_amtl, AmtlConfig};
pub use metrics::RunResult;
pub use problem::MtlProblem;
pub use smtl::{run_smtl, SmtlConfig};
