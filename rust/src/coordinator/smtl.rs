//! Deprecated synchronized entry point.
//!
//! The SMTL baseline (§III.B) now lives in the unified
//! [`Session`](super::session::Session) API as the
//! [`Synchronized`](super::schedule::Synchronized) schedule; this module
//! survives as a thin compatibility shim so existing callers keep
//! compiling. Unlike the old driver, the schedule has full feature parity
//! with the asynchronous one (faults, minibatch steps, `prox_every`,
//! dynamic step) via the shared [`RunConfig`] — use the builder to reach
//! those knobs.

use super::metrics::RunResult;
use super::problem::MtlProblem;
use super::schedule::Synchronized;
use super::session::{RunConfig, Session};
use super::step_size::KmSchedule;
use crate::net::DelayModel;
use crate::runtime::TaskCompute;
use anyhow::Result;
use std::time::Duration;

/// Configuration of one SMTL run (the old, reduced surface).
#[deprecated(note = "use coordinator::RunConfig with Session")]
#[derive(Clone, Debug)]
pub struct SmtlConfig {
    /// Synchronized iterations (each is one forward step per node).
    pub iters: usize,
    pub delay: DelayModel,
    pub time_scale: Duration,
    /// KM/relaxation step applied to the collected updates (the same η_k
    /// as AMTL so per-iteration progress is comparable — §IV.B.1).
    pub km: KmSchedule,
    pub record_every: u64,
    pub seed: u64,
}

#[allow(deprecated)]
impl Default for SmtlConfig {
    fn default() -> Self {
        SmtlConfig {
            iters: 10,
            delay: DelayModel::None,
            time_scale: Duration::from_millis(100),
            km: KmSchedule::fixed(0.5),
            record_every: 1,
            seed: 7,
        }
    }
}

#[allow(deprecated)]
impl From<&SmtlConfig> for RunConfig {
    fn from(cfg: &SmtlConfig) -> RunConfig {
        RunConfig {
            iters_per_node: cfg.iters,
            delay: cfg.delay.clone(),
            time_scale: cfg.time_scale,
            km: cfg.km,
            record_every: cfg.record_every,
            seed: cfg.seed,
            ..RunConfig::default()
        }
    }
}

/// Run synchronized distributed MTL.
#[deprecated(note = "use Session::builder(problem).schedule(Synchronized)")]
#[allow(deprecated)]
pub fn run_smtl(
    problem: &MtlProblem,
    computes: Vec<Box<dyn TaskCompute>>,
    cfg: &SmtlConfig,
) -> Result<RunResult> {
    Session::builder(problem)
        .config(RunConfig::from(cfg))
        .computes(computes)
        .schedule(Synchronized)
        .build()?
        .run()
}

#[cfg(test)]
#[allow(deprecated)]
mod tests {
    use super::*;
    use crate::data::synthetic;
    use crate::optim::prox::RegularizerKind;
    use crate::runtime::Engine;
    use crate::util::Rng;

    fn problem(seed: u64, t: usize, n: usize, d: usize) -> MtlProblem {
        let mut rng = Rng::new(seed);
        let ds = synthetic::lowrank_regression(&vec![n; t], d, 2, 0.05, &mut rng);
        MtlProblem::new(ds, RegularizerKind::Nuclear, 0.2, 0.5, &mut rng)
    }

    #[test]
    fn smtl_runs_expected_rounds() {
        let p = problem(140, 4, 20, 5);
        let cfg = SmtlConfig { iters: 6, ..Default::default() };
        let r = run_smtl(&p, p.build_computes(Engine::Native, None).unwrap(), &cfg).unwrap();
        assert_eq!(r.updates, 24); // T × iters
        assert_eq!(r.updates_per_node, vec![6; 4]);
        assert_eq!(r.method, "smtl");
    }

    #[test]
    fn smtl_decreases_objective() {
        let p = problem(141, 4, 40, 6);
        let cfg = SmtlConfig { iters: 80, km: KmSchedule::fixed(0.9), ..Default::default() };
        let r = run_smtl(&p, p.build_computes(Engine::Native, None).unwrap(), &cfg).unwrap();
        let f0 = p.objective(&p.prox_map(&crate::linalg::Mat::zeros(6, 4)));
        let f1 = p.objective(&r.w_final);
        assert!(f1 < 0.2 * f0, "objective {f0} -> {f1}");
    }

    #[test]
    fn smtl_and_amtl_reach_similar_objectives() {
        // Same per-node iteration budget; asynchrony should not change the
        // quality of the solution materially (paper Fig. 4).
        let p = problem(142, 4, 40, 6);
        let cfg = RunConfig {
            iters_per_node: 120,
            km: KmSchedule::fixed(0.9),
            ..Default::default()
        };
        let rs = Session::builder(&p)
            .config(cfg.clone())
            .schedule(Synchronized)
            .build()
            .unwrap()
            .run()
            .unwrap();
        let ra = Session::builder(&p)
            .config(cfg)
            .schedule(crate::coordinator::schedule::Async)
            .build()
            .unwrap()
            .run()
            .unwrap();
        let fs = p.objective(&rs.w_final);
        let fa = p.objective(&ra.w_final);
        assert!((fs - fa).abs() / fs.max(1e-9) < 0.1, "smtl {fs} vs amtl {fa}");
    }

    #[test]
    fn smtl_round_time_is_dominated_by_slowest_node() {
        // Node 0 is 8× slower than the others; the barrier makes every
        // round pay node 0's delay.
        let p = problem(143, 4, 10, 4);
        let slow = DelayModel::OffsetJitter {
            offset: Duration::from_millis(40),
            jitter: Duration::ZERO,
        };
        let fast = DelayModel::OffsetJitter {
            offset: Duration::from_millis(5),
            jitter: Duration::ZERO,
        };
        let cfg = SmtlConfig {
            iters: 5,
            delay: DelayModel::PerNode {
                per_node: vec![
                    Box::new(slow),
                    Box::new(fast.clone()),
                    Box::new(fast.clone()),
                    Box::new(fast),
                ],
            },
            ..Default::default()
        };
        let r = run_smtl(&p, p.build_computes(Engine::Native, None).unwrap(), &cfg).unwrap();
        // 5 rounds × ≥40ms straggler = ≥200ms.
        assert!(
            r.wall_time >= Duration::from_millis(190),
            "wall {:?}",
            r.wall_time
        );
    }
}
