//! Synchronized distributed MTL (SMTL) — the §III.B baseline.
//!
//! Classic map-reduce proximal gradient: every iteration, the server
//! computes `Ŵ = Prox_{ηλg}(V)` once and broadcasts; **all** T task nodes
//! compute their forward steps in parallel (each behind its own simulated
//! network delay); a barrier waits for the **slowest** node; then the
//! server applies the collected updates and the next iteration begins.
//! The straggler effect the paper measures comes entirely from that
//! barrier: round time = max over nodes of (delay + compute).

use super::metrics::{Recorder, RunResult};
use super::problem::MtlProblem;
use super::server::CentralServer;
use super::state::SharedState;
use super::step_size::KmSchedule;
use crate::net::DelayModel;
use crate::runtime::TaskCompute;
use crate::util::Rng;
use anyhow::Result;
use std::sync::{Arc, Barrier, Mutex, RwLock};
use std::time::{Duration, Instant};

/// Configuration of one SMTL run (mirrors [`super::amtl::AmtlConfig`]).
#[derive(Clone, Debug)]
pub struct SmtlConfig {
    /// Synchronized iterations (each is one forward step per node).
    pub iters: usize,
    pub delay: DelayModel,
    pub time_scale: Duration,
    /// KM/relaxation step applied to the collected updates (the same η_k
    /// as AMTL so per-iteration progress is comparable — §IV.B.1 "both
    /// have nearly identical progress per iteration").
    pub km: KmSchedule,
    pub record_every: u64,
    pub seed: u64,
}

impl Default for SmtlConfig {
    fn default() -> Self {
        SmtlConfig {
            iters: 10,
            delay: DelayModel::None,
            time_scale: Duration::from_millis(100),
            km: KmSchedule::fixed(0.5),
            record_every: 1,
            seed: 7,
        }
    }
}

impl SmtlConfig {
    pub fn with_paper_offset(mut self, offset_units: f64) -> SmtlConfig {
        self.delay = DelayModel::paper_offset(self.time_scale.mul_f64(offset_units));
        self
    }
}

/// Run synchronized distributed MTL.
pub fn run_smtl(
    problem: &MtlProblem,
    mut computes: Vec<Box<dyn TaskCompute>>,
    cfg: &SmtlConfig,
) -> Result<RunResult> {
    let t_count = problem.t();
    anyhow::ensure!(computes.len() == t_count, "one compute per task");

    let state = Arc::new(SharedState::zeros(problem.d(), t_count));
    let server = Arc::new(CentralServer::new(
        Arc::clone(&state),
        problem.regularizer(),
        problem.eta,
    ));
    let recorder = Recorder::new(cfg.record_every);
    recorder.record_now(0, state.snapshot());

    // Broadcast slot for Ŵ and collection slots for the forward results.
    let w_hat: RwLock<Arc<crate::linalg::Mat>> = RwLock::new(server.prox_matrix());
    let slots: Vec<Mutex<Option<Vec<f64>>>> = (0..t_count).map(|_| Mutex::new(None)).collect();
    let barrier = Barrier::new(t_count + 1);
    let mut root_rng = Rng::new(cfg.seed);
    let mut worker_rngs: Vec<Rng> = (0..t_count).map(|t| root_rng.fork(t as u64)).collect();

    let start = Instant::now();
    let total_delay = Mutex::new(0.0f64);
    std::thread::scope(|s| -> Result<()> {
        let mut handles = Vec::new();
        for (t, (compute, mut rng)) in computes.iter_mut().zip(worker_rngs.drain(..)).enumerate() {
            let barrier = &barrier;
            let w_hat = &w_hat;
            let slots = &slots;
            let server = Arc::clone(&server);
            let delay = cfg.delay.clone();
            let total_delay = &total_delay;
            let handle = std::thread::Builder::new()
                .name(format!("smtl-worker-{t}"))
                .spawn_scoped(s, move || -> Result<()> {
                    for _ in 0..cfg.iters {
                        barrier.wait(); // iteration start: Ŵ published
                        let sample = delay.sample(t, &mut rng);
                        if sample.duration > Duration::ZERO {
                            std::thread::sleep(sample.duration);
                        }
                        *total_delay.lock().unwrap() += sample.duration.as_secs_f64();
                        let wt = w_hat.read().unwrap().col(t).to_vec();
                        let (u, _loss) = compute.step(&wt, server.eta())?;
                        *slots[t].lock().unwrap() = Some(u);
                        barrier.wait(); // iteration end: all nodes done
                    }
                    Ok(())
                })?;
            handles.push(handle);
        }

        // The server loop (this thread).
        for iter in 0..cfg.iters {
            barrier.wait(); // release workers into the round
            barrier.wait(); // wait for the slowest worker (the straggler cost)
            for t in 0..t_count {
                let u = slots[t].lock().unwrap().take().expect("worker missed slot");
                state.km_update(t, &u, cfg.km.eta_k);
            }
            recorder.maybe_record(state.version(), || state.snapshot());
            if iter + 1 < cfg.iters {
                *w_hat.write().unwrap() = server.prox_matrix();
            }
        }
        for h in handles {
            h.join().map_err(|_| anyhow::anyhow!("smtl worker panicked"))??;
        }
        Ok(())
    })?;
    let wall_time = start.elapsed();

    let v_final = state.snapshot();
    recorder.record_now(state.version(), v_final.clone());
    let w_final = server.final_w();
    let updates = state.version();
    let mean_delay_secs = if updates > 0 {
        *total_delay.lock().unwrap() / updates as f64
    } else {
        0.0
    };
    Ok(RunResult {
        method: "smtl".into(),
        wall_time,
        v_final,
        w_final,
        updates,
        updates_per_node: vec![cfg.iters as u64; t_count],
        prox_count: server.prox_count(),
        trajectory: recorder.into_points(),
        mean_delay_secs,
        dropped_updates: 0,
        crashed_nodes: vec![],
        compute_secs: 0.0,
        backward_wait_secs: 0.0,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;
    use crate::optim::prox::RegularizerKind;
    use crate::runtime::Engine;

    fn problem(seed: u64, t: usize, n: usize, d: usize) -> MtlProblem {
        let mut rng = Rng::new(seed);
        let ds = synthetic::lowrank_regression(&vec![n; t], d, 2, 0.05, &mut rng);
        MtlProblem::new(ds, RegularizerKind::Nuclear, 0.2, 0.5, &mut rng)
    }

    #[test]
    fn smtl_runs_expected_rounds() {
        let p = problem(140, 4, 20, 5);
        let cfg = SmtlConfig { iters: 6, ..Default::default() };
        let r = run_smtl(&p, p.build_computes(Engine::Native, None).unwrap(), &cfg).unwrap();
        assert_eq!(r.updates, 24); // T × iters
        assert_eq!(r.updates_per_node, vec![6; 4]);
    }

    #[test]
    fn smtl_decreases_objective() {
        let p = problem(141, 4, 40, 6);
        let cfg = SmtlConfig { iters: 80, km: KmSchedule::fixed(0.9), ..Default::default() };
        let r = run_smtl(&p, p.build_computes(Engine::Native, None).unwrap(), &cfg).unwrap();
        let f0 = p.objective(&p.prox_map(&crate::linalg::Mat::zeros(6, 4)));
        let f1 = p.objective(&r.w_final);
        assert!(f1 < 0.2 * f0, "objective {f0} -> {f1}");
    }

    #[test]
    fn smtl_and_amtl_reach_similar_objectives() {
        // Same per-node iteration budget; asynchrony should not change the
        // quality of the solution materially (paper Fig. 4).
        let p = problem(142, 4, 40, 6);
        let smtl_cfg = SmtlConfig { iters: 120, km: KmSchedule::fixed(0.9), ..Default::default() };
        let amtl_cfg = crate::coordinator::amtl::AmtlConfig {
            iters_per_node: 120,
            km: KmSchedule::fixed(0.9),
            ..Default::default()
        };
        let rs = run_smtl(&p, p.build_computes(Engine::Native, None).unwrap(), &smtl_cfg).unwrap();
        let ra = crate::coordinator::amtl::run_amtl(
            &p,
            p.build_computes(Engine::Native, None).unwrap(),
            &amtl_cfg,
        )
        .unwrap();
        let fs = p.objective(&rs.w_final);
        let fa = p.objective(&ra.w_final);
        assert!((fs - fa).abs() / fs.max(1e-9) < 0.1, "smtl {fs} vs amtl {fa}");
    }

    #[test]
    fn smtl_round_time_is_dominated_by_slowest_node() {
        // Node 0 is 8× slower than the others; the barrier makes every
        // round pay node 0's delay.
        let p = problem(143, 4, 10, 4);
        let slow = DelayModel::OffsetJitter {
            offset: Duration::from_millis(40),
            jitter: Duration::ZERO,
        };
        let fast = DelayModel::OffsetJitter {
            offset: Duration::from_millis(5),
            jitter: Duration::ZERO,
        };
        let cfg = SmtlConfig {
            iters: 5,
            delay: DelayModel::PerNode {
                per_node: vec![
                    Box::new(slow),
                    Box::new(fast.clone()),
                    Box::new(fast.clone()),
                    Box::new(fast),
                ],
            },
            ..Default::default()
        };
        let r = run_smtl(&p, p.build_computes(Engine::Native, None).unwrap(), &cfg).unwrap();
        // 5 rounds × ≥40ms straggler = ≥200ms.
        assert!(
            r.wall_time >= Duration::from_millis(190),
            "wall {:?}",
            r.wall_time
        );
    }
}
