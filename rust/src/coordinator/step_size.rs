//! Step-size schedules for the KM relaxation (η_k) and the dynamic
//! delay-compensating multiplier of §III.D.

use crate::net::NodeDelays;
use std::sync::Mutex;

/// The η_k schedule of Theorem 1: a constant inside
/// `[η_min, c/(2τ/√T + 1)]`, where `τ` is the (expected) maximum delay in
/// update counts and `T` the number of tasks.
#[derive(Clone, Copy, Debug)]
pub struct KmSchedule {
    /// The relaxation step η_k.
    pub eta_k: f64,
}

impl KmSchedule {
    /// Pick η_k at the Theorem-1 upper bound with safety factor `c`.
    pub fn from_bound(c: f64, tau_updates: f64, t: usize, eta_min: f64) -> KmSchedule {
        let hi = crate::optim::lipschitz::km_step_bound(c, tau_updates, t);
        KmSchedule { eta_k: hi.max(eta_min) }
    }

    /// A fixed η_k (the paper's tables use 0.5/0.9-style constants).
    pub fn fixed(eta_k: f64) -> KmSchedule {
        KmSchedule { eta_k }
    }
}

/// Dynamic step-size controller (Eq. III.5/III.6):
/// `c_{t,k} = log(max(ν̄_{t,k}, 10))` where `ν̄_{t,k}` is the mean of the
/// last `window` delays of task node `t` (the paper uses the last 5),
/// measured in the paper's delay unit.
///
/// With no dynamic scaling the multiplier is 1.
pub struct StepController {
    schedule: KmSchedule,
    dynamic: bool,
    window: usize,
    delays: Mutex<NodeDelays>,
}

impl StepController {
    /// A controller over `t_count` nodes (`dynamic` enables Eq. III.6).
    pub fn new(schedule: KmSchedule, dynamic: bool, t_count: usize, window: usize) -> StepController {
        StepController {
            schedule,
            dynamic,
            window,
            delays: Mutex::new(NodeDelays::new(t_count, window)),
        }
    }

    /// The delay-history window length (the paper uses 5).
    pub fn window(&self) -> usize {
        self.window
    }

    /// True when the Eq. III.6 multiplier is active.
    pub fn is_dynamic(&self) -> bool {
        self.dynamic
    }

    /// Record an observed communication delay for node `t` (paper units).
    pub fn record_delay(&self, t: usize, delay_units: f64) {
        self.delays.lock().unwrap().record(t, delay_units);
    }

    /// The Eq. III.6 multiplier for node `t` (1.0 when dynamic is off).
    pub fn multiplier(&self, t: usize) -> f64 {
        if !self.dynamic {
            return 1.0;
        }
        let nu_bar = self.delays.lock().unwrap().recent_mean(t);
        nu_bar.max(10.0).ln()
    }

    /// The effective step `c_{t,k} · η_k` used in the KM update.
    pub fn step(&self, t: usize) -> f64 {
        self.multiplier(t) * self.schedule.eta_k
    }

    /// The base relaxation step η_k.
    pub fn eta_k(&self) -> f64 {
        self.schedule.eta_k
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn static_controller_multiplier_is_one() {
        let c = StepController::new(KmSchedule::fixed(0.5), false, 3, 5);
        c.record_delay(0, 100.0);
        assert_eq!(c.multiplier(0), 1.0);
        assert_eq!(c.step(0), 0.5);
    }

    #[test]
    fn dynamic_multiplier_is_log_of_clamped_mean() {
        let c = StepController::new(KmSchedule::fixed(0.1), true, 2, 5);
        // No history → mean 0 → max(0,10)=10 → ln(10).
        assert!((c.multiplier(0) - 10f64.ln()).abs() < 1e-12);
        // Mean 20 → ln 20.
        for _ in 0..5 {
            c.record_delay(0, 20.0);
        }
        assert!((c.multiplier(0) - 20f64.ln()).abs() < 1e-12);
        // Node 1 unaffected.
        assert!((c.multiplier(1) - 10f64.ln()).abs() < 1e-12);
    }

    #[test]
    fn dynamic_window_uses_recent_only() {
        let c = StepController::new(KmSchedule::fixed(1.0), true, 1, 2);
        c.record_delay(0, 1000.0);
        c.record_delay(0, 30.0);
        c.record_delay(0, 30.0); // window 2 → mean 30
        assert!((c.multiplier(0) - 30f64.ln()).abs() < 1e-12);
    }

    #[test]
    fn longer_delays_give_larger_steps() {
        // The paper's motivation: nodes that waited longer take bigger steps.
        let c = StepController::new(KmSchedule::fixed(0.2), true, 2, 5);
        c.record_delay(0, 5.0); // clamps to 10
        c.record_delay(1, 30.0);
        assert!(c.step(1) > c.step(0));
    }

    #[test]
    fn from_bound_respects_eta_min() {
        let s = KmSchedule::from_bound(0.9, 1e9, 4, 1e-3);
        assert!((s.eta_k - 1e-3).abs() < 1e-15, "floor at eta_min");
        let s2 = KmSchedule::from_bound(0.9, 0.0, 4, 1e-3);
        assert!((s2.eta_k - 0.9).abs() < 1e-12);
    }
}
