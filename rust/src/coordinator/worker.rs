//! A task node: the worker side of Algorithm 1.
//!
//! Each worker owns one task's [`TaskCompute`] (its private data never
//! leaves the node — only model vectors cross the transport, matching the
//! paper's privacy argument) and repeatedly:
//!
//! 1. waits out its (simulated or real) network delay,
//! 2. retrieves its block of the server's backward step `(Prox(V̂))_t`
//!    through its [`Transport`],
//! 3. computes the forward step `u = ŵ − η ∇ℓ_t(ŵ)` (PJRT artifact or
//!    native mirror),
//! 4. commits the KM relaxation `v_t ← v_t + c_{t,k} η_k (u − v_t)`
//!    through the same transport.
//!
//! The worker never touches the central server directly: whether the
//! transport is shared memory ([`crate::transport::InProc`]) or a TCP
//! connection to another process ([`crate::transport::TcpClient`]) is
//! invisible here.

use super::metrics::Recorder;
use super::schedule::StalenessGate;
use super::state::SharedState;
use super::step_size::StepController;
use crate::net::{DelayModel, FaultModel, FaultOutcome};
use crate::obs::fleet::{self, Hop};
use crate::obs::{self, Histogram, TraceWriter};
use crate::runtime::TaskCompute;
use crate::transport::wire::MetricsReport;
use crate::transport::Transport;
use crate::util::json::Json;
use crate::util::Rng;
use anyhow::Result;
use std::sync::{Arc, OnceLock};
use std::time::{Duration, Instant};

/// The worker side's histogram handles (`node.*`, all µs), resolved once
/// per process so the activation loop records lock-free.
struct NodeObs {
    delay_us: Arc<Histogram>,
    fetch_us: Arc<Histogram>,
    step_us: Arc<Histogram>,
    commit_us: Arc<Histogram>,
}

fn node_obs() -> &'static NodeObs {
    static OBS: OnceLock<NodeObs> = OnceLock::new();
    OBS.get_or_init(|| {
        let g = obs::global();
        NodeObs {
            delay_us: g.hist("node.delay_us"),
            fetch_us: g.hist("node.fetch_us"),
            step_us: g.hist("node.step_us"),
            commit_us: g.hist("node.commit_us"),
        }
    })
}

/// Trajectory sampling wiring: the run's recorder plus the locally-held
/// model state it snapshots. Present when the state is co-located with the
/// worker (in-proc and loopback-TCP sessions); `None` on a remote task
/// node, where the serving process samples instead (see
/// [`crate::transport::TcpServer::spawn`]).
pub struct TrajectorySink {
    /// The run's trajectory recorder.
    pub recorder: Arc<Recorder>,
    /// The locally-held shared state it snapshots.
    pub state: Arc<SharedState>,
}

impl TrajectorySink {
    fn record(&self, version: u64) {
        self.recorder.maybe_record(version, || self.state.snapshot());
    }
}

/// Everything one free-running worker thread needs.
pub struct WorkerCtx {
    /// This node's task index.
    pub t: usize,
    /// Activation budget.
    pub iters: usize,
    /// The node's channel to the central server (fetch + commit + η).
    pub transport: Box<dyn Transport>,
    /// KM step-size controller (shared across nodes).
    pub controller: Arc<StepController>,
    /// Injected network-delay model.
    pub delay: DelayModel,
    /// Fault injection (robustness experiments; default none).
    pub faults: FaultModel,
    /// When set, forward steps use importance-corrected Bernoulli
    /// minibatches of this fraction (the paper's future-work SGD variant).
    pub sgd_fraction: Option<f64>,
    /// Wall-clock duration of one paper delay-unit (the paper's
    /// "seconds" are scaled; benches use 10 ms per paper-second).
    pub time_scale: Duration,
    /// Trajectory sampling (`None` on remote task nodes).
    pub sink: Option<TrajectorySink>,
    /// This node's deterministic RNG stream.
    pub rng: Rng,
    /// Bounded-staleness gate (the `SemiSync` schedule); `None` = fully
    /// asynchronous.
    pub gate: Option<Arc<StalenessGate>>,
    /// Heartbeat interval for elastic membership: long delay sleeps and
    /// gate waits are chunked to this stride so the node keeps proving
    /// liveness (and learns it was evicted, re-registering). `None` =
    /// membership disabled.
    pub heartbeat: Option<Duration>,
    /// Resume a restarted node: skip the activations the server already
    /// has applied for this column (reported by `Register`) instead of
    /// redoing them.
    pub resume: bool,
    /// When set, every activation appends one JSONL trace event carrying
    /// its delay/fetch/compute timing split (`--trace-out`).
    pub trace: Option<Arc<TraceWriter>>,
    /// When set, the worker pushes its process registry to the server on
    /// this stride (`PushMetrics`, role `NODE`) plus once on exit, so the
    /// trainer's `MetricsReport` fans in every worker. Set by the
    /// `--node` CLI (a separate OS process with its own registry); `None`
    /// for in-process workers, which share the trainer's registry and
    /// would only duplicate it.
    pub metrics_stride: Option<Duration>,
}

/// Per-worker outcome.
#[derive(Clone, Debug, Default)]
pub struct WorkerStats {
    /// Updates successfully committed.
    pub updates: u64,
    /// Activations whose update was lost in transit (fault injection).
    pub dropped: u64,
    /// True if this node crashed before exhausting its budget.
    pub crashed: bool,
    /// Sum of injected delays (wall-clock seconds).
    pub total_delay_secs: f64,
    /// Wall-clock spent in the forward step (gradient compute).
    pub compute_secs: f64,
    /// Wall-clock spent waiting on the server's backward step (over TCP
    /// this includes the real network round-trip).
    pub backward_wait_secs: f64,
    /// Wall-clock spent committing updates (the KM push round-trip; over
    /// TCP this includes the WAL fsync the server performs before acking).
    pub commit_wait_secs: f64,
    /// Objective values of `ℓ_t` observed at each forward step (free —
    /// the fused kernels return them).
    pub last_task_loss: f64,
    /// Activations spent inside a silent crash/restart window
    /// (`FaultModel::CrashRestart`): the node was down, nothing ran.
    pub offline: u64,
}

/// Deactivates a node's staleness-gate slot on drop — including a panic
/// unwind out of the worker loop, where a skipped deactivation would hang
/// every peer at the gate forever.
struct GateGuard {
    gate: Arc<StalenessGate>,
    t: usize,
}

impl Drop for GateGuard {
    fn drop(&mut self) {
        self.gate.deactivate(self.t);
    }
}

/// The free-running worker loop. Runs `iters` activations, waiting on no
/// other node (unless a staleness gate bounds how far ahead it may run).
pub fn run_worker(mut ctx: WorkerCtx, compute: &mut dyn TaskCompute) -> Result<WorkerStats> {
    // Whatever the exit path (budget exhausted, crash, compute error, or
    // a panic unwinding out of the loop), leave the staleness minimum so
    // no peer blocks on a dead node.
    let gate_guard = ctx.gate.clone().map(|gate| GateGuard { gate, t: ctx.t });
    let result = worker_loop(&mut ctx, compute);
    // Unblock peers first, then depart membership and tear the transport
    // down politely (both best-effort — a vanished server is not an
    // error on the way out).
    drop(gate_guard);
    let _ = ctx.transport.leave(ctx.t);
    let _ = ctx.transport.close();
    result
}

/// What one activation produced (the "receive → compute" phase shared by
/// every schedule; the caller decides how to commit the update).
pub(crate) enum Activation {
    /// The node died on this activation (fault injection).
    Crashed,
    /// The compute ran but the update was lost in transit.
    Dropped,
    /// The node is inside a silent-down window: nothing ran at all.
    Offline,
    /// A forward-step update ready to commit. `fetch_start_us` is the
    /// wall-clock stamp of the activation's backward fetch — the start
    /// of the commit's critical path, which ends at the server's ack.
    Update {
        /// The forward-step result to commit.
        u: Vec<f64>,
        /// Wall-clock µs when the backward fetch began.
        fetch_start_us: u64,
    },
}

/// One activation of task node `ctx.t`: fault check, simulated network
/// delay (recorded in paper units for the dynamic step controller,
/// Eq. III.6), backward-step fetch via `fetch_w` (handed the node's
/// transport), and the forward step (minibatch or full batch). Shared by
/// the free-running worker loop and the synchronized round loop so the
/// per-activation protocol cannot drift between schedules.
pub(crate) fn run_activation(
    ctx: &mut WorkerCtx,
    compute: &mut dyn TaskCompute,
    k: u64,
    fetch_w: impl FnOnce(&mut dyn Transport) -> Result<Vec<f64>>,
    stats: &mut WorkerStats,
) -> Result<Activation> {
    // 0. Fault check for this activation.
    let outcome = ctx.faults.outcome(ctx.t, k, &mut ctx.rng);
    if outcome == FaultOutcome::Crashed {
        return Ok(Activation::Crashed);
    }
    if outcome == FaultOutcome::Offline {
        stats.offline += 1;
        return Ok(Activation::Offline);
    }

    // 1. Simulated network delay for this activation (heartbeating
    //    through long waits so the node is not spuriously evicted).
    let sample = ctx.delay.sample(ctx.t, &mut ctx.rng);
    if sample.duration > Duration::ZERO {
        sleep_heartbeating(ctx, sample.duration);
    }
    stats.total_delay_secs += sample.duration.as_secs_f64();
    let delay_us = sample.duration.as_micros() as u64;
    node_obs().delay_us.record(delay_us);
    let units = sample.duration.as_secs_f64() / ctx.time_scale.as_secs_f64().max(1e-12);
    ctx.controller.record_delay(ctx.t, units);

    // 2. Backward step block (server prox column over the transport).
    let fetch_start_us = fleet::unix_us();
    let t0 = Instant::now();
    let w_hat = fetch_w(ctx.transport.as_mut())?;
    let fetch_us = t0.elapsed().as_micros() as u64;
    stats.backward_wait_secs += t0.elapsed().as_secs_f64();
    node_obs().fetch_us.record(fetch_us);
    fleet::record_hop(
        ctx.trace.as_deref(),
        Hop::NodeFetch,
        ctx.t,
        k,
        fetch_start_us,
        fetch_start_us + fetch_us,
    );

    // 3. Forward step on the task's private data.
    let eta = ctx.transport.eta();
    let step_start_us = fleet::unix_us();
    let t1 = Instant::now();
    let (u, task_loss) = match ctx.sgd_fraction {
        Some(frac) => compute.step_minibatch(&w_hat, eta, frac, &mut ctx.rng)?,
        None => compute.step(&w_hat, eta)?,
    };
    let step_us = t1.elapsed().as_micros() as u64;
    stats.compute_secs += t1.elapsed().as_secs_f64();
    node_obs().step_us.record(step_us);
    fleet::record_hop(
        ctx.trace.as_deref(),
        Hop::NodeStep,
        ctx.t,
        k,
        step_start_us,
        step_start_us + step_us,
    );
    stats.last_task_loss = task_loss;
    if let Some(tr) = &ctx.trace {
        tr.event(
            "activation",
            Some(ctx.t),
            Some(k),
            None,
            &[
                ("delay_us", Json::Num(delay_us as f64)),
                ("fetch_us", Json::Num(fetch_us as f64)),
                ("step_us", Json::Num(step_us as f64)),
            ],
        );
    }

    // 3b. Lost in transit? The compute happened but the server never
    // sees it (the paper's failure mode; the next activation retries).
    if outcome == FaultOutcome::Dropped {
        stats.dropped += 1;
        return Ok(Activation::Dropped);
    }
    Ok(Activation::Update { u, fetch_start_us })
}

/// Sleep `total`, chunked to the heartbeat interval so a long injected
/// delay keeps proving liveness; a node that learns it was evicted
/// rejoins by re-registering.
fn sleep_heartbeating(ctx: &mut WorkerCtx, total: Duration) {
    let Some(interval) = ctx.heartbeat else {
        std::thread::sleep(total);
        return;
    };
    let mut remaining = total;
    loop {
        let nap = remaining.min(interval);
        std::thread::sleep(nap);
        remaining = remaining.saturating_sub(nap);
        if remaining.is_zero() {
            return;
        }
        if let Ok(false) = ctx.transport.heartbeat(ctx.t) {
            let _ = ctx.transport.register(ctx.t);
        }
    }
}

/// Push this process's registry to the server as a role-`NODE` report
/// (best-effort: metrics export must never take the worker down).
fn push_node_metrics(ctx: &mut WorkerCtx) {
    let report = MetricsReport::from_snapshot(
        MetricsReport::ROLE_NODE,
        obs::log::uptime_ms(),
        obs::global().snapshot(),
    );
    let _ = ctx.transport.push_metrics(ctx.t, report);
}

fn worker_loop(ctx: &mut WorkerCtx, compute: &mut dyn TaskCompute) -> Result<WorkerStats> {
    let mut stats = WorkerStats::default();
    // Join the run. Without a registry this is a cheap ack that still
    // reports the column's applied-commit horizon — which is exactly
    // where a restarted node resumes when `resume` is set.
    let ack = ctx.transport.register(ctx.t)?;
    let start = if ctx.resume { ack.col_version.min(ctx.iters as u64) as usize } else { 0 };
    let mut was_offline = false;
    let mut last_metrics = Instant::now();
    for k in start..ctx.iters {
        // Silent-down window (crash/restart fault): the node is simply
        // not there — no gate interaction, no heartbeat, no compute.
        // Wall-clock passes so timeout eviction can observe the silence.
        if ctx.faults.offline_at(ctx.t, k as u64) {
            stats.offline += 1;
            std::thread::sleep(ctx.heartbeat.unwrap_or(ctx.time_scale));
            was_offline = true;
            continue;
        }
        if was_offline {
            // Back from the dead: rejoin membership (the server very
            // likely evicted us during the silence).
            was_offline = false;
            let _ = ctx.transport.register(ctx.t);
        }

        // Bounded staleness: wait until activation `k` is allowed —
        // heartbeating while parked, so a slow-but-alive federation
        // never reads as dead (and so *somebody* keeps sweeping the
        // registry while everyone waits on a silent straggler).
        if let Some(g) = ctx.gate.clone() {
            match ctx.heartbeat {
                Some(interval) => {
                    let t = ctx.t;
                    let transport = ctx.transport.as_mut();
                    g.wait_to_start_ticking(k as u64, interval, || {
                        if let Ok(false) = transport.heartbeat(t) {
                            let _ = transport.register(t);
                        }
                    });
                }
                None => g.wait_to_start(k as u64),
            }
        }

        let t = ctx.t;
        match run_activation(ctx, compute, k as u64, |tr| tr.fetch_prox_col(t), &mut stats)? {
            Activation::Crashed => {
                stats.crashed = true;
                break;
            }
            Activation::Dropped | Activation::Offline => {}
            Activation::Update { u, fetch_start_us } => {
                // KM relaxation on this task block, committed through the
                // transport (shared memory or the wire). `k` is the dedup
                // key that makes transport resends exactly-once.
                let step = ctx.controller.step(ctx.t);
                let commit_start_us = fleet::unix_us();
                let t2 = Instant::now();
                let version = ctx.transport.push_update(ctx.t, k as u64, step, &u)?;
                let commit_us = t2.elapsed().as_micros() as u64;
                stats.commit_wait_secs += t2.elapsed().as_secs_f64();
                node_obs().commit_us.record(commit_us);
                let ack_us = commit_start_us + commit_us;
                fleet::record_hop(
                    ctx.trace.as_deref(),
                    Hop::WireCommit,
                    ctx.t,
                    k as u64,
                    commit_start_us,
                    ack_us,
                );
                fleet::record_critical_path(ack_us.saturating_sub(fetch_start_us));
                stats.updates += 1;
                if let Some(sink) = &ctx.sink {
                    sink.record(version);
                }
            }
        }
        if let Some(g) = &ctx.gate {
            g.finish_iter(ctx.t);
        }
        if let Some(stride) = ctx.metrics_stride {
            if last_metrics.elapsed() >= stride {
                push_node_metrics(ctx);
                last_metrics = Instant::now();
            }
        }
    }
    // One final snapshot on the way out, so even a run shorter than the
    // stride leaves a NODE row behind on the trainer.
    if ctx.metrics_stride.is_some() {
        push_node_metrics(ctx);
    }
    Ok(stats)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::server::CentralServer;
    use crate::coordinator::state::SharedState;
    use crate::coordinator::step_size::KmSchedule;
    use crate::data::synthetic;
    use crate::optim::prox::RegularizerKind;
    use crate::runtime::NativeTaskCompute;
    use crate::transport::InProc;

    fn setup(seed: u64) -> (Arc<CentralServer>, NativeTaskCompute, crate::coordinator::problem::MtlProblem) {
        let mut rng = Rng::new(seed);
        let ds = synthetic::lowrank_regression(&[30; 3], 6, 2, 0.05, &mut rng);
        let problem = crate::coordinator::problem::MtlProblem::new(
            ds,
            RegularizerKind::Nuclear,
            0.1,
            0.5,
            &mut rng,
        );
        let state = Arc::new(SharedState::zeros(problem.d(), problem.t()));
        let server = Arc::new(CentralServer::new(
            state,
            problem.regularizer(),
            problem.eta,
        ));
        let compute = NativeTaskCompute::new(&problem.dataset.tasks[0]);
        (server, compute, problem)
    }

    fn sink(server: &Arc<CentralServer>, every: u64) -> Option<TrajectorySink> {
        Some(TrajectorySink {
            recorder: Arc::new(Recorder::new(every)),
            state: Arc::clone(server.state()),
        })
    }

    #[test]
    fn worker_applies_expected_update_count() {
        let (server, mut compute, _p) = setup(120);
        let ctx = WorkerCtx {
            t: 0,
            iters: 7,
            transport: Box::new(InProc::new(Arc::clone(&server))),
            controller: Arc::new(StepController::new(KmSchedule::fixed(0.5), false, 3, 5)),
            delay: DelayModel::None,
            faults: FaultModel::None,
            sgd_fraction: None,
            time_scale: Duration::from_millis(100),
            sink: sink(&server, 1),
            rng: Rng::new(121),
            gate: None,
            heartbeat: None,
            resume: false,
            trace: None,
            metrics_stride: None,
        };
        let stats = run_worker(ctx, &mut compute).unwrap();
        assert_eq!(stats.updates, 7);
        assert_eq!(server.state().col_version(0), 7);
        assert_eq!(server.state().col_version(1), 0, "other blocks untouched");
    }

    #[test]
    fn worker_progress_decreases_task_loss() {
        let (server, mut compute, _p) = setup(122);
        let w0 = server.prox_col(0);
        let loss_before = compute.obj(&w0).unwrap();
        let ctx = WorkerCtx {
            t: 0,
            iters: 100,
            transport: Box::new(InProc::new(Arc::clone(&server))),
            controller: Arc::new(StepController::new(KmSchedule::fixed(0.9), false, 3, 5)),
            delay: DelayModel::None,
            faults: FaultModel::None,
            sgd_fraction: None,
            time_scale: Duration::from_millis(100),
            sink: sink(&server, 1000),
            rng: Rng::new(123),
            gate: None,
            heartbeat: None,
            resume: false,
            trace: None,
            metrics_stride: None,
        };
        run_worker(ctx, &mut compute).unwrap();
        let w1 = server.prox_col(0);
        let loss_after = compute.obj(&w1).unwrap();
        assert!(
            loss_after < loss_before * 0.5,
            "loss {loss_before} -> {loss_after}"
        );
    }

    #[test]
    fn worker_records_delays_in_paper_units() {
        let (server, mut compute, _p) = setup(124);
        let controller = Arc::new(StepController::new(KmSchedule::fixed(0.5), true, 3, 5));
        let ctx = WorkerCtx {
            t: 0,
            iters: 3,
            transport: Box::new(InProc::new(Arc::clone(&server))),
            controller: Arc::clone(&controller),
            // 20 ms delay at a 10 ms time-scale = 2.0 paper units (< 10 → clamped).
            delay: DelayModel::OffsetJitter {
                offset: Duration::from_millis(20),
                jitter: Duration::ZERO,
            },
            faults: FaultModel::None,
            sgd_fraction: None,
            time_scale: Duration::from_millis(10),
            sink: sink(&server, 1000),
            rng: Rng::new(125),
            gate: None,
            heartbeat: None,
            resume: false,
            trace: None,
            metrics_stride: None,
        };
        let stats = run_worker(ctx, &mut compute).unwrap();
        assert!((stats.total_delay_secs - 0.06).abs() < 0.02);
        // ν̄ = 2.0 → multiplier ln(max(2,10)) = ln 10.
        assert!((controller.multiplier(0) - 10f64.ln()).abs() < 1e-9);
    }

    #[test]
    fn worker_over_tcp_matches_inproc_bitwise() {
        // Same seeds, same budget: the transport must be invisible to the
        // math. One task ⇒ no interleaving ⇒ exact agreement.
        let run = |tcp: bool| {
            let (server, mut compute, _p) = setup(126);
            let handle = if tcp {
                Some(crate::transport::TcpServer::spawn("127.0.0.1:0", Arc::clone(&server), None).unwrap())
            } else {
                None
            };
            let transport: Box<dyn Transport> = match &handle {
                Some(h) => Box::new(
                    crate::transport::TcpClient::connect(h.addr(), Default::default()).unwrap(),
                ),
                None => Box::new(InProc::new(Arc::clone(&server))),
            };
            let ctx = WorkerCtx {
                t: 0,
                iters: 12,
                transport,
                controller: Arc::new(StepController::new(KmSchedule::fixed(0.7), false, 3, 5)),
                delay: DelayModel::None,
                faults: FaultModel::None,
                sgd_fraction: None,
                time_scale: Duration::from_millis(100),
                sink: None,
                rng: Rng::new(127),
                gate: None,
                heartbeat: None,
                resume: false,
                trace: None,
                metrics_stride: None,
            };
            let stats = run_worker(ctx, &mut compute).unwrap();
            assert_eq!(stats.updates, 12);
            server.state().read_col(0)
        };
        let inproc = run(false);
        let tcp = run(true);
        assert_eq!(inproc, tcp, "TCP transport must be bit-identical");
    }
}
