//! A task node: the worker side of Algorithm 1.
//!
//! Each worker owns one task's [`TaskCompute`] (its private data never
//! leaves the node — only model vectors cross the channel, matching the
//! paper's privacy argument) and repeatedly:
//!
//! 1. waits out its simulated network delay,
//! 2. retrieves its block of the server's backward step `(Prox(V̂))_t`,
//! 3. computes the forward step `u = ŵ − η ∇ℓ_t(ŵ)` (PJRT artifact or
//!    native mirror),
//! 4. applies the KM relaxation `v_t ← v_t + c_{t,k} η_k (u − v_t)`.

use super::server::CentralServer;
use super::step_size::StepController;
use crate::coordinator::metrics::Recorder;
use crate::net::{DelayModel, FaultModel, FaultOutcome};
use crate::runtime::TaskCompute;
use crate::util::Rng;
use anyhow::Result;
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Everything one AMTL worker thread needs.
pub struct WorkerCtx {
    pub t: usize,
    pub iters: usize,
    pub server: Arc<CentralServer>,
    pub controller: Arc<StepController>,
    pub delay: DelayModel,
    /// Fault injection (robustness experiments; default none).
    pub faults: FaultModel,
    /// When set, forward steps use importance-corrected Bernoulli
    /// minibatches of this fraction (the paper's future-work SGD variant).
    pub sgd_fraction: Option<f64>,
    /// Wall-clock duration of one paper delay-unit (see DESIGN.md
    /// §Substitutions: the paper's "seconds" are scaled).
    pub time_scale: Duration,
    pub recorder: Arc<Recorder>,
    pub rng: Rng,
}

/// Per-worker outcome.
#[derive(Clone, Debug, Default)]
pub struct WorkerStats {
    pub updates: u64,
    /// Activations whose update was lost in transit (fault injection).
    pub dropped: u64,
    /// True if this node crashed before exhausting its budget.
    pub crashed: bool,
    /// Sum of injected delays (wall-clock seconds).
    pub total_delay_secs: f64,
    /// Wall-clock spent in the forward step (gradient compute).
    pub compute_secs: f64,
    /// Wall-clock spent waiting on the server's backward step.
    pub backward_wait_secs: f64,
    /// Objective values of `ℓ_t` observed at each forward step (free —
    /// the fused kernels return them).
    pub last_task_loss: f64,
}

/// The asynchronous worker loop. Runs `iters` activations, never waiting
/// for any other node.
pub fn run_worker(mut ctx: WorkerCtx, compute: &mut dyn TaskCompute) -> Result<WorkerStats> {
    let mut stats = WorkerStats::default();
    for k in 0..ctx.iters {
        // 0. Fault check for this activation.
        let outcome = ctx.faults.outcome(ctx.t, k as u64, &mut ctx.rng);
        if outcome == FaultOutcome::Crashed {
            stats.crashed = true;
            break;
        }

        // 1. Simulated network delay for this activation.
        let sample = ctx.delay.sample(ctx.t, &mut ctx.rng);
        if sample.duration > Duration::ZERO {
            std::thread::sleep(sample.duration);
        }
        stats.total_delay_secs += sample.duration.as_secs_f64();
        // Record in paper units for the dynamic step controller (Eq. III.6).
        let units = sample.duration.as_secs_f64() / ctx.time_scale.as_secs_f64().max(1e-12);
        ctx.controller.record_delay(ctx.t, units);

        // 2. Backward step block (inconsistent read of V is inside).
        let t0 = Instant::now();
        let w_hat = ctx.server.prox_col(ctx.t);
        stats.backward_wait_secs += t0.elapsed().as_secs_f64();

        // 3. Forward step on the task's private data.
        let t1 = Instant::now();
        let (u, task_loss) = match ctx.sgd_fraction {
            Some(frac) => {
                compute.step_minibatch(&w_hat, ctx.server.eta(), frac, &mut ctx.rng)?
            }
            None => compute.step(&w_hat, ctx.server.eta())?,
        };
        stats.compute_secs += t1.elapsed().as_secs_f64();
        stats.last_task_loss = task_loss;

        // 3b. Lost in transit? The compute happened but the server never
        // sees it (the paper's failure mode; the next activation retries).
        if outcome == FaultOutcome::Dropped {
            stats.dropped += 1;
            continue;
        }

        // 4. KM relaxation on this task block.
        let step = ctx.controller.step(ctx.t);
        let version = ctx.server.state().km_update(ctx.t, &u, step);
        // Keep the (optional) online-SVD factorization in sync.
        let new_col = ctx.server.state().read_col(ctx.t);
        ctx.server.notify_column_update(ctx.t, &new_col);

        stats.updates += 1;
        ctx.recorder
            .maybe_record(version, || ctx.server.state().snapshot());
    }
    Ok(stats)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::state::SharedState;
    use crate::coordinator::step_size::KmSchedule;
    use crate::data::synthetic;
    use crate::optim::prox::RegularizerKind;
    use crate::runtime::NativeTaskCompute;

    fn setup(seed: u64) -> (Arc<CentralServer>, NativeTaskCompute, crate::coordinator::problem::MtlProblem) {
        let mut rng = Rng::new(seed);
        let ds = synthetic::lowrank_regression(&[30; 3], 6, 2, 0.05, &mut rng);
        let problem = crate::coordinator::problem::MtlProblem::new(
            ds,
            RegularizerKind::Nuclear,
            0.1,
            0.5,
            &mut rng,
        );
        let state = Arc::new(SharedState::zeros(problem.d(), problem.t()));
        let server = Arc::new(CentralServer::new(
            state,
            problem.regularizer(),
            problem.eta,
        ));
        let compute = NativeTaskCompute::new(&problem.dataset.tasks[0]);
        (server, compute, problem)
    }

    #[test]
    fn worker_applies_expected_update_count() {
        let (server, mut compute, _p) = setup(120);
        let ctx = WorkerCtx {
            t: 0,
            iters: 7,
            server: Arc::clone(&server),
            controller: Arc::new(StepController::new(KmSchedule::fixed(0.5), false, 3, 5)),
            delay: DelayModel::None,
            faults: FaultModel::None,
            sgd_fraction: None,
            time_scale: Duration::from_millis(100),
            recorder: Arc::new(Recorder::new(1)),
            rng: Rng::new(121),
        };
        let stats = run_worker(ctx, &mut compute).unwrap();
        assert_eq!(stats.updates, 7);
        assert_eq!(server.state().col_version(0), 7);
        assert_eq!(server.state().col_version(1), 0, "other blocks untouched");
    }

    #[test]
    fn worker_progress_decreases_task_loss() {
        let (server, mut compute, _p) = setup(122);
        let w0 = server.prox_col(0);
        let loss_before = compute.obj(&w0).unwrap();
        let ctx = WorkerCtx {
            t: 0,
            iters: 100,
            server: Arc::clone(&server),
            controller: Arc::new(StepController::new(KmSchedule::fixed(0.9), false, 3, 5)),
            delay: DelayModel::None,
            faults: FaultModel::None,
            sgd_fraction: None,
            time_scale: Duration::from_millis(100),
            recorder: Arc::new(Recorder::new(1000)),
            rng: Rng::new(123),
        };
        run_worker(ctx, &mut compute).unwrap();
        let w1 = server.prox_col(0);
        let loss_after = compute.obj(&w1).unwrap();
        assert!(
            loss_after < loss_before * 0.5,
            "loss {loss_before} -> {loss_after}"
        );
    }

    #[test]
    fn worker_records_delays_in_paper_units() {
        let (server, mut compute, _p) = setup(124);
        let controller = Arc::new(StepController::new(KmSchedule::fixed(0.5), true, 3, 5));
        let ctx = WorkerCtx {
            t: 0,
            iters: 3,
            server,
            controller: Arc::clone(&controller),
            // 20 ms delay at a 10 ms time-scale = 2.0 paper units (< 10 → clamped).
            delay: DelayModel::OffsetJitter {
                offset: Duration::from_millis(20),
                jitter: Duration::ZERO,
            },
            faults: FaultModel::None,
            sgd_fraction: None,
            time_scale: Duration::from_millis(10),
            recorder: Arc::new(Recorder::new(1000)),
            rng: Rng::new(125),
        };
        let stats = run_worker(ctx, &mut compute).unwrap();
        assert!((stats.total_delay_secs - 0.06).abs() < 0.02);
        // ν̄ = 2.0 → multiplier ln(max(2,10)) = ln 10.
        assert!((controller.multiplier(0) - 10f64.ln()).abs() < 1e-9);
    }
}
