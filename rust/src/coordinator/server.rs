//! The central server: owns the regularizer and performs the backward
//! (proximal) step over snapshots of the shared state.
//!
//! Per Algorithm 1, an activated task node "requests the server for the
//! forward step computation `Prox_{ηλg}(v̂)` and retrieves
//! `(Prox_{ηλg}(v̂))_t`". The server therefore:
//!
//! 1. takes an (inconsistent) snapshot of `V`,
//! 2. applies `Prox_{ηλg}` — SVT via the native Jacobi SVD for the nuclear
//!    norm, row shrinkage for ℓ2,1, … (see [`crate::optim::prox`]),
//! 3. hands the requesting node its column.
//!
//! A version-keyed cache collapses repeated proxes of an unchanged `V`
//! (the paper: "the proximal mapping can be also applied after several
//! gradient updates depending on the speed of gradient update"). The
//! `prox_every` knob generalizes this: with `prox_every = k`, a cached
//! prox is reused until `k` new block updates have landed.
//!
//! ## Hot-path sharding
//!
//! With many TCP task nodes committing concurrently, the commit path must
//! not funnel through any server-wide lock. [`CentralServer::commit_update`]
//! touches only per-column state: the column's KM lock inside
//! [`SharedState`], then the column's *pending slot*. The slot holds the
//! latest committed value of that column, not yet folded into the online
//! SVD; the fold happens lazily at the next prox, under the regularizer
//! lock that the prox needs anyway. Because a rank-1 *column replacement*
//! is idempotent in the latest value, adjacent commits from the same task
//! coalesce into one fold — the server does O(distinct-columns) incremental
//! work per prox no matter how fast any single node spins (the
//! [`CentralServer::coalesced_count`] counter measures the savings).
//! Fetches hit the prox cache through a read lock; only an actual
//! recompute takes the write side, behind a double-checked serialization
//! gate (one server, one prox at a time — as in the paper).

use super::registry::NodeRegistry;
use super::state::SharedState;
use crate::linalg::Mat;
use crate::obs::fleet::{self, Hop};
use crate::obs::{self, Histogram, TraceWriter};
use crate::optim::formulation::{self, SharedProx};
use crate::persist::{Checkpointer, FormulationState, ServerSnapshot, WalEntry};
use crate::transport::wire::MetricsReport;
use crate::util::json::Json;
use crate::util::RngState;
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, RwLock};
use std::time::Instant;

/// The server's handles into the process-wide metrics registry, resolved
/// once at construction so the commit/prox hot paths record lock-free.
struct ServerObs {
    /// `server.commits` — updates applied (excludes dedup'd resends).
    commits: Arc<AtomicU64>,
    /// `server.coalesced` — pending-slot overwrites the online SVD skipped.
    coalesced: Arc<AtomicU64>,
    /// `server.version` gauge — the global KM version after the last commit.
    version: Arc<AtomicU64>,
    /// `server.staleness` — process-wide twin of the session-local histogram.
    staleness: Arc<Histogram>,
    /// `server.prox_us.<reg-id>` — wall time per uncached backward step.
    prox_us: Arc<Histogram>,
    /// `server.registrations` — node joins/rejoins (generation bumps).
    registrations: Arc<AtomicU64>,
}

impl ServerObs {
    fn resolve(reg_id: &str) -> ServerObs {
        let g = obs::global();
        ServerObs {
            commits: g.counter("server.commits"),
            coalesced: g.counter("server.coalesced"),
            version: g.gauge("server.version"),
            staleness: g.hist("server.staleness"),
            prox_us: g.hist(&format!("server.prox_us.{reg_id}")),
            registrations: g.counter("server.registrations"),
        }
    }
}

/// The central node: regularizer owner and backward-step executor.
pub struct CentralServer {
    state: Arc<SharedState>,
    /// The coupling formulation, behind the open
    /// [`SharedProx`] API — any registered regularizer plugs in here.
    reg: Mutex<Box<dyn SharedProx>>,
    /// True iff `reg` runs an incremental prox (fixed at construction;
    /// lets the commit path skip the pending slots — and any shared state
    /// beyond the column — when the fold would be a no-op).
    online: bool,
    /// Prox step size `η` (the same η as the forward step, Eq. III.4).
    eta: f64,
    /// Global task index of this server's column 0. Zero for a whole-model
    /// server; a prox shard sets it to its range start so trace events and
    /// cross-process span hops carry **global** task indices (and join the
    /// committing worker's span, which is keyed by global `t`) even though
    /// the server itself works in local columns.
    node_base: usize,
    /// Reuse the cached prox until this many new updates have landed.
    prox_every: u64,
    /// Version-keyed prox cache: read-locked on the (frequent) hit path,
    /// write-locked only to install a fresh result.
    cache: RwLock<Option<(u64, Arc<Mat>)>>,
    /// Serializes prox *computation* (the cache lock is no longer held
    /// while the SVD runs, so fetches of the cached matrix never wait
    /// behind a recompute they don't need).
    prox_gate: Mutex<()>,
    prox_count: AtomicU64,
    /// Same-column commits that overwrote a not-yet-folded pending slot
    /// (each one is an online-SVD rank-1 update the server never ran).
    coalesced: AtomicU64,
    /// Raw commits not yet handed to the regularizer's refresh-stride
    /// counter (drained — with the pending slots — at prox time). Counted
    /// per commit so the `resvd_every` drift bound holds even when
    /// coalescing collapses several commits into one fold.
    uncounted_commits: AtomicU64,
    /// Per-column staging for the online SVD: the latest committed column
    /// value awaiting its fold into the factorization.
    pending: Vec<Mutex<Option<Vec<f64>>>>,
    /// Per-column: the activation counter of the value currently staged
    /// in `pending` — what lets the prox-time fold attribute its work back
    /// to the originating commit's span. Observability-only, never
    /// persisted (a recovered staging slot re-tags from its next commit).
    staged_k: Vec<AtomicU64>,
    /// Per-column commit dedup keys: 0 = no commit applied yet, else the
    /// highest applied activation counter plus one. A resent `PushUpdate`
    /// (the TCP client's at-least-once retry, or a node replaying after a
    /// server restart) is acknowledged without re-applying — commits are
    /// exactly-once end to end.
    applied_k: Vec<AtomicU64>,
    /// When set, every commit and uncached prox is written ahead to the
    /// WAL and snapshots rotate on the configured stride.
    persist: Option<Arc<Checkpointer>>,
    /// WAL entries replayed into this server by recovery (0 on a fresh
    /// start); reported through `RunResult`.
    wal_replayed: AtomicU64,
    /// Elastic-membership liveness table, when heartbeats are enabled.
    registry: Option<Arc<NodeRegistry>>,
    /// When set (ℓ2,1 only), the backward step runs through the
    /// `prox_l21` Pallas artifact instead of the native mirror — the whole
    /// data path is then AOT-compiled kernels (see `runtime::prox_compute`).
    pjrt_prox: Option<crate::runtime::PjrtL21Prox>,
    /// Per-column: the global version `V` was at when column `t` was last
    /// fetched (`prox_col`). Diffed against the apply-time version to
    /// measure each commit's staleness τ — the quantity the paper's
    /// convergence bound is parameterized by.
    fetch_version: Vec<AtomicU64>,
    /// Session-local staleness histogram (in versions, not time). Kept
    /// separate from the process-global `server.staleness` twin so one
    /// run's summary (`RunResult`) is not polluted by a parallel run in
    /// the same process (e.g. `cargo test`).
    staleness: Arc<Histogram>,
    /// Optional JSONL trace sink for commit/prox events.
    trace: Option<Arc<TraceWriter>>,
    /// Registry handles for the hot paths, resolved at construction.
    obs: ServerObs,
    /// The latest metrics snapshot each remote worker pushed
    /// (`PushMetrics`), keyed by task index. Fanned into the `nodes` rows
    /// of the trainer's own `MetricsReport`; entries persist after a
    /// worker leaves so short-lived nodes still show up in `amtl top`.
    node_metrics: Mutex<BTreeMap<u32, MetricsReport>>,
}

impl CentralServer {
    /// A server over `state` applying `reg` with prox step `eta`.
    pub fn new(state: Arc<SharedState>, reg: Box<dyn SharedProx>, eta: f64) -> CentralServer {
        let online = reg.is_incremental();
        let obs = ServerObs::resolve(reg.id());
        let pending = (0..state.t()).map(|_| Mutex::new(None)).collect();
        let staged_k = (0..state.t()).map(|_| AtomicU64::new(0)).collect();
        let applied_k = (0..state.t()).map(|_| AtomicU64::new(0)).collect();
        let fetch_version = (0..state.t()).map(|_| AtomicU64::new(0)).collect();
        CentralServer {
            state,
            reg: Mutex::new(reg),
            online,
            eta,
            node_base: 0,
            prox_every: 1,
            cache: RwLock::new(None),
            prox_gate: Mutex::new(()),
            prox_count: AtomicU64::new(0),
            coalesced: AtomicU64::new(0),
            uncounted_commits: AtomicU64::new(0),
            pending,
            staged_k,
            applied_k,
            persist: None,
            wal_replayed: AtomicU64::new(0),
            registry: None,
            pjrt_prox: None,
            fetch_version,
            staleness: Arc::new(Histogram::new()),
            trace: None,
            obs,
            node_metrics: Mutex::new(BTreeMap::new()),
        }
    }

    /// Set the prox reuse window (default 1 = re-prox after every update).
    pub fn with_prox_every(mut self, k: u64) -> CentralServer {
        self.prox_every = k.max(1);
        self
    }

    /// Declare this server a prox shard whose column 0 is global task
    /// `base`: trace events and span hops report `base + t` so a fleet of
    /// shards shows up in one coherent task space (`amtl top --fleet`,
    /// trace span joins).
    pub fn with_node_base(mut self, base: usize) -> CentralServer {
        self.node_base = base;
        self
    }

    /// Attach durability: every commit is written ahead to `cp`'s WAL and
    /// snapshots rotate on its stride. Writes the genesis snapshot (the
    /// server's current state) so the directory is recoverable from the
    /// first moment.
    pub fn with_checkpointer(
        mut self,
        cp: Arc<Checkpointer>,
    ) -> anyhow::Result<CentralServer> {
        self.persist = Some(Arc::clone(&cp));
        cp.checkpoint_now(&self)?;
        Ok(self)
    }

    /// Attach an elastic-membership registry (`Register`/`Heartbeat`/
    /// `Leave` traffic lands in it; both transports reach it through
    /// [`CentralServer::registry`]).
    pub fn with_registry(mut self, registry: Arc<NodeRegistry>) -> CentralServer {
        self.registry = Some(registry);
        self
    }

    /// Attach a JSONL trace sink: every applied commit and every uncached
    /// prox emits one event (`docs/OBSERVABILITY.md` has the schema).
    pub fn with_trace(mut self, trace: Arc<TraceWriter>) -> CentralServer {
        if let Some(cp) = &self.persist {
            cp.set_trace(Arc::clone(&trace));
        }
        self.trace = Some(trace);
        self
    }

    /// The attached membership registry, if heartbeats are enabled.
    pub fn registry(&self) -> Option<&Arc<NodeRegistry>> {
        self.registry.as_ref()
    }

    /// Park the latest metrics snapshot pushed by remote worker `t`
    /// (`PushMetrics`). Sub-reports are exactly one level deep, so any
    /// `nodes` rows a confused client attached are dropped here.
    pub fn note_node_metrics(&self, t: u32, mut report: MetricsReport) {
        report.nodes.clear();
        self.node_metrics.lock().unwrap().insert(t, report);
    }

    /// The per-node rows for the trainer's `FetchMetrics` reply: the last
    /// snapshot each remote worker pushed, keyed by task index.
    pub fn node_metrics_rows(&self) -> Vec<(u32, MetricsReport)> {
        self.node_metrics.lock().unwrap().iter().map(|(t, r)| (*t, r.clone())).collect()
    }

    /// The attached checkpointer, if durability is enabled.
    pub fn checkpointer(&self) -> Option<&Arc<Checkpointer>> {
        self.persist.as_ref()
    }

    /// Snapshots written for this server so far (0 without durability).
    pub fn checkpoints_written(&self) -> u64 {
        self.persist.as_ref().map(|cp| cp.checkpoints_written()).unwrap_or(0)
    }

    /// WAL entries replayed into this server by recovery.
    pub fn wal_replayed(&self) -> u64 {
        self.wal_replayed.load(Ordering::Relaxed)
    }

    pub(crate) fn note_wal_replayed(&self, n: u64) {
        self.wal_replayed.store(n, Ordering::Relaxed);
    }

    /// fsync in-flight WAL writes (the `Shutdown` handler acknowledges
    /// only after this returns). No-op without durability.
    pub fn sync_persist(&self) -> anyhow::Result<()> {
        match &self.persist {
            Some(cp) => cp.sync(),
            None => Ok(()),
        }
    }

    /// Route the ℓ2,1 backward step through the `prox_l21` PJRT artifact.
    /// Errors if the regularizer is not ℓ2,1 or no bucket covers `(d, T)`.
    pub fn with_pjrt_l21_prox(
        mut self,
        pool: &crate::runtime::ComputePool,
    ) -> anyhow::Result<CentralServer> {
        anyhow::ensure!(
            self.reg.lock().unwrap().id() == "l21",
            "PJRT prox is only available for the l21 regularizer"
        );
        let prox = crate::runtime::PjrtL21Prox::new(pool, self.state.d(), self.state.t())?;
        self.pjrt_prox = Some(prox);
        Ok(self)
    }

    /// The shared auxiliary state `V` this server proxes over.
    pub fn state(&self) -> &Arc<SharedState> {
        &self.state
    }

    /// The prox step size η.
    pub fn eta(&self) -> f64 {
        self.eta
    }

    /// Registry id of the coupling formulation this server applies.
    pub fn reg_id(&self) -> &'static str {
        self.reg.lock().unwrap().id()
    }

    /// Strength λ of the coupling formulation this server applies.
    pub fn reg_lambda(&self) -> f64 {
        self.reg.lock().unwrap().lambda()
    }

    /// Number of proximal mappings actually computed (not cache hits).
    pub fn prox_count(&self) -> u64 {
        self.prox_count.load(Ordering::Relaxed)
    }

    /// Same-task commits that were coalesced before the online SVD ever
    /// saw them (0 on the exact path, where there is nothing to fold).
    pub fn coalesced_count(&self) -> u64 {
        self.coalesced.load(Ordering::Relaxed)
    }

    /// Exact refreshes the incremental formulation state has gone through.
    pub fn svd_refresh_count(&self) -> u64 {
        self.reg.lock().unwrap().refresh_count()
    }

    /// Drift measured at the last exact refresh.
    pub fn svd_drift(&self) -> f64 {
        self.reg.lock().unwrap().refresh_drift()
    }

    /// A snapshot of this server's commit-staleness histogram (in
    /// versions): for each applied commit, the gap between the global
    /// version its fetch saw and the version it applied at. Session-local
    /// — unaffected by other servers in the same process.
    pub fn staleness_snapshot(&self) -> crate::obs::HistSnapshot {
        self.staleness.snapshot()
    }

    /// The full backward step `Prox_{ηλg}(V̂)` over a fresh-enough snapshot.
    pub fn prox_matrix(&self) -> Arc<Mat> {
        let version = self.state.version();
        if let Some((v, m)) = self.cache.read().unwrap().as_ref() {
            if version < v + self.prox_every {
                return Arc::clone(m);
            }
        }
        // Recompute, one prox at a time (the paper has one central node);
        // concurrent fetchers that raced here park on the gate, then
        // re-check the cache — usually the winner's result serves them.
        let _gate = self.prox_gate.lock().unwrap();
        let version = self.state.version();
        if let Some((v, m)) = self.cache.read().unwrap().as_ref() {
            if version < v + self.prox_every {
                return Arc::clone(m);
            }
        }
        let m = Arc::new(self.compute_prox());
        *self.cache.write().unwrap() = Some((version, Arc::clone(&m)));
        m
    }

    /// One uncached backward step, logged to the WAL when durability is
    /// on: the *fold order* the log preserves is what lets recovery
    /// rebuild the online factorization bitwise.
    fn compute_prox(&self) -> Mat {
        // Quiesce gate read side: a snapshot never lands between the fold
        // and its log entry. Acquired before the regularizer lock —
        // the same order the snapshot writer uses.
        let _quiesce = self.persist.as_ref().map(|cp| cp.commit_gate());
        if let Some(cp) = &self.persist {
            // Logged before the fold (WAL discipline). An append failure
            // degrades durability of THIS fold's ordering, but must not
            // poison the fetch path serving live workers.
            let _ = cp.log_prox();
        }
        self.prox_fold_and_compute()
    }

    /// Fold staged column commits into the online factorization (if
    /// any), re-anchor it on an exact Jacobi SVD when the raw-commit
    /// counter says the stride is due, then apply the prox. On the
    /// incremental path no full-matrix snapshot is taken at all (the
    /// factorization *is* the operand) — the server only pays the T
    /// column-lock sweep when refreshing or running an exact prox.
    /// Shared by the live fetch path and WAL replay.
    fn prox_fold_and_compute(&self) -> Mat {
        let started = Instant::now();
        let mut reg = self.reg.lock().unwrap();
        self.drain_pending(&mut **reg);
        if reg.needs_refresh() {
            // Snapshot after the counter drain (in drain_pending): commits
            // that land in between are already inside the snapshot the
            // incremental state is rebuilt from, so no commit ever escapes
            // the stride accounting.
            reg.refresh(&self.state.snapshot());
        }
        let out = if let Some(m) = reg.online_prox(self.eta) {
            m
        } else {
            let mut snap = self.state.snapshot();
            if let Some(pjrt) = &self.pjrt_prox {
                let tau = self.eta * reg.lambda();
                // Artifact failures fall back to the native mirror
                // (identical math) rather than poisoning the run.
                if pjrt.apply(&mut snap, tau).is_err() {
                    reg.prox(&mut snap, self.eta);
                }
            } else {
                reg.prox(&mut snap, self.eta);
            }
            snap
        };
        self.prox_count.fetch_add(1, Ordering::Relaxed);
        self.obs.prox_us.record(started.elapsed().as_micros() as u64);
        if let Some(tr) = &self.trace {
            tr.event("prox", None, None, Some(self.state.version()), &[]);
        }
        out
    }

    /// Fold every staged column into the incremental formulation state and
    /// hand the raw-commit count to the regularizer's refresh-stride
    /// counter. Called with the regularizer lock held; a no-op on the
    /// exact path.
    fn drain_pending(&self, reg: &mut dyn SharedProx) {
        if !self.online {
            return;
        }
        for (t, slot) in self.pending.iter().enumerate() {
            let staged = slot.lock().unwrap().take();
            if let Some(col) = staged {
                // Coalescing means this fold may stand in for several
                // commits; the span it joins is the *latest* staged one —
                // the value actually being folded.
                let k = self.staged_k[t].load(Ordering::Relaxed);
                let fold_start_us = fleet::unix_us();
                reg.notify_column_update(t, &col);
                fleet::record_hop(
                    self.trace.as_deref(),
                    Hop::ProxFold,
                    self.node_base + t,
                    k,
                    fold_start_us,
                    fleet::unix_us(),
                );
            }
        }
        // `swap` (not load+store) so increments racing with the drain are
        // kept for the next one instead of silently dropped.
        reg.note_commits(self.uncounted_commits.swap(0, Ordering::AcqRel));
    }

    /// `(Prox_{ηλg}(V̂))_t` — what an activated task node retrieves.
    /// Remembers the version the fetch saw, so the column's next commit
    /// can report its staleness.
    pub fn prox_col(&self, t: usize) -> Vec<f64> {
        self.fetch_version[t].store(self.state.version(), Ordering::Relaxed);
        self.prox_matrix().col(t).to_vec()
    }

    /// Tell the server a column changed (drives the online-SVD path).
    /// Stages the value in the column's pending slot; the fold into the
    /// factorization happens at the next prox, so adjacent updates of the
    /// same column coalesce into one rank-1 replacement.
    pub fn notify_column_update(&self, t: usize, col: &[f64]) {
        if !self.online {
            return;
        }
        let mut slot = self.pending[t].lock().unwrap();
        if slot.replace(col.to_vec()).is_some() {
            self.coalesced.fetch_add(1, Ordering::Relaxed);
            self.obs.coalesced.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Commit one forward-step result: the KM relaxation
    /// `v_t ← v_t + step·(u − v_t)` on block `t`, plus the online-SVD
    /// staging. This is the single server-side commit path — both the
    /// in-proc and the TCP [`Transport`](crate::transport::Transport)
    /// implementations land updates through it, so the commit protocol
    /// cannot drift between the two. Touches only block-`t` state: commits
    /// from different tasks never contend.
    ///
    /// `k` is the committing node's activation counter: an activation
    /// already applied (a transport resend, or a node replaying after a
    /// server restart) is acknowledged without re-applying, making the
    /// at-least-once wire retry exactly-once. With durability attached,
    /// the commit is WAL-appended and fsync'd *before* it is applied
    /// (write-ahead discipline), so an acknowledged update survives
    /// SIGKILL; the error case is a failed append, which leaves state
    /// untouched.
    ///
    /// Returns the new global version (total KM updates).
    pub fn commit_update(&self, t: usize, k: u64, u: &[f64], step: f64) -> anyhow::Result<u64> {
        if k.saturating_add(1) <= self.applied_k[t].load(Ordering::Acquire) {
            // Duplicate of an applied activation: acknowledge, don't apply.
            return Ok(self.state.version());
        }
        let version = match &self.persist {
            None => {
                let stage_start_us = fleet::unix_us();
                let version = self.apply_commit(t, k, u, step);
                fleet::record_hop(
                    self.trace.as_deref(),
                    Hop::Staging,
                    self.node_base + t,
                    k,
                    stage_start_us,
                    fleet::unix_us(),
                );
                version
            }
            Some(cp) => {
                let version = {
                    let _quiesce = cp.commit_gate();
                    let wal_start_us = fleet::unix_us();
                    cp.log_commit(t, k, step, u)?;
                    fleet::record_hop(
                        self.trace.as_deref(),
                        Hop::Wal,
                        self.node_base + t,
                        k,
                        wal_start_us,
                        fleet::unix_us(),
                    );
                    let stage_start_us = fleet::unix_us();
                    let version = self.apply_commit(t, k, u, step);
                    fleet::record_hop(
                        self.trace.as_deref(),
                        Hop::Staging,
                        self.node_base + t,
                        k,
                        stage_start_us,
                        fleet::unix_us(),
                    );
                    version
                };
                // The commit is applied and WAL-durable at this point; a
                // failed snapshot *rotation* must not fail acknowledged
                // work. Warn and keep serving — the WAL keeps growing and
                // the rotation retries on the next commit.
                if let Err(e) = cp.maybe_snapshot(self) {
                    crate::log_warn!(
                        "server",
                        "checkpoint rotation failed ({e:#}); \
                         continuing on the write-ahead log"
                    );
                }
                version
            }
        };
        self.note_commit(t, k, version);
        Ok(version)
    }

    /// Observability for one *live* applied commit (WAL replay bypasses
    /// this — replayed commits have no fetch to be stale against): the
    /// staleness measurement, counters, and the trace event.
    fn note_commit(&self, t: usize, k: u64, version: u64) {
        // Staleness τ: KM updates that landed globally between this
        // column's fetch and this commit's apply. `version` already
        // counts this commit itself, hence the −1.
        let fetched = self.fetch_version[t].load(Ordering::Relaxed);
        let staleness = version.saturating_sub(1).saturating_sub(fetched);
        self.staleness.record(staleness);
        self.obs.staleness.record(staleness);
        self.obs.commits.fetch_add(1, Ordering::Relaxed);
        self.obs.version.store(version, Ordering::Relaxed);
        if let Some(tr) = &self.trace {
            tr.event(
                "commit",
                Some(self.node_base + t),
                Some(k),
                Some(version),
                &[("staleness", Json::Num(staleness as f64))],
            );
        }
    }

    /// Apply one commit to in-memory state (no logging, no dedup): the KM
    /// relaxation, the dedup-key advance, and the online-SVD staging.
    /// Shared by the live commit path and WAL replay.
    fn apply_commit(&self, t: usize, k: u64, u: &[f64], step: f64) -> u64 {
        let version = self.state.km_update(t, u, step);
        self.applied_k[t].fetch_max(k.saturating_add(1), Ordering::AcqRel);
        if self.online {
            let new_col = self.state.read_col(t);
            self.notify_column_update(t, &new_col);
            self.staged_k[t].store(k, Ordering::Relaxed);
            // Raw-commit count for the refresh stride: coalescing may fold
            // several of these into one factorization update, but the
            // drift bound is promised per *commit*.
            self.uncounted_commits.fetch_add(1, Ordering::AcqRel);
        }
        version
    }

    /// Commits already applied for column `t` (the dedup horizon a
    /// re-registering node catches up from).
    pub fn applied_commits(&self, t: usize) -> u64 {
        self.applied_k[t].load(Ordering::Acquire)
    }

    /// Join (or rejoin) the run as task node `t`: bump its membership
    /// generation in the registry (when one is attached) and report the
    /// column's applied-commit horizon so a restarted node resumes instead
    /// of redoing finished activations. This is the single registration
    /// path — both the in-proc and the TCP transport land here — and it
    /// emits a `"register"` trace event (with the generation and the
    /// catch-up horizon), which is what lets the chaos invariant checker
    /// balance every eviction against a later re-registration.
    pub fn register_node(&self, t: usize) -> crate::transport::RegisterAck {
        let generation = self.registry.as_ref().map(|r| r.register(t)).unwrap_or(0);
        let col_version = self.applied_commits(t);
        self.obs.registrations.fetch_add(1, Ordering::Relaxed);
        if let Some(tr) = &self.trace {
            tr.event(
                "register",
                Some(self.node_base + t),
                None,
                None,
                &[
                    ("generation", Json::Num(generation as f64)),
                    ("col_version", Json::Num(col_version as f64)),
                ],
            );
        }
        crate::transport::RegisterAck { col_version, generation }
    }

    /// Re-apply one WAL entry during recovery (no re-logging — the entry
    /// is already durable).
    pub(crate) fn replay_entry(&self, entry: &WalEntry) {
        match entry {
            WalEntry::Commit { t, k, step, u, .. } => {
                self.apply_commit(*t as usize, *k, u, *step);
            }
            WalEntry::Prox { .. } => {
                let _ = self.prox_fold_and_compute();
            }
        }
    }

    /// `λ·g(W)` for objective reporting.
    pub fn reg_value(&self, w: &Mat) -> f64 {
        self.reg.lock().unwrap().value(w)
    }

    /// Capture the server's complete state at WAL horizon `seq`. Called
    /// by the checkpointer with the quiesce gate's write side held, so no
    /// commit or prox is mid-flight: the capture is consistent with
    /// exactly the operations logged so far.
    pub(crate) fn capture_snapshot(
        &self,
        seq: u64,
        rng_streams: Vec<(u64, RngState)>,
    ) -> ServerSnapshot {
        let reg = self.reg.lock().unwrap();
        let pending: Vec<Option<Vec<f64>>> =
            self.pending.iter().map(|slot| slot.lock().unwrap().clone()).collect();
        ServerSnapshot {
            seq,
            eta: self.eta,
            prox_every: self.prox_every,
            version: self.state.version(),
            col_versions: (0..self.state.t()).map(|t| self.state.col_version(t)).collect(),
            applied_k: self.applied_k.iter().map(|a| a.load(Ordering::Acquire)).collect(),
            v: self.state.snapshot(),
            pending,
            prox_count: self.prox_count.load(Ordering::Relaxed),
            coalesced: self.coalesced.load(Ordering::Relaxed),
            uncounted_commits: self.uncounted_commits.load(Ordering::Acquire),
            reg: FormulationState { id: reg.id().to_string(), blob: reg.state_save() },
            rng_streams,
        }
    }

    /// Rebuild a server from a snapshot: shared state (values *and*
    /// version counters), the formulation restored by id through the
    /// registry (incremental state and refresh-stride counter included,
    /// so the drift bound continues instead of resetting), pending slots,
    /// dedup keys, and metrics counters. The result has no
    /// checkpointer/registry attached and no PJRT prox (re-attach what
    /// the deployment needs). Errors when the snapshot names a
    /// formulation this build does not register.
    pub fn from_snapshot(snap: &ServerSnapshot) -> anyhow::Result<CentralServer> {
        let state = Arc::new(SharedState::restore(&snap.v, &snap.col_versions, snap.version));
        let reg = formulation::restore(&snap.reg.id, &snap.reg.blob)?;
        let online = reg.is_incremental();
        let obs = ServerObs::resolve(reg.id());
        let fetch_version = (0..snap.col_versions.len()).map(|_| AtomicU64::new(0)).collect();
        Ok(CentralServer {
            state,
            reg: Mutex::new(reg),
            online,
            eta: snap.eta,
            node_base: 0,
            prox_every: snap.prox_every,
            cache: RwLock::new(None),
            prox_gate: Mutex::new(()),
            prox_count: AtomicU64::new(snap.prox_count),
            coalesced: AtomicU64::new(snap.coalesced),
            uncounted_commits: AtomicU64::new(snap.uncounted_commits),
            pending: snap.pending.iter().cloned().map(Mutex::new).collect(),
            staged_k: snap.pending.iter().map(|_| AtomicU64::new(0)).collect(),
            applied_k: snap.applied_k.iter().map(|&k| AtomicU64::new(k)).collect(),
            persist: None,
            wal_replayed: AtomicU64::new(0),
            registry: None,
            pjrt_prox: None,
            fetch_version,
            staleness: Arc::new(Histogram::new()),
            trace: None,
            obs,
            node_metrics: Mutex::new(BTreeMap::new()),
        })
    }

    /// The final primal iterate `W* = Prox_{ηλg}(V*)` (one extra backward
    /// step maps the auxiliary variable back — §III.C).
    pub fn final_w(&self) -> Mat {
        let mut reg = self.reg.lock().unwrap();
        self.drain_pending(&mut **reg);
        if let Some(m) = reg.online_prox(self.eta) {
            return m;
        }
        let mut snap = self.state.snapshot();
        reg.prox(&mut snap, self.eta);
        snap
    }

    /// The serving iterate `W = Prox_{ηλg}(V)` computed **without mutating
    /// any replay state** — the read-replica analogue of
    /// [`CentralServer::final_w`].
    ///
    /// `final_w` drains the pending slots into the live formulation. That
    /// is exactly right at the end of a run, but would corrupt a replica
    /// mid-tail: the WAL's `Prox` markers dictate *when* staged columns
    /// fold into the online factorization, and an early drain diverges
    /// the fold history from the trainer's. This method instead folds
    /// *clones* of the staged columns into a *clone* of the formulation,
    /// leaving the server bitwise-identical to before the call. At any
    /// quiesced point it equals `final_w()` over the same state.
    pub fn serving_w(&self) -> Mat {
        let mut reg = self.reg.lock().unwrap().clone_box();
        if self.online {
            for (t, slot) in self.pending.iter().enumerate() {
                let staged = slot.lock().unwrap().clone();
                if let Some(col) = staged {
                    reg.notify_column_update(t, &col);
                }
            }
        }
        if let Some(m) = reg.online_prox(self.eta) {
            return m;
        }
        let mut snap = self.state.snapshot();
        reg.prox(&mut snap, self.eta);
        snap
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optim::prox::{NuclearProx, Regularizer, RegularizerKind};
    use crate::util::Rng;

    fn server_with(kind: RegularizerKind, lambda: f64, eta: f64, d: usize, t: usize) -> CentralServer {
        let state = Arc::new(SharedState::zeros(d, t));
        CentralServer::new(state, Regularizer::new(kind, lambda), eta)
    }

    #[test]
    fn prox_col_matches_manual_prox() {
        let mut rng = Rng::new(100);
        let m = Mat::randn(6, 3, &mut rng);
        let state = Arc::new(SharedState::new(&m));
        let srv = CentralServer::new(state, Regularizer::new(RegularizerKind::L21, 0.5), 0.2);
        let mut want = m.clone();
        Regularizer::new(RegularizerKind::L21, 0.5).prox(&mut want, 0.2);
        for t in 0..3 {
            assert_eq!(srv.prox_col(t), want.col(t));
        }
    }

    #[test]
    fn cache_hits_until_update() {
        let srv = server_with(RegularizerKind::L21, 0.1, 0.1, 4, 2);
        let _ = srv.prox_matrix();
        let _ = srv.prox_matrix();
        let _ = srv.prox_col(0);
        assert_eq!(srv.prox_count(), 1, "unchanged V must not re-prox");
        srv.state().km_update(0, &[1.0, 0.0, 0.0, 0.0], 1.0);
        let _ = srv.prox_matrix();
        assert_eq!(srv.prox_count(), 2);
    }

    #[test]
    fn prox_every_widens_reuse() {
        let srv = server_with(RegularizerKind::L21, 0.1, 0.1, 2, 2).with_prox_every(3);
        let _ = srv.prox_matrix();
        srv.state().km_update(0, &[1.0, 0.0], 1.0);
        srv.state().km_update(1, &[1.0, 0.0], 1.0);
        let _ = srv.prox_matrix(); // only 2 updates landed: cache hit
        assert_eq!(srv.prox_count(), 1);
        srv.state().km_update(0, &[2.0, 0.0], 1.0);
        let _ = srv.prox_matrix(); // 3 updates: recompute
        assert_eq!(srv.prox_count(), 2);
    }

    #[test]
    fn nuclear_server_thresholds_spectrum() {
        let mut rng = Rng::new(101);
        let m = Mat::randn(8, 4, &mut rng);
        let state = Arc::new(SharedState::new(&m));
        let lambda = 0.7;
        let eta = 0.3;
        let srv = CentralServer::new(state, Regularizer::new(RegularizerKind::Nuclear, lambda), eta);
        let got = srv.prox_matrix();
        let before = crate::optim::svd::Svd::jacobi(&m);
        let after = crate::optim::svd::Svd::jacobi(&got);
        for (a, b) in after.sigma.iter().zip(&before.sigma) {
            assert!((a - (b - eta * lambda).max(0.0)).abs() < 1e-8);
        }
    }

    #[test]
    fn final_w_is_prox_of_current_v() {
        let mut rng = Rng::new(102);
        let m = Mat::randn(5, 3, &mut rng);
        let state = Arc::new(SharedState::new(&m));
        let srv = CentralServer::new(state, Regularizer::new(RegularizerKind::L1, 0.4), 0.5);
        let mut want = m.clone();
        Regularizer::new(RegularizerKind::L1, 0.4).prox(&mut want, 0.5);
        assert!(srv.final_w().max_abs_diff(&want) < 1e-12);
    }

    #[test]
    fn pending_commits_coalesce_per_column() {
        let mut rng = Rng::new(103);
        let m = Mat::randn(6, 3, &mut rng);
        let state = Arc::new(SharedState::new(&m));
        let reg = Box::new(NuclearProx::new(0.3).with_online(&m));
        let srv = CentralServer::new(state, reg, 0.2);
        // Three commits to one block before any prox: two coalesce away.
        for k in 0..3 {
            let u = rng.normal_vec(6);
            srv.commit_update(0, k, &u, 0.5).unwrap();
        }
        assert_eq!(srv.coalesced_count(), 2);
        // The prox still matches the exact backward step of the current V.
        let got = srv.prox_matrix();
        let mut want = srv.state().snapshot();
        Regularizer::new(RegularizerKind::Nuclear, 0.3).prox(&mut want, 0.2);
        assert!(got.max_abs_diff(&want) < 1e-7, "{}", got.max_abs_diff(&want));
    }

    #[test]
    fn online_server_tracks_exact_server_with_refresh() {
        let mut rng = Rng::new(104);
        let m = Mat::randn(8, 4, &mut rng);
        let exact = CentralServer::new(
            Arc::new(SharedState::new(&m)),
            Regularizer::new(RegularizerKind::Nuclear, 0.4),
            0.25,
        );
        let online = CentralServer::new(
            Arc::new(SharedState::new(&m)),
            Box::new(NuclearProx::new(0.4).with_online(&m).with_resvd_every(3)),
            0.25,
        );
        for step in 0..12 {
            let t = step % 4;
            let k = (step / 4) as u64;
            let u = rng.normal_vec(8);
            exact.commit_update(t, k, &u, 0.6).unwrap();
            online.commit_update(t, k, &u, 0.6).unwrap();
            let a = exact.prox_matrix();
            let b = online.prox_matrix();
            assert!(
                a.max_abs_diff(&b) < 1e-7,
                "step {step}: online prox diverged {}",
                a.max_abs_diff(&b)
            );
        }
        assert!(online.svd_refresh_count() >= 3, "refresh stride 3 over 12 commits");
        assert!(online.svd_drift() < 1e-8, "drift {}", online.svd_drift());
        assert!(
            exact.final_w().max_abs_diff(&online.final_w()) < 1e-7,
            "final iterates must agree"
        );
    }

    #[test]
    fn serving_w_matches_final_w_without_draining() {
        let mut rng = Rng::new(105);
        let m = Mat::randn(7, 3, &mut rng);
        let reg = Box::new(NuclearProx::new(0.3).with_online(&m));
        let srv = CentralServer::new(Arc::new(SharedState::new(&m)), reg, 0.2);
        for k in 0..2 {
            for t in 0..3 {
                let u = rng.normal_vec(7);
                srv.commit_update(t, k, &u, 0.5).unwrap();
            }
        }
        // Two reads in a row are bitwise-identical: nothing inside moved.
        let a = srv.serving_w();
        let b = srv.serving_w();
        assert_eq!(a.max_abs_diff(&b), 0.0, "serving_w must not mutate");
        // And both equal the draining read over the same state.
        assert_eq!(a.max_abs_diff(&srv.final_w()), 0.0);
    }

    #[test]
    fn duplicate_commits_are_acknowledged_not_reapplied() {
        let srv = server_with(RegularizerKind::L21, 0.1, 0.1, 3, 2);
        let v1 = srv.commit_update(0, 0, &[1.0, 0.0, 0.0], 0.5).unwrap();
        assert_eq!(v1, 1);
        let col_after = srv.state().read_col(0);
        // A resend of activation 0 must not move the state.
        let v2 = srv.commit_update(0, 0, &[9.0, 9.0, 9.0], 0.5).unwrap();
        assert_eq!(v2, 1, "duplicate acks the current version");
        assert_eq!(srv.state().read_col(0), col_after);
        assert_eq!(srv.applied_commits(0), 1);
        // The next activation applies normally.
        assert_eq!(srv.commit_update(0, 1, &[1.0, 1.0, 1.0], 1.0).unwrap(), 2);
        // Dedup is per column: the same counter on another column applies.
        assert_eq!(srv.commit_update(1, 0, &[2.0, 2.0, 2.0], 1.0).unwrap(), 3);
    }

    #[test]
    fn concurrent_prox_requests_are_safe() {
        let srv = Arc::new(server_with(RegularizerKind::Nuclear, 0.2, 0.1, 10, 6));
        let mut handles = Vec::new();
        for t in 0..6 {
            let srv = Arc::clone(&srv);
            handles.push(std::thread::spawn(move || {
                let mut rng = Rng::new(200 + t as u64);
                for _ in 0..50 {
                    let col = srv.prox_col(t);
                    assert_eq!(col.len(), 10);
                    let u = rng.normal_vec(10);
                    srv.state().km_update(t, &u, 0.5);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(srv.state().version(), 300);
        assert!(srv.prox_count() <= 301, "prox per update at most");
    }
}
