//! The central server: owns the regularizer and performs the backward
//! (proximal) step over snapshots of the shared state.
//!
//! Per Algorithm 1, an activated task node "requests the server for the
//! forward step computation `Prox_{ηλg}(v̂)` and retrieves
//! `(Prox_{ηλg}(v̂))_t`". The server therefore:
//!
//! 1. takes an (inconsistent) snapshot of `V`,
//! 2. applies `Prox_{ηλg}` — SVT via the native Jacobi SVD for the nuclear
//!    norm, row shrinkage for ℓ2,1, … (see [`crate::optim::prox`]),
//! 3. hands the requesting node its column.
//!
//! A version-keyed cache collapses repeated proxes of an unchanged `V`
//! (the paper: "the proximal mapping can be also applied after several
//! gradient updates depending on the speed of gradient update"). The
//! `prox_every` knob generalizes this: with `prox_every = k`, a cached
//! prox is reused until `k` new block updates have landed.

use super::state::SharedState;
use crate::linalg::Mat;
use crate::optim::prox::Regularizer;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

pub struct CentralServer {
    state: Arc<SharedState>,
    reg: Mutex<Regularizer>,
    /// Prox step size `η` (the same η as the forward step, Eq. III.4).
    eta: f64,
    /// Reuse the cached prox until this many new updates have landed.
    prox_every: u64,
    cache: Mutex<Option<(u64, Arc<Mat>)>>,
    prox_count: AtomicU64,
    /// When set (ℓ2,1 only), the backward step runs through the
    /// `prox_l21` Pallas artifact instead of the native mirror — the whole
    /// data path is then AOT-compiled kernels (see `runtime::prox_compute`).
    pjrt_prox: Option<crate::runtime::PjrtL21Prox>,
}

impl CentralServer {
    pub fn new(state: Arc<SharedState>, reg: Regularizer, eta: f64) -> CentralServer {
        CentralServer {
            state,
            reg: Mutex::new(reg),
            eta,
            prox_every: 1,
            cache: Mutex::new(None),
            prox_count: AtomicU64::new(0),
            pjrt_prox: None,
        }
    }

    /// Set the prox reuse window (default 1 = re-prox after every update).
    pub fn with_prox_every(mut self, k: u64) -> CentralServer {
        self.prox_every = k.max(1);
        self
    }

    /// Route the ℓ2,1 backward step through the `prox_l21` PJRT artifact.
    /// Errors if the regularizer is not ℓ2,1 or no bucket covers `(d, T)`.
    pub fn with_pjrt_l21_prox(
        mut self,
        pool: &crate::runtime::ComputePool,
    ) -> anyhow::Result<CentralServer> {
        anyhow::ensure!(
            self.reg.lock().unwrap().kind == crate::optim::prox::RegularizerKind::L21,
            "PJRT prox is only available for the l21 regularizer"
        );
        let prox = crate::runtime::PjrtL21Prox::new(pool, self.state.d(), self.state.t())?;
        self.pjrt_prox = Some(prox);
        Ok(self)
    }

    pub fn state(&self) -> &Arc<SharedState> {
        &self.state
    }

    pub fn eta(&self) -> f64 {
        self.eta
    }

    /// Number of proximal mappings actually computed (not cache hits).
    pub fn prox_count(&self) -> u64 {
        self.prox_count.load(Ordering::Relaxed)
    }

    /// The full backward step `Prox_{ηλg}(V̂)` over a fresh-enough snapshot.
    pub fn prox_matrix(&self) -> Arc<Mat> {
        let version = self.state.version();
        let mut cache = self.cache.lock().unwrap();
        if let Some((v, m)) = cache.as_ref() {
            if version < v + self.prox_every {
                return Arc::clone(m);
            }
        }
        // Compute a fresh prox. The cache lock is held during the prox:
        // the central node applies proximal mappings one at a time (as in
        // the paper — there is one server).
        let mut snap = self.state.snapshot();
        if let Some(pjrt) = &self.pjrt_prox {
            let tau = self.eta * self.reg.lock().unwrap().lambda;
            // Artifact failures fall back to the native mirror (identical
            // math) rather than poisoning the run.
            if pjrt.apply(&mut snap, tau).is_err() {
                self.reg.lock().unwrap().prox(&mut snap, self.eta);
            }
        } else {
            self.reg.lock().unwrap().prox(&mut snap, self.eta);
        }
        self.prox_count.fetch_add(1, Ordering::Relaxed);
        let m = Arc::new(snap);
        *cache = Some((version, Arc::clone(&m)));
        m
    }

    /// `(Prox_{ηλg}(V̂))_t` — what an activated task node retrieves.
    pub fn prox_col(&self, t: usize) -> Vec<f64> {
        self.prox_matrix().col(t).to_vec()
    }

    /// Tell the regularizer a column changed (drives the online-SVD path).
    pub fn notify_column_update(&self, t: usize, col: &[f64]) {
        let mut reg = self.reg.lock().unwrap();
        if reg.uses_online_svd() {
            reg.notify_column_update(t, col);
        }
    }

    /// Commit one forward-step result: the KM relaxation
    /// `v_t ← v_t + step·(u − v_t)` on block `t`, plus the online-SVD
    /// bookkeeping. This is the single server-side commit path — both the
    /// in-proc and the TCP [`Transport`](crate::transport::Transport)
    /// implementations land updates through it, so the commit protocol
    /// cannot drift between the two.
    ///
    /// Returns the new global version (total KM updates).
    pub fn commit_update(&self, t: usize, u: &[f64], step: f64) -> u64 {
        let version = self.state.km_update(t, u, step);
        let new_col = self.state.read_col(t);
        self.notify_column_update(t, &new_col);
        version
    }

    /// `λ·g(W)` for objective reporting.
    pub fn reg_value(&self, w: &Mat) -> f64 {
        self.reg.lock().unwrap().value(w)
    }

    /// The final primal iterate `W* = Prox_{ηλg}(V*)` (one extra backward
    /// step maps the auxiliary variable back — §III.C).
    pub fn final_w(&self) -> Mat {
        let mut snap = self.state.snapshot();
        self.reg.lock().unwrap().prox(&mut snap, self.eta);
        snap
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optim::prox::RegularizerKind;
    use crate::util::Rng;

    fn server_with(kind: RegularizerKind, lambda: f64, eta: f64, d: usize, t: usize) -> CentralServer {
        let state = Arc::new(SharedState::zeros(d, t));
        CentralServer::new(state, Regularizer::new(kind, lambda), eta)
    }

    #[test]
    fn prox_col_matches_manual_prox() {
        let mut rng = Rng::new(100);
        let m = Mat::randn(6, 3, &mut rng);
        let state = Arc::new(SharedState::new(&m));
        let srv = CentralServer::new(state, Regularizer::new(RegularizerKind::L21, 0.5), 0.2);
        let mut want = m.clone();
        Regularizer::new(RegularizerKind::L21, 0.5).prox(&mut want, 0.2);
        for t in 0..3 {
            assert_eq!(srv.prox_col(t), want.col(t));
        }
    }

    #[test]
    fn cache_hits_until_update() {
        let srv = server_with(RegularizerKind::L21, 0.1, 0.1, 4, 2);
        let _ = srv.prox_matrix();
        let _ = srv.prox_matrix();
        let _ = srv.prox_col(0);
        assert_eq!(srv.prox_count(), 1, "unchanged V must not re-prox");
        srv.state().km_update(0, &[1.0, 0.0, 0.0, 0.0], 1.0);
        let _ = srv.prox_matrix();
        assert_eq!(srv.prox_count(), 2);
    }

    #[test]
    fn prox_every_widens_reuse() {
        let srv = server_with(RegularizerKind::L21, 0.1, 0.1, 2, 2).with_prox_every(3);
        let _ = srv.prox_matrix();
        srv.state().km_update(0, &[1.0, 0.0], 1.0);
        srv.state().km_update(1, &[1.0, 0.0], 1.0);
        let _ = srv.prox_matrix(); // only 2 updates landed: cache hit
        assert_eq!(srv.prox_count(), 1);
        srv.state().km_update(0, &[2.0, 0.0], 1.0);
        let _ = srv.prox_matrix(); // 3 updates: recompute
        assert_eq!(srv.prox_count(), 2);
    }

    #[test]
    fn nuclear_server_thresholds_spectrum() {
        let mut rng = Rng::new(101);
        let m = Mat::randn(8, 4, &mut rng);
        let state = Arc::new(SharedState::new(&m));
        let lambda = 0.7;
        let eta = 0.3;
        let srv = CentralServer::new(state, Regularizer::new(RegularizerKind::Nuclear, lambda), eta);
        let got = srv.prox_matrix();
        let before = crate::optim::svd::Svd::jacobi(&m);
        let after = crate::optim::svd::Svd::jacobi(&got);
        for (a, b) in after.sigma.iter().zip(&before.sigma) {
            assert!((a - (b - eta * lambda).max(0.0)).abs() < 1e-8);
        }
    }

    #[test]
    fn final_w_is_prox_of_current_v() {
        let mut rng = Rng::new(102);
        let m = Mat::randn(5, 3, &mut rng);
        let state = Arc::new(SharedState::new(&m));
        let srv = CentralServer::new(state, Regularizer::new(RegularizerKind::L1, 0.4), 0.5);
        let mut want = m.clone();
        Regularizer::new(RegularizerKind::L1, 0.4).prox(&mut want, 0.5);
        assert!(srv.final_w().max_abs_diff(&want) < 1e-12);
    }

    #[test]
    fn concurrent_prox_requests_are_safe() {
        let srv = Arc::new(server_with(RegularizerKind::Nuclear, 0.2, 0.1, 10, 6));
        let mut handles = Vec::new();
        for t in 0..6 {
            let srv = Arc::clone(&srv);
            handles.push(std::thread::spawn(move || {
                let mut rng = Rng::new(200 + t as u64);
                for _ in 0..50 {
                    let col = srv.prox_col(t);
                    assert_eq!(col.len(), 10);
                    let u = rng.normal_vec(10);
                    srv.state().km_update(t, &u, 0.5);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(srv.state().version(), 300);
        assert!(srv.prox_count() <= 301, "prox per update at most");
    }
}
