//! Update schedules: who waits for whom.
//!
//! The paper's solver is one backward-forward iteration; what
//! distinguishes §III.B (synchronized) from Algorithm 1 (asynchronous) is
//! purely the *schedule* — the orchestration of worker activations. The
//! [`Schedule`] trait owns exactly that seam, and nothing else: shared
//! setup, worker-context construction, RNG forking and result assembly
//! all live in [`Session`](super::session::Session).
//!
//! * [`Async`] — Algorithm 1 / ARock: every node free-runs, no barrier.
//! * [`Synchronized`] — §III.B map-reduce rounds: one prox broadcast per
//!   round, a barrier on the slowest node (the straggler tax the paper
//!   measures).
//! * [`SemiSync`] — bounded staleness: nodes free-run but may be at most
//!   `staleness_bound` activations ahead of the slowest live node. The
//!   middle ground the forked AMTL/SMTL drivers could not express — at
//!   large bounds it behaves like [`Async`], at bound 1 like a pipelined
//!   barrier.
//!
//! A schedule only needs [`Orchestrator`]'s public surface, so downstream
//! code can plug in its own (e.g. elastic membership, priority serving).

use super::session::{Orchestrator, RunConfig};
use super::worker::{run_activation, run_worker, Activation, WorkerStats};
use anyhow::Result;
use std::sync::{Barrier, Condvar, Mutex};

/// A worker orchestration policy. `orchestrate` must drive every task
/// node to completion (or recorded crash) and return one [`WorkerStats`]
/// per node, in node order.
pub trait Schedule: Send + Sync {
    /// Short method name, used as `RunResult::method` ("amtl", "smtl", ...).
    fn name(&self) -> &'static str;

    /// Validate schedule-specific parameters against the shared config.
    fn validate(&self, cfg: &RunConfig) -> Result<()> {
        let _ = cfg;
        Ok(())
    }

    /// Run the worker loop(s) to completion.
    fn orchestrate(&self, orch: &mut Orchestrator<'_>) -> Result<Vec<WorkerStats>>;
}

/// Algorithm 1: fully asynchronous — workers never wait for each other.
#[derive(Clone, Copy, Debug, Default)]
pub struct Async;

impl Schedule for Async {
    fn name(&self) -> &'static str {
        "amtl"
    }

    fn orchestrate(&self, orch: &mut Orchestrator<'_>) -> Result<Vec<WorkerStats>> {
        run_free(orch, self.name(), None)
    }
}

/// Bounded-staleness schedule: free-running workers, but no node may start
/// activation `k` until every live node has completed activation
/// `k - staleness_bound`. Crashed or finished nodes stop counting, so a
/// dead straggler cannot stall the federation.
#[derive(Clone, Copy, Debug)]
pub struct SemiSync {
    /// Maximum activations any node may run ahead of the slowest live
    /// node. Must be >= 1 (0 would be a full barrier — use
    /// [`Synchronized`]).
    pub staleness_bound: u64,
}

impl Schedule for SemiSync {
    fn name(&self) -> &'static str {
        "semisync"
    }

    fn validate(&self, cfg: &RunConfig) -> Result<()> {
        anyhow::ensure!(
            self.staleness_bound >= 1,
            "staleness_bound must be >= 1 (use Synchronized for a full barrier)"
        );
        anyhow::ensure!(
            !cfg.faults.has_silent_window() || cfg.heartbeat.is_some(),
            "a silent crash/restart fault under bounded staleness needs heartbeat \
             eviction (set heartbeat_ms), or the live nodes stall on the dead one"
        );
        Ok(())
    }

    fn orchestrate(&self, orch: &mut Orchestrator<'_>) -> Result<Vec<WorkerStats>> {
        let gate = std::sync::Arc::new(StalenessGate::new(orch.t_count(), self.staleness_bound));
        // A resumed run's workers start at their applied-commit horizon;
        // the gate's completed counters must start there too, or every
        // worker would park forever behind counters stuck at zero.
        if orch.cfg().resume {
            let server = orch.server();
            let counts: Vec<u64> =
                (0..orch.t_count()).map(|t| server.applied_commits(t)).collect();
            gate.prime_completed(&counts);
        }
        // Elastic membership: a node evicted for silence stops gating the
        // federation — exactly like one that reported its own crash.
        if let Some(registry) = orch.registry() {
            let g = std::sync::Arc::clone(&gate);
            registry.on_evict(move |t| g.deactivate(t));
        }
        run_free(orch, self.name(), Some(gate))
    }
}

/// Spawn one free-running worker thread per node (optionally behind a
/// staleness gate) and join them in node order.
fn run_free(
    orch: &mut Orchestrator<'_>,
    name: &str,
    gate: Option<std::sync::Arc<StalenessGate>>,
) -> Result<Vec<WorkerStats>> {
    let mut ctxs = orch.worker_ctxs()?;
    if let Some(g) = &gate {
        for ctx in &mut ctxs {
            ctx.gate = Some(std::sync::Arc::clone(g));
        }
    }
    let computes = orch.computes();
    let t_count = ctxs.len();
    let mut stats = Vec::new();
    std::thread::scope(|s| -> Result<()> {
        let mut handles = Vec::new();
        for (t, (ctx, compute)) in ctxs.into_iter().zip(computes.iter_mut()).enumerate() {
            let spawned = std::thread::Builder::new()
                .name(format!("{name}-worker-{t}"))
                .spawn_scoped(s, move || run_worker(ctx, compute.as_mut()));
            match spawned {
                Ok(handle) => handles.push(handle),
                Err(e) => {
                    // Nodes t.. never run: remove them from the staleness
                    // minimum, or the already-spawned workers would block
                    // forever on them while the scope joins.
                    if let Some(g) = &gate {
                        for dead in t..t_count {
                            g.deactivate(dead);
                        }
                    }
                    return Err(e.into());
                }
            }
        }
        for h in handles {
            stats.push(h.join().map_err(|_| anyhow::anyhow!("worker panicked"))??);
        }
        Ok(())
    })?;
    Ok(stats)
}

/// §III.B: classic map-reduce proximal gradient. Every round the server
/// proxes once (each node fetches its block through its transport; the
/// version-keyed prox cache makes that one broadcast); all nodes compute
/// forward steps in parallel behind their own delays; a barrier waits for
/// the slowest; the round loop commits the collected updates in task
/// order. Round time = max over nodes of (delay + compute) — the
/// straggler effect the paper measures.
///
/// Feature parity with the free-running schedules comes from the shared
/// [`RunConfig`]: faults (a crashed node simply stops contributing —
/// rounds proceed so the run terminates), minibatch forward steps,
/// `prox_every` (via the server's prox cache) and the dynamic step size
/// all behave identically.
#[derive(Clone, Copy, Debug, Default)]
pub struct Synchronized;

impl Schedule for Synchronized {
    fn name(&self) -> &'static str {
        "smtl"
    }

    fn orchestrate(&self, orch: &mut Orchestrator<'_>) -> Result<Vec<WorkerStats>> {
        let t_count = orch.t_count();
        let iters = orch.cfg().iters_per_node;
        let server = orch.server();
        let controller = orch.controller();
        let recorder = orch.recorder();
        // A resumed run continues at the round the durable state ends at
        // (rounds below a column's applied-commit horizon would only be
        // deduplicated away). Columns that were already ahead of the
        // lowest horizon are caught up by the dedup itself.
        let start_round = if orch.cfg().resume {
            (0..t_count)
                .map(|t| server.applied_commits(t))
                .min()
                .unwrap_or(0)
                .min(iters as u64) as usize
        } else {
            0
        };
        // The round loop's own channel to the server (over TCP: its own
        // connection) — workers only *fetch*; commits all flow through
        // this one handle, in task order, exactly one batch per round.
        let mut commit = orch.transport()?;
        let ctxs = orch.worker_ctxs()?;
        let computes = orch.computes();

        // Collection slots for the round's forward results.
        let slots: Vec<Mutex<Option<Vec<f64>>>> =
            (0..t_count).map(|_| Mutex::new(None)).collect();
        let barrier = Barrier::new(t_count + 1);

        let mut stats_out = Vec::new();
        std::thread::scope(|s| -> Result<()> {
            let mut handles = Vec::new();
            // Known limitation: if spawning worker j fails after j > 0
            // workers started, the early return leaves them parked at the
            // round-start barrier and the scope join hangs. OS-level
            // thread-spawn failure at T+1 threads is treated as fatal
            // environment exhaustion; panics *inside* workers are
            // contained below and do not have this problem.
            for (ctx, compute) in ctxs.into_iter().zip(computes.iter_mut()) {
                let barrier = &barrier;
                let slots = &slots;
                let handle = std::thread::Builder::new()
                    .name(format!("smtl-worker-{}", ctx.t))
                    .spawn_scoped(s, move || -> Result<WorkerStats> {
                        let mut ctx = ctx;
                        let mut stats = WorkerStats::default();
                        // A compute failure must not skip the round-end
                        // barrier (the server and peers would deadlock):
                        // park the error, keep pacing rounds, surface it
                        // after the loop.
                        let mut failure: Option<anyhow::Error> = None;
                        for k in start_round..ctx.iters {
                            barrier.wait(); // round start: commits landed
                            if stats.crashed || failure.is_some() {
                                // Dead node: keep the barrier count, do
                                // nothing (its block stays frozen).
                                barrier.wait();
                                continue;
                            }
                            let t = ctx.t;
                            // Every node fetches its block of the same
                            // prox (the server's version-keyed cache
                            // computes it once per round) — the broadcast
                            // of §III.B, expressed through the transport.
                            let fetch =
                                |tr: &mut dyn crate::transport::Transport| tr.fetch_prox_col(t);
                            // A panic in the compute must not unwind past
                            // the barrier pacing (peers and the round loop
                            // would deadlock waiting for this thread):
                            // contain it and park it like any failure.
                            let outcome = std::panic::catch_unwind(
                                std::panic::AssertUnwindSafe(|| {
                                    run_activation(&mut ctx, compute, k as u64, fetch, &mut stats)
                                }),
                            )
                            .unwrap_or_else(|_| {
                                Err(anyhow::anyhow!("worker {t} panicked mid-round"))
                            });
                            match outcome {
                                Ok(Activation::Crashed) => stats.crashed = true,
                                Ok(Activation::Dropped) | Ok(Activation::Offline) => {}
                                Ok(Activation::Update { u, .. }) => {
                                    // The round loop commits the whole batch
                                    // after the barrier, so per-commit span
                                    // stamps are not meaningful here.
                                    *slots[t].lock().unwrap() = Some(u);
                                    stats.updates += 1;
                                }
                                Err(e) => failure = Some(e),
                            }
                            barrier.wait(); // round end: all nodes done
                        }
                        match failure {
                            Some(e) => Err(e),
                            None => Ok(stats),
                        }
                    })?;
                handles.push(handle);
            }

            // The round loop (this thread): commit the collected forward
            // results through the transport, then sample the trajectory
            // once per round. A commit failure must not abandon the
            // barrier pacing (workers would deadlock mid-round): park it,
            // keep the rounds turning without commits, surface it after
            // the workers are joined.
            let mut commit_failure: Option<anyhow::Error> = None;
            for round in start_round..iters {
                barrier.wait(); // release workers into the round
                barrier.wait(); // wait for the slowest worker
                if commit_failure.is_some() {
                    continue;
                }
                for t in 0..t_count {
                    if let Some(u) = slots[t].lock().unwrap().take() {
                        let step = controller.step(t);
                        // The round number is each column's activation
                        // counter (the commit dedup key).
                        if let Err(e) = commit.push_update(t, round as u64, step, &u) {
                            commit_failure = Some(e);
                            break;
                        }
                    }
                }
                recorder.maybe_record(server.state().version(), || server.state().snapshot());
            }
            for h in handles {
                stats_out.push(
                    h.join().map_err(|_| anyhow::anyhow!("smtl worker panicked"))??,
                );
            }
            match commit_failure {
                Some(e) => Err(e),
                None => Ok(()),
            }
        })?;
        Ok(stats_out)
    }
}

/// Resolve a CLI `--method` name (+ the optional `--staleness` bound)
/// into a schedule, rejecting the contradictory combination of a
/// staleness bound with a schedule that has no staleness concept — that
/// flag silently doing nothing is exactly the misconfiguration class
/// `RunConfig::validate` exists to catch.
pub fn schedule_from_cli(method: &str, staleness: Option<u64>) -> Result<Box<dyn Schedule>> {
    anyhow::ensure!(
        staleness.is_none() || method == "semisync",
        "--staleness only applies to --method semisync (got --method {method})"
    );
    Ok(match method {
        "amtl" => Box::new(Async),
        "smtl" => Box::new(Synchronized),
        "semisync" => Box::new(SemiSync { staleness_bound: staleness.unwrap_or(4) }),
        other => anyhow::bail!("unknown --method '{other}' (expected one of amtl|smtl|semisync)"),
    })
}

/// Progress tracker for [`SemiSync`]: nodes block in `wait_to_start(k)`
/// until every *live* node has completed at least `k - bound` activations.
/// Finished/crashed/errored nodes deactivate themselves so they stop
/// holding the minimum back.
pub struct StalenessGate {
    bound: u64,
    inner: Mutex<GateInner>,
    cv: Condvar,
}

struct GateInner {
    completed: Vec<u64>,
    active: Vec<bool>,
}

impl StalenessGate {
    /// A gate over `t_count` nodes with staleness bound `bound`.
    pub fn new(t_count: usize, bound: u64) -> StalenessGate {
        StalenessGate {
            bound,
            inner: Mutex::new(GateInner {
                completed: vec![0; t_count],
                active: vec![true; t_count],
            }),
            cv: Condvar::new(),
        }
    }

    fn min_live_completed(inner: &GateInner) -> u64 {
        inner
            .completed
            .iter()
            .zip(&inner.active)
            .filter(|(_, live)| **live)
            .map(|(c, _)| *c)
            .min()
            .unwrap_or(u64::MAX)
    }

    /// Block until activation `k` (0-based) is within the staleness bound.
    pub fn wait_to_start(&self, k: u64) {
        let mut inner = self.inner.lock().unwrap();
        while k > Self::min_live_completed(&inner).saturating_add(self.bound) {
            inner = self.cv.wait(inner).unwrap();
        }
    }

    /// Like [`StalenessGate::wait_to_start`], but runs `tick()` (outside
    /// the gate lock) at least every `interval` while parked. Workers
    /// with elastic membership tick their heartbeat here, so a node
    /// blocked on a silent straggler both stays live itself and keeps
    /// sweeping the registry — which is what eventually evicts the
    /// straggler and (via the eviction callback) unblocks this wait.
    pub fn wait_to_start_ticking(
        &self,
        k: u64,
        interval: std::time::Duration,
        mut tick: impl FnMut(),
    ) {
        loop {
            {
                let inner = self.inner.lock().unwrap();
                if k <= Self::min_live_completed(&inner).saturating_add(self.bound) {
                    return;
                }
                let (inner, _timeout) = self.cv.wait_timeout(inner, interval).unwrap();
                if k <= Self::min_live_completed(&inner).saturating_add(self.bound) {
                    return;
                }
            }
            tick();
        }
    }

    /// Record one completed activation for node `t`.
    pub fn finish_iter(&self, t: usize) {
        self.inner.lock().unwrap().completed[t] += 1;
        self.cv.notify_all();
    }

    /// Pre-load the completed counters (a resumed run's workers begin at
    /// their applied-commit horizons, and `wait_to_start` measures
    /// staleness against these counts).
    pub fn prime_completed(&self, counts: &[u64]) {
        let mut inner = self.inner.lock().unwrap();
        for (slot, &c) in inner.completed.iter_mut().zip(counts) {
            *slot = c;
        }
        drop(inner);
        self.cv.notify_all();
    }

    /// Remove node `t` from the staleness minimum (finished or dead).
    pub fn deactivate(&self, t: usize) {
        self.inner.lock().unwrap().active[t] = false;
        self.cv.notify_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::problem::MtlProblem;
    use crate::coordinator::session::Session;
    use crate::data::synthetic;
    use crate::net::FaultModel;
    use crate::optim::prox::RegularizerKind;
    use crate::util::Rng;
    use std::sync::mpsc;
    use std::sync::Arc;
    use std::time::Duration;

    fn problem(seed: u64, t: usize, n: usize, d: usize) -> MtlProblem {
        let mut rng = Rng::new(seed);
        let ds = synthetic::lowrank_regression(&vec![n; t], d, 2, 0.05, &mut rng);
        MtlProblem::new(ds, RegularizerKind::Nuclear, 0.2, 0.5, &mut rng)
    }

    #[test]
    fn schedule_from_cli_resolves_and_rejects_contradictions() {
        assert_eq!(schedule_from_cli("amtl", None).unwrap().name(), "amtl");
        assert_eq!(schedule_from_cli("smtl", None).unwrap().name(), "smtl");
        assert_eq!(schedule_from_cli("semisync", Some(2)).unwrap().name(), "semisync");
        assert_eq!(schedule_from_cli("semisync", None).unwrap().name(), "semisync");
        let err = schedule_from_cli("amtl", Some(3)).unwrap_err();
        assert!(format!("{err}").contains("--staleness"), "{err}");
        let err = schedule_from_cli("bogus", None).unwrap_err();
        assert!(format!("{err}").contains("amtl|smtl|semisync"), "{err}");
    }

    #[test]
    fn gate_blocks_until_within_bound() {
        let gate = Arc::new(StalenessGate::new(2, 1));
        // Node 0 finished activations 0 and 1; node 1 finished nothing.
        gate.finish_iter(0);
        gate.finish_iter(0);
        let (tx, rx) = mpsc::channel();
        let g = Arc::clone(&gate);
        std::thread::spawn(move || {
            g.wait_to_start(2); // 2 > min(2,0)+1 → must block on node 1
            tx.send(()).unwrap();
        });
        assert!(
            rx.recv_timeout(Duration::from_millis(100)).is_err(),
            "node 0 must block two ahead of node 1"
        );
        gate.finish_iter(1); // min rises to 1: 2 <= 1+1 → unblocks
        rx.recv_timeout(Duration::from_secs(5)).expect("unblocked");
    }

    #[test]
    fn gate_deactivation_unblocks_waiters() {
        let gate = Arc::new(StalenessGate::new(2, 1));
        gate.finish_iter(0);
        gate.finish_iter(0);
        let (tx, rx) = mpsc::channel();
        let g = Arc::clone(&gate);
        std::thread::spawn(move || {
            g.wait_to_start(2);
            tx.send(()).unwrap();
        });
        assert!(rx.recv_timeout(Duration::from_millis(100)).is_err());
        gate.deactivate(1); // node 1 dies: it no longer gates progress
        rx.recv_timeout(Duration::from_secs(5)).expect("unblocked");
    }

    #[test]
    fn semisync_runs_full_budget_and_decreases_objective() {
        let p = problem(720, 4, 40, 8);
        let r = Session::builder(&p)
            .iters_per_node(60)
            .eta_k(0.9)
            .schedule(SemiSync { staleness_bound: 2 })
            .build()
            .unwrap()
            .run()
            .unwrap();
        assert_eq!(r.updates, 240);
        assert_eq!(r.updates_per_node, vec![60; 4]);
        let f0 = p.objective(&p.prox_map(&crate::linalg::Mat::zeros(8, 4)));
        let f1 = p.objective(&r.w_final);
        assert!(f1 < 0.2 * f0, "objective {f0} -> {f1}");
    }

    #[test]
    fn semisync_survives_a_crashed_straggler() {
        // The crashed node deactivates itself; the others must still
        // finish their budget instead of deadlocking at the gate.
        let p = problem(721, 3, 20, 5);
        let r = Session::builder(&p)
            .iters_per_node(30)
            .faults(FaultModel::CrashAfter { node: 1, after: 2 })
            .schedule(SemiSync { staleness_bound: 1 })
            .build()
            .unwrap()
            .run()
            .unwrap();
        assert_eq!(r.crashed_nodes, vec![1]);
        assert_eq!(r.updates_per_node, vec![30, 2, 30]);
    }

    #[test]
    fn synchronized_supports_faults_via_shared_config() {
        // Parity satellite: the old SmtlConfig had no fault model at all.
        let p = problem(722, 4, 30, 6);
        let r = Session::builder(&p)
            .iters_per_node(20)
            .faults(FaultModel::CrashAfter { node: 2, after: 3 })
            .schedule(Synchronized)
            .build()
            .unwrap()
            .run()
            .unwrap();
        assert_eq!(r.crashed_nodes, vec![2]);
        assert_eq!(r.updates_per_node, vec![20, 20, 3, 20]);
        assert_eq!(r.updates, 63);
        assert!(p.objective(&r.w_final).is_finite());
    }

    #[test]
    fn synchronized_supports_minibatch_forward_steps() {
        let p = problem(723, 3, 60, 6);
        let r = Session::builder(&p)
            .iters_per_node(80)
            .eta_k(0.9)
            .sgd_fraction(Some(0.5))
            .schedule(Synchronized)
            .build()
            .unwrap()
            .run()
            .unwrap();
        let f0 = p.objective(&p.prox_map(&crate::linalg::Mat::zeros(6, 3)));
        let f1 = p.objective(&r.w_final);
        assert!(f1 < 0.3 * f0, "sgd smtl: {f0} -> {f1}");
    }

    #[test]
    fn synchronized_honors_prox_every() {
        let p = problem(724, 4, 20, 5);
        let run = |stride: u64| {
            Session::builder(&p)
                .iters_per_node(12)
                .prox_every(stride)
                .schedule(Synchronized)
                .build()
                .unwrap()
                .run()
                .unwrap()
        };
        let dense = run(1);
        let sparse = run(16);
        assert!(
            sparse.prox_count < dense.prox_count,
            "prox_every=16 ({}) must prox less than =1 ({})",
            sparse.prox_count,
            dense.prox_count
        );
    }

    #[test]
    fn async_node_survives_silent_restart_window() {
        // A crash/restart window under Async: the node misses its window
        // and resumes — nobody waits on it, so nothing else changes.
        let p = problem(726, 3, 20, 5);
        let r = Session::builder(&p)
            .iters_per_node(10)
            .time_scale(Duration::from_millis(5))
            .faults(FaultModel::CrashRestart { node: 0, down_from: 3, down_for: 4 })
            .build()
            .unwrap()
            .run()
            .unwrap();
        assert_eq!(r.updates_per_node, vec![6, 10, 10]);
        assert_eq!(r.updates, 26);
        assert!(r.crashed_nodes.is_empty(), "offline is not a crash");
    }

    #[test]
    fn synchronized_tolerates_silent_restart_window() {
        let p = problem(727, 3, 20, 5);
        let r = Session::builder(&p)
            .iters_per_node(10)
            .faults(FaultModel::CrashRestart { node: 2, down_from: 1, down_for: 3 })
            .schedule(Synchronized)
            .build()
            .unwrap()
            .run()
            .unwrap();
        assert_eq!(r.updates_per_node, vec![10, 10, 7]);
    }

    #[test]
    fn semisync_evicts_silent_node_and_does_not_stall() {
        // The acceptance scenario: a node goes silent mid-run under
        // bounded staleness. Without membership the live nodes would park
        // at the gate forever; with heartbeats the registry evicts the
        // silent node (swept by the parked peers' ticks), the eviction
        // callback deactivates its gate slot, and the rest of the
        // federation finishes its full budget.
        let p = problem(728, 3, 20, 5);
        let r = Session::builder(&p)
            .iters_per_node(12)
            .eta_k(0.9)
            .faults(FaultModel::CrashRestart { node: 1, down_from: 2, down_for: 100 })
            .heartbeat(Some(Duration::from_millis(25)))
            .schedule(SemiSync { staleness_bound: 1 })
            .build()
            .unwrap()
            .run()
            .unwrap();
        assert_eq!(r.updates_per_node[0], 12, "live node 0 must finish");
        assert_eq!(r.updates_per_node[2], 12, "live node 2 must finish");
        assert_eq!(r.updates_per_node[1], 2, "silent node stopped at its window");
        assert!(r.evicted_nodes.contains(&1), "evicted: {:?}", r.evicted_nodes);
    }

    #[test]
    fn semisync_rejects_silent_faults_without_heartbeats() {
        // A silent window with no eviction mechanism would stall the live
        // nodes forever; the builder refuses the combination up front.
        let p = problem(729, 2, 10, 4);
        let err = Session::builder(&p)
            .faults(FaultModel::CrashRestart { node: 0, down_from: 1, down_for: 5 })
            .schedule(SemiSync { staleness_bound: 1 })
            .build()
            .unwrap_err();
        assert!(format!("{err}").contains("heartbeat"), "{err}");
    }

    #[test]
    fn all_schedules_reach_similar_objectives() {
        // Fig. 4 generalized: per-iteration progress is schedule-invariant.
        let p = problem(725, 4, 40, 6);
        let run = |schedule: Box<dyn Schedule>| {
            Session::builder(&p)
                .iters_per_node(120)
                .eta_k(0.9)
                .schedule_box(schedule)
                .build()
                .unwrap()
                .run()
                .unwrap()
        };
        let fa = p.objective(&run(Box::new(Async)).w_final);
        let fs = p.objective(&run(Box::new(Synchronized)).w_final);
        let fb = p.objective(&run(Box::new(SemiSync { staleness_bound: 3 })).w_final);
        assert!((fa - fs).abs() / fs.max(1e-9) < 0.1, "amtl {fa} vs smtl {fs}");
        assert!((fb - fs).abs() / fs.max(1e-9) < 0.1, "semisync {fb} vs smtl {fs}");
    }
}
