//! The unified coordinator entry point: one [`Session`] drives the paper's
//! regularized MTL solve under any update [`Schedule`](super::schedule::Schedule).
//!
//! The paper's formulation (Eq. III.1) is schedule-agnostic: the same
//! backward (prox) + forward (gradient) iteration runs synchronized
//! (§III.B), asynchronous (Algorithm 1 / ARock), or anywhere in between.
//! `Session` owns everything the schedules share — problem wiring, the
//! shared state `V`, the central server, the step controller, RNG forking,
//! trajectory recording, and [`RunResult`] assembly — while the schedule
//! owns only the worker orchestration loop.
//!
//! ```no_run
//! # use amtl::coordinator::{MtlProblem, Session, SemiSync};
//! # fn demo(problem: &MtlProblem) -> anyhow::Result<()> {
//! let result = Session::builder(problem)
//!     .iters_per_node(50)
//!     .paper_offset(5.0)
//!     .eta_k(0.9)
//!     .schedule(SemiSync { staleness_bound: 4 })
//!     .build()?
//!     .run()?;
//! println!("{}", result.summary());
//! # Ok(())
//! # }
//! ```

use super::metrics::{Recorder, RunResult};
use super::problem::MtlProblem;
use super::registry::NodeRegistry;
use super::schedule::{Async, Schedule};
use super::server::CentralServer;
use super::state::SharedState;
use super::step_size::{KmSchedule, StepController};
use super::worker::{TrajectorySink, WorkerCtx};
use crate::net::{DelayModel, FaultModel};
use crate::obs::TraceWriter;
use crate::optim::formulation::SharedProx;
use crate::optim::svd::SvdMode;
use crate::persist::{Checkpointer, PersistConfig};
use crate::runtime::{ComputePool, Engine, TaskCompute};
use crate::transport::{InProc, TcpClient, TcpOptions, TcpServer, Transport, TransportKind};
use crate::util::Rng;
use anyhow::Result;
use std::net::SocketAddr;
use std::path::PathBuf;
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Configuration shared by every schedule. One activation is one forward
/// step of one task node; `iters_per_node` is the per-node activation
/// budget ("iterations" in the paper's tables, rounds for the
/// synchronized schedule).
#[derive(Clone, Debug)]
pub struct RunConfig {
    /// Activations per task node.
    pub iters_per_node: usize,
    /// Injected network-delay model.
    pub delay: DelayModel,
    /// Injected fault model (robustness experiments).
    pub faults: FaultModel,
    /// Minibatch fraction for stochastic forward steps (None = full batch).
    pub sgd_fraction: Option<f64>,
    /// Wall-clock duration of one paper delay-unit (default: 100 ms
    /// represents one paper "second").
    pub time_scale: Duration,
    /// KM relaxation step η_k.
    pub km: KmSchedule,
    /// Enable the §III.D dynamic step size.
    pub dynamic_step: bool,
    /// Delay-history window for Eq. III.6 (the paper uses 5).
    pub dyn_window: usize,
    /// Server re-prox stride (1 = after every update, the paper default).
    pub prox_every: u64,
    /// Trajectory sampling stride in updates.
    pub record_every: u64,
    /// Which SVD backs the nuclear prox: incremental Brand updates (the
    /// default) or exact Jacobi on every uncached prox. Ignored by
    /// non-nuclear regularizers.
    pub svd: SvdMode,
    /// Online-SVD drift bound: exact Jacobi refresh every this many
    /// commits (0 = never refresh). Ignored under [`SvdMode::Exact`].
    pub resvd_every: u64,
    /// Root seed for the run's deterministic per-node RNG streams.
    pub seed: u64,
    /// Durability: when set, the central server checkpoints to this
    /// directory (snapshots + a commit WAL fsync'd before each ack).
    pub checkpoint_dir: Option<PathBuf>,
    /// Commits between snapshot rotations.
    pub checkpoint_every: u64,
    /// Resume from `checkpoint_dir` instead of starting fresh: the
    /// server is rebuilt from the latest valid snapshot + WAL replay,
    /// and workers skip the activations already applied to their column.
    pub resume: bool,
    /// Elastic-membership heartbeat interval; nodes silent for
    /// [`HEARTBEAT_TIMEOUT_FACTOR`] intervals are evicted. `None` =
    /// membership disabled.
    pub heartbeat: Option<Duration>,
    /// When set, the run appends one JSONL event per activation, commit,
    /// prox, checkpoint, and eviction to this writer (`--trace-out`).
    pub trace: Option<Arc<TraceWriter>>,
}

impl Default for RunConfig {
    fn default() -> Self {
        RunConfig {
            iters_per_node: 10,
            delay: DelayModel::None,
            faults: FaultModel::None,
            sgd_fraction: None,
            time_scale: Duration::from_millis(100),
            km: KmSchedule::fixed(0.5),
            dynamic_step: false,
            dyn_window: 5,
            prox_every: 1,
            record_every: 1,
            svd: SvdMode::default(),
            resvd_every: DEFAULT_RESVD_EVERY,
            seed: 7,
            checkpoint_dir: None,
            checkpoint_every: crate::persist::DEFAULT_SNAPSHOT_EVERY,
            resume: false,
            heartbeat: None,
            trace: None,
        }
    }
}

/// Default exact-refresh stride for the online nuclear prox: deep enough
/// that refresh cost amortizes away, shallow enough that drift stays far
/// below the 1e-8 verification tolerance (see `docs/PERFORMANCE.md`).
pub const DEFAULT_RESVD_EVERY: u64 = 64;

/// A node is evicted after this many missed heartbeat intervals: tight
/// enough that a dead node stops gating a run quickly, loose enough that
/// one slow heartbeat round-trip is never read as death.
pub const HEARTBEAT_TIMEOUT_FACTOR: u32 = 3;

impl RunConfig {
    /// The paper's AMTL-k / SMTL-k network setting: delay offset of
    /// `offset_units` paper-units (plus the exponential random component),
    /// scaled by `time_scale`. This is the one paper-offset helper — the
    /// per-method copies it replaced are gone.
    pub fn with_paper_offset(mut self, offset_units: f64) -> RunConfig {
        if offset_units > 0.0 {
            self.delay = DelayModel::paper_offset(self.time_scale.mul_f64(offset_units));
        }
        self
    }

    /// Assemble the server side of a run — shared state `V`, the central
    /// server (regularizer, prox stride, optional online-SVD seeding,
    /// optional durability + membership), and the trajectory recorder with
    /// its initial sample. This is the ONE construction path for both
    /// [`Session::run`] and the standalone `amtl --serve` process, so the
    /// two cannot drift apart. With `resume` set, the server is rebuilt
    /// from `checkpoint_dir` (latest valid snapshot + WAL replay) instead
    /// of starting from zero.
    pub fn build_server(
        &self,
        problem: &MtlProblem,
    ) -> Result<(Arc<SharedState>, Arc<CentralServer>, Arc<Recorder>)> {
        let mut server = if self.resume {
            let dir = self
                .checkpoint_dir
                .as_ref()
                .ok_or_else(|| anyhow::anyhow!("resume requires a checkpoint_dir"))?;
            let recovered =
                crate::persist::recover(PersistConfig::new(dir, self.checkpoint_every))?;
            let server = recovered.server;
            anyhow::ensure!(
                server.state().d() == problem.d() && server.state().t() == problem.t(),
                "checkpoint is {}x{} but the problem is {}x{} — resumed runs must use \
                 the original data/problem options",
                server.state().d(),
                server.state().t(),
                problem.d(),
                problem.t()
            );
            // The restored formulation must match the problem's spec, or
            // the server would prox with one coupling while objectives are
            // reported with another (silently wrong results).
            anyhow::ensure!(
                server.reg_id() == problem.reg_name(),
                "checkpoint was written with the '{}' formulation but the problem \
                 uses '{}' — resumed runs must keep the original --reg",
                server.reg_id(),
                problem.reg_name()
            );
            anyhow::ensure!(
                server.reg_lambda() == problem.lambda,
                "checkpoint was written with lambda {} but the problem has {} — \
                 resumed runs must keep the original regularization strength",
                server.reg_lambda(),
                problem.lambda
            );
            server
        } else {
            let state = Arc::new(SharedState::zeros(problem.d(), problem.t()));
            let mut reg = problem.regularizer();
            if self.svd == SvdMode::Online {
                // The formulation decides what "incremental" means:
                // nuclear seeds its Brand factorization, mean its running
                // centroid; formulations without an incremental form
                // ignore the hook.
                reg.enable_incremental(&state.snapshot(), self.resvd_every);
            }
            let mut server = CentralServer::new(Arc::clone(&state), reg, problem.eta)
                .with_prox_every(self.prox_every);
            if let Some(dir) = &self.checkpoint_dir {
                let cp = Arc::new(Checkpointer::create(PersistConfig::new(
                    dir,
                    self.checkpoint_every,
                ))?);
                cp.set_rng_stream(0, Rng::new(self.seed).state());
                server = server.with_checkpointer(cp)?;
            }
            server
        };
        if let Some(interval) = self.heartbeat {
            let registry = Arc::new(NodeRegistry::new(
                problem.t(),
                interval * HEARTBEAT_TIMEOUT_FACTOR,
            ));
            // Observability rides the same callback path the schedules use,
            // so every eviction is counted and traced no matter who sweeps.
            let trace = self.trace.clone();
            registry.on_evict(move |t| {
                crate::obs::global().inc("registry.evictions", 1);
                if let Some(tr) = &trace {
                    tr.event("eviction", Some(t), None, None, &[]);
                }
            });
            server = server.with_registry(registry);
        }
        if let Some(tr) = &self.trace {
            server = server.with_trace(Arc::clone(tr));
        }
        let server = Arc::new(server);
        let state = Arc::clone(server.state());
        let recorder = Arc::new(Recorder::new(self.record_every));
        recorder.record_now(state.version(), state.snapshot());
        Ok((state, server, recorder))
    }

    /// Validate parameter ranges (called by [`SessionBuilder::build`]).
    pub fn validate(&self) -> Result<()> {
        anyhow::ensure!(
            self.km.eta_k.is_finite() && self.km.eta_k > 0.0,
            "km step eta_k must be finite and positive, got {}",
            self.km.eta_k
        );
        if let Some(f) = self.sgd_fraction {
            anyhow::ensure!(
                f > 0.0 && f <= 1.0,
                "sgd_fraction must be in (0, 1], got {f}"
            );
        }
        anyhow::ensure!(self.dyn_window >= 1, "dyn_window must be >= 1");
        anyhow::ensure!(
            !(self.svd == SvdMode::Exact
                && self.resvd_every != DEFAULT_RESVD_EVERY
                && self.resvd_every != 0),
            "resvd_every only applies to the incremental path (svd = online): \
             with svd = exact every uncached prox recomputes from scratch, so a \
             refresh stride of {} would silently do nothing",
            self.resvd_every
        );
        anyhow::ensure!(self.checkpoint_every >= 1, "checkpoint_every must be >= 1");
        anyhow::ensure!(
            !self.resume || self.checkpoint_dir.is_some(),
            "resume requires a checkpoint_dir"
        );
        if let Some(interval) = self.heartbeat {
            anyhow::ensure!(!interval.is_zero(), "heartbeat interval must be positive");
        }
        Ok(())
    }
}

/// Builder for a [`Session`]. Setters apply in call order; `.config(..)`
/// replaces the whole [`RunConfig`], so call it before field setters.
pub struct SessionBuilder<'p> {
    problem: &'p MtlProblem,
    cfg: RunConfig,
    schedule: Box<dyn Schedule>,
    computes: Option<Vec<Box<dyn TaskCompute>>>,
    engine: Engine,
    pool: Option<&'p ComputePool>,
    paper_offset_units: Option<f64>,
    transport: TransportKind,
}

impl<'p> SessionBuilder<'p> {
    fn new(problem: &'p MtlProblem) -> SessionBuilder<'p> {
        SessionBuilder {
            problem,
            cfg: RunConfig::default(),
            schedule: Box::new(Async),
            computes: None,
            engine: Engine::Native,
            pool: None,
            paper_offset_units: None,
            transport: TransportKind::InProc,
        }
    }

    /// Replace the entire run configuration.
    pub fn config(mut self, cfg: RunConfig) -> Self {
        self.cfg = cfg;
        self
    }

    /// The update schedule (defaults to [`Async`]).
    pub fn schedule(self, schedule: impl Schedule + 'static) -> Self {
        self.schedule_box(Box::new(schedule))
    }

    /// Boxed form of [`SessionBuilder::schedule`] for dynamic dispatch
    /// (e.g. a schedule chosen from CLI flags).
    pub fn schedule_box(mut self, schedule: Box<dyn Schedule>) -> Self {
        self.schedule = schedule;
        self
    }

    /// Per-task compute engines, pre-built. Overrides `.engine()`/`.pool()`.
    pub fn computes(mut self, computes: Vec<Box<dyn TaskCompute>>) -> Self {
        self.computes = Some(computes);
        self
    }

    /// Engine used to build the per-task computes at `build()` time
    /// (default [`Engine::Native`]).
    pub fn engine(mut self, engine: Engine) -> Self {
        self.engine = engine;
        self
    }

    /// Executor pool for the PJRT engine.
    pub fn pool(mut self, pool: Option<&'p ComputePool>) -> Self {
        self.pool = pool;
        self
    }

    /// Activation budget per task node.
    pub fn iters_per_node(mut self, iters: usize) -> Self {
        self.cfg.iters_per_node = iters;
        self
    }

    /// Injected network-delay model.
    pub fn delay(mut self, delay: DelayModel) -> Self {
        self.cfg.delay = delay;
        self
    }

    /// Injected fault model (drops/crashes).
    pub fn faults(mut self, faults: FaultModel) -> Self {
        self.cfg.faults = faults;
        self
    }

    /// Minibatch fraction for stochastic forward steps (`None` = full).
    pub fn sgd_fraction(mut self, fraction: Option<f64>) -> Self {
        self.cfg.sgd_fraction = fraction;
        self
    }

    /// Wall-clock duration of one paper delay-unit.
    pub fn time_scale(mut self, time_scale: Duration) -> Self {
        self.cfg.time_scale = time_scale;
        self
    }

    /// The KM relaxation schedule.
    pub fn km(mut self, km: KmSchedule) -> Self {
        self.cfg.km = km;
        self
    }

    /// Shorthand for a fixed KM relaxation step.
    pub fn eta_k(mut self, eta_k: f64) -> Self {
        self.cfg.km = KmSchedule::fixed(eta_k);
        self
    }

    /// Enable the Eq. III.6 dynamic step size.
    pub fn dynamic_step(mut self, on: bool) -> Self {
        self.cfg.dynamic_step = on;
        self
    }

    /// Delay-history window for the dynamic step (the paper uses 5).
    pub fn dyn_window(mut self, window: usize) -> Self {
        self.cfg.dyn_window = window;
        self
    }

    /// Server re-prox stride (1 = after every update).
    pub fn prox_every(mut self, stride: u64) -> Self {
        self.cfg.prox_every = stride;
        self
    }

    /// Trajectory sampling stride in updates.
    pub fn record_every(mut self, stride: u64) -> Self {
        self.cfg.record_every = stride;
        self
    }

    /// Which SVD backs the nuclear prox (default [`SvdMode::Online`]).
    pub fn svd(mut self, mode: SvdMode) -> Self {
        self.cfg.svd = mode;
        self
    }

    /// Online-SVD exact-refresh stride in commits (0 = never refresh).
    pub fn resvd_every(mut self, k: u64) -> Self {
        self.cfg.resvd_every = k;
        self
    }

    /// Root seed for the per-node RNG streams.
    pub fn seed(mut self, seed: u64) -> Self {
        self.cfg.seed = seed;
        self
    }

    /// Durability: checkpoint the central server into `dir` (`None`
    /// disables; the default).
    pub fn checkpoint_dir(mut self, dir: Option<PathBuf>) -> Self {
        self.cfg.checkpoint_dir = dir;
        self
    }

    /// Commits between snapshot rotations (default
    /// [`crate::persist::DEFAULT_SNAPSHOT_EVERY`]).
    pub fn checkpoint_every(mut self, every: u64) -> Self {
        self.cfg.checkpoint_every = every;
        self
    }

    /// Resume from the checkpoint directory instead of starting fresh.
    pub fn resume(mut self, resume: bool) -> Self {
        self.cfg.resume = resume;
        self
    }

    /// Elastic-membership heartbeat interval (`None` disables; the
    /// default). Nodes silent for [`HEARTBEAT_TIMEOUT_FACTOR`] intervals
    /// are evicted and stop gating any schedule.
    pub fn heartbeat(mut self, interval: Option<Duration>) -> Self {
        self.cfg.heartbeat = interval;
        self
    }

    /// Per-run JSONL trace writer (`None` disables; the default). When
    /// set, every activation, commit, prox, checkpoint, and eviction
    /// appends one event line (see `docs/OBSERVABILITY.md`).
    pub fn trace(mut self, trace: Option<Arc<TraceWriter>>) -> Self {
        self.cfg.trace = trace;
        self
    }

    /// How workers reach the central server (default
    /// [`TransportKind::InProc`]). [`TransportKind::Tcp`] spawns a
    /// loopback TCP server around the session's central server and routes
    /// every backward fetch and KM commit through the real wire protocol
    /// — same math, real sockets.
    pub fn transport(mut self, kind: TransportKind) -> Self {
        self.transport = kind;
        self
    }

    /// The paper's AMTL-k / SMTL-k delay setting, in paper units. Resolved
    /// against `time_scale` at `build()` time, so setter order does not
    /// matter. Non-positive offsets leave the delay model unchanged.
    pub fn paper_offset(mut self, offset_units: f64) -> Self {
        self.paper_offset_units = Some(offset_units);
        self
    }

    /// Validate and assemble the [`Session`].
    pub fn build(self) -> Result<Session<'p>> {
        let mut cfg = self.cfg;
        if let Some(units) = self.paper_offset_units {
            cfg = cfg.with_paper_offset(units);
        }
        cfg.validate()?;
        self.schedule.validate(&cfg)?;
        let computes = match self.computes {
            Some(c) => c,
            None => self.problem.build_computes(self.engine, self.pool)?,
        };
        let t_count = self.problem.t();
        anyhow::ensure!(
            computes.len() == t_count,
            "need one compute per task ({} != {t_count})",
            computes.len()
        );
        Ok(Session {
            problem: self.problem,
            computes,
            cfg,
            schedule: self.schedule,
            transport: self.transport,
        })
    }
}

/// One configured optimization run: problem + computes + config + schedule
/// (+ the transport workers use to reach the server).
pub struct Session<'p> {
    problem: &'p MtlProblem,
    computes: Vec<Box<dyn TaskCompute>>,
    cfg: RunConfig,
    schedule: Box<dyn Schedule>,
    transport: TransportKind,
}

impl<'p> Session<'p> {
    /// Start configuring a run over `problem`.
    pub fn builder(problem: &'p MtlProblem) -> SessionBuilder<'p> {
        SessionBuilder::new(problem)
    }

    /// Execute the run under the configured schedule.
    pub fn run(mut self) -> Result<RunResult> {
        let problem = self.problem;
        let cfg = &self.cfg;
        let t_count = problem.t();

        // Shared construction (identical for every schedule — and for the
        // standalone serve process, via the same helper): state, server
        // with the problem's regularizer, recorder, step controller, and
        // the root RNG that forks one stream per task node.
        let (state, server, recorder) = cfg.build_server(problem)?;
        let controller = Arc::new(StepController::new(
            cfg.km,
            cfg.dynamic_step,
            t_count,
            cfg.dyn_window,
        ));

        // The TCP transport hosts a loopback server around this session's
        // central server; workers then reach it only through sockets. The
        // handle joins its threads on drop (including error paths).
        let (endpoint, mut tcp_handle) = match self.transport {
            TransportKind::InProc => (Endpoint::InProc, None),
            TransportKind::Tcp => {
                let handle = TcpServer::spawn("127.0.0.1:0", Arc::clone(&server), None)?;
                (Endpoint::Tcp(handle.addr()), Some(handle))
            }
        };

        let start = Instant::now();
        let mut orch = Orchestrator {
            problem,
            cfg,
            computes: &mut self.computes,
            server: Arc::clone(&server),
            state: Arc::clone(&state),
            endpoint,
            controller,
            recorder: Arc::clone(&recorder),
            // The root stream the per-node streams fork from. A durable
            // run persists it (stream 0), so a resumed run derives the
            // SAME worker streams as the original even if the resume
            // command line carries a different seed.
            root_rng: server
                .checkpointer()
                .and_then(|cp| cp.rng_stream(0))
                .map(Rng::from_state)
                .unwrap_or_else(|| Rng::new(cfg.seed)),
            forked: 0,
        };
        let stats = self.schedule.orchestrate(&mut orch)?;
        // Release the orchestrator's recorder clone so the trajectory can
        // be unwrapped below, and join the loopback server's threads.
        drop(orch);
        if let Some(handle) = tcp_handle.as_mut() {
            handle.shutdown();
        }
        // End-of-run barrier: everything the run traced is on disk before
        // the result is handed back (live tails and smoke jobs read here).
        if let Some(tr) = &cfg.trace {
            tr.flush();
        }
        let wall_time = start.elapsed();
        anyhow::ensure!(
            stats.len() == t_count,
            "schedule '{}' returned {} worker stats for {t_count} nodes",
            self.schedule.name(),
            stats.len()
        );

        // Shared result assembly.
        let v_final = state.snapshot();
        recorder.record_now(state.version(), v_final.clone());
        let w_final = server.final_w();
        let updates_per_node: Vec<u64> = stats.iter().map(|s| s.updates).collect();
        let total_updates: u64 = updates_per_node.iter().sum();
        let mean_delay_secs = if total_updates > 0 {
            stats.iter().map(|s| s.total_delay_secs).sum::<f64>() / total_updates as f64
        } else {
            0.0
        };
        let recorder = Arc::try_unwrap(recorder)
            .map_err(|_| anyhow::anyhow!("recorder still referenced"))?;
        let stale = server.staleness_snapshot();
        Ok(RunResult {
            method: self.schedule.name().into(),
            wall_time,
            v_final,
            w_final,
            updates: total_updates,
            updates_per_node,
            prox_count: server.prox_count(),
            coalesced_updates: server.coalesced_count(),
            svd_refreshes: server.svd_refresh_count(),
            trajectory: recorder.into_points(),
            mean_delay_secs,
            dropped_updates: stats.iter().map(|s| s.dropped).sum(),
            crashed_nodes: stats
                .iter()
                .enumerate()
                .filter(|(_, s)| s.crashed)
                .map(|(i, _)| i)
                .collect(),
            compute_secs: stats.iter().map(|s| s.compute_secs).sum(),
            backward_wait_secs: stats.iter().map(|s| s.backward_wait_secs).sum(),
            commit_wait_secs: stats.iter().map(|s| s.commit_wait_secs).sum(),
            mean_staleness: stale.mean(),
            staleness_p50: stale.quantile(0.5),
            staleness_p99: stale.quantile(0.99),
            staleness_max: stale.max,
            checkpoints_written: server.checkpoints_written(),
            wal_replayed: server.wal_replayed(),
            evicted_nodes: server.registry().map(|r| r.evicted_nodes()).unwrap_or_default(),
        })
    }
}

/// Where the session's workers find the central server: in this address
/// space, or behind a socket address.
enum Endpoint {
    InProc,
    Tcp(SocketAddr),
}

/// What a [`Schedule`] gets to orchestrate with: accessors for the shared
/// machinery plus the one worker-context construction path (RNG forking
/// included) used by every schedule.
pub struct Orchestrator<'r> {
    problem: &'r MtlProblem,
    cfg: &'r RunConfig,
    computes: &'r mut [Box<dyn TaskCompute>],
    server: Arc<CentralServer>,
    state: Arc<SharedState>,
    endpoint: Endpoint,
    controller: Arc<StepController>,
    recorder: Arc<Recorder>,
    root_rng: Rng,
    forked: usize,
}

impl<'r> Orchestrator<'r> {
    /// The problem under optimization.
    pub fn problem(&self) -> &'r MtlProblem {
        self.problem
    }

    /// The run configuration.
    pub fn cfg(&self) -> &'r RunConfig {
        self.cfg
    }

    /// Number of task nodes.
    pub fn t_count(&self) -> usize {
        self.computes.len()
    }

    /// The run's central server.
    pub fn server(&self) -> Arc<CentralServer> {
        Arc::clone(&self.server)
    }

    /// The shared KM step controller.
    pub fn controller(&self) -> Arc<StepController> {
        Arc::clone(&self.controller)
    }

    /// The run's trajectory recorder.
    pub fn recorder(&self) -> Arc<Recorder> {
        Arc::clone(&self.recorder)
    }

    /// The run's membership registry, when heartbeats are enabled
    /// (schedules hook eviction callbacks here).
    pub fn registry(&self) -> Option<Arc<NodeRegistry>> {
        self.server.registry().cloned()
    }

    /// A fresh channel to this run's central server: direct calls for the
    /// in-proc session, a new socket (own connection, own framing) for the
    /// TCP session. Schedules use this for commit paths that are not tied
    /// to one worker (e.g. the synchronized round loop).
    pub fn transport(&self) -> Result<Box<dyn Transport>> {
        match self.endpoint {
            Endpoint::InProc => Ok(Box::new(InProc::new(Arc::clone(&self.server)))),
            Endpoint::Tcp(addr) => Ok(Box::new(TcpClient::connect(addr, TcpOptions::default())?)),
        }
    }

    /// One worker context per task node, with per-node RNG streams forked
    /// deterministically in node order from the root seed and one
    /// transport per node. Call once — forking twice would hand later
    /// callers different streams.
    pub fn worker_ctxs(&mut self) -> Result<Vec<WorkerCtx>> {
        assert_eq!(self.forked, 0, "worker_ctxs may only be called once");
        self.forked = 1;
        (0..self.computes.len())
            .map(|t| {
                Ok(WorkerCtx {
                    t,
                    iters: self.cfg.iters_per_node,
                    transport: self.transport()?,
                    controller: Arc::clone(&self.controller),
                    delay: self.cfg.delay.clone(),
                    faults: self.cfg.faults.clone(),
                    sgd_fraction: self.cfg.sgd_fraction,
                    time_scale: self.cfg.time_scale,
                    sink: Some(TrajectorySink {
                        recorder: Arc::clone(&self.recorder),
                        state: Arc::clone(&self.state),
                    }),
                    rng: self.root_rng.fork(t as u64),
                    gate: None,
                    heartbeat: self.cfg.heartbeat,
                    resume: self.cfg.resume,
                    trace: self.cfg.trace.clone(),
                    // In-process workers share this registry; exporting it
                    // back to ourselves would just duplicate every row.
                    metrics_stride: None,
                })
            })
            .collect()
    }

    /// The per-task compute engines (index = task id).
    pub fn computes(&mut self) -> &mut [Box<dyn TaskCompute>] {
        self.computes
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::schedule::{SemiSync, Synchronized};
    use crate::data::synthetic;
    use crate::optim::prox::RegularizerKind;

    fn problem(seed: u64, t: usize, n: usize, d: usize) -> MtlProblem {
        let mut rng = Rng::new(seed);
        let ds = synthetic::lowrank_regression(&vec![n; t], d, 2, 0.05, &mut rng);
        MtlProblem::new(ds, RegularizerKind::Nuclear, 0.2, 0.5, &mut rng)
    }

    #[test]
    fn builder_defaults_run_async() {
        let p = problem(700, 3, 20, 5);
        let r = Session::builder(&p)
            .iters_per_node(4)
            .build()
            .unwrap()
            .run()
            .unwrap();
        assert_eq!(r.method, "amtl");
        assert_eq!(r.updates, 12);
        assert_eq!(r.updates_per_node, vec![4; 3]);
    }

    #[test]
    fn builder_rejects_mismatched_compute_count() {
        let p = problem(701, 3, 20, 5);
        let mut computes = p.build_computes(Engine::Native, None).unwrap();
        computes.pop();
        let err = Session::builder(&p).computes(computes).build().unwrap_err();
        assert!(format!("{err}").contains("one compute per task"), "{err}");
    }

    #[test]
    fn builder_rejects_bad_sgd_fraction() {
        let p = problem(702, 2, 20, 4);
        for bad in [0.0, -0.5, 1.5] {
            let err = Session::builder(&p)
                .sgd_fraction(Some(bad))
                .build()
                .unwrap_err();
            assert!(format!("{err}").contains("sgd_fraction"), "{bad}: {err}");
        }
    }

    #[test]
    fn builder_rejects_bad_eta_k() {
        let p = problem(703, 2, 20, 4);
        for bad in [0.0, -1.0, f64::NAN] {
            assert!(Session::builder(&p).eta_k(bad).build().is_err(), "{bad}");
        }
    }

    #[test]
    fn builder_rejects_bad_schedule_params() {
        let p = problem(704, 2, 20, 4);
        let err = Session::builder(&p)
            .schedule(SemiSync { staleness_bound: 0 })
            .build()
            .unwrap_err();
        assert!(format!("{err}").contains("staleness_bound"), "{err}");
    }

    #[test]
    fn builder_rejects_resvd_with_exact_svd() {
        // Contradictory-flag fix: an explicit refresh stride under the
        // exact backend used to pass silently and do nothing.
        let p = problem(707, 2, 10, 4);
        let err = Session::builder(&p)
            .svd(SvdMode::Exact)
            .resvd_every(32)
            .build()
            .unwrap_err();
        assert!(format!("{err}").contains("resvd_every"), "{err}");
        // The default stride and 0 (= never) are not contradictions.
        assert!(Session::builder(&p).svd(SvdMode::Exact).build().is_ok());
        assert!(Session::builder(&p).svd(SvdMode::Exact).resvd_every(0).build().is_ok());
    }

    #[test]
    fn builder_rejects_zero_checkpoint_stride() {
        let p = problem(708, 2, 10, 4);
        let err = Session::builder(&p).checkpoint_every(0).build().unwrap_err();
        assert!(format!("{err}").contains("checkpoint_every"), "{err}");
    }

    #[test]
    fn paper_offset_resolves_at_build_time_in_either_order() {
        // paper_offset before time_scale must still use the final scale.
        let p = problem(705, 2, 10, 4);
        let s = Session::builder(&p)
            .paper_offset(2.0)
            .time_scale(Duration::from_millis(10))
            .build()
            .unwrap();
        match s.cfg.delay {
            DelayModel::OffsetExp { offset, .. } => {
                assert_eq!(offset, Duration::from_millis(20));
            }
            ref other => panic!("expected OffsetExp, got {other:?}"),
        }
    }

    // (InProc-vs-Tcp session equivalence lives in
    // rust/tests/integration_transport.rs — bitwise on one task, within
    // tolerance under concurrency.)

    #[test]
    fn schedules_share_one_config_and_name_their_results() {
        let p = problem(706, 3, 25, 5);
        let cfg = RunConfig { iters_per_node: 5, ..Default::default() };
        for (name, run) in [
            ("amtl", Session::builder(&p).config(cfg.clone()).schedule(Async).build()),
            ("smtl", Session::builder(&p).config(cfg.clone()).schedule(Synchronized).build()),
            (
                "semisync",
                Session::builder(&p)
                    .config(cfg.clone())
                    .schedule(SemiSync { staleness_bound: 2 })
                    .build(),
            ),
        ] {
            let r = run.unwrap().run().unwrap();
            assert_eq!(r.method, name);
            assert_eq!(r.updates, 15, "{name}");
        }
    }
}
