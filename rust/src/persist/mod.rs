//! Durable checkpoint/recovery: snapshots + a commit write-ahead log.
//!
//! The paper's runtime premise is that *task nodes* are unreliable; this
//! module makes the **central server** survivable too. A
//! [`Checkpointer`] attached to a
//! [`CentralServer`](crate::coordinator::server::CentralServer) maintains
//! on disk:
//!
//! * **Snapshots** ([`ServerSnapshot`]) — a versioned, checksummed binary
//!   capture of the whole server: `V` with its version counters, the
//!   per-column commit dedup keys, pending column slots, the coupling
//!   formulation as a generic [`FormulationState`] (registry id + the
//!   opaque blob its `state_save` hook produced — incremental SVD basis,
//!   resvd counter, similarity graph, centroid cache, whatever the
//!   formulation keeps — so Online mode resumes without resetting its
//!   drift bound and *any* registered formulation persists), η, the
//!   metrics counters, and registered RNG streams. Format v2; v1 files
//!   (fixed-layout classic regularizer record) remain readable.
//! * **A WAL** ([`WalEntry`]) — every commit (and every uncached prox,
//!   whose fold order matters to the online factorization) between
//!   snapshots, fsync'd before the commit is acknowledged.
//!
//! Recovery ([`recover`]) loads the newest *valid* snapshot — falling
//! back to the previous one if the newest is damaged — replays the WAL
//! tail, and returns a server whose state is **bitwise identical** to an
//! uninterrupted sequential run (asserted in
//! `rust/tests/integration_persist.rs`). Killing the serving process with
//! SIGKILL mid-run and restarting it with `--resume` therefore continues
//! the optimization instead of losing it.
//!
//! Layout of a checkpoint directory (sequence numbers zero-padded so the
//! lexicographic order is the numeric order):
//!
//! ```text
//! checkpoints/
//!   snapshot-00000000000000000000.amtls   genesis (horizon 0)
//!   snapshot-00000000000000000273.amtls   latest (horizon 273)
//!   wal-00000000000000000274.amtlw        entries 274..
//! ```

pub mod codec;
pub mod snapshot;
pub mod wal;

pub use codec::PersistError;
pub use snapshot::{FormulationState, ServerSnapshot};
pub use wal::{WalEntry, WalScan, WalWriter};

use crate::coordinator::server::CentralServer;
use crate::obs::{self, Histogram, TraceWriter};
use crate::util::RngState;
use anyhow::Result;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex, RwLock, RwLockReadGuard};
use std::time::{Duration, Instant};

/// Durability knobs.
#[derive(Clone, Debug)]
pub struct PersistConfig {
    /// Directory holding snapshots and WALs (created if absent).
    pub dir: PathBuf,
    /// Commits between snapshot rotations (clamped to ≥ 1).
    pub snapshot_every: u64,
}

impl PersistConfig {
    /// A config over `dir` with the given snapshot stride.
    pub fn new(dir: impl Into<PathBuf>, snapshot_every: u64) -> PersistConfig {
        PersistConfig { dir: dir.into(), snapshot_every: snapshot_every.max(1) }
    }
}

/// Default commits-per-snapshot stride (the CLI's `--checkpoint-every`).
pub const DEFAULT_SNAPSHOT_EVERY: u64 = 256;

/// Global-registry handles for the durability paths, resolved once at
/// construction so the per-commit WAL path records lock-free.
struct CpObs {
    appends: Arc<AtomicU64>,
    append_us: Arc<Histogram>,
    fsync_us: Arc<Histogram>,
    writes: Arc<AtomicU64>,
    write_us: Arc<Histogram>,
}

impl CpObs {
    fn resolve() -> CpObs {
        let reg = obs::global();
        CpObs {
            appends: reg.counter("wal.appends"),
            append_us: reg.hist("wal.append_us"),
            fsync_us: reg.hist("wal.fsync_us"),
            writes: reg.counter("checkpoint.writes"),
            write_us: reg.hist("checkpoint.write_us"),
        }
    }
}

struct CpInner {
    wal: WalWriter,
    /// Sequence number the next logged operation will carry.
    next_seq: u64,
    /// Commits logged since the last snapshot rotation.
    commits_since_snapshot: u64,
    /// Horizon of the newest snapshot on disk.
    snapshot_seq: u64,
    /// Horizon of the snapshot before it (files older than this are
    /// pruned on rotation).
    prev_snapshot_seq: u64,
}

/// Durability driver for one central server: owns the WAL, rotates
/// snapshots, and quiesces commits while a snapshot is captured so the
/// snapshot's WAL horizon is exact.
pub struct Checkpointer {
    cfg: PersistConfig,
    /// Commit/prox paths hold the read side while mutating state and
    /// appending; snapshot capture holds the write side, so a snapshot
    /// never interleaves with a half-logged operation.
    gate: RwLock<()>,
    inner: Mutex<CpInner>,
    checkpoints: AtomicU64,
    rng_streams: Mutex<Vec<(u64, RngState)>>,
    /// Horizon of the newest snapshot, published outside `inner` so
    /// observers (the serve loop's rotation reporting, tests) can wait on
    /// rotations without contending with the WAL append path.
    rotation: Mutex<u64>,
    rotation_cv: Condvar,
    obs: CpObs,
    /// Trace sink for "checkpoint" events (set when the owning server has
    /// a [`TraceWriter`] attached).
    trace: Mutex<Option<Arc<TraceWriter>>>,
}

impl Checkpointer {
    /// Start fresh durability in `cfg.dir`, **claiming the directory**:
    /// snapshot/WAL files from any previous run in there are removed (use
    /// [`recover`] instead to continue one). The genesis snapshot is
    /// written when the checkpointer is attached to a server
    /// (`CentralServer::with_checkpointer`).
    pub fn create(cfg: PersistConfig) -> Result<Checkpointer> {
        std::fs::create_dir_all(&cfg.dir)?;
        for (_, path) in list_numbered(&cfg.dir, "snapshot-", ".amtls")? {
            std::fs::remove_file(path)?;
        }
        for (_, path) in list_numbered(&cfg.dir, "wal-", ".amtlw")? {
            std::fs::remove_file(path)?;
        }
        Checkpointer::open_at(cfg, 1)
    }

    /// A checkpointer whose next logged operation gets sequence number
    /// `next_seq` (recovery continues a directory this way).
    fn open_at(cfg: PersistConfig, next_seq: u64) -> Result<Checkpointer> {
        let wal = WalWriter::create(&wal_path(&cfg.dir, next_seq))?;
        Ok(Checkpointer {
            cfg,
            gate: RwLock::new(()),
            inner: Mutex::new(CpInner {
                wal,
                next_seq,
                commits_since_snapshot: 0,
                snapshot_seq: next_seq - 1,
                prev_snapshot_seq: next_seq - 1,
            }),
            checkpoints: AtomicU64::new(0),
            rng_streams: Mutex::new(Vec::new()),
            rotation: Mutex::new(next_seq - 1),
            rotation_cv: Condvar::new(),
            obs: CpObs::resolve(),
            trace: Mutex::new(None),
        })
    }

    /// Emit a "checkpoint" trace event for every snapshot rotation from
    /// now on (wired by `CentralServer::with_trace`).
    pub(crate) fn set_trace(&self, trace: Arc<TraceWriter>) {
        *self.trace.lock().unwrap() = Some(trace);
    }

    /// Horizon (last covered sequence number) of the newest snapshot this
    /// checkpointer has written — 0 until the first post-genesis rotation.
    pub fn snapshot_horizon(&self) -> u64 {
        *self.rotation.lock().unwrap()
    }

    /// Block until a snapshot with horizon greater than `after` has been
    /// written, or `timeout` elapses. Returns the newest snapshot horizon
    /// either way — callers compare it against `after` to tell a rotation
    /// from a timeout. This is how the serve loop (and replica-aware
    /// tooling) observes checkpoint rotations without polling the
    /// directory.
    pub fn wait_rotation(&self, after: u64, timeout: Duration) -> u64 {
        let deadline = Instant::now() + timeout;
        let mut horizon = self.rotation.lock().unwrap();
        while *horizon <= after {
            let left = deadline.saturating_duration_since(Instant::now());
            if left.is_zero() {
                break;
            }
            let (guard, _) = self.rotation_cv.wait_timeout(horizon, left).unwrap();
            horizon = guard;
        }
        *horizon
    }

    /// The checkpoint directory.
    pub fn dir(&self) -> &Path {
        &self.cfg.dir
    }

    /// Snapshots written by this checkpointer (genesis included).
    pub fn checkpoints_written(&self) -> u64 {
        self.checkpoints.load(Ordering::Relaxed)
    }

    /// Record an RNG stream to embed in every subsequent snapshot. The
    /// in-proc session stores its *root* stream as id 0 — the state its
    /// per-node streams are forked from — so a resumed run derives the
    /// same worker streams as the original, even under a different
    /// `--seed` on the resume command line.
    pub fn set_rng_stream(&self, id: u64, state: RngState) {
        let mut streams = self.rng_streams.lock().unwrap();
        if let Some(slot) = streams.iter_mut().find(|(i, _)| *i == id) {
            slot.1 = state;
        } else {
            streams.push((id, state));
        }
    }

    /// The stored state of RNG stream `id`, if one was recorded (recovery
    /// carries streams from the loaded snapshot into the new
    /// checkpointer, so this is how a resumed session reads them back).
    pub fn rng_stream(&self, id: u64) -> Option<RngState> {
        self.rng_streams.lock().unwrap().iter().find(|(i, _)| *i == id).map(|(_, s)| *s)
    }

    /// The quiesce gate's read side — held by the server around every
    /// state mutation + WAL append pair.
    pub(crate) fn commit_gate(&self) -> RwLockReadGuard<'_, ()> {
        self.gate.read().unwrap()
    }

    /// The quiesce gate's **write** side: blocks until every in-flight
    /// commit/prox finishes and holds off new ones until dropped. This is
    /// the same exclusion `checkpoint_now` uses internally; the sharded
    /// coordination round takes it directly to gather a consistent slice
    /// of a shard ([`shard`](crate::shard)) without writing a snapshot.
    pub fn quiesce(&self) -> std::sync::RwLockWriteGuard<'_, ()> {
        self.gate.write().unwrap()
    }

    /// Append one commit (WAL discipline: callers log *before* applying)
    /// and fsync it, so an acknowledged update is never lost.
    pub(crate) fn log_commit(&self, t: usize, k: u64, step: f64, u: &[f64]) -> Result<()> {
        let started = Instant::now();
        let mut inner = self.inner.lock().unwrap();
        let seq = inner.next_seq;
        inner.next_seq += 1;
        inner.commits_since_snapshot += 1;
        let entry = WalEntry::Commit { seq, t: t as u32, k, step, u: u.to_vec() };
        inner.wal.append(&entry)?;
        let pre_sync = Instant::now();
        inner.wal.sync()?;
        drop(inner);
        self.obs.fsync_us.record(pre_sync.elapsed().as_micros() as u64);
        self.obs.append_us.record(started.elapsed().as_micros() as u64);
        self.obs.appends.fetch_add(1, Ordering::Relaxed);
        Ok(())
    }

    /// Append a prox marker (uncached backward step: the fold order it
    /// fixes is what makes online-SVD recovery bitwise).
    pub(crate) fn log_prox(&self) -> Result<()> {
        let started = Instant::now();
        let mut inner = self.inner.lock().unwrap();
        let seq = inner.next_seq;
        inner.next_seq += 1;
        inner.wal.append(&WalEntry::Prox { seq })?;
        let pre_sync = Instant::now();
        inner.wal.sync()?;
        drop(inner);
        self.obs.fsync_us.record(pre_sync.elapsed().as_micros() as u64);
        self.obs.append_us.record(started.elapsed().as_micros() as u64);
        self.obs.appends.fetch_add(1, Ordering::Relaxed);
        Ok(())
    }

    /// fsync any buffered WAL writes (the `Shutdown` handler calls this
    /// before acknowledging, so a polite teardown loses nothing).
    pub fn sync(&self) -> Result<()> {
        self.inner.lock().unwrap().wal.sync()?;
        Ok(())
    }

    /// Rotate a snapshot if the commit stride is due.
    pub(crate) fn maybe_snapshot(&self, server: &CentralServer) -> Result<()> {
        let due =
            self.inner.lock().unwrap().commits_since_snapshot >= self.cfg.snapshot_every;
        if due {
            self.checkpoint_now(server)?;
        }
        Ok(())
    }

    /// Quiesce commits and write a snapshot + WAL rotation immediately.
    pub fn checkpoint_now(&self, server: &CentralServer) -> Result<()> {
        let started = Instant::now();
        let _quiesced = self.gate.write().unwrap();
        let mut inner = self.inner.lock().unwrap();
        let horizon = inner.next_seq - 1;
        let rng_streams = self.rng_streams.lock().unwrap().clone();
        let snap = server.capture_snapshot(horizon, rng_streams);
        snap.write_file(&snapshot_path(&self.cfg.dir, horizon))?;
        // Rotate: new WAL starting at the next sequence number. (When the
        // horizon has not moved — e.g. a forced checkpoint right after a
        // rotation — the WAL path is unchanged and recreated empty, which
        // is exactly its current state.)
        inner.wal = WalWriter::create(&wal_path(&self.cfg.dir, inner.next_seq))?;
        inner.prev_snapshot_seq = inner.snapshot_seq;
        inner.snapshot_seq = horizon;
        inner.commits_since_snapshot = 0;
        // Keep the latest two snapshots (corruption fallback) plus every
        // WAL needed to roll forward from the older of them. A WAL file
        // starting at `s` only holds entries up to the snapshot whose
        // rotation retired it, so `start ≤ fallback horizon` ⇒ obsolete.
        let fallback = inner.prev_snapshot_seq;
        drop(inner);
        self.checkpoints.fetch_add(1, Ordering::Relaxed);
        {
            let mut rot = self.rotation.lock().unwrap();
            *rot = horizon;
            self.rotation_cv.notify_all();
        }
        for (seq, path) in list_numbered(&self.cfg.dir, "snapshot-", ".amtls")? {
            if seq < fallback {
                let _ = std::fs::remove_file(path);
            }
        }
        for (start, path) in list_numbered(&self.cfg.dir, "wal-", ".amtlw")? {
            if start <= fallback {
                let _ = std::fs::remove_file(path);
            }
        }
        self.obs.writes.fetch_add(1, Ordering::Relaxed);
        self.obs.write_us.record(started.elapsed().as_micros() as u64);
        if let Some(tr) = &*self.trace.lock().unwrap() {
            tr.event("checkpoint", None, None, Some(horizon), &[]);
        }
        Ok(())
    }
}

/// What [`recover`] rebuilds from a checkpoint directory.
pub struct Recovered {
    /// The rebuilt central server, checkpointer re-attached (durability
    /// continues seamlessly: a fresh snapshot at the recovered horizon is
    /// written as part of recovery).
    pub server: CentralServer,
    /// WAL entries replayed on top of the loaded snapshot.
    pub wal_replayed: u64,
    /// RNG streams stored in the snapshot (id → state).
    pub rng_streams: Vec<(u64, RngState)>,
}

/// True when `dir` holds at least one snapshot file (i.e. [`recover`] has
/// something to work with).
pub fn has_checkpoint(dir: &Path) -> bool {
    list_numbered(dir, "snapshot-", ".amtls").map(|v| !v.is_empty()).unwrap_or(false)
}

/// `(horizon, path)` for every snapshot file in `dir`, ascending — part
/// of the tail-reader API a read replica uses to follow a live
/// checkpoint directory.
pub fn list_snapshot_files(dir: &Path) -> Result<Vec<(u64, PathBuf)>> {
    list_numbered(dir, "snapshot-", ".amtls")
}

/// `(start_seq, path)` for every WAL file in `dir`, ascending by the
/// sequence number of the first entry each file may hold.
pub fn list_wal_files(dir: &Path) -> Result<Vec<(u64, PathBuf)>> {
    list_numbered(dir, "wal-", ".amtlw")
}

/// Load the newest snapshot in `dir` that validates, falling back across
/// damaged or misnamed files exactly like [`recover`] does. `Ok(None)`
/// when the directory has no usable snapshot (empty, or every file
/// damaged) — a tailer treats that as "trainer not up yet" and retries.
pub fn newest_valid_snapshot(dir: &Path) -> Result<Option<ServerSnapshot>> {
    let mut snapshots = list_numbered(dir, "snapshot-", ".amtls")?;
    snapshots.reverse(); // newest first
    for (seq, path) in &snapshots {
        match ServerSnapshot::read_file(path) {
            // A snapshot whose internal horizon disagrees with its name
            // (renamed, or copied from another directory) is as unusable
            // as a corrupt one: fall back rather than abort.
            Ok(s) if s.seq != *seq => {
                crate::log_warn!(
                    "persist",
                    "snapshot {} claims horizon {} but is named {seq}; skipping",
                    path.display(),
                    s.seq
                );
            }
            Ok(s) => return Ok(Some(s)),
            Err(e) => {
                crate::log_warn!(
                    "persist",
                    "snapshot {} is unreadable ({e}); falling back",
                    path.display()
                );
            }
        }
    }
    Ok(None)
}

/// Rebuild a central server from `cfg.dir`: load the newest snapshot that
/// validates (falling back across damaged ones), replay the WAL tail in
/// sequence order — stopping at the first gap or torn record — and
/// re-attach a checkpointer so the resumed run stays durable.
pub fn recover(cfg: PersistConfig) -> Result<Recovered> {
    anyhow::ensure!(
        has_checkpoint(&cfg.dir),
        "no snapshot found in {} — nothing to resume",
        cfg.dir.display()
    );
    let snap = newest_valid_snapshot(&cfg.dir)?
        .ok_or_else(|| anyhow::anyhow!("every snapshot in the directory is damaged"))?;

    // Gather WAL entries past the snapshot's horizon, in sequence order.
    // Files are scanned in start order; a torn tail ends that file's
    // contribution, and a sequence gap ends the whole replay (entries
    // beyond a gap are causally unsafe).
    let server = CentralServer::from_snapshot(&snap)?;
    let (d, t_count) = (server.state().d(), server.state().t());
    let mut expected = snap.seq + 1;
    let mut replayed = 0u64;
    'files: for (_, path) in list_numbered(&cfg.dir, "wal-", ".amtlw")? {
        let scan = wal::read_wal(&path)?;
        for entry in &scan.entries {
            let seq = entry.seq();
            if seq <= snap.seq {
                continue;
            }
            if seq != expected {
                break 'files;
            }
            if let WalEntry::Commit { t, u, .. } = entry {
                anyhow::ensure!(
                    (*t as usize) < t_count && u.len() == d,
                    "wal commit entry does not fit the snapshot's dimensions"
                );
            }
            server.replay_entry(entry);
            expected += 1;
            replayed += 1;
        }
        if scan.torn_tail {
            break 'files;
        }
    }
    server.note_wal_replayed(replayed);

    // Continue durability from the recovered horizon: fresh snapshot,
    // fresh WAL, old files pruned down to the fallback pair.
    let cp = std::sync::Arc::new(Checkpointer::open_at(cfg, expected)?);
    for (id, st) in &snap.rng_streams {
        cp.set_rng_stream(*id, *st);
    }
    let server = server.with_checkpointer(std::sync::Arc::clone(&cp))?;
    Ok(Recovered { server, wal_replayed: replayed, rng_streams: snap.rng_streams })
}

fn snapshot_path(dir: &Path, seq: u64) -> PathBuf {
    dir.join(format!("snapshot-{seq:020}.amtls"))
}

fn wal_path(dir: &Path, start_seq: u64) -> PathBuf {
    dir.join(format!("wal-{start_seq:020}.amtlw"))
}

/// `(number, path)` pairs for `<prefix><n><ext>` files in `dir`, sorted
/// ascending by `n`. Unparseable names are ignored.
fn list_numbered(dir: &Path, prefix: &str, ext: &str) -> Result<Vec<(u64, PathBuf)>> {
    let mut out = Vec::new();
    let entries = match std::fs::read_dir(dir) {
        Ok(e) => e,
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(out),
        Err(e) => return Err(e.into()),
    };
    for entry in entries {
        let entry = entry?;
        let name = entry.file_name();
        let Some(name) = name.to_str() else { continue };
        let Some(body) = name.strip_prefix(prefix).and_then(|s| s.strip_suffix(ext)) else {
            continue;
        };
        if let Ok(n) = body.parse::<u64>() {
            out.push((n, entry.path()));
        }
    }
    out.sort_by_key(|(n, _)| *n);
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::state::SharedState;
    use crate::optim::prox::NuclearProx;
    use crate::optim::SharedProx;
    use crate::util::Rng;
    use std::sync::Arc;

    fn tmp_dir(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("amtl_persist_{}_{name}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        dir
    }

    fn durable_server(dir: &Path, every: u64, online: bool, d: usize, t: usize) -> Arc<CentralServer> {
        let mut rng = Rng::new(5150);
        let m = crate::linalg::Mat::randn(d, t, &mut rng);
        let state = Arc::new(SharedState::new(&m));
        let mut reg = NuclearProx::new(0.3);
        if online {
            reg = reg.with_online(&m).with_resvd_every(5);
        }
        let reg: Box<dyn SharedProx> = Box::new(reg);
        let cp = Arc::new(
            Checkpointer::create(PersistConfig::new(dir, every)).unwrap(),
        );
        Arc::new(
            CentralServer::new(state, reg, 0.2)
                .with_checkpointer(cp)
                .unwrap(),
        )
    }

    /// Drive `n` sequential commit/prox rounds (deterministic sequence);
    /// `k0` offsets each node's activation counter so a continued run's
    /// commits are not deduplicated away as resends.
    fn drive(srv: &CentralServer, n: usize, t_count: usize, seed: u64, k0: u64) {
        let mut rng = Rng::new(seed);
        let d = srv.state().d();
        for i in 0..n {
            let t = i % t_count;
            let u = rng.normal_vec(d);
            srv.commit_update(t, k0 + (i / t_count) as u64, &u, 0.6).unwrap();
            let _ = srv.prox_matrix();
        }
    }

    #[test]
    fn genesis_snapshot_written_on_attach() {
        let dir = tmp_dir("genesis");
        let srv = durable_server(&dir, 100, false, 4, 2);
        assert!(has_checkpoint(&dir));
        assert_eq!(srv.checkpoints_written(), 1);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn rotation_notification_tracks_checkpoints() {
        let dir = tmp_dir("rotation");
        let srv = durable_server(&dir, 4, false, 4, 2);
        let cp = Arc::clone(srv.checkpointer().unwrap());
        assert_eq!(cp.snapshot_horizon(), 0, "genesis snapshot is horizon 0");
        // No rotation pending: the wait times out and reports the horizon.
        assert_eq!(cp.wait_rotation(0, Duration::from_millis(20)), 0);
        // Cross the stride while a waiter blocks: it must be released by
        // the rotation, not by its (long) timeout.
        let waiter = {
            let cp = Arc::clone(&cp);
            std::thread::spawn(move || cp.wait_rotation(0, Duration::from_secs(30)))
        };
        drive(&srv, 9, 2, 902, 0);
        let seen = waiter.join().unwrap();
        assert!(seen > 0, "waiter released by a real rotation (saw {seen})");
        assert!(cp.snapshot_horizon() >= seen);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn recovery_is_bitwise_identical_exact_mode() {
        let dir = tmp_dir("bitwise_exact");
        let srv = durable_server(&dir, 7, false, 5, 3);
        drive(&srv, 23, 3, 900, 0);
        let live_v = srv.state().snapshot();
        let live_w = srv.final_w();

        let rec = recover(PersistConfig::new(&dir, 7)).unwrap();
        assert_eq!(rec.server.state().snapshot(), live_v, "V must recover bitwise");
        assert_eq!(rec.server.final_w(), live_w, "W must recover bitwise");
        assert_eq!(rec.server.state().version(), srv.state().version());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn recovery_is_bitwise_identical_online_mode() {
        // The prox markers preserve the fold history, so even the
        // incremental factorization's numerical state recovers exactly.
        let dir = tmp_dir("bitwise_online");
        let srv = durable_server(&dir, 6, true, 6, 3);
        drive(&srv, 20, 3, 901, 0);
        let live_w = srv.final_w();
        let live_refreshes = srv.svd_refresh_count();

        let rec = recover(PersistConfig::new(&dir, 6)).unwrap();
        assert_eq!(rec.server.svd_refresh_count(), live_refreshes);
        assert_eq!(rec.server.final_w(), live_w, "online W must recover bitwise");
        assert!(rec.wal_replayed > 0, "some tail must have replayed");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn corrupt_latest_snapshot_falls_back_to_previous() {
        let dir = tmp_dir("fallback");
        let srv = durable_server(&dir, 4, false, 4, 2);
        drive(&srv, 17, 2, 902, 0);
        let live_v = srv.state().snapshot();

        // Damage the newest snapshot; recovery must use the previous one
        // plus a longer WAL replay and land on the same state.
        let mut snaps = list_numbered(&dir, "snapshot-", ".amtls").unwrap();
        let (_, newest) = snaps.pop().unwrap();
        let mut bytes = std::fs::read(&newest).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0xFF;
        std::fs::write(&newest, &bytes).unwrap();

        let rec = recover(PersistConfig::new(&dir, 4)).unwrap();
        assert_eq!(rec.server.state().snapshot(), live_v);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn torn_wal_tail_recovers_prefix() {
        let dir = tmp_dir("torn");
        let srv = durable_server(&dir, 1000, false, 4, 2);
        drive(&srv, 6, 2, 903, 0);
        // Tear the live WAL mid-record: recovery must replay the intact
        // prefix and come up at some earlier-but-valid version.
        let wals = list_numbered(&dir, "wal-", ".amtlw").unwrap();
        let (_, wal) = wals.last().unwrap();
        let bytes = std::fs::read(wal).unwrap();
        std::fs::write(wal, &bytes[..bytes.len() - 5]).unwrap();

        let rec = recover(PersistConfig::new(&dir, 1000)).unwrap();
        let v = rec.server.state().version();
        assert!(v >= 5 && v < 6 + 1, "prefix recovered, torn tail dropped (got {v})");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn recovery_continues_durably() {
        // Recover, keep committing, recover again: the second recovery
        // must see the post-resume commits.
        let dir = tmp_dir("continue");
        let srv = durable_server(&dir, 5, false, 4, 2);
        drive(&srv, 8, 2, 904, 0);
        drop(srv);

        let rec = recover(PersistConfig::new(&dir, 5)).unwrap();
        let srv2 = Arc::new(rec.server);
        drive(&srv2, 6, 2, 905, 4);
        let live_v = srv2.state().snapshot();

        let rec2 = recover(PersistConfig::new(&dir, 5)).unwrap();
        assert_eq!(rec2.server.state().snapshot(), live_v);
        assert_eq!(rec2.server.state().version(), 14);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn empty_dir_refuses_to_resume() {
        let dir = tmp_dir("empty");
        std::fs::create_dir_all(&dir).unwrap();
        assert!(!has_checkpoint(&dir));
        assert!(recover(PersistConfig::new(&dir, 10)).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}
