//! The snapshot format: one durable file capturing
//! [`CentralServer`](crate::coordinator::server::CentralServer) state end
//! to end.
//!
//! A snapshot holds everything recovery needs to rebuild the server at an
//! exact WAL horizon (`seq`): the shared matrix `V` with its version
//! counters, the per-column commit-dedup keys, the pending column slots,
//! the full coupling formulation — as a [`FormulationState`]: the
//! registry id plus the opaque blob its
//! [`state_save`](crate::optim::formulation::SharedProx::state_save) hook
//! produced, so *any* registered formulation (incremental basis, resvd
//! stride counter, similarity graph, centroid cache, …) persists without
//! the codec knowing its internals — the run constants (η, prox stride),
//! the server metrics counters, and any registered RNG streams.
//!
//! Format **v2** introduced the generic formulation record. **v1** files
//! (fixed-layout nuclear/ℓ2,1/ℓ1/elastic-net/none regularizer record +
//! separate factor records) are still readable: the decoder maps the
//! legacy layout onto the same [`FormulationState`] the v2 impls expect,
//! so a pre-redesign checkpoint resumes under the trait-based server.
//!
//! Files are written atomically (temp file + fsync + rename) and every
//! record is checksummed; a damaged snapshot reads as an error and
//! recovery falls back to the previous one.

use super::codec::{
    read_header, read_record, write_header, write_record, PersistError, SNAPSHOT_MAGIC,
};
use crate::linalg::Mat;
use crate::optim::prox::{ElasticNetProx, L1Prox, L21Prox, NuclearProx, ZeroProx};
use crate::optim::SharedProx;
use crate::transport::wire::{push_f64s, Cursor};
use crate::util::RngState;
use std::fs::File;
use std::io::{BufReader, BufWriter, Read, Write};
use std::path::Path;

const TAG_META: u8 = 0x01;
const TAG_COL_VERSIONS: u8 = 0x02;
const TAG_APPLIED: u8 = 0x03;
const TAG_COLUMN: u8 = 0x04;
const TAG_PENDING: u8 = 0x05;
const TAG_REG: u8 = 0x06;
/// v1-only: online-SVD factor matrices (v2 folds them into the blob).
const TAG_FACTOR: u8 = 0x07;
/// v1-only: online-SVD singular values.
const TAG_SIGMA: u8 = 0x08;
const TAG_RNG: u8 = 0x09;
const TAG_END: u8 = 0x7E;

/// Max formulation-state bytes per TAG_REG record. Large state (a
/// similarity graph over thousands of tasks, a big SVD basis) is split
/// across continuation records (`id_len = 0`) so no single record ever
/// approaches the reader's `MAX_RECORD` bound.
const REG_CHUNK: usize = 1 << 22;

/// A formulation's persist identity: its registry id (see
/// [`SharedProx::id`]) and the opaque state blob its `state_save` hook
/// produced. Recovery hands both to
/// [`formulation::restore`](crate::optim::formulation::restore).
#[derive(Clone, Debug, PartialEq)]
pub struct FormulationState {
    /// Canonical formulation name (registry key).
    pub id: String,
    /// Opaque state bytes, as produced by `state_save`.
    pub blob: Vec<u8>,
}

/// A complete, consistent capture of central-server state at WAL horizon
/// `seq` (every operation with sequence number ≤ `seq` is inside it).
#[derive(Clone, Debug, PartialEq)]
pub struct ServerSnapshot {
    /// WAL horizon: replay skips entries with `seq` ≤ this.
    pub seq: u64,
    /// Prox step size η (a run constant).
    pub eta: f64,
    /// Server re-prox stride.
    pub prox_every: u64,
    /// Global KM version (total updates applied).
    pub version: u64,
    /// Per-column update counters.
    pub col_versions: Vec<u64>,
    /// Per-column commit dedup keys (0 = none applied, else `k + 1`).
    pub applied_k: Vec<u64>,
    /// The shared auxiliary matrix `V`.
    pub v: Mat,
    /// Per-column pending slots awaiting their incremental fold.
    pub pending: Vec<Option<Vec<f64>>>,
    /// Proximal computations performed.
    pub prox_count: u64,
    /// Same-column commits coalesced before folding.
    pub coalesced: u64,
    /// Raw commits not yet handed to the refresh-stride counter.
    pub uncounted_commits: u64,
    /// The coupling formulation, by registry id + opaque state.
    pub reg: FormulationState,
    /// Named RNG streams (id → exact generator state); which streams are
    /// stored is the embedding run's choice. The in-proc session stores
    /// its *root* stream as id 0 — the state worker streams fork from —
    /// so a resumed run reproduces the original run's per-node streams
    /// regardless of the seed on the resume command line.
    pub rng_streams: Vec<(u64, RngState)>,
}

fn push_u64s(out: &mut Vec<u8>, xs: &[u64]) {
    out.reserve(xs.len() * 8);
    for x in xs {
        out.extend_from_slice(&x.to_le_bytes());
    }
}

fn mat_payload(which: u8, m: &Mat) -> Vec<u8> {
    let mut out = Vec::with_capacity(9 + m.rows() * m.cols() * 8);
    out.push(which);
    out.extend_from_slice(&(m.rows() as u32).to_le_bytes());
    out.extend_from_slice(&(m.cols() as u32).to_le_bytes());
    push_f64s(&mut out, m.data());
    out
}

fn mat_from_payload(payload: &[u8]) -> Result<(u8, Mat), PersistError> {
    let mut c = Cursor::new(payload);
    let which = c.u8()?;
    let rows = c.u32()? as usize;
    let cols = c.u32()? as usize;
    let data = c.rest_f64s()?;
    c.finish()?;
    if data.len() != rows * cols {
        return Err(PersistError::Malformed("factor data does not match its dimensions"));
    }
    let mut m = Mat::zeros(rows, cols);
    m.data_mut().copy_from_slice(&data);
    Ok((which, m))
}

/// The v1 fixed-layout regularizer record, held until the decode loop has
/// also collected the factor records it may reference.
struct V1Reg {
    id: &'static str,
    lambda: f64,
    gamma: f64,
    resvd_every: u64,
    commits_since_refresh: u64,
    refreshes: u64,
    last_drift: f64,
    online_expected: bool,
}

/// Map a v1 kind code to the formulation registry id.
fn v1_kind_id(code: u8) -> Result<&'static str, PersistError> {
    Ok(match code {
        0 => "nuclear",
        1 => "l21",
        2 => "l1",
        3 => "elasticnet",
        4 => "none",
        _ => return Err(PersistError::Malformed("unknown regularizer kind code")),
    })
}

/// Assemble the v2 state blob a v1 record stands for, through the same
/// impls `state_save` uses — the two encodings cannot drift apart.
fn v1_reg_to_state(
    legacy: V1Reg,
    factors: Option<(Mat, Vec<f64>, Mat)>,
) -> Result<FormulationState, PersistError> {
    let blob = match legacy.id {
        "nuclear" => NuclearProx::encode_state_parts(
            legacy.lambda,
            legacy.resvd_every,
            legacy.commits_since_refresh,
            legacy.refreshes,
            legacy.last_drift,
            factors.as_ref().map(|(u, s, v)| (u, s.as_slice(), v)),
        ),
        "l21" => L21Prox::new(legacy.lambda).state_save(),
        "l1" => L1Prox::new(legacy.lambda).state_save(),
        "elasticnet" => ElasticNetProx::new(legacy.lambda, legacy.gamma).state_save(),
        "none" => ZeroProx::new(legacy.lambda).state_save(),
        _ => return Err(PersistError::Malformed("v1 kind outside the classic set")),
    };
    Ok(FormulationState { id: legacy.id.to_string(), blob })
}

impl ServerSnapshot {
    /// Serialize to `w` (header + records + end marker), always in the
    /// current format version.
    pub fn encode(&self, w: &mut impl Write) -> Result<(), PersistError> {
        let d = self.v.rows();
        let t = self.v.cols();
        write_header(w, SNAPSHOT_MAGIC)?;

        let mut meta = Vec::with_capacity(64);
        push_u64s(&mut meta, &[self.seq]);
        meta.extend_from_slice(&(d as u32).to_le_bytes());
        meta.extend_from_slice(&(t as u32).to_le_bytes());
        meta.extend_from_slice(&self.eta.to_bits().to_le_bytes());
        push_u64s(
            &mut meta,
            &[self.prox_every, self.version, self.prox_count, self.coalesced, self.uncounted_commits],
        );
        write_record(w, TAG_META, &meta)?;

        let mut vers = Vec::new();
        push_u64s(&mut vers, &self.col_versions);
        write_record(w, TAG_COL_VERSIONS, &vers)?;

        let mut applied = Vec::new();
        push_u64s(&mut applied, &self.applied_k);
        write_record(w, TAG_APPLIED, &applied)?;

        for c in 0..t {
            let mut payload = Vec::with_capacity(4 + d * 8);
            payload.extend_from_slice(&(c as u32).to_le_bytes());
            push_f64s(&mut payload, self.v.col(c));
            write_record(w, TAG_COLUMN, &payload)?;
        }
        for (c, slot) in self.pending.iter().enumerate() {
            if let Some(col) = slot {
                let mut payload = Vec::with_capacity(4 + col.len() * 8);
                payload.extend_from_slice(&(c as u32).to_le_bytes());
                push_f64s(&mut payload, col);
                write_record(w, TAG_PENDING, &payload)?;
            }
        }

        // v2 formulation record: id (length-prefixed) + opaque state
        // blob, chunked across continuation records when large.
        let id = self.reg.id.as_bytes();
        if id.is_empty() || id.len() > u8::MAX as usize {
            return Err(PersistError::Malformed("formulation id must be 1..=255 bytes"));
        }
        let mut first = true;
        let mut off = 0;
        loop {
            let end = (off + REG_CHUNK).min(self.reg.blob.len());
            let chunk = &self.reg.blob[off..end];
            let mut payload = Vec::with_capacity(1 + id.len() + chunk.len());
            if first {
                payload.push(id.len() as u8);
                payload.extend_from_slice(id);
            } else {
                payload.push(0);
            }
            payload.extend_from_slice(chunk);
            write_record(w, TAG_REG, &payload)?;
            first = false;
            off = end;
            if off >= self.reg.blob.len() {
                break;
            }
        }

        for (id, st) in &self.rng_streams {
            let mut payload = Vec::with_capacity(49);
            push_u64s(&mut payload, &[*id]);
            push_u64s(&mut payload, &st.s);
            match st.spare {
                None => payload.push(0),
                Some(x) => {
                    payload.push(1);
                    payload.extend_from_slice(&x.to_bits().to_le_bytes());
                }
            }
            write_record(w, TAG_RNG, &payload)?;
        }

        write_record(w, TAG_END, &[])?;
        Ok(())
    }

    /// Decode from `r`, validating structure as well as checksums: all
    /// columns present, dedup/version vectors sized `T`, an explicit end
    /// marker (so a truncated snapshot can never read as a shorter valid
    /// one), and — branching on the header version — either the v2
    /// formulation record or the v1 fixed regularizer layout (mapped onto
    /// the same [`FormulationState`]).
    pub fn decode(r: &mut impl Read) -> Result<ServerSnapshot, PersistError> {
        let file_version = read_header(r, SNAPSHOT_MAGIC)?;
        let (tag, meta) = read_record(r)?.ok_or(PersistError::Truncated)?;
        if tag != TAG_META {
            return Err(PersistError::Malformed("snapshot must start with its meta record"));
        }
        let mut c = Cursor::new(&meta);
        let seq = c.u64()?;
        let d = c.u32()? as usize;
        let t = c.u32()? as usize;
        let eta = c.f64()?;
        let prox_every = c.u64()?;
        let version = c.u64()?;
        let prox_count = c.u64()?;
        let coalesced = c.u64()?;
        let uncounted_commits = c.u64()?;
        c.finish()?;

        let mut col_versions: Option<Vec<u64>> = None;
        let mut applied_k: Option<Vec<u64>> = None;
        let mut v = Mat::zeros(d, t);
        let mut seen_cols = vec![false; t];
        let mut pending: Vec<Option<Vec<f64>>> = vec![None; t];
        let mut reg: Option<FormulationState> = None;
        let mut v1_reg: Option<V1Reg> = None;
        let mut fac_u: Option<Mat> = None;
        let mut fac_v: Option<Mat> = None;
        let mut sigma: Option<Vec<f64>> = None;
        let mut rng_streams = Vec::new();
        let mut ended = false;

        while let Some((tag, payload)) = read_record(r)? {
            let mut c = Cursor::new(&payload);
            match tag {
                TAG_COL_VERSIONS => {
                    let xs = read_u64s(&mut c, t)?;
                    c.finish()?;
                    col_versions = Some(xs);
                }
                TAG_APPLIED => {
                    let xs = read_u64s(&mut c, t)?;
                    c.finish()?;
                    applied_k = Some(xs);
                }
                TAG_COLUMN | TAG_PENDING => {
                    let idx = c.u32()? as usize;
                    let col = c.rest_f64s()?;
                    c.finish()?;
                    if idx >= t || col.len() != d {
                        return Err(PersistError::Malformed("column record out of shape"));
                    }
                    if tag == TAG_COLUMN {
                        v.set_col(idx, &col);
                        seen_cols[idx] = true;
                    } else {
                        pending[idx] = Some(col);
                    }
                }
                TAG_REG if file_version >= 2 => {
                    let id_len = c.u8()? as usize;
                    if id_len == 0 {
                        // Continuation chunk of a large state blob.
                        let state = reg.as_mut().ok_or(PersistError::Malformed(
                            "formulation continuation before its header record",
                        ))?;
                        state.blob.extend_from_slice(c.take_rest());
                    } else {
                        if reg.is_some() {
                            return Err(PersistError::Malformed(
                                "duplicate formulation record",
                            ));
                        }
                        let id_bytes = c.take(id_len)?;
                        let id = std::str::from_utf8(id_bytes)
                            .map_err(|_| {
                                PersistError::Malformed("formulation id not utf-8")
                            })?
                            .to_string();
                        let blob = c.take_rest().to_vec();
                        reg = Some(FormulationState { id, blob });
                    }
                }
                TAG_REG => {
                    // v1 fixed layout: kind code + λ/γ + resvd counters +
                    // drift + online flag (factors follow separately).
                    let id = v1_kind_id(c.u8()?)?;
                    let lambda = c.f64()?;
                    let gamma = c.f64()?;
                    let resvd_every = c.u64()?;
                    let commits_since_refresh = c.u64()?;
                    let refreshes = c.u64()?;
                    let last_drift = c.f64()?;
                    let online_expected = match c.u8()? {
                        0 => false,
                        1 => true,
                        _ => return Err(PersistError::Malformed("online flag not 0/1")),
                    };
                    c.finish()?;
                    v1_reg = Some(V1Reg {
                        id,
                        lambda,
                        gamma,
                        resvd_every,
                        commits_since_refresh,
                        refreshes,
                        last_drift,
                        online_expected,
                    });
                }
                TAG_FACTOR => {
                    if file_version >= 2 {
                        return Err(PersistError::Malformed(
                            "factor records are v1-only (v2 stores factors in the blob)",
                        ));
                    }
                    let (which, m) = mat_from_payload(&payload)?;
                    match which {
                        0 => fac_u = Some(m),
                        1 => fac_v = Some(m),
                        _ => return Err(PersistError::Malformed("factor selector not U/V")),
                    }
                }
                TAG_SIGMA => {
                    if file_version >= 2 {
                        return Err(PersistError::Malformed(
                            "sigma records are v1-only (v2 stores factors in the blob)",
                        ));
                    }
                    let xs = c.rest_f64s()?;
                    c.finish()?;
                    sigma = Some(xs);
                }
                TAG_RNG => {
                    let id = c.u64()?;
                    let s = [c.u64()?, c.u64()?, c.u64()?, c.u64()?];
                    let spare = match c.u8()? {
                        0 => None,
                        1 => Some(c.f64()?),
                        _ => return Err(PersistError::Malformed("rng spare flag not 0/1")),
                    };
                    c.finish()?;
                    rng_streams.push((id, RngState { s, spare }));
                }
                TAG_END => {
                    c.finish()?;
                    ended = true;
                    break;
                }
                other => return Err(PersistError::BadTag(other)),
            }
        }

        if !ended {
            return Err(PersistError::Truncated);
        }
        if !seen_cols.iter().all(|&s| s) {
            return Err(PersistError::Malformed("snapshot is missing matrix columns"));
        }
        let col_versions =
            col_versions.ok_or(PersistError::Malformed("snapshot has no version record"))?;
        let applied_k =
            applied_k.ok_or(PersistError::Malformed("snapshot has no dedup record"))?;
        let reg = if file_version >= 2 {
            reg.ok_or(PersistError::Malformed("snapshot has no formulation record"))?
        } else {
            let legacy =
                v1_reg.ok_or(PersistError::Malformed("snapshot has no regularizer record"))?;
            let factors = if legacy.online_expected {
                let u =
                    fac_u.ok_or(PersistError::Malformed("online snapshot missing U factor"))?;
                let vv =
                    fac_v.ok_or(PersistError::Malformed("online snapshot missing V factor"))?;
                let sigma =
                    sigma.ok_or(PersistError::Malformed("online snapshot missing sigma"))?;
                if u.cols() != sigma.len()
                    || vv.cols() != sigma.len()
                    || u.rows() != d
                    || vv.rows() != t
                {
                    return Err(PersistError::Malformed("factor dimensions inconsistent"));
                }
                Some((u, sigma, vv))
            } else {
                None
            };
            v1_reg_to_state(legacy, factors)?
        };

        Ok(ServerSnapshot {
            seq,
            eta,
            prox_every,
            version,
            col_versions,
            applied_k,
            v,
            pending,
            prox_count,
            coalesced,
            uncounted_commits,
            reg,
            rng_streams,
        })
    }

    /// Write atomically to `path`: temp file in the same directory, fsync,
    /// rename over the target, then best-effort directory fsync — a crash
    /// leaves either the old snapshot or the new one, never a torn mix.
    pub fn write_file(&self, path: &Path) -> Result<(), PersistError> {
        let tmp = path.with_extension("tmp");
        {
            let file = File::create(&tmp)?;
            let mut w = BufWriter::new(file);
            self.encode(&mut w)?;
            w.flush()?;
            w.get_ref().sync_all()?;
        }
        std::fs::rename(&tmp, path)?;
        if let Some(dir) = path.parent() {
            if let Ok(d) = File::open(dir) {
                let _ = d.sync_all();
            }
        }
        Ok(())
    }

    /// Read and fully validate a snapshot file.
    pub fn read_file(path: &Path) -> Result<ServerSnapshot, PersistError> {
        let mut r = BufReader::new(File::open(path)?);
        ServerSnapshot::decode(&mut r)
    }
}

fn read_u64s(c: &mut Cursor<'_>, n: usize) -> Result<Vec<u64>, PersistError> {
    let mut out = Vec::with_capacity(n);
    for _ in 0..n {
        out.push(c.u64()?);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optim::formulation::{self, FormulationSpec, FORMULATIONS};
    use crate::optim::svd::Svd;
    use crate::util::Rng;

    fn nuclear_state(online: bool, v: &Mat) -> FormulationState {
        let mut reg = NuclearProx::new(0.4).with_resvd_every(64);
        if online {
            reg = reg.with_online(v);
        }
        reg.note_commits(13);
        FormulationState { id: "nuclear".into(), blob: reg.state_save() }
    }

    fn sample(online: bool) -> ServerSnapshot {
        let mut rng = Rng::new(4040);
        let d = 6;
        let t = 3;
        let v = Mat::randn(d, t, &mut rng);
        let reg = nuclear_state(online, &v);
        ServerSnapshot {
            seq: 41,
            eta: 0.125,
            prox_every: 2,
            version: 17,
            col_versions: vec![5, 8, 4],
            applied_k: vec![5, 0, 4],
            pending: vec![None, Some(rng.normal_vec(d)), None],
            v,
            prox_count: 9,
            coalesced: 3,
            uncounted_commits: 2,
            reg,
            rng_streams: vec![(0, Rng::new(7).state()), (3, Rng::new(8).state())],
        }
    }

    fn roundtrip(s: &ServerSnapshot) -> ServerSnapshot {
        let mut buf = Vec::new();
        s.encode(&mut buf).unwrap();
        ServerSnapshot::decode(&mut std::io::Cursor::new(&buf)).unwrap()
    }

    #[test]
    fn snapshot_roundtrips_bitwise() {
        for online in [false, true] {
            let s = sample(online);
            assert_eq!(roundtrip(&s), s);
        }
    }

    #[test]
    fn snapshot_roundtrips_every_registered_formulation() {
        // The generic record must carry any registered formulation's
        // state — including the two shipped through the open API — and
        // the restored impl must re-save the identical blob.
        let mut rng = Rng::new(4141);
        let v = Mat::randn(5, 4, &mut rng);
        for info in FORMULATIONS {
            let spec = FormulationSpec::parse(info.name).unwrap();
            let mut reg = formulation::resolve(&spec, 0.3, 1.25, 4).unwrap();
            reg.enable_incremental(&v, 32);
            reg.notify_column_update(1, &rng.normal_vec(5));
            reg.note_commits(2);
            let mut s = sample(false);
            s.v = v.clone();
            s.col_versions = vec![1; 4];
            s.applied_k = vec![1; 4];
            s.pending = vec![None; 4];
            s.reg = FormulationState { id: reg.id().to_string(), blob: reg.state_save() };
            let back = roundtrip(&s);
            assert_eq!(back, s, "{}", info.name);
            let restored = formulation::restore(&back.reg.id, &back.reg.blob).unwrap();
            assert_eq!(restored.state_save(), s.reg.blob, "{}", info.name);
        }
    }

    #[test]
    fn oversized_formulation_blobs_chunk_across_records() {
        // A state blob bigger than one chunk must round-trip via
        // continuation records (e.g. a similarity graph over thousands of
        // tasks). The blob is opaque to the codec, so synthesize one.
        let mut s = sample(false);
        s.reg = FormulationState {
            id: "graph".into(),
            blob: (0..(REG_CHUNK * 2 + 123)).map(|i| (i * 31 % 251) as u8).collect(),
        };
        assert_eq!(roundtrip(&s), s);
    }

    #[test]
    fn every_truncation_errors_never_panics() {
        let s = sample(true);
        let mut buf = Vec::new();
        s.encode(&mut buf).unwrap();
        for cut in 0..buf.len() {
            assert!(
                ServerSnapshot::decode(&mut std::io::Cursor::new(&buf[..cut])).is_err(),
                "prefix of {cut}/{} bytes must not decode",
                buf.len()
            );
        }
    }

    #[test]
    fn corrupted_bytes_error_never_panic() {
        let s = sample(true);
        let mut buf = Vec::new();
        s.encode(&mut buf).unwrap();
        // Stride through the file (it is a few KB) flipping one byte.
        for pos in (0..buf.len()).step_by(17) {
            let mut bad = buf.clone();
            bad[pos] ^= 0x20;
            assert!(
                ServerSnapshot::decode(&mut std::io::Cursor::new(&bad)).is_err(),
                "corruption at byte {pos} must error"
            );
        }
    }

    #[test]
    fn file_roundtrip_is_atomic_write() {
        let dir = std::env::temp_dir().join(format!("amtl_snap_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("snapshot-41.amtls");
        let s = sample(true);
        s.write_file(&path).unwrap();
        assert!(!path.with_extension("tmp").exists(), "temp file renamed away");
        assert_eq!(ServerSnapshot::read_file(&path).unwrap(), s);
        std::fs::remove_dir_all(&dir).ok();
    }

    // ------------------------------------------------- v1 read-compat

    /// Byte-exact replica of the v1 writer (the pre-redesign fixed
    /// regularizer layout), used to prove the new decoder reads old
    /// checkpoints.
    fn encode_v1(
        s: &ServerSnapshot,
        kind_code: u8,
        lambda: f64,
        gamma: f64,
        resvd_every: u64,
        commits: u64,
        refreshes: u64,
        drift: f64,
        factors: Option<(&Mat, &[f64], &Mat)>,
    ) -> Vec<u8> {
        let d = s.v.rows();
        let t = s.v.cols();
        let mut w = Vec::new();
        w.extend_from_slice(&SNAPSHOT_MAGIC);
        w.push(1); // v1 header

        let mut meta = Vec::new();
        push_u64s(&mut meta, &[s.seq]);
        meta.extend_from_slice(&(d as u32).to_le_bytes());
        meta.extend_from_slice(&(t as u32).to_le_bytes());
        meta.extend_from_slice(&s.eta.to_bits().to_le_bytes());
        push_u64s(
            &mut meta,
            &[s.prox_every, s.version, s.prox_count, s.coalesced, s.uncounted_commits],
        );
        write_record(&mut w, TAG_META, &meta).unwrap();

        let mut vers = Vec::new();
        push_u64s(&mut vers, &s.col_versions);
        write_record(&mut w, TAG_COL_VERSIONS, &vers).unwrap();
        let mut applied = Vec::new();
        push_u64s(&mut applied, &s.applied_k);
        write_record(&mut w, TAG_APPLIED, &applied).unwrap();
        for c in 0..t {
            let mut payload = Vec::new();
            payload.extend_from_slice(&(c as u32).to_le_bytes());
            push_f64s(&mut payload, s.v.col(c));
            write_record(&mut w, TAG_COLUMN, &payload).unwrap();
        }
        for (c, slot) in s.pending.iter().enumerate() {
            if let Some(col) = slot {
                let mut payload = Vec::new();
                payload.extend_from_slice(&(c as u32).to_le_bytes());
                push_f64s(&mut payload, col);
                write_record(&mut w, TAG_PENDING, &payload).unwrap();
            }
        }

        let mut reg = Vec::new();
        reg.push(kind_code);
        reg.extend_from_slice(&lambda.to_bits().to_le_bytes());
        reg.extend_from_slice(&gamma.to_bits().to_le_bytes());
        push_u64s(&mut reg, &[resvd_every, commits, refreshes]);
        reg.extend_from_slice(&drift.to_bits().to_le_bytes());
        reg.push(u8::from(factors.is_some()));
        write_record(&mut w, TAG_REG, &reg).unwrap();

        if let Some((u, sigma, v)) = factors {
            write_record(&mut w, TAG_FACTOR, &mat_payload(0, u)).unwrap();
            write_record(&mut w, TAG_FACTOR, &mat_payload(1, v)).unwrap();
            let mut sig = Vec::new();
            push_f64s(&mut sig, sigma);
            write_record(&mut w, TAG_SIGMA, &sig).unwrap();
        }

        for (id, st) in &s.rng_streams {
            let mut payload = Vec::new();
            push_u64s(&mut payload, &[*id]);
            push_u64s(&mut payload, &st.s);
            match st.spare {
                None => payload.push(0),
                Some(x) => {
                    payload.push(1);
                    payload.extend_from_slice(&x.to_bits().to_le_bytes());
                }
            }
            write_record(&mut w, TAG_RNG, &payload).unwrap();
        }
        write_record(&mut w, TAG_END, &[]).unwrap();
        w
    }

    #[test]
    fn v1_snapshot_decodes_to_equivalent_formulation_state() {
        // An online-nuclear v1 checkpoint: the decoder must map the fixed
        // layout + factor records onto the exact blob the v2 NuclearProx
        // would save, so `restore` resumes it with the factorization and
        // the resvd stride counter intact.
        let skeleton = sample(false);
        let svd = Svd::jacobi(&skeleton.v);
        let bytes = encode_v1(
            &skeleton,
            0, // nuclear
            0.4,
            1.0,
            64,
            13,
            2,
            3.5e-12,
            Some((&svd.u, &svd.sigma, &svd.v)),
        );
        let got = ServerSnapshot::decode(&mut std::io::Cursor::new(&bytes)).unwrap();
        assert_eq!(got.reg.id, "nuclear");
        let want_blob = NuclearProx::encode_state_parts(
            0.4,
            64,
            13,
            2,
            3.5e-12,
            Some((&svd.u, svd.sigma.as_slice(), &svd.v)),
        );
        assert_eq!(got.reg.blob, want_blob);
        let restored = formulation::restore(&got.reg.id, &got.reg.blob).unwrap();
        assert!(restored.is_incremental(), "online path must survive v1 migration");
        assert_eq!(restored.lambda(), 0.4);
        // Stride counter continues: 13 folded + 51 more = 64 ⇒ due.
        let mut restored = restored;
        assert!(!restored.needs_refresh());
        restored.note_commits(51);
        assert!(restored.needs_refresh());
        // Everything else decodes unchanged.
        assert_eq!(got.v, skeleton.v);
        assert_eq!(got.seq, skeleton.seq);
        assert_eq!(got.col_versions, skeleton.col_versions);
    }

    #[test]
    fn v1_classic_kinds_map_to_their_impl_blobs() {
        let skeleton = sample(false);
        for (code, id) in [(1u8, "l21"), (2, "l1"), (3, "elasticnet"), (4, "none")] {
            let bytes =
                encode_v1(&skeleton, code, 0.7, 2.5, 0, 0, 0, 0.0, None);
            let got = ServerSnapshot::decode(&mut std::io::Cursor::new(&bytes)).unwrap();
            assert_eq!(got.reg.id, id);
            let restored = formulation::restore(&got.reg.id, &got.reg.blob).unwrap();
            assert_eq!(restored.id(), id);
            assert_eq!(restored.lambda(), 0.7);
        }
        // Unknown kind code must error, not panic.
        let bad = encode_v1(&skeleton, 9, 0.7, 1.0, 0, 0, 0, 0.0, None);
        assert!(ServerSnapshot::decode(&mut std::io::Cursor::new(&bad)).is_err());
    }

    #[test]
    fn v2_rejects_stray_v1_factor_records() {
        let s = sample(false);
        let mut buf = Vec::new();
        s.encode(&mut buf).unwrap();
        // Splice a factor record before the end marker: the v2 decoder
        // must reject it rather than silently ignore half a factorization.
        let end_record_len = {
            let mut end = Vec::new();
            write_record(&mut end, TAG_END, &[]).unwrap();
            end.len()
        };
        let split = buf.len() - end_record_len;
        let mut spliced = buf[..split].to_vec();
        write_record(&mut spliced, TAG_FACTOR, &mat_payload(0, &s.v)).unwrap();
        spliced.extend_from_slice(&buf[split..]);
        assert!(ServerSnapshot::decode(&mut std::io::Cursor::new(&spliced)).is_err());
    }
}
