//! The snapshot format: one durable file capturing
//! [`CentralServer`](crate::coordinator::server::CentralServer) state end
//! to end.
//!
//! A snapshot holds everything recovery needs to rebuild the server at an
//! exact WAL horizon (`seq`): the shared matrix `V` with its version
//! counters, the per-column commit-dedup keys, the pending online-SVD
//! slots, the full [`Regularizer`](crate::optim::prox::Regularizer) —
//! including the incremental factorization's basis and the resvd stride
//! counter, so the online nuclear prox resumes *without* resetting its
//! drift bound — the run constants (η, prox stride), the server metrics
//! counters, and any registered RNG streams.
//!
//! Files are written atomically (temp file + fsync + rename) and every
//! record is checksummed; a damaged snapshot reads as an error and
//! recovery falls back to the previous one.

use super::codec::{
    read_header, read_record, write_header, write_record, PersistError, SNAPSHOT_MAGIC,
};
use crate::linalg::Mat;
use crate::optim::prox::RegularizerKind;
use crate::transport::wire::{push_f64s, Cursor};
use crate::util::RngState;
use std::fs::File;
use std::io::{BufReader, BufWriter, Read, Write};
use std::path::Path;

const TAG_META: u8 = 0x01;
const TAG_COL_VERSIONS: u8 = 0x02;
const TAG_APPLIED: u8 = 0x03;
const TAG_COLUMN: u8 = 0x04;
const TAG_PENDING: u8 = 0x05;
const TAG_REG: u8 = 0x06;
const TAG_FACTOR: u8 = 0x07;
const TAG_SIGMA: u8 = 0x08;
const TAG_RNG: u8 = 0x09;
const TAG_END: u8 = 0x7E;

/// The online-SVD factorization `U diag(σ) Vᵀ`, serialized basis and all.
#[derive(Clone, Debug, PartialEq)]
pub struct SvdFactors {
    /// Left factor (`d × k`).
    pub u: Mat,
    /// Retained singular values.
    pub sigma: Vec<f64>,
    /// Right factor (`T × k`).
    pub v: Mat,
}

/// Serialized [`Regularizer`](crate::optim::prox::Regularizer) state.
#[derive(Clone, Debug, PartialEq)]
pub struct RegSnapshot {
    /// Which coupling `g` is.
    pub kind: RegularizerKind,
    /// Regularization strength λ.
    pub lambda: f64,
    /// Elastic-net ℓ2 weight γ.
    pub gamma: f64,
    /// Exact-refresh stride (0 = never).
    pub resvd_every: u64,
    /// Commits folded since the last exact refresh — preserved so a
    /// resumed run refreshes on the original stride, not a reset one.
    pub commits_since_refresh: u64,
    /// Exact refreshes performed so far.
    pub refreshes: u64,
    /// Drift recorded at the last exact refresh.
    pub last_drift: f64,
    /// The incremental factorization, when the online path is active.
    pub online: Option<SvdFactors>,
}

/// A complete, consistent capture of central-server state at WAL horizon
/// `seq` (every operation with sequence number ≤ `seq` is inside it).
#[derive(Clone, Debug, PartialEq)]
pub struct ServerSnapshot {
    /// WAL horizon: replay skips entries with `seq` ≤ this.
    pub seq: u64,
    /// Prox step size η (a run constant).
    pub eta: f64,
    /// Server re-prox stride.
    pub prox_every: u64,
    /// Global KM version (total updates applied).
    pub version: u64,
    /// Per-column update counters.
    pub col_versions: Vec<u64>,
    /// Per-column commit dedup keys (0 = none applied, else `k + 1`).
    pub applied_k: Vec<u64>,
    /// The shared auxiliary matrix `V`.
    pub v: Mat,
    /// Per-column pending slots awaiting their online-SVD fold.
    pub pending: Vec<Option<Vec<f64>>>,
    /// Proximal computations performed.
    pub prox_count: u64,
    /// Same-column commits coalesced before folding.
    pub coalesced: u64,
    /// Raw commits not yet handed to the refresh-stride counter.
    pub uncounted_commits: u64,
    /// The regularizer, factorization included.
    pub reg: RegSnapshot,
    /// Named RNG streams (id → exact generator state); which streams are
    /// stored is the embedding run's choice. The in-proc session stores
    /// its *root* stream as id 0 — the state worker streams fork from —
    /// so a resumed run reproduces the original run's per-node streams
    /// regardless of the seed on the resume command line.
    pub rng_streams: Vec<(u64, RngState)>,
}

fn kind_code(kind: RegularizerKind) -> u8 {
    match kind {
        RegularizerKind::Nuclear => 0,
        RegularizerKind::L21 => 1,
        RegularizerKind::L1 => 2,
        RegularizerKind::ElasticNet => 3,
        RegularizerKind::None => 4,
    }
}

fn kind_from_code(code: u8) -> Result<RegularizerKind, PersistError> {
    Ok(match code {
        0 => RegularizerKind::Nuclear,
        1 => RegularizerKind::L21,
        2 => RegularizerKind::L1,
        3 => RegularizerKind::ElasticNet,
        4 => RegularizerKind::None,
        _ => return Err(PersistError::Malformed("unknown regularizer kind code")),
    })
}

fn push_u64s(out: &mut Vec<u8>, xs: &[u64]) {
    out.reserve(xs.len() * 8);
    for x in xs {
        out.extend_from_slice(&x.to_le_bytes());
    }
}

fn mat_payload(which: u8, m: &Mat) -> Vec<u8> {
    let mut out = Vec::with_capacity(9 + m.rows() * m.cols() * 8);
    out.push(which);
    out.extend_from_slice(&(m.rows() as u32).to_le_bytes());
    out.extend_from_slice(&(m.cols() as u32).to_le_bytes());
    push_f64s(&mut out, m.data());
    out
}

fn mat_from_payload(payload: &[u8]) -> Result<(u8, Mat), PersistError> {
    let mut c = Cursor::new(payload);
    let which = c.u8()?;
    let rows = c.u32()? as usize;
    let cols = c.u32()? as usize;
    let data = c.rest_f64s()?;
    c.finish()?;
    if data.len() != rows * cols {
        return Err(PersistError::Malformed("factor data does not match its dimensions"));
    }
    let mut m = Mat::zeros(rows, cols);
    m.data_mut().copy_from_slice(&data);
    Ok((which, m))
}

impl ServerSnapshot {
    /// Serialize to `w` (header + records + end marker).
    pub fn encode(&self, w: &mut impl Write) -> Result<(), PersistError> {
        let d = self.v.rows();
        let t = self.v.cols();
        write_header(w, SNAPSHOT_MAGIC)?;

        let mut meta = Vec::with_capacity(64);
        push_u64s(&mut meta, &[self.seq]);
        meta.extend_from_slice(&(d as u32).to_le_bytes());
        meta.extend_from_slice(&(t as u32).to_le_bytes());
        meta.extend_from_slice(&self.eta.to_bits().to_le_bytes());
        push_u64s(
            &mut meta,
            &[self.prox_every, self.version, self.prox_count, self.coalesced, self.uncounted_commits],
        );
        write_record(w, TAG_META, &meta)?;

        let mut vers = Vec::new();
        push_u64s(&mut vers, &self.col_versions);
        write_record(w, TAG_COL_VERSIONS, &vers)?;

        let mut applied = Vec::new();
        push_u64s(&mut applied, &self.applied_k);
        write_record(w, TAG_APPLIED, &applied)?;

        for c in 0..t {
            let mut payload = Vec::with_capacity(4 + d * 8);
            payload.extend_from_slice(&(c as u32).to_le_bytes());
            push_f64s(&mut payload, self.v.col(c));
            write_record(w, TAG_COLUMN, &payload)?;
        }
        for (c, slot) in self.pending.iter().enumerate() {
            if let Some(col) = slot {
                let mut payload = Vec::with_capacity(4 + col.len() * 8);
                payload.extend_from_slice(&(c as u32).to_le_bytes());
                push_f64s(&mut payload, col);
                write_record(w, TAG_PENDING, &payload)?;
            }
        }

        let mut reg = Vec::with_capacity(64);
        reg.push(kind_code(self.reg.kind));
        reg.extend_from_slice(&self.reg.lambda.to_bits().to_le_bytes());
        reg.extend_from_slice(&self.reg.gamma.to_bits().to_le_bytes());
        push_u64s(&mut reg, &[self.reg.resvd_every, self.reg.commits_since_refresh, self.reg.refreshes]);
        reg.extend_from_slice(&self.reg.last_drift.to_bits().to_le_bytes());
        reg.push(u8::from(self.reg.online.is_some()));
        write_record(w, TAG_REG, &reg)?;

        if let Some(f) = &self.reg.online {
            write_record(w, TAG_FACTOR, &mat_payload(0, &f.u))?;
            write_record(w, TAG_FACTOR, &mat_payload(1, &f.v))?;
            let mut sig = Vec::new();
            push_f64s(&mut sig, &f.sigma);
            write_record(w, TAG_SIGMA, &sig)?;
        }

        for (id, st) in &self.rng_streams {
            let mut payload = Vec::with_capacity(49);
            push_u64s(&mut payload, &[*id]);
            push_u64s(&mut payload, &st.s);
            match st.spare {
                None => payload.push(0),
                Some(x) => {
                    payload.push(1);
                    payload.extend_from_slice(&x.to_bits().to_le_bytes());
                }
            }
            write_record(w, TAG_RNG, &payload)?;
        }

        write_record(w, TAG_END, &[])?;
        Ok(())
    }

    /// Decode from `r`, validating structure as well as checksums: all
    /// columns present, dedup/version vectors sized `T`, factor
    /// dimensions consistent, and an explicit end marker (so a truncated
    /// snapshot can never read as a shorter valid one).
    pub fn decode(r: &mut impl Read) -> Result<ServerSnapshot, PersistError> {
        read_header(r, SNAPSHOT_MAGIC)?;
        let (tag, meta) = read_record(r)?.ok_or(PersistError::Truncated)?;
        if tag != TAG_META {
            return Err(PersistError::Malformed("snapshot must start with its meta record"));
        }
        let mut c = Cursor::new(&meta);
        let seq = c.u64()?;
        let d = c.u32()? as usize;
        let t = c.u32()? as usize;
        let eta = c.f64()?;
        let prox_every = c.u64()?;
        let version = c.u64()?;
        let prox_count = c.u64()?;
        let coalesced = c.u64()?;
        let uncounted_commits = c.u64()?;
        c.finish()?;

        let mut col_versions: Option<Vec<u64>> = None;
        let mut applied_k: Option<Vec<u64>> = None;
        let mut v = Mat::zeros(d, t);
        let mut seen_cols = vec![false; t];
        let mut pending: Vec<Option<Vec<f64>>> = vec![None; t];
        let mut reg: Option<RegSnapshot> = None;
        let mut fac_u: Option<Mat> = None;
        let mut fac_v: Option<Mat> = None;
        let mut sigma: Option<Vec<f64>> = None;
        let mut online_expected = false;
        let mut rng_streams = Vec::new();
        let mut ended = false;

        while let Some((tag, payload)) = read_record(r)? {
            let mut c = Cursor::new(&payload);
            match tag {
                TAG_COL_VERSIONS => {
                    let xs = read_u64s(&mut c, t)?;
                    c.finish()?;
                    col_versions = Some(xs);
                }
                TAG_APPLIED => {
                    let xs = read_u64s(&mut c, t)?;
                    c.finish()?;
                    applied_k = Some(xs);
                }
                TAG_COLUMN | TAG_PENDING => {
                    let idx = c.u32()? as usize;
                    let col = c.rest_f64s()?;
                    c.finish()?;
                    if idx >= t || col.len() != d {
                        return Err(PersistError::Malformed("column record out of shape"));
                    }
                    if tag == TAG_COLUMN {
                        v.set_col(idx, &col);
                        seen_cols[idx] = true;
                    } else {
                        pending[idx] = Some(col);
                    }
                }
                TAG_REG => {
                    let kind = kind_from_code(c.u8()?)?;
                    let lambda = c.f64()?;
                    let gamma = c.f64()?;
                    let resvd_every = c.u64()?;
                    let commits_since_refresh = c.u64()?;
                    let refreshes = c.u64()?;
                    let last_drift = c.f64()?;
                    online_expected = match c.u8()? {
                        0 => false,
                        1 => true,
                        _ => return Err(PersistError::Malformed("online flag not 0/1")),
                    };
                    c.finish()?;
                    reg = Some(RegSnapshot {
                        kind,
                        lambda,
                        gamma,
                        resvd_every,
                        commits_since_refresh,
                        refreshes,
                        last_drift,
                        online: None,
                    });
                }
                TAG_FACTOR => {
                    let (which, m) = mat_from_payload(&payload)?;
                    match which {
                        0 => fac_u = Some(m),
                        1 => fac_v = Some(m),
                        _ => return Err(PersistError::Malformed("factor selector not U/V")),
                    }
                }
                TAG_SIGMA => {
                    let xs = c.rest_f64s()?;
                    c.finish()?;
                    sigma = Some(xs);
                }
                TAG_RNG => {
                    let id = c.u64()?;
                    let s = [c.u64()?, c.u64()?, c.u64()?, c.u64()?];
                    let spare = match c.u8()? {
                        0 => None,
                        1 => Some(c.f64()?),
                        _ => return Err(PersistError::Malformed("rng spare flag not 0/1")),
                    };
                    c.finish()?;
                    rng_streams.push((id, RngState { s, spare }));
                }
                TAG_END => {
                    c.finish()?;
                    ended = true;
                    break;
                }
                other => return Err(PersistError::BadTag(other)),
            }
        }

        if !ended {
            return Err(PersistError::Truncated);
        }
        if !seen_cols.iter().all(|&s| s) {
            return Err(PersistError::Malformed("snapshot is missing matrix columns"));
        }
        let col_versions =
            col_versions.ok_or(PersistError::Malformed("snapshot has no version record"))?;
        let applied_k =
            applied_k.ok_or(PersistError::Malformed("snapshot has no dedup record"))?;
        let mut reg =
            reg.ok_or(PersistError::Malformed("snapshot has no regularizer record"))?;
        if online_expected {
            let u = fac_u.ok_or(PersistError::Malformed("online snapshot missing U factor"))?;
            let vv = fac_v.ok_or(PersistError::Malformed("online snapshot missing V factor"))?;
            let sigma =
                sigma.ok_or(PersistError::Malformed("online snapshot missing sigma"))?;
            if u.cols() != sigma.len() || vv.cols() != sigma.len() || u.rows() != d || vv.rows() != t
            {
                return Err(PersistError::Malformed("factor dimensions inconsistent"));
            }
            reg.online = Some(SvdFactors { u, sigma, v: vv });
        }

        Ok(ServerSnapshot {
            seq,
            eta,
            prox_every,
            version,
            col_versions,
            applied_k,
            v,
            pending,
            prox_count,
            coalesced,
            uncounted_commits,
            reg,
            rng_streams,
        })
    }

    /// Write atomically to `path`: temp file in the same directory, fsync,
    /// rename over the target, then best-effort directory fsync — a crash
    /// leaves either the old snapshot or the new one, never a torn mix.
    pub fn write_file(&self, path: &Path) -> Result<(), PersistError> {
        let tmp = path.with_extension("tmp");
        {
            let file = File::create(&tmp)?;
            let mut w = BufWriter::new(file);
            self.encode(&mut w)?;
            w.flush()?;
            w.get_ref().sync_all()?;
        }
        std::fs::rename(&tmp, path)?;
        if let Some(dir) = path.parent() {
            if let Ok(d) = File::open(dir) {
                let _ = d.sync_all();
            }
        }
        Ok(())
    }

    /// Read and fully validate a snapshot file.
    pub fn read_file(path: &Path) -> Result<ServerSnapshot, PersistError> {
        let mut r = BufReader::new(File::open(path)?);
        ServerSnapshot::decode(&mut r)
    }
}

fn read_u64s(c: &mut Cursor<'_>, n: usize) -> Result<Vec<u64>, PersistError> {
    let mut out = Vec::with_capacity(n);
    for _ in 0..n {
        out.push(c.u64()?);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn sample(online: bool) -> ServerSnapshot {
        let mut rng = Rng::new(4040);
        let d = 6;
        let t = 3;
        let v = Mat::randn(d, t, &mut rng);
        let online_factors = online.then(|| {
            let s = crate::optim::svd::Svd::jacobi(&v);
            SvdFactors { u: s.u, sigma: s.sigma, v: s.v }
        });
        ServerSnapshot {
            seq: 41,
            eta: 0.125,
            prox_every: 2,
            version: 17,
            col_versions: vec![5, 8, 4],
            applied_k: vec![5, 0, 4],
            v,
            pending: vec![None, Some(rng.normal_vec(d)), None],
            prox_count: 9,
            coalesced: 3,
            uncounted_commits: 2,
            reg: RegSnapshot {
                kind: RegularizerKind::Nuclear,
                lambda: 0.4,
                gamma: 1.0,
                resvd_every: 64,
                commits_since_refresh: 13,
                refreshes: 2,
                last_drift: 3.2e-12,
                online: online_factors,
            },
            rng_streams: vec![(0, Rng::new(7).state()), (3, Rng::new(8).state())],
        }
    }

    fn roundtrip(s: &ServerSnapshot) -> ServerSnapshot {
        let mut buf = Vec::new();
        s.encode(&mut buf).unwrap();
        ServerSnapshot::decode(&mut std::io::Cursor::new(&buf)).unwrap()
    }

    #[test]
    fn snapshot_roundtrips_bitwise() {
        for online in [false, true] {
            let s = sample(online);
            assert_eq!(roundtrip(&s), s);
        }
    }

    #[test]
    fn every_truncation_errors_never_panics() {
        let s = sample(true);
        let mut buf = Vec::new();
        s.encode(&mut buf).unwrap();
        for cut in 0..buf.len() {
            assert!(
                ServerSnapshot::decode(&mut std::io::Cursor::new(&buf[..cut])).is_err(),
                "prefix of {cut}/{} bytes must not decode",
                buf.len()
            );
        }
    }

    #[test]
    fn corrupted_bytes_error_never_panic() {
        let s = sample(true);
        let mut buf = Vec::new();
        s.encode(&mut buf).unwrap();
        // Stride through the file (it is a few KB) flipping one byte.
        for pos in (0..buf.len()).step_by(17) {
            let mut bad = buf.clone();
            bad[pos] ^= 0x20;
            assert!(
                ServerSnapshot::decode(&mut std::io::Cursor::new(&bad)).is_err(),
                "corruption at byte {pos} must error"
            );
        }
    }

    #[test]
    fn file_roundtrip_is_atomic_write() {
        let dir = std::env::temp_dir().join(format!("amtl_snap_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("snapshot-41.amtls");
        let s = sample(true);
        s.write_file(&path).unwrap();
        assert!(!path.with_extension("tmp").exists(), "temp file renamed away");
        assert_eq!(ServerSnapshot::read_file(&path).unwrap(), s);
        std::fs::remove_dir_all(&dir).ok();
    }
}
