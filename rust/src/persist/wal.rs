//! The commit write-ahead log.
//!
//! Between snapshots, every state-mutating server operation is appended
//! here: KM commits (`Commit`) and uncached proximal computations
//! (`Prox`). Recovery replays the tail of this log on top of the latest
//! snapshot; because both entry kinds are deterministic given the replay
//! order, a sequentially-committed run recovers **bitwise identical**
//! state — including the online-SVD factorization, whose value depends on
//! the fold history that the `Prox` markers preserve.
//!
//! Entries carry a global sequence number so a log can be replayed
//! against any snapshot: entries at or below the snapshot's horizon are
//! skipped. Appends are fsync'd before the server acknowledges the commit
//! (see [`Checkpointer`](super::Checkpointer)), so an acknowledged update
//! is never lost to a crash.

use super::codec::{
    read_header, read_record, write_header, write_record, PersistError, WAL_MAGIC,
};
use crate::transport::wire::{push_f64s, Cursor};
use std::fs::File;
use std::io::{BufReader, BufWriter, Read, Seek, SeekFrom, Write};
use std::path::{Path, PathBuf};

const TAG_COMMIT: u8 = 0x01;
const TAG_PROX: u8 = 0x02;

/// Byte length of the WAL file header (magic + format version). The first
/// record starts here, so this is also the smallest valid resume offset.
pub const WAL_HEADER_LEN: u64 = 5;

/// One durable server operation.
#[derive(Clone, Debug, PartialEq)]
pub enum WalEntry {
    /// A KM commit `v_t ← v_t + step·(u − v_t)` from activation `k` of
    /// task node `t`.
    Commit {
        /// Global operation sequence number.
        seq: u64,
        /// Task (column) index.
        t: u32,
        /// The node's activation counter (commit dedup key).
        k: u64,
        /// KM relaxation step.
        step: f64,
        /// The forward-step result `u`.
        u: Vec<f64>,
    },
    /// An uncached proximal computation: the server drained its pending
    /// column slots into the online factorization (refreshing it if the
    /// stride was due) and computed `Prox_{ηλg}(V̂)`.
    Prox {
        /// Global operation sequence number.
        seq: u64,
    },
}

impl WalEntry {
    /// The entry's global sequence number.
    pub fn seq(&self) -> u64 {
        match self {
            WalEntry::Commit { seq, .. } | WalEntry::Prox { seq } => *seq,
        }
    }

    fn tag(&self) -> u8 {
        match self {
            WalEntry::Commit { .. } => TAG_COMMIT,
            WalEntry::Prox { .. } => TAG_PROX,
        }
    }

    fn payload(&self) -> Vec<u8> {
        match self {
            WalEntry::Commit { seq, t, k, step, u } => {
                let mut out = Vec::with_capacity(28 + u.len() * 8);
                out.extend_from_slice(&seq.to_le_bytes());
                out.extend_from_slice(&t.to_le_bytes());
                out.extend_from_slice(&k.to_le_bytes());
                out.extend_from_slice(&step.to_bits().to_le_bytes());
                push_f64s(&mut out, u);
                out
            }
            WalEntry::Prox { seq } => seq.to_le_bytes().to_vec(),
        }
    }

    /// Decode one entry from a record's `(tag, payload)`.
    pub fn decode(tag: u8, payload: &[u8]) -> Result<WalEntry, PersistError> {
        let mut c = Cursor::new(payload);
        let entry = match tag {
            TAG_COMMIT => {
                let seq = c.u64().map_err(PersistError::from)?;
                let t = c.u32().map_err(PersistError::from)?;
                let k = c.u64().map_err(PersistError::from)?;
                let step = c.f64().map_err(PersistError::from)?;
                let u = c.rest_f64s().map_err(PersistError::from)?;
                WalEntry::Commit { seq, t, k, step, u }
            }
            TAG_PROX => WalEntry::Prox { seq: c.u64().map_err(PersistError::from)? },
            other => return Err(PersistError::BadTag(other)),
        };
        c.finish().map_err(PersistError::from)?;
        Ok(entry)
    }
}

/// Append-only WAL file handle.
pub struct WalWriter {
    file: File,
    path: PathBuf,
    /// Entries appended but not yet fsync'd.
    dirty: bool,
}

impl WalWriter {
    /// Create (truncating) a WAL at `path`, write its header, and fsync so
    /// an immediately-following crash still finds a valid empty log.
    pub fn create(path: &Path) -> Result<WalWriter, PersistError> {
        let file = File::create(path)?;
        let mut w = WalWriter { file, path: path.to_path_buf(), dirty: false };
        write_header(&mut w.file, WAL_MAGIC)?;
        w.file.sync_data()?;
        Ok(w)
    }

    /// The log's filesystem path.
    pub fn path(&self) -> &Path {
        &self.path
    }

    /// Append one entry. Call [`WalWriter::sync`] before acknowledging the
    /// operation to the client.
    pub fn append(&mut self, entry: &WalEntry) -> Result<(), PersistError> {
        let mut buf = Vec::new();
        write_record(&mut buf, entry.tag(), &entry.payload())?;
        self.file.write_all(&buf)?;
        self.dirty = true;
        Ok(())
    }

    /// fsync appended entries to stable storage (no-op when clean).
    pub fn sync(&mut self) -> Result<(), PersistError> {
        if self.dirty {
            self.file.sync_data()?;
            self.dirty = false;
        }
        Ok(())
    }
}

/// Result of scanning one WAL file.
pub struct WalScan {
    /// Entries read, in append order.
    pub entries: Vec<WalEntry>,
    /// True when the scan stopped at an invalid tail record (the normal
    /// artifact of a crash mid-append) rather than a clean EOF. The
    /// damaged record and everything after it are unrecoverable; `error`
    /// says what was wrong with it.
    pub torn_tail: bool,
    /// The decode failure that terminated a torn scan.
    pub error: Option<PersistError>,
    /// Byte offset just past the last *valid* entry — the position a
    /// tailer should hand back to [`read_wal_from`] to resume without
    /// re-scanning the file. On a torn tail this still points at the last
    /// valid record boundary, so a live tailer that caught a writer
    /// mid-append simply retries the same offset once the record is
    /// complete. Never less than [`WAL_HEADER_LEN`].
    pub resume_offset: u64,
}

/// Counts bytes consumed through it, so the scan knows the exact boundary
/// of the last valid record even when a later read fails mid-record.
struct CountingReader<R> {
    inner: R,
    pos: u64,
}

impl<R: Read> Read for CountingReader<R> {
    fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
        let n = self.inner.read(buf)?;
        self.pos += n as u64;
        Ok(n)
    }
}

/// Scan a WAL file, tolerating a torn tail: entries are read until the
/// first invalid record, which ends the scan (a crash mid-append is
/// expected, and resynchronizing a byte stream after damage is not
/// possible). A missing or damaged *header* is a hard error — that file
/// was never a valid log.
pub fn read_wal(path: &Path) -> Result<WalScan, PersistError> {
    read_wal_from(path, 0)
}

/// Scan a WAL file starting at byte `offset` — the tail-reader entry
/// point. `offset` must be a record boundary previously returned in
/// [`WalScan::resume_offset`] (or `0` / [`WAL_HEADER_LEN`] for a full
/// scan); an arbitrary offset lands mid-record and reads as a torn tail.
/// The header is validated on every call, so a tailer resuming into a
/// file that was replaced by something else entirely still gets a hard
/// error rather than garbage entries.
pub fn read_wal_from(path: &Path, offset: u64) -> Result<WalScan, PersistError> {
    let mut file = File::open(path)?;
    read_header(&mut file, WAL_MAGIC)?;
    let start = offset.max(WAL_HEADER_LEN);
    if start > WAL_HEADER_LEN {
        file.seek(SeekFrom::Start(start))?;
    }
    let mut r = CountingReader { inner: BufReader::new(file), pos: start };
    let mut entries = Vec::new();
    let mut resume = start;
    loop {
        match read_record(&mut r) {
            Ok(None) => {
                return Ok(WalScan { entries, torn_tail: false, error: None, resume_offset: resume })
            }
            Ok(Some((tag, payload))) => match WalEntry::decode(tag, &payload) {
                Ok(entry) => {
                    entries.push(entry);
                    resume = r.pos;
                }
                Err(e) => {
                    return Ok(WalScan {
                        entries,
                        torn_tail: true,
                        error: Some(e),
                        resume_offset: resume,
                    })
                }
            },
            Err(e) => {
                return Ok(WalScan {
                    entries,
                    torn_tail: true,
                    error: Some(e),
                    resume_offset: resume,
                })
            }
        }
    }
}

/// Strict scan: any irregularity — torn tail included — is an error.
/// Used by tests and integrity checks; recovery uses [`read_wal`].
pub fn read_wal_strict(path: &Path) -> Result<Vec<WalEntry>, PersistError> {
    let scan = read_wal(path)?;
    if scan.torn_tail {
        return Err(scan.error.unwrap_or(PersistError::Truncated));
    }
    Ok(scan.entries)
}

/// Write a whole WAL in one call (tests and tooling).
pub fn write_wal(path: &Path, entries: &[WalEntry]) -> Result<(), PersistError> {
    let file = File::create(path)?;
    let mut w = BufWriter::new(file);
    write_header(&mut w, WAL_MAGIC)?;
    for e in entries {
        write_record(&mut w, e.tag(), &e.payload())?;
    }
    w.flush()?;
    w.get_ref().sync_data()?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::forall;

    fn tmp(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("amtl_wal_{}_{name}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        dir.join("log.amtlw")
    }

    fn sample_entries() -> Vec<WalEntry> {
        vec![
            WalEntry::Commit { seq: 1, t: 0, k: 0, step: 0.5, u: vec![1.0, -2.0, 3.5] },
            WalEntry::Prox { seq: 2 },
            WalEntry::Commit { seq: 3, t: 2, k: 7, step: 1.0, u: vec![] },
            WalEntry::Commit { seq: 4, t: 1, k: 1, step: 0.25, u: vec![f64::MIN_POSITIVE] },
        ]
    }

    /// Byte offset of the record boundary after `entries[..i]`.
    fn boundary(entries: &[WalEntry], i: usize) -> u64 {
        WAL_HEADER_LEN + entries[..i].iter().map(|e| 9 + e.payload().len() as u64).sum::<u64>()
    }

    #[test]
    fn wal_roundtrips_through_writer_and_reader() {
        let path = tmp("roundtrip");
        let mut w = WalWriter::create(&path).unwrap();
        for e in sample_entries() {
            w.append(&e).unwrap();
        }
        w.sync().unwrap();
        drop(w);
        assert_eq!(read_wal_strict(&path).unwrap(), sample_entries());
        let scan = read_wal(&path).unwrap();
        assert_eq!(scan.resume_offset, std::fs::metadata(&path).unwrap().len());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn torn_tail_is_tolerated_and_reported() {
        let path = tmp("torn");
        write_wal(&path, &sample_entries()).unwrap();
        let full = std::fs::read(&path).unwrap();
        // Chop the file mid-final-record: the first three entries survive.
        std::fs::write(&path, &full[..full.len() - 3]).unwrap();
        let scan = read_wal(&path).unwrap();
        assert!(scan.torn_tail);
        assert_eq!(scan.entries, sample_entries()[..3].to_vec());
        // The resume offset points at the last valid record boundary, not 0.
        assert_eq!(scan.resume_offset, boundary(&sample_entries(), 3));
        assert!(read_wal_strict(&path).is_err(), "strict read must reject the torn tail");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn torn_tail_resume_offset_picks_up_the_completed_record() {
        // The live-tailer scenario: a scan catches the writer mid-append
        // (torn tail), then the record completes; resuming at the reported
        // offset yields exactly the remaining entries.
        let path = tmp("resume_completion");
        write_wal(&path, &sample_entries()).unwrap();
        let full = std::fs::read(&path).unwrap();
        std::fs::write(&path, &full[..full.len() - 3]).unwrap();
        let scan = read_wal(&path).unwrap();
        assert!(scan.torn_tail);
        std::fs::write(&path, &full).unwrap(); // the append completes
        let resumed = read_wal_from(&path, scan.resume_offset).unwrap();
        assert!(!resumed.torn_tail);
        assert_eq!(resumed.entries, sample_entries()[3..].to_vec());
        assert_eq!(resumed.resume_offset, full.len() as u64);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn resume_from_zero_or_header_is_a_full_scan() {
        let path = tmp("resume_zero");
        write_wal(&path, &sample_entries()).unwrap();
        for off in [0, WAL_HEADER_LEN] {
            let scan = read_wal_from(&path, off).unwrap();
            assert_eq!(scan.entries, sample_entries());
        }
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn resume_into_replaced_file_is_a_hard_error() {
        let path = tmp("resume_replaced");
        std::fs::write(&path, b"not a wal at all").unwrap();
        assert!(matches!(read_wal_from(&path, 9), Err(PersistError::BadMagic(_))));
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn corrupted_checksum_errors_never_panics() {
        let path = tmp("corrupt");
        write_wal(&path, &sample_entries()).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0x40;
        std::fs::write(&path, &bytes).unwrap();
        assert!(read_wal_strict(&path).is_err());
        // The tolerant scan stops at the damage instead of erroring.
        let scan = read_wal(&path).unwrap();
        assert!(scan.torn_tail && scan.entries.len() < sample_entries().len());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn header_damage_is_a_hard_error() {
        let path = tmp("header");
        write_wal(&path, &sample_entries()).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        bytes[0] = b'X';
        std::fs::write(&path, &bytes).unwrap();
        assert!(matches!(read_wal(&path), Err(PersistError::BadMagic(_))));
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn prop_resume_at_any_valid_offset_matches_full_scan_suffix() {
        let path = tmp("prop_resume");
        forall(
            "wal tail-reads resumed at any record boundary equal the full-scan suffix",
            40,
            |g| {
                let n = g.usize_in(0, 10);
                let entries: Vec<WalEntry> = (0..n)
                    .map(|i| {
                        if g.usize_in(0, 3) == 0 {
                            WalEntry::Prox { seq: i as u64 + 1 }
                        } else {
                            let len = g.usize_in(0, 12);
                            WalEntry::Commit {
                                seq: i as u64 + 1,
                                t: g.usize_in(0, 7) as u32,
                                k: g.usize_in(0, 100) as u64,
                                step: g.f64_in(0.0, 1.0),
                                u: g.normal_vec(len),
                            }
                        }
                    })
                    .collect();
                let cut = g.usize_in(0, n);
                (entries, cut)
            },
            |(entries, cut)| {
                let cut = (*cut).min(entries.len()); // shrinking may shorten entries
                write_wal(&path, entries).unwrap();
                let full = read_wal(&path).unwrap();
                let resumed = read_wal_from(&path, boundary(entries, cut)).unwrap();
                full.entries == *entries
                    && !resumed.torn_tail
                    && resumed.entries == entries[cut..]
                    && resumed.resume_offset == full.resume_offset
            },
        );
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn prop_entries_roundtrip_bitwise() {
        forall(
            "wal commit entries encode/decode identically",
            60,
            |g| {
                let n = g.usize_in(0, 200);
                let u = g.normal_vec(n);
                let step = g.f64_in(-4.0, 4.0);
                let seq = g.usize_in(0, 1 << 20);
                ((u, step), seq)
            },
            |((u, step), seq)| {
                let e = WalEntry::Commit {
                    seq: *seq as u64,
                    t: (*seq % 97) as u32,
                    k: *seq as u64 / 3,
                    step: *step,
                    u: u.clone(),
                };
                let mut buf = Vec::new();
                write_record(&mut buf, e.tag(), &e.payload()).unwrap();
                let (tag, payload) =
                    read_record(&mut std::io::Cursor::new(&buf)).unwrap().unwrap();
                WalEntry::decode(tag, &payload).unwrap() == e
            },
        );
    }
}
