//! Record framing shared by snapshot and WAL files.
//!
//! Durable files reuse the wire protocol's codec discipline
//! (`transport/wire.rs`): little-endian integers, `f64`s as raw bit
//! patterns, length prefixes bounded before allocation, and the same
//! FNV-1a 32-bit checksum over every record. A file is
//!
//! ```text
//! ┌───────┬─────────┬─ repeated ─────────────────────────────┐
//! │ magic │ version │ tag(1B) len(u32) payload crc(u32) ...  │
//! └───────┴─────────┴────────────────────────────────────────┘
//! ```
//!
//! with `crc = fnv1a32(tag ‖ len ‖ payload)`. Decoding NEVER panics:
//! truncated or corrupted input returns a [`PersistError`]. A clean EOF at
//! a record boundary reads as `Ok(None)` — that distinction is what lets
//! WAL recovery treat a torn tail (the normal crash artifact) differently
//! from mid-file corruption.

use crate::transport::wire::{fnv1a32, WireError};
use std::fmt;
use std::io::{ErrorKind, Read, Write};

/// Snapshot-file magic (`AMTS`nap).
pub const SNAPSHOT_MAGIC: [u8; 4] = *b"AMTS";
/// WAL-file magic (`AMTW`al).
pub const WAL_MAGIC: [u8; 4] = *b"AMTW";
/// On-disk format version; bumped on any incompatible record change.
/// v2 replaced the fixed-layout regularizer record with a generic
/// formulation tag + opaque state blob (see `snapshot.rs`); v1 files
/// remain readable ([`read_header`] accepts [`MIN_FORMAT_VERSION`]..).
pub const FORMAT_VERSION: u8 = 2;
/// Oldest on-disk format version the readers still decode.
pub const MIN_FORMAT_VERSION: u8 = 1;
/// Upper bound on a single record's payload (guards allocation on
/// corrupted lengths; large state is split across per-column records).
pub const MAX_RECORD: u32 = 1 << 26;

/// Durable-format decode/IO failure. Malformed input is an error, never a
/// panic.
#[derive(Debug)]
pub enum PersistError {
    /// Underlying filesystem failure.
    Io(std::io::Error),
    /// File did not start with the expected magic.
    BadMagic([u8; 4]),
    /// File written by a different (incompatible) format version.
    BadVersion(u8),
    /// Unknown record tag.
    BadTag(u8),
    /// Declared record length exceeds [`MAX_RECORD`].
    Oversize(u32),
    /// FNV checksum mismatch (corrupt record).
    BadChecksum {
        /// Checksum computed over the stored record.
        got: u32,
        /// Checksum the record claims.
        want: u32,
    },
    /// File ended mid-record (torn write or truncation).
    Truncated,
    /// Structurally invalid record payload.
    Malformed(&'static str),
}

impl fmt::Display for PersistError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            PersistError::Io(e) => write!(f, "persist io error: {e}"),
            PersistError::BadMagic(m) => write!(f, "bad file magic {m:02x?}"),
            PersistError::BadVersion(v) => {
                write!(
                    f,
                    "unsupported persist format version {v} \
                     (supported: {MIN_FORMAT_VERSION}..={FORMAT_VERSION})"
                )
            }
            PersistError::BadTag(t) => write!(f, "unknown record tag {t:#04x}"),
            PersistError::Oversize(n) => {
                write!(f, "record length {n} exceeds maximum {MAX_RECORD}")
            }
            PersistError::BadChecksum { got, want } => {
                write!(f, "record checksum mismatch: file says {want:#010x}, computed {got:#010x}")
            }
            PersistError::Truncated => write!(f, "file ends mid-record (torn write)"),
            PersistError::Malformed(what) => write!(f, "malformed record: {what}"),
        }
    }
}

impl std::error::Error for PersistError {}

impl From<std::io::Error> for PersistError {
    fn from(e: std::io::Error) -> PersistError {
        if e.kind() == ErrorKind::UnexpectedEof {
            PersistError::Truncated
        } else {
            PersistError::Io(e)
        }
    }
}

impl From<WireError> for PersistError {
    fn from(e: WireError) -> PersistError {
        match e {
            WireError::Io(e) => PersistError::from(e),
            WireError::BadMagic(m) => PersistError::BadMagic(m),
            WireError::BadVersion(v) => PersistError::BadVersion(v),
            WireError::BadOpcode(op) => PersistError::BadTag(op),
            WireError::Oversize(n) => PersistError::Oversize(n),
            WireError::BadChecksum { got, want } => PersistError::BadChecksum { got, want },
            WireError::Malformed(what) => PersistError::Malformed(what),
        }
    }
}

/// Write the file header: magic + format version.
pub fn write_header(w: &mut impl Write, magic: [u8; 4]) -> Result<(), PersistError> {
    w.write_all(&magic)?;
    w.write_all(&[FORMAT_VERSION])?;
    Ok(())
}

/// Read and validate the file header against `magic`, returning the
/// file's format version (any supported version; decoders branch on it
/// for read-compat with older files).
pub fn read_header(r: &mut impl Read, magic: [u8; 4]) -> Result<u8, PersistError> {
    let mut got = [0u8; 4];
    r.read_exact(&mut got)?;
    if got != magic {
        return Err(PersistError::BadMagic(got));
    }
    let mut ver = [0u8; 1];
    r.read_exact(&mut ver)?;
    if !(MIN_FORMAT_VERSION..=FORMAT_VERSION).contains(&ver[0]) {
        return Err(PersistError::BadVersion(ver[0]));
    }
    Ok(ver[0])
}

/// Write one checksummed record: tag, length, payload, crc.
pub fn write_record(w: &mut impl Write, tag: u8, payload: &[u8]) -> Result<(), PersistError> {
    // Hard error (not a debug_assert): a record the reader's MAX_RECORD
    // bound would reject must never be written — an unreadable checkpoint
    // is worse than a failed write.
    if payload.len() as u64 > MAX_RECORD as u64 {
        return Err(PersistError::Oversize(payload.len().min(u32::MAX as usize) as u32));
    }
    let len = (payload.len() as u32).to_le_bytes();
    let crc = fnv1a32(&[&[tag], &len, payload]).to_le_bytes();
    w.write_all(&[tag])?;
    w.write_all(&len)?;
    w.write_all(payload)?;
    w.write_all(&crc)?;
    Ok(())
}

/// Read one record, verifying the size bound and checksum. Returns
/// `Ok(None)` on a clean EOF at a record boundary; a partial record is
/// [`PersistError::Truncated`] and a checksum mismatch is
/// [`PersistError::BadChecksum`] — callers decide whether a failure at the
/// tail is tolerable (WAL recovery) or fatal (snapshot load).
pub fn read_record(r: &mut impl Read) -> Result<Option<(u8, Vec<u8>)>, PersistError> {
    let mut head = [0u8; 5]; // tag, len
    match read_exact_or_eof(r, &mut head)? {
        ReadOutcome::Eof => return Ok(None),
        ReadOutcome::Full => {}
    }
    let tag = head[0];
    let len = u32::from_le_bytes([head[1], head[2], head[3], head[4]]);
    if len > MAX_RECORD {
        return Err(PersistError::Oversize(len));
    }
    let mut payload = vec![0u8; len as usize];
    r.read_exact(&mut payload)?;
    let mut crc = [0u8; 4];
    r.read_exact(&mut crc)?;
    let want = u32::from_le_bytes(crc);
    let got = fnv1a32(&[&head, &payload]);
    if got != want {
        return Err(PersistError::BadChecksum { got, want });
    }
    Ok(Some((tag, payload)))
}

enum ReadOutcome {
    Full,
    Eof,
}

/// Fill `buf` completely, or report a clean EOF if the stream ended
/// *before the first byte*. EOF mid-buffer is a truncation error.
fn read_exact_or_eof(r: &mut impl Read, buf: &mut [u8]) -> Result<ReadOutcome, PersistError> {
    let mut filled = 0;
    while filled < buf.len() {
        match r.read(&mut buf[filled..]) {
            Ok(0) => {
                return if filled == 0 {
                    Ok(ReadOutcome::Eof)
                } else {
                    Err(PersistError::Truncated)
                };
            }
            Ok(n) => filled += n,
            Err(e) if e.kind() == ErrorKind::Interrupted => {}
            Err(e) => return Err(e.into()),
        }
    }
    Ok(ReadOutcome::Full)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn one_record(tag: u8, payload: &[u8]) -> Vec<u8> {
        let mut out = Vec::new();
        write_record(&mut out, tag, payload).unwrap();
        out
    }

    #[test]
    fn record_roundtrips() {
        let bytes = one_record(0x11, b"hello persist");
        let mut r = std::io::Cursor::new(&bytes);
        let (tag, payload) = read_record(&mut r).unwrap().unwrap();
        assert_eq!(tag, 0x11);
        assert_eq!(payload, b"hello persist");
        assert!(read_record(&mut r).unwrap().is_none(), "clean EOF after the record");
    }

    #[test]
    fn empty_stream_is_clean_eof() {
        let mut r = std::io::Cursor::new(Vec::<u8>::new());
        assert!(read_record(&mut r).unwrap().is_none());
    }

    #[test]
    fn every_truncation_errors_never_panics() {
        let bytes = one_record(0x07, &[9u8; 33]);
        for cut in 1..bytes.len() {
            let mut r = std::io::Cursor::new(&bytes[..cut]);
            assert!(
                matches!(read_record(&mut r), Err(PersistError::Truncated)),
                "prefix of {cut}/{} bytes must read as truncated",
                bytes.len()
            );
        }
    }

    #[test]
    fn every_single_byte_corruption_is_caught() {
        let bytes = one_record(0x07, &[1, 2, 3, 4, 5, 6, 7]);
        for pos in 0..bytes.len() {
            for flip in [0xFFu8, 0x01, 0x80] {
                let mut bad = bytes.clone();
                bad[pos] ^= flip;
                let mut r = std::io::Cursor::new(&bad);
                // A corrupted length can read as Oversize or Truncated; any
                // payload/tag/crc damage is a checksum mismatch. All error.
                assert!(
                    read_record(&mut r).is_err(),
                    "corruption at byte {pos} (xor {flip:#x}) must error"
                );
            }
        }
    }

    #[test]
    fn oversize_length_rejected_without_allocating() {
        let mut bytes = one_record(0x01, &[]);
        bytes[1..5].copy_from_slice(&(MAX_RECORD + 1).to_le_bytes());
        let mut r = std::io::Cursor::new(&bytes);
        assert!(matches!(read_record(&mut r), Err(PersistError::Oversize(_))));
    }

    #[test]
    fn header_roundtrips_and_rejects_mismatch() {
        let mut out = Vec::new();
        write_header(&mut out, SNAPSHOT_MAGIC).unwrap();
        assert_eq!(
            read_header(&mut std::io::Cursor::new(&out), SNAPSHOT_MAGIC).unwrap(),
            FORMAT_VERSION
        );
        // Older supported versions are accepted and reported.
        let mut v1 = out.clone();
        v1[4] = MIN_FORMAT_VERSION;
        assert_eq!(
            read_header(&mut std::io::Cursor::new(&v1), SNAPSHOT_MAGIC).unwrap(),
            MIN_FORMAT_VERSION
        );
        assert!(matches!(
            read_header(&mut std::io::Cursor::new(&out), WAL_MAGIC),
            Err(PersistError::BadMagic(_))
        ));
        let mut bad = out.clone();
        bad[4] = FORMAT_VERSION + 1;
        assert!(matches!(
            read_header(&mut std::io::Cursor::new(&bad), SNAPSHOT_MAGIC),
            Err(PersistError::BadVersion(_))
        ));
        assert!(matches!(
            read_header(&mut std::io::Cursor::new(&out[..3]), SNAPSHOT_MAGIC),
            Err(PersistError::Truncated)
        ));
    }
}
