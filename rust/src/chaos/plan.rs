//! Composable, seed-reproducible chaos-run specifications.
//!
//! A [`ChaosPlan`] is the *entire* description of a fault storm: swarm
//! size, schedule, transport, heartbeat cadence, and a [`StormSpec`]
//! describing which fault families to compose. Everything random about
//! the storm — which nodes flap, which sit behind slow links — is
//! derived from the plan's single `seed` by [`ChaosPlan::materialize`],
//! so a failing run reproduces from one printed integer.

use crate::coordinator::{Async, Schedule, SemiSync, Synchronized};
use crate::net::{DelayModel, FaultModel};
use crate::transport::TransportKind;
use crate::util::Rng;
use anyhow::Result;
use std::time::Duration;

/// Which update schedule the storm runs under. A storm is only a storm
/// relative to a schedule: the same fault set that is a nuisance under
/// [`Async`] is a liveness hazard under [`SemiSync`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ScheduleChoice {
    /// Algorithm 1 / ARock free-running workers.
    Async,
    /// §III.B barrier rounds.
    Synchronized,
    /// Bounded staleness: no node runs more than `staleness_bound`
    /// activations ahead of the slowest live node.
    SemiSync {
        /// The bound handed to [`SemiSync`].
        staleness_bound: u64,
    },
}

impl ScheduleChoice {
    /// Instantiate the schedule for a session.
    pub fn to_schedule(&self) -> Box<dyn Schedule> {
        match self {
            ScheduleChoice::Async => Box::new(Async),
            ScheduleChoice::Synchronized => Box::new(Synchronized),
            ScheduleChoice::SemiSync { staleness_bound } => {
                Box::new(SemiSync { staleness_bound: *staleness_bound })
            }
        }
    }

    /// The staleness bound, when this choice has one.
    pub fn staleness_bound(&self) -> Option<u64> {
        match self {
            ScheduleChoice::SemiSync { staleness_bound } => Some(*staleness_bound),
            _ => None,
        }
    }

    /// The schedule's method name ("amtl" | "smtl" | "semisync").
    pub fn name(&self) -> &'static str {
        match self {
            ScheduleChoice::Async => "amtl",
            ScheduleChoice::Synchronized => "smtl",
            ScheduleChoice::SemiSync { .. } => "semisync",
        }
    }

    /// True for the free-running schedules, whose workers register with
    /// the membership registry (the [`Synchronized`] round loop never
    /// registers — its barrier already is the liveness mechanism).
    pub fn registers_membership(&self) -> bool {
        !matches!(self, ScheduleChoice::Synchronized)
    }
}

/// The fault-storm half of a plan: which fault families to inject and at
/// what intensity. Node *selection* happens in
/// [`ChaosPlan::materialize`], deterministically from the plan seed.
#[derive(Clone, Debug)]
pub struct StormSpec {
    /// Per-activation probability that a node's update is lost in
    /// transit ([`FaultModel::DropActivation`]).
    pub drop_p: f64,
    /// Fraction of nodes that go silently down mid-run and come back
    /// (a correlated [`FaultModel::CrashRestart`] wave).
    pub flap_fraction: f64,
    /// Length of each flapping node's silent window, in activations.
    pub flap_down_for: u64,
    /// Activation at which the first wave member goes down.
    pub flap_start: u64,
    /// Stagger between consecutive wave members' `down_from` (0 = the
    /// whole wave drops at once — the most correlated storm).
    pub flap_spacing: u64,
    /// Fraction of nodes that sit behind a slow link (stragglers).
    pub straggler_fraction: f64,
    /// The stragglers' delay offset (plus an exponential tail of half
    /// this mean, the paper's AMTL-k network model).
    pub straggler_offset: Duration,
    /// Uniform jitter every non-straggler node sees per activation.
    pub base_jitter: Duration,
}

impl Default for StormSpec {
    /// A mild but complete storm: every fault family is represented.
    fn default() -> StormSpec {
        StormSpec {
            drop_p: 0.1,
            flap_fraction: 0.25,
            flap_down_for: 8,
            flap_start: 4,
            flap_spacing: 1,
            straggler_fraction: 0.125,
            straggler_offset: Duration::from_millis(4),
            base_jitter: Duration::from_millis(1),
        }
    }
}

/// A complete chaos-run specification. Two plans with equal fields
/// materialize bit-identical storms; the `seed` alone fixes the random
/// choices, so a violation report only needs to print the seed (plus the
/// plan constructor it came from) to be reproducible.
#[derive(Clone, Debug)]
pub struct ChaosPlan {
    /// Number of task nodes in the swarm.
    pub nodes: usize,
    /// Activation budget per node.
    pub iters_per_node: usize,
    /// Root seed: data/worker RNG streams *and* storm materialization.
    pub seed: u64,
    /// The schedule under test.
    pub schedule: ScheduleChoice,
    /// Worker↔server edge: shared memory or real loopback sockets.
    pub transport: TransportKind,
    /// Heartbeat interval (elastic membership is always on under chaos —
    /// silent windows without eviction stall bounded-staleness runs and
    /// leave the membership invariant with nothing to check).
    pub heartbeat: Duration,
    /// Wall-clock length of one simulated delay unit.
    pub time_scale: Duration,
    /// Fixed KM relaxation step.
    pub eta_k: f64,
    /// The fault storm to compose.
    pub storm: StormSpec,
    /// Relative tolerance for the convergence invariant: the storm run's
    /// final objective must be ≤ `(1 + tol) ×` the undisturbed
    /// reference's.
    pub convergence_tol: f64,
}

/// A plan's storm, made concrete: the composed fault model, the
/// heterogeneous delay table, and the node sets each family targets
/// (the invariant checker uses `flapped` to pick the cohort whose
/// commits the staleness bound provably orders).
#[derive(Clone, Debug)]
pub struct MaterializedStorm {
    /// The composed fault model ([`FaultModel::Compose`]).
    pub faults: FaultModel,
    /// Per-node delay table ([`DelayModel::PerNode`]).
    pub delay: DelayModel,
    /// Nodes with a silent crash/restart window, ascending.
    pub flapped: Vec<usize>,
    /// Nodes behind the slow link, ascending.
    pub stragglers: Vec<usize>,
}

impl ChaosPlan {
    /// A plan with the default mild storm over the given swarm shape.
    pub fn new(nodes: usize, iters_per_node: usize, seed: u64) -> ChaosPlan {
        ChaosPlan {
            nodes,
            iters_per_node,
            seed,
            schedule: ScheduleChoice::Async,
            transport: TransportKind::InProc,
            heartbeat: Duration::from_millis(10),
            time_scale: Duration::from_millis(1),
            eta_k: 0.5,
            storm: StormSpec::default(),
            convergence_tol: 0.35,
        }
    }

    /// Number of flapping nodes this plan's storm selects.
    pub fn flap_count(&self) -> usize {
        ((self.storm.flap_fraction * self.nodes as f64).round() as usize).min(self.nodes)
    }

    /// Number of straggler nodes this plan's storm selects.
    pub fn straggler_count(&self) -> usize {
        ((self.storm.straggler_fraction * self.nodes as f64).round() as usize).min(self.nodes)
    }

    /// Reject plans that cannot run to completion or whose invariants
    /// would be vacuous. The [`SemiSync`] rule is a liveness proof
    /// obligation: a flapping node that is neither evicted while silent
    /// (window ≥ 4 heartbeat-length sleeps, past the 3× eviction
    /// timeout) nor within the staleness bound of its stalled gate slot
    /// (window ≤ bound) would park at the gate behind its own counter,
    /// heartbeating itself live forever.
    pub fn validate(&self) -> Result<()> {
        anyhow::ensure!(self.nodes >= 1, "chaos plan needs at least one node");
        anyhow::ensure!(self.iters_per_node >= 1, "chaos plan needs a positive budget");
        anyhow::ensure!(
            (0.0..1.0).contains(&self.storm.drop_p),
            "drop_p must be in [0, 1): 1.0 would drop every commit"
        );
        anyhow::ensure!(
            (0.0..=1.0).contains(&self.storm.flap_fraction)
                && (0.0..=1.0).contains(&self.storm.straggler_fraction),
            "node fractions must be in [0, 1]"
        );
        anyhow::ensure!(!self.heartbeat.is_zero(), "heartbeat interval must be positive");
        if self.flap_count() > 0 {
            let last_return = self.storm.flap_start
                + self.storm.flap_spacing * (self.flap_count() as u64 - 1)
                + self.storm.flap_down_for;
            anyhow::ensure!(
                last_return < self.iters_per_node as u64,
                "flap windows must end inside the activation budget \
                 (last node returns at {last_return}, budget {}): otherwise \
                 the wave never rejoins and the re-register balance is vacuous",
                self.iters_per_node
            );
            if let Some(bound) = self.schedule.staleness_bound() {
                anyhow::ensure!(
                    self.storm.flap_down_for <= bound || self.storm.flap_down_for >= 4,
                    "a semisync flap window of {} activations is neither within \
                     the staleness bound ({bound}) nor long enough (≥ 4) to \
                     guarantee eviction before the node returns",
                    self.storm.flap_down_for
                );
            }
        }
        Ok(())
    }

    /// Make the storm concrete. Deterministic: the same plan always
    /// selects the same nodes and builds the same models. Crash/restart
    /// children are composed *before* the drop storm so per-node
    /// targeting never perturbs other nodes' drop-RNG sequences
    /// (see [`FaultModel::Compose`] on ordering).
    pub fn materialize(&self) -> MaterializedStorm {
        // A fixed stream id keeps storm materialization independent of
        // the data/worker streams forked from the same root seed.
        let mut rng = Rng::new(self.seed).fork(0x5701_3a5e);
        let flapped = pick_nodes(&mut rng, self.nodes, self.flap_count());
        let stragglers = pick_nodes(&mut rng, self.nodes, self.straggler_count());

        let mut children: Vec<FaultModel> = flapped
            .iter()
            .enumerate()
            .map(|(i, &node)| FaultModel::CrashRestart {
                node,
                down_from: self.storm.flap_start + i as u64 * self.storm.flap_spacing,
                down_for: self.storm.flap_down_for,
            })
            .collect();
        if self.storm.drop_p > 0.0 {
            children.push(FaultModel::DropActivation { p: self.storm.drop_p });
        }
        let faults =
            if children.is_empty() { FaultModel::None } else { FaultModel::Compose(children) };

        let per_node = (0..self.nodes)
            .map(|t| {
                Box::new(if stragglers.binary_search(&t).is_ok() {
                    DelayModel::paper_offset(self.storm.straggler_offset)
                } else {
                    DelayModel::OffsetJitter {
                        offset: Duration::ZERO,
                        jitter: self.storm.base_jitter,
                    }
                })
            })
            .collect();
        let delay = DelayModel::PerNode { per_node };

        MaterializedStorm { faults, delay, flapped, stragglers }
    }

    /// The nodes *never* targeted by a silent window — the cohort whose
    /// commit order the staleness bound provably constrains (a flapped
    /// node is deactivated from the gate on eviction and may lawfully
    /// burst old activations when it rejoins).
    pub fn cohort(&self, storm: &MaterializedStorm) -> Vec<usize> {
        (0..self.nodes).filter(|t| storm.flapped.binary_search(t).is_err()).collect()
    }
}

/// Choose `count` distinct nodes out of `n`, ascending, deterministically
/// from `rng` (a full Fisher–Yates shuffle, then the prefix — the extra
/// draws keep the selection's distribution uniform for every `count`).
fn pick_nodes(rng: &mut Rng, n: usize, count: usize) -> Vec<usize> {
    let mut idx: Vec<usize> = (0..n).collect();
    rng.shuffle(&mut idx);
    idx.truncate(count);
    idx.sort_unstable();
    idx
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn materialize_is_deterministic_in_the_seed() {
        let plan = ChaosPlan::new(32, 40, 4242);
        let a = plan.materialize();
        let b = plan.materialize();
        assert_eq!(a.flapped, b.flapped);
        assert_eq!(a.stragglers, b.stragglers);
        assert_eq!(a.flapped.len(), plan.flap_count());
        assert_eq!(a.stragglers.len(), plan.straggler_count());
        // A different seed picks a different wave (with 32C8 choices the
        // odds of a collision are negligible; a fixed pair keeps this
        // deterministic rather than flaky).
        let other = ChaosPlan::new(32, 40, 4243).materialize();
        assert_ne!(a.flapped, other.flapped);
    }

    #[test]
    fn materialized_fault_targets_match_the_flap_set() {
        let plan = ChaosPlan::new(16, 40, 77);
        let storm = plan.materialize();
        for &t in &storm.flapped {
            let down_from = (0..plan.iters_per_node as u64)
                .find(|&k| storm.faults.offline_at(t, k))
                .expect("flapped node has a window");
            // The window has exactly the planned length.
            let width = (down_from..plan.iters_per_node as u64)
                .take_while(|&k| storm.faults.offline_at(t, k))
                .count() as u64;
            assert_eq!(width, plan.storm.flap_down_for);
        }
        for t in plan.cohort(&storm) {
            assert!(
                (0..plan.iters_per_node as u64).all(|k| !storm.faults.offline_at(t, k)),
                "cohort node {t} must never be offline"
            );
        }
        assert!(storm.faults.has_silent_window());
    }

    #[test]
    fn straggler_delays_dominate_the_base_jitter() {
        let plan = ChaosPlan::new(16, 40, 909);
        let storm = plan.materialize();
        let strag = *storm.stragglers.first().expect("16 × 0.125 = 2 stragglers");
        let other = (0..16).find(|t| storm.stragglers.binary_search(t).is_err()).unwrap();
        assert!(storm.delay.mean(strag) > storm.delay.mean(other));
    }

    #[test]
    fn validate_rejects_unsound_plans() {
        let mut plan = ChaosPlan::new(8, 10, 1);
        // Default flap windows (start 4 + down 8 = 12) overrun a 10-iter
        // budget: the wave would never rejoin.
        assert!(plan.validate().is_err());
        plan.iters_per_node = 40;
        plan.validate().unwrap();
        // A semisync window between the bound and the eviction threshold
        // can park a node behind its own stalled gate slot.
        plan.schedule = ScheduleChoice::SemiSync { staleness_bound: 2 };
        plan.storm.flap_down_for = 3;
        assert!(plan.validate().is_err());
        plan.storm.flap_down_for = 8;
        plan.validate().unwrap();
        plan.storm.drop_p = 1.0;
        assert!(plan.validate().is_err());
    }

    #[test]
    fn schedule_choice_maps_to_schedules() {
        assert_eq!(ScheduleChoice::Async.to_schedule().name(), "amtl");
        assert_eq!(ScheduleChoice::Synchronized.to_schedule().name(), "smtl");
        let ss = ScheduleChoice::SemiSync { staleness_bound: 3 };
        assert_eq!(ss.to_schedule().name(), "semisync");
        assert_eq!(ss.staleness_bound(), Some(3));
        assert_eq!(ScheduleChoice::Async.staleness_bound(), None);
        assert!(ScheduleChoice::Async.registers_membership());
        assert!(!ScheduleChoice::Synchronized.registers_membership());
    }
}
