//! Chaos-at-scale harness: seed-reproducible fault storms with
//! machine-checked invariants.
//!
//! The harness has three layers:
//!
//! * [`plan`] — a [`ChaosPlan`] is the complete, declarative description
//!   of a storm (swarm shape, schedule, transport, [`StormSpec`] fault
//!   mix). `materialize()` turns it into concrete [`FaultModel`] /
//!   [`DelayModel`] instances deterministically from the plan's single
//!   seed, so every failure reproduces from one printed integer.
//! * [`storm`] — [`run_storm`] / [`run_resumed_storm`] execute the plan
//!   (one server lifetime, or two joined by checkpoint/WAL recovery)
//!   alongside an undisturbed reference run, collecting the JSONL obs
//!   traces and [`RunResult`]s as evidence.
//! * [`invariants`] — [`check_invariants`] replays that evidence and
//!   machine-asserts four families: **exactly-once** commit application,
//!   **convergence** within tolerance of the reference, **membership**
//!   (eviction/re-register bookkeeping balances), and the **staleness
//!   bound** over the never-flapped cohort's commit order.
//!
//! The harness is exercised in-tree (`cargo test`), by the CI smoke
//! storm (`cargo run --example chaos_run -- --quick`), and by the
//! opt-in soak suite (`AMTL_SOAK=1 cargo test --test soak_chaos`).
//! See `docs/TESTING.md` for the invariant catalog and seed-reproduction
//! workflow.
//!
//! [`FaultModel`]: crate::net::FaultModel
//! [`DelayModel`]: crate::net::DelayModel
//! [`RunResult`]: crate::coordinator::RunResult

pub mod invariants;
pub mod plan;
pub mod storm;

pub use invariants::{check_invariants, Expectations, Leg, Violation};
pub use plan::{ChaosPlan, MaterializedStorm, ScheduleChoice, StormSpec};
pub use storm::{run_resumed_storm, run_storm, StormReport};
