//! Drive a [`ChaosPlan`]: reference run, storm run(s), invariant check.
//!
//! [`run_storm`] executes the plan as one uninterrupted server lifetime;
//! [`run_resumed_storm`] splits the same budget across two lifetimes
//! joined by the checkpoint/WAL recovery path (half the budget, polite
//! shutdown, `resume` into the same directory), so the exactly-once and
//! membership invariants are checked *across* a restart — the in-process
//! counterpart of the SIGKILL tests in `rust/tests/integration_persist.rs`.
//! Either way the storm's evidence (JSONL traces + [`RunResult`]s) is
//! handed to [`check_invariants`] and the outcome is a [`StormReport`]
//! whose `repro_line` reproduces any failure from the printed seed.

use super::invariants::{check_invariants, Expectations, Leg, Violation};
use super::plan::{ChaosPlan, MaterializedStorm};
use crate::coordinator::{MtlProblem, RunResult, Session};
use crate::obs::TraceWriter;
use crate::transport::TransportKind;
use anyhow::{Context, Result};
use std::path::{Path, PathBuf};
use std::sync::Arc;

/// Everything a storm produced: the runs, their evidence, and the
/// verdict. Failures print [`StormReport::repro_line`] so the exact
/// storm reruns from one seed.
#[derive(Debug)]
pub struct StormReport {
    /// The plan that ran.
    pub plan: ChaosPlan,
    /// Nodes the storm flapped (silent crash/restart windows).
    pub flapped: Vec<usize>,
    /// Nodes the storm put behind the slow link.
    pub stragglers: Vec<usize>,
    /// The undisturbed reference run (same schedule, seed, budget).
    pub reference: RunResult,
    /// The storm run's legs, in order (one, or two when resumed).
    pub legs: Vec<RunResult>,
    /// One JSONL trace per leg, same order.
    pub trace_paths: Vec<PathBuf>,
    /// Final objective of the reference run.
    pub objective_reference: f64,
    /// Final objective of the storm run (its last leg).
    pub objective_chaos: f64,
    /// Every invariant violation found (empty = the storm passed).
    pub violations: Vec<Violation>,
}

impl StormReport {
    /// True when every invariant held.
    pub fn passed(&self) -> bool {
        self.violations.is_empty()
    }

    /// The one line to paste to rerun this exact storm.
    pub fn repro_line(&self) -> String {
        format!(
            "chaos repro: seed={} nodes={} iters={} schedule={} transport={} legs={}",
            self.plan.seed,
            self.plan.nodes,
            self.plan.iters_per_node,
            self.plan.schedule.name(),
            match self.plan.transport {
                TransportKind::InProc => "inproc",
                TransportKind::Tcp => "tcp",
            },
            self.legs.len(),
        )
    }

    /// One-line outcome summary (for logs and the example's output).
    pub fn summary(&self) -> String {
        let last = self.legs.last().expect("a storm has at least one leg");
        format!(
            "{}: {} nodes, {} updates, {} dropped, evicted {:?}, \
             objective {:.4} vs reference {:.4} — {}",
            self.plan.schedule.name(),
            self.plan.nodes,
            last.updates,
            last.dropped_updates,
            last.evicted_nodes,
            self.objective_chaos,
            self.objective_reference,
            if self.passed() {
                "all invariants held".to_string()
            } else {
                format!("{} VIOLATION(S)", self.violations.len())
            }
        )
    }
}

/// Run the plan as one uninterrupted server lifetime.
pub fn run_storm(
    problem: &MtlProblem,
    plan: &ChaosPlan,
    artifact_dir: &Path,
) -> Result<StormReport> {
    run(problem, plan, artifact_dir, false)
}

/// Run the plan across a checkpoint/WAL restart: the first leg runs half
/// the budget with durability on, the second resumes from the recovered
/// horizon and finishes it. Invariants are checked over both legs'
/// concatenated evidence.
pub fn run_resumed_storm(
    problem: &MtlProblem,
    plan: &ChaosPlan,
    artifact_dir: &Path,
) -> Result<StormReport> {
    run(problem, plan, artifact_dir, true)
}

fn run(
    problem: &MtlProblem,
    plan: &ChaosPlan,
    artifact_dir: &Path,
    resumed: bool,
) -> Result<StormReport> {
    plan.validate()?;
    anyhow::ensure!(
        problem.t() == plan.nodes,
        "plan is for {} nodes but the problem has {} tasks",
        plan.nodes,
        problem.t()
    );
    std::fs::create_dir_all(artifact_dir)
        .with_context(|| format!("creating artifact dir {}", artifact_dir.display()))?;
    let storm = plan.materialize();

    // The undisturbed twin: same schedule, seed and budget; no faults,
    // no delays, shared-memory transport. Its objective anchors the
    // convergence invariant.
    let reference = Session::builder(problem)
        .iters_per_node(plan.iters_per_node)
        .eta_k(plan.eta_k)
        .seed(plan.seed)
        .schedule_box(plan.schedule.to_schedule())
        .build()?
        .run()?;

    let mut legs = Vec::new();
    let mut trace_paths = Vec::new();
    if resumed {
        let ckpt = artifact_dir.join(format!("ckpt-{}-{}", plan.schedule.name(), plan.seed));
        // A fresh directory per storm: recovery must see only this
        // storm's snapshots and WAL.
        if ckpt.exists() {
            std::fs::remove_dir_all(&ckpt)?;
        }
        let first_budget = (plan.iters_per_node / 2).max(1);
        let leg1 = run_leg(
            problem,
            plan,
            &storm,
            &leg_trace_path(artifact_dir, plan, 0),
            first_budget,
            Some(&ckpt),
            false,
        )?;
        trace_paths.push(leg_trace_path(artifact_dir, plan, 0));
        legs.push(leg1);
        let leg2 = run_leg(
            problem,
            plan,
            &storm,
            &leg_trace_path(artifact_dir, plan, 1),
            plan.iters_per_node,
            Some(&ckpt),
            true,
        )?;
        trace_paths.push(leg_trace_path(artifact_dir, plan, 1));
        legs.push(leg2);
    } else {
        let leg = run_leg(
            problem,
            plan,
            &storm,
            &leg_trace_path(artifact_dir, plan, 0),
            plan.iters_per_node,
            None,
            false,
        )?;
        trace_paths.push(leg_trace_path(artifact_dir, plan, 0));
        legs.push(leg);
    }

    let objective_reference = problem.objective(&reference.w_final);
    let objective_chaos =
        problem.objective(&legs.last().expect("at least one leg").w_final);
    // Strict eviction/re-register interleaving is provable only when
    // every silent window is long enough (≥ 4 heartbeat-length sleeps,
    // past the 3× eviction timeout) to guarantee eviction before the
    // node's unconditional rejoin register. A resumed leg breaks that
    // proof for flapped nodes: the restart lands at the applied-commit
    // horizon, which can sit *inside* the k-indexed window, leaving only
    // a short tail of silence — so resumed storms with flaps fall back
    // to the one-sided balance (evictions ≤ registrations).
    let expect = Expectations {
        nodes: plan.nodes,
        staleness_bound: plan.schedule.staleness_bound(),
        cohort: plan.cohort(&storm),
        convergence_tol: plan.convergence_tol,
        membership: plan.schedule.registers_membership(),
        evictions_guaranteed: storm.flapped.is_empty()
            || (!resumed && plan.storm.flap_down_for >= 4),
    };
    let leg_refs: Vec<Leg<'_>> = legs
        .iter()
        .zip(&trace_paths)
        .map(|(result, trace)| Leg { trace, result })
        .collect();
    let violations =
        check_invariants(&leg_refs, objective_chaos, objective_reference, &expect)?;

    Ok(StormReport {
        plan: plan.clone(),
        flapped: storm.flapped,
        stragglers: storm.stragglers,
        reference,
        legs,
        trace_paths,
        objective_reference,
        objective_chaos,
        violations,
    })
}

fn leg_trace_path(artifact_dir: &Path, plan: &ChaosPlan, leg: usize) -> PathBuf {
    artifact_dir.join(format!(
        "storm-{}-{}-leg{leg}.trace.jsonl",
        plan.schedule.name(),
        plan.seed
    ))
}

fn run_leg(
    problem: &MtlProblem,
    plan: &ChaosPlan,
    storm: &MaterializedStorm,
    trace_path: &Path,
    iters: usize,
    checkpoint_dir: Option<&Path>,
    resume: bool,
) -> Result<RunResult> {
    let trace = Arc::new(TraceWriter::create(trace_path)?);
    let mut builder = Session::builder(problem)
        .iters_per_node(iters)
        .eta_k(plan.eta_k)
        .seed(plan.seed)
        .time_scale(plan.time_scale)
        .delay(storm.delay.clone())
        .faults(storm.faults.clone())
        .heartbeat(Some(plan.heartbeat))
        .trace(Some(trace))
        .transport(plan.transport)
        .schedule_box(plan.schedule.to_schedule());
    if let Some(dir) = checkpoint_dir {
        builder = builder.checkpoint_dir(Some(dir.to_path_buf())).checkpoint_every(4);
    }
    builder.resume(resume).build()?.run()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::chaos::plan::ScheduleChoice;
    use crate::data::synthetic;
    use crate::optim::prox::RegularizerKind;
    use crate::util::Rng;

    fn problem(seed: u64, t: usize) -> MtlProblem {
        let mut rng = Rng::new(seed);
        let ds = synthetic::lowrank_regression(&vec![24; t], 6, 2, 0.05, &mut rng);
        MtlProblem::new(ds, RegularizerKind::Nuclear, 0.2, 0.5, &mut rng)
    }

    fn artifact_dir(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join("amtl-chaos-storm-tests").join(name);
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    #[test]
    fn mini_async_storm_passes_all_invariants() {
        let p = problem(3100, 8);
        let plan = ChaosPlan::new(8, 24, 3100);
        let report = run_storm(&p, &plan, &artifact_dir("mini-async")).unwrap();
        assert!(report.passed(), "{:?}\n{}", report.violations, report.repro_line());
        let last = report.legs.last().unwrap();
        assert!(last.updates > 0);
        // The wave flapped and came back: evictions happened, nobody is
        // still evicted at the end, and the report knows who flapped.
        assert_eq!(report.flapped.len(), plan.flap_count());
        assert!(last.evicted_nodes.is_empty(), "evicted: {:?}", last.evicted_nodes);
        assert!(report.trace_paths[0].exists());
        assert!(report.repro_line().contains("seed=3100"));
    }

    #[test]
    fn mini_resumed_storm_checks_across_the_restart() {
        let p = problem(3200, 6);
        let mut plan = ChaosPlan::new(6, 24, 3200);
        plan.storm.flap_start = 2;
        plan.storm.flap_down_for = 6;
        let report = run_resumed_storm(&p, &plan, &artifact_dir("mini-resumed")).unwrap();
        assert!(report.passed(), "{:?}\n{}", report.violations, report.repro_line());
        assert_eq!(report.legs.len(), 2);
        assert_eq!(report.trace_paths.len(), 2);
        // The second leg actually recovered durable state.
        assert!(report.legs[1].wal_replayed > 0 || report.legs[1].updates > 0);
        assert!(report.repro_line().contains("legs=2"));
    }

    #[test]
    fn storm_rejects_mismatched_problem_shape() {
        let p = problem(3300, 4);
        let plan = ChaosPlan::new(8, 24, 3300);
        let err = run_storm(&p, &plan, &artifact_dir("mismatch")).unwrap_err();
        assert!(format!("{err}").contains("nodes"), "{err}");
    }

    #[test]
    fn semisync_storm_checks_the_staleness_bound() {
        let p = problem(3400, 8);
        let mut plan = ChaosPlan::new(8, 24, 3400);
        plan.schedule = ScheduleChoice::SemiSync { staleness_bound: 4 };
        let report = run_storm(&p, &plan, &artifact_dir("mini-semisync")).unwrap();
        assert!(report.passed(), "{:?}\n{}", report.violations, report.repro_line());
        assert!(report.summary().contains("semisync"));
    }
}
