//! Machine-checked invariants over a chaos run's evidence.
//!
//! After a storm, [`check_invariants`] replays the run's JSONL trace
//! (one or more *legs* when the server was killed and resumed) against
//! the [`RunResult`]s and asserts four families of invariants:
//!
//! 1. **Exactly-once** — every applied commit carries a per-node
//!    activation counter that is strictly increasing across all legs
//!    (no duplicate application, ever — including transport retries and
//!    post-restart replays), and the trace's applied-commit counts agree
//!    with the workers' own accounting.
//! 2. **Convergence** — the storm run's final objective lands within a
//!    relative tolerance of an undisturbed reference run.
//! 3. **Membership balance** — every commit is preceded by a
//!    registration, membership generations count up by exactly one per
//!    (re-)registration, evictions and rejoins interleave (`R (E R)* E?`
//!    per node per leg), and the server's final evicted set is exactly
//!    the set of nodes whose last membership event is an eviction.
//! 4. **Staleness bound** — under `SemiSync`, commits from the *cohort*
//!    (nodes never silently down) respect the bound in trace order: a
//!    cohort commit of activation `k` after another cohort commit of
//!    activation `k′` implies `k ≥ k′ − b`. (Trace order is emission
//!    order — the writer serializes — and a node only commits `k` after
//!    the gate proved every live node had completed `k − b`.) Flapped
//!    nodes are excluded: eviction removes them from the gate, so they
//!    may lawfully burst old activations when they rejoin.
//!
//! Violations are *data*, not panics: callers print them next to the
//! reproducing seed and fail their own assertion.

use crate::coordinator::RunResult;
use crate::util::json::Json;
use anyhow::{Context, Result};
use std::fmt;
use std::path::Path;

/// One failed invariant, with enough detail to debug from the artifact.
#[derive(Clone, Debug)]
pub struct Violation {
    /// Which invariant family failed:
    /// `"exactly-once" | "convergence" | "membership" | "staleness-bound"`.
    pub invariant: &'static str,
    /// Human-readable specifics (node, activation, counts, ...).
    pub detail: String,
}

impl fmt::Display for Violation {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "[{}] {}", self.invariant, self.detail)
    }
}

/// What the checker is entitled to assume about the run it is checking.
#[derive(Clone, Debug)]
pub struct Expectations {
    /// Swarm size (node ids in the trace must be below this).
    pub nodes: usize,
    /// The `SemiSync` bound, when that schedule was active.
    pub staleness_bound: Option<u64>,
    /// Nodes never targeted by a silent window (invariant 4's cohort).
    pub cohort: Vec<usize>,
    /// Relative tolerance for invariant 2.
    pub convergence_tol: f64,
    /// Whether workers register with the membership registry (true for
    /// the free-running schedules under a heartbeat; false for
    /// `Synchronized`, whose round loop never registers — there
    /// invariant 3 degenerates to "no membership traffic at all").
    pub membership: bool,
    /// True when every silent window in the storm spans at least four
    /// heartbeat-length sleeps, so a returning node is provably evicted
    /// (by a peer's sweep, or by its own re-register's sweep of its
    /// `> 3×` stale slot) *before* it rejoins. Only then does the strict
    /// `R (E R)* E?` interleave hold; a node back from a shorter window
    /// re-registers without an eviction, and the checker must fall back
    /// to the one-sided `evictions ≤ registrations`.
    pub evictions_guaranteed: bool,
}

/// One server lifetime: a trace file plus the [`RunResult`] of the
/// session that produced it. A kill/resume chaos run hands the checker
/// its legs in order; an uninterrupted run is a single leg.
pub struct Leg<'a> {
    /// The leg's JSONL trace.
    pub trace: &'a Path,
    /// The leg's run outcome.
    pub result: &'a RunResult,
}

/// Per-leg evidence distilled from the trace.
struct LegEvidence {
    /// `(node, k)` for every applied commit, in emission order.
    commits: Vec<(usize, u64)>,
    /// Per node: applied-commit count.
    commit_counts: Vec<u64>,
    /// Per node: `generation` extras of its register events, in order.
    generations: Vec<Vec<u64>>,
    /// Per node: eviction-event count.
    evictions: Vec<u64>,
    /// Per node: whether the last membership event was a registration
    /// (`Some(true)`), an eviction (`Some(false)`), or absent.
    last_member_was_register: Vec<Option<bool>>,
    /// Per node: trace index of the first commit / first register.
    first_commit_at: Vec<Option<usize>>,
    first_register_at: Vec<Option<usize>>,
}

/// Parse one leg's trace. A torn *final* line is tolerated — a
/// SIGKILL'd server can die mid-write — but garbage anywhere else is an
/// error (the artifact itself is corrupt, not merely the run wrong).
fn read_leg(trace: &Path, nodes: usize) -> Result<LegEvidence> {
    let text = std::fs::read_to_string(trace)
        .with_context(|| format!("reading chaos trace {}", trace.display()))?;
    let lines: Vec<&str> = text.lines().collect();
    let mut ev = LegEvidence {
        commits: Vec::new(),
        commit_counts: vec![0; nodes],
        generations: vec![Vec::new(); nodes],
        evictions: vec![0; nodes],
        last_member_was_register: vec![None; nodes],
        first_commit_at: vec![None; nodes],
        first_register_at: vec![None; nodes],
    };
    for (i, line) in lines.iter().enumerate() {
        let v = match Json::parse(line) {
            Ok(v) => v,
            Err(_) if i + 1 == lines.len() => break, // torn tail of a killed leg
            Err(e) => {
                anyhow::bail!("corrupt trace line {} in {}: {e}", i + 1, trace.display())
            }
        };
        let event = v.get("event").and_then(Json::as_str).unwrap_or_default();
        let node = v.get("node").and_then(Json::as_usize);
        match (event, node) {
            ("commit", Some(t)) => {
                anyhow::ensure!(t < nodes, "commit from out-of-range node {t}");
                let k = v
                    .get("k")
                    .and_then(Json::as_f64)
                    .map(|x| x as u64)
                    .context("commit event without activation counter")?;
                ev.commits.push((t, k));
                ev.commit_counts[t] += 1;
                ev.first_commit_at[t].get_or_insert(i);
            }
            ("register", Some(t)) => {
                anyhow::ensure!(t < nodes, "register from out-of-range node {t}");
                let generation =
                    v.get("generation").and_then(Json::as_f64).map(|x| x as u64).unwrap_or(0);
                ev.generations[t].push(generation);
                ev.last_member_was_register[t] = Some(true);
                ev.first_register_at[t].get_or_insert(i);
            }
            ("eviction", Some(t)) => {
                anyhow::ensure!(t < nodes, "eviction of out-of-range node {t}");
                ev.evictions[t] += 1;
                ev.last_member_was_register[t] = Some(false);
            }
            _ => {} // activation / prox / checkpoint: not evidence here
        }
    }
    Ok(ev)
}

/// Run every invariant over the legs' evidence. Returns the (possibly
/// empty) violation list; `Err` means the evidence itself was unusable.
pub fn check_invariants(
    legs: &[Leg<'_>],
    objective_chaos: f64,
    objective_reference: f64,
    expect: &Expectations,
) -> Result<Vec<Violation>> {
    anyhow::ensure!(!legs.is_empty(), "invariant check needs at least one leg");
    let mut violations = Vec::new();
    let evidence: Vec<LegEvidence> = legs
        .iter()
        .map(|leg| read_leg(leg.trace, expect.nodes))
        .collect::<Result<_>>()?;

    check_exactly_once(legs, &evidence, expect, &mut violations);
    check_convergence(objective_chaos, objective_reference, expect, &mut violations);
    check_membership(legs, &evidence, expect, &mut violations);
    if let Some(bound) = expect.staleness_bound {
        check_staleness_bound(&evidence, bound, expect, &mut violations);
    }
    Ok(violations)
}

/// Invariant 1: strictly increasing per-node activation counters across
/// all legs, and trace counts == worker counts == run total, per leg.
fn check_exactly_once(
    legs: &[Leg<'_>],
    evidence: &[LegEvidence],
    expect: &Expectations,
    out: &mut Vec<Violation>,
) {
    let mut last_k: Vec<Option<u64>> = vec![None; expect.nodes];
    for (leg_i, ev) in evidence.iter().enumerate() {
        for &(t, k) in &ev.commits {
            if let Some(prev) = last_k[t] {
                if k <= prev {
                    out.push(Violation {
                        invariant: "exactly-once",
                        detail: format!(
                            "node {t} applied activation {k} after {prev} \
                             (leg {leg_i}): duplicate or out-of-order application"
                        ),
                    });
                }
            }
            last_k[t] = Some(k);
        }
        let result = legs[leg_i].result;
        for t in 0..expect.nodes {
            let traced = ev.commit_counts[t];
            let counted = result.updates_per_node.get(t).copied().unwrap_or(0);
            if traced != counted {
                out.push(Violation {
                    invariant: "exactly-once",
                    detail: format!(
                        "leg {leg_i} node {t}: trace applied {traced} commits \
                         but the worker counted {counted}"
                    ),
                });
            }
        }
        let traced_total: u64 = ev.commit_counts.iter().sum();
        if traced_total != result.updates {
            out.push(Violation {
                invariant: "exactly-once",
                detail: format!(
                    "leg {leg_i}: trace applied {traced_total} commits \
                     but the run reported {} updates",
                    result.updates
                ),
            });
        }
    }
}

/// Invariant 2: the storm lands within tolerance of the reference.
fn check_convergence(
    objective_chaos: f64,
    objective_reference: f64,
    expect: &Expectations,
    out: &mut Vec<Violation>,
) {
    if !objective_chaos.is_finite() || !objective_reference.is_finite() {
        out.push(Violation {
            invariant: "convergence",
            detail: format!(
                "non-finite objective (chaos {objective_chaos}, \
                 reference {objective_reference})"
            ),
        });
        return;
    }
    let limit = objective_reference * (1.0 + expect.convergence_tol) + 1e-9;
    if objective_chaos > limit {
        out.push(Violation {
            invariant: "convergence",
            detail: format!(
                "chaos objective {objective_chaos:.6} exceeds \
                 {:.0}%-tolerance limit {limit:.6} \
                 (reference {objective_reference:.6})",
                expect.convergence_tol * 100.0
            ),
        });
    }
}

/// Invariant 3: registrations precede commits, generations count up by
/// one, evictions interleave with rejoins, and the final evicted set
/// matches the trace's last membership event per node.
fn check_membership(
    legs: &[Leg<'_>],
    evidence: &[LegEvidence],
    expect: &Expectations,
    out: &mut Vec<Violation>,
) {
    if !expect.membership {
        // The round-based schedule never registers: any membership
        // traffic at all means a layer below acquired a behavior it
        // must not have.
        for (leg_i, ev) in evidence.iter().enumerate() {
            let regs: usize = ev.generations.iter().map(Vec::len).sum();
            let evs: u64 = ev.evictions.iter().sum();
            if regs > 0 || evs > 0 {
                out.push(Violation {
                    invariant: "membership",
                    detail: format!(
                        "leg {leg_i}: {regs} registrations / {evs} evictions \
                         under a schedule with no membership traffic"
                    ),
                });
            }
        }
        return;
    }
    for (leg_i, ev) in evidence.iter().enumerate() {
        for t in 0..expect.nodes {
            match (ev.first_commit_at[t], ev.first_register_at[t]) {
                (Some(c), Some(r)) if r > c => out.push(Violation {
                    invariant: "membership",
                    detail: format!(
                        "leg {leg_i} node {t}: first commit (trace line {}) \
                         precedes first registration (line {})",
                        c + 1,
                        r + 1
                    ),
                }),
                (Some(c), None) => out.push(Violation {
                    invariant: "membership",
                    detail: format!(
                        "leg {leg_i} node {t}: committed (trace line {}) \
                         without ever registering",
                        c + 1
                    ),
                }),
                _ => {}
            }
            // Each leg's registry starts fresh, so generations within a
            // leg must be exactly 1, 2, 3, ... — a gap means a lost
            // registration, a repeat means a double-counted one.
            for (i, &generation) in ev.generations[t].iter().enumerate() {
                let want = i as u64 + 1;
                if generation != want {
                    out.push(Violation {
                        invariant: "membership",
                        detail: format!(
                            "leg {leg_i} node {t}: registration #{want} \
                             carried generation {generation}"
                        ),
                    });
                }
            }
            // Per node per leg the membership history is R (E R)* E?:
            // joins and evictions may differ by at most the leading join.
            // (Only one-sided when short silent windows allow a rejoin
            // with no eviction in between — see `evictions_guaranteed`.)
            let regs = ev.generations[t].len() as u64;
            let evs = ev.evictions[t];
            let balanced = if expect.evictions_guaranteed {
                regs == evs || regs == evs + 1
            } else {
                evs <= regs
            };
            if !balanced {
                out.push(Violation {
                    invariant: "membership",
                    detail: format!(
                        "leg {leg_i} node {t}: {regs} registrations vs \
                         {evs} evictions cannot interleave as join/evict/rejoin"
                    ),
                });
            }
        }
    }
    // The last leg's final evicted set must be exactly the nodes whose
    // membership history ends on an eviction.
    let final_leg = evidence.last().expect("checked non-empty");
    let final_result = legs.last().expect("checked non-empty").result;
    for t in 0..expect.nodes {
        let trace_says_evicted = final_leg.last_member_was_register[t] == Some(false);
        let result_says_evicted = final_result.evicted_nodes.contains(&t);
        if trace_says_evicted != result_says_evicted {
            out.push(Violation {
                invariant: "membership",
                detail: format!(
                    "node {t}: trace ends {} but the run reports it {}",
                    if trace_says_evicted { "evicted" } else { "re-registered" },
                    if result_says_evicted { "evicted" } else { "live/left" }
                ),
            });
        }
    }
}

/// Invariant 4: cohort commits respect the staleness bound in trace
/// order. For each cohort commit of activation `k`, every *earlier*
/// cohort commit's activation `k′` satisfies `k ≥ k′ − b` — because the
/// committer passed the gate for `k` only after all live nodes had
/// completed `k′ − b`, and commits precede completions.
fn check_staleness_bound(
    evidence: &[LegEvidence],
    bound: u64,
    expect: &Expectations,
    out: &mut Vec<Violation>,
) {
    let in_cohort = |t: usize| expect.cohort.binary_search(&t).is_ok();
    for (leg_i, ev) in evidence.iter().enumerate() {
        // The gate is rebuilt (and primed from the durable horizon) per
        // server lifetime, so the ordering argument resets per leg.
        let mut running_max: Option<u64> = None;
        for &(t, k) in &ev.commits {
            if !in_cohort(t) {
                continue;
            }
            if let Some(max_k) = running_max {
                if k.saturating_add(bound) < max_k {
                    out.push(Violation {
                        invariant: "staleness-bound",
                        detail: format!(
                            "leg {leg_i}: cohort node {t} committed activation {k} \
                             after activation {max_k} was already committed \
                             (bound {bound})"
                        ),
                    });
                }
            }
            running_max = Some(running_max.map_or(k, |m| m.max(k)));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Mat;
    use std::path::PathBuf;
    use std::time::Duration;

    fn result(per_node: &[u64], evicted: &[usize]) -> RunResult {
        RunResult {
            method: "amtl".into(),
            wall_time: Duration::ZERO,
            v_final: Mat::zeros(1, per_node.len()),
            w_final: Mat::zeros(1, per_node.len()),
            updates: per_node.iter().sum(),
            updates_per_node: per_node.to_vec(),
            prox_count: 0,
            coalesced_updates: 0,
            svd_refreshes: 0,
            trajectory: Vec::new(),
            mean_delay_secs: 0.0,
            dropped_updates: 0,
            crashed_nodes: Vec::new(),
            compute_secs: 0.0,
            backward_wait_secs: 0.0,
            commit_wait_secs: 0.0,
            mean_staleness: 0.0,
            staleness_p50: 0,
            staleness_p99: 0,
            staleness_max: 0,
            checkpoints_written: 0,
            wal_replayed: 0,
            evicted_nodes: evicted.to_vec(),
        }
    }

    fn expectations(nodes: usize) -> Expectations {
        Expectations {
            nodes,
            staleness_bound: None,
            cohort: (0..nodes).collect(),
            convergence_tol: 0.3,
            membership: true,
            evictions_guaranteed: true,
        }
    }

    fn commit(t: usize, k: u64) -> String {
        format!(r#"{{"ts_us":1,"event":"commit","node":{t},"k":{k},"version":1,"staleness":0}}"#)
    }

    fn register(t: usize, generation: u64) -> String {
        format!(
            r#"{{"ts_us":1,"event":"register","node":{t},"generation":{generation},"col_version":0}}"#
        )
    }

    fn eviction(t: usize) -> String {
        format!(r#"{{"ts_us":1,"event":"eviction","node":{t}}}"#)
    }

    fn write_trace(name: &str, lines: &[String]) -> PathBuf {
        let dir = std::env::temp_dir().join("amtl-chaos-invariant-tests");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join(format!("{name}.jsonl"));
        std::fs::write(&path, lines.join("\n")).unwrap();
        path
    }

    #[test]
    fn clean_run_passes_all_invariants() {
        let lines = vec![
            register(0, 1),
            register(1, 1),
            commit(0, 0),
            commit(1, 0),
            commit(0, 1),
            eviction(1),
            register(1, 2),
            commit(1, 1),
        ];
        let path = write_trace("clean", &lines);
        let r = result(&[2, 2], &[]);
        let v = check_invariants(
            &[Leg { trace: &path, result: &r }],
            1.0,
            1.0,
            &expectations(2),
        )
        .unwrap();
        assert!(v.is_empty(), "{v:?}");
    }

    #[test]
    fn duplicate_application_is_caught() {
        let lines =
            vec![register(0, 1), commit(0, 0), commit(0, 1), commit(0, 1)];
        let path = write_trace("dup", &lines);
        let r = result(&[3], &[]);
        let v = check_invariants(
            &[Leg { trace: &path, result: &r }],
            1.0,
            1.0,
            &expectations(1),
        )
        .unwrap();
        assert!(
            v.iter().any(|v| v.invariant == "exactly-once" && v.detail.contains("duplicate")),
            "{v:?}"
        );
    }

    #[test]
    fn count_mismatch_is_caught() {
        let lines = vec![register(0, 1), commit(0, 0)];
        let path = write_trace("count", &lines);
        let r = result(&[2], &[]); // worker claims 2, trace has 1
        let v = check_invariants(
            &[Leg { trace: &path, result: &r }],
            1.0,
            1.0,
            &expectations(1),
        )
        .unwrap();
        assert!(v.iter().any(|v| v.invariant == "exactly-once"), "{v:?}");
    }

    #[test]
    fn commit_without_registration_is_caught() {
        let lines = vec![commit(0, 0), register(0, 1)];
        let path = write_trace("noreg", &lines);
        let r = result(&[1], &[]);
        let v = check_invariants(
            &[Leg { trace: &path, result: &r }],
            1.0,
            1.0,
            &expectations(1),
        )
        .unwrap();
        assert!(v.iter().any(|v| v.invariant == "membership"), "{v:?}");
    }

    #[test]
    fn eviction_bookkeeping_must_balance() {
        // Two evictions but only one (re-)registration: impossible history.
        let lines = vec![register(0, 1), eviction(0), eviction(0)];
        let path = write_trace("balance", &lines);
        let r = result(&[0], &[0]);
        let v = check_invariants(
            &[Leg { trace: &path, result: &r }],
            1.0,
            1.0,
            &expectations(1),
        )
        .unwrap();
        assert!(
            v.iter().any(|v| v.invariant == "membership" && v.detail.contains("interleave")),
            "{v:?}"
        );
        // Final-state disagreement: trace ends evicted, result says live.
        let lines = vec![register(0, 1), eviction(0)];
        let path = write_trace("finalstate", &lines);
        let r = result(&[0], &[]);
        let v = check_invariants(
            &[Leg { trace: &path, result: &r }],
            1.0,
            1.0,
            &expectations(1),
        )
        .unwrap();
        assert!(
            v.iter().any(|v| v.detail.contains("re-registered") || v.detail.contains("evicted")),
            "{v:?}"
        );
        // A rejoin with no eviction in between is lawful exactly when
        // short silent windows make eviction non-guaranteed.
        let lines = vec![register(0, 1), register(0, 2)];
        let path = write_trace("shortwindow", &lines);
        let r = result(&[0], &[]);
        let legs = [Leg { trace: &path, result: &r }];
        let mut relaxed = expectations(1);
        relaxed.evictions_guaranteed = false;
        let v = check_invariants(&legs, 1.0, 1.0, &relaxed).unwrap();
        assert!(v.is_empty(), "{v:?}");
        let strict = check_invariants(&legs, 1.0, 1.0, &expectations(1)).unwrap();
        assert!(strict.iter().any(|v| v.invariant == "membership"), "{strict:?}");
    }

    #[test]
    fn generation_gaps_are_caught() {
        let lines = vec![register(0, 1), eviction(0), register(0, 3)];
        let path = write_trace("gen", &lines);
        let r = result(&[0], &[]);
        let v = check_invariants(
            &[Leg { trace: &path, result: &r }],
            1.0,
            1.0,
            &expectations(1),
        )
        .unwrap();
        assert!(
            v.iter().any(|v| v.invariant == "membership" && v.detail.contains("generation")),
            "{v:?}"
        );
    }

    #[test]
    fn staleness_bound_violation_is_caught_only_for_cohort() {
        let lines = vec![
            register(0, 1),
            register(1, 1),
            register(2, 1),
            commit(0, 10),
            commit(1, 0), // 0 + bound(2) < 10: violation if node 1 in cohort
            commit(2, 0), // node 2 excluded from cohort: lawful burst
        ];
        let path = write_trace("stale", &lines);
        let r = result(&[1, 1, 1], &[]);
        let mut expect = expectations(3);
        expect.staleness_bound = Some(2);
        expect.cohort = vec![0, 1];
        let v = check_invariants(&[Leg { trace: &path, result: &r }], 1.0, 1.0, &expect)
            .unwrap();
        let stale: Vec<_> =
            v.iter().filter(|v| v.invariant == "staleness-bound").collect();
        assert_eq!(stale.len(), 1, "{v:?}");
        assert!(stale[0].detail.contains("node 1"), "{stale:?}");
    }

    #[test]
    fn convergence_tolerance_is_enforced() {
        let lines = vec![register(0, 1), commit(0, 0)];
        let path = write_trace("conv", &lines);
        let r = result(&[1], &[]);
        let legs = [Leg { trace: &path, result: &r }];
        let expect = expectations(1);
        let ok = check_invariants(&legs, 1.2, 1.0, &expect).unwrap();
        assert!(ok.iter().all(|v| v.invariant != "convergence"), "{ok:?}");
        let bad = check_invariants(&legs, 1.5, 1.0, &expect).unwrap();
        assert!(bad.iter().any(|v| v.invariant == "convergence"), "{bad:?}");
        let nan = check_invariants(&legs, f64::NAN, 1.0, &expect).unwrap();
        assert!(nan.iter().any(|v| v.invariant == "convergence"), "{nan:?}");
    }

    #[test]
    fn multi_leg_counters_continue_across_restart() {
        // Leg 1 applies activations 0..2 for node 0; the resumed leg must
        // continue above them. A resumed leg that replayed an old k is a
        // duplicate application even though it is leg-locally increasing.
        let leg1 = write_trace("leg1", &[register(0, 1), commit(0, 0), commit(0, 1)]);
        let leg2_ok = write_trace("leg2ok", &[register(0, 1), commit(0, 2)]);
        let leg2_bad = write_trace("leg2bad", &[register(0, 1), commit(0, 1)]);
        let r1 = result(&[2], &[]);
        let r2 = result(&[1], &[]);
        let expect = expectations(1);
        let ok = check_invariants(
            &[
                Leg { trace: &leg1, result: &r1 },
                Leg { trace: &leg2_ok, result: &r2 },
            ],
            1.0,
            1.0,
            &expect,
        )
        .unwrap();
        assert!(ok.is_empty(), "{ok:?}");
        let bad = check_invariants(
            &[
                Leg { trace: &leg1, result: &r1 },
                Leg { trace: &leg2_bad, result: &r2 },
            ],
            1.0,
            1.0,
            &expect,
        )
        .unwrap();
        assert!(bad.iter().any(|v| v.invariant == "exactly-once"), "{bad:?}");
    }

    #[test]
    fn torn_final_line_is_tolerated_but_corrupt_middle_is_not() {
        let mut lines = vec![register(0, 1), commit(0, 0)];
        lines.push(r#"{"ts_us":9,"event":"com"#.to_string()); // torn tail
        let path = write_trace("torn", &lines);
        let r = result(&[1], &[]);
        let v = check_invariants(
            &[Leg { trace: &path, result: &r }],
            1.0,
            1.0,
            &expectations(1),
        )
        .unwrap();
        assert!(v.is_empty(), "{v:?}");
        let lines =
            vec![register(0, 1), "not json at all".to_string(), commit(0, 0)];
        let path = write_trace("corrupt", &lines);
        let err = check_invariants(
            &[Leg { trace: &path, result: &r }],
            1.0,
            1.0,
            &expectations(1),
        );
        assert!(err.is_err());
    }

    #[test]
    fn violation_displays_its_family() {
        let v = Violation { invariant: "exactly-once", detail: "node 3".into() };
        assert_eq!(format!("{v}"), "[exactly-once] node 3");
    }
}
