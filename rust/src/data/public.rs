//! Simulated equivalents of the paper's public datasets (Table II).
//!
//! | dataset | tasks | sample sizes | dim | loss |
//! |---------|-------|--------------|-----|------|
//! | School  | 139   | 22–251       | 28  | squared  |
//! | MNIST   | 5     | 13137–14702  | 100 | logistic |
//! | MTFL    | 4     | 2224–10000   | 10  | logistic |
//!
//! The real files are unavailable offline, so each simulator reproduces the
//! exact task count, the per-task sample-size *range* (sizes drawn
//! deterministically across the range), the dimensionality, and the loss
//! type, with a planted shared low-rank structure (the School exam-score
//! tasks and MNIST one-vs-one digit tasks are strongly related families —
//! which is the property the MTL coupling exploits). The experiments that
//! consume these (Tables II/III) measure *training time under delay
//! regimes*, a function only of (T, n_t, d, loss, delays) — all matched.

use super::{synthetic, MultiTaskDataset};
use crate::util::Rng;

/// Deterministically spread `t_count` sample sizes across `[lo, hi]`.
fn spread_sizes(t_count: usize, lo: usize, hi: usize, rng: &mut Rng) -> Vec<usize> {
    (0..t_count)
        .map(|t| {
            let frac = if t_count == 1 { 0.5 } else { t as f64 / (t_count - 1) as f64 };
            let base = lo as f64 + frac * (hi - lo) as f64;
            // jitter ±10% within bounds to avoid an artificial linear ramp
            let jit = 1.0 + 0.1 * (2.0 * rng.f64() - 1.0);
            ((base * jit).round() as usize).clamp(lo, hi)
        })
        .collect()
}

/// School-like: 139 exam-score regression tasks, d=28, n ∈ [22, 251].
pub fn school_sim(rng: &mut Rng) -> MultiTaskDataset {
    let ns = spread_sizes(139, 22, 251, rng);
    let mut ds = synthetic::lowrank_regression(&ns, 28, 4, 0.5, rng);
    ds.name = "School-sim".into();
    ds
}

/// MNIST-like: 5 binary digit-pair tasks, d=100, n ∈ [13137, 14702].
pub fn mnist_sim(rng: &mut Rng) -> MultiTaskDataset {
    let ns = spread_sizes(5, 13137, 14702, rng);
    let mut ds = synthetic::lowrank_classification(&ns, 100, 6, rng);
    ds.name = "MNIST-sim".into();
    ds
}

/// MTFL-like: 4 binary face-attribute tasks, d=10, n ∈ [2224, 10000].
pub fn mtfl_sim(rng: &mut Rng) -> MultiTaskDataset {
    let ns = spread_sizes(4, 2224, 10000, rng);
    let mut ds = synthetic::lowrank_classification(&ns, 10, 3, rng);
    ds.name = "MTFL-sim".into();
    ds
}

/// Smaller variants for tests and smoke runs (same structure, ~1% volume).
pub fn school_sim_small(rng: &mut Rng) -> MultiTaskDataset {
    let ns = spread_sizes(10, 22, 120, rng);
    let mut ds = synthetic::lowrank_regression(&ns, 28, 3, 0.5, rng);
    ds.name = "School-sim-small".into();
    ds
}

/// Look up a simulated public dataset by its Table-II name.
pub fn by_name(name: &str, rng: &mut Rng) -> Option<MultiTaskDataset> {
    Some(match name {
        "school" => school_sim(rng),
        "mnist" => mnist_sim(rng),
        "mtfl" => mtfl_sim(rng),
        "school-small" => school_sim_small(rng),
        _ => return None,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optim::losses::Loss;

    #[test]
    fn school_matches_table2() {
        let mut rng = Rng::new(70);
        let ds = school_sim(&mut rng);
        assert_eq!(ds.t(), 139);
        assert_eq!(ds.d(), 28);
        for t in &ds.tasks {
            assert!((22..=251).contains(&t.n()), "n={}", t.n());
            assert_eq!(t.loss, Loss::Squared);
        }
        // Size range should actually be spread, not constant.
        let ns: Vec<usize> = ds.tasks.iter().map(|t| t.n()).collect();
        assert!(ns.iter().max().unwrap() - ns.iter().min().unwrap() > 100);
    }

    #[test]
    fn mnist_matches_table2() {
        let mut rng = Rng::new(71);
        let ds = mnist_sim(&mut rng);
        assert_eq!(ds.t(), 5);
        assert_eq!(ds.d(), 100);
        for t in &ds.tasks {
            assert!((13137..=14702).contains(&t.n()));
            assert_eq!(t.loss, Loss::Logistic);
            assert!(t.y.iter().all(|&v| v == 0.0 || v == 1.0));
        }
    }

    #[test]
    fn mtfl_matches_table2() {
        let mut rng = Rng::new(72);
        let ds = mtfl_sim(&mut rng);
        assert_eq!(ds.t(), 4);
        assert_eq!(ds.d(), 10);
        for t in &ds.tasks {
            assert!((2224..=10000).contains(&t.n()));
            assert_eq!(t.loss, Loss::Logistic);
        }
    }

    #[test]
    fn by_name_resolves_and_rejects() {
        let mut rng = Rng::new(73);
        assert!(by_name("school-small", &mut rng).is_some());
        assert!(by_name("imagenet", &mut rng).is_none());
    }

    #[test]
    fn describe_formats_table2_row() {
        let mut rng = Rng::new(74);
        let ds = mtfl_sim(&mut rng);
        let s = ds.describe();
        assert!(s.contains("4 tasks"));
        assert!(s.contains("dimensionality 10"));
    }
}
