//! Dataset substrate.
//!
//! * [`synthetic`] — the paper's randomly-generated regression datasets
//!   (§IV.B.1) plus planted shared-low-rank families for convergence and
//!   effectiveness studies.
//! * [`public`] — *simulated equivalents* of the three public datasets in
//!   Table II (School, MNIST-binary-pairs, MTFL). The real files are not
//!   downloadable in this offline environment; the simulators match the
//!   task counts, per-task sample-size ranges, dimensionalities and loss
//!   types exactly, and plant a shared low-rank structure so the MTL
//!   coupling is exercised (simulated stand-ins: the real files are not
//!   redistributable in an offline build).

pub mod public;
pub mod synthetic;

use crate::optim::losses::{Loss, RowMat};

/// One task's dataset: features, labels, and loss type.
#[derive(Clone, Debug)]
pub struct TaskDataset {
    /// Human-readable task name.
    pub name: String,
    /// Feature matrix (rows are samples).
    pub x: RowMat,
    /// Labels, one per sample.
    pub y: Vec<f64>,
    /// The task's loss function.
    pub loss: Loss,
}

impl TaskDataset {
    /// Sample count.
    pub fn n(&self) -> usize {
        self.x.rows
    }

    /// Feature dimension.
    pub fn d(&self) -> usize {
        self.x.cols
    }
}

/// A multi-task problem: T tasks over a common feature dimension.
#[derive(Clone, Debug)]
pub struct MultiTaskDataset {
    /// Dataset name (e.g. `school`, `synthetic-lowrank`).
    pub name: String,
    /// One dataset per task.
    pub tasks: Vec<TaskDataset>,
    /// Planted model matrix, when the generator knows it (synthetic data).
    pub w_true: Option<crate::linalg::Mat>,
}

impl MultiTaskDataset {
    /// Number of tasks.
    pub fn t(&self) -> usize {
        self.tasks.len()
    }

    /// Common feature dimension (0 for an empty dataset).
    pub fn d(&self) -> usize {
        self.tasks.first().map(|t| t.d()).unwrap_or(0)
    }

    /// Total number of samples across tasks.
    pub fn total_samples(&self) -> usize {
        self.tasks.iter().map(|t| t.n()).sum()
    }

    /// Table II-style description line.
    pub fn describe(&self) -> String {
        let ns: Vec<usize> = self.tasks.iter().map(|t| t.n()).collect();
        let lo = ns.iter().min().copied().unwrap_or(0);
        let hi = ns.iter().max().copied().unwrap_or(0);
        format!(
            "{}: {} tasks, sample sizes {}-{}, dimensionality {}",
            self.name,
            self.t(),
            lo,
            hi,
            self.d()
        )
    }
}
