//! Synthetic multi-task data generators.
//!
//! §IV.B.1 of the paper uses "randomly generated synthetic datasets" with a
//! given number of tasks, per-task sample size, and dimensionality. Two
//! generators are provided:
//!
//! * [`random_regression`] — i.i.d. Gaussian features and labels, exactly
//!   the paper's timing workload (the objective content is irrelevant for
//!   wall-clock comparisons of AMTL vs SMTL).
//! * [`lowrank_regression`] — task models drawn from a planted shared
//!   `rank`-dimensional subspace plus noise, `y = X w_t + ε`. This family
//!   exercises the knowledge-transfer claim: the nuclear-norm coupling must
//!   recover the subspace and beat single-task learning.

use super::{MultiTaskDataset, TaskDataset};
use crate::linalg::Mat;
use crate::optim::losses::{Loss, RowMat};
use crate::util::Rng;

/// i.i.d. Gaussian features/labels, `t_count` regression tasks with `n`
/// samples each, dimension `d` (the paper's timing workload).
pub fn random_regression(t_count: usize, n: usize, d: usize, rng: &mut Rng) -> MultiTaskDataset {
    let tasks = (0..t_count)
        .map(|t| {
            let mut x = RowMat::zeros(n, d);
            for v in x.data.iter_mut() {
                *v = rng.normal();
            }
            let y = rng.normal_vec(n);
            TaskDataset { name: format!("synthetic-{t}"), x, y, loss: Loss::Squared }
        })
        .collect();
    MultiTaskDataset { name: format!("synthetic(T={t_count},n={n},d={d})"), tasks, w_true: None }
}

/// Planted shared-subspace regression.
///
/// `W* = B C` with `B ∈ R^{d×rank}` (shared basis) and per-task coefficients
/// `C ∈ R^{rank×T}`; labels `y_t = X_t w*_t + noise·ε`. Per-task sample
/// counts may vary (pass `ns` of length `t_count`).
pub fn lowrank_regression(
    ns: &[usize],
    d: usize,
    rank: usize,
    noise: f64,
    rng: &mut Rng,
) -> MultiTaskDataset {
    let t_count = ns.len();
    let basis = Mat::randn(d, rank, rng);
    let coef = Mat::randn(rank, t_count, rng);
    let w_true = basis.matmul(&coef);
    let tasks = ns
        .iter()
        .enumerate()
        .map(|(t, &n)| {
            let mut x = RowMat::zeros(n, d);
            for v in x.data.iter_mut() {
                *v = rng.normal();
            }
            let wt = w_true.col(t);
            let y: Vec<f64> = (0..n)
                .map(|i| {
                    let z: f64 = x.row(i).iter().zip(wt).map(|(a, b)| a * b).sum();
                    z + noise * rng.normal()
                })
                .collect();
            TaskDataset { name: format!("lowrank-{t}"), x, y, loss: Loss::Squared }
        })
        .collect();
    MultiTaskDataset {
        name: format!("lowrank(T={t_count},d={d},rank={rank})"),
        tasks,
        w_true: Some(w_true),
    }
}

/// Planted shared-subspace binary classification (logistic tasks):
/// `P(y=1|x) = σ(x·w*_t)`.
pub fn lowrank_classification(
    ns: &[usize],
    d: usize,
    rank: usize,
    rng: &mut Rng,
) -> MultiTaskDataset {
    let t_count = ns.len();
    let basis = Mat::randn(d, rank, rng);
    let coef = Mat::randn(rank, t_count, rng);
    let w_true = basis.matmul(&coef);
    let tasks = ns
        .iter()
        .enumerate()
        .map(|(t, &n)| {
            let mut x = RowMat::zeros(n, d);
            for v in x.data.iter_mut() {
                *v = rng.normal();
            }
            let wt = w_true.col(t);
            let y: Vec<f64> = (0..n)
                .map(|i| {
                    let z: f64 = x.row(i).iter().zip(wt).map(|(a, b)| a * b).sum();
                    let p = crate::optim::losses::sigmoid(z);
                    if rng.bool(p) {
                        1.0
                    } else {
                        0.0
                    }
                })
                .collect();
            TaskDataset { name: format!("lowrank-cls-{t}"), x, y, loss: Loss::Logistic }
        })
        .collect();
    MultiTaskDataset {
        name: format!("lowrank-cls(T={t_count},d={d},rank={rank})"),
        tasks,
        w_true: Some(w_true),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn random_regression_shapes() {
        let mut rng = Rng::new(60);
        let ds = random_regression(5, 100, 50, &mut rng);
        assert_eq!(ds.t(), 5);
        assert_eq!(ds.d(), 50);
        assert_eq!(ds.total_samples(), 500);
        for t in &ds.tasks {
            assert_eq!(t.n(), 100);
            assert_eq!(t.loss, Loss::Squared);
        }
    }

    #[test]
    fn lowrank_w_true_has_planted_rank() {
        let mut rng = Rng::new(61);
        let ds = lowrank_regression(&[50; 6], 20, 3, 0.0, &mut rng);
        let w = ds.w_true.as_ref().unwrap();
        let svd = crate::optim::svd::Svd::jacobi(w);
        assert!(svd.sigma[2] > 1e-6);
        assert!(svd.sigma[3] < 1e-10 * svd.sigma[0]);
    }

    #[test]
    fn noiseless_lowrank_labels_are_consistent() {
        let mut rng = Rng::new(62);
        let ds = lowrank_regression(&[30, 40], 10, 2, 0.0, &mut rng);
        let w = ds.w_true.as_ref().unwrap();
        for (t, task) in ds.tasks.iter().enumerate() {
            let obj = Loss::Squared.obj(&task.x, &task.y, w.col(t), &vec![1.0; task.n()]);
            assert!(obj < 1e-18, "task {t} residual {obj}");
        }
    }

    #[test]
    fn variable_sample_sizes_respected() {
        let mut rng = Rng::new(63);
        let ns = [22, 251, 100];
        let ds = lowrank_regression(&ns, 28, 4, 0.1, &mut rng);
        for (task, &n) in ds.tasks.iter().zip(&ns) {
            assert_eq!(task.n(), n);
        }
    }

    #[test]
    fn classification_labels_are_binary_and_correlated() {
        let mut rng = Rng::new(64);
        let ds = lowrank_classification(&[2000], 8, 2, &mut rng);
        let task = &ds.tasks[0];
        assert!(task.y.iter().all(|&v| v == 0.0 || v == 1.0));
        // The planted (Bayes-optimal) model must beat chance clearly. The
        // expected accuracy is E[σ(|z|)] which depends on ‖w*‖; a weak draw
        // can push it toward ~0.6, so the bar is "clearly above chance".
        let w = ds.w_true.as_ref().unwrap().col(0);
        let correct = (0..task.n())
            .filter(|&i| {
                let z: f64 = task.x.row(i).iter().zip(w).map(|(a, b)| a * b).sum();
                (z > 0.0) == (task.y[i] > 0.5)
            })
            .count();
        let acc = correct as f64 / task.n() as f64;
        assert!(acc > 0.6, "planted-model accuracy {acc}");
    }

    #[test]
    fn generation_is_deterministic_in_seed() {
        let mut a = Rng::new(65);
        let mut b = Rng::new(65);
        let da = random_regression(2, 10, 4, &mut a);
        let db = random_regression(2, 10, 4, &mut b);
        assert_eq!(da.tasks[1].y, db.tasks[1].y);
        assert_eq!(da.tasks[0].x.data, db.tasks[0].x.data);
    }
}
