//! Shared experiment harness used by the bench binaries (`rust/benches/`)
//! and the examples: engine selection, paired runs of any
//! [`Schedule`] under one network setting, and paper-style table
//! formatting.
//!
//! Delay units: the paper injects delays measured in seconds (offsets
//! 5/10/30 s). Experiments here scale one "paper second" to
//! [`ExpConfig::time_scale`] of wall-clock (default 10 ms in benches) so
//! the full suite runs in minutes; ratios are preserved (sensitivity
//! check: the `ablation` bench's time-scale section).

use crate::coordinator::step_size::KmSchedule;
use crate::coordinator::{
    Async, MtlProblem, RunConfig, RunResult, Schedule, Session, Synchronized,
};
use crate::net::DelayModel;
use crate::optim::svd::SvdMode;
use crate::runtime::{ComputePool, Engine, PoolConfig};
use anyhow::Result;
use std::time::Duration;

/// Experiment-wide knobs shared by AMTL and SMTL runs.
#[derive(Clone, Debug)]
pub struct ExpConfig {
    /// Activations per task node.
    pub iters: usize,
    /// Delay offset in paper units (the `k` of AMTL-k / SMTL-k).
    pub offset_units: f64,
    /// Wall-clock per paper unit.
    pub time_scale: Duration,
    /// KM relaxation step.
    pub eta_k: f64,
    /// Enable the Eq. III.6 dynamic step size.
    pub dynamic_step: bool,
    /// Server re-prox stride (see `CentralServer::with_prox_every`).
    pub prox_every: u64,
    /// Trajectory sampling stride.
    pub record_every: u64,
    /// Nuclear-prox SVD backend (see [`SvdMode`]; default online).
    pub svd: SvdMode,
    /// Online-SVD exact-refresh stride (0 = never).
    pub resvd_every: u64,
    /// Root RNG seed.
    pub seed: u64,
}

impl Default for ExpConfig {
    fn default() -> Self {
        ExpConfig {
            iters: 10,
            offset_units: 0.0,
            time_scale: Duration::from_millis(10),
            eta_k: 0.5,
            dynamic_step: false,
            prox_every: 1,
            record_every: u64::MAX / 2,
            svd: SvdMode::default(),
            resvd_every: crate::coordinator::session::DEFAULT_RESVD_EVERY,
            seed: 7,
        }
    }
}

impl ExpConfig {
    /// The paper's delay model: `offset + Exp(offset/2)` per activation.
    pub fn delay_model(&self) -> DelayModel {
        if self.offset_units <= 0.0 {
            return DelayModel::None;
        }
        DelayModel::paper_offset(self.time_scale.mul_f64(self.offset_units))
    }

    /// Lower into the coordinator's schedule-agnostic [`RunConfig`].
    pub fn run_config(&self) -> RunConfig {
        RunConfig {
            iters_per_node: self.iters,
            delay: self.delay_model(),
            faults: crate::net::FaultModel::None,
            sgd_fraction: None,
            time_scale: self.time_scale,
            km: KmSchedule::fixed(self.eta_k),
            dynamic_step: self.dynamic_step,
            dyn_window: 5,
            prox_every: self.prox_every,
            record_every: self.record_every,
            svd: self.svd,
            resvd_every: self.resvd_every,
            seed: self.seed,
            ..Default::default()
        }
    }
}

/// Apply the bench flags every bench binary shares: `--threads N` sizes
/// the linalg worker pool (frozen at first kernel use; 0/absent defers to
/// `PALLAS_THREADS`, then core count) and `--svd exact|online` selects the
/// nuclear-prox backend for [`ExpConfig`]-driven runs. Returns the chosen
/// SVD mode and prints the resolved parallelism so recorded numbers are
/// attributable.
pub fn bench_flags(opts: &crate::config::Opts) -> Result<SvdMode> {
    let threads = opts
        .get_usize("threads", 0)
        .map_err(|e| anyhow::anyhow!("{e}"))?;
    if threads > 0 {
        crate::linalg::configure_threads(threads);
    }
    let mode = match opts.get("svd") {
        Some(v) => SvdMode::parse(v)?,
        None => SvdMode::default(),
    };
    println!("linalg threads: {}  svd: {}", crate::linalg::threads(), mode.name());
    Ok(mode)
}

/// Pick the PJRT engine when artifacts are available, else fall back to the
/// native mirror (printing which one was used).
pub fn auto_engine(executors: usize) -> (Engine, Option<ComputePool>) {
    // Silence the TfrtCpuClient created/destroyed info logs.
    if std::env::var_os("TF_CPP_MIN_LOG_LEVEL").is_none() {
        std::env::set_var("TF_CPP_MIN_LOG_LEVEL", "2");
    }
    let dir = crate::runtime::manifest::default_dir();
    match ComputePool::new(PoolConfig { executors, artifacts_dir: dir.clone() }) {
        Ok(pool) => (Engine::Pjrt, Some(pool)),
        Err(e) => {
            crate::log_warn!(
                "experiments",
                "PJRT artifacts unavailable ({e}); using native engine \
                 (run `make artifacts` for the full three-layer path)"
            );
            (Engine::Native, None)
        }
    }
}

/// Warm the executable + upload caches for every task of `problem`:
/// executes one zero-step per task so that timed runs never pay XLA
/// compilation. No-op for the native engine.
pub fn warm(problem: &MtlProblem, engine: Engine, pool: Option<&ComputePool>) -> Result<()> {
    if engine != Engine::Pjrt {
        return Ok(());
    }
    let mut computes = problem.build_computes(engine, pool)?;
    let w = vec![0.0; problem.d()];
    for c in computes.iter_mut() {
        let _ = c.step(&w, 0.0)?;
    }
    Ok(())
}

/// Run `cfg` once under the given schedule (the one experiment driver:
/// AMTL, SMTL, and semi-sync runs all go through here).
pub fn run_once(
    problem: &MtlProblem,
    engine: Engine,
    pool: Option<&ComputePool>,
    cfg: &ExpConfig,
    schedule: impl Schedule + 'static,
) -> Result<RunResult> {
    Session::builder(problem)
        .engine(engine)
        .pool(pool)
        .config(cfg.run_config())
        .schedule(schedule)
        .build()?
        .run()
}

/// Run AMTL under `cfg`, returning the result.
pub fn run_amtl_once(
    problem: &MtlProblem,
    engine: Engine,
    pool: Option<&ComputePool>,
    cfg: &ExpConfig,
) -> Result<RunResult> {
    run_once(problem, engine, pool, cfg, Async)
}

/// Run SMTL under `cfg`, returning the result.
pub fn run_smtl_once(
    problem: &MtlProblem,
    engine: Engine,
    pool: Option<&ComputePool>,
    cfg: &ExpConfig,
) -> Result<RunResult> {
    run_once(problem, engine, pool, cfg, Synchronized)
}

/// Machine-readable bench output: each bench binary appends one record
/// per measured run and writes `BENCH_<name>.json` at exit, so the perf
/// trajectory (objective, wall-clock, updates/sec) is tracked across PRs
/// instead of living only in stdout tables.
pub struct BenchLog {
    name: String,
    records: Vec<crate::util::json::Json>,
}

impl BenchLog {
    /// A log named `name` (becomes `BENCH_<name>.json`).
    pub fn new(name: &str) -> BenchLog {
        BenchLog { name: name.to_string(), records: Vec::new() }
    }

    /// Append one optimization run: the objective it reached, wall-clock,
    /// update throughput, and the counters that explain them.
    pub fn record_run(&mut self, label: &str, r: &RunResult, objective: f64) {
        use crate::util::json::Json;
        let wall = r.wall_time.as_secs_f64();
        self.records.push(Json::obj(vec![
            ("label", Json::Str(label.to_string())),
            ("method", Json::Str(r.method.clone())),
            ("objective", Json::Num(objective)),
            ("wall_secs", Json::Num(wall)),
            ("updates", Json::Num(r.updates as f64)),
            ("updates_per_sec", Json::Num(r.updates as f64 / wall.max(1e-12))),
            ("prox_count", Json::Num(r.prox_count as f64)),
            ("coalesced_updates", Json::Num(r.coalesced_updates as f64)),
            ("svd_refreshes", Json::Num(r.svd_refreshes as f64)),
            ("threads", Json::Num(crate::linalg::threads() as f64)),
            ("mean_delay_secs", Json::Num(r.mean_delay_secs)),
        ]));
    }

    /// Append a free-form numeric record (micro-benchmarks without a
    /// [`RunResult`], e.g. per-op latencies).
    pub fn record_kv(&mut self, label: &str, pairs: &[(&str, f64)]) {
        use crate::util::json::Json;
        let mut fields = vec![("label", Json::Str(label.to_string()))];
        for (k, v) in pairs {
            fields.push((*k, Json::Num(*v)));
        }
        self.records.push(Json::obj(fields));
    }

    /// Number of records accumulated so far.
    pub fn len(&self) -> usize {
        self.records.len()
    }

    /// True when nothing has been recorded.
    pub fn is_empty(&self) -> bool {
        self.records.is_empty()
    }

    /// Write `BENCH_<name>.json` into `$AMTL_BENCH_DIR` (default: the
    /// working directory) and return the path.
    pub fn write(&self) -> Result<std::path::PathBuf> {
        let dir = std::env::var_os("AMTL_BENCH_DIR")
            .map(std::path::PathBuf::from)
            .unwrap_or_else(|| std::path::PathBuf::from("."));
        self.write_to(&dir)
    }

    /// Write `BENCH_<name>.json` into `dir` (created if absent).
    pub fn write_to(&self, dir: &std::path::Path) -> Result<std::path::PathBuf> {
        use crate::util::json::Json;
        std::fs::create_dir_all(dir)?;
        let path = dir.join(format!("BENCH_{}.json", self.name));
        let doc = Json::obj(vec![
            ("bench", Json::Str(self.name.clone())),
            ("records", Json::Arr(self.records.clone())),
        ]);
        std::fs::write(&path, doc.to_string() + "\n")?;
        Ok(path)
    }
}

/// Markdown-ish table printer for paper-style rows.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    /// A table with the given column headers.
    pub fn new(headers: &[&str]) -> Table {
        Table { headers: headers.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    /// Append a row (must match the header count).
    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len());
        self.rows.push(cells);
    }

    /// Print the table, column-aligned, to stdout.
    pub fn print(&self) {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (w, c) in widths.iter_mut().zip(row) {
                *w = (*w).max(c.len());
            }
        }
        let line = |cells: &[String]| {
            let parts: Vec<String> = cells
                .iter()
                .zip(&widths)
                .map(|(c, w)| format!("{c:>w$}", w = w))
                .collect();
            println!("| {} |", parts.join(" | "));
        };
        line(&self.headers);
        let sep: Vec<String> = widths.iter().map(|w| "-".repeat(*w)).collect();
        line(&sep);
        for row in &self.rows {
            line(row);
        }
    }
}

/// Paper-vs-measured banner for bench outputs.
pub fn banner(title: &str, paper_note: &str) {
    println!("\n=== {title} ===");
    println!("paper: {paper_note}");
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;
    use crate::optim::prox::RegularizerKind;
    use crate::util::Rng;

    #[test]
    fn delay_model_none_at_zero_offset() {
        let cfg = ExpConfig::default();
        assert!(matches!(cfg.delay_model(), DelayModel::None));
        let cfg2 = ExpConfig { offset_units: 5.0, ..ExpConfig::default() };
        assert!(matches!(cfg2.delay_model(), DelayModel::OffsetExp { .. }));
    }

    #[test]
    fn paired_runs_share_the_network_setting() {
        let mut rng = Rng::new(150);
        let ds = synthetic::lowrank_regression(&[20; 3], 5, 2, 0.1, &mut rng);
        let p = MtlProblem::new(ds, RegularizerKind::Nuclear, 0.2, 0.5, &mut rng);
        let cfg = ExpConfig {
            iters: 3,
            offset_units: 1.0,
            time_scale: Duration::from_millis(2),
            ..Default::default()
        };
        let a = run_amtl_once(&p, Engine::Native, None, &cfg).unwrap();
        let s = run_smtl_once(&p, Engine::Native, None, &cfg).unwrap();
        assert_eq!(a.updates, 9);
        assert_eq!(s.updates, 9);
        assert!(a.mean_delay_secs > 0.0 && s.mean_delay_secs > 0.0);
    }

    #[test]
    fn run_once_accepts_any_schedule() {
        let mut rng = Rng::new(151);
        let ds = synthetic::lowrank_regression(&[20; 3], 5, 2, 0.1, &mut rng);
        let p = MtlProblem::new(ds, RegularizerKind::Nuclear, 0.2, 0.5, &mut rng);
        let cfg = ExpConfig { iters: 4, ..Default::default() };
        let r = run_once(
            &p,
            Engine::Native,
            None,
            &cfg,
            crate::coordinator::SemiSync { staleness_bound: 2 },
        )
        .unwrap();
        assert_eq!(r.method, "semisync");
        assert_eq!(r.updates, 12);
    }

    #[test]
    fn bench_log_writes_parseable_json() {
        let mut rng = Rng::new(152);
        let ds = synthetic::lowrank_regression(&[15; 2], 4, 2, 0.1, &mut rng);
        let p = MtlProblem::new(ds, RegularizerKind::Nuclear, 0.2, 0.5, &mut rng);
        let cfg = ExpConfig { iters: 3, ..Default::default() };
        let r = run_amtl_once(&p, Engine::Native, None, &cfg).unwrap();

        // write_to creates the directory itself; no process-global env
        // mutation (tests run multithreaded).
        let dir = std::env::temp_dir().join(format!("amtl_benchlog_{}", std::process::id()));
        let mut log = BenchLog::new("selftest");
        log.record_run("t2", &r, p.objective(&r.w_final));
        log.record_kv("micro", &[("ns_per_op", 12.5)]);
        assert_eq!(log.len(), 2);
        let path = log.write_to(&dir).unwrap();

        let text = std::fs::read_to_string(&path).unwrap();
        let doc = crate::util::json::Json::parse(text.trim()).unwrap();
        assert_eq!(doc.get("bench").and_then(|j| j.as_str()), Some("selftest"));
        let records = doc.get("records").and_then(|j| j.as_arr()).unwrap();
        assert_eq!(records.len(), 2);
        assert_eq!(records[0].get("updates").and_then(|j| j.as_usize()), Some(6));
        assert!(records[0].get("updates_per_sec").and_then(|j| j.as_f64()).unwrap() > 0.0);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn table_prints_aligned() {
        let mut t = Table::new(&["Network", "5 Tasks"]);
        t.row(vec!["AMTL-5".into(), "156.21".into()]);
        t.print(); // smoke: no panic
    }
}
