//! Lock-free latency accounting for the predict hot path.
//!
//! The replica answers predictions from many connection threads at once;
//! per-request timing must not introduce a shared lock on that path.
//! Historically this module owned its own log₂ histogram; that structure
//! was generalized into [`crate::obs::hist`] (adding `merge`, snapshots,
//! and wire export) and the serving tier now reuses it under the
//! [`LatencyHistogram`] name: recording is one `fetch_add` plus a
//! `fetch_max`, and quantile reads walk the buckets without stopping any
//! writer.

/// A concurrent log₂-bucketed histogram of microsecond latencies — the
/// observability layer's [`Histogram`](crate::obs::Histogram) under the
/// serving tier's historical name.
///
/// Quantiles report the matching bucket's upper edge (clamped to the
/// exact maximum), so estimates are conservative — they never claim a
/// request was faster than it was, and overshoot by at most 2×.
pub use crate::obs::hist::Histogram as LatencyHistogram;

#[cfg(test)]
mod tests {
    use super::*;

    // The generalized histogram carries its own unit/property tests in
    // `obs::hist`; these pin the serving-tier behaviors the predict
    // endpoint's stats frame depends on.

    #[test]
    fn quantiles_are_conservative_upper_edges() {
        let h = LatencyHistogram::new();
        // 99 fast samples, one slow outlier.
        for _ in 0..99 {
            h.record(100); // bucket upper edge 127
        }
        h.record(50_000);
        assert_eq!(h.count(), 100);
        assert_eq!(h.max(), 50_000);
        let p50 = h.quantile(0.5);
        assert!((100..=127).contains(&p50), "p50 = {p50}");
        let p99 = h.quantile(0.99);
        assert!((100..=127).contains(&p99), "p99 covers the 99th fast sample, got {p99}");
        // The outlier only shows up at the very top.
        let p100 = h.quantile(1.0);
        assert!(p100 >= 50_000, "p100 = {p100}");
    }

    #[test]
    fn zero_and_one_microsecond_samples_bucket_correctly() {
        let h = LatencyHistogram::new();
        h.record(0);
        h.record(1);
        assert_eq!(h.quantile(0.5), 0, "first of two samples is the zero");
        assert_eq!(h.quantile(1.0), 1);
        assert_eq!(h.max(), 1);
    }
}
