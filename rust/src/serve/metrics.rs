//! Lock-free latency accounting for the predict hot path.
//!
//! The replica answers predictions from many connection threads at once;
//! per-request timing must not introduce a shared lock on that path. A
//! [`LatencyHistogram`] is a fixed array of log₂ buckets behind relaxed
//! atomics: recording is one `fetch_add` plus a `fetch_max`, and quantile
//! reads walk the 64 buckets without stopping any writer.

use std::sync::atomic::{AtomicU64, Ordering};

/// Bucket `i` holds samples needing `i` significant bits: value 0 lands
/// in bucket 0, a value in `[2^(i-1), 2^i)` in bucket `i`. 64 buckets
/// cover every `u64`.
const BUCKETS: usize = 64;

/// A concurrent log₂-bucketed histogram of microsecond latencies.
///
/// Quantiles report the matching bucket's upper edge, so estimates are
/// conservative — they never claim a request was faster than it was, and
/// overshoot by at most 2×. The maximum is tracked exactly.
pub struct LatencyHistogram {
    counts: [AtomicU64; BUCKETS],
    max: AtomicU64,
    total: AtomicU64,
}

impl Default for LatencyHistogram {
    fn default() -> LatencyHistogram {
        LatencyHistogram {
            counts: std::array::from_fn(|_| AtomicU64::new(0)),
            max: AtomicU64::new(0),
            total: AtomicU64::new(0),
        }
    }
}

impl LatencyHistogram {
    /// An empty histogram.
    pub fn new() -> LatencyHistogram {
        LatencyHistogram::default()
    }

    /// Record one sample (microseconds).
    pub fn record(&self, us: u64) {
        let idx = ((u64::BITS - us.leading_zeros()) as usize).min(BUCKETS - 1);
        self.counts[idx].fetch_add(1, Ordering::Relaxed);
        self.max.fetch_max(us, Ordering::Relaxed);
        self.total.fetch_add(1, Ordering::Relaxed);
    }

    /// Samples recorded so far.
    pub fn count(&self) -> u64 {
        self.total.load(Ordering::Relaxed)
    }

    /// The exact largest sample seen (0 when empty).
    pub fn max(&self) -> u64 {
        self.max.load(Ordering::Relaxed)
    }

    /// The `q`-quantile (`0.0 ..= 1.0`) as a bucket upper edge, 0 when
    /// empty. Concurrent recording can make the walk fall short of the
    /// rank; the exact maximum is the honest answer then.
    pub fn quantile(&self, q: f64) -> u64 {
        let total = self.total.load(Ordering::Relaxed);
        if total == 0 {
            return 0;
        }
        let rank = ((q * total as f64).ceil() as u64).clamp(1, total);
        let mut seen = 0u64;
        for (idx, c) in self.counts.iter().enumerate() {
            seen += c.load(Ordering::Relaxed);
            if seen >= rank {
                return upper_edge(idx);
            }
        }
        self.max()
    }
}

/// Largest value that lands in bucket `idx`.
fn upper_edge(idx: usize) -> u64 {
    if idx == 0 {
        0
    } else {
        (1u64 << idx.min(63)) - 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_histogram_reports_zeros() {
        let h = LatencyHistogram::new();
        assert_eq!(h.count(), 0);
        assert_eq!(h.max(), 0);
        assert_eq!(h.quantile(0.5), 0);
        assert_eq!(h.quantile(0.99), 0);
    }

    #[test]
    fn quantiles_are_conservative_upper_edges() {
        let h = LatencyHistogram::new();
        // 99 fast samples, one slow outlier.
        for _ in 0..99 {
            h.record(100); // bucket upper edge 127
        }
        h.record(50_000);
        assert_eq!(h.count(), 100);
        assert_eq!(h.max(), 50_000);
        let p50 = h.quantile(0.5);
        assert!((100..=127).contains(&p50), "p50 = {p50}");
        let p99 = h.quantile(0.99);
        assert!((100..=127).contains(&p99), "p99 covers the 99th fast sample, got {p99}");
        // The outlier only shows up at the very top.
        let p100 = h.quantile(1.0);
        assert!(p100 >= 50_000, "p100 = {p100}");
    }

    #[test]
    fn zero_and_one_microsecond_samples_bucket_correctly() {
        let h = LatencyHistogram::new();
        h.record(0);
        h.record(1);
        assert_eq!(h.quantile(0.5), 0, "first of two samples is the zero");
        assert_eq!(h.quantile(1.0), 1);
        assert_eq!(h.max(), 1);
    }

    #[test]
    fn concurrent_recording_loses_nothing() {
        let h = std::sync::Arc::new(LatencyHistogram::new());
        std::thread::scope(|s| {
            for t in 0..4 {
                let h = std::sync::Arc::clone(&h);
                s.spawn(move || {
                    for i in 0..1000u64 {
                        h.record(t * 1000 + i);
                    }
                });
            }
        });
        assert_eq!(h.count(), 4000);
        assert_eq!(h.max(), 3999);
    }
}
