//! The serving tier: read-optimized model replicas fed by the trainer's
//! own durability artifacts.
//!
//! The paper ends where training ends, but a deployed multi-task model
//! has to *answer queries* while training continues. This module closes
//! that loop without ever letting read traffic touch the training hot
//! path: a replica process shares **no memory and no locks** with the
//! trainer — its only coupling is the checkpoint directory the trainer
//! already writes for durability ([`crate::persist`]).
//!
//! A [`ModelReplica`]:
//!
//! 1. **bootstraps** from the newest valid snapshot (same fallback rules
//!    as recovery),
//! 2. **tails the WAL**, resuming each poll at the byte offset where the
//!    last one stopped (`WalScan::resume_offset`) and applying committed
//!    entries in order through the trainer's own replay machinery — so
//!    the replica's state, including the online SVD's fold history, is
//!    bitwise what the trainer would recover to,
//! 3. **hot-swaps** onto a newer snapshot when keep-2 checkpoint
//!    rotation prunes the WAL tail out from under it — a replica can
//!    fall behind, but it can never be stranded.
//!
//! Each drain batch publishes one immutable
//! [`ServingModel`](replica::ServingModel) (`W = Prox_{ηλg}(V)` via the
//! non-mutating `CentralServer::serving_w`), swapped atomically — a
//! concurrent predict sees a whole batch or none of it, never a
//! partially-applied column.
//!
//! Queries arrive over the same wire codec the trainer speaks
//! ([`crate::transport::wire`]), extended with two additive frames:
//! `Predict { t, x } → Prediction { ŷ, model_seq }` (per-task routing:
//! `ŷ = ⟨w_t, x⟩`) and `FetchStats → Stats` ([`ReplicaStats`]: replica
//! lag in commit sequence numbers, request counters, and latency
//! quantiles from a lock-free log₂ histogram ([`metrics`])).
//!
//! The CLI runs the tier as `amtl --replica <addr> --follow <dir>`; `amtl
//! predict` is the matching query client, and `examples/load_gen.rs`
//! measures the endpoint under concurrent load while training runs live
//! (`BENCH_serve.json`). See `docs/ARCHITECTURE.md` § "Serving tier".

pub mod client;
pub mod metrics;
pub mod replica;
pub mod server;

pub use client::PredictClient;
pub use metrics::LatencyHistogram;
pub use replica::{ModelReplica, ReplicaCore, ServingModel};
pub use server::{ReplicaServer, ReplicaServerHandle};

pub use crate::transport::wire::ReplicaStats;
