//! A minimal blocking client for the predict protocol.
//!
//! Deliberately *without* the training client's reconnect-and-resend
//! loop: load generators and smoke tests must observe every failure (the
//! acceptance bar is a replica that never errors under live traffic), so
//! nothing here retries a failure away. One request in flight at a time,
//! one socket for the connection's lifetime.

use crate::transport::wire::{MetricsReport, ReplicaStats, Request, Response};
use anyhow::{anyhow, bail, Result};
use std::net::{TcpStream, ToSocketAddrs};
use std::time::Duration;

/// One connection to a replica's predict endpoint.
pub struct PredictClient {
    stream: TcpStream,
}

impl PredictClient {
    /// Resolve `addr` and connect; `timeout` bounds the connect and every
    /// subsequent read/write.
    pub fn connect(addr: impl ToSocketAddrs, timeout: Duration) -> Result<PredictClient> {
        let addr = addr
            .to_socket_addrs()
            .map_err(|e| anyhow!("cannot resolve replica address: {e}"))?
            .next()
            .ok_or_else(|| anyhow!("replica address resolved to nothing"))?;
        let stream = TcpStream::connect_timeout(&addr, timeout)
            .map_err(|e| anyhow!("connect to {addr}: {e}"))?;
        stream.set_nodelay(true)?;
        stream.set_read_timeout(Some(timeout))?;
        stream.set_write_timeout(Some(timeout))?;
        Ok(PredictClient { stream })
    }

    fn request(&mut self, req: &Request) -> Result<Response> {
        req.write_to(&mut self.stream)?;
        match Response::read_from(&mut self.stream)? {
            Response::Error(msg) => bail!("replica rejected request: {msg}"),
            resp => Ok(resp),
        }
    }

    /// Score the caller's own feature vector `x` against task `t`'s
    /// serving column. Returns `(ŷ, model_seq)` — the prediction and the
    /// WAL horizon of the model that produced it.
    pub fn predict(&mut self, t: usize, x: &[f64]) -> Result<(f64, u64)> {
        match self.request(&Request::Predict { t: t as u32, x: x.to_vec() })? {
            Response::Prediction { y, model_seq } => Ok((y, model_seq)),
            other => bail!("expected Prediction, got {other:?}"),
        }
    }

    /// Fetch the replica's stats frame (lag, latency quantiles, request
    /// counters).
    pub fn stats(&mut self) -> Result<ReplicaStats> {
        match self.request(&Request::FetchStats)? {
            Response::Stats(stats) => Ok(stats),
            other => bail!("expected Stats, got {other:?}"),
        }
    }

    /// Fetch the full observability dump ([`MetricsReport`]): the remote
    /// process's metrics registry. Answered by replicas *and* by the
    /// training server (`amtl top` points this client at either).
    pub fn metrics(&mut self) -> Result<MetricsReport> {
        match self.request(&Request::FetchMetrics)? {
            Response::Metrics(report) => Ok(report),
            other => bail!("expected Metrics, got {other:?}"),
        }
    }

    /// Polite teardown: tells the replica to close this connection (the
    /// replica itself keeps serving). Errors are advisory.
    pub fn close(mut self) -> Result<()> {
        let _ = self.request(&Request::Shutdown);
        Ok(())
    }
}
