//! The replica itself: snapshot bootstrap, WAL tailing, hot-swap.
//!
//! [`ReplicaCore`] is the synchronous state machine — bootstrap from the
//! newest valid snapshot, then per [`ReplicaCore::poll`] drain every WAL
//! entry currently on disk, in order, resuming at the byte offset where
//! the previous drain stopped (`WalScan::resume_offset`). It reuses the
//! trainer's own recovery machinery (`CentralServer::from_snapshot` +
//! `replay_entry`), so the replica's state — including the online SVD's
//! fold history, which the WAL's `Prox` markers order — is bitwise the
//! trainer's. The serving iterate is computed with
//! [`CentralServer::serving_w`], which never disturbs that replay state.
//!
//! Readers never see the replay in progress: each drain batch publishes
//! one immutable [`ServingModel`] behind an `RwLock<Arc<..>>` swap, so a
//! concurrent predict observes either the whole batch or none of it —
//! no partially-applied column can ever be read.
//!
//! [`ModelReplica`] wraps the core in a polling thread (the `amtl
//! --replica … --follow <dir>` process) and owns the shared stats the
//! predict endpoint reports.

use super::metrics::LatencyHistogram;
use crate::coordinator::server::CentralServer;
use crate::linalg::{self, Mat};
use crate::obs::fleet::{self, Hop};
use crate::persist::{self, wal};
use crate::persist::WalEntry;
use crate::transport::wire::ReplicaStats;
use anyhow::{Context, Result};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, RwLock};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// One immutable, fully-consistent serving iterate: the whole primal
/// matrix `W = Prox_{ηλg}(V)` as of one WAL horizon. Swapped in
/// atomically after a drain batch — never mutated in place.
pub struct ServingModel {
    /// The primal iterate, `d × T` (column `t` scores task `t`).
    pub w: Mat,
    /// WAL sequence horizon this iterate incorporates (snapshot horizon
    /// plus every entry applied since).
    pub seq: u64,
    /// KM update count of the underlying auxiliary state.
    pub version: u64,
}

/// State shared between the tail thread and the predict endpoint: the
/// current [`ServingModel`] plus every counter [`ReplicaStats`] reports.
pub(crate) struct ReplicaShared {
    /// `None` until the bootstrap snapshot is found and applied.
    model: RwLock<Option<Arc<ServingModel>>>,
    /// Newest WAL sequence number observed on disk (may run ahead of the
    /// serving model's horizon while a drain batch is in flight).
    latest_seq: AtomicU64,
    applied_entries: AtomicU64,
    predictions: AtomicU64,
    errors: AtomicU64,
    bootstraps: AtomicU64,
    hot_swaps: AtomicU64,
    /// Per-request service latency, recorded by the predict endpoint.
    pub(crate) hist: LatencyHistogram,
    started: Instant,
}

impl ReplicaShared {
    fn new() -> Arc<ReplicaShared> {
        Arc::new(ReplicaShared {
            model: RwLock::new(None),
            latest_seq: AtomicU64::new(0),
            applied_entries: AtomicU64::new(0),
            predictions: AtomicU64::new(0),
            errors: AtomicU64::new(0),
            bootstraps: AtomicU64::new(0),
            hot_swaps: AtomicU64::new(0),
            hist: LatencyHistogram::new(),
            started: Instant::now(),
        })
    }

    /// The current serving model (cheap: clones an `Arc` under a read
    /// lock held for the clone only).
    pub(crate) fn model(&self) -> Option<Arc<ServingModel>> {
        self.model.read().unwrap().clone()
    }

    /// Score the querier's feature vector `x` against task `t`:
    /// `ŷ = ⟨w_t, x⟩` over the current serving model. Validation failures
    /// are counted and reported as messages, never panics.
    pub(crate) fn predict(&self, t: u32, x: &[f64]) -> std::result::Result<(f64, u64), String> {
        let reject = |msg: String| {
            self.errors.fetch_add(1, Ordering::Relaxed);
            Err(msg)
        };
        let Some(model) = self.model() else {
            return reject("replica is still bootstrapping (no snapshot applied yet)".into());
        };
        let (d, t_count) = (model.w.rows(), model.w.cols());
        let t = t as usize;
        if t >= t_count {
            return reject(format!("task index {t} out of range (T={t_count})"));
        }
        if x.len() != d {
            return reject(format!("feature vector has dimension {}, expected {d}", x.len()));
        }
        if !x.iter().all(|v| v.is_finite()) {
            return reject("feature vector contains non-finite values".into());
        }
        let y = linalg::dot(model.w.col(t), x);
        self.predictions.fetch_add(1, Ordering::Relaxed);
        Ok((y, model.seq))
    }

    /// Assemble the stats frame the wire protocol serves.
    pub(crate) fn stats(&self) -> ReplicaStats {
        let (tasks, dim, model_seq) = match self.model() {
            Some(m) => (m.w.cols() as u32, m.w.rows() as u32, m.seq),
            None => (0, 0, 0),
        };
        ReplicaStats {
            tasks,
            dim,
            model_seq,
            latest_seq: self.latest_seq.load(Ordering::Relaxed).max(model_seq),
            applied_entries: self.applied_entries.load(Ordering::Relaxed),
            predictions: self.predictions.load(Ordering::Relaxed),
            errors: self.errors.load(Ordering::Relaxed),
            bootstraps: self.bootstraps.load(Ordering::Relaxed),
            hot_swaps: self.hot_swaps.load(Ordering::Relaxed),
            p50_us: self.hist.quantile(0.5),
            p99_us: self.hist.quantile(0.99),
            max_us: self.hist.max(),
            uptime_ms: self.started.elapsed().as_millis() as u64,
        }
    }
}

/// Position within the WAL file currently being tailed, so the next poll
/// resumes mid-file instead of re-scanning from the header.
struct TailFile {
    /// The file's start sequence (from its name — part of its identity).
    start: u64,
    path: PathBuf,
    /// Byte offset just past the last entry consumed.
    offset: u64,
}

/// The synchronous tailer: bootstraps from the newest valid snapshot in a
/// checkpoint directory and replays the trainer's WAL in order. Exposed
/// directly for deterministic tests; production wraps it in a
/// [`ModelReplica`] thread.
pub struct ReplicaCore {
    dir: PathBuf,
    server: CentralServer,
    /// Next WAL sequence number to apply.
    expected: u64,
    tail: Option<TailFile>,
    shared: Arc<ReplicaShared>,
}

impl ReplicaCore {
    /// Bootstrap from the newest valid snapshot in `dir`. Errors when the
    /// directory has no readable snapshot yet — callers poll until the
    /// trainer's genesis snapshot lands.
    pub fn bootstrap(dir: impl Into<PathBuf>) -> Result<ReplicaCore> {
        ReplicaCore::bootstrap_shared(dir.into(), ReplicaShared::new())
    }

    fn bootstrap_shared(dir: PathBuf, shared: Arc<ReplicaShared>) -> Result<ReplicaCore> {
        let snap = persist::newest_valid_snapshot(&dir)?
            .ok_or_else(|| anyhow::anyhow!("no readable snapshot in {}", dir.display()))?;
        let server = CentralServer::from_snapshot(&snap)
            .map_err(|e| e.context(format!("bootstrapping replica from {}", dir.display())))?;
        let core = ReplicaCore { dir, server, expected: snap.seq + 1, tail: None, shared };
        core.shared.bootstraps.fetch_add(1, Ordering::Relaxed);
        core.publish();
        Ok(core)
    }

    /// Publish the current state as one immutable [`ServingModel`].
    fn publish(&self) {
        let model = ServingModel {
            w: self.server.serving_w(),
            seq: self.expected - 1,
            version: self.server.state().version(),
        };
        *self.shared.model.write().unwrap() = Some(Arc::new(model));
        self.shared.latest_seq.fetch_max(self.expected - 1, Ordering::Relaxed);
    }

    /// Drain every WAL entry currently on disk into the replica's state,
    /// publishing a fresh [`ServingModel`] when at least one applied.
    /// Returns the number of entries applied.
    ///
    /// Running behind never errors: a torn tail is a live writer caught
    /// mid-append (the stored offset retries that boundary next poll),
    /// and a WAL pruned out from under us by keep-2 rotation triggers a
    /// hot-swap — re-bootstrap from the newer snapshot that justified the
    /// pruning. Errors are reserved for a directory the replica cannot
    /// make progress in at all.
    pub fn poll(&mut self) -> Result<u64> {
        let mut applied = 0u64;
        // At most one snapshot re-bootstrap per poll: a replica can fall
        // behind, but it can never spin here.
        let mut swaps_left = 1u32;
        loop {
            let wals = persist::list_wal_files(&self.dir)?;
            // The file covering `expected`: the last one starting at or
            // before it (names carry the start sequence).
            let covering = wals.iter().rev().find(|(s, _)| *s <= self.expected).cloned();
            let Some((start, path)) = covering else {
                // Every WAL on disk starts past us: rotation pruned our
                // tail. The snapshot that justified the pruning is newer
                // than our state — swap to it.
                if swaps_left > 0 && self.hot_swap()? {
                    swaps_left -= 1;
                    continue;
                }
                break;
            };
            let offset = self.resume_offset(start, &path);
            let scan = match wal::read_wal_from(&path, offset) {
                Ok(scan) => scan,
                // The file vanished (or was replaced) between listing and
                // opening — pruning raced us. Same remedy as above.
                Err(e) => {
                    if swaps_left > 0 && self.hot_swap()? {
                        swaps_left -= 1;
                        continue;
                    }
                    return Err(e).with_context(|| format!("tailing {}", path.display()));
                }
            };
            let mut gap = false;
            for entry in &scan.entries {
                let seq = entry.seq();
                if seq < self.expected {
                    continue; // resumed from 0: already applied
                }
                if seq > self.expected {
                    gap = true;
                    break;
                }
                // A replayed commit is the last hop of its originating
                // span: the update is now visible to predict traffic.
                let apply_start_us = fleet::unix_us();
                self.server.replay_entry(entry);
                if let WalEntry::Commit { t, k, .. } = entry {
                    fleet::record_hop(
                        None,
                        Hop::ReplicaApply,
                        *t as usize,
                        *k,
                        apply_start_us,
                        fleet::unix_us(),
                    );
                }
                self.expected += 1;
                applied += 1;
            }
            self.shared.latest_seq.fetch_max(self.expected - 1, Ordering::Relaxed);
            self.tail = Some(TailFile { start, path, offset: scan.resume_offset });
            if gap {
                // A sequence hole inside the log: unreachable by the
                // writer's append discipline, so treat it as damage and
                // recover the way the trainer would — from a snapshot.
                if swaps_left > 0 && self.hot_swap()? {
                    swaps_left -= 1;
                    continue;
                }
                anyhow::bail!(
                    "WAL sequence gap at {} in {} with no newer snapshot to swap to",
                    self.expected,
                    self.dir.display()
                );
            }
            // A successor file starting exactly at `expected` means the
            // writer rotated past this file; loop so the covering pick
            // moves to it. Otherwise we are caught up (a torn tail here
            // is just the writer mid-append — the stored offset makes
            // the next poll retry the same boundary).
            let rotated = wals.iter().any(|(s, _)| *s == self.expected && *s > start);
            if !rotated {
                break;
            }
        }
        if applied > 0 {
            self.shared.applied_entries.fetch_add(applied, Ordering::Relaxed);
            self.publish();
        }
        Ok(applied)
    }

    /// The byte offset to resume scanning `path` from: the stored tail
    /// position when it provably refers to the same file (same start
    /// sequence, same path, file at least as long as the stored offset —
    /// shorter means truncated or recreated), else 0. A header re-scan is
    /// safe: already-applied entries are skipped by sequence number.
    fn resume_offset(&self, start: u64, path: &Path) -> u64 {
        match &self.tail {
            Some(t) if t.start == start && t.path == *path => {
                let len = std::fs::metadata(path).map(|m| m.len()).unwrap_or(0);
                if len >= t.offset {
                    t.offset
                } else {
                    0
                }
            }
            _ => 0,
        }
    }

    /// Re-bootstrap from the newest valid snapshot, provided it is ahead
    /// of the state we already hold (a replica never steps backwards).
    /// Returns whether a swap happened.
    fn hot_swap(&mut self) -> Result<bool> {
        let Some(snap) = persist::newest_valid_snapshot(&self.dir)? else {
            return Ok(false);
        };
        if snap.seq < self.expected {
            return Ok(false);
        }
        self.server = CentralServer::from_snapshot(&snap)
            .map_err(|e| e.context(format!("hot-swapping replica onto snapshot {}", snap.seq)))?;
        self.expected = snap.seq + 1;
        self.tail = None;
        self.shared.hot_swaps.fetch_add(1, Ordering::Relaxed);
        self.publish();
        Ok(true)
    }

    /// The current serving model (always `Some` after bootstrap).
    pub fn serving(&self) -> Option<Arc<ServingModel>> {
        self.shared.model()
    }

    /// The same stats frame the wire protocol serves.
    pub fn stats(&self) -> ReplicaStats {
        self.shared.stats()
    }

    /// Next WAL sequence number the tailer expects.
    pub fn expected_seq(&self) -> u64 {
        self.expected
    }
}

/// A background tailer around [`ReplicaCore`]: waits for the trainer's
/// genesis snapshot, bootstraps, then drains the WAL every `poll`
/// interval. The `amtl --replica … --follow <dir>` process is one of
/// these plus a [`ReplicaServer`](super::server::ReplicaServer).
pub struct ModelReplica {
    shared: Arc<ReplicaShared>,
    stop: Arc<AtomicBool>,
    thread: Option<JoinHandle<()>>,
}

impl ModelReplica {
    /// Follow checkpoint directory `dir`, polling for new WAL entries
    /// (and, before bootstrap, for the first snapshot) every `poll`.
    pub fn follow(dir: impl Into<PathBuf>, poll: Duration) -> ModelReplica {
        let dir = dir.into();
        let shared = ReplicaShared::new();
        let stop = Arc::new(AtomicBool::new(false));
        let thread = {
            let shared = Arc::clone(&shared);
            let stop = Arc::clone(&stop);
            std::thread::Builder::new()
                .name("amtl-replica-tail".into())
                .spawn(move || run_tail(&dir, poll, &shared, &stop))
                .expect("spawn replica tail thread")
        };
        ModelReplica { shared, stop, thread: Some(thread) }
    }

    pub(crate) fn shared(&self) -> Arc<ReplicaShared> {
        Arc::clone(&self.shared)
    }

    /// The current serving model, if bootstrap has happened.
    pub fn serving(&self) -> Option<Arc<ServingModel>> {
        self.shared.model()
    }

    /// A stats snapshot of the replica right now.
    pub fn stats(&self) -> ReplicaStats {
        self.shared.stats()
    }

    /// Block until the first serving model is published (the bootstrap
    /// snapshot was found and applied), up to `timeout`. Returns whether
    /// the replica is ready.
    pub fn wait_ready(&self, timeout: Duration) -> bool {
        let deadline = Instant::now() + timeout;
        while self.shared.model().is_none() {
            if Instant::now() >= deadline {
                return false;
            }
            std::thread::sleep(Duration::from_millis(5));
        }
        true
    }

    /// Stop the tail thread and join it. Idempotent; also runs on drop.
    pub fn shutdown(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(t) = self.thread.take() {
            let _ = t.join();
        }
    }
}

impl Drop for ModelReplica {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// The tail thread body: bootstrap as soon as a snapshot exists, then
/// drain on the poll cadence. Tail errors are reported and retried — a
/// replica outlives transient filesystem races with the trainer.
fn run_tail(dir: &Path, poll: Duration, shared: &Arc<ReplicaShared>, stop: &AtomicBool) {
    let mut core: Option<ReplicaCore> = None;
    while !stop.load(Ordering::SeqCst) {
        match &mut core {
            None => {
                if persist::has_checkpoint(dir) {
                    match ReplicaCore::bootstrap_shared(dir.to_path_buf(), Arc::clone(shared)) {
                        Ok(c) => {
                            core = Some(c);
                            continue; // drain what is already on disk
                        }
                        Err(e) => {
                            crate::log_warn!("replica", "bootstrap failed ({e:#}); retrying");
                        }
                    }
                }
            }
            Some(c) => {
                if let Err(e) = c.poll() {
                    crate::log_warn!("replica", "tail error ({e:#}); retrying");
                }
            }
        }
        sleep_checking(stop, poll);
    }
}

/// Sleep `total`, waking every 20 ms to honor a shutdown request.
fn sleep_checking(stop: &AtomicBool, total: Duration) {
    let deadline = Instant::now() + total;
    while !stop.load(Ordering::SeqCst) {
        let left = deadline.saturating_duration_since(Instant::now());
        if left.is_zero() {
            break;
        }
        std::thread::sleep(left.min(Duration::from_millis(20)));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::state::SharedState;
    use crate::optim::prox::NuclearProx;
    use crate::optim::SharedProx;
    use crate::persist::{Checkpointer, PersistConfig};
    use crate::util::Rng;

    fn tmp_dir(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("amtl_serve_{}_{name}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        dir
    }

    fn durable_server(dir: &Path, every: u64, online: bool, d: usize, t: usize) -> CentralServer {
        let mut rng = Rng::new(6200);
        let m = Mat::randn(d, t, &mut rng);
        let state = Arc::new(SharedState::new(&m));
        let mut reg = NuclearProx::new(0.3);
        if online {
            reg = reg.with_online(&m).with_resvd_every(5);
        }
        let reg: Box<dyn SharedProx> = Box::new(reg);
        let cp = Arc::new(Checkpointer::create(PersistConfig::new(dir, every)).unwrap());
        CentralServer::new(state, reg, 0.2).with_checkpointer(cp).unwrap()
    }

    fn drive(srv: &CentralServer, n: usize, t_count: usize, seed: u64, k0: u64) {
        let mut rng = Rng::new(seed);
        let d = srv.state().d();
        for i in 0..n {
            let t = i % t_count;
            let u = rng.normal_vec(d);
            srv.commit_update(t, k0 + (i / t_count) as u64, &u, 0.6).unwrap();
            let _ = srv.prox_matrix();
        }
    }

    #[test]
    fn bootstrap_requires_a_snapshot() {
        let dir = tmp_dir("no_snap");
        std::fs::create_dir_all(&dir).unwrap();
        let err = ReplicaCore::bootstrap(&dir).unwrap_err();
        assert!(format!("{err:#}").contains("no readable snapshot"), "{err:#}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn replica_drains_wal_to_trainer_state() {
        let dir = tmp_dir("drain");
        let srv = durable_server(&dir, 1000, true, 6, 3);
        drive(&srv, 17, 3, 6201, 0);
        srv.sync_persist().unwrap();

        let mut replica = ReplicaCore::bootstrap(&dir).unwrap();
        let applied = replica.poll().unwrap();
        assert!(applied > 0, "stride 1000 means everything lives in the WAL");
        let model = replica.serving().unwrap();
        assert_eq!(model.w.max_abs_diff(&srv.serving_w()), 0.0, "serving W is bitwise the trainer's");
        assert_eq!(model.version, srv.state().version());
        // Caught up: another poll applies nothing and changes nothing.
        assert_eq!(replica.poll().unwrap(), 0);
        assert_eq!(replica.stats().lag(), 0);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn incremental_polls_match_one_full_drain() {
        let dir = tmp_dir("incremental");
        let srv = durable_server(&dir, 1000, true, 5, 2);
        let mut incremental = ReplicaCore::bootstrap(&dir).unwrap();
        // Interleave training with tailing: the replica resumes mid-file
        // every time instead of re-scanning.
        for round in 0..6 {
            drive(&srv, 5, 2, 6300 + round, 3 * round);
            srv.sync_persist().unwrap();
            incremental.poll().unwrap();
        }
        let mut full = ReplicaCore::bootstrap(&dir).unwrap();
        full.poll().unwrap();
        let a = incremental.serving().unwrap();
        let b = full.serving().unwrap();
        assert_eq!(a.seq, b.seq);
        assert_eq!(a.w.max_abs_diff(&b.w), 0.0, "resumed tailing must equal a full scan");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn rotation_hot_swap_survives_pruning() {
        let dir = tmp_dir("hot_swap");
        // Aggressive rotation: keep-2 pruning removes old WALs quickly.
        let srv = durable_server(&dir, 3, false, 4, 2);
        let mut replica = ReplicaCore::bootstrap(&dir).unwrap();
        drive(&srv, 30, 2, 6400, 0);
        srv.sync_persist().unwrap();
        // The replica's original tail was pruned away several rotations
        // ago; it must recover through a snapshot, not error.
        replica.poll().unwrap();
        let model = replica.serving().unwrap();
        assert_eq!(model.w.max_abs_diff(&srv.serving_w()), 0.0);
        assert!(replica.stats().hot_swaps >= 1, "pruned tail forces a snapshot swap");
        assert_eq!(replica.stats().lag(), 0);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn predict_validates_and_counts() {
        let dir = tmp_dir("predict");
        let srv = durable_server(&dir, 1000, false, 3, 2);
        drive(&srv, 4, 2, 6500, 0);
        srv.sync_persist().unwrap();
        let mut replica = ReplicaCore::bootstrap(&dir).unwrap();
        replica.poll().unwrap();
        let shared = &replica.shared;

        let w = srv.serving_w();
        let x = [1.0, -2.0, 0.5];
        let (y, seq) = shared.predict(1, &x).unwrap();
        assert_eq!(y, linalg::dot(w.col(1), &x));
        assert_eq!(seq, replica.serving().unwrap().seq);
        assert!(shared.predict(9, &x).is_err(), "task out of range");
        assert!(shared.predict(0, &[1.0]).is_err(), "dimension mismatch");
        assert!(shared.predict(0, &[f64::NAN, 0.0, 0.0]).is_err(), "non-finite input");
        let stats = shared.stats();
        assert_eq!(stats.predictions, 1);
        assert_eq!(stats.errors, 3);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn model_replica_thread_follows_a_live_directory() {
        let dir = tmp_dir("thread");
        // Start following before any snapshot exists: the thread waits.
        let mut replica = ModelReplica::follow(&dir, Duration::from_millis(10));
        assert!(replica.serving().is_none());
        let srv = durable_server(&dir, 8, false, 4, 2);
        assert!(replica.wait_ready(Duration::from_secs(30)), "bootstrap after genesis");
        drive(&srv, 12, 2, 6600, 0);
        srv.sync_persist().unwrap();
        // Exact mode: the serving iterate is a pure function of V, so
        // matching KM versions means matching models.
        let want = srv.state().version();
        let deadline = Instant::now() + Duration::from_secs(30);
        while replica.serving().map(|m| m.version) != Some(want) {
            assert!(Instant::now() < deadline, "replica never caught up: {:?}", replica.stats());
            std::thread::sleep(Duration::from_millis(10));
        }
        let model = replica.serving().unwrap();
        assert_eq!(model.w.max_abs_diff(&srv.serving_w()), 0.0);
        replica.shutdown();
        std::fs::remove_dir_all(&dir).ok();
    }
}
