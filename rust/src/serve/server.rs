//! The predict endpoint: a TCP server answering `Predict`/`FetchStats`
//! frames against a [`ModelReplica`]'s latest published serving model.
//!
//! Mirrors the training-side [`TcpServer`](crate::transport::tcp::TcpServer)
//! discipline exactly — non-blocking accept loop, one thread per
//! connection, `PatientReader` polling the stop flag, per-response write
//! timeout, reaping of finished connection threads — but shares *no
//! state* with a trainer: every answer comes from the immutable
//! [`ServingModel`](super::replica::ServingModel) swap, so predict
//! traffic never takes a lock a training commit could hold. Training
//! frames arriving here are refused with an `Error` response.

use super::replica::{ModelReplica, ReplicaShared};
use crate::obs;
use crate::transport::tcp::{PatientReader, POLL, WRITE_TIMEOUT};
use crate::transport::wire::{MetricsReport, Request, Response, WireError};
use anyhow::{anyhow, Result};
use std::io::ErrorKind;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

/// The serving side of the predict protocol.
pub struct ReplicaServer;

/// Running predict-endpoint handle. Dropping it (or calling
/// [`ReplicaServerHandle::shutdown`]) stops the accept loop and joins
/// every connection thread. Does not stop the replica's tail thread —
/// that belongs to the [`ModelReplica`].
pub struct ReplicaServerHandle {
    addr: SocketAddr,
    stop_flag: Arc<AtomicBool>,
    accept: Option<JoinHandle<()>>,
    conns: Arc<Mutex<Vec<JoinHandle<()>>>>,
}

impl ReplicaServer {
    /// Bind `addr` (e.g. `"127.0.0.1:0"` for an ephemeral port) and
    /// answer predict traffic against `replica`'s serving model until the
    /// handle is shut down. Serving starts immediately: requests arriving
    /// before the replica bootstraps get an `Error` response, not a hang.
    pub fn spawn(addr: &str, replica: &ModelReplica) -> Result<ReplicaServerHandle> {
        let shared = replica.shared();
        let listener = TcpListener::bind(addr)
            .map_err(|e| anyhow!("cannot bind replica server on {addr}: {e}"))?;
        let local = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let stop_flag = Arc::new(AtomicBool::new(false));
        let conns: Arc<Mutex<Vec<JoinHandle<()>>>> = Arc::new(Mutex::new(Vec::new()));

        let accept = {
            let stop = Arc::clone(&stop_flag);
            let conns = Arc::clone(&conns);
            std::thread::Builder::new()
                .name("amtl-replica-accept".into())
                .spawn(move || loop {
                    if stop.load(Ordering::SeqCst) {
                        break;
                    }
                    match listener.accept() {
                        Ok((stream, _peer)) => {
                            let shared = Arc::clone(&shared);
                            let stop = Arc::clone(&stop);
                            let spawned = std::thread::Builder::new()
                                .name("amtl-replica-conn".into())
                                .spawn(move || serve_conn(stream, &shared, &stop));
                            if let Ok(h) = spawned {
                                let mut conns = conns.lock().unwrap();
                                conns.retain(|c| !c.is_finished());
                                conns.push(h);
                            }
                        }
                        Err(e) if e.kind() == ErrorKind::WouldBlock => std::thread::sleep(POLL),
                        Err(_) => std::thread::sleep(POLL),
                    }
                })?
        };

        Ok(ReplicaServerHandle { addr: local, stop_flag, accept: Some(accept), conns })
    }
}

impl ReplicaServerHandle {
    /// The bound address (resolves `:0` to the actual ephemeral port).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Stop accepting, wake blocked connection threads, join everything.
    /// Idempotent; also invoked on drop.
    pub fn shutdown(&mut self) {
        self.stop_flag.store(true, Ordering::SeqCst);
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
        let handles = std::mem::take(&mut *self.conns.lock().unwrap());
        for h in handles {
            let _ = h.join();
        }
    }
}

impl Drop for ReplicaServerHandle {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// The replica's answer to `FetchMetrics`: the process-wide registry
/// plus the replica-local stats (prediction/error counters, apply lag,
/// predict latency histogram) folded in under `replica.*` names, so
/// `amtl top --connect <replica>` sees one coherent table.
fn metrics_report(shared: &ReplicaShared) -> MetricsReport {
    let stats = shared.stats();
    let mut report = MetricsReport::from_snapshot(
        MetricsReport::ROLE_REPLICA,
        stats.uptime_ms,
        obs::global().snapshot(),
    );
    for (name, v) in [
        ("replica.predictions", stats.predictions),
        ("replica.errors", stats.errors),
        ("replica.applied_entries", stats.applied_entries),
        ("replica.bootstraps", stats.bootstraps),
        ("replica.hot_swaps", stats.hot_swaps),
    ] {
        report.counters.push((name.to_string(), v));
    }
    report.counters.sort();
    report.gauges.push(("replica.lag".to_string(), stats.lag()));
    report.gauges.push(("replica.model_seq".to_string(), stats.model_seq));
    report.gauges.sort();
    report.hists.push(("replica.predict_us".to_string(), shared.hist.snapshot()));
    report.hists.sort_by(|a, b| a.0.cmp(&b.0));
    report
}

/// One connection's request loop: validate → score → respond. Latency is
/// recorded per `Predict`, measured from request decode to the response
/// hitting the socket (the full server-side service time).
fn serve_conn(stream: TcpStream, shared: &ReplicaShared, stop: &AtomicBool) {
    let _ = stream.set_nonblocking(false);
    let _ = stream.set_read_timeout(Some(POLL));
    let _ = stream.set_write_timeout(Some(WRITE_TIMEOUT));
    let _ = stream.set_nodelay(true);
    let mut reader = PatientReader { stream: &stream, stop };
    loop {
        let req = match Request::read_from(&mut reader) {
            Ok(req) => req,
            // Client closed, or we are shutting down: silent exit.
            Err(WireError::Io(_)) => return,
            Err(e) => {
                let _ = Response::Error(format!("protocol error: {e}")).write_to(&mut &stream);
                return;
            }
        };
        let started = Instant::now();
        let is_predict = matches!(req, Request::Predict { .. });
        let resp = match req {
            Request::Predict { t, x } => match shared.predict(t, &x) {
                Ok((y, model_seq)) => Response::Prediction { y, model_seq },
                Err(msg) => Response::Error(msg),
            },
            Request::FetchStats => Response::Stats(shared.stats()),
            Request::FetchMetrics => Response::Metrics(metrics_report(shared)),
            Request::Shutdown => {
                // Closes this connection only; the replica keeps serving.
                let _ = Response::ShutdownAck.write_to(&mut &stream);
                return;
            }
            // Training traffic has no business here: a replica holds a
            // read-only shadow of V and could neither commit nor prox.
            Request::FetchProxCol { .. }
            | Request::PushUpdate { .. }
            | Request::PushBatch { .. }
            | Request::FetchEta
            | Request::Register { .. }
            | Request::Heartbeat { .. }
            | Request::Leave { .. }
            | Request::PushMetrics { .. }
            | Request::FetchShardMap
            | Request::FetchSlice
            | Request::PushProxSlice { .. } => Response::Error(
                "this is a read replica; training traffic goes to the central \
                 server (`amtl --serve`)"
                    .into(),
            ),
        };
        let wrote = resp.write_to(&mut &stream).is_ok();
        if is_predict {
            shared.hist.record(started.elapsed().as_micros() as u64);
        }
        if !wrote {
            return;
        }
    }
}
