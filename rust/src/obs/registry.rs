//! A process-wide registry of named atomic counters, gauges, and log₂
//! histograms — every layer reports into [`global()`], and the
//! `FetchMetrics` wire frame snapshots it for `amtl top`.
//!
//! Names are dotted paths (`server.commits`, `wal.fsync_us`); the full
//! table with units lives in `docs/OBSERVABILITY.md`. Lookup takes a
//! short mutex, so hot paths should resolve their `Arc` handle once
//! (e.g. at construction) and record through it lock-free.

use super::hist::{HistSnapshot, Histogram};
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

/// A named collection of counters (monotonic), gauges (last-write), and
/// histograms (log₂ buckets).
#[derive(Default)]
pub struct MetricsRegistry {
    counters: Mutex<BTreeMap<String, Arc<AtomicU64>>>,
    gauges: Mutex<BTreeMap<String, Arc<AtomicU64>>>,
    hists: Mutex<BTreeMap<String, Arc<Histogram>>>,
}

impl MetricsRegistry {
    /// An empty registry.
    pub fn new() -> MetricsRegistry {
        MetricsRegistry::default()
    }

    /// The shared counter named `name`, created at zero on first use.
    pub fn counter(&self, name: &str) -> Arc<AtomicU64> {
        let mut m = self.counters.lock().unwrap();
        Arc::clone(m.entry(name.to_string()).or_default())
    }

    /// Add `delta` to the counter named `name`.
    pub fn inc(&self, name: &str, delta: u64) {
        self.counter(name).fetch_add(delta, Ordering::Relaxed);
    }

    /// The shared gauge named `name`, created at zero on first use.
    pub fn gauge(&self, name: &str) -> Arc<AtomicU64> {
        let mut m = self.gauges.lock().unwrap();
        Arc::clone(m.entry(name.to_string()).or_default())
    }

    /// Set the gauge named `name` to `value`.
    pub fn set_gauge(&self, name: &str, value: u64) {
        self.gauge(name).store(value, Ordering::Relaxed);
    }

    /// The shared histogram named `name`, created empty on first use.
    pub fn hist(&self, name: &str) -> Arc<Histogram> {
        let mut m = self.hists.lock().unwrap();
        Arc::clone(m.entry(name.to_string()).or_default())
    }

    /// Record `value` into the histogram named `name`.
    pub fn observe(&self, name: &str, value: u64) {
        self.hist(name).record(value);
    }

    /// A point-in-time copy of every registered metric, sorted by name.
    pub fn snapshot(&self) -> MetricsSnapshot {
        let counters = self
            .counters
            .lock()
            .unwrap()
            .iter()
            .map(|(k, v)| (k.clone(), v.load(Ordering::Relaxed)))
            .collect();
        let gauges = self
            .gauges
            .lock()
            .unwrap()
            .iter()
            .map(|(k, v)| (k.clone(), v.load(Ordering::Relaxed)))
            .collect();
        let hists = self
            .hists
            .lock()
            .unwrap()
            .iter()
            .map(|(k, v)| (k.clone(), v.snapshot()))
            .collect();
        MetricsSnapshot { counters, gauges, hists }
    }
}

/// A point-in-time copy of a registry (name-sorted).
#[derive(Clone, Debug, Default)]
pub struct MetricsSnapshot {
    /// Counter name → value.
    pub counters: Vec<(String, u64)>,
    /// Gauge name → value.
    pub gauges: Vec<(String, u64)>,
    /// Histogram name → bucket snapshot.
    pub hists: Vec<(String, HistSnapshot)>,
}

/// The process-wide registry every layer reports into (and the one the
/// `FetchMetrics` handlers dump).
pub fn global() -> &'static MetricsRegistry {
    static GLOBAL: OnceLock<MetricsRegistry> = OnceLock::new();
    GLOBAL.get_or_init(MetricsRegistry::new)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate_and_share_handles() {
        let r = MetricsRegistry::new();
        r.inc("a.b", 2);
        r.inc("a.b", 3);
        let h = r.counter("a.b");
        assert_eq!(h.load(Ordering::Relaxed), 5);
        h.fetch_add(1, Ordering::Relaxed);
        assert_eq!(r.counter("a.b").load(Ordering::Relaxed), 6);
    }

    #[test]
    fn gauges_keep_the_last_write() {
        let r = MetricsRegistry::new();
        r.set_gauge("lag", 10);
        r.set_gauge("lag", 3);
        assert_eq!(r.gauge("lag").load(Ordering::Relaxed), 3);
    }

    #[test]
    fn snapshot_is_name_sorted_and_complete() {
        let r = MetricsRegistry::new();
        r.inc("z.last", 1);
        r.inc("a.first", 1);
        r.set_gauge("mid", 7);
        r.observe("lat_us", 120);
        r.observe("lat_us", 4000);
        let s = r.snapshot();
        assert_eq!(
            s.counters.iter().map(|(k, _)| k.as_str()).collect::<Vec<_>>(),
            vec!["a.first", "z.last"]
        );
        assert_eq!(s.gauges, vec![("mid".to_string(), 7)]);
        assert_eq!(s.hists.len(), 1);
        assert_eq!(s.hists[0].1.count(), 2);
        assert_eq!(s.hists[0].1.max, 4000);
    }

    #[test]
    fn global_registry_is_a_singleton() {
        global().inc("obs.selftest", 1);
        assert!(global().counter("obs.selftest").load(Ordering::Relaxed) >= 1);
    }
}
