//! Lock-free log₂ histograms, generalized from the serving tier's
//! latency histogram so every layer (workers, server, WAL, transport,
//! replica) shares one implementation with `merge` and snapshot
//! iteration.
//!
//! Recording is one relaxed `fetch_add` per bucket plus a `fetch_max`
//! and a sum accumulation — cheap enough for per-activation hot paths.
//! A sample lands in the bucket of its bit length, so bucket `i` (for
//! `i >= 1`) covers `[2^(i-1), 2^i - 1]` and bucket 0 holds exactly the
//! zeros. Quantiles return the upper edge of the hit bucket clamped to
//! the recorded maximum: never below the true value and at most 2x
//! above it, at every magnitude up to `u64::MAX` (which is why there
//! are 65 buckets, not 64 — values at or above `2^63` get their own
//! bucket instead of being folded into the one below).

use std::sync::atomic::{AtomicU64, Ordering};

/// Number of log₂ buckets: one per `u64` bit length (0 through 64).
pub const BUCKETS: usize = 65;

/// Inclusive upper edge of bucket `idx`.
fn upper_edge(idx: usize) -> u64 {
    if idx == 0 {
        0
    } else if idx >= 64 {
        u64::MAX
    } else {
        (1u64 << idx) - 1
    }
}

/// A lock-free base-2 histogram of `u64` samples. The unit (µs,
/// versions, bytes) is the caller's; `docs/OBSERVABILITY.md` tabulates
/// the unit of every registered metric.
pub struct Histogram {
    buckets: [AtomicU64; BUCKETS],
    max: AtomicU64,
    sum: AtomicU64,
}

impl Histogram {
    /// An empty histogram.
    pub fn new() -> Histogram {
        Histogram {
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
            max: AtomicU64::new(0),
            sum: AtomicU64::new(0),
        }
    }

    /// The bucket a sample lands in: its bit length.
    fn bucket_index(v: u64) -> usize {
        (u64::BITS - v.leading_zeros()) as usize
    }

    /// Record one sample.
    pub fn record(&self, v: u64) {
        self.buckets[Self::bucket_index(v)].fetch_add(1, Ordering::Relaxed);
        self.max.fetch_max(v, Ordering::Relaxed);
        self.sum.fetch_add(v, Ordering::Relaxed);
    }

    /// Total number of recorded samples.
    pub fn count(&self) -> u64 {
        self.buckets.iter().map(|b| b.load(Ordering::Relaxed)).sum()
    }

    /// True when nothing has been recorded.
    pub fn is_empty(&self) -> bool {
        self.count() == 0
    }

    /// Largest recorded sample (0 when empty).
    pub fn max(&self) -> u64 {
        self.max.load(Ordering::Relaxed)
    }

    /// Sum of all recorded samples.
    pub fn sum(&self) -> u64 {
        self.sum.load(Ordering::Relaxed)
    }

    /// Mean of the recorded samples (0.0 when empty).
    pub fn mean(&self) -> f64 {
        self.snapshot().mean()
    }

    /// Conservative quantile `q` in `[0, 1]`: the upper edge of the
    /// bucket holding the rank-`q` sample, clamped to the recorded max
    /// (so it is never below the true value and at most 2x above it).
    pub fn quantile(&self, q: f64) -> u64 {
        self.snapshot().quantile(q)
    }

    /// Fold another histogram's samples into this one.
    pub fn merge(&self, other: &Histogram) {
        for (mine, theirs) in self.buckets.iter().zip(other.buckets.iter()) {
            let n = theirs.load(Ordering::Relaxed);
            if n > 0 {
                mine.fetch_add(n, Ordering::Relaxed);
            }
        }
        self.max.fetch_max(other.max(), Ordering::Relaxed);
        self.sum.fetch_add(other.sum(), Ordering::Relaxed);
    }

    /// Point-in-time copy for reporting and wire serialization.
    pub fn snapshot(&self) -> HistSnapshot {
        HistSnapshot {
            counts: std::array::from_fn(|i| self.buckets[i].load(Ordering::Relaxed)),
            max: self.max(),
            sum: self.sum(),
        }
    }
}

impl Default for Histogram {
    fn default() -> Histogram {
        Histogram::new()
    }
}

impl std::fmt::Debug for Histogram {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "Histogram(n={}, max={})", self.count(), self.max())
    }
}

/// A point-in-time copy of a [`Histogram`]: dense bucket counts plus
/// the max/sum accumulators, with the same derived statistics.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct HistSnapshot {
    /// `counts[i]` holds the samples of bit length `i`.
    pub counts: [u64; BUCKETS],
    /// Largest recorded sample.
    pub max: u64,
    /// Sum of all recorded samples.
    pub sum: u64,
}

impl HistSnapshot {
    /// A snapshot with no samples.
    pub fn empty() -> HistSnapshot {
        HistSnapshot { counts: [0; BUCKETS], max: 0, sum: 0 }
    }

    /// Total number of samples.
    pub fn count(&self) -> u64 {
        self.counts.iter().sum()
    }

    /// True when no samples were recorded.
    pub fn is_empty(&self) -> bool {
        self.count() == 0
    }

    /// Mean sample (0.0 when empty).
    pub fn mean(&self) -> f64 {
        let n = self.count();
        if n == 0 {
            0.0
        } else {
            self.sum as f64 / n as f64
        }
    }

    /// Conservative quantile (same contract as [`Histogram::quantile`]).
    pub fn quantile(&self, q: f64) -> u64 {
        let total = self.count();
        if total == 0 {
            return 0;
        }
        let rank = ((q * total as f64).ceil() as u64).clamp(1, total);
        let mut seen = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            seen += c;
            if seen >= rank {
                return upper_edge(i).min(self.max);
            }
        }
        self.max
    }

    /// The non-empty buckets as `(bucket index, count)` pairs — the
    /// sparse form the wire encoding ships.
    pub fn nonzero(&self) -> impl Iterator<Item = (usize, u64)> + '_ {
        self.counts.iter().enumerate().filter(|(_, &c)| c > 0).map(|(i, &c)| (i, c))
    }

    /// Fold another snapshot's samples into this one — the snapshot-side
    /// twin of [`Histogram::merge`], used by the fleet collector to merge
    /// wire-shipped histograms across processes.
    pub fn merge(&mut self, other: &HistSnapshot) {
        for (mine, theirs) in self.counts.iter_mut().zip(other.counts.iter()) {
            *mine += theirs;
        }
        self.max = self.max.max(other.max);
        self.sum += other.sum;
    }
}

impl Default for HistSnapshot {
    fn default() -> HistSnapshot {
        HistSnapshot::empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::forall;
    use std::sync::Arc;

    #[test]
    fn empty_histogram_reports_zeros() {
        let h = Histogram::new();
        assert!(h.is_empty());
        assert_eq!(h.count(), 0);
        assert_eq!(h.max(), 0);
        assert_eq!(h.quantile(0.5), 0);
        assert_eq!(h.mean(), 0.0);
    }

    #[test]
    fn zero_and_one_bucket_exactly() {
        let h = Histogram::new();
        h.record(0);
        h.record(0);
        h.record(1);
        assert_eq!(h.count(), 3);
        assert_eq!(h.quantile(0.5), 0); // rank 2 of [0,0,1]
        assert_eq!(h.quantile(1.0), 1);
        assert_eq!(h.max(), 1);
    }

    #[test]
    fn u64_edge_buckets_hold_the_two_times_bound() {
        // The extremes that used to share a 64-bucket top bin: values at
        // and above 2^63 get bucket 64 to themselves, so the quantile
        // bound q <= 2x true value survives at the edge of u64.
        for v in [u64::MAX, 1u64 << 63, (1u64 << 63) - 1, (1u64 << 62) + 1] {
            let h = Histogram::new();
            h.record(v);
            let q = h.quantile(1.0);
            assert!(q >= v, "quantile {q} under true value {v}");
            assert!(q as u128 <= 2 * v as u128, "quantile {q} over 2x of {v}");
        }
    }

    #[test]
    fn concurrent_recording_loses_nothing() {
        let h = Arc::new(Histogram::new());
        let threads: Vec<_> = (0..4)
            .map(|t| {
                let h = Arc::clone(&h);
                std::thread::spawn(move || {
                    for i in 0..1000u64 {
                        h.record(t * 1000 + i);
                    }
                })
            })
            .collect();
        for t in threads {
            t.join().unwrap();
        }
        assert_eq!(h.count(), 4000);
        assert_eq!(h.max(), 3999);
    }

    #[test]
    fn snapshot_matches_live_statistics() {
        let h = Histogram::new();
        for v in [3u64, 17, 120, 4096, 0] {
            h.record(v);
        }
        let s = h.snapshot();
        assert_eq!(s.count(), h.count());
        assert_eq!(s.max, h.max());
        assert_eq!(s.sum, h.sum());
        assert_eq!(s.quantile(0.5), h.quantile(0.5));
        assert_eq!(s.nonzero().map(|(_, c)| c).sum::<u64>(), 5);
    }

    #[test]
    fn prop_merge_equals_concatenated_recording() {
        forall(
            "hist merge == concatenated recording",
            150,
            |g| {
                let n = g.usize_in(0, 40);
                let m = g.usize_in(0, 40);
                let a: Vec<f64> = (0..n).map(|_| g.f64_in(0.0, 1e12)).collect();
                let b: Vec<f64> = (0..m).map(|_| g.f64_in(0.0, 1e12)).collect();
                (a, b)
            },
            |(a, b)| {
                let (ha, hb, hcat) = (Histogram::new(), Histogram::new(), Histogram::new());
                for &x in a {
                    ha.record(x as u64);
                    hcat.record(x as u64);
                }
                for &x in b {
                    hb.record(x as u64);
                    hcat.record(x as u64);
                }
                let mut snap_merged = Histogram::new().snapshot();
                snap_merged.merge(&ha.snapshot());
                snap_merged.merge(&hb.snapshot());
                ha.merge(&hb);
                ha.snapshot() == hcat.snapshot()
                    && snap_merged == hcat.snapshot()
                    && ha.quantile(0.5) == hcat.quantile(0.5)
                    && ha.quantile(0.99) == hcat.quantile(0.99)
            },
        );
    }

    #[test]
    fn prop_quantile_upper_edge_within_2x_of_true_value() {
        forall(
            "hist quantile in [true, 2x true]",
            150,
            |g| {
                let n = g.usize_in(1, 60).max(1);
                let q = g.f64_in(0.01, 1.0);
                let xs: Vec<f64> =
                    (0..n).map(|_| g.f64_in(0.0, 1e15).powf(g.f64_in(0.3, 1.0))).collect();
                (xs, q)
            },
            |(xs, q)| {
                let xs: Vec<u64> = xs.iter().map(|&x| x as u64).collect();
                if xs.is_empty() {
                    return true;
                }
                let h = Histogram::new();
                for &x in &xs {
                    h.record(x);
                }
                let mut sorted = xs.clone();
                sorted.sort_unstable();
                let rank = ((q * sorted.len() as f64).ceil() as usize).clamp(1, sorted.len());
                let truth = sorted[rank - 1];
                let got = h.quantile(*q);
                got >= truth && got as u128 <= (2 * truth as u128).max(1)
            },
        );
    }
}
