//! Fleet observability: cross-process commit spans, a multi-endpoint
//! metrics collector, and declarative health rules.
//!
//! Three pieces, built on the layers that already exist:
//!
//! * **Commit spans** — every commit is stamped with a span id derived
//!   from `(node, k)` ([`span_id`]) and carried in `PushUpdate`, so the
//!   worker, trainer, and replica processes can each emit `span` hop
//!   events ([`record_hop`]) into their own JSONL traces that join into
//!   one cross-process timeline. Hop durations also land in always-on
//!   `span.hop_us.<hop>` histograms, and the worker records the whole
//!   fetch→ack critical path in `commit_critical_path_us`.
//! * **[`Collector`]** — polls N `FetchMetrics` endpoints (trainer +
//!   replicas; the trainer fans in worker `NODE` rows), keeps a short
//!   ring-buffer history per endpoint for rate/delta derivation, and
//!   merges histograms fleet-wide via [`HistSnapshot::merge`].
//! * **[`HealthRules`]** — declarative cluster health checks (staleness
//!   runaway, replica lag divergence, eviction storm, updates/sec
//!   stall, WAL fsync spike, endpoint down) evaluated over a collector;
//!   `amtl health` exits nonzero on any [`Violation`], which is what the
//!   chaos harness and CI script against.
//!
//! Span hop names, units, and the health rule catalog are tabulated in
//! `docs/OBSERVABILITY.md`.

use super::hist::{HistSnapshot, Histogram};
use super::trace::TraceWriter;
use crate::transport::wire::MetricsReport;
use crate::util::json::Json;
use std::collections::VecDeque;
use std::fmt;
use std::sync::{Arc, OnceLock};
use std::time::{SystemTime, UNIX_EPOCH};

// ------------------------------------------------------------ span ids

/// Bits of a span id that hold the activation counter `k`.
const SPAN_K_BITS: u32 = 48;

/// The cross-process span id of one commit: node index in the top 16
/// bits, activation counter `k` in the low 48. Structured rather than
/// random so every process derives the *same* id from `(t, k)` without
/// coordination, and a trace reader can recover both with [`split_span`].
/// Collision-free for `node < 65536` and `k < 2^48` — far beyond any
/// deployment this repo targets.
pub fn span_id(node: usize, k: u64) -> u64 {
    ((node as u64 & 0xFFFF) << SPAN_K_BITS) | (k & ((1 << SPAN_K_BITS) - 1))
}

/// Recover `(node, k)` from a span id.
pub fn split_span(span: u64) -> (usize, u64) {
    ((span >> SPAN_K_BITS) as usize, span & ((1 << SPAN_K_BITS) - 1))
}

/// Wall-clock microseconds since the UNIX epoch. Span hop timestamps use
/// the wall clock — not a per-process monotonic clock — so hops emitted
/// by different processes on the same host are directly comparable.
pub fn unix_us() -> u64 {
    SystemTime::now()
        .duration_since(UNIX_EPOCH)
        .map(|d| d.as_micros() as u64)
        .unwrap_or(0)
}

// ---------------------------------------------------------------- hops

/// One hop of a commit's cross-process life, in causal order. Each hop
/// is emitted by the process that performed it; the union over all
/// traces reconstructs the commit end to end.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Hop {
    /// Worker: backward fetch (`fetch_prox_col`) round trip.
    NodeFetch,
    /// Worker: forward gradient step on the node's own data.
    NodeStep,
    /// Worker: `push_update` send → `Pushed` ack (the full wire+server
    /// round trip as the client saw it).
    WireCommit,
    /// Trainer: WAL append + fsync of the commit record.
    Wal,
    /// Trainer: staging the commit into its per-column slot (the
    /// coalescing path) + dedup/apply bookkeeping.
    Staging,
    /// Trainer: the proximal fold that drained this commit's column.
    ProxFold,
    /// Replica: replaying the commit's WAL entry into the shadow model.
    ReplicaApply,
}

impl Hop {
    /// Every hop, in causal order.
    pub const ALL: [Hop; 7] = [
        Hop::NodeFetch,
        Hop::NodeStep,
        Hop::WireCommit,
        Hop::Wal,
        Hop::Staging,
        Hop::ProxFold,
        Hop::ReplicaApply,
    ];

    /// The hop's name as it appears in `span` trace events and in the
    /// `span.hop_us.<name>` histogram family.
    pub fn name(self) -> &'static str {
        match self {
            Hop::NodeFetch => "node_fetch",
            Hop::NodeStep => "node_step",
            Hop::WireCommit => "wire_commit",
            Hop::Wal => "wal",
            Hop::Staging => "staging",
            Hop::ProxFold => "prox_fold",
            Hop::ReplicaApply => "replica_apply",
        }
    }

    /// Position in the causal order (0-based). On one host's shared wall
    /// clock, a well-formed span's hop `start_us` values are monotone
    /// non-decreasing in this rank — the property the integration tests
    /// assert.
    pub fn causal_rank(self) -> usize {
        Self::ALL.iter().position(|h| *h == self).unwrap_or(usize::MAX)
    }

    /// Parse a hop from its trace-event name.
    pub fn from_name(name: &str) -> Option<Hop> {
        Self::ALL.into_iter().find(|h| h.name() == name)
    }
}

/// Pre-resolved histogram handles for the span hot paths: one
/// `span.hop_us.<hop>` histogram per hop plus `commit_critical_path_us`.
/// Resolved once (registry lookup takes a mutex) and recorded through
/// lock-free thereafter.
struct SpanObs {
    hops: [Arc<Histogram>; Hop::ALL.len()],
    critical_path: Arc<Histogram>,
}

fn span_obs() -> &'static SpanObs {
    static OBS: OnceLock<SpanObs> = OnceLock::new();
    OBS.get_or_init(|| {
        let reg = super::global();
        SpanObs {
            hops: std::array::from_fn(|i| {
                reg.hist(&format!("span.hop_us.{}", Hop::ALL[i].name()))
            }),
            critical_path: reg.hist("commit_critical_path_us"),
        }
    })
}

/// Record one span hop: the duration always lands in the hop's
/// `span.hop_us.<hop>` histogram; when a trace writer is attached, a
/// `span` event with wall-clock `start_us`/`end_us` is emitted so the
/// hop can be joined cross-process. The span id is written as a 16-digit
/// hex string (JSON numbers are doubles; ids exceed 2^53).
pub fn record_hop(
    trace: Option<&TraceWriter>,
    hop: Hop,
    node: usize,
    k: u64,
    start_us: u64,
    end_us: u64,
) {
    let obs = span_obs();
    obs.hops[hop.causal_rank()].record(end_us.saturating_sub(start_us));
    if let Some(tw) = trace {
        tw.event(
            "span",
            Some(node),
            Some(k),
            None,
            &[
                ("span", Json::Str(format!("{:016x}", span_id(node, k)))),
                ("hop", Json::Str(hop.name().to_string())),
                ("start_us", Json::Num(start_us as f64)),
                ("end_us", Json::Num(end_us as f64)),
            ],
        );
    }
}

/// Record one commit's worker-side critical path (fetch start → commit
/// ack) into `commit_critical_path_us`.
pub fn record_critical_path(us: u64) {
    span_obs().critical_path.record(us);
}

// ------------------------------------------------------- delta helpers

/// Counter delta across two polls of the *same* endpoint, guarded
/// against restarts: a counter that went backwards (the endpoint
/// restarted and re-zeroed its registry) reads as 0, not as a u64
/// underflow. `amtl top` and the [`Collector`] both derive rates
/// through this.
pub fn counter_delta(prev: u64, cur: u64) -> u64 {
    cur.saturating_sub(prev)
}

/// Rate per second from two counter readings `dt_secs` apart (0.0 when
/// the interval is degenerate or the counter reset).
pub fn counter_rate(prev: u64, cur: u64, dt_secs: f64) -> f64 {
    if dt_secs <= 0.0 {
        0.0
    } else {
        counter_delta(prev, cur) as f64 / dt_secs
    }
}

// ------------------------------------------------------- the collector

/// How many samples of history each endpoint keeps (at `amtl top`'s
/// default 1 s poll interval: two minutes of rate context).
pub const HISTORY_CAP: usize = 120;

/// One endpoint's sample history: a bounded ring of
/// `(local clock ms, report)` pairs plus reachability bookkeeping.
pub struct EndpointHistory {
    /// The endpoint address this history belongs to (as given to
    /// [`Collector::new`]; purely a label here).
    pub addr: String,
    samples: VecDeque<(u64, MetricsReport)>,
    /// Whether the most recent poll failed to produce a report.
    pub down: bool,
    /// Consecutive failed polls ending now (0 when up).
    pub down_streak: u64,
}

impl EndpointHistory {
    fn new(addr: &str) -> EndpointHistory {
        EndpointHistory {
            addr: addr.to_string(),
            samples: VecDeque::new(),
            down: false,
            down_streak: 0,
        }
    }

    /// The most recent report, if any poll ever succeeded.
    pub fn latest(&self) -> Option<&MetricsReport> {
        self.samples.back().map(|(_, r)| r)
    }

    /// The oldest retained report.
    pub fn oldest(&self) -> Option<&MetricsReport> {
        self.samples.front().map(|(_, r)| r)
    }

    /// Number of retained samples.
    pub fn len(&self) -> usize {
        self.samples.len()
    }

    /// True when no sample has ever been recorded.
    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }

    /// Delta of counter `name` across the retained window (first → last
    /// sample), restart-guarded. With a single sample the absolute value
    /// is the delta — the window started empty.
    pub fn counter_window_delta(&self, name: &str) -> u64 {
        match (self.samples.front(), self.samples.back()) {
            (Some((_, first)), Some((_, last))) if self.samples.len() >= 2 => counter_delta(
                first.counter(name).unwrap_or(0),
                last.counter(name).unwrap_or(0),
            ),
            (_, Some((_, only))) => only.counter(name).unwrap_or(0),
            _ => 0,
        }
    }

    /// Rate per second of counter `name` across the retained window.
    /// `None` until two samples exist (a rate needs an interval).
    pub fn counter_window_rate(&self, name: &str) -> Option<f64> {
        let (first_at, first) = self.samples.front()?;
        let (last_at, last) = self.samples.back()?;
        if self.samples.len() < 2 {
            return None;
        }
        let dt = last_at.saturating_sub(*first_at) as f64 / 1000.0;
        Some(counter_rate(
            first.counter(name).unwrap_or(0),
            last.counter(name).unwrap_or(0),
            dt,
        ))
    }
}

/// One flattened row of the fleet view: an endpoint's own report, or one
/// of the `NODE` sub-reports a trainer fanned in.
pub struct FleetRow<'a> {
    /// Address of the endpoint the row came from.
    pub addr: &'a str,
    /// Task index for `NODE` rows fanned in by a trainer.
    pub node: Option<u32>,
    /// The row's report.
    pub report: &'a MetricsReport,
}

impl FleetRow<'_> {
    /// Display label: `addr` for an endpoint's own row,
    /// `addr#node<t>` for a fanned-in worker row.
    pub fn label(&self) -> String {
        match self.node {
            Some(t) => format!("{}#node{t}", self.addr),
            None => self.addr.to_string(),
        }
    }
}

/// A cluster-wide metrics collector: per-endpoint ring-buffer histories
/// fed by whatever polling mechanism the caller has (the `amtl top
/// --fleet` / `amtl health` commands poll `FetchMetrics` sockets; the
/// chaos harness feeds in-process reports directly), plus fleet-level
/// merge/flatten queries and [`HealthRules`] evaluation over the result.
pub struct Collector {
    endpoints: Vec<EndpointHistory>,
}

impl Collector {
    /// A collector over the given endpoint labels, with empty histories.
    pub fn new<S: AsRef<str>>(addrs: &[S]) -> Collector {
        Collector {
            endpoints: addrs.iter().map(|a| EndpointHistory::new(a.as_ref())).collect(),
        }
    }

    /// The tracked endpoints, in construction order.
    pub fn endpoints(&self) -> &[EndpointHistory] {
        &self.endpoints
    }

    /// Feed one poll result for endpoint `idx` (`None` = unreachable).
    /// `at_ms` is any collector-local monotonic clock (e.g.
    /// [`crate::obs::log::uptime_ms`]); only differences matter.
    pub fn observe(&mut self, idx: usize, at_ms: u64, report: Option<MetricsReport>) {
        let Some(ep) = self.endpoints.get_mut(idx) else { return };
        match report {
            Some(r) => {
                ep.down = false;
                ep.down_streak = 0;
                ep.samples.push_back((at_ms, r));
                while ep.samples.len() > HISTORY_CAP {
                    ep.samples.pop_front();
                }
            }
            None => {
                ep.down = true;
                ep.down_streak += 1;
            }
        }
    }

    /// Poll every endpoint through `fetch` (address → report) and feed
    /// the results in. Returns how many endpoints answered.
    pub fn poll_with(
        &mut self,
        at_ms: u64,
        mut fetch: impl FnMut(&str) -> Option<MetricsReport>,
    ) -> usize {
        let mut up = 0;
        for i in 0..self.endpoints.len() {
            let report = fetch(&self.endpoints[i].addr.clone());
            up += usize::from(report.is_some());
            self.observe(i, at_ms, report);
        }
        up
    }

    /// Every current row of the fleet, flattened: each endpoint's latest
    /// report, then (for trainers) its fanned-in `NODE` rows.
    pub fn rows(&self) -> Vec<FleetRow<'_>> {
        let mut rows = Vec::new();
        for ep in &self.endpoints {
            if let Some(report) = ep.latest() {
                rows.push(FleetRow { addr: &ep.addr, node: None, report });
                for (t, sub) in &report.nodes {
                    rows.push(FleetRow { addr: &ep.addr, node: Some(*t), report: sub });
                }
            }
        }
        rows
    }

    /// The histogram named `name` merged across every current fleet row
    /// (endpoints and `NODE` sub-reports alike). `None` when no row
    /// carries it.
    pub fn merged_hist(&self, name: &str) -> Option<HistSnapshot> {
        let mut acc: Option<HistSnapshot> = None;
        for row in self.rows() {
            if let Some(h) = row.report.hist(name) {
                match &mut acc {
                    Some(a) => a.merge(h),
                    None => acc = Some(h.clone()),
                }
            }
        }
        acc
    }

    /// Sum of counter `name` across every current fleet row.
    pub fn summed_counter(&self, name: &str) -> u64 {
        self.rows().iter().filter_map(|r| r.report.counter(name)).sum()
    }
}

// --------------------------------------------------------- health rules

/// Declarative cluster health rules, evaluated over a [`Collector`].
/// Each threshold catches one way the paper's asynchrony story goes
/// wrong operationally; the catalog with rationale lives in
/// `docs/OBSERVABILITY.md`.
#[derive(Clone, Debug)]
pub struct HealthRules {
    /// Staleness runaway: fire when the trainer's observed staleness max
    /// exceeds this bound. Meaningful under `--method semisync` (set it
    /// to the run's `--staleness` bound: the scheduler *guarantees* it,
    /// so exceeding it is a correctness bug, not load). `None` = skip.
    pub staleness_bound: Option<u64>,
    /// Replica lag divergence: fire when a replica reports
    /// `replica.lag` above this many commits — the feed stopped keeping
    /// up and predictions are going stale.
    pub max_replica_lag: u64,
    /// Eviction storm: fire when `registry.evictions` grew by at least
    /// this much over the retained window — membership is flapping
    /// faster than nodes rejoin.
    pub eviction_storm: u64,
    /// Updates/sec stall: fire when the trainer's `server.commits` rate
    /// over the window drops below this. 0.0 disables the rule (the
    /// default — a *finished* run legitimately commits nothing).
    pub min_updates_per_sec: f64,
    /// WAL fsync latency spike: fire when `wal.fsync_us` p99 exceeds
    /// this. The fsync is on every commit's ack path, so a slow disk
    /// stalls the whole training side.
    pub wal_fsync_p99_us: u64,
}

impl Default for HealthRules {
    fn default() -> HealthRules {
        HealthRules {
            staleness_bound: None,
            max_replica_lag: 5_000,
            eviction_storm: 3,
            min_updates_per_sec: 0.0,
            wal_fsync_p99_us: 100_000,
        }
    }
}

/// One fired health rule: which rule, where, and the measured evidence.
#[derive(Clone, Debug, PartialEq)]
pub struct Violation {
    /// Rule identifier (`staleness_runaway`, `replica_lag`,
    /// `eviction_storm`, `updates_stall`, `wal_fsync_spike`,
    /// `endpoint_down`).
    pub rule: &'static str,
    /// The endpoint (or `addr#node<t>` row) the evidence came from.
    pub endpoint: String,
    /// Human-readable measured-vs-threshold detail.
    pub detail: String,
}

impl fmt::Display for Violation {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "[{}] {}: {}", self.rule, self.endpoint, self.detail)
    }
}

impl HealthRules {
    /// Evaluate every rule over the collector's current state. An empty
    /// result is a healthy fleet; `amtl health` exits nonzero otherwise.
    pub fn evaluate(&self, c: &Collector) -> Vec<Violation> {
        let mut out = Vec::new();
        for ep in c.endpoints() {
            if ep.down {
                out.push(Violation {
                    rule: "endpoint_down",
                    endpoint: ep.addr.clone(),
                    detail: format!(
                        "unreachable for {} consecutive poll(s)",
                        ep.down_streak
                    ),
                });
                continue;
            }
            let Some(latest) = ep.latest() else { continue };
            if let Some(bound) = self.staleness_bound {
                if let Some(h) = latest.hist("server.staleness") {
                    if h.max > bound {
                        out.push(Violation {
                            rule: "staleness_runaway",
                            endpoint: ep.addr.clone(),
                            detail: format!(
                                "staleness max {} exceeds the semisync bound {bound}",
                                h.max
                            ),
                        });
                    }
                }
            }
            if let Some(lag) = latest.gauge("replica.lag") {
                if lag > self.max_replica_lag {
                    out.push(Violation {
                        rule: "replica_lag",
                        endpoint: ep.addr.clone(),
                        detail: format!(
                            "replica lag {lag} commits exceeds {}",
                            self.max_replica_lag
                        ),
                    });
                }
            }
            if self.eviction_storm > 0 {
                let evictions = ep.counter_window_delta("registry.evictions");
                if evictions >= self.eviction_storm {
                    out.push(Violation {
                        rule: "eviction_storm",
                        endpoint: ep.addr.clone(),
                        detail: format!(
                            "{evictions} eviction(s) in the window (threshold {})",
                            self.eviction_storm
                        ),
                    });
                }
            }
            if self.min_updates_per_sec > 0.0 && latest.counter("server.commits").is_some() {
                if let Some(rate) = ep.counter_window_rate("server.commits") {
                    if rate < self.min_updates_per_sec {
                        out.push(Violation {
                            rule: "updates_stall",
                            endpoint: ep.addr.clone(),
                            detail: format!(
                                "{rate:.2} updates/sec below the floor {:.2}",
                                self.min_updates_per_sec
                            ),
                        });
                    }
                }
            }
            if let Some(h) = latest.hist("wal.fsync_us") {
                if !h.is_empty() && h.quantile(0.99) > self.wal_fsync_p99_us {
                    out.push(Violation {
                        rule: "wal_fsync_spike",
                        endpoint: ep.addr.clone(),
                        detail: format!(
                            "wal fsync p99 {}us exceeds {}us",
                            h.quantile(0.99),
                            self.wal_fsync_p99_us
                        ),
                    });
                }
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn report_with(
        role: u8,
        counters: Vec<(&str, u64)>,
        gauges: Vec<(&str, u64)>,
        hists: Vec<(&str, HistSnapshot)>,
    ) -> MetricsReport {
        MetricsReport {
            role,
            uptime_ms: 1000,
            counters: counters.into_iter().map(|(k, v)| (k.to_string(), v)).collect(),
            gauges: gauges.into_iter().map(|(k, v)| (k.to_string(), v)).collect(),
            hists: hists.into_iter().map(|(k, v)| (k.to_string(), v)).collect(),
            nodes: Vec::new(),
        }
    }

    fn hist_of(samples: &[u64]) -> HistSnapshot {
        let h = Histogram::new();
        for &s in samples {
            h.record(s);
        }
        h.snapshot()
    }

    #[test]
    fn span_id_roundtrips_and_separates_nodes() {
        for (node, k) in [(0usize, 0u64), (1, 7), (65535, (1 << 48) - 1), (42, 12345)] {
            let id = span_id(node, k);
            assert_eq!(split_span(id), (node, k));
        }
        assert_ne!(span_id(1, 7), span_id(2, 7));
        assert_ne!(span_id(1, 7), span_id(1, 8));
    }

    #[test]
    fn hop_names_roundtrip_and_rank_is_causal() {
        for (i, hop) in Hop::ALL.into_iter().enumerate() {
            assert_eq!(hop.causal_rank(), i);
            assert_eq!(Hop::from_name(hop.name()), Some(hop));
        }
        assert_eq!(Hop::from_name("nope"), None);
        assert!(Hop::NodeFetch.causal_rank() < Hop::Wal.causal_rank());
        assert!(Hop::Wal.causal_rank() < Hop::ReplicaApply.causal_rank());
    }

    #[test]
    fn counter_delta_guards_restarts() {
        assert_eq!(counter_delta(10, 25), 15);
        assert_eq!(counter_delta(10, 10), 0);
        // A restarted endpoint re-zeroes its counters; the delta must
        // read 0, not underflow to ~u64::MAX.
        assert_eq!(counter_delta(1000, 3), 0);
        assert_eq!(counter_rate(1000, 3, 1.0), 0.0);
        assert_eq!(counter_rate(10, 30, 2.0), 10.0);
        assert_eq!(counter_rate(10, 30, 0.0), 0.0);
    }

    #[test]
    fn collector_history_is_bounded_and_rates_derive() {
        let mut c = Collector::new(&["a"]);
        for i in 0..(HISTORY_CAP as u64 + 40) {
            let r = report_with(0, vec![("server.commits", i * 10)], vec![], vec![]);
            c.observe(0, i * 1000, Some(r));
        }
        let ep = &c.endpoints()[0];
        assert_eq!(ep.len(), HISTORY_CAP);
        // 10 commits per 1000 ms sample → 10/sec across the window.
        let rate = ep.counter_window_rate("server.commits").unwrap();
        assert!((rate - 10.0).abs() < 1e-9, "rate {rate}");
    }

    #[test]
    fn collector_merges_hists_across_endpoint_and_node_rows() {
        let mut trainer = report_with(0, vec![], vec![], vec![("lat_us", hist_of(&[10, 20]))]);
        trainer
            .nodes
            .push((0, report_with(2, vec![], vec![], vec![("lat_us", hist_of(&[30]))])));
        let replica = report_with(1, vec![], vec![], vec![("lat_us", hist_of(&[40, 50, 60]))]);
        let mut c = Collector::new(&["t", "r"]);
        c.observe(0, 0, Some(trainer));
        c.observe(1, 0, Some(replica));
        assert_eq!(c.rows().len(), 3);
        let merged = c.merged_hist("lat_us").unwrap();
        assert_eq!(merged.count(), 6);
        assert_eq!(merged.max, 60);
        assert_eq!(merged.sum, 10 + 20 + 30 + 40 + 50 + 60);
    }

    #[test]
    fn health_rules_fire_on_each_condition() {
        let mut c = Collector::new(&["trainer", "replica", "dead"]);
        let trainer = report_with(
            0,
            vec![("registry.evictions", 5), ("server.commits", 100)],
            vec![],
            vec![
                ("server.staleness", hist_of(&[1, 2, 9])),
                ("wal.fsync_us", hist_of(&[200_000])),
            ],
        );
        let replica = report_with(1, vec![], vec![("replica.lag", 9_999)], vec![]);
        c.observe(0, 0, Some(trainer));
        c.observe(1, 0, Some(replica));
        c.observe(2, 0, None);
        let rules = HealthRules {
            staleness_bound: Some(4),
            max_replica_lag: 5_000,
            eviction_storm: 3,
            min_updates_per_sec: 0.0,
            wal_fsync_p99_us: 100_000,
        };
        let violations = rules.evaluate(&c);
        let fired: Vec<&str> = violations.iter().map(|v| v.rule).collect();
        assert!(fired.contains(&"staleness_runaway"), "{fired:?}");
        assert!(fired.contains(&"replica_lag"), "{fired:?}");
        assert!(fired.contains(&"eviction_storm"), "{fired:?}");
        assert!(fired.contains(&"wal_fsync_spike"), "{fired:?}");
        assert!(fired.contains(&"endpoint_down"), "{fired:?}");
        assert!(!fired.contains(&"updates_stall"), "disabled by default: {fired:?}");
    }

    #[test]
    fn healthy_fleet_has_no_violations() {
        let mut c = Collector::new(&["trainer"]);
        let r = report_with(
            0,
            vec![("server.commits", 50), ("registry.evictions", 0)],
            vec![],
            vec![
                ("server.staleness", hist_of(&[0, 1, 2])),
                ("wal.fsync_us", hist_of(&[80, 120])),
            ],
        );
        c.observe(0, 0, Some(r.clone()));
        let mut r2 = r;
        r2.counters[0].1 = 90; // server.commits advances; evictions stay flat
        c.observe(0, 1000, Some(r2));
        let rules = HealthRules {
            staleness_bound: Some(4),
            min_updates_per_sec: 1.0,
            ..HealthRules::default()
        };
        assert_eq!(rules.evaluate(&c), Vec::new());
    }

    #[test]
    fn updates_stall_fires_when_enabled_and_flat() {
        let mut c = Collector::new(&["trainer"]);
        let r = report_with(0, vec![("server.commits", 70)], vec![], vec![]);
        c.observe(0, 0, Some(r.clone()));
        c.observe(0, 2000, Some(r));
        let rules =
            HealthRules { min_updates_per_sec: 0.5, ..HealthRules::default() };
        let fired: Vec<&str> = rules.evaluate(&c).iter().map(|v| v.rule).collect();
        assert_eq!(fired, vec!["updates_stall"]);
    }

    #[test]
    fn eviction_storm_uses_window_delta_not_lifetime_total() {
        // An endpoint that evicted a lot long ago but is quiet across the
        // retained window must NOT fire once two samples bracket it.
        let mut c = Collector::new(&["trainer"]);
        let r = report_with(0, vec![("registry.evictions", 50)], vec![], vec![]);
        c.observe(0, 0, Some(r.clone()));
        c.observe(0, 1000, Some(r));
        assert!(HealthRules::default().evaluate(&c).is_empty());
        // A single-sample history (the in-process chaos case) reads the
        // absolute count: the window began at process start.
        let mut c1 = Collector::new(&["storm"]);
        c1.observe(0, 0, Some(report_with(0, vec![("registry.evictions", 50)], vec![], vec![])));
        let fired: Vec<&str> =
            HealthRules::default().evaluate(&c1).iter().map(|v| v.rule).collect();
        assert_eq!(fired, vec!["eviction_storm"]);
    }
}
