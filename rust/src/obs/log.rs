//! Leveled, target-prefixed structured logging to stderr, timestamped
//! against a process-wide monotonic run clock.
//!
//! The filter is set once at startup from `--log-level` (CLI) falling
//! back to the `AMTL_LOG` environment variable, then `warn`. Every
//! diagnostic in `rust/src/` goes through the `log_*!` macros (CI greps
//! for raw `eprintln!` outside this module); user-facing CLI output in
//! `main.rs` and the examples stays on stdout.
//!
//! ```text
//! [   12.345s WARN  persist] snapshot 000042 unreadable; falling back
//! ```

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::OnceLock;
use std::time::Instant;

/// Log verbosity, ordered from most to least severe.
#[derive(Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Debug)]
pub enum Level {
    /// The run cannot proceed as asked (mirrors fatal error paths).
    Error = 0,
    /// Something degraded but the run continues (the default filter).
    Warn = 1,
    /// Lifecycle milestones: connections, checkpoints, evictions.
    Info = 2,
    /// Per-component diagnostics useful when debugging a run.
    Debug = 3,
    /// Per-activation firehose; pair with `--trace-out` for analysis.
    Trace = 4,
}

impl Level {
    /// Parse a level name (case-insensitive); `None` for unknown names.
    pub fn parse(s: &str) -> Option<Level> {
        match s.to_ascii_lowercase().as_str() {
            "error" => Some(Level::Error),
            "warn" | "warning" => Some(Level::Warn),
            "info" => Some(Level::Info),
            "debug" => Some(Level::Debug),
            "trace" => Some(Level::Trace),
            _ => None,
        }
    }

    /// The lowercase level name.
    pub fn name(self) -> &'static str {
        match self {
            Level::Error => "error",
            Level::Warn => "warn",
            Level::Info => "info",
            Level::Debug => "debug",
            Level::Trace => "trace",
        }
    }

    fn tag(self) -> &'static str {
        match self {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        }
    }
}

static MAX_LEVEL: AtomicU8 = AtomicU8::new(Level::Warn as u8);
static CLOCK: OnceLock<Instant> = OnceLock::new();

/// Seconds on the monotonic run clock (started at first logger or
/// metrics use in this process).
pub fn run_clock_secs() -> f64 {
    CLOCK.get_or_init(Instant::now).elapsed().as_secs_f64()
}

/// Milliseconds on the monotonic run clock (the `uptime_ms` every
/// `MetricsReport` carries).
pub fn uptime_ms() -> u64 {
    CLOCK.get_or_init(Instant::now).elapsed().as_millis() as u64
}

/// Set the maximum emitted level (also starts the run clock).
pub fn set_level(level: Level) {
    CLOCK.get_or_init(Instant::now);
    MAX_LEVEL.store(level as u8, Ordering::Relaxed);
}

/// The current maximum emitted level.
pub fn max_level() -> Level {
    match MAX_LEVEL.load(Ordering::Relaxed) {
        0 => Level::Error,
        1 => Level::Warn,
        2 => Level::Info,
        3 => Level::Debug,
        _ => Level::Trace,
    }
}

/// True when a record at `level` would be emitted.
pub fn enabled(level: Level) -> bool {
    (level as u8) <= MAX_LEVEL.load(Ordering::Relaxed)
}

/// Initialize the filter: an explicit CLI value (`--log-level`) wins,
/// then the `AMTL_LOG` environment variable, then `warn`. Errors name
/// the accepted levels.
pub fn init(cli: Option<&str>) -> Result<(), String> {
    let source = match cli {
        Some(v) => Some(v.to_string()),
        None => std::env::var("AMTL_LOG").ok(),
    };
    let level = match source {
        None => Level::Warn,
        Some(v) => Level::parse(&v)
            .ok_or_else(|| format!("bad log level '{v}' (error|warn|info|debug|trace)"))?,
    };
    set_level(level);
    Ok(())
}

/// Emit one record (macro backend — call through the `log_*!` macros,
/// which skip formatting entirely when the level is filtered out).
pub fn emit(level: Level, target: &str, args: std::fmt::Arguments<'_>) {
    if !enabled(level) {
        return;
    }
    eprintln!("[{:10.3}s {} {}] {}", run_clock_secs(), level.tag(), target, args);
}

/// Log at `error` level: `log_error!("target", "fmt {}", args)`.
#[macro_export]
macro_rules! log_error {
    ($target:expr, $($arg:tt)*) => {
        if $crate::obs::log::enabled($crate::obs::log::Level::Error) {
            $crate::obs::log::emit(
                $crate::obs::log::Level::Error, $target, format_args!($($arg)*));
        }
    };
}

/// Log at `warn` level: `log_warn!("target", "fmt {}", args)`.
#[macro_export]
macro_rules! log_warn {
    ($target:expr, $($arg:tt)*) => {
        if $crate::obs::log::enabled($crate::obs::log::Level::Warn) {
            $crate::obs::log::emit(
                $crate::obs::log::Level::Warn, $target, format_args!($($arg)*));
        }
    };
}

/// Log at `info` level: `log_info!("target", "fmt {}", args)`.
#[macro_export]
macro_rules! log_info {
    ($target:expr, $($arg:tt)*) => {
        if $crate::obs::log::enabled($crate::obs::log::Level::Info) {
            $crate::obs::log::emit(
                $crate::obs::log::Level::Info, $target, format_args!($($arg)*));
        }
    };
}

/// Log at `debug` level: `log_debug!("target", "fmt {}", args)`.
#[macro_export]
macro_rules! log_debug {
    ($target:expr, $($arg:tt)*) => {
        if $crate::obs::log::enabled($crate::obs::log::Level::Debug) {
            $crate::obs::log::emit(
                $crate::obs::log::Level::Debug, $target, format_args!($($arg)*));
        }
    };
}

/// Log at `trace` level: `log_trace!("target", "fmt {}", args)`.
#[macro_export]
macro_rules! log_trace {
    ($target:expr, $($arg:tt)*) => {
        if $crate::obs::log::enabled($crate::obs::log::Level::Trace) {
            $crate::obs::log::emit(
                $crate::obs::log::Level::Trace, $target, format_args!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_every_level_name() {
        assert_eq!(Level::parse("error"), Some(Level::Error));
        assert_eq!(Level::parse("WARN"), Some(Level::Warn));
        assert_eq!(Level::parse("warning"), Some(Level::Warn));
        assert_eq!(Level::parse("Info"), Some(Level::Info));
        assert_eq!(Level::parse("debug"), Some(Level::Debug));
        assert_eq!(Level::parse("trace"), Some(Level::Trace));
        assert_eq!(Level::parse("loud"), None);
        for l in [Level::Error, Level::Warn, Level::Info, Level::Debug, Level::Trace] {
            assert_eq!(Level::parse(l.name()), Some(l));
        }
    }

    #[test]
    fn severity_ordering_gates_levels() {
        // Process-global state: assert the ordering relation rather than
        // mutating the shared filter (tests run multithreaded).
        assert!(Level::Error < Level::Warn);
        assert!(Level::Warn < Level::Info);
        assert!(Level::Info < Level::Debug);
        assert!(Level::Debug < Level::Trace);
        // Whatever the filter is, error is at least as enabled as trace.
        assert!(enabled(Level::Error) || !enabled(Level::Trace));
    }

    #[test]
    fn init_rejects_garbage_levels() {
        let err = init(Some("loud")).unwrap_err();
        assert!(err.contains("error|warn|info|debug|trace"), "{err}");
    }

    #[test]
    fn run_clock_is_monotonic() {
        let a = run_clock_secs();
        let b = run_clock_secs();
        assert!(b >= a);
        let _ = uptime_ms();
    }
}
