//! Per-run JSONL trace: one JSON object per line, one line per run
//! event (activation, commit, prox, checkpoint, eviction), so a
//! cross-node delay/staleness timeline can be reconstructed offline.
//!
//! Every event carries `ts_us` (microseconds on this writer's monotonic
//! clock) and `event`; identifiers (`node`, `k`, `version`) and
//! event-specific extras ride along when known. The schema is tabulated
//! in `docs/OBSERVABILITY.md`. Writers are shared (`Arc`) across the
//! worker/server/persist layers. Lines are buffered and flushed every
//! [`FLUSH_EVERY`] events (flushing per line measurably taxes the
//! instrumented hot path); [`TraceWriter::flush`] is called at
//! end-of-run/Shutdown barriers and on `Drop`, so a completed run's
//! file always holds every event and a killed process leaves a valid
//! prefix.

use crate::util::json::Json;
use anyhow::Result;
use std::fs::File;
use std::io::{BufWriter, Write};
use std::path::Path;
use std::sync::Mutex;
use std::time::Instant;

/// Events buffered between automatic flushes.
const FLUSH_EVERY: u32 = 64;

struct Inner {
    out: BufWriter<File>,
    /// Events written since the last flush.
    pending: u32,
}

/// An append-only JSONL event sink (see the module docs for the
/// schema). Cloned by `Arc` into every instrumented layer.
pub struct TraceWriter {
    inner: Mutex<Inner>,
    start: Instant,
}

impl std::fmt::Debug for TraceWriter {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "TraceWriter")
    }
}

impl TraceWriter {
    /// Create (truncating) the trace file at `path`, creating parent
    /// directories as needed.
    pub fn create(path: &Path) -> Result<TraceWriter> {
        if let Some(parent) = path.parent() {
            if !parent.as_os_str().is_empty() {
                std::fs::create_dir_all(parent)?;
            }
        }
        Ok(TraceWriter {
            inner: Mutex::new(Inner { out: BufWriter::new(File::create(path)?), pending: 0 }),
            start: Instant::now(),
        })
    }

    /// Append one event line. `node`, `k`, and `version` are emitted
    /// only when known; `extra` carries event-specific fields.
    pub fn event(
        &self,
        event: &str,
        node: Option<usize>,
        k: Option<u64>,
        version: Option<u64>,
        extra: &[(&str, Json)],
    ) {
        let mut fields = vec![
            ("ts_us", Json::Num(self.start.elapsed().as_micros() as f64)),
            ("event", Json::Str(event.to_string())),
        ];
        if let Some(n) = node {
            fields.push(("node", Json::Num(n as f64)));
        }
        if let Some(k) = k {
            fields.push(("k", Json::Num(k as f64)));
        }
        if let Some(v) = version {
            fields.push(("version", Json::Num(v as f64)));
        }
        for (key, val) in extra {
            fields.push((key, val.clone()));
        }
        let line = Json::obj(fields).to_string();
        // Trace I/O must never take the run down: drop the line on a
        // full disk rather than propagate.
        let mut inner = self.inner.lock().unwrap();
        let _ = writeln!(inner.out, "{line}");
        inner.pending += 1;
        if inner.pending >= FLUSH_EVERY {
            let _ = inner.out.flush();
            inner.pending = 0;
        }
    }

    /// Flush buffered lines to the OS. Called at explicit end-of-run /
    /// `Shutdown` barriers (and on `Drop`) so live tail readers — `top`,
    /// the smoke jobs, the chaos checker — see every event written so
    /// far, not just the last multiple of [`FLUSH_EVERY`].
    pub fn flush(&self) {
        let mut inner = self.inner.lock().unwrap();
        let _ = inner.out.flush();
        inner.pending = 0;
    }
}

impl Drop for TraceWriter {
    fn drop(&mut self) {
        // The BufWriter's own Drop would flush too, but do it explicitly:
        // the guarantee "a dropped writer's file holds every event" is a
        // documented part of the trace contract, not an accident.
        self.flush();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn events_are_one_json_object_per_line() {
        let dir = std::env::temp_dir().join(format!("amtl_trace_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("t.jsonl");
        let w = TraceWriter::create(&path).unwrap();
        w.event("commit", Some(2), Some(7), Some(19), &[("staleness", Json::Num(3.0))]);
        w.event("checkpoint", None, None, Some(20), &[]);
        w.flush();
        let text = std::fs::read_to_string(&path).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 2);
        let first = Json::parse(lines[0]).unwrap();
        assert_eq!(first.get("event").and_then(|j| j.as_str()), Some("commit"));
        assert_eq!(first.get("node").and_then(|j| j.as_usize()), Some(2));
        assert_eq!(first.get("k").and_then(|j| j.as_usize()), Some(7));
        assert_eq!(first.get("version").and_then(|j| j.as_usize()), Some(19));
        assert_eq!(first.get("staleness").and_then(|j| j.as_usize()), Some(3));
        let second = Json::parse(lines[1]).unwrap();
        assert_eq!(second.get("event").and_then(|j| j.as_str()), Some("checkpoint"));
        assert!(second.get("node").is_none());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn dropped_writer_leaves_no_buffered_events_behind() {
        // Write a count that is NOT a multiple of the flush stride, so
        // events are still sitting in the buffer when the writer drops;
        // the file must nevertheless parse to the full event count.
        let dir = std::env::temp_dir().join(format!("amtl_trace_drop_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("drop.jsonl");
        let total = FLUSH_EVERY as usize + 7;
        {
            let w = TraceWriter::create(&path).unwrap();
            for i in 0..total {
                w.event("activation", Some(0), Some(i as u64), None, &[]);
            }
            // No explicit flush: Drop must do it.
        }
        let text = std::fs::read_to_string(&path).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), total);
        for line in lines {
            Json::parse(line).unwrap();
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn create_makes_parent_directories() {
        let dir = std::env::temp_dir().join(format!("amtl_trace_mk_{}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        let path = dir.join("nested/run.jsonl");
        let w = TraceWriter::create(&path).unwrap();
        w.event("activation", Some(0), Some(1), None, &[]);
        assert!(path.exists());
        std::fs::remove_dir_all(&dir).ok();
    }
}
