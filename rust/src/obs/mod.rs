//! Unified observability: metrics registry, leveled logging, and
//! per-run JSONL tracing.
//!
//! Three pieces, designed to stay std-only:
//!
//! * [`registry`] — a process-wide [`MetricsRegistry`] of named atomic
//!   counters/gauges and log₂ [`Histogram`]s ([`hist`]), reported into
//!   by every layer (workers, central server, WAL, transport, replica)
//!   and dumped over the wire by the `FetchMetrics → MetricsReport`
//!   frame pair that `amtl top` polls.
//! * [`log`] — a leveled, target-prefixed logger (`--log-level` /
//!   `AMTL_LOG`, default `warn`) behind the crate-level `log_error!` ..
//!   `log_trace!` macros; all diagnostics in `rust/src/` route through
//!   it (CI rejects raw `eprintln!` outside this module).
//! * [`trace`] — an opt-in (`--trace-out <path>`) JSONL event stream:
//!   one line per activation/commit/prox/checkpoint/eviction/span-hop
//!   with node id, activation counter `k`, and server version, for
//!   offline staleness/delay timeline reconstruction.
//! * [`fleet`] — the cross-process layer: commit span ids carried in
//!   `PushUpdate` and emitted as per-hop `span` events, a multi-endpoint
//!   [`fleet::Collector`] with ring-buffer rate history, and declarative
//!   [`fleet::HealthRules`] behind `amtl top --fleet` / `amtl health`.
//!
//! Metric names, units, and the trace schema are tabulated in
//! `docs/OBSERVABILITY.md`.

pub mod fleet;
pub mod hist;
pub mod log;
pub mod registry;
pub mod trace;

pub use fleet::{Collector, HealthRules, Violation};
pub use hist::{HistSnapshot, Histogram};
pub use registry::{global, MetricsRegistry, MetricsSnapshot};
pub use trace::TraceWriter;
