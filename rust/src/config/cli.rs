//! Minimal GNU-style CLI parser: `--key value`, `--key=value`, `--flag`,
//! and positional arguments.

use std::collections::BTreeMap;
use std::fmt;

/// A CLI parsing/validation error (the message is user-facing).
#[derive(Debug, Clone, PartialEq)]
pub struct CliError(pub String);

impl fmt::Display for CliError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for CliError {}

/// Parsed command-line options.
#[derive(Debug, Clone, Default)]
pub struct Opts {
    /// Arguments that are not `--options` (e.g. the subcommand).
    pub positional: Vec<String>,
    options: BTreeMap<String, String>,
    flags: Vec<String>,
    /// Option keys that were actually consumed by the program (for
    /// unknown-option detection).
    known: std::cell::RefCell<Vec<String>>,
}

impl Opts {
    /// Parse a raw argument list (without argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(args: I) -> Result<Opts, CliError> {
        let mut opts = Opts::default();
        let mut iter = args.into_iter().peekable();
        while let Some(arg) = iter.next() {
            if let Some(body) = arg.strip_prefix("--") {
                if body.is_empty() {
                    // "--" terminator: rest is positional.
                    opts.positional.extend(iter);
                    break;
                }
                if let Some((k, v)) = body.split_once('=') {
                    opts.options.insert(k.to_string(), v.to_string());
                } else if iter
                    .peek()
                    .map(|next| !next.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = iter.next().unwrap();
                    opts.options.insert(body.to_string(), v);
                } else {
                    opts.flags.push(body.to_string());
                }
            } else {
                opts.positional.push(arg);
            }
        }
        Ok(opts)
    }

    /// Parse the process arguments (skipping argv[0]).
    pub fn from_env() -> Result<Opts, CliError> {
        Opts::parse(std::env::args().skip(1))
    }

    fn mark(&self, key: &str) {
        self.known.borrow_mut().push(key.to_string());
    }

    /// Raw value of `--key`, if supplied.
    pub fn get(&self, key: &str) -> Option<&str> {
        self.mark(key);
        self.options.get(key).map(|s| s.as_str())
    }

    /// Value of `--key`, or `default`.
    pub fn get_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    /// Integer value of `--key`, or `default`; errors on a non-integer.
    pub fn get_usize(&self, key: &str, default: usize) -> Result<usize, CliError> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| CliError(format!("--{key} expects an integer, got '{v}'"))),
        }
    }

    /// `u64` value of `--key`, or `default`; errors on a non-integer.
    pub fn get_u64(&self, key: &str, default: u64) -> Result<u64, CliError> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| CliError(format!("--{key} expects an integer, got '{v}'"))),
        }
    }

    /// Float value of `--key`, or `default`; errors on a non-number.
    pub fn get_f64(&self, key: &str, default: f64) -> Result<f64, CliError> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| CliError(format!("--{key} expects a number, got '{v}'"))),
        }
    }

    /// True when the bare `--key` flag was supplied.
    pub fn flag(&self, key: &str) -> bool {
        self.mark(key);
        self.flags.iter().any(|f| f == key)
    }

    /// Required option: error (naming the flag) when absent.
    pub fn require(&self, key: &str) -> Result<String, CliError> {
        self.get(key)
            .map(|s| s.to_string())
            .ok_or_else(|| CliError(format!("missing required option --{key}")))
    }

    /// Enumerated option: the value (or `default`) must be one of
    /// `allowed`, otherwise an error naming the alternatives.
    pub fn get_one_of(
        &self,
        key: &str,
        allowed: &[&str],
        default: &str,
    ) -> Result<String, CliError> {
        let v = self.get_or(key, default);
        if allowed.iter().any(|a| *a == v) {
            Ok(v)
        } else {
            Err(CliError(format!(
                "--{key} must be one of {}, got '{v}'",
                allowed.join("|")
            )))
        }
    }

    /// Error if any supplied `--option` was never queried.
    pub fn reject_unknown(&self) -> Result<(), CliError> {
        let known = self.known.borrow();
        for k in self.options.keys() {
            if !known.iter().any(|x| x == k) {
                return Err(CliError(format!("unknown option --{k}")));
            }
        }
        for f in &self.flags {
            if !known.iter().any(|x| x == f) {
                return Err(CliError(format!("unknown flag --{f}")));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(args: &[&str]) -> Opts {
        Opts::parse(args.iter().map(|s| s.to_string())).unwrap()
    }

    #[test]
    fn parses_key_value_both_styles() {
        let o = parse(&["--tasks", "5", "--lambda=0.3"]);
        assert_eq!(o.get("tasks"), Some("5"));
        assert_eq!(o.get("lambda"), Some("0.3"));
    }

    #[test]
    fn parses_flags_and_positional() {
        let o = parse(&["train", "--dynamic-step", "--tasks", "3", "extra"]);
        assert_eq!(o.positional, vec!["train", "extra"]);
        assert!(o.flag("dynamic-step"));
        assert!(!o.flag("online-svd"));
    }

    #[test]
    fn typed_getters_with_defaults() {
        let o = parse(&["--n", "100", "--eta", "0.25"]);
        assert_eq!(o.get_usize("n", 5).unwrap(), 100);
        assert_eq!(o.get_usize("m", 7).unwrap(), 7);
        assert_eq!(o.get_f64("eta", 0.0).unwrap(), 0.25);
        assert!(o.get_usize("eta", 1).is_err());
    }

    #[test]
    fn double_dash_stops_option_parsing() {
        let o = parse(&["--a", "1", "--", "--not-an-option"]);
        assert_eq!(o.get("a"), Some("1"));
        assert_eq!(o.positional, vec!["--not-an-option"]);
    }

    #[test]
    fn reject_unknown_catches_typos() {
        let o = parse(&["--taskz", "5"]);
        let _ = o.get("tasks");
        assert!(o.reject_unknown().is_err());
        let o2 = parse(&["--tasks", "5"]);
        let _ = o2.get("tasks");
        assert!(o2.reject_unknown().is_ok());
    }

    #[test]
    fn get_one_of_validates_against_alternatives() {
        let o = parse(&["--method", "semisync"]);
        let m = o.get_one_of("method", &["amtl", "smtl", "semisync"], "amtl");
        assert_eq!(m.unwrap(), "semisync");
        let o2 = parse(&["--method", "bogus"]);
        let err = o2
            .get_one_of("method", &["amtl", "smtl", "semisync"], "amtl")
            .unwrap_err();
        assert!(err.0.contains("amtl|smtl|semisync"), "{err}");
        let o3 = parse(&[]);
        assert_eq!(o3.get_one_of("method", &["amtl"], "amtl").unwrap(), "amtl");
    }

    #[test]
    fn require_names_the_missing_flag() {
        let o = parse(&["--connect", "127.0.0.1:7171"]);
        assert_eq!(o.require("connect").unwrap(), "127.0.0.1:7171");
        let err = o.require("node").unwrap_err();
        assert!(err.0.contains("--node"), "{err}");
    }

    #[test]
    fn negative_numbers_are_values_not_flags() {
        // "--offset -3" : -3 doesn't start with --, so it's the value.
        let o = parse(&["--offset", "-3"]);
        assert_eq!(o.get_f64("offset", 0.0).unwrap(), -3.0);
    }
}
