//! Configuration: CLI parsing (no `clap` in the offline vendored set) and
//! experiment config assembly.

pub mod cli;

pub use cli::{CliError, Opts};
