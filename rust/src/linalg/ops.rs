//! BLAS-1 style vector kernels used on the coordinator hot path.
//!
//! These run inside the server's update critical section (see
//! `coordinator::state`), so they are written as simple, auto-vectorizable
//! loops with no allocation.

/// `y += a * x`
#[inline]
pub fn axpy(a: f64, x: &[f64], y: &mut [f64]) {
    debug_assert_eq!(x.len(), y.len());
    for (yi, xi) in y.iter_mut().zip(x) {
        *yi += a * xi;
    }
}

/// `x · y`
#[inline]
pub fn dot(x: &[f64], y: &[f64]) -> f64 {
    debug_assert_eq!(x.len(), y.len());
    x.iter().zip(y).map(|(a, b)| a * b).sum()
}

/// Euclidean norm.
#[inline]
pub fn nrm2(x: &[f64]) -> f64 {
    dot(x, x).sqrt()
}

/// `x *= a`
#[inline]
pub fn scal(a: f64, x: &mut [f64]) {
    for xi in x {
        *xi *= a;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn axpy_matches_definition() {
        let x = [1.0, 2.0, 3.0];
        let mut y = [10.0, 20.0, 30.0];
        axpy(2.0, &x, &mut y);
        assert_eq!(y, [12.0, 24.0, 36.0]);
    }

    #[test]
    fn dot_and_nrm2() {
        assert_eq!(dot(&[1.0, 2.0], &[3.0, 4.0]), 11.0);
        assert!((nrm2(&[3.0, 4.0]) - 5.0).abs() < 1e-15);
    }

    #[test]
    fn scal_scales() {
        let mut x = [1.0, -2.0];
        scal(-3.0, &mut x);
        assert_eq!(x, [-3.0, 6.0]);
    }

    #[test]
    fn empty_vectors_are_fine() {
        let mut y: [f64; 0] = [];
        axpy(1.0, &[], &mut y);
        assert_eq!(dot(&[], &[]), 0.0);
        assert_eq!(nrm2(&[]), 0.0);
    }
}
