//! Dense linear-algebra substrate.
//!
//! The model matrix `W ∈ R^{d×T}` is stored **column-major**: one contiguous
//! column per task, because task nodes read/write exactly their own column
//! (`w_t`) on every update, and the server's proximal step consumes whole
//! columns. `f64` is used for all server-side math (prox / SVD); the PJRT
//! boundary converts to `f32` (the artifact dtype).
//!
//! Heavy kernels (matmul, Gram, long axpy) route through [`par`], which
//! blocks the output over a process-wide worker pool — sized by
//! `--threads` / `PALLAS_THREADS` via [`configure_threads`] — and is
//! bitwise identical to the serial loops at any thread count.

mod mat;
mod ops;
pub mod par;

pub use mat::Mat;
pub use ops::{axpy, dot, nrm2, scal};
pub use par::{configure_threads, threads};
