//! Column-major dense matrix.

use crate::util::Rng;

/// Dense `rows × cols` matrix, column-major (`data[c * rows + r]`).
#[derive(Clone, Debug, PartialEq)]
pub struct Mat {
    rows: usize,
    cols: usize,
    data: Vec<f64>,
}

impl Mat {
    /// All-zeros matrix.
    pub fn zeros(rows: usize, cols: usize) -> Mat {
        Mat { rows, cols, data: vec![0.0; rows * cols] }
    }

    /// Assemble from per-column vectors (each of length `rows`).
    pub fn from_cols(rows: usize, cols: Vec<Vec<f64>>) -> Mat {
        let c = cols.len();
        let mut data = Vec::with_capacity(rows * c);
        for col in &cols {
            assert_eq!(col.len(), rows, "column length mismatch");
            data.extend_from_slice(col);
        }
        Mat { rows, cols: c, data }
    }

    /// Build from a closure over (row, col).
    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f64) -> Mat {
        let mut m = Mat::zeros(rows, cols);
        for c in 0..cols {
            for r in 0..rows {
                m.set(r, c, f(r, c));
            }
        }
        m
    }

    /// Standard-normal entries.
    pub fn randn(rows: usize, cols: usize, rng: &mut Rng) -> Mat {
        let data = (0..rows * cols).map(|_| rng.normal()).collect();
        Mat { rows, cols, data }
    }

    /// The `n × n` identity.
    pub fn identity(n: usize) -> Mat {
        Mat::from_fn(n, n, |r, c| if r == c { 1.0 } else { 0.0 })
    }

    #[inline]
    /// Number of rows.
    pub fn rows(&self) -> usize {
        self.rows
    }

    #[inline]
    /// Number of columns.
    pub fn cols(&self) -> usize {
        self.cols
    }

    #[inline]
    /// Read entry `(r, c)`.
    pub fn get(&self, r: usize, c: usize) -> f64 {
        debug_assert!(r < self.rows && c < self.cols);
        self.data[c * self.rows + r]
    }

    #[inline]
    /// Write entry `(r, c)`.
    pub fn set(&mut self, r: usize, c: usize, v: f64) {
        debug_assert!(r < self.rows && c < self.cols);
        self.data[c * self.rows + r] = v;
    }

    /// Contiguous view of column `c` — the per-task model `w_t`.
    #[inline]
    pub fn col(&self, c: usize) -> &[f64] {
        &self.data[c * self.rows..(c + 1) * self.rows]
    }

    #[inline]
    /// Mutable contiguous view of column `c`.
    pub fn col_mut(&mut self, c: usize) -> &mut [f64] {
        &mut self.data[c * self.rows..(c + 1) * self.rows]
    }

    /// Overwrite column `c`.
    pub fn set_col(&mut self, c: usize, v: &[f64]) {
        self.col_mut(c).copy_from_slice(v);
    }

    /// The raw column-major backing slice.
    pub fn data(&self) -> &[f64] {
        &self.data
    }

    /// Mutable raw column-major backing slice.
    pub fn data_mut(&mut self) -> &mut [f64] {
        &mut self.data
    }

    /// `selfᵀ` as a new matrix.
    pub fn transpose(&self) -> Mat {
        Mat::from_fn(self.cols, self.rows, |r, c| self.get(c, r))
    }

    /// `self * other` — blocked over output columns on the global linalg
    /// pool when the shape is large enough (see [`crate::linalg::par`]),
    /// serial column-major triple loop otherwise. Parallel and serial
    /// results are bitwise identical.
    pub fn matmul(&self, other: &Mat) -> Mat {
        crate::linalg::par::matmul(self, other)
    }

    /// The Gram matrix `selfᵀ · self`, through the same parallel kernel
    /// layer as [`Mat::matmul`].
    pub fn gram(&self) -> Mat {
        crate::linalg::par::gram(self)
    }

    /// `self · v` (matrix–vector).
    pub fn matvec(&self, v: &[f64]) -> Vec<f64> {
        assert_eq!(self.cols, v.len());
        let mut out = vec![0.0; self.rows];
        for (k, &vk) in v.iter().enumerate() {
            if vk != 0.0 {
                let col = self.col(k);
                for (o, a) in out.iter_mut().zip(col) {
                    *o += a * vk;
                }
            }
        }
        out
    }

    /// `selfᵀ · v`.
    pub fn tmatvec(&self, v: &[f64]) -> Vec<f64> {
        assert_eq!(self.rows, v.len());
        (0..self.cols).map(|c| crate::linalg::dot(self.col(c), v)).collect()
    }

    /// `‖self‖_F`.
    pub fn frobenius_norm(&self) -> f64 {
        self.data.iter().map(|x| x * x).sum::<f64>().sqrt()
    }

    /// Largest singular value via power iteration on `AᵀA`.
    pub fn spectral_norm(&self, iters: usize, rng: &mut Rng) -> f64 {
        if self.rows == 0 || self.cols == 0 {
            return 0.0;
        }
        let mut v = rng.normal_vec(self.cols);
        let mut sigma = 0.0;
        for _ in 0..iters {
            let av = self.matvec(&v);
            let atav = self.tmatvec(&av);
            let nrm = crate::linalg::nrm2(&atav);
            if nrm == 0.0 {
                return 0.0;
            }
            for (vi, ai) in v.iter_mut().zip(&atav) {
                *vi = ai / nrm;
            }
            sigma = nrm.sqrt();
        }
        sigma
    }

    /// Elementwise `self + a * other` into a new matrix.
    pub fn add_scaled(&self, a: f64, other: &Mat) -> Mat {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        let data = self
            .data
            .iter()
            .zip(&other.data)
            .map(|(x, y)| x + a * y)
            .collect();
        Mat { rows: self.rows, cols: self.cols, data }
    }

    /// `max |self − other|` over entries (shape-checked).
    pub fn max_abs_diff(&self, other: &Mat) -> f64 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        self.data
            .iter()
            .zip(&other.data)
            .map(|(x, y)| (x - y).abs())
            .fold(0.0, f64::max)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn col_major_layout() {
        let m = Mat::from_fn(2, 3, |r, c| (10 * r + c) as f64);
        assert_eq!(m.col(0), &[0.0, 10.0]);
        assert_eq!(m.col(2), &[2.0, 12.0]);
        assert_eq!(m.get(1, 2), 12.0);
    }

    #[test]
    fn matmul_against_hand_computed() {
        let a = Mat::from_cols(2, vec![vec![1.0, 3.0], vec![2.0, 4.0]]); // [[1,2],[3,4]]
        let b = Mat::from_cols(2, vec![vec![5.0, 7.0], vec![6.0, 8.0]]); // [[5,6],[7,8]]
        let c = a.matmul(&b);
        assert_eq!(c.get(0, 0), 19.0);
        assert_eq!(c.get(0, 1), 22.0);
        assert_eq!(c.get(1, 0), 43.0);
        assert_eq!(c.get(1, 1), 50.0);
    }

    #[test]
    fn matvec_and_tmatvec_agree_with_matmul() {
        let mut rng = Rng::new(1);
        let a = Mat::randn(4, 3, &mut rng);
        let v = rng.normal_vec(3);
        let got = a.matvec(&v);
        let vm = Mat::from_cols(3, vec![v.clone()]);
        let want = a.matmul(&vm);
        for r in 0..4 {
            assert!((got[r] - want.get(r, 0)).abs() < 1e-12);
        }
        let u = rng.normal_vec(4);
        let got_t = a.tmatvec(&u);
        let want_t = a.transpose().matvec(&u);
        for c in 0..3 {
            assert!((got_t[c] - want_t[c]).abs() < 1e-12);
        }
    }

    #[test]
    fn transpose_involution() {
        let mut rng = Rng::new(2);
        let a = Mat::randn(5, 3, &mut rng);
        assert_eq!(a.transpose().transpose(), a);
    }

    #[test]
    fn identity_is_matmul_neutral() {
        let mut rng = Rng::new(3);
        let a = Mat::randn(4, 4, &mut rng);
        let i = Mat::identity(4);
        assert!(a.matmul(&i).max_abs_diff(&a) < 1e-15);
        assert!(i.matmul(&a).max_abs_diff(&a) < 1e-15);
    }

    #[test]
    fn spectral_norm_of_diagonal() {
        let mut m = Mat::zeros(3, 3);
        m.set(0, 0, 1.0);
        m.set(1, 1, -7.0);
        m.set(2, 2, 3.0);
        let mut rng = Rng::new(4);
        let s = m.spectral_norm(200, &mut rng);
        assert!((s - 7.0).abs() < 1e-6, "sigma={s}");
    }

    #[test]
    fn frobenius_norm() {
        let m = Mat::from_cols(2, vec![vec![3.0, 0.0], vec![0.0, 4.0]]);
        assert!((m.frobenius_norm() - 5.0).abs() < 1e-15);
    }

    #[test]
    fn add_scaled_matches_definition() {
        let a = Mat::from_cols(2, vec![vec![1.0, 2.0]]);
        let b = Mat::from_cols(2, vec![vec![10.0, 20.0]]);
        let c = a.add_scaled(0.5, &b);
        assert_eq!(c.col(0), &[6.0, 12.0]);
    }
}
