//! Thread-pool-backed linear-algebra kernels.
//!
//! The server's backward step is dense linalg over the `d × T` model
//! matrix — SVT reconstruction matmuls in the prox, online-SVD basis
//! rotations per commit, and the `XᵀX` Gram products behind the Lipschitz
//! estimates; with task nodes committing asynchronously, a
//! single-threaded server becomes the bottleneck exactly where the paper
//! promises scaling. The kernels here block their output into per-column
//! chunks and fan the chunks out over a process-wide [`WorkerPool`] (the
//! generic CPU pool in `runtime::pool`, shared with the PJRT executor
//! plumbing — no new dependencies).
//!
//! **Determinism:** every parallel kernel partitions the *output* and
//! computes each element with exactly the serial loop structure and
//! summation order, so parallel results are **bitwise identical** to the
//! serial fallback (property-tested in `rust/tests/properties.rs`). Thread
//! count changes wall-clock, never bits.
//!
//! **Thread-count resolution** (first use wins, then frozen for the
//! process):
//!
//! 1. [`configure_threads`] — explicit, e.g. from the CLI `--threads` flag;
//! 2. the `PALLAS_THREADS` environment variable;
//! 3. `std::thread::available_parallelism()`.
//!
//! A resolved count of 1 (or a small problem — see `PAR_MIN_WORK`) skips
//! the pool entirely and runs the serial loop in place.

use crate::linalg::Mat;
use crate::linalg::ops::{axpy, dot};
use crate::runtime::pool::WorkerPool;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::OnceLock;

/// Below this many flop-equivalents a kernel runs serially: chunk setup +
/// latch wake-ups cost more than the arithmetic they would spread out.
const PAR_MIN_WORK: usize = 32 * 1024;

/// Thread count requested via [`configure_threads`] (0 = unset).
static CONFIGURED: AtomicUsize = AtomicUsize::new(0);

/// The lazily-built process-wide pool; `None` when the resolved thread
/// count is 1.
static POOL: OnceLock<Option<WorkerPool>> = OnceLock::new();

/// Request `threads` workers for the global linalg pool (0 = keep the
/// `PALLAS_THREADS` / auto default). Returns `false` if the pool was
/// already built — the count is frozen at first use, so call this before
/// any parallel kernel runs (the `amtl` CLI does it while parsing flags).
pub fn configure_threads(threads: usize) -> bool {
    CONFIGURED.store(threads, Ordering::Relaxed);
    POOL.get().is_none()
}

/// The thread count the global pool uses (resolves and freezes it if this
/// is the first linalg-pool touch). 1 means all kernels run serially.
pub fn threads() -> usize {
    match pool() {
        Some(p) => p.threads(),
        None => 1,
    }
}

fn resolve_threads() -> usize {
    let configured = CONFIGURED.load(Ordering::Relaxed);
    if configured > 0 {
        return configured;
    }
    if let Ok(v) = std::env::var("PALLAS_THREADS") {
        if let Ok(n) = v.trim().parse::<usize>() {
            if n > 0 {
                return n;
            }
        }
    }
    std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1)
}

fn pool() -> Option<&'static WorkerPool> {
    POOL.get_or_init(|| {
        let n = resolve_threads();
        if n <= 1 {
            None
        } else {
            Some(WorkerPool::new(n))
        }
    })
    .as_ref()
}

/// The pool, gated on problem size: `None` (serial path) when the work is
/// too small to amortize fan-out or the process is single-threaded.
fn pool_for(work: usize) -> Option<&'static WorkerPool> {
    if work < PAR_MIN_WORK {
        return None;
    }
    pool()
}

// ---------------------------------------------------------------- matmul

/// `a · b`, parallelized over output-column chunks on the global pool
/// (serial for small shapes). Bitwise identical to [`matmul_serial`].
pub fn matmul(a: &Mat, b: &Mat) -> Mat {
    let work = a.rows().saturating_mul(a.cols()).saturating_mul(b.cols());
    matmul_on(pool_for(work), a, b)
}

/// `a · b` with an explicit pool choice (`None` = serial). Exposed so
/// tests and benches can pin the execution mode regardless of machine
/// shape or global configuration.
pub fn matmul_on(pool: Option<&WorkerPool>, a: &Mat, b: &Mat) -> Mat {
    assert_eq!(a.cols(), b.rows(), "matmul shape mismatch");
    let m = a.rows();
    let n = b.cols();
    let mut out = Mat::zeros(m, n);
    match pool {
        Some(pool) if m > 0 && n > 1 => {
            let cols_per_job = n.div_ceil(pool.threads());
            let jobs: Vec<Box<dyn FnOnce() + Send + '_>> = out
                .data_mut()
                .chunks_mut(m * cols_per_job)
                .enumerate()
                .map(|(i, chunk)| {
                    let j0 = i * cols_per_job;
                    Box::new(move || matmul_cols_into(a, b, j0, chunk))
                        as Box<dyn FnOnce() + Send + '_>
                })
                .collect();
            pool.scope(jobs);
        }
        _ => matmul_cols_into(a, b, 0, out.data_mut()),
    }
    out
}

/// Serial reference matmul (the seed's triple loop, column-major order).
pub fn matmul_serial(a: &Mat, b: &Mat) -> Mat {
    matmul_on(None, a, b)
}

/// Compute output columns `j0..` of `a · b` into `out` (a column-major
/// span of whole columns). This is the one inner loop both the serial and
/// every parallel chunk run, so their results cannot differ by a bit.
fn matmul_cols_into(a: &Mat, b: &Mat, j0: usize, out: &mut [f64]) {
    let m = a.rows();
    if m == 0 {
        return;
    }
    for (jj, out_col) in out.chunks_mut(m).enumerate() {
        let j = j0 + jj;
        for k in 0..a.cols() {
            let bkj = b.get(k, j);
            if bkj != 0.0 {
                axpy(bkj, a.col(k), out_col);
            }
        }
    }
}

// ------------------------------------------------------------------ gram

/// The Gram matrix `aᵀ · a` (`cols × cols`), parallelized over output
/// columns. Bitwise identical to [`gram_serial`].
pub fn gram(a: &Mat) -> Mat {
    let work = a.rows().saturating_mul(a.cols()).saturating_mul(a.cols());
    gram_on(pool_for(work), a)
}

/// `aᵀ · a` with an explicit pool choice (`None` = serial).
///
/// The Gram matrix is symmetric, so each unordered column pair's dot
/// product is computed **once** into a packed upper triangle (the
/// triangle's per-column spans are contiguous, giving the pool disjoint
/// `&mut` chunks) and then mirrored — half the flops of filling the full
/// matrix, with the mirrored entry bitwise equal to an independently
/// computed one (`dot` is elementwise-commutative in its arguments).
pub fn gram_on(pool: Option<&WorkerPool>, a: &Mat) -> Mat {
    let n = a.cols();
    // Packed upper triangle: column j's entries (i ≤ j) live at
    // `tri[j(j+1)/2 .. j(j+1)/2 + j + 1]`.
    let mut tri = vec![0.0f64; n * (n + 1) / 2];
    match pool {
        Some(pool) if n > 1 => {
            // Equal column counts per job (later chunks carry longer
            // triangle columns; fine for the shapes we run).
            let cols_per_job = n.div_ceil(pool.threads());
            let mut jobs: Vec<Box<dyn FnOnce() + Send + '_>> = Vec::new();
            let mut rest: &mut [f64] = &mut tri;
            let mut j0 = 0;
            while j0 < n {
                let j1 = (j0 + cols_per_job).min(n);
                let len = tri_offset(j1) - tri_offset(j0);
                let (chunk, tail) = std::mem::take(&mut rest).split_at_mut(len);
                rest = tail;
                jobs.push(Box::new(move || gram_tri_into(a, j0, j1, chunk)));
                j0 = j1;
            }
            pool.scope(jobs);
        }
        _ => gram_tri_into(a, 0, n, &mut tri),
    }
    let mut out = Mat::zeros(n, n);
    for j in 0..n {
        let base = tri_offset(j);
        for i in 0..=j {
            let v = tri[base + i];
            out.set(i, j, v);
            out.set(j, i, v);
        }
    }
    out
}

/// Serial reference Gram product.
pub fn gram_serial(a: &Mat) -> Mat {
    gram_on(None, a)
}

/// Start of column `j`'s span in the packed upper triangle.
fn tri_offset(j: usize) -> usize {
    j * (j + 1) / 2
}

/// Fill the packed upper-triangle entries of columns `j0..j1` into `tri`
/// (whose length is exactly those columns' spans).
fn gram_tri_into(a: &Mat, j0: usize, j1: usize, tri: &mut [f64]) {
    let mut pos = 0;
    for j in j0..j1 {
        let aj = a.col(j);
        for i in 0..=j {
            tri[pos] = dot(a.col(i), aj);
            pos += 1;
        }
    }
}

// ------------------------------------------------------------------ axpy

/// `y += alpha * x` over long spans, chunked across the pool. Bitwise
/// identical to the serial [`axpy`] (each element touches exactly one
/// fused multiply-add either way). Small spans run serially in place.
pub fn axpy_par(alpha: f64, x: &[f64], y: &mut [f64]) {
    assert_eq!(x.len(), y.len());
    let pool = match pool_for(y.len()) {
        Some(p) => p,
        None => return axpy(alpha, x, y),
    };
    let chunk = y.len().div_ceil(pool.threads()).max(1);
    let jobs: Vec<Box<dyn FnOnce() + Send + '_>> = y
        .chunks_mut(chunk)
        .zip(x.chunks(chunk))
        .map(|(yc, xc)| {
            Box::new(move || axpy(alpha, xc, yc)) as Box<dyn FnOnce() + Send + '_>
        })
        .collect();
    pool.scope(jobs);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn parallel_matmul_is_bitwise_serial() {
        let pool = WorkerPool::new(3);
        let mut rng = Rng::new(600);
        for (m, k, n) in [(7, 5, 9), (16, 16, 16), (1, 4, 6), (5, 1, 3), (33, 20, 2)] {
            let a = Mat::randn(m, k, &mut rng);
            let b = Mat::randn(k, n, &mut rng);
            let serial = matmul_serial(&a, &b);
            let par = matmul_on(Some(&pool), &a, &b);
            assert_eq!(serial, par, "shape {m}x{k}x{n}");
        }
    }

    #[test]
    fn parallel_gram_is_bitwise_serial_and_symmetric() {
        let pool = WorkerPool::new(4);
        let mut rng = Rng::new(601);
        let a = Mat::randn(23, 11, &mut rng);
        let serial = gram_serial(&a);
        let par = gram_on(Some(&pool), &a);
        assert_eq!(serial, par);
        for i in 0..11 {
            for j in 0..11 {
                assert_eq!(serial.get(i, j), serial.get(j, i), "gram symmetry");
            }
        }
    }

    #[test]
    fn gram_matches_explicit_transpose_matmul() {
        let mut rng = Rng::new(602);
        let a = Mat::randn(14, 6, &mut rng);
        let want = a.transpose().matmul(&a);
        let got = gram_serial(&a);
        assert!(got.max_abs_diff(&want) < 1e-12);
    }

    #[test]
    fn axpy_par_matches_serial_on_long_spans() {
        let mut rng = Rng::new(603);
        let x = rng.normal_vec(100_000);
        let mut y1 = rng.normal_vec(100_000);
        let mut y2 = y1.clone();
        axpy(0.37, &x, &mut y1);
        axpy_par(0.37, &x, &mut y2);
        assert_eq!(y1, y2, "parallel axpy must be bitwise serial");
    }

    #[test]
    fn empty_and_degenerate_shapes() {
        let pool = WorkerPool::new(2);
        let a = Mat::zeros(0, 3);
        let b = Mat::zeros(3, 4);
        assert_eq!(matmul_on(Some(&pool), &a, &b).rows(), 0);
        let g = gram_on(Some(&pool), &Mat::zeros(5, 0));
        assert_eq!((g.rows(), g.cols()), (0, 0));
        let mut y: [f64; 0] = [];
        axpy_par(1.0, &[], &mut y);
    }

    #[test]
    fn global_threads_resolves_to_at_least_one() {
        assert!(threads() >= 1);
    }
}
