//! Singular value decomposition, from scratch.
//!
//! Two implementations, mirroring §IV.A of the paper:
//!
//! * [`Svd::jacobi`] — one-sided Jacobi: numerically robust, exact to
//!   machine precision, O(sweeps · m · n²). The reference backward step
//!   (`--svd exact`) and the periodic-refresh anchor of the online path.
//! * [`OnlineSvd`] — Brand-style rank-1 column update ("online SVD" in the
//!   paper, §IV.A): after a task node replaces one column of `W`, the
//!   factorization is updated in O((d + T) k + k³) instead of recomputed,
//!   where `k` is the retained rank. This is the **default** nuclear-prox
//!   path (`--svd online`), re-anchored to an exact Jacobi factorization
//!   every `--resvd-every` commits (see [`SvdMode`] and
//!   [`NuclearProx`](crate::optim::prox::NuclearProx)).

use crate::linalg::{dot, nrm2, Mat};
use crate::util::EnumTable;

/// Name table for [`SvdMode`].
const SVD_MODES: EnumTable<SvdMode> = EnumTable {
    what: "--svd value",
    rows: &[
        ("exact", &["jacobi"], SvdMode::Exact),
        ("online", &["brand"], SvdMode::Online),
    ],
};

/// Which backend drives a formulation's *incremental* path — for the
/// nuclear-norm prox (Eq. IV.2), which SVD it runs on.
///
/// [`SvdMode::Online`] is the default: `build_server` calls the
/// formulation's `enable_incremental` hook, so the nuclear prox maintains
/// a Brand rank-1-update factorization across commits (refreshed exactly
/// every `resvd_every` commits to bound drift, see
/// [`NuclearProx`](crate::optim::prox::NuclearProx)) and the mean
/// formulation maintains its running centroid. [`SvdMode::Exact`] skips
/// the hook: every uncached prox recomputes from a matrix snapshot — the
/// pre-incremental behavior, kept as the reference.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum SvdMode {
    /// Exact recompute from a snapshot on every uncached prox.
    Exact,
    /// Incremental updates with periodic exact refresh.
    #[default]
    Online,
}

impl SvdMode {
    /// Parse a CLI value (`"exact"` | `"online"`); the error lists the
    /// valid values.
    pub fn parse(s: &str) -> anyhow::Result<SvdMode> {
        SVD_MODES.parse(s)
    }

    /// Canonical CLI name.
    pub fn name(&self) -> &'static str {
        SVD_MODES.name(*self)
    }
}

/// Thin SVD `A = U Σ Vᵀ` with `U: m×k`, `Σ: k`, `V: n×k`, `k = min(m,n)`.
#[derive(Clone, Debug)]
pub struct Svd {
    /// Left singular vectors (`m × k`, orthonormal columns).
    pub u: Mat,
    /// Singular values, sorted descending.
    pub sigma: Vec<f64>,
    /// Right singular vectors (`n × k`, orthonormal columns).
    pub v: Mat,
}

impl Svd {
    /// One-sided Jacobi SVD.
    ///
    /// Orthogonalizes pairs of columns of a working copy of `A` with Givens
    /// rotations, accumulating them into `V`; on convergence the column
    /// norms are the singular values and the normalized columns are `U`.
    /// For `m < n` the transpose is factored and the roles of `U`/`V` swap.
    pub fn jacobi(a: &Mat) -> Svd {
        if a.rows() < a.cols() {
            let t = Self::jacobi(&a.transpose());
            return Svd { u: t.v, sigma: t.sigma, v: t.u };
        }
        let m = a.rows();
        let n = a.cols();
        let mut w = a.clone(); // working copy; columns get orthogonalized
        let mut v = Mat::identity(n);

        // Convergence: all |aᵢ·aⱼ| below eps * ‖aᵢ‖‖aⱼ‖.
        let eps = 1e-14;
        let max_sweeps = 60;
        for _ in 0..max_sweeps {
            let mut off = 0.0f64;
            for i in 0..n {
                for j in (i + 1)..n {
                    // 2x2 Gram block of columns i, j.
                    let (ci, cj) = (w.col(i), w.col(j));
                    let alpha = dot(ci, ci);
                    let beta = dot(cj, cj);
                    let gamma = dot(ci, cj);
                    if alpha == 0.0 || beta == 0.0 {
                        continue;
                    }
                    let denom = (alpha * beta).sqrt();
                    off = off.max((gamma / denom).abs());
                    if gamma.abs() <= eps * denom {
                        continue;
                    }
                    // Jacobi rotation that annihilates the off-diagonal.
                    let zeta = (beta - alpha) / (2.0 * gamma);
                    let t = zeta.signum() / (zeta.abs() + (1.0 + zeta * zeta).sqrt());
                    let c = 1.0 / (1.0 + t * t).sqrt();
                    let s = c * t;
                    rotate_cols(&mut w, i, j, c, s);
                    rotate_cols(&mut v, i, j, c, s);
                }
            }
            if off <= eps {
                break;
            }
        }

        // Extract Σ and U; sort descending.
        let mut order: Vec<usize> = (0..n).collect();
        let norms: Vec<f64> = (0..n).map(|c| nrm2(w.col(c))).collect();
        order.sort_by(|&i, &j| norms[j].partial_cmp(&norms[i]).unwrap());

        let mut u = Mat::zeros(m, n);
        let mut sigma = vec![0.0; n];
        let mut vs = Mat::zeros(n, n);
        for (k, &c) in order.iter().enumerate() {
            sigma[k] = norms[c];
            if norms[c] > 0.0 {
                let src = w.col(c).to_vec();
                for (r, x) in src.iter().enumerate() {
                    u.set(r, k, x / norms[c]);
                }
            }
            vs.set_col(k, v.col(c));
        }
        Svd { u, sigma, v: vs }
    }

    /// Reconstruct `U Σ Vᵀ`.
    pub fn reconstruct(&self) -> Mat {
        let k = self.sigma.len();
        let mut us = self.u.clone();
        for i in 0..k {
            for r in 0..us.rows() {
                us.set(r, i, us.get(r, i) * self.sigma[i]);
            }
        }
        us.matmul(&self.v.transpose())
    }

    /// Apply soft-thresholding to the spectrum and reconstruct:
    /// `U (Σ − τ)₊ Vᵀ` — the SVT backward step of Eq. IV.2.
    pub fn shrink_reconstruct(&self, tau: f64) -> Mat {
        let k = self.sigma.len();
        let mut us = self.u.clone();
        for i in 0..k {
            let s = (self.sigma[i] - tau).max(0.0);
            for r in 0..us.rows() {
                us.set(r, i, us.get(r, i) * s);
            }
        }
        us.matmul(&self.v.transpose())
    }

    /// `‖A‖_* = Σ σᵢ`.
    pub fn nuclear_norm(&self) -> f64 {
        self.sigma.iter().sum()
    }
}

fn rotate_cols(m: &mut Mat, i: usize, j: usize, c: f64, s: f64) {
    let rows = m.rows();
    for r in 0..rows {
        let a = m.get(r, i);
        let b = m.get(r, j);
        m.set(r, i, c * a - s * b);
        m.set(r, j, s * a + c * b);
    }
}

/// Incremental thin SVD with rank-1 **column replacement** updates
/// (M. Brand, "Fast online SVD revisions", SDM 2003), as discussed for the
/// high-`T` regime in §IV.A of the paper.
///
/// Maintains `A ≈ U diag(σ) Vᵀ`. Replacing column `j` with `a'` is the
/// rank-1 update `A + (a' − a_j) e_jᵀ`, which reduces to re-diagonalizing a
/// `(k+1) × (k+1)` core matrix — done here with the Jacobi SVD above.
#[derive(Clone, Debug)]
pub struct OnlineSvd {
    /// Left factor (`m × k`).
    pub u: Mat,
    /// Retained singular values (`k`).
    pub sigma: Vec<f64>,
    /// Right factor (`n × k`).
    pub v: Mat,
}

impl OnlineSvd {
    /// Initialize from a full Jacobi factorization.
    pub fn init(a: &Mat) -> OnlineSvd {
        let s = Svd::jacobi(a);
        OnlineSvd { u: s.u, sigma: s.sigma, v: s.v }
    }

    /// Currently retained rank `k`.
    pub fn rank(&self) -> usize {
        self.sigma.len()
    }

    /// Replace column `j` of the implicitly-represented matrix with `new_col`.
    pub fn replace_column(&mut self, j: usize, new_col: &[f64]) {
        let m = self.u.rows();
        let n = self.v.rows();
        let k = self.sigma.len();
        assert_eq!(new_col.len(), m);
        assert!(j < n);

        // Current column j: a_j = U diag(σ) (Vᵀ e_j).
        let vrow: Vec<f64> = (0..k).map(|i| self.v.get(j, i)).collect();
        let mut a_j = vec![0.0; m];
        for i in 0..k {
            let s = self.sigma[i] * vrow[i];
            if s != 0.0 {
                crate::linalg::axpy(s, self.u.col(i), &mut a_j);
            }
        }
        // Rank-1 update vectors: A' = A + c e_jᵀ with c = new_col − a_j.
        let c: Vec<f64> = new_col.iter().zip(&a_j).map(|(x, y)| x - y).collect();

        // Project c on span(U): c = U p + r, r ⟂ U.
        let p: Vec<f64> = (0..k).map(|i| dot(self.u.col(i), &c)).collect();
        let mut r = c.clone();
        for i in 0..k {
            crate::linalg::axpy(-p[i], self.u.col(i), &mut r);
        }
        let r_norm = nrm2(&r);

        // e_j is trivially in span basis extension for V: e_j = V q + s h,
        // with q = Vᵀ e_j (= vrow), h unit ⟂ V.
        let q = vrow.clone();
        let mut h = vec![0.0; n];
        h[j] = 1.0;
        for i in 0..k {
            crate::linalg::axpy(-q[i], self.v.col(i), &mut h);
        }
        let h_norm = nrm2(&h);

        // Core matrix K = [diag(σ) 0; 0 0] + [p; r_norm] [q; h_norm]ᵀ of
        // size (k+1)², then its small SVD.
        let kk = k + 1;
        let mut core = Mat::zeros(kk, kk);
        for i in 0..k {
            core.set(i, i, self.sigma[i]);
        }
        let pe: Vec<f64> = p.iter().copied().chain([r_norm]).collect();
        let qe: Vec<f64> = q.iter().copied().chain([h_norm]).collect();
        for a in 0..kk {
            for b in 0..kk {
                core.set(a, b, core.get(a, b) + pe[a] * qe[b]);
            }
        }
        let cs = Svd::jacobi(&core);

        // Extended bases.
        let r_unit: Vec<f64> = if r_norm > 1e-300 {
            r.iter().map(|x| x / r_norm).collect()
        } else {
            vec![0.0; m]
        };
        let h_unit: Vec<f64> = if h_norm > 1e-300 {
            h.iter().map(|x| x / h_norm).collect()
        } else {
            vec![0.0; n]
        };

        // U' = [U r̂] · Uc,  V' = [V ĥ] · Vc; keep the top-k' = min(m, n, kk)
        // columns (drop the trailing one if it carries ~zero energy). The
        // extended bases are materialized so the rotations run through the
        // blocked (pool-parallel) matmul kernel — this is the per-commit
        // hot loop of the incremental prox.
        let keep = kk.min(m).min(n);
        let mut ext_u = Mat::zeros(m, kk);
        for i in 0..k {
            ext_u.set_col(i, self.u.col(i));
        }
        ext_u.set_col(k, &r_unit);
        let mut ext_v = Mat::zeros(n, kk);
        for i in 0..k {
            ext_v.set_col(i, self.v.col(i));
        }
        ext_v.set_col(k, &h_unit);
        let mut rot_u = Mat::zeros(kk, keep);
        let mut rot_v = Mat::zeros(kk, keep);
        let mut new_sigma = vec![0.0; keep];
        for col in 0..keep {
            new_sigma[col] = cs.sigma[col];
            rot_u.set_col(col, &cs.u.col(col)[..kk]);
            rot_v.set_col(col, &cs.v.col(col)[..kk]);
        }
        let mut new_u = ext_u.matmul(&rot_u);
        let mut new_v = ext_v.matmul(&rot_v);
        // Truncate numerically-dead trailing rank to keep k bounded by n.
        let tol = new_sigma.first().copied().unwrap_or(0.0) * 1e-13;
        let mut kept = new_sigma.iter().take_while(|s| **s > tol).count().max(1);
        kept = kept.min(keep);
        if kept < keep {
            let mut tu = Mat::zeros(m, kept);
            let mut tv = Mat::zeros(n, kept);
            for c2 in 0..kept {
                tu.set_col(c2, new_u.col(c2));
                tv.set_col(c2, new_v.col(c2));
            }
            new_u = tu;
            new_v = tv;
            new_sigma.truncate(kept);
        }
        self.u = new_u;
        self.v = new_v;
        self.sigma = new_sigma;
    }

    /// Materialize `U Σ Vᵀ` (the tracked matrix approximation).
    pub fn reconstruct(&self) -> Mat {
        Svd { u: self.u.clone(), sigma: self.sigma.clone(), v: self.v.clone() }.reconstruct()
    }

    /// SVT through the incremental factorization: `U (Σ − τ)₊ Vᵀ`.
    pub fn shrink_reconstruct(&self, tau: f64) -> Mat {
        Svd { u: self.u.clone(), sigma: self.sigma.clone(), v: self.v.clone() }
            .shrink_reconstruct(tau)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn assert_mat_close(a: &Mat, b: &Mat, tol: f64) {
        let d = a.max_abs_diff(b);
        assert!(d < tol, "max diff {d} > {tol}");
    }

    fn check_orthonormal_cols(m: &Mat, tol: f64) {
        for i in 0..m.cols() {
            for j in i..m.cols() {
                let d = dot(m.col(i), m.col(j));
                let want = if i == j { 1.0 } else { 0.0 };
                assert!((d - want).abs() < tol, "col {i}·{j} = {d}");
            }
        }
    }

    #[test]
    fn svd_reconstructs_random_tall() {
        let mut rng = Rng::new(10);
        let a = Mat::randn(20, 6, &mut rng);
        let s = Svd::jacobi(&a);
        assert_mat_close(&s.reconstruct(), &a, 1e-10);
        check_orthonormal_cols(&s.u, 1e-10);
        check_orthonormal_cols(&s.v, 1e-10);
    }

    #[test]
    fn svd_reconstructs_random_wide() {
        let mut rng = Rng::new(11);
        let a = Mat::randn(5, 17, &mut rng);
        let s = Svd::jacobi(&a);
        assert_eq!(s.sigma.len(), 5);
        assert_mat_close(&s.reconstruct(), &a, 1e-10);
    }

    #[test]
    fn singular_values_sorted_nonnegative() {
        let mut rng = Rng::new(12);
        let a = Mat::randn(12, 8, &mut rng);
        let s = Svd::jacobi(&a);
        for w in s.sigma.windows(2) {
            assert!(w[0] >= w[1]);
        }
        assert!(s.sigma.iter().all(|&x| x >= 0.0));
    }

    #[test]
    fn svd_of_diagonal_is_exact() {
        let mut a = Mat::zeros(4, 4);
        for (i, &v) in [4.0, 1.0, 3.0, 2.0].iter().enumerate() {
            a.set(i, i, v);
        }
        let s = Svd::jacobi(&a);
        let want = [4.0, 3.0, 2.0, 1.0];
        for (got, want) in s.sigma.iter().zip(want) {
            assert!((got - want).abs() < 1e-12);
        }
    }

    #[test]
    fn svd_of_rank_deficient() {
        let mut rng = Rng::new(13);
        let b = Mat::randn(10, 2, &mut rng);
        let c = Mat::randn(2, 7, &mut rng);
        let a = b.matmul(&c); // rank 2
        let s = Svd::jacobi(&a);
        assert!(s.sigma[2] < 1e-10 * s.sigma[0]);
        assert_mat_close(&s.reconstruct(), &a, 1e-9);
    }

    #[test]
    fn svd_of_zero_matrix() {
        let a = Mat::zeros(5, 3);
        let s = Svd::jacobi(&a);
        assert!(s.sigma.iter().all(|&x| x == 0.0));
        assert_mat_close(&s.reconstruct(), &a, 1e-15);
    }

    #[test]
    fn spectral_norm_matches_svd() {
        let mut rng = Rng::new(14);
        let a = Mat::randn(30, 9, &mut rng);
        let s = Svd::jacobi(&a);
        let p = a.spectral_norm(300, &mut rng);
        assert!((s.sigma[0] - p).abs() / s.sigma[0] < 1e-4);
    }

    #[test]
    fn shrink_reconstruct_thresholds_spectrum() {
        let mut rng = Rng::new(15);
        let a = Mat::randn(10, 5, &mut rng);
        let s = Svd::jacobi(&a);
        let tau = s.sigma[2]; // kill the bottom three
        let out = s.shrink_reconstruct(tau);
        let s2 = Svd::jacobi(&out);
        for (i, sig) in s2.sigma.iter().enumerate() {
            let want = (s.sigma[i] - tau).max(0.0);
            assert!((sig - want).abs() < 1e-9, "σ{i}: {sig} vs {want}");
        }
    }

    #[test]
    fn online_svd_matches_full_after_column_replacement() {
        let mut rng = Rng::new(16);
        let mut a = Mat::randn(15, 6, &mut rng);
        let mut osvd = OnlineSvd::init(&a);
        for step in 0..10 {
            let j = step % 6;
            let col = rng.normal_vec(15);
            a.set_col(j, &col);
            osvd.replace_column(j, &col);
            assert_mat_close(&osvd.reconstruct(), &a, 1e-8);
        }
    }

    #[test]
    fn online_svd_singular_values_track_full() {
        let mut rng = Rng::new(17);
        let mut a = Mat::randn(12, 4, &mut rng);
        let mut osvd = OnlineSvd::init(&a);
        for j in 0..4 {
            let col = rng.normal_vec(12);
            a.set_col(j, &col);
            osvd.replace_column(j, &col);
        }
        let full = Svd::jacobi(&a);
        for (i, (o, f)) in osvd.sigma.iter().zip(&full.sigma).enumerate() {
            assert!((o - f).abs() < 1e-8, "σ{i}: {o} vs {f}");
        }
    }

    #[test]
    fn online_svd_rank_stays_bounded() {
        let mut rng = Rng::new(18);
        let a = Mat::randn(10, 3, &mut rng);
        let mut osvd = OnlineSvd::init(&a);
        for step in 0..30 {
            let col = rng.normal_vec(10);
            osvd.replace_column(step % 3, &col);
        }
        assert!(osvd.rank() <= 3, "rank grew to {}", osvd.rank());
    }
}
