//! Optimization substrate: SVD, proximal operators, losses, Lipschitz
//! estimation, and the centralized FISTA baseline.
//!
//! The nuclear-norm backward step (singular-value thresholding, Eq. IV.2 of
//! the paper) runs natively here: `jnp.linalg.svd` lowers to a typed-FFI
//! LAPACK custom-call that the CPU PJRT plugin of xla_extension 0.5.1
//! cannot execute (verified empirically), and architecturally the
//! prox is the *central server's* job, which is rust.

pub mod fista;
pub mod lipschitz;
pub mod losses;
pub mod prox;
pub mod svd;

pub use prox::{Regularizer, RegularizerKind};
pub use svd::{OnlineSvd, Svd, SvdMode};
