//! Optimization substrate: SVD, the open formulation API (trait-based
//! losses + proximable regularizers), Lipschitz estimation, and the
//! centralized FISTA baseline.
//!
//! The formulation layer is an **open world** (see [`formulation`]): a
//! [`SharedProx`] coupling regularizer and a [`TaskLoss`] smooth loss are
//! traits, the concrete formulations — nuclear, ℓ2,1, ℓ1, elastic net,
//! none ([`prox`]), graph-Laplacian relationship coupling and
//! mean-regularized clustering ([`coupling`]) — are registered impls, and
//! a [`FormulationSpec`] resolves them by name + params for the CLI and
//! the session builder.
//!
//! The nuclear-norm backward step (singular-value thresholding, Eq. IV.2 of
//! the paper) runs natively here: `jnp.linalg.svd` lowers to a typed-FFI
//! LAPACK custom-call that the CPU PJRT plugin of xla_extension 0.5.1
//! cannot execute (verified empirically), and architecturally the
//! prox is the *central server's* job, which is rust.

pub mod coupling;
pub mod fista;
pub mod formulation;
pub mod lipschitz;
pub mod losses;
pub mod prox;
pub mod svd;

pub use coupling::{GraphProx, MeanProx, TaskGraph};
pub use formulation::{FormulationSpec, SharedProx, TaskLoss};
pub use prox::{Regularizer, RegularizerKind};
pub use svd::{OnlineSvd, Svd, SvdMode};
