//! The open formulation API: trait-based losses and proximable
//! regularizers behind a name-keyed registry.
//!
//! The paper's claim is that "many regularized MTL formulations can
//! benefit from this framework" — so the math layer must be an *open*
//! world. Two traits define the seams:
//!
//! * [`SharedProx`] — the coupling regularizer `λ·g(W)` the **central
//!   server** owns: its prox, its value, optional *incremental* hooks
//!   (column-update notifications, a snapshot-free `online_prox`, a
//!   periodic exact `refresh` that bounds drift) and *state* hooks
//!   (`state_save`/`state_load`) so persist snapshots stay generic.
//! * [`TaskLoss`] — the smooth per-task loss a **task node** owns:
//!   gradient + objective, the fused forward step, its Lipschitz
//!   constant, and the AOT artifact op that implements it.
//!
//! Concrete formulations live in [`prox`](crate::optim::prox) (the
//! classics: nuclear, ℓ2,1, ℓ1, elastic net, none) and
//! [`coupling`](crate::optim::coupling) (graph-Laplacian relationship
//! coupling and mean-regularized clustering); losses in
//! [`losses`](crate::optim::losses). The [`FormulationSpec`] /
//! [`resolve`] pair is how the CLI (`--reg graph:weight=0.5`) and
//! `SessionBuilder` reach them by name + params, and [`restore`] is how a
//! persist snapshot rebuilds one from its saved id + state blob.
//!
//! ## Adding a formulation
//!
//! 1. Implement [`SharedProx`] (only `id`, `lambda`, `prox`, `value`,
//!    `clone_box`, `state_save`, `state_load` are mandatory; the
//!    incremental hooks default to "not incremental").
//! 2. Register it: a row in [`FORMULATIONS`], an arm in [`resolve`] and
//!    one in [`restore`].
//! 3. The CLI flag, the persist layer, every
//!    [`Schedule`](crate::coordinator::Schedule)
//!    (Async/Synchronized/SemiSync) and the prox proptests in
//!    `rust/tests/properties.rs` pick it up from the registry — no
//!    coordinator changes.

use crate::linalg::Mat;
use crate::optim::coupling::{GraphProx, MeanProx, TaskGraph};
use crate::optim::losses::RowMat;
use crate::optim::prox::{
    ElasticNetProx, L1Prox, L21Prox, NuclearProx, RegularizerKind, ZeroProx,
};
use crate::transport::wire::{Cursor, WireError};
use crate::util::Rng;
use anyhow::Result;

// ------------------------------------------------------------- SharedProx

/// A coupling regularizer `λ·g(W)` as the central server consumes it: the
/// proximal operator, the value for objective reporting, optional
/// incremental hooks, and opaque persist state.
///
/// The incremental contract mirrors the server's hot path: the server
/// stages committed columns, calls [`SharedProx::notify_column_update`]
/// for each distinct column at prox time (coalescing adjacent commits),
/// advances the raw-commit counter via [`SharedProx::note_commits`], runs
/// an exact [`SharedProx::refresh`] when [`SharedProx::needs_refresh`]
/// says the drift stride is due, and asks [`SharedProx::online_prox`] for
/// a snapshot-free backward step. A formulation with no incremental form
/// simply keeps the defaults and is proxed over a matrix snapshot.
pub trait SharedProx: Send + Sync {
    /// Registry id (canonical formulation name; also the persist tag).
    fn id(&self) -> &'static str;

    /// Regularization strength λ.
    fn lambda(&self) -> f64;

    /// `Prox_{η λ g}(W)`, overwriting `w`. `eta` is the prox step size.
    fn prox(&mut self, w: &mut Mat, eta: f64);

    /// `λ·g(W)` for objective reporting.
    fn value(&self, w: &Mat) -> f64;

    /// A boxed deep copy (object-safe `Clone`).
    fn clone_box(&self) -> Box<dyn SharedProx>;

    /// Switch on the incremental path, seeded from the operand `w0`, with
    /// an exact refresh every `refresh_every` commits (0 = never). No-op
    /// for formulations without an incremental form.
    fn enable_incremental(&mut self, _w0: &Mat, _refresh_every: u64) {}

    /// True when the incremental path is active (the server then stages
    /// column updates and may use [`SharedProx::online_prox`]).
    fn is_incremental(&self) -> bool {
        false
    }

    /// Column `j` of the operand changed to `col` (no-op unless
    /// incremental). Does not advance the refresh stride — the server
    /// feeds raw commit counts through [`SharedProx::note_commits`],
    /// because one notification may represent many coalesced commits.
    fn notify_column_update(&mut self, _j: usize, _col: &[f64]) {}

    /// Advance the refresh-stride counter by `n` raw commits.
    fn note_commits(&mut self, _n: u64) {}

    /// The snapshot-free incremental prox, when active (`None` otherwise):
    /// reads only the formulation's internal state, so the caller does not
    /// need a snapshot of the operand matrix.
    fn online_prox(&self, _eta: f64) -> Option<Mat> {
        None
    }

    /// True when the commit counter says the incremental state is due for
    /// an exact rebuild.
    fn needs_refresh(&self) -> bool {
        false
    }

    /// Rebuild the incremental state exactly from `current` (the true
    /// operand), recording the drift the incremental path had accumulated.
    fn refresh(&mut self, _current: &Mat) {}

    /// Exact refreshes performed so far on the incremental path.
    fn refresh_count(&self) -> u64 {
        0
    }

    /// Drift measured at the most recent exact refresh.
    fn refresh_drift(&self) -> f64 {
        0.0
    }

    /// True when the prox is **column-separable**: for any column subset
    /// `S`, `(Prox(W))_S = Prox(W_S)` — proxing a slice of columns in
    /// isolation yields exactly the corresponding columns of the
    /// full-matrix prox. This is the capability a sharded server needs to
    /// split `V` across column-range shards with no cross-shard talk
    /// (`rust/src/shard/`); `rust/tests/properties.rs` proptests the
    /// property for every formulation that claims it.
    ///
    /// Defaults to `false`. Only the *elementwise* proxes (`l1`,
    /// `elasticnet`, `none`) return true. Note in particular that `l21`
    /// (each row norm spans all T columns) and `mean` (the centroid spans
    /// all T columns) are NOT column-separable, despite sounding local —
    /// they take the coordination-round path alongside `nuclear`/`graph`.
    fn is_separable(&self) -> bool {
        false
    }

    /// Serialize the formulation's complete state (strength, counters,
    /// incremental basis, …) as an opaque blob for a persist snapshot.
    /// Paired with [`restore`], which rebuilds the formulation from
    /// `(id, blob)`; the round trip must be bitwise exact.
    fn state_save(&self) -> Vec<u8>;

    /// Overwrite this formulation's state from a blob produced by
    /// [`SharedProx::state_save`]. Malformed input is an error, never a
    /// panic.
    fn state_load(&mut self, bytes: &[u8]) -> Result<()>;
}

// --------------------------------------------------------------- TaskLoss

/// The smooth per-task loss `ℓ_t` as a task node consumes it.
pub trait TaskLoss: Send + Sync {
    /// Canonical loss name (`"squared"`, `"logistic"`).
    fn name(&self) -> &'static str;

    /// The AOT artifact op implementing this loss's fused forward step.
    fn step_op(&self) -> &'static str;

    /// Gradient and objective at `w` over row-major `x` (`n × d`), labels
    /// `y`, with a row `mask` (1 = real row, 0 = padding).
    fn grad_obj(&self, x: &RowMat, y: &[f64], w: &[f64], mask: &[f64]) -> (Vec<f64>, f64);

    /// Objective only.
    fn obj(&self, x: &RowMat, y: &[f64], w: &[f64], mask: &[f64]) -> f64 {
        self.grad_obj(x, y, w, mask).1
    }

    /// Fused forward step `u = w − η ∇ℓ(w)`, returning `(u, ℓ(w))`.
    fn step(&self, x: &RowMat, y: &[f64], w: &[f64], mask: &[f64], eta: f64) -> (Vec<f64>, f64) {
        let (g, obj) = self.grad_obj(x, y, w, mask);
        let u = w.iter().zip(&g).map(|(wi, gi)| wi - eta * gi).collect();
        (u, obj)
    }

    /// Lipschitz constant of `∇ℓ` over the data `x` (power iteration).
    fn lipschitz(&self, x: &RowMat, rng: &mut Rng) -> f64;
}

/// Resolve a loss by name (canonical or alias) to its registered impl.
pub fn resolve_loss(name: &str) -> Result<&'static dyn TaskLoss> {
    Ok(crate::optim::losses::Loss::parse(name)?.task_loss())
}

// -------------------------------------------------------------- the spec

/// A formulation request: a registered name plus free-form `key=value`
/// parameters, optionally carrying a preloaded task-similarity graph.
/// Parsed from CLI syntax like `nuclear`, `elasticnet:gamma=2`,
/// `graph:topology=ring,weight=0.5` or `mean`.
#[derive(Clone, Debug)]
pub struct FormulationSpec {
    name: &'static str,
    params: Vec<(String, String)>,
    graph: Option<TaskGraph>,
}

impl FormulationSpec {
    /// Parse `name[:k=v,k=v,...]`, validating the name against the
    /// registry (aliases accepted, canonicalized).
    pub fn parse(s: &str) -> Result<FormulationSpec> {
        let (name, rest) = match s.split_once(':') {
            Some((n, r)) => (n, Some(r)),
            None => (s, None),
        };
        let name = canonical(name).ok_or_else(|| {
            anyhow::anyhow!(
                "unknown --reg formulation '{name}' (expected one of {})",
                FORMULATIONS.iter().map(|f| f.name).collect::<Vec<_>>().join("|")
            )
        })?;
        let mut params = Vec::new();
        if let Some(rest) = rest {
            for part in rest.split(',').filter(|p| !p.is_empty()) {
                let (k, v) = part.split_once('=').ok_or_else(|| {
                    anyhow::anyhow!(
                        "malformed --reg parameter '{part}' (expected key=value)"
                    )
                })?;
                params.push((k.to_string(), v.to_string()));
            }
        }
        Ok(FormulationSpec { name, params, graph: None })
    }

    /// The canonical formulation name.
    pub fn name(&self) -> &'static str {
        self.name
    }

    /// The value of parameter `key`, if supplied.
    pub fn param(&self, key: &str) -> Option<&str> {
        self.params.iter().find(|(k, _)| k == key).map(|(_, v)| v.as_str())
    }

    /// `f64` value of parameter `key`, or `default`.
    pub fn param_f64(&self, key: &str, default: f64) -> Result<f64> {
        match self.param(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| {
                anyhow::anyhow!("--reg parameter {key} expects a number, got '{v}'")
            }),
        }
    }

    /// Attach a preloaded task-similarity graph (the `--graph-file` path;
    /// only meaningful for the `graph` formulation).
    pub fn with_graph(mut self, graph: TaskGraph) -> FormulationSpec {
        self.graph = Some(graph);
        self
    }

    /// The attached similarity graph, if any.
    pub fn graph(&self) -> Option<&TaskGraph> {
        self.graph.as_ref()
    }

    /// Error on parameters outside `allowed` (typo protection: an unknown
    /// key must not silently change nothing).
    fn expect_params(&self, allowed: &[&str]) -> Result<()> {
        for (k, _) in &self.params {
            anyhow::ensure!(
                allowed.iter().any(|a| *a == k.as_str()),
                "formulation '{}' does not take parameter '{k}'{}",
                self.name,
                if allowed.is_empty() {
                    String::new()
                } else {
                    format!(" (allowed: {})", allowed.join(", "))
                }
            );
        }
        Ok(())
    }
}

impl From<RegularizerKind> for FormulationSpec {
    fn from(kind: RegularizerKind) -> FormulationSpec {
        FormulationSpec { name: kind.name(), params: Vec::new(), graph: None }
    }
}

// --------------------------------------------------------------- registry

/// One registry row: how a formulation is named and what it is.
pub struct FormulationInfo {
    /// Canonical name (the [`SharedProx::id`] and persist tag).
    pub name: &'static str,
    /// Accepted aliases.
    pub aliases: &'static [&'static str],
    /// One-line description (CLI/docs).
    pub summary: &'static str,
    /// What the incremental hooks do for this formulation, if anything.
    pub incremental: &'static str,
}

/// The registered shared-prox formulations.
pub const FORMULATIONS: &[FormulationInfo] = &[
    FormulationInfo {
        name: "nuclear",
        aliases: &["trace", "lowrank"],
        summary: "low-rank coupling g(W)=||W||_* (SVT prox)",
        incremental: "Brand online SVD, exact Jacobi re-anchor every resvd_every commits",
    },
    FormulationInfo {
        name: "l21",
        aliases: &[],
        summary: "joint feature selection g(W)=||W||_{2,1} (row shrinkage)",
        incremental: "none (row-separable prox over a snapshot)",
    },
    FormulationInfo {
        name: "l1",
        aliases: &[],
        summary: "elementwise sparsity (soft threshold)",
        incremental: "none",
    },
    FormulationInfo {
        name: "elasticnet",
        aliases: &["en"],
        summary: "||W||_1 + (gamma/2)||W||_F^2, the strongly convex variant",
        incremental: "none",
    },
    FormulationInfo {
        name: "none",
        aliases: &["stl"],
        summary: "no coupling: decoupled single-task learning baseline",
        incremental: "none",
    },
    FormulationInfo {
        name: "graph",
        aliases: &["laplacian"],
        summary: "task-relationship coupling g(W)=tr(W L W^T) over a similarity graph",
        incremental: "none (closed-form prox W(I+2*tau*L)^-1, inverse cached per tau)",
    },
    FormulationInfo {
        name: "mean",
        aliases: &["centroid"],
        summary: "mean-regularized clustering g(W)=(1/2)sum_t ||w_t - mean(W)||^2",
        incremental: "O(d) centroid update per commit; exact recentre every refresh stride",
    },
];

/// Canonicalize a formulation name or alias.
pub fn canonical(name: &str) -> Option<&'static str> {
    FORMULATIONS
        .iter()
        .find(|f| f.name == name || f.aliases.contains(&name))
        .map(|f| f.name)
}

/// Build the formulation `spec` names, with strength `lambda`, default
/// elastic-net weight `gamma`, over `t` tasks.
///
/// This is the one construction path: `MtlProblem`, the CLI and the
/// persist layer's [`restore`] all resolve through the same registry, so a
/// formulation registered here is immediately reachable from every
/// schedule, both transports, and `--resume`.
pub fn resolve(
    spec: &FormulationSpec,
    lambda: f64,
    gamma: f64,
    t: usize,
) -> Result<Box<dyn SharedProx>> {
    anyhow::ensure!(lambda >= 0.0, "regularization strength must be >= 0, got {lambda}");
    Ok(match spec.name() {
        "nuclear" => {
            spec.expect_params(&[])?;
            Box::new(NuclearProx::new(lambda))
        }
        "l21" => {
            spec.expect_params(&[])?;
            Box::new(L21Prox::new(lambda))
        }
        "l1" => {
            spec.expect_params(&[])?;
            Box::new(L1Prox::new(lambda))
        }
        "elasticnet" => {
            spec.expect_params(&["gamma"])?;
            let gamma = spec.param_f64("gamma", gamma)?;
            anyhow::ensure!(gamma >= 0.0, "elastic-net gamma must be >= 0, got {gamma}");
            Box::new(ElasticNetProx::new(lambda, gamma))
        }
        "none" => {
            spec.expect_params(&[])?;
            Box::new(ZeroProx::new(lambda))
        }
        "graph" => {
            spec.expect_params(&["topology", "weight"])?;
            let graph = match spec.graph() {
                Some(g) => {
                    anyhow::ensure!(
                        spec.param("topology").is_none() && spec.param("weight").is_none(),
                        "graph topology/weight params conflict with an explicitly \
                         provided similarity graph (--graph-file): pick one source"
                    );
                    anyhow::ensure!(
                        g.t() == t,
                        "similarity graph covers {} tasks but the problem has {t}",
                        g.t()
                    );
                    g.clone()
                }
                None => {
                    let weight = spec.param_f64("weight", 1.0)?;
                    anyhow::ensure!(weight > 0.0, "graph weight must be > 0, got {weight}");
                    match spec.param("topology").unwrap_or("full") {
                        "full" => TaskGraph::fully_connected(t, weight),
                        "ring" => TaskGraph::ring(t, weight),
                        other => anyhow::bail!(
                            "unknown graph topology '{other}' (expected full|ring, \
                             or pass --graph-file)"
                        ),
                    }
                }
            };
            Box::new(GraphProx::new(lambda, graph))
        }
        "mean" => {
            spec.expect_params(&[])?;
            Box::new(MeanProx::new(lambda))
        }
        other => anyhow::bail!("formulation '{other}' is registered but has no constructor"),
    })
}

/// Rebuild a formulation from its persist tag and state blob (the inverse
/// of [`SharedProx::id`] + [`SharedProx::state_save`]).
pub fn restore(id: &str, blob: &[u8]) -> Result<Box<dyn SharedProx>> {
    let mut reg: Box<dyn SharedProx> = match id {
        "nuclear" => Box::new(NuclearProx::new(0.0)),
        "l21" => Box::new(L21Prox::new(0.0)),
        "l1" => Box::new(L1Prox::new(0.0)),
        "elasticnet" => Box::new(ElasticNetProx::new(0.0, 1.0)),
        "none" => Box::new(ZeroProx::new(0.0)),
        "graph" => Box::new(GraphProx::blank()),
        "mean" => Box::new(MeanProx::new(0.0)),
        other => anyhow::bail!("snapshot names unknown formulation '{other}'"),
    };
    reg.state_load(blob)?;
    Ok(reg)
}

// ----------------------------------------------- shared state-blob codecs

/// Read exactly `n` little-endian f64s from a state-blob cursor.
pub(crate) fn read_f64s(c: &mut Cursor<'_>, n: usize) -> Result<Vec<f64>, WireError> {
    let len = n.checked_mul(8).ok_or(WireError::Malformed("f64 vector length overflow"))?;
    let bytes = c.take(len)?;
    Ok(bytes
        .chunks_exact(8)
        .map(|b| {
            f64::from_bits(u64::from_le_bytes([
                b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7],
            ]))
        })
        .collect())
}

/// Append a matrix (rows, cols, column-major f64 data) to a state blob.
pub(crate) fn push_mat(out: &mut Vec<u8>, m: &Mat) {
    out.extend_from_slice(&(m.rows() as u32).to_le_bytes());
    out.extend_from_slice(&(m.cols() as u32).to_le_bytes());
    crate::transport::wire::push_f64s(out, m.data());
}

/// Read a matrix written by [`push_mat`].
pub(crate) fn read_mat(c: &mut Cursor<'_>) -> Result<Mat, WireError> {
    let rows = c.u32()? as usize;
    let cols = c.u32()? as usize;
    let Some(len) = rows.checked_mul(cols) else {
        return Err(WireError::Malformed("matrix dimensions overflow"));
    };
    let data = read_f64s(c, len)?;
    let mut m = Mat::zeros(rows, cols);
    m.data_mut().copy_from_slice(&data);
    Ok(m)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spec_parses_names_aliases_and_params() {
        assert_eq!(FormulationSpec::parse("nuclear").unwrap().name(), "nuclear");
        assert_eq!(FormulationSpec::parse("trace").unwrap().name(), "nuclear");
        assert_eq!(FormulationSpec::parse("en").unwrap().name(), "elasticnet");
        let s = FormulationSpec::parse("graph:topology=ring,weight=0.5").unwrap();
        assert_eq!(s.name(), "graph");
        assert_eq!(s.param("topology"), Some("ring"));
        assert_eq!(s.param_f64("weight", 1.0).unwrap(), 0.5);
    }

    #[test]
    fn spec_rejects_unknown_names_and_malformed_params() {
        let err = FormulationSpec::parse("bogus").unwrap_err();
        assert!(format!("{err}").contains("nuclear|l21|l1|elasticnet|none|graph|mean"), "{err}");
        assert!(FormulationSpec::parse("graph:ring").is_err(), "bare param must error");
    }

    #[test]
    fn resolve_rejects_graph_params_alongside_an_attached_graph() {
        let spec = FormulationSpec::parse("graph:weight=2").unwrap().with_graph(
            crate::optim::coupling::TaskGraph::ring(3, 1.0),
        );
        let err = resolve(&spec, 0.5, 1.0, 3).unwrap_err();
        assert!(format!("{err}").contains("conflict"), "{err}");
        // Without the contradictory params the attached graph resolves.
        let spec = FormulationSpec::parse("graph").unwrap().with_graph(
            crate::optim::coupling::TaskGraph::ring(3, 1.0),
        );
        assert!(resolve(&spec, 0.5, 1.0, 3).is_ok());
    }

    #[test]
    fn resolve_rejects_unknown_params() {
        let s = FormulationSpec::parse("mean:weight=2").unwrap();
        let err = resolve(&s, 0.5, 1.0, 3).unwrap_err();
        assert!(format!("{err}").contains("does not take parameter"), "{err}");
    }

    #[test]
    fn every_registered_formulation_resolves_and_restores() {
        for info in FORMULATIONS {
            let spec = FormulationSpec::parse(info.name).unwrap();
            let reg = resolve(&spec, 0.4, 1.5, 4).unwrap();
            assert_eq!(reg.id(), info.name);
            assert_eq!(reg.lambda(), 0.4);
            let blob = reg.state_save();
            let back = restore(reg.id(), &blob).unwrap();
            assert_eq!(back.id(), info.name);
            assert_eq!(back.lambda(), 0.4);
            assert_eq!(back.state_save(), blob, "{}: save/restore/save must be stable", info.name);
        }
    }

    #[test]
    fn restore_rejects_unknown_id_and_garbage() {
        assert!(restore("bogus", &[]).is_err());
        assert!(restore("nuclear", &[1, 2, 3]).is_err(), "truncated blob must error");
    }

    #[test]
    fn kind_converts_to_spec() {
        let s: FormulationSpec = RegularizerKind::ElasticNet.into();
        assert_eq!(s.name(), "elasticnet");
    }

    #[test]
    fn losses_resolve_by_name() {
        assert_eq!(resolve_loss("squared").unwrap().name(), "squared");
        assert_eq!(resolve_loss("lsq").unwrap().name(), "squared");
        assert_eq!(resolve_loss("logistic").unwrap().name(), "logistic");
        assert!(resolve_loss("hinge").is_err());
    }
}
