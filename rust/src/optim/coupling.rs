//! Relationship-coupled formulations, shipped through the open
//! [`SharedProx`](crate::optim::formulation::SharedProx) API to prove the
//! formulation layer is extensible (not just a refactor of the classics):
//!
//! * [`GraphProx`] — **graph-Laplacian relationship coupling** (the
//!   Distributed Multi-Task Relationship Learning family):
//!   `g(W) = tr(W L Wᵀ) = Σ_{i<j} S_ij ‖w_i − w_j‖²` over a
//!   [`TaskGraph`] of pairwise task similarities `S`. The prox is closed
//!   form — `Prox_{τg}(W) = W (I + 2τL)⁻¹` — one small `T × T` solve,
//!   cached per τ, applied as a matmul. Tasks related in the graph are
//!   pulled together; unrelated tasks are left alone.
//! * [`MeanProx`] — **mean-regularized clustering** (the Federated
//!   Multi-Task Learning baseline): `g(W) = ½ Σ_t ‖w_t − w̄‖²` pulls
//!   every task toward the shared centroid `w̄`. The prox keeps the
//!   centroid and shrinks deviations: `z_t = w̄ + (w_t − w̄)/(1+τ)`.
//!   Its incremental hooks maintain the centroid in **O(d) per commit**
//!   (a running column cache + sum), with the periodic exact refresh
//!   re-centring the sum to bound float drift — the same
//!   stage/coalesce/refresh plumbing the online nuclear prox uses.

use crate::linalg::Mat;
use crate::optim::formulation::{push_mat, read_f64s, read_mat, SharedProx};
use crate::optim::svd::Svd;
use crate::transport::wire::{push_f64s, Cursor, WireError};
use anyhow::Result;
use std::path::Path;

// -------------------------------------------------------------- TaskGraph

/// Pairwise task similarities: a symmetric `T × T` weight matrix with a
/// zero diagonal. `S_ij > 0` couples tasks `i` and `j` with that strength.
#[derive(Clone, Debug, PartialEq)]
pub struct TaskGraph {
    weights: Mat,
}

impl TaskGraph {
    /// A graph from an explicit weight matrix. Errors unless `w` is
    /// square, symmetric, nonnegative, and zero on the diagonal.
    pub fn from_weights(w: Mat) -> Result<TaskGraph> {
        anyhow::ensure!(
            w.rows() == w.cols(),
            "similarity matrix must be square, got {}x{}",
            w.rows(),
            w.cols()
        );
        let t = w.rows();
        for i in 0..t {
            anyhow::ensure!(w.get(i, i) == 0.0, "similarity diagonal must be zero (task {i})");
            for j in 0..t {
                let s = w.get(i, j);
                anyhow::ensure!(s >= 0.0, "similarity weights must be >= 0 ({i},{j} is {s})");
                anyhow::ensure!(
                    (s - w.get(j, i)).abs() == 0.0,
                    "similarity matrix must be symmetric ({i},{j})"
                );
            }
        }
        Ok(TaskGraph { weights: w })
    }

    /// Every pair of tasks coupled with weight `w` (the densest prior).
    pub fn fully_connected(t: usize, w: f64) -> TaskGraph {
        let mut m = Mat::zeros(t, t);
        for i in 0..t {
            for j in 0..t {
                if i != j {
                    m.set(i, j, w);
                }
            }
        }
        TaskGraph { weights: m }
    }

    /// Tasks on a cycle, each coupled to its two neighbors with weight
    /// `w` (a locality prior: task `t` resembles tasks `t±1`).
    pub fn ring(t: usize, w: f64) -> TaskGraph {
        let mut m = Mat::zeros(t, t);
        if t >= 2 {
            for i in 0..t {
                let j = (i + 1) % t;
                if i != j {
                    m.set(i, j, w);
                    m.set(j, i, w);
                }
            }
        }
        TaskGraph { weights: m }
    }

    /// Parse the `--graph-file` JSON format:
    ///
    /// ```json
    /// { "tasks": 4, "edges": [[0, 1, 1.0], [1, 2, 0.5]] }
    /// ```
    ///
    /// Each edge is `[i, j, weight]` (undirected; listing both directions
    /// is allowed if the weights agree).
    pub fn from_json(text: &str) -> Result<TaskGraph> {
        let doc = crate::util::json::Json::parse(text)?;
        let t = doc
            .get("tasks")
            .and_then(|j| j.as_usize())
            .ok_or_else(|| anyhow::anyhow!("graph json needs a \"tasks\" count"))?;
        let edges = doc
            .get("edges")
            .and_then(|j| j.as_arr())
            .ok_or_else(|| anyhow::anyhow!("graph json needs an \"edges\" array"))?;
        let mut m = Mat::zeros(t, t);
        for (n, e) in edges.iter().enumerate() {
            let triple = e.as_arr().filter(|a| a.len() == 3).ok_or_else(|| {
                anyhow::anyhow!("edge {n} must be [i, j, weight]")
            })?;
            let i = triple[0]
                .as_usize()
                .ok_or_else(|| anyhow::anyhow!("edge {n}: task index must be an integer"))?;
            let j = triple[1]
                .as_usize()
                .ok_or_else(|| anyhow::anyhow!("edge {n}: task index must be an integer"))?;
            let w = triple[2]
                .as_f64()
                .ok_or_else(|| anyhow::anyhow!("edge {n}: weight must be a number"))?;
            anyhow::ensure!(i < t && j < t, "edge {n}: task index out of range (tasks={t})");
            anyhow::ensure!(i != j, "edge {n}: self-loops are not allowed");
            anyhow::ensure!(w >= 0.0, "edge {n}: weight must be >= 0, got {w}");
            let existing = m.get(i, j);
            anyhow::ensure!(
                existing == 0.0 || existing == w,
                "edge {n}: ({i},{j}) listed twice with different weights"
            );
            m.set(i, j, w);
            m.set(j, i, w);
        }
        Ok(TaskGraph { weights: m })
    }

    /// Load [`TaskGraph::from_json`] from a file.
    pub fn from_json_file(path: &Path) -> Result<TaskGraph> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("reading graph file {}: {e}", path.display()))?;
        TaskGraph::from_json(&text)
    }

    /// Number of tasks the graph covers.
    pub fn t(&self) -> usize {
        self.weights.rows()
    }

    /// The symmetric similarity matrix `S`.
    pub fn weights(&self) -> &Mat {
        &self.weights
    }

    /// The graph Laplacian `L = D − S` (`D_ii = Σ_j S_ij`).
    pub fn laplacian(&self) -> Mat {
        let t = self.t();
        let mut l = Mat::zeros(t, t);
        for i in 0..t {
            let mut degree = 0.0;
            for j in 0..t {
                let s = self.weights.get(i, j);
                degree += s;
                if i != j {
                    l.set(i, j, -s);
                }
            }
            l.set(i, i, degree);
        }
        l
    }
}

// -------------------------------------------------------------- GraphProx

/// Graph-Laplacian relationship coupling `λ·tr(W L Wᵀ)` with the
/// closed-form prox `W (I + 2τL)⁻¹`.
#[derive(Clone, Debug)]
pub struct GraphProx {
    lambda: f64,
    graph: TaskGraph,
    laplacian: Mat,
    /// `(τ, (I + 2τL)⁻¹)` — τ is fixed for a run (η and λ are run
    /// constants), so the small `T × T` inverse is computed once and the
    /// per-prox cost is one `d×T · T×T` matmul.
    inverse: Option<(f64, Mat)>,
}

impl GraphProx {
    /// A graph regularizer with strength `lambda` over `graph`.
    pub fn new(lambda: f64, graph: TaskGraph) -> GraphProx {
        let laplacian = graph.laplacian();
        GraphProx { lambda, graph, laplacian, inverse: None }
    }

    /// An empty placeholder for [`state_load`](SharedProx::state_load)
    /// (the persist restore path).
    pub(crate) fn blank() -> GraphProx {
        GraphProx::new(0.0, TaskGraph::fully_connected(0, 1.0))
    }

    /// The similarity graph.
    pub fn graph(&self) -> &TaskGraph {
        &self.graph
    }

    /// `(I + 2τL)⁻¹`, cached per τ. `I + 2τL` is symmetric positive
    /// definite (its spectrum is `1 + 2τ·eig(L) ≥ 1`), inverted through
    /// the exact Jacobi SVD: `A⁻¹ = V Σ⁻¹ Uᵀ`.
    fn inverse_for(&mut self, tau: f64) -> &Mat {
        let stale = match &self.inverse {
            Some((cached_tau, _)) => *cached_tau != tau,
            None => true,
        };
        if stale {
            let t = self.laplacian.rows();
            let mut a = Mat::identity(t);
            for i in 0..t {
                for j in 0..t {
                    a.set(i, j, a.get(i, j) + 2.0 * tau * self.laplacian.get(i, j));
                }
            }
            let s = Svd::jacobi(&a);
            let mut v_scaled = s.v.clone();
            for (k, sigma) in s.sigma.iter().enumerate() {
                let inv_sigma = 1.0 / sigma;
                for x in v_scaled.col_mut(k) {
                    *x *= inv_sigma;
                }
            }
            let inv = v_scaled.matmul(&s.u.transpose());
            self.inverse = Some((tau, inv));
        }
        &self.inverse.as_ref().expect("just computed").1
    }
}

impl SharedProx for GraphProx {
    fn id(&self) -> &'static str {
        "graph"
    }

    fn lambda(&self) -> f64 {
        self.lambda
    }

    fn prox(&mut self, w: &mut Mat, eta: f64) {
        let tau = eta * self.lambda;
        if tau == 0.0 || w.cols() == 0 {
            return;
        }
        let inv = self.inverse_for(tau);
        *w = w.matmul(inv);
    }

    fn value(&self, w: &Mat) -> f64 {
        // tr(W L Wᵀ) = Σ_{i<j} S_ij ‖w_i − w_j‖², each pair once.
        let t = w.cols();
        let mut sum = 0.0;
        for i in 0..t {
            for j in (i + 1)..t {
                let s = self.graph.weights().get(i, j);
                if s == 0.0 {
                    continue;
                }
                let mut d2 = 0.0;
                for (a, b) in w.col(i).iter().zip(w.col(j)) {
                    let d = a - b;
                    d2 += d * d;
                }
                sum += s * d2;
            }
        }
        self.lambda * sum
    }

    fn clone_box(&self) -> Box<dyn SharedProx> {
        Box::new(self.clone())
    }

    fn state_save(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(16 + self.graph.t() * self.graph.t() * 8);
        out.extend_from_slice(&self.lambda.to_bits().to_le_bytes());
        push_mat(&mut out, self.graph.weights());
        out
    }

    fn state_load(&mut self, bytes: &[u8]) -> Result<()> {
        let mut c = Cursor::new(bytes);
        let lambda = c.f64()?;
        let weights = read_mat(&mut c)?;
        c.finish()?;
        let graph = TaskGraph::from_weights(weights)?;
        *self = GraphProx::new(lambda, graph);
        Ok(())
    }
}

// --------------------------------------------------------------- MeanProx

/// The incremental centroid state: a mirror of the operand's columns and
/// their running sum, maintained in O(d) per column update.
#[derive(Clone, Debug)]
struct MeanCache {
    cols: Mat,
    sum: Vec<f64>,
}

fn column_sum(m: &Mat) -> Vec<f64> {
    let mut sum = vec![0.0; m.rows()];
    for c in 0..m.cols() {
        for (s, x) in sum.iter_mut().zip(m.col(c)) {
            *s += x;
        }
    }
    sum
}

/// `z_t = c + (w_t − c) / (1 + τ)`: keep the centroid, shrink deviations.
fn shrink_toward(src: &Mat, centroid: &[f64], tau: f64) -> Mat {
    let shrink = 1.0 / (1.0 + tau);
    let mut out = Mat::zeros(src.rows(), src.cols());
    for t in 0..src.cols() {
        let (src_col, out_col) = (src.col(t), out.col_mut(t));
        for ((o, x), c) in out_col.iter_mut().zip(src_col).zip(centroid) {
            *o = c + (x - c) * shrink;
        }
    }
    out
}

/// Mean-regularized clustering `λ·½ Σ_t ‖w_t − w̄‖²` (every task pulled
/// toward the shared centroid).
///
/// Not column-separable in the [`SharedProx::is_separable`] sense: the
/// centroid is a sum over *all* T columns, so a column-range shard proxing
/// its slice alone would shrink toward the wrong (slice-local) centroid.
/// Sharded runs route it through the coordination round.
///
/// The prox *is* column-separable given the centroid — which is what the
/// incremental hooks exploit: with the incremental path enabled the
/// centroid is maintained as a running sum (O(d) per commit instead of
/// O(dT) per prox), [`SharedProx::online_prox`] is snapshot-free, and the
/// periodic exact [`SharedProx::refresh`] re-centres the sum, recording
/// the float drift the incremental accumulation had built up.
#[derive(Clone, Debug)]
pub struct MeanProx {
    lambda: f64,
    cache: Option<MeanCache>,
    refresh_every: u64,
    commits_since_refresh: u64,
    refreshes: u64,
    last_drift: f64,
}

impl MeanProx {
    /// A mean regularizer with strength `lambda`.
    pub fn new(lambda: f64) -> MeanProx {
        MeanProx {
            lambda,
            cache: None,
            refresh_every: 0,
            commits_since_refresh: 0,
            refreshes: 0,
            last_drift: 0.0,
        }
    }
}

impl SharedProx for MeanProx {
    fn id(&self) -> &'static str {
        "mean"
    }

    fn lambda(&self) -> f64 {
        self.lambda
    }

    fn prox(&mut self, w: &mut Mat, eta: f64) {
        let tau = eta * self.lambda;
        if tau == 0.0 || w.cols() == 0 {
            return;
        }
        let mut centroid = column_sum(w);
        let inv_t = 1.0 / w.cols() as f64;
        for c in centroid.iter_mut() {
            *c *= inv_t;
        }
        *w = shrink_toward(w, &centroid, tau);
    }

    fn value(&self, w: &Mat) -> f64 {
        if w.cols() == 0 {
            return 0.0;
        }
        let mut centroid = column_sum(w);
        let inv_t = 1.0 / w.cols() as f64;
        for c in centroid.iter_mut() {
            *c *= inv_t;
        }
        let mut sum = 0.0;
        for t in 0..w.cols() {
            for (x, c) in w.col(t).iter().zip(&centroid) {
                let d = x - c;
                sum += d * d;
            }
        }
        0.5 * self.lambda * sum
    }

    fn clone_box(&self) -> Box<dyn SharedProx> {
        Box::new(self.clone())
    }

    fn enable_incremental(&mut self, w0: &Mat, refresh_every: u64) {
        self.cache = Some(MeanCache { sum: column_sum(w0), cols: w0.clone() });
        self.refresh_every = refresh_every;
        self.commits_since_refresh = 0;
    }

    fn is_incremental(&self) -> bool {
        self.cache.is_some()
    }

    fn notify_column_update(&mut self, j: usize, col: &[f64]) {
        if let Some(cache) = self.cache.as_mut() {
            // O(d): fold the column delta into the running sum.
            for (i, (s, new)) in cache.sum.iter_mut().zip(col).enumerate() {
                *s += new - cache.cols.get(i, j);
            }
            cache.cols.set_col(j, col);
        }
    }

    fn note_commits(&mut self, n: u64) {
        if self.cache.is_some() {
            self.commits_since_refresh += n;
        }
    }

    fn online_prox(&self, eta: f64) -> Option<Mat> {
        let cache = self.cache.as_ref()?;
        let t = cache.cols.cols();
        if t == 0 {
            return Some(cache.cols.clone());
        }
        let tau = eta * self.lambda;
        let inv_t = 1.0 / t as f64;
        let centroid: Vec<f64> = cache.sum.iter().map(|s| s * inv_t).collect();
        Some(shrink_toward(&cache.cols, &centroid, tau))
    }

    fn needs_refresh(&self) -> bool {
        self.cache.is_some()
            && self.refresh_every > 0
            && self.commits_since_refresh >= self.refresh_every
    }

    fn refresh(&mut self, current: &Mat) {
        if self.cache.is_some() {
            // Drift = how far the incrementally-accumulated sum wandered
            // from an exact re-summation (pure float error: the column
            // cache itself is exact under column replacement).
            let fresh = column_sum(current);
            let old = &self.cache.as_ref().expect("checked above").sum;
            self.last_drift = fresh
                .iter()
                .zip(old)
                .map(|(a, b)| (a - b).abs())
                .fold(0.0, f64::max);
            self.cache = Some(MeanCache { sum: fresh, cols: current.clone() });
            self.refreshes += 1;
            self.commits_since_refresh = 0;
        }
    }

    fn refresh_count(&self) -> u64 {
        self.refreshes
    }

    fn refresh_drift(&self) -> f64 {
        self.last_drift
    }

    fn state_save(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(64);
        out.extend_from_slice(&self.lambda.to_bits().to_le_bytes());
        out.extend_from_slice(&self.refresh_every.to_le_bytes());
        out.extend_from_slice(&self.commits_since_refresh.to_le_bytes());
        out.extend_from_slice(&self.refreshes.to_le_bytes());
        out.extend_from_slice(&self.last_drift.to_bits().to_le_bytes());
        match &self.cache {
            None => out.push(0),
            Some(cache) => {
                out.push(1);
                push_mat(&mut out, &cache.cols);
                push_f64s(&mut out, &cache.sum);
            }
        }
        out
    }

    fn state_load(&mut self, bytes: &[u8]) -> Result<()> {
        let mut c = Cursor::new(bytes);
        self.lambda = c.f64()?;
        self.refresh_every = c.u64()?;
        self.commits_since_refresh = c.u64()?;
        self.refreshes = c.u64()?;
        self.last_drift = c.f64()?;
        self.cache = match c.u8()? {
            0 => None,
            1 => {
                let cols = read_mat(&mut c)?;
                let sum = read_f64s(&mut c, cols.rows())?;
                Some(MeanCache { cols, sum })
            }
            _ => return Err(WireError::Malformed("mean cache flag not 0/1").into()),
        };
        c.finish()?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::forall;
    use crate::util::Rng;

    fn mat_from(v: &[f64], rows: usize) -> Mat {
        Mat::from_cols(rows, v.chunks(rows).map(|c| c.to_vec()).collect())
    }

    #[test]
    fn graph_constructors_and_laplacian() {
        let full = TaskGraph::fully_connected(3, 2.0);
        let l = full.laplacian();
        for i in 0..3 {
            assert_eq!(l.get(i, i), 4.0, "degree = (T-1)*w");
            for j in 0..3 {
                if i != j {
                    assert_eq!(l.get(i, j), -2.0);
                }
            }
        }
        let ring = TaskGraph::ring(4, 1.0);
        assert_eq!(ring.laplacian().get(0, 0), 2.0, "two neighbors each");
        assert_eq!(ring.weights().get(0, 2), 0.0, "non-neighbors uncoupled");
        // Row sums of any Laplacian are zero.
        for i in 0..4 {
            let s: f64 = (0..4).map(|j| ring.laplacian().get(i, j)).sum();
            assert!(s.abs() < 1e-15);
        }
    }

    #[test]
    fn graph_json_roundtrip_and_validation() {
        let g = TaskGraph::from_json(
            r#"{ "tasks": 3, "edges": [[0, 1, 1.5], [1, 2, 0.5]] }"#,
        )
        .unwrap();
        assert_eq!(g.t(), 3);
        assert_eq!(g.weights().get(0, 1), 1.5);
        assert_eq!(g.weights().get(1, 0), 1.5, "undirected");
        assert_eq!(g.weights().get(0, 2), 0.0);

        assert!(TaskGraph::from_json(r#"{ "edges": [] }"#).is_err(), "missing tasks");
        assert!(
            TaskGraph::from_json(r#"{ "tasks": 2, "edges": [[0, 0, 1.0]] }"#).is_err(),
            "self-loop"
        );
        assert!(
            TaskGraph::from_json(r#"{ "tasks": 2, "edges": [[0, 5, 1.0]] }"#).is_err(),
            "out of range"
        );
        assert!(
            TaskGraph::from_json(r#"{ "tasks": 2, "edges": [[0, 1, -1.0]] }"#).is_err(),
            "negative weight"
        );
    }

    #[test]
    fn graph_prox_two_tasks_matches_eigen_closed_form() {
        // T=2, one edge of weight s: L has eigenvalues 0 (mean direction)
        // and 2s (difference direction), so the prox keeps the mean and
        // shrinks the difference by 1/(1 + 4τs).
        let s = 0.7;
        let tau = 0.3;
        let mut g = GraphProx::new(1.0, TaskGraph::fully_connected(2, s));
        let mut rng = Rng::new(40);
        let w = Mat::randn(5, 2, &mut rng);
        let mut z = w.clone();
        g.prox(&mut z, tau);
        let shrink = 1.0 / (1.0 + 4.0 * tau * s);
        for i in 0..5 {
            let mean = 0.5 * (w.get(i, 0) + w.get(i, 1));
            let diff = 0.5 * (w.get(i, 0) - w.get(i, 1));
            assert!((z.get(i, 0) - (mean + diff * shrink)).abs() < 1e-10);
            assert!((z.get(i, 1) - (mean - diff * shrink)).abs() < 1e-10);
        }
    }

    #[test]
    fn graph_prox_satisfies_stationarity() {
        // z = Prox_{τg}(w) solves z − w + 2τ·zL = 0.
        let mut rng = Rng::new(41);
        let graph = TaskGraph::ring(5, 0.8);
        let l = graph.laplacian();
        let mut g = GraphProx::new(0.6, graph);
        let w = Mat::randn(4, 5, &mut rng);
        let mut z = w.clone();
        let eta = 0.5;
        g.prox(&mut z, eta);
        let tau = eta * 0.6;
        let residual = z.add_scaled(-1.0, &w).add_scaled(2.0 * tau, &z.matmul(&l));
        assert!(
            residual.frobenius_norm() < 1e-9,
            "stationarity residual {}",
            residual.frobenius_norm()
        );
    }

    #[test]
    fn graph_value_matches_pairwise_sum() {
        let graph = TaskGraph::from_json(
            r#"{ "tasks": 3, "edges": [[0, 1, 2.0]] }"#,
        )
        .unwrap();
        let g = GraphProx::new(0.5, graph);
        // w_0 = (1,0), w_1 = (0,1), w_2 = (9,9): only the 0-1 edge counts.
        let w = Mat::from_cols(2, vec![vec![1.0, 0.0], vec![0.0, 1.0], vec![9.0, 9.0]]);
        // λ · S_01 · ‖w_0 − w_1‖² = 0.5 · 2 · 2 = 2.
        assert!((g.value(&w) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn graph_uncoupled_tasks_are_untouched() {
        // A task with no edges must pass through the prox unchanged.
        let graph = TaskGraph::from_json(
            r#"{ "tasks": 3, "edges": [[0, 1, 1.0]] }"#,
        )
        .unwrap();
        let mut g = GraphProx::new(1.0, graph);
        let mut rng = Rng::new(42);
        let w = Mat::randn(4, 3, &mut rng);
        let mut z = w.clone();
        g.prox(&mut z, 0.4);
        for i in 0..4 {
            assert!(
                (z.get(i, 2) - w.get(i, 2)).abs() < 1e-10,
                "isolated task column must be identity under the prox"
            );
        }
    }

    #[test]
    fn mean_prox_matches_closed_form() {
        let mut rng = Rng::new(43);
        let w = Mat::randn(6, 4, &mut rng);
        let mut reg = MeanProx::new(0.8);
        let mut z = w.clone();
        let eta = 0.5;
        reg.prox(&mut z, eta);
        let tau = eta * 0.8;
        for i in 0..6 {
            let c: f64 = (0..4).map(|t| w.get(i, t)).sum::<f64>() / 4.0;
            for t in 0..4 {
                let want = c + (w.get(i, t) - c) / (1.0 + tau);
                assert!((z.get(i, t) - want).abs() < 1e-12);
            }
        }
        // The centroid itself is preserved.
        for i in 0..6 {
            let before: f64 = (0..4).map(|t| w.get(i, t)).sum();
            let after: f64 = (0..4).map(|t| z.get(i, t)).sum();
            assert!((before - after).abs() < 1e-10);
        }
    }

    #[test]
    fn mean_incremental_tracks_exact_and_refresh_measures_drift() {
        let mut rng = Rng::new(44);
        let mut w = Mat::randn(5, 3, &mut rng);
        let mut reg = MeanProx::new(0.6);
        reg.enable_incremental(&w, 8);
        assert!(reg.is_incremental());
        for step in 0..20 {
            let j = step % 3;
            let col = rng.normal_vec(5);
            w.set_col(j, &col);
            reg.notify_column_update(j, &col);
            reg.note_commits(1);
            if reg.needs_refresh() {
                reg.refresh(&w);
                assert!(reg.refresh_drift() < 1e-12, "drift {}", reg.refresh_drift());
            }
            let online = reg.online_prox(0.5).expect("incremental path active");
            let mut exact = w.clone();
            MeanProx::new(0.6).prox(&mut exact, 0.5);
            assert!(
                online.max_abs_diff(&exact) < 1e-12,
                "step {step}: incremental centroid diverged {}",
                online.max_abs_diff(&exact)
            );
        }
        assert_eq!(reg.refresh_count(), 2, "20 commits / refresh_every=8");
    }

    #[test]
    fn mean_and_graph_state_roundtrip_bitwise() {
        let mut rng = Rng::new(45);
        let w = Mat::randn(4, 3, &mut rng);
        let mut mean = MeanProx::new(0.7);
        mean.enable_incremental(&w, 32);
        mean.notify_column_update(1, &rng.normal_vec(4));
        mean.note_commits(5);
        let blob = mean.state_save();
        let mut back = MeanProx::new(0.0);
        back.state_load(&blob).unwrap();
        assert_eq!(back.state_save(), blob);
        assert_eq!(
            mean.online_prox(0.5).unwrap(),
            back.online_prox(0.5).unwrap(),
            "restored centroid cache must prox bitwise-identically"
        );

        let graph = GraphProx::new(0.4, TaskGraph::ring(5, 1.5));
        let blob = graph.state_save();
        let mut back = GraphProx::blank();
        back.state_load(&blob).unwrap();
        assert_eq!(back.state_save(), blob);
        assert_eq!(back.graph(), graph.graph());
        for cut in 0..blob.len() {
            assert!(
                GraphProx::blank().state_load(&blob[..cut]).is_err(),
                "prefix of {cut} bytes must not load"
            );
        }
    }

    #[test]
    fn prop_graph_and_mean_proxes_nonexpansive() {
        for which in ["graph", "mean"] {
            forall(
                &format!("prox {which} nonexpansive"),
                30,
                |g| (g.normal_vec(12), g.normal_vec(12)),
                |(a, b)| {
                    let ma = mat_from(a, 3);
                    let mb = mat_from(b, 3);
                    let before = ma.add_scaled(-1.0, &mb).frobenius_norm();
                    let mut reg: Box<dyn SharedProx> = if which == "graph" {
                        Box::new(GraphProx::new(0.5, TaskGraph::fully_connected(4, 0.8)))
                    } else {
                        Box::new(MeanProx::new(0.5))
                    };
                    let mut pa = ma.clone();
                    let mut pb = mb.clone();
                    reg.prox(&mut pa, 0.7);
                    reg.prox(&mut pb, 0.7);
                    pa.add_scaled(-1.0, &pb).frobenius_norm() <= before + 1e-9
                },
            );
        }
    }
}
