//! Task losses in rust: the **native compute engine** (used when artifacts
//! are absent, and as a cross-check oracle against the PJRT path) mirrors
//! the L1 Pallas kernels exactly — masked least-squares and logistic
//! gradient + objective in one pass.
//!
//! The paper's loss for task t is `ℓ_t(w) = Σ_i (x_i·w − y_i)²` (squared
//! loss, Eq. IV.1 — note: *not* halved) or the logistic loss
//! `Σ_i log(1+exp(x_i·w)) − y_i (x_i·w)` with labels in {0,1}.
//!
//! Each loss is a [`TaskLoss`](crate::optim::formulation::TaskLoss) impl
//! ([`LeastSquares`], [`LogisticLoss`]); the [`Loss`] enum remains the
//! compact storage form datasets carry and delegates every operation to
//! the trait impl, so downstream code can hold either.

use crate::optim::formulation::TaskLoss;
use crate::util::{EnumTable, Rng};

/// Name table for [`Loss`].
const LOSSES: EnumTable<Loss> = EnumTable {
    what: "loss",
    rows: &[
        ("squared", &["lsq", "l2"], Loss::Squared),
        ("logistic", &["logreg"], Loss::Logistic),
    ],
};

/// The per-task smooth loss `ℓ_t` (storage form; see
/// [`Loss::task_loss`] for the trait impl it delegates to).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Loss {
    /// `Σ (x·w − y)²`, gradient `2 Xᵀ(Xw − y)`.
    Squared,
    /// `Σ softplus(x·w) − y(x·w)`, gradient `Xᵀ(σ(Xw) − y)`.
    Logistic,
}

impl Loss {
    /// Parse a CLI value (`"squared"` | `"logistic"`, plus aliases); the
    /// error lists the valid values.
    pub fn parse(s: &str) -> anyhow::Result<Loss> {
        LOSSES.parse(s)
    }

    /// Canonical CLI name.
    pub fn name(&self) -> &'static str {
        LOSSES.name(*self)
    }

    /// The registered [`TaskLoss`] impl this enum value stands for.
    pub fn task_loss(&self) -> &'static dyn TaskLoss {
        match self {
            Loss::Squared => &LeastSquares,
            Loss::Logistic => &LogisticLoss,
        }
    }

    /// The AOT artifact op implementing this loss's fused forward step.
    pub fn step_op(&self) -> &'static str {
        self.task_loss().step_op()
    }

    /// Gradient and objective at `w` over row-major `x` (`n × d`), labels
    /// `y`, with a row `mask` (1 = real row, 0 = padding).
    pub fn grad_obj(&self, x: &RowMat, y: &[f64], w: &[f64], mask: &[f64]) -> (Vec<f64>, f64) {
        self.task_loss().grad_obj(x, y, w, mask)
    }

    /// Objective only.
    pub fn obj(&self, x: &RowMat, y: &[f64], w: &[f64], mask: &[f64]) -> f64 {
        self.task_loss().obj(x, y, w, mask)
    }

    /// Fused forward step `u = w − η ∇ℓ(w)`, returning `(u, ℓ(w))` — the
    /// native mirror of the `*_step` artifacts.
    pub fn step(
        &self,
        x: &RowMat,
        y: &[f64],
        w: &[f64],
        mask: &[f64],
        eta: f64,
    ) -> (Vec<f64>, f64) {
        self.task_loss().step(x, y, w, mask, eta)
    }
}

/// One masked accumulation pass shared by every loss: for each live row,
/// `per_row(z, y)` returns the gradient coefficient and the objective
/// contribution at margin `z = x_i · w`.
fn accumulate(
    x: &RowMat,
    y: &[f64],
    w: &[f64],
    mask: &[f64],
    per_row: impl Fn(f64, f64) -> (f64, f64),
) -> (Vec<f64>, f64) {
    let n = x.rows;
    let d = x.cols;
    debug_assert_eq!(y.len(), n);
    debug_assert_eq!(mask.len(), n);
    debug_assert_eq!(w.len(), d);
    let mut g = vec![0.0; d];
    let mut obj = 0.0;
    for i in 0..n {
        if mask[i] == 0.0 {
            continue;
        }
        let xi = x.row(i);
        let z: f64 = xi.iter().zip(w).map(|(a, b)| a * b).sum();
        let (coef, contrib) = per_row(z, y[i]);
        let coef = coef * mask[i];
        for (gk, xk) in g.iter_mut().zip(xi) {
            *gk += coef * xk;
        }
        obj += mask[i] * contrib;
    }
    (g, obj)
}

/// Masked least squares `Σ (x·w − y)²` (Eq. IV.1; not halved).
#[derive(Clone, Copy, Debug, Default)]
pub struct LeastSquares;

impl TaskLoss for LeastSquares {
    fn name(&self) -> &'static str {
        "squared"
    }

    fn step_op(&self) -> &'static str {
        "lsq_step"
    }

    fn grad_obj(&self, x: &RowMat, y: &[f64], w: &[f64], mask: &[f64]) -> (Vec<f64>, f64) {
        accumulate(x, y, w, mask, |z, yi| {
            let r = z - yi;
            (2.0 * r, r * r)
        })
    }

    fn lipschitz(&self, x: &RowMat, rng: &mut Rng) -> f64 {
        // `L = 2‖X‖₂²` (Hessian `2XᵀX`).
        let s = crate::optim::lipschitz::gram_spectral_norm(x, 100, rng);
        2.0 * s * s
    }
}

/// Masked logistic loss `Σ softplus(x·w) − y(x·w)` with labels in {0,1}.
#[derive(Clone, Copy, Debug, Default)]
pub struct LogisticLoss;

impl TaskLoss for LogisticLoss {
    fn name(&self) -> &'static str {
        "logistic"
    }

    fn step_op(&self) -> &'static str {
        "logistic_step"
    }

    fn grad_obj(&self, x: &RowMat, y: &[f64], w: &[f64], mask: &[f64]) -> (Vec<f64>, f64) {
        accumulate(x, y, w, mask, |z, yi| {
            let p = sigmoid(z);
            (p - yi, softplus(z) - yi * z)
        })
    }

    fn lipschitz(&self, x: &RowMat, rng: &mut Rng) -> f64 {
        // `L = ‖X‖₂²/4` (σ′ ≤ 1/4).
        let s = crate::optim::lipschitz::gram_spectral_norm(x, 100, rng);
        0.25 * s * s
    }
}

/// Numerically-stable logistic sigmoid `1/(1+e^{−z})`.
#[inline]
pub fn sigmoid(z: f64) -> f64 {
    if z >= 0.0 {
        1.0 / (1.0 + (-z).exp())
    } else {
        let e = z.exp();
        e / (1.0 + e)
    }
}

/// Numerically-stable `log(1+e^z)`.
#[inline]
pub fn softplus(z: f64) -> f64 {
    z.max(0.0) + (-z.abs()).exp().ln_1p()
}

/// Row-major matrix for per-task data (`x_t`): rows are samples, which is
/// the natural iteration order for gradient accumulation and matches the
/// PJRT artifact input layout (row-major f32).
#[derive(Clone, Debug)]
pub struct RowMat {
    /// Number of rows (samples).
    pub rows: usize,
    /// Number of columns (features).
    pub cols: usize,
    /// Row-major backing storage (`data[i * cols + j]`).
    pub data: Vec<f64>,
}

impl RowMat {
    /// All-zeros matrix.
    pub fn zeros(rows: usize, cols: usize) -> RowMat {
        RowMat { rows, cols, data: vec![0.0; rows * cols] }
    }

    #[inline]
    /// Contiguous view of row `i` (one sample).
    pub fn row(&self, i: usize) -> &[f64] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    #[inline]
    /// Mutable view of row `i`.
    pub fn row_mut(&mut self, i: usize) -> &mut [f64] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Downcast to the PJRT artifact dtype (row-major f32).
    pub fn as_f32(&self) -> Vec<f32> {
        self.data.iter().map(|&x| x as f32).collect()
    }

    /// Spectral norm of `X` via power iteration (for Lipschitz constants).
    pub fn spectral_norm(&self, iters: usize, rng: &mut crate::util::Rng) -> f64 {
        let mut v = rng.normal_vec(self.cols);
        let mut sigma = 0.0;
        for _ in 0..iters {
            // u = X v
            let mut u = vec![0.0; self.rows];
            for i in 0..self.rows {
                u[i] = self.row(i).iter().zip(&v).map(|(a, b)| a * b).sum();
            }
            // v = Xᵀ u
            let mut xtv = vec![0.0; self.cols];
            for i in 0..self.rows {
                let ui = u[i];
                if ui != 0.0 {
                    for (k, a) in self.row(i).iter().enumerate() {
                        xtv[k] += a * ui;
                    }
                }
            }
            let nrm = crate::linalg::nrm2(&xtv);
            if nrm == 0.0 {
                return 0.0;
            }
            for (vi, xi) in v.iter_mut().zip(&xtv) {
                *vi = xi / nrm;
            }
            sigma = nrm.sqrt();
        }
        sigma
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn make(n: usize, d: usize, seed: u64) -> (RowMat, Vec<f64>, Vec<f64>, Vec<f64>) {
        let mut rng = Rng::new(seed);
        let mut x = RowMat::zeros(n, d);
        for v in x.data.iter_mut() {
            *v = rng.normal();
        }
        let y = rng.normal_vec(n);
        let w = rng.normal_vec(d);
        let mask = vec![1.0; n];
        (x, y, w, mask)
    }

    #[test]
    fn loss_parse_names_and_errors() {
        assert_eq!(Loss::parse("squared").unwrap(), Loss::Squared);
        assert_eq!(Loss::parse("lsq").unwrap(), Loss::Squared);
        assert_eq!(Loss::parse("logreg").unwrap(), Loss::Logistic);
        assert_eq!(Loss::Logistic.name(), "logistic");
        let err = Loss::parse("hinge").unwrap_err();
        assert!(format!("{err}").contains("squared|logistic"), "{err}");
    }

    #[test]
    fn enum_delegates_to_trait_impls() {
        let (x, y, w, mask) = make(10, 4, 29);
        let (ge, oe) = Loss::Squared.grad_obj(&x, &y, &w, &mask);
        let (gt, ot) = LeastSquares.grad_obj(&x, &y, &w, &mask);
        assert_eq!(ge, gt);
        assert_eq!(oe, ot);
        assert_eq!(Loss::Squared.step_op(), "lsq_step");
        assert_eq!(Loss::Logistic.task_loss().name(), "logistic");
    }

    #[test]
    fn squared_grad_matches_finite_differences() {
        let (x, y, w, mask) = make(20, 5, 30);
        let loss = Loss::Squared;
        let (g, _) = loss.grad_obj(&x, &y, &w, &mask);
        let h = 1e-6;
        for k in 0..5 {
            let mut wp = w.clone();
            wp[k] += h;
            let mut wm = w.clone();
            wm[k] -= h;
            let fd = (loss.obj(&x, &y, &wp, &mask) - loss.obj(&x, &y, &wm, &mask)) / (2.0 * h);
            assert!((g[k] - fd).abs() < 1e-4, "k={k}: {} vs {}", g[k], fd);
        }
    }

    #[test]
    fn logistic_grad_matches_finite_differences() {
        let (x, _, w, mask) = make(20, 5, 31);
        let mut rng = Rng::new(99);
        let y: Vec<f64> = (0..20).map(|_| if rng.bool(0.5) { 1.0 } else { 0.0 }).collect();
        let loss = Loss::Logistic;
        let (g, _) = loss.grad_obj(&x, &y, &w, &mask);
        let h = 1e-6;
        for k in 0..5 {
            let mut wp = w.clone();
            wp[k] += h;
            let mut wm = w.clone();
            wm[k] -= h;
            let fd = (loss.obj(&x, &y, &wp, &mask) - loss.obj(&x, &y, &wm, &mask)) / (2.0 * h);
            assert!((g[k] - fd).abs() < 1e-4);
        }
    }

    #[test]
    fn mask_zero_rows_do_not_contribute() {
        let (x, y, w, _) = make(10, 3, 32);
        let mut mask = vec![1.0; 10];
        mask[3] = 0.0;
        mask[7] = 0.0;
        let (g_masked, o_masked) = Loss::Squared.grad_obj(&x, &y, &w, &mask);
        // Build the reduced problem without rows 3 and 7.
        let keep: Vec<usize> = (0..10).filter(|i| !matches!(i, 3 | 7)).collect();
        let mut xr = RowMat::zeros(8, 3);
        let mut yr = vec![0.0; 8];
        for (new_i, &old_i) in keep.iter().enumerate() {
            xr.row_mut(new_i).copy_from_slice(x.row(old_i));
            yr[new_i] = y[old_i];
        }
        let (g_red, o_red) = Loss::Squared.grad_obj(&xr, &yr, &w, &vec![1.0; 8]);
        for k in 0..3 {
            assert!((g_masked[k] - g_red[k]).abs() < 1e-12);
        }
        assert!((o_masked - o_red).abs() < 1e-12);
    }

    #[test]
    fn squared_obj_zero_at_consistent_solution() {
        let mut rng = Rng::new(33);
        let mut x = RowMat::zeros(15, 4);
        for v in x.data.iter_mut() {
            *v = rng.normal();
        }
        let w = rng.normal_vec(4);
        let y: Vec<f64> = (0..15)
            .map(|i| x.row(i).iter().zip(&w).map(|(a, b)| a * b).sum())
            .collect();
        let mask = vec![1.0; 15];
        assert!(Loss::Squared.obj(&x, &y, &w, &mask) < 1e-20);
        let (g, _) = Loss::Squared.grad_obj(&x, &y, &w, &mask);
        assert!(g.iter().all(|v| v.abs() < 1e-10));
    }

    #[test]
    fn sigmoid_softplus_stable_at_extremes() {
        assert!((sigmoid(1000.0) - 1.0).abs() < 1e-12);
        assert!(sigmoid(-1000.0).abs() < 1e-12);
        assert!(softplus(1000.0).is_finite());
        assert!((softplus(1000.0) - 1000.0).abs() < 1e-9);
        assert!(softplus(-1000.0).abs() < 1e-12);
        assert!((sigmoid(0.0) - 0.5).abs() < 1e-15);
    }

    #[test]
    fn step_reduces_objective_with_safe_eta() {
        let (x, y, w, mask) = make(50, 8, 34);
        let mut rng = Rng::new(35);
        let lip = 2.0 * x.spectral_norm(100, &mut rng).powi(2);
        let eta = 1.0 / lip;
        let (u, o0) = Loss::Squared.step(&x, &y, &w, &mask, eta);
        let o1 = Loss::Squared.obj(&x, &y, &u, &mask);
        assert!(o1 <= o0 + 1e-12, "{o1} > {o0}");
    }

    #[test]
    fn logistic_obj_nonnegative() {
        let (x, _, w, mask) = make(30, 6, 36);
        let y: Vec<f64> = (0..30).map(|i| (i % 2) as f64).collect();
        assert!(Loss::Logistic.obj(&x, &y, &w, &mask) >= 0.0);
    }

    #[test]
    fn rowmat_spectral_norm_matches_mat() {
        let mut rng = Rng::new(37);
        let mut x = RowMat::zeros(12, 5);
        for v in x.data.iter_mut() {
            *v = rng.normal();
        }
        let m = crate::linalg::Mat::from_fn(12, 5, |r, c| x.row(r)[c]);
        let a = x.spectral_norm(200, &mut rng);
        let b = m.spectral_norm(200, &mut rng);
        assert!((a - b).abs() / a < 1e-4);
    }
}
