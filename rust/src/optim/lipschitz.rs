//! Lipschitz-constant estimation and step-size selection.
//!
//! Theorem 1 requires `η ∈ (0, 2/L)` for the forward operator to be
//! non-expansive, and the KM relaxation `η_k ∈ [η_min, c/(2τ/√T + 1)]`.
//! `L` for the joint smooth loss `f(W) = Σ_t ℓ_t(w_t)` is the max of the
//! per-task constants (block-separable f ⇒ block-diagonal Hessian).

use crate::linalg::Mat;
use crate::optim::losses::{Loss, RowMat};
use crate::util::Rng;

/// Per-task Lipschitz constant of `∇ℓ_t`, delegated to the loss's
/// [`TaskLoss`](crate::optim::formulation::TaskLoss) impl:
///
/// * squared loss `Σ(x·w−y)²`: `L_t = 2‖X‖₂²`
/// * logistic loss: `L_t = ‖X‖₂²/4` (σ′ ≤ 1/4)
pub fn task_lipschitz(loss: Loss, x: &RowMat, rng: &mut Rng) -> f64 {
    loss.task_loss().lipschitz(x, rng)
}

/// `‖X‖₂` via power iteration on the Gram matrix `G = XᵀX` (the kernel
/// behind every registered loss's `lipschitz` hook).
///
/// `G` is built once through the pooled [`Mat::gram`] kernel, then the
/// iteration runs on the small `d × d` product: `O(n·d²) + O(iters·d²)`
/// instead of `O(iters·n·d)` for the matvec/tmatvec form, and the Gram
/// build parallelizes across the linalg worker pool. Same fixed point as
/// iterating `Xᵀ(Xv)` directly — that product *is* `Gv` — up to
/// floating-point association.
pub(crate) fn gram_spectral_norm(x: &RowMat, iters: usize, rng: &mut Rng) -> f64 {
    if x.rows == 0 || x.cols == 0 {
        return 0.0;
    }
    // Column-major copy of the row-major task data.
    let xm = Mat::from_fn(x.rows, x.cols, |r, c| x.data[r * x.cols + c]);
    let g = xm.gram();
    let mut v = rng.normal_vec(x.cols);
    let mut sigma = 0.0;
    for _ in 0..iters {
        let gv = g.matvec(&v);
        let nrm = crate::linalg::nrm2(&gv);
        if nrm == 0.0 {
            return 0.0;
        }
        for (vi, gi) in v.iter_mut().zip(&gv) {
            *vi = gi / nrm;
        }
        sigma = nrm.sqrt();
    }
    sigma
}

/// Forward step size `η = scale · 2/L` with `scale ∈ (0,1)` for safety.
pub fn forward_step_size(l_max: f64, scale: f64) -> f64 {
    assert!(l_max > 0.0, "Lipschitz constant must be positive");
    assert!((0.0..1.0).contains(&scale));
    scale * 2.0 / l_max
}

/// The KM relaxation upper bound of Theorem 1: `c / (2τ/√T + 1)`.
///
/// `tau` is the maximum delay measured in *update counts*, `t` the number of
/// tasks, and `c ∈ (0,1)`.
pub fn km_step_bound(c: f64, tau: f64, t: usize) -> f64 {
    assert!((0.0..1.0).contains(&c) && c > 0.0);
    c / (2.0 * tau / (t as f64).sqrt() + 1.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn random_x(n: usize, d: usize, seed: u64) -> RowMat {
        let mut rng = Rng::new(seed);
        let mut x = RowMat::zeros(n, d);
        for v in x.data.iter_mut() {
            *v = rng.normal();
        }
        x
    }

    #[test]
    fn squared_descent_lemma_holds_at_estimated_l() {
        // ℓ(u) ≤ ℓ(w) + ∇ℓ(w)·(u−w) + L/2 ‖u−w‖² for random pairs.
        let x = random_x(30, 6, 40);
        let mut rng = Rng::new(41);
        let y = rng.normal_vec(30);
        let mask = vec![1.0; 30];
        let l = task_lipschitz(Loss::Squared, &x, &mut rng) * 1.001;
        for _ in 0..20 {
            let w = rng.normal_vec(6);
            let u = rng.normal_vec(6);
            let (g, fw) = Loss::Squared.grad_obj(&x, &y, &w, &mask);
            let fu = Loss::Squared.obj(&x, &y, &u, &mask);
            let lin: f64 = g.iter().zip(u.iter().zip(&w)).map(|(gi, (ui, wi))| gi * (ui - wi)).sum();
            let quad: f64 = u.iter().zip(&w).map(|(ui, wi)| (ui - wi) * (ui - wi)).sum();
            assert!(fu <= fw + lin + 0.5 * l * quad + 1e-8);
        }
    }

    #[test]
    fn logistic_descent_lemma_holds() {
        let x = random_x(25, 4, 42);
        let mut rng = Rng::new(43);
        let y: Vec<f64> = (0..25).map(|i| (i % 2) as f64).collect();
        let mask = vec![1.0; 25];
        let l = task_lipschitz(Loss::Logistic, &x, &mut rng) * 1.001;
        for _ in 0..20 {
            let w = rng.normal_vec(4);
            let u = rng.normal_vec(4);
            let (g, fw) = Loss::Logistic.grad_obj(&x, &y, &w, &mask);
            let fu = Loss::Logistic.obj(&x, &y, &u, &mask);
            let lin: f64 = g.iter().zip(u.iter().zip(&w)).map(|(gi, (ui, wi))| gi * (ui - wi)).sum();
            let quad: f64 = u.iter().zip(&w).map(|(ui, wi)| (ui - wi) * (ui - wi)).sum();
            assert!(fu <= fw + lin + 0.5 * l * quad + 1e-8);
        }
    }

    #[test]
    fn km_bound_decreases_with_delay_increases_with_tasks() {
        let b0 = km_step_bound(0.9, 0.0, 10);
        let b1 = km_step_bound(0.9, 5.0, 10);
        let b2 = km_step_bound(0.9, 5.0, 100);
        assert!(b0 > b1, "delay should shrink the bound");
        assert!(b2 > b1, "more tasks should relax the bound");
        assert!((b0 - 0.9).abs() < 1e-12, "zero delay bound is c");
    }

    #[test]
    #[should_panic]
    fn forward_step_rejects_zero_l() {
        forward_step_size(0.0, 0.5);
    }
}
