//! Proximal operators for regularized MTL (the server's backward step,
//! Eq. III.3), plus the regularizer values used for objective reporting.
//!
//! Supported couplings — the formulations named in §III.A of the paper:
//!
//! * [`RegularizerKind::Nuclear`] — shared-subspace / low-rank MTL,
//!   `g(W) = ‖W‖_*`; prox = singular-value thresholding (Eq. IV.2).
//! * [`RegularizerKind::L21`] — joint feature selection, `g(W) = ‖W‖_{2,1}`;
//!   prox = row-wise group soft-threshold.
//! * [`RegularizerKind::L1`] — elementwise sparsity (Lasso-style).
//! * [`RegularizerKind::ElasticNet`] — `‖W‖₁ + (γ/2)‖W‖²_F`, the strongly
//!   convex variant the paper invokes for linear convergence (Remark after
//!   Theorem 1).
//! * [`RegularizerKind::None`] — decoupled single-task learning baseline.

use crate::linalg::Mat;
use crate::optim::svd::{OnlineSvd, Svd};

/// Which coupling regularizer `g(W)` the problem uses.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RegularizerKind {
    /// Shared-subspace / low-rank MTL: `g(W) = ‖W‖_*` (SVT prox).
    Nuclear,
    /// Joint feature selection: `g(W) = ‖W‖_{2,1}` (row shrinkage).
    L21,
    /// Elementwise sparsity (Lasso-style soft threshold).
    L1,
    /// `‖W‖₁ + (γ/2)‖W‖²_F` — the strongly convex variant.
    ElasticNet,
    /// No coupling: decoupled single-task learning baseline.
    None,
}

impl RegularizerKind {
    /// Parse a CLI value (`"nuclear"`, `"l21"`, `"l1"`, ...).
    pub fn parse(s: &str) -> Option<RegularizerKind> {
        Some(match s {
            "nuclear" | "trace" | "lowrank" => RegularizerKind::Nuclear,
            "l21" => RegularizerKind::L21,
            "l1" => RegularizerKind::L1,
            "elasticnet" | "en" => RegularizerKind::ElasticNet,
            "none" | "stl" => RegularizerKind::None,
            _ => return None,
        })
    }

    /// Canonical CLI name.
    pub fn name(&self) -> &'static str {
        match self {
            RegularizerKind::Nuclear => "nuclear",
            RegularizerKind::L21 => "l21",
            RegularizerKind::L1 => "l1",
            RegularizerKind::ElasticNet => "elasticnet",
            RegularizerKind::None => "none",
        }
    }
}

/// A regularizer `λ·g(W)` with its prox and value.
#[derive(Clone, Debug)]
pub struct Regularizer {
    /// Which coupling `g` is (nuclear, ℓ2,1, …).
    pub kind: RegularizerKind,
    /// Regularization strength λ.
    pub lambda: f64,
    /// ℓ2 weight for the elastic-net variant.
    pub gamma: f64,
    /// When set, the nuclear prox maintains an incremental factorization
    /// (Brand online SVD) instead of refactorizing; see `svd::OnlineSvd`.
    /// This is the default nuclear path (see `SvdMode`).
    online: Option<OnlineSvd>,
    /// Exact-refresh stride for the online path: after this many column
    /// commits the factorization is rebuilt from an exact Jacobi SVD of
    /// the true matrix, bounding numerical drift. 0 = never refresh.
    resvd_every: u64,
    /// Column commits folded into the factorization since the last exact
    /// refresh.
    commits_since_refresh: u64,
    /// Number of exact refreshes performed.
    refreshes: u64,
    /// Max-abs reconstruction drift observed at the last exact refresh
    /// (`‖UΣVᵀ − W‖_max` just before re-initializing).
    last_drift: f64,
}

impl Regularizer {
    /// A regularizer with strength `lambda` (elastic-net γ defaults to 1).
    pub fn new(kind: RegularizerKind, lambda: f64) -> Regularizer {
        Regularizer {
            kind,
            lambda,
            gamma: 1.0,
            online: None,
            resvd_every: 0,
            commits_since_refresh: 0,
            refreshes: 0,
            last_drift: 0.0,
        }
    }

    /// The strongly convex `‖W‖₁ + (γ/2)‖W‖²_F` variant.
    pub fn elastic_net(lambda: f64, gamma: f64) -> Regularizer {
        let mut reg = Regularizer::new(RegularizerKind::ElasticNet, lambda);
        reg.gamma = gamma;
        reg
    }

    /// Enable the incremental (Brand online-SVD) nuclear prox, seeded from
    /// `w0`. This is the primary nuclear path; pair with
    /// [`Regularizer::with_resvd_every`] to bound drift.
    pub fn with_online_svd(mut self, w0: &Mat) -> Regularizer {
        assert_eq!(self.kind, RegularizerKind::Nuclear);
        self.online = Some(OnlineSvd::init(w0));
        self.commits_since_refresh = 0;
        self
    }

    /// Set the exact-refresh stride for the online path (0 = never): the
    /// factorization is rebuilt from an exact Jacobi SVD every `k` commits
    /// (see [`Regularizer::refresh_online`]). The stride counter advances
    /// via [`Regularizer::note_commits`] — `CentralServer` feeds it raw
    /// commit counts, so commits that coalesce into one fold still count.
    pub fn with_resvd_every(mut self, k: u64) -> Regularizer {
        self.resvd_every = k;
        self
    }

    /// Advance the refresh-stride counter by `n` raw commits. Kept
    /// separate from [`Regularizer::notify_column_update`] because one
    /// fold may represent many coalesced commits, and the drift bound is
    /// promised per commit.
    pub fn note_commits(&mut self, n: u64) {
        if self.online.is_some() {
            self.commits_since_refresh += n;
        }
    }

    /// The incremental nuclear prox `U (Σ − ηλ)₊ Vᵀ`, when the online path
    /// is active (`None` otherwise). Reads only the factorization — the
    /// caller does not need a snapshot of the operand matrix.
    pub fn online_prox(&self, eta: f64) -> Option<Mat> {
        self.online
            .as_ref()
            .map(|osvd| osvd.shrink_reconstruct(eta * self.lambda))
    }

    /// True when the incremental nuclear path is active.
    pub fn uses_online_svd(&self) -> bool {
        self.online.is_some()
    }

    /// The configured exact-refresh stride (0 = never).
    pub fn resvd_every(&self) -> u64 {
        self.resvd_every
    }

    /// Exact refreshes performed so far on the online path.
    pub fn svd_refreshes(&self) -> u64 {
        self.refreshes
    }

    /// Reconstruction drift measured at the most recent exact refresh.
    pub fn svd_drift(&self) -> f64 {
        self.last_drift
    }

    /// Inform the incremental factorization that column `j` of the operand
    /// changed (no-op unless the online path is active). Does not advance
    /// the refresh stride — pair with [`Regularizer::note_commits`].
    pub fn notify_column_update(&mut self, j: usize, col: &[f64]) {
        if let Some(osvd) = self.online.as_mut() {
            osvd.replace_column(j, col);
        }
    }

    /// True when the drift counter says the online factorization is due
    /// for an exact rebuild.
    pub fn needs_refresh(&self) -> bool {
        self.online.is_some()
            && self.resvd_every > 0
            && self.commits_since_refresh >= self.resvd_every
    }

    /// Serialize the regularizer — factorization basis, resvd stride
    /// counter, and drift metrics included — for a persist snapshot.
    pub(crate) fn snapshot_parts(&self) -> crate::persist::RegSnapshot {
        crate::persist::RegSnapshot {
            kind: self.kind,
            lambda: self.lambda,
            gamma: self.gamma,
            resvd_every: self.resvd_every,
            commits_since_refresh: self.commits_since_refresh,
            refreshes: self.refreshes,
            last_drift: self.last_drift,
            online: self.online.as_ref().map(|osvd| crate::persist::SvdFactors {
                u: osvd.u.clone(),
                sigma: osvd.sigma.clone(),
                v: osvd.v.clone(),
            }),
        }
    }

    /// Rebuild a regularizer from a persist snapshot. The restored online
    /// factorization and `commits_since_refresh` counter continue the
    /// original run's resvd stride — resuming does not reset the drift
    /// bound.
    pub(crate) fn from_snapshot(rs: &crate::persist::RegSnapshot) -> Regularizer {
        Regularizer {
            kind: rs.kind,
            lambda: rs.lambda,
            gamma: rs.gamma,
            online: rs.online.as_ref().map(|f| OnlineSvd {
                u: f.u.clone(),
                sigma: f.sigma.clone(),
                v: f.v.clone(),
            }),
            resvd_every: rs.resvd_every,
            commits_since_refresh: rs.commits_since_refresh,
            refreshes: rs.refreshes,
            last_drift: rs.last_drift,
        }
    }

    /// Rebuild the online factorization from an exact Jacobi SVD of
    /// `current` (the true matrix), recording the drift the incremental
    /// path had accumulated. No-op unless the online path is active.
    pub fn refresh_online(&mut self, current: &Mat) {
        if let Some(osvd) = self.online.as_ref() {
            self.last_drift = osvd.reconstruct().max_abs_diff(current);
            self.online = Some(OnlineSvd::init(current));
            self.refreshes += 1;
            self.commits_since_refresh = 0;
        }
    }

    /// `Prox_{η λ g}(W)`, overwriting `w`. `eta` is the prox step size.
    pub fn prox(&mut self, w: &mut Mat, eta: f64) {
        let tau = eta * self.lambda;
        match self.kind {
            RegularizerKind::None => {}
            RegularizerKind::Nuclear => {
                let out = if let Some(osvd) = self.online.as_ref() {
                    osvd.shrink_reconstruct(tau)
                } else {
                    Svd::jacobi(w).shrink_reconstruct(tau)
                };
                *w = out;
            }
            RegularizerKind::L21 => prox_l21(w, tau),
            RegularizerKind::L1 => {
                for x in w.data_mut() {
                    *x = soft(*x, tau);
                }
            }
            RegularizerKind::ElasticNet => {
                // prox of τ‖·‖₁ + (τγ/2)‖·‖² = soft(x, τ) / (1 + τγ)
                let scale = 1.0 / (1.0 + tau * self.gamma);
                for x in w.data_mut() {
                    *x = soft(*x, tau) * scale;
                }
            }
        }
    }

    /// `λ·g(W)` for objective reporting.
    pub fn value(&self, w: &Mat) -> f64 {
        match self.kind {
            RegularizerKind::None => 0.0,
            RegularizerKind::Nuclear => self.lambda * Svd::jacobi(w).nuclear_norm(),
            RegularizerKind::L21 => {
                let mut sum = 0.0;
                for r in 0..w.rows() {
                    let mut s = 0.0;
                    for c in 0..w.cols() {
                        let x = w.get(r, c);
                        s += x * x;
                    }
                    sum += s.sqrt();
                }
                self.lambda * sum
            }
            RegularizerKind::L1 => self.lambda * w.data().iter().map(|x| x.abs()).sum::<f64>(),
            RegularizerKind::ElasticNet => {
                let l1: f64 = w.data().iter().map(|x| x.abs()).sum();
                let sq: f64 = w.data().iter().map(|x| x * x).sum();
                self.lambda * (l1 + 0.5 * self.gamma * sq)
            }
        }
    }
}

#[inline]
fn soft(x: f64, tau: f64) -> f64 {
    if x > tau {
        x - tau
    } else if x < -tau {
        x + tau
    } else {
        0.0
    }
}

/// Row-wise group soft-threshold (rust mirror of the `prox_l21` Pallas
/// kernel; the kernel artifact is used when a bucketed shape exists, this
/// native path otherwise — both are tested against each other).
pub fn prox_l21(w: &mut Mat, tau: f64) {
    let (d, t) = (w.rows(), w.cols());
    for r in 0..d {
        let mut nrm = 0.0;
        for c in 0..t {
            let x = w.get(r, c);
            nrm += x * x;
        }
        nrm = nrm.sqrt();
        let scale = if nrm > tau { (nrm - tau) / nrm } else { 0.0 };
        for c in 0..t {
            w.set(r, c, w.get(r, c) * scale);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::forall;
    use crate::util::Rng;

    #[test]
    fn soft_thresholding_cases() {
        assert_eq!(soft(3.0, 1.0), 2.0);
        assert_eq!(soft(-3.0, 1.0), -2.0);
        assert_eq!(soft(0.5, 1.0), 0.0);
        assert_eq!(soft(-0.5, 1.0), 0.0);
        assert_eq!(soft(1.0, 1.0), 0.0);
    }

    #[test]
    fn nuclear_prox_thresholds_singular_values() {
        let mut rng = Rng::new(20);
        let a = Mat::randn(8, 5, &mut rng);
        let before = Svd::jacobi(&a);
        let tau = before.sigma[2];
        let mut w = a.clone();
        Regularizer::new(RegularizerKind::Nuclear, 1.0).prox(&mut w, tau);
        let after = Svd::jacobi(&w);
        for (i, s) in after.sigma.iter().enumerate() {
            let want = (before.sigma[i] - tau).max(0.0);
            assert!((s - want).abs() < 1e-9);
        }
    }

    #[test]
    fn nuclear_prox_zero_tau_is_identity() {
        let mut rng = Rng::new(21);
        let a = Mat::randn(6, 4, &mut rng);
        let mut w = a.clone();
        Regularizer::new(RegularizerKind::Nuclear, 0.0).prox(&mut w, 0.1);
        assert!(w.max_abs_diff(&a) < 1e-9);
    }

    #[test]
    fn l21_prox_matches_row_norm_shrinkage() {
        let mut rng = Rng::new(22);
        let a = Mat::randn(10, 4, &mut rng);
        let mut w = a.clone();
        prox_l21(&mut w, 0.8);
        for r in 0..10 {
            let before: f64 = (0..4).map(|c| a.get(r, c).powi(2)).sum::<f64>().sqrt();
            let after: f64 = (0..4).map(|c| w.get(r, c).powi(2)).sum::<f64>().sqrt();
            assert!((after - (before - 0.8).max(0.0)).abs() < 1e-12);
        }
    }

    #[test]
    fn l1_prox_is_elementwise_soft() {
        let mut w = Mat::from_cols(2, vec![vec![2.0, -0.1], vec![-3.0, 0.4]]);
        Regularizer::new(RegularizerKind::L1, 0.5).prox(&mut w, 1.0);
        assert_eq!(w.get(0, 0), 1.5);
        assert_eq!(w.get(1, 0), 0.0);
        assert_eq!(w.get(0, 1), -2.5);
        assert_eq!(w.get(1, 1), 0.0);
    }

    #[test]
    fn elastic_net_prox_shrinks_more_than_l1() {
        let mut rng = Rng::new(23);
        let a = Mat::randn(6, 3, &mut rng);
        let mut l1 = a.clone();
        Regularizer::new(RegularizerKind::L1, 0.3).prox(&mut l1, 1.0);
        let mut en = a.clone();
        Regularizer::elastic_net(0.3, 2.0).prox(&mut en, 1.0);
        assert!(en.frobenius_norm() <= l1.frobenius_norm() + 1e-12);
    }

    #[test]
    fn none_prox_is_identity_and_zero_value() {
        let mut rng = Rng::new(24);
        let a = Mat::randn(5, 5, &mut rng);
        let mut w = a.clone();
        let mut reg = Regularizer::new(RegularizerKind::None, 3.0);
        reg.prox(&mut w, 0.7);
        assert_eq!(w, a);
        assert_eq!(reg.value(&a), 0.0);
    }

    #[test]
    fn values_match_definitions() {
        let w = Mat::from_cols(2, vec![vec![3.0, 0.0], vec![0.0, 4.0]]); // diag(3,4)
        assert!((Regularizer::new(RegularizerKind::Nuclear, 2.0).value(&w) - 14.0).abs() < 1e-9);
        assert!((Regularizer::new(RegularizerKind::L21, 1.0).value(&w) - 7.0).abs() < 1e-12);
        assert!((Regularizer::new(RegularizerKind::L1, 1.0).value(&w) - 7.0).abs() < 1e-12);
    }

    #[test]
    fn online_svd_prox_matches_full_prox() {
        let mut rng = Rng::new(25);
        let mut a = Mat::randn(12, 5, &mut rng);
        let mut full = Regularizer::new(RegularizerKind::Nuclear, 0.4);
        let mut online = Regularizer::new(RegularizerKind::Nuclear, 0.4).with_online_svd(&a);
        for step in 0..6 {
            let j = step % 5;
            let col = rng.normal_vec(12);
            a.set_col(j, &col);
            online.notify_column_update(j, &col);
            let mut w_full = a.clone();
            full.prox(&mut w_full, 0.5);
            let mut w_online = a.clone();
            online.prox(&mut w_online, 0.5);
            assert!(
                w_full.max_abs_diff(&w_online) < 1e-7,
                "step {step}: {}",
                w_full.max_abs_diff(&w_online)
            );
        }
    }

    #[test]
    fn resvd_refresh_bounds_drift_and_tracks_exact() {
        let mut rng = Rng::new(26);
        let mut a = Mat::randn(10, 6, &mut rng);
        let mut reg = Regularizer::new(RegularizerKind::Nuclear, 0.3)
            .with_online_svd(&a)
            .with_resvd_every(4);
        let mut refreshes = 0;
        for step in 0..20 {
            let j = step % 6;
            let col = rng.normal_vec(10);
            a.set_col(j, &col);
            reg.notify_column_update(j, &col);
            reg.note_commits(1);
            if reg.needs_refresh() {
                reg.refresh_online(&a);
                refreshes += 1;
                assert!(reg.svd_drift() < 1e-8, "refresh drift {}", reg.svd_drift());
            }
            let mut w_online = a.clone();
            reg.prox(&mut w_online, 0.5);
            let mut w_exact = a.clone();
            Regularizer::new(RegularizerKind::Nuclear, 0.3).prox(&mut w_exact, 0.5);
            assert!(
                w_online.max_abs_diff(&w_exact) < 1e-7,
                "step {step}: online prox drifted {}",
                w_online.max_abs_diff(&w_exact)
            );
        }
        assert_eq!(refreshes, 5, "20 commits / resvd_every=4");
        assert_eq!(reg.svd_refreshes(), 5);
        assert_eq!(reg.resvd_every(), 4);
    }

    #[test]
    fn prop_all_proxes_nonexpansive() {
        // Non-expansiveness of the backward operator underpins Theorem 1.
        for kind in [
            RegularizerKind::Nuclear,
            RegularizerKind::L21,
            RegularizerKind::L1,
            RegularizerKind::ElasticNet,
        ] {
            forall(
                &format!("prox {:?} nonexpansive", kind),
                30,
                |g| {
                    let a = g.normal_vec(12);
                    let b = g.normal_vec(12);
                    (a, b)
                },
                |(a, b)| {
                    let ma = Mat::from_cols(4, a.chunks(4).map(|c| c.to_vec()).collect());
                    let mb = Mat::from_cols(4, b.chunks(4).map(|c| c.to_vec()).collect());
                    let dist_before = ma.add_scaled(-1.0, &mb).frobenius_norm();
                    let mut pa = ma.clone();
                    let mut pb = mb.clone();
                    let mut reg = Regularizer::new(kind, 0.5);
                    reg.prox(&mut pa, 0.7);
                    reg.prox(&mut pb, 0.7);
                    let dist_after = pa.add_scaled(-1.0, &pb).frobenius_norm();
                    dist_after <= dist_before + 1e-9
                },
            );
        }
    }

    #[test]
    fn prop_prox_decreases_moreau_envelope_objective() {
        // prox(v) minimizes ½‖w−v‖² + τ·g(w): value at prox(v) ≤ value at v.
        forall(
            "prox optimality (l21)",
            40,
            |g| g.normal_vec(20),
            |v| {
                let m = Mat::from_cols(5, v.chunks(5).map(|c| c.to_vec()).collect());
                let mut p = m.clone();
                let mut reg = Regularizer::new(RegularizerKind::L21, 1.0);
                let tau = 0.6;
                reg.prox(&mut p, tau);
                let lhs = 0.5 * p.add_scaled(-1.0, &m).frobenius_norm().powi(2)
                    + tau * reg.value(&p) / reg.lambda;
                let rhs = tau * reg.value(&m) / reg.lambda;
                lhs <= rhs + 1e-9
            },
        );
    }
}
