//! The classic proximable regularizers of §III.A, as
//! [`SharedProx`](crate::optim::formulation::SharedProx) impls (the
//! server's backward step, Eq. III.3), plus the regularizer values used
//! for objective reporting.
//!
//! * [`NuclearProx`] — shared-subspace / low-rank MTL, `g(W) = ‖W‖_*`;
//!   prox = singular-value thresholding (Eq. IV.2), with the Brand
//!   online-SVD incremental path behind the trait's incremental hooks.
//! * [`L21Prox`] — joint feature selection, `g(W) = ‖W‖_{2,1}`; prox =
//!   row-wise group soft-threshold.
//! * [`L1Prox`] — elementwise sparsity (Lasso-style).
//! * [`ElasticNetProx`] — `‖W‖₁ + (γ/2)‖W‖²_F`, the strongly convex
//!   variant the paper invokes for linear convergence (Remark after
//!   Theorem 1).
//! * [`ZeroProx`] — no coupling: decoupled single-task learning baseline.
//!
//! The graph-Laplacian and mean-regularized formulations live in
//! [`coupling`](crate::optim::coupling); all are registered in
//! [`formulation`](crate::optim::formulation) and reachable by name.

use crate::linalg::Mat;
use crate::optim::formulation::{push_mat, read_f64s, read_mat, SharedProx};
use crate::optim::svd::{OnlineSvd, Svd};
use crate::transport::wire::{push_f64s, Cursor, WireError};
use crate::util::EnumTable;

/// Name table for [`RegularizerKind`] (classic formulations only; the
/// full open set is [`formulation::FORMULATIONS`](crate::optim::formulation::FORMULATIONS)).
const KINDS: EnumTable<RegularizerKind> = EnumTable {
    what: "--reg value",
    rows: &[
        ("nuclear", &["trace", "lowrank"], RegularizerKind::Nuclear),
        ("l21", &[], RegularizerKind::L21),
        ("l1", &[], RegularizerKind::L1),
        ("elasticnet", &["en"], RegularizerKind::ElasticNet),
        ("none", &["stl"], RegularizerKind::None),
    ],
};

/// Which *classic* coupling regularizer `g(W)` a problem uses — shorthand
/// for the five formulations of §III.A. The open set (graph, mean, and
/// anything registered later) is addressed by name through
/// [`FormulationSpec`](crate::optim::formulation::FormulationSpec);
/// `RegularizerKind` converts into a spec via `From`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RegularizerKind {
    /// Shared-subspace / low-rank MTL: `g(W) = ‖W‖_*` (SVT prox).
    Nuclear,
    /// Joint feature selection: `g(W) = ‖W‖_{2,1}` (row shrinkage).
    L21,
    /// Elementwise sparsity (Lasso-style soft threshold).
    L1,
    /// `‖W‖₁ + (γ/2)‖W‖²_F` — the strongly convex variant.
    ElasticNet,
    /// No coupling: decoupled single-task learning baseline.
    None,
}

impl RegularizerKind {
    /// Parse a CLI value (`"nuclear"`, `"l21"`, `"l1"`, ...); the error
    /// lists the valid values.
    pub fn parse(s: &str) -> anyhow::Result<RegularizerKind> {
        KINDS.parse(s)
    }

    /// Canonical CLI name.
    pub fn name(&self) -> &'static str {
        KINDS.name(*self)
    }
}

/// Factory for the classic regularizers: the closed-enum constructor the
/// open [`SharedProx`] API replaced, kept as the idiomatic way to build
/// one of the five §III.A couplings directly.
pub struct Regularizer;

impl Regularizer {
    /// A classic regularizer with strength `lambda` (elastic-net γ = 1).
    pub fn new(kind: RegularizerKind, lambda: f64) -> Box<dyn SharedProx> {
        match kind {
            RegularizerKind::Nuclear => Box::new(NuclearProx::new(lambda)),
            RegularizerKind::L21 => Box::new(L21Prox::new(lambda)),
            RegularizerKind::L1 => Box::new(L1Prox::new(lambda)),
            RegularizerKind::ElasticNet => Box::new(ElasticNetProx::new(lambda, 1.0)),
            RegularizerKind::None => Box::new(ZeroProx::new(lambda)),
        }
    }

    /// The strongly convex `‖W‖₁ + (γ/2)‖W‖²_F` variant.
    pub fn elastic_net(lambda: f64, gamma: f64) -> Box<dyn SharedProx> {
        Box::new(ElasticNetProx::new(lambda, gamma))
    }
}

// ---------------------------------------------------------------- nuclear

/// Low-rank coupling `g(W) = ‖W‖_*`: prox is singular-value thresholding,
/// either over an exact Jacobi SVD of the operand or — when the
/// incremental path is enabled — over a maintained Brand online-SVD
/// factorization re-anchored every `resvd_every` commits.
#[derive(Clone, Debug)]
pub struct NuclearProx {
    lambda: f64,
    /// The incremental factorization, when the online path is active.
    online: Option<OnlineSvd>,
    /// Exact-refresh stride for the online path (0 = never refresh).
    resvd_every: u64,
    /// Column commits folded since the last exact refresh.
    commits_since_refresh: u64,
    /// Exact refreshes performed.
    refreshes: u64,
    /// Max-abs reconstruction drift observed at the last exact refresh
    /// (`‖UΣVᵀ − W‖_max` just before re-initializing).
    last_drift: f64,
}

impl NuclearProx {
    /// A nuclear-norm regularizer with strength `lambda` (exact path
    /// until [`SharedProx::enable_incremental`] is called).
    pub fn new(lambda: f64) -> NuclearProx {
        NuclearProx {
            lambda,
            online: None,
            resvd_every: 0,
            commits_since_refresh: 0,
            refreshes: 0,
            last_drift: 0.0,
        }
    }

    /// Builder form of the incremental path, seeded from `w0`.
    pub fn with_online(mut self, w0: &Mat) -> NuclearProx {
        self.online = Some(OnlineSvd::init(w0));
        self.commits_since_refresh = 0;
        self
    }

    /// Builder form of the exact-refresh stride (0 = never).
    pub fn with_resvd_every(mut self, k: u64) -> NuclearProx {
        self.resvd_every = k;
        self
    }

    /// Serialize nuclear-prox state from explicit parts. Shared by
    /// [`SharedProx::state_save`] and the persist layer's v1-snapshot
    /// migration, so the two encodings cannot drift apart.
    pub(crate) fn encode_state_parts(
        lambda: f64,
        resvd_every: u64,
        commits_since_refresh: u64,
        refreshes: u64,
        last_drift: f64,
        online: Option<(&Mat, &[f64], &Mat)>,
    ) -> Vec<u8> {
        let mut out = Vec::with_capacity(64);
        out.extend_from_slice(&lambda.to_bits().to_le_bytes());
        out.extend_from_slice(&resvd_every.to_le_bytes());
        out.extend_from_slice(&commits_since_refresh.to_le_bytes());
        out.extend_from_slice(&refreshes.to_le_bytes());
        out.extend_from_slice(&last_drift.to_bits().to_le_bytes());
        match online {
            None => out.push(0),
            Some((u, sigma, v)) => {
                out.push(1);
                push_mat(&mut out, u);
                out.extend_from_slice(&(sigma.len() as u32).to_le_bytes());
                push_f64s(&mut out, sigma);
                push_mat(&mut out, v);
            }
        }
        out
    }
}

impl SharedProx for NuclearProx {
    fn id(&self) -> &'static str {
        "nuclear"
    }

    fn lambda(&self) -> f64 {
        self.lambda
    }

    fn prox(&mut self, w: &mut Mat, eta: f64) {
        let tau = eta * self.lambda;
        let out = if let Some(osvd) = self.online.as_ref() {
            osvd.shrink_reconstruct(tau)
        } else {
            Svd::jacobi(w).shrink_reconstruct(tau)
        };
        *w = out;
    }

    fn value(&self, w: &Mat) -> f64 {
        self.lambda * Svd::jacobi(w).nuclear_norm()
    }

    fn clone_box(&self) -> Box<dyn SharedProx> {
        Box::new(self.clone())
    }

    fn enable_incremental(&mut self, w0: &Mat, refresh_every: u64) {
        self.online = Some(OnlineSvd::init(w0));
        self.resvd_every = refresh_every;
        self.commits_since_refresh = 0;
    }

    fn is_incremental(&self) -> bool {
        self.online.is_some()
    }

    fn notify_column_update(&mut self, j: usize, col: &[f64]) {
        if let Some(osvd) = self.online.as_mut() {
            osvd.replace_column(j, col);
        }
    }

    fn note_commits(&mut self, n: u64) {
        if self.online.is_some() {
            self.commits_since_refresh += n;
        }
    }

    fn online_prox(&self, eta: f64) -> Option<Mat> {
        self.online
            .as_ref()
            .map(|osvd| osvd.shrink_reconstruct(eta * self.lambda))
    }

    fn needs_refresh(&self) -> bool {
        self.online.is_some()
            && self.resvd_every > 0
            && self.commits_since_refresh >= self.resvd_every
    }

    fn refresh(&mut self, current: &Mat) {
        if let Some(osvd) = self.online.as_ref() {
            self.last_drift = osvd.reconstruct().max_abs_diff(current);
            self.online = Some(OnlineSvd::init(current));
            self.refreshes += 1;
            self.commits_since_refresh = 0;
        }
    }

    fn refresh_count(&self) -> u64 {
        self.refreshes
    }

    fn refresh_drift(&self) -> f64 {
        self.last_drift
    }

    fn state_save(&self) -> Vec<u8> {
        NuclearProx::encode_state_parts(
            self.lambda,
            self.resvd_every,
            self.commits_since_refresh,
            self.refreshes,
            self.last_drift,
            self.online.as_ref().map(|o| (&o.u, o.sigma.as_slice(), &o.v)),
        )
    }

    fn state_load(&mut self, bytes: &[u8]) -> anyhow::Result<()> {
        let mut c = Cursor::new(bytes);
        self.lambda = c.f64()?;
        self.resvd_every = c.u64()?;
        self.commits_since_refresh = c.u64()?;
        self.refreshes = c.u64()?;
        self.last_drift = c.f64()?;
        self.online = match c.u8()? {
            0 => None,
            1 => {
                let u = read_mat(&mut c)?;
                let k = c.u32()? as usize;
                let sigma = read_f64s(&mut c, k)?;
                let v = read_mat(&mut c)?;
                if u.cols() != k || v.cols() != k {
                    return Err(WireError::Malformed(
                        "nuclear factor dimensions inconsistent",
                    )
                    .into());
                }
                Some(OnlineSvd { u, sigma, v })
            }
            _ => return Err(WireError::Malformed("nuclear online flag not 0/1").into()),
        };
        c.finish()?;
        Ok(())
    }
}

// ------------------------------------------------------- l21 / l1 / en / 0

/// Joint feature selection `g(W) = ‖W‖_{2,1}` (row-wise group shrinkage).
///
/// Not column-separable (`is_separable` stays false): each row's group
/// norm spans all T columns, so the shrink factor of any entry depends on
/// every column — a column-range shard cannot prox its slice alone.
#[derive(Clone, Debug)]
pub struct L21Prox {
    lambda: f64,
}

impl L21Prox {
    /// An ℓ2,1 regularizer with strength `lambda`.
    pub fn new(lambda: f64) -> L21Prox {
        L21Prox { lambda }
    }
}

impl SharedProx for L21Prox {
    fn id(&self) -> &'static str {
        "l21"
    }

    fn lambda(&self) -> f64 {
        self.lambda
    }

    fn prox(&mut self, w: &mut Mat, eta: f64) {
        prox_l21(w, eta * self.lambda);
    }

    fn value(&self, w: &Mat) -> f64 {
        let mut sum = 0.0;
        for r in 0..w.rows() {
            let mut s = 0.0;
            for c in 0..w.cols() {
                let x = w.get(r, c);
                s += x * x;
            }
            sum += s.sqrt();
        }
        self.lambda * sum
    }

    fn clone_box(&self) -> Box<dyn SharedProx> {
        Box::new(self.clone())
    }

    fn state_save(&self) -> Vec<u8> {
        self.lambda.to_bits().to_le_bytes().to_vec()
    }

    fn state_load(&mut self, bytes: &[u8]) -> anyhow::Result<()> {
        let mut c = Cursor::new(bytes);
        self.lambda = c.f64()?;
        c.finish()?;
        Ok(())
    }
}

/// Elementwise sparsity `g(W) = ‖W‖₁` (soft threshold).
#[derive(Clone, Debug)]
pub struct L1Prox {
    lambda: f64,
}

impl L1Prox {
    /// An ℓ1 regularizer with strength `lambda`.
    pub fn new(lambda: f64) -> L1Prox {
        L1Prox { lambda }
    }
}

impl SharedProx for L1Prox {
    fn id(&self) -> &'static str {
        "l1"
    }

    fn lambda(&self) -> f64 {
        self.lambda
    }

    fn is_separable(&self) -> bool {
        true // elementwise soft threshold: column subsets prox independently
    }

    fn prox(&mut self, w: &mut Mat, eta: f64) {
        let tau = eta * self.lambda;
        for x in w.data_mut() {
            *x = soft(*x, tau);
        }
    }

    fn value(&self, w: &Mat) -> f64 {
        self.lambda * w.data().iter().map(|x| x.abs()).sum::<f64>()
    }

    fn clone_box(&self) -> Box<dyn SharedProx> {
        Box::new(self.clone())
    }

    fn state_save(&self) -> Vec<u8> {
        self.lambda.to_bits().to_le_bytes().to_vec()
    }

    fn state_load(&mut self, bytes: &[u8]) -> anyhow::Result<()> {
        let mut c = Cursor::new(bytes);
        self.lambda = c.f64()?;
        c.finish()?;
        Ok(())
    }
}

/// The strongly convex `‖W‖₁ + (γ/2)‖W‖²_F` variant.
#[derive(Clone, Debug)]
pub struct ElasticNetProx {
    lambda: f64,
    gamma: f64,
}

impl ElasticNetProx {
    /// An elastic-net regularizer with strength `lambda` and ℓ2 weight
    /// `gamma`.
    pub fn new(lambda: f64, gamma: f64) -> ElasticNetProx {
        ElasticNetProx { lambda, gamma }
    }

    /// The ℓ2 weight γ.
    pub fn gamma(&self) -> f64 {
        self.gamma
    }
}

impl SharedProx for ElasticNetProx {
    fn id(&self) -> &'static str {
        "elasticnet"
    }

    fn lambda(&self) -> f64 {
        self.lambda
    }

    fn is_separable(&self) -> bool {
        true // elementwise shrink-and-scale: no cross-column coupling
    }

    fn prox(&mut self, w: &mut Mat, eta: f64) {
        // prox of τ‖·‖₁ + (τγ/2)‖·‖² = soft(x, τ) / (1 + τγ)
        let tau = eta * self.lambda;
        let scale = 1.0 / (1.0 + tau * self.gamma);
        for x in w.data_mut() {
            *x = soft(*x, tau) * scale;
        }
    }

    fn value(&self, w: &Mat) -> f64 {
        let l1: f64 = w.data().iter().map(|x| x.abs()).sum();
        let sq: f64 = w.data().iter().map(|x| x * x).sum();
        self.lambda * (l1 + 0.5 * self.gamma * sq)
    }

    fn clone_box(&self) -> Box<dyn SharedProx> {
        Box::new(self.clone())
    }

    fn state_save(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(16);
        out.extend_from_slice(&self.lambda.to_bits().to_le_bytes());
        out.extend_from_slice(&self.gamma.to_bits().to_le_bytes());
        out
    }

    fn state_load(&mut self, bytes: &[u8]) -> anyhow::Result<()> {
        let mut c = Cursor::new(bytes);
        self.lambda = c.f64()?;
        self.gamma = c.f64()?;
        c.finish()?;
        Ok(())
    }
}

/// No coupling: prox is the identity, value is zero (the single-task
/// learning baseline). Keeps its λ only so a restored snapshot reports
/// the strength it was configured with.
#[derive(Clone, Debug)]
pub struct ZeroProx {
    lambda: f64,
}

impl ZeroProx {
    /// The no-coupling baseline (λ recorded but unused).
    pub fn new(lambda: f64) -> ZeroProx {
        ZeroProx { lambda }
    }
}

impl SharedProx for ZeroProx {
    fn id(&self) -> &'static str {
        "none"
    }

    fn lambda(&self) -> f64 {
        self.lambda
    }

    fn is_separable(&self) -> bool {
        true // the identity prox is trivially column-separable
    }

    fn prox(&mut self, _w: &mut Mat, _eta: f64) {}

    fn value(&self, _w: &Mat) -> f64 {
        0.0
    }

    fn clone_box(&self) -> Box<dyn SharedProx> {
        Box::new(self.clone())
    }

    fn state_save(&self) -> Vec<u8> {
        self.lambda.to_bits().to_le_bytes().to_vec()
    }

    fn state_load(&mut self, bytes: &[u8]) -> anyhow::Result<()> {
        let mut c = Cursor::new(bytes);
        self.lambda = c.f64()?;
        c.finish()?;
        Ok(())
    }
}

// ---------------------------------------------------------------- kernels

#[inline]
pub(crate) fn soft(x: f64, tau: f64) -> f64 {
    if x > tau {
        x - tau
    } else if x < -tau {
        x + tau
    } else {
        0.0
    }
}

/// Row-wise group soft-threshold (rust mirror of the `prox_l21` Pallas
/// kernel; the kernel artifact is used when a bucketed shape exists, this
/// native path otherwise — both are tested against each other).
pub fn prox_l21(w: &mut Mat, tau: f64) {
    let (d, t) = (w.rows(), w.cols());
    for r in 0..d {
        let mut nrm = 0.0;
        for c in 0..t {
            let x = w.get(r, c);
            nrm += x * x;
        }
        nrm = nrm.sqrt();
        let scale = if nrm > tau { (nrm - tau) / nrm } else { 0.0 };
        for c in 0..t {
            w.set(r, c, w.get(r, c) * scale);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::forall;
    use crate::util::Rng;

    #[test]
    fn soft_thresholding_cases() {
        assert_eq!(soft(3.0, 1.0), 2.0);
        assert_eq!(soft(-3.0, 1.0), -2.0);
        assert_eq!(soft(0.5, 1.0), 0.0);
        assert_eq!(soft(-0.5, 1.0), 0.0);
        assert_eq!(soft(1.0, 1.0), 0.0);
    }

    #[test]
    fn kind_parse_names_and_errors() {
        assert_eq!(RegularizerKind::parse("nuclear").unwrap(), RegularizerKind::Nuclear);
        assert_eq!(RegularizerKind::parse("lowrank").unwrap(), RegularizerKind::Nuclear);
        assert_eq!(RegularizerKind::parse("en").unwrap(), RegularizerKind::ElasticNet);
        assert_eq!(RegularizerKind::Nuclear.name(), "nuclear");
        let err = RegularizerKind::parse("ridge").unwrap_err();
        assert!(
            format!("{err}").contains("nuclear|l21|l1|elasticnet|none"),
            "{err}"
        );
    }

    #[test]
    fn nuclear_prox_thresholds_singular_values() {
        let mut rng = Rng::new(20);
        let a = Mat::randn(8, 5, &mut rng);
        let before = Svd::jacobi(&a);
        let tau = before.sigma[2];
        let mut w = a.clone();
        Regularizer::new(RegularizerKind::Nuclear, 1.0).prox(&mut w, tau);
        let after = Svd::jacobi(&w);
        for (i, s) in after.sigma.iter().enumerate() {
            let want = (before.sigma[i] - tau).max(0.0);
            assert!((s - want).abs() < 1e-9);
        }
    }

    #[test]
    fn nuclear_prox_zero_tau_is_identity() {
        let mut rng = Rng::new(21);
        let a = Mat::randn(6, 4, &mut rng);
        let mut w = a.clone();
        Regularizer::new(RegularizerKind::Nuclear, 0.0).prox(&mut w, 0.1);
        assert!(w.max_abs_diff(&a) < 1e-9);
    }

    #[test]
    fn l21_prox_matches_row_norm_shrinkage() {
        let mut rng = Rng::new(22);
        let a = Mat::randn(10, 4, &mut rng);
        let mut w = a.clone();
        prox_l21(&mut w, 0.8);
        for r in 0..10 {
            let before: f64 = (0..4).map(|c| a.get(r, c).powi(2)).sum::<f64>().sqrt();
            let after: f64 = (0..4).map(|c| w.get(r, c).powi(2)).sum::<f64>().sqrt();
            assert!((after - (before - 0.8).max(0.0)).abs() < 1e-12);
        }
    }

    #[test]
    fn l1_prox_is_elementwise_soft() {
        let mut w = Mat::from_cols(2, vec![vec![2.0, -0.1], vec![-3.0, 0.4]]);
        Regularizer::new(RegularizerKind::L1, 0.5).prox(&mut w, 1.0);
        assert_eq!(w.get(0, 0), 1.5);
        assert_eq!(w.get(1, 0), 0.0);
        assert_eq!(w.get(0, 1), -2.5);
        assert_eq!(w.get(1, 1), 0.0);
    }

    #[test]
    fn elastic_net_prox_shrinks_more_than_l1() {
        let mut rng = Rng::new(23);
        let a = Mat::randn(6, 3, &mut rng);
        let mut l1 = a.clone();
        Regularizer::new(RegularizerKind::L1, 0.3).prox(&mut l1, 1.0);
        let mut en = a.clone();
        Regularizer::elastic_net(0.3, 2.0).prox(&mut en, 1.0);
        assert!(en.frobenius_norm() <= l1.frobenius_norm() + 1e-12);
    }

    #[test]
    fn none_prox_is_identity_and_zero_value() {
        let mut rng = Rng::new(24);
        let a = Mat::randn(5, 5, &mut rng);
        let mut w = a.clone();
        let mut reg = Regularizer::new(RegularizerKind::None, 3.0);
        reg.prox(&mut w, 0.7);
        assert_eq!(w, a);
        assert_eq!(reg.value(&a), 0.0);
    }

    #[test]
    fn values_match_definitions() {
        let w = Mat::from_cols(2, vec![vec![3.0, 0.0], vec![0.0, 4.0]]); // diag(3,4)
        assert!((Regularizer::new(RegularizerKind::Nuclear, 2.0).value(&w) - 14.0).abs() < 1e-9);
        assert!((Regularizer::new(RegularizerKind::L21, 1.0).value(&w) - 7.0).abs() < 1e-12);
        assert!((Regularizer::new(RegularizerKind::L1, 1.0).value(&w) - 7.0).abs() < 1e-12);
    }

    #[test]
    fn online_svd_prox_matches_full_prox() {
        let mut rng = Rng::new(25);
        let mut a = Mat::randn(12, 5, &mut rng);
        let mut full = NuclearProx::new(0.4);
        let mut online = NuclearProx::new(0.4).with_online(&a);
        for step in 0..6 {
            let j = step % 5;
            let col = rng.normal_vec(12);
            a.set_col(j, &col);
            online.notify_column_update(j, &col);
            let mut w_full = a.clone();
            full.prox(&mut w_full, 0.5);
            let mut w_online = a.clone();
            online.prox(&mut w_online, 0.5);
            assert!(
                w_full.max_abs_diff(&w_online) < 1e-7,
                "step {step}: {}",
                w_full.max_abs_diff(&w_online)
            );
        }
    }

    #[test]
    fn resvd_refresh_bounds_drift_and_tracks_exact() {
        let mut rng = Rng::new(26);
        let mut a = Mat::randn(10, 6, &mut rng);
        let mut reg = NuclearProx::new(0.3).with_online(&a).with_resvd_every(4);
        let mut refreshes = 0;
        for step in 0..20 {
            let j = step % 6;
            let col = rng.normal_vec(10);
            a.set_col(j, &col);
            reg.notify_column_update(j, &col);
            reg.note_commits(1);
            if reg.needs_refresh() {
                reg.refresh(&a);
                refreshes += 1;
                assert!(reg.refresh_drift() < 1e-8, "refresh drift {}", reg.refresh_drift());
            }
            let mut w_online = a.clone();
            reg.prox(&mut w_online, 0.5);
            let mut w_exact = a.clone();
            NuclearProx::new(0.3).prox(&mut w_exact, 0.5);
            assert!(
                w_online.max_abs_diff(&w_exact) < 1e-7,
                "step {step}: online prox drifted {}",
                w_online.max_abs_diff(&w_exact)
            );
        }
        assert_eq!(refreshes, 5, "20 commits / resvd_every=4");
        assert_eq!(reg.refresh_count(), 5);
    }

    #[test]
    fn nuclear_state_roundtrips_online_path_bitwise() {
        let mut rng = Rng::new(27);
        let a = Mat::randn(9, 4, &mut rng);
        let mut reg = NuclearProx::new(0.6).with_online(&a).with_resvd_every(16);
        reg.notify_column_update(1, &rng.normal_vec(9));
        reg.note_commits(3);
        let blob = reg.state_save();
        let mut back = NuclearProx::new(0.0);
        back.state_load(&blob).unwrap();
        assert_eq!(back.state_save(), blob, "save/load/save must be stable");
        assert_eq!(
            reg.online_prox(0.5).unwrap(),
            back.online_prox(0.5).unwrap(),
            "restored factorization must prox bitwise-identically"
        );
        assert!(!back.needs_refresh());
        back.note_commits(13);
        assert!(back.needs_refresh(), "restored stride counter continues (3+13 >= 16)");
    }

    #[test]
    fn state_load_rejects_truncated_blobs() {
        let mut rng = Rng::new(28);
        let a = Mat::randn(6, 3, &mut rng);
        let reg = NuclearProx::new(0.2).with_online(&a);
        let blob = reg.state_save();
        for cut in 0..blob.len() {
            let mut back = NuclearProx::new(0.0);
            assert!(
                back.state_load(&blob[..cut]).is_err(),
                "prefix of {cut}/{} bytes must not load",
                blob.len()
            );
        }
    }

    #[test]
    fn prop_all_proxes_nonexpansive() {
        // Non-expansiveness of the backward operator underpins Theorem 1.
        // (The full registered set, graph and mean included, is covered in
        // rust/tests/properties.rs; this is the classic-kind fast check.)
        for kind in [
            RegularizerKind::Nuclear,
            RegularizerKind::L21,
            RegularizerKind::L1,
            RegularizerKind::ElasticNet,
        ] {
            forall(
                &format!("prox {:?} nonexpansive", kind),
                30,
                |g| {
                    let a = g.normal_vec(12);
                    let b = g.normal_vec(12);
                    (a, b)
                },
                |(a, b)| {
                    let ma = Mat::from_cols(4, a.chunks(4).map(|c| c.to_vec()).collect());
                    let mb = Mat::from_cols(4, b.chunks(4).map(|c| c.to_vec()).collect());
                    let dist_before = ma.add_scaled(-1.0, &mb).frobenius_norm();
                    let mut pa = ma.clone();
                    let mut pb = mb.clone();
                    let mut reg = Regularizer::new(kind, 0.5);
                    reg.prox(&mut pa, 0.7);
                    reg.prox(&mut pb, 0.7);
                    let dist_after = pa.add_scaled(-1.0, &pb).frobenius_norm();
                    dist_after <= dist_before + 1e-9
                },
            );
        }
    }

    #[test]
    fn prop_prox_decreases_moreau_envelope_objective() {
        // prox(v) minimizes ½‖w−v‖² + τ·g(w): value at prox(v) ≤ value at v.
        forall(
            "prox optimality (l21)",
            40,
            |g| g.normal_vec(20),
            |v| {
                let m = Mat::from_cols(5, v.chunks(5).map(|c| c.to_vec()).collect());
                let mut p = m.clone();
                let mut reg = Regularizer::new(RegularizerKind::L21, 1.0);
                let tau = 0.6;
                reg.prox(&mut p, tau);
                let lhs = 0.5 * p.add_scaled(-1.0, &m).frobenius_norm().powi(2)
                    + tau * reg.value(&p) / reg.lambda();
                let rhs = tau * reg.value(&m) / reg.lambda();
                lhs <= rhs + 1e-9
            },
        );
    }
}
