//! Proximal operators for regularized MTL (the server's backward step,
//! Eq. III.3), plus the regularizer values used for objective reporting.
//!
//! Supported couplings — the formulations named in §III.A of the paper:
//!
//! * [`RegularizerKind::Nuclear`] — shared-subspace / low-rank MTL,
//!   `g(W) = ‖W‖_*`; prox = singular-value thresholding (Eq. IV.2).
//! * [`RegularizerKind::L21`] — joint feature selection, `g(W) = ‖W‖_{2,1}`;
//!   prox = row-wise group soft-threshold.
//! * [`RegularizerKind::L1`] — elementwise sparsity (Lasso-style).
//! * [`RegularizerKind::ElasticNet`] — `‖W‖₁ + (γ/2)‖W‖²_F`, the strongly
//!   convex variant the paper invokes for linear convergence (Remark after
//!   Theorem 1).
//! * [`RegularizerKind::None`] — decoupled single-task learning baseline.

use crate::linalg::Mat;
use crate::optim::svd::{OnlineSvd, Svd};

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RegularizerKind {
    Nuclear,
    L21,
    L1,
    ElasticNet,
    None,
}

impl RegularizerKind {
    pub fn parse(s: &str) -> Option<RegularizerKind> {
        Some(match s {
            "nuclear" | "trace" | "lowrank" => RegularizerKind::Nuclear,
            "l21" => RegularizerKind::L21,
            "l1" => RegularizerKind::L1,
            "elasticnet" | "en" => RegularizerKind::ElasticNet,
            "none" | "stl" => RegularizerKind::None,
            _ => return None,
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            RegularizerKind::Nuclear => "nuclear",
            RegularizerKind::L21 => "l21",
            RegularizerKind::L1 => "l1",
            RegularizerKind::ElasticNet => "elasticnet",
            RegularizerKind::None => "none",
        }
    }
}

/// A regularizer `λ·g(W)` with its prox and value.
#[derive(Clone, Debug)]
pub struct Regularizer {
    pub kind: RegularizerKind,
    pub lambda: f64,
    /// ℓ2 weight for the elastic-net variant.
    pub gamma: f64,
    /// When set, the nuclear prox maintains an incremental factorization
    /// (Brand online SVD) instead of refactorizing; see `svd::OnlineSvd`.
    online: Option<OnlineSvd>,
}

impl Regularizer {
    pub fn new(kind: RegularizerKind, lambda: f64) -> Regularizer {
        Regularizer { kind, lambda, gamma: 1.0, online: None }
    }

    pub fn elastic_net(lambda: f64, gamma: f64) -> Regularizer {
        Regularizer { kind: RegularizerKind::ElasticNet, lambda, gamma, online: None }
    }

    /// Enable the online-SVD path for the nuclear prox (ablation).
    pub fn with_online_svd(mut self, w0: &Mat) -> Regularizer {
        assert_eq!(self.kind, RegularizerKind::Nuclear);
        self.online = Some(OnlineSvd::init(w0));
        self
    }

    pub fn uses_online_svd(&self) -> bool {
        self.online.is_some()
    }

    /// Inform the incremental factorization that column `j` of the operand
    /// changed (no-op unless the online path is active).
    pub fn notify_column_update(&mut self, j: usize, col: &[f64]) {
        if let Some(osvd) = self.online.as_mut() {
            osvd.replace_column(j, col);
        }
    }

    /// `Prox_{η λ g}(W)`, overwriting `w`. `eta` is the prox step size.
    pub fn prox(&mut self, w: &mut Mat, eta: f64) {
        let tau = eta * self.lambda;
        match self.kind {
            RegularizerKind::None => {}
            RegularizerKind::Nuclear => {
                let out = if let Some(osvd) = self.online.as_ref() {
                    osvd.shrink_reconstruct(tau)
                } else {
                    Svd::jacobi(w).shrink_reconstruct(tau)
                };
                *w = out;
            }
            RegularizerKind::L21 => prox_l21(w, tau),
            RegularizerKind::L1 => {
                for x in w.data_mut() {
                    *x = soft(*x, tau);
                }
            }
            RegularizerKind::ElasticNet => {
                // prox of τ‖·‖₁ + (τγ/2)‖·‖² = soft(x, τ) / (1 + τγ)
                let scale = 1.0 / (1.0 + tau * self.gamma);
                for x in w.data_mut() {
                    *x = soft(*x, tau) * scale;
                }
            }
        }
    }

    /// `λ·g(W)` for objective reporting.
    pub fn value(&self, w: &Mat) -> f64 {
        match self.kind {
            RegularizerKind::None => 0.0,
            RegularizerKind::Nuclear => self.lambda * Svd::jacobi(w).nuclear_norm(),
            RegularizerKind::L21 => {
                let mut sum = 0.0;
                for r in 0..w.rows() {
                    let mut s = 0.0;
                    for c in 0..w.cols() {
                        let x = w.get(r, c);
                        s += x * x;
                    }
                    sum += s.sqrt();
                }
                self.lambda * sum
            }
            RegularizerKind::L1 => self.lambda * w.data().iter().map(|x| x.abs()).sum::<f64>(),
            RegularizerKind::ElasticNet => {
                let l1: f64 = w.data().iter().map(|x| x.abs()).sum();
                let sq: f64 = w.data().iter().map(|x| x * x).sum();
                self.lambda * (l1 + 0.5 * self.gamma * sq)
            }
        }
    }
}

#[inline]
fn soft(x: f64, tau: f64) -> f64 {
    if x > tau {
        x - tau
    } else if x < -tau {
        x + tau
    } else {
        0.0
    }
}

/// Row-wise group soft-threshold (rust mirror of the `prox_l21` Pallas
/// kernel; the kernel artifact is used when a bucketed shape exists, this
/// native path otherwise — both are tested against each other).
pub fn prox_l21(w: &mut Mat, tau: f64) {
    let (d, t) = (w.rows(), w.cols());
    for r in 0..d {
        let mut nrm = 0.0;
        for c in 0..t {
            let x = w.get(r, c);
            nrm += x * x;
        }
        nrm = nrm.sqrt();
        let scale = if nrm > tau { (nrm - tau) / nrm } else { 0.0 };
        for c in 0..t {
            w.set(r, c, w.get(r, c) * scale);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::forall;
    use crate::util::Rng;

    #[test]
    fn soft_thresholding_cases() {
        assert_eq!(soft(3.0, 1.0), 2.0);
        assert_eq!(soft(-3.0, 1.0), -2.0);
        assert_eq!(soft(0.5, 1.0), 0.0);
        assert_eq!(soft(-0.5, 1.0), 0.0);
        assert_eq!(soft(1.0, 1.0), 0.0);
    }

    #[test]
    fn nuclear_prox_thresholds_singular_values() {
        let mut rng = Rng::new(20);
        let a = Mat::randn(8, 5, &mut rng);
        let before = Svd::jacobi(&a);
        let tau = before.sigma[2];
        let mut w = a.clone();
        Regularizer::new(RegularizerKind::Nuclear, 1.0).prox(&mut w, tau);
        let after = Svd::jacobi(&w);
        for (i, s) in after.sigma.iter().enumerate() {
            let want = (before.sigma[i] - tau).max(0.0);
            assert!((s - want).abs() < 1e-9);
        }
    }

    #[test]
    fn nuclear_prox_zero_tau_is_identity() {
        let mut rng = Rng::new(21);
        let a = Mat::randn(6, 4, &mut rng);
        let mut w = a.clone();
        Regularizer::new(RegularizerKind::Nuclear, 0.0).prox(&mut w, 0.1);
        assert!(w.max_abs_diff(&a) < 1e-9);
    }

    #[test]
    fn l21_prox_matches_row_norm_shrinkage() {
        let mut rng = Rng::new(22);
        let a = Mat::randn(10, 4, &mut rng);
        let mut w = a.clone();
        prox_l21(&mut w, 0.8);
        for r in 0..10 {
            let before: f64 = (0..4).map(|c| a.get(r, c).powi(2)).sum::<f64>().sqrt();
            let after: f64 = (0..4).map(|c| w.get(r, c).powi(2)).sum::<f64>().sqrt();
            assert!((after - (before - 0.8).max(0.0)).abs() < 1e-12);
        }
    }

    #[test]
    fn l1_prox_is_elementwise_soft() {
        let mut w = Mat::from_cols(2, vec![vec![2.0, -0.1], vec![-3.0, 0.4]]);
        Regularizer::new(RegularizerKind::L1, 0.5).prox(&mut w, 1.0);
        assert_eq!(w.get(0, 0), 1.5);
        assert_eq!(w.get(1, 0), 0.0);
        assert_eq!(w.get(0, 1), -2.5);
        assert_eq!(w.get(1, 1), 0.0);
    }

    #[test]
    fn elastic_net_prox_shrinks_more_than_l1() {
        let mut rng = Rng::new(23);
        let a = Mat::randn(6, 3, &mut rng);
        let mut l1 = a.clone();
        Regularizer::new(RegularizerKind::L1, 0.3).prox(&mut l1, 1.0);
        let mut en = a.clone();
        Regularizer::elastic_net(0.3, 2.0).prox(&mut en, 1.0);
        assert!(en.frobenius_norm() <= l1.frobenius_norm() + 1e-12);
    }

    #[test]
    fn none_prox_is_identity_and_zero_value() {
        let mut rng = Rng::new(24);
        let a = Mat::randn(5, 5, &mut rng);
        let mut w = a.clone();
        let mut reg = Regularizer::new(RegularizerKind::None, 3.0);
        reg.prox(&mut w, 0.7);
        assert_eq!(w, a);
        assert_eq!(reg.value(&a), 0.0);
    }

    #[test]
    fn values_match_definitions() {
        let w = Mat::from_cols(2, vec![vec![3.0, 0.0], vec![0.0, 4.0]]); // diag(3,4)
        assert!((Regularizer::new(RegularizerKind::Nuclear, 2.0).value(&w) - 14.0).abs() < 1e-9);
        assert!((Regularizer::new(RegularizerKind::L21, 1.0).value(&w) - 7.0).abs() < 1e-12);
        assert!((Regularizer::new(RegularizerKind::L1, 1.0).value(&w) - 7.0).abs() < 1e-12);
    }

    #[test]
    fn online_svd_prox_matches_full_prox() {
        let mut rng = Rng::new(25);
        let mut a = Mat::randn(12, 5, &mut rng);
        let mut full = Regularizer::new(RegularizerKind::Nuclear, 0.4);
        let mut online = Regularizer::new(RegularizerKind::Nuclear, 0.4).with_online_svd(&a);
        for step in 0..6 {
            let j = step % 5;
            let col = rng.normal_vec(12);
            a.set_col(j, &col);
            online.notify_column_update(j, &col);
            let mut w_full = a.clone();
            full.prox(&mut w_full, 0.5);
            let mut w_online = a.clone();
            online.prox(&mut w_online, 0.5);
            assert!(
                w_full.max_abs_diff(&w_online) < 1e-7,
                "step {step}: {}",
                w_full.max_abs_diff(&w_online)
            );
        }
    }

    #[test]
    fn prop_all_proxes_nonexpansive() {
        // Non-expansiveness of the backward operator underpins Theorem 1.
        for kind in [
            RegularizerKind::Nuclear,
            RegularizerKind::L21,
            RegularizerKind::L1,
            RegularizerKind::ElasticNet,
        ] {
            forall(
                &format!("prox {:?} nonexpansive", kind),
                30,
                |g| {
                    let a = g.normal_vec(12);
                    let b = g.normal_vec(12);
                    (a, b)
                },
                |(a, b)| {
                    let ma = Mat::from_cols(4, a.chunks(4).map(|c| c.to_vec()).collect());
                    let mb = Mat::from_cols(4, b.chunks(4).map(|c| c.to_vec()).collect());
                    let dist_before = ma.add_scaled(-1.0, &mb).frobenius_norm();
                    let mut pa = ma.clone();
                    let mut pb = mb.clone();
                    let mut reg = Regularizer::new(kind, 0.5);
                    reg.prox(&mut pa, 0.7);
                    reg.prox(&mut pb, 0.7);
                    let dist_after = pa.add_scaled(-1.0, &pb).frobenius_norm();
                    dist_after <= dist_before + 1e-9
                },
            );
        }
    }

    #[test]
    fn prop_prox_decreases_moreau_envelope_objective() {
        // prox(v) minimizes ½‖w−v‖² + τ·g(w): value at prox(v) ≤ value at v.
        forall(
            "prox optimality (l21)",
            40,
            |g| g.normal_vec(20),
            |v| {
                let m = Mat::from_cols(5, v.chunks(5).map(|c| c.to_vec()).collect());
                let mut p = m.clone();
                let mut reg = Regularizer::new(RegularizerKind::L21, 1.0);
                let tau = 0.6;
                reg.prox(&mut p, tau);
                let lhs = 0.5 * p.add_scaled(-1.0, &m).frobenius_norm().powi(2)
                    + tau * reg.value(&p) / reg.lambda;
                let rhs = tau * reg.value(&m) / reg.lambda;
                lhs <= rhs + 1e-9
            },
        );
    }
}
