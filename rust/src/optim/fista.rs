//! Centralized FISTA baseline (Beck & Teboulle 2009) for
//! `min_W Σ_t ℓ_t(w_t) + λ g(W)`.
//!
//! This is the data-centralized solver the paper's distributed methods are
//! measured against: it assumes all task data is in one place. We use it to
//! (a) compute reference optima `F*` for convergence plots, and (b) sanity-
//! check that AMTL/SMTL converge to the same objective value.

use crate::linalg::Mat;
use crate::optim::formulation::SharedProx;
use crate::optim::losses::{Loss, RowMat};

/// One task's centralized view.
pub struct TaskData<'a> {
    /// Feature matrix `X_t` (rows are samples).
    pub x: &'a RowMat,
    /// Labels `y_t`.
    pub y: &'a [f64],
    /// Per-sample weights (1 = present; supports padding).
    pub mask: &'a [f64],
    /// Which loss `ℓ_t` is.
    pub loss: Loss,
}

/// Outcome of a centralized FISTA solve.
pub struct FistaResult {
    /// The final iterate `W`.
    pub w: Mat,
    /// Objective after every iteration (F = f + λg).
    pub history: Vec<f64>,
    /// Iterations actually run (≤ `max_iters` with early stopping).
    pub iterations: usize,
}

/// Run FISTA for `max_iters` iterations with fixed step `1/L`.
/// Stops early when the relative objective change drops below `rel_tol`.
pub fn fista(
    tasks: &[TaskData],
    reg: &mut dyn SharedProx,
    l: f64,
    max_iters: usize,
    rel_tol: f64,
) -> FistaResult {
    assert!(!tasks.is_empty());
    let d = tasks[0].x.cols;
    let t_count = tasks.len();
    let eta = 1.0 / l;

    let mut w = Mat::zeros(d, t_count);
    let mut z = w.clone(); // extrapolated point
    let mut theta = 1.0f64;
    let mut history = Vec::with_capacity(max_iters);

    for iter in 0..max_iters {
        // Gradient step at z (task-separable).
        let mut w_next = Mat::zeros(d, t_count);
        for (t, task) in tasks.iter().enumerate() {
            let (g, _) = task.loss.grad_obj(task.x, task.y, z.col(t), task.mask);
            let col: Vec<f64> = z.col(t).iter().zip(&g).map(|(zi, gi)| zi - eta * gi).collect();
            w_next.set_col(t, &col);
        }
        // Proximal step on the full matrix.
        reg.prox(&mut w_next, eta);

        // Nesterov momentum.
        let theta_next = 0.5 * (1.0 + (1.0 + 4.0 * theta * theta).sqrt());
        let beta = (theta - 1.0) / theta_next;
        z = w_next.add_scaled(beta, &w_next.add_scaled(-1.0, &w));
        theta = theta_next;
        w = w_next;

        let obj = objective(tasks, &w, reg);
        history.push(obj);
        if iter > 0 {
            let prev = history[iter - 1];
            if (prev - obj).abs() <= rel_tol * prev.abs().max(1e-12) {
                return FistaResult { w, history, iterations: iter + 1 };
            }
        }
    }
    let iterations = history.len();
    FistaResult { w, history, iterations }
}

/// Full MTL objective `Σ_t ℓ_t(w_t) + λ g(W)`.
pub fn objective(tasks: &[TaskData], w: &Mat, reg: &dyn SharedProx) -> f64 {
    let f: f64 = tasks
        .iter()
        .enumerate()
        .map(|(t, task)| task.loss.obj(task.x, task.y, w.col(t), task.mask))
        .sum();
    f + reg.value(w)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optim::lipschitz::task_lipschitz;
    use crate::optim::prox::{Regularizer, RegularizerKind};
    use crate::util::Rng;

    fn make_tasks(
        t_count: usize,
        n: usize,
        d: usize,
        seed: u64,
    ) -> (Vec<RowMat>, Vec<Vec<f64>>, Vec<Vec<f64>>) {
        let mut rng = Rng::new(seed);
        let mut xs = Vec::new();
        let mut ys = Vec::new();
        let mut masks = Vec::new();
        for _ in 0..t_count {
            let mut x = RowMat::zeros(n, d);
            for v in x.data.iter_mut() {
                *v = rng.normal();
            }
            let w_true = rng.normal_vec(d);
            let y: Vec<f64> = (0..n)
                .map(|i| {
                    x.row(i).iter().zip(&w_true).map(|(a, b)| a * b).sum::<f64>()
                        + 0.01 * rng.normal()
                })
                .collect();
            xs.push(x);
            ys.push(y);
            masks.push(vec![1.0; n]);
        }
        (xs, ys, masks)
    }

    #[test]
    fn fista_monotonically_decreases_unregularized() {
        let (xs, ys, masks) = make_tasks(3, 40, 6, 50);
        let tasks: Vec<TaskData> = (0..3)
            .map(|t| TaskData { x: &xs[t], y: &ys[t], mask: &masks[t], loss: Loss::Squared })
            .collect();
        let mut rng = Rng::new(51);
        let l = tasks
            .iter()
            .map(|t| task_lipschitz(Loss::Squared, t.x, &mut rng))
            .fold(0.0, f64::max);
        let mut reg = Regularizer::new(RegularizerKind::None, 0.0);
        let res = fista(&tasks, &mut reg, l, 200, 0.0);
        // FISTA is not strictly monotone, but the trend must be decreasing.
        assert!(res.history.last().unwrap() < &res.history[0]);
        assert!(res.history.last().unwrap() < &1.0);
    }

    #[test]
    fn fista_nuclear_reaches_low_objective_on_lowrank_data() {
        // Planted rank-1 task family: nuclear-regularized FISTA should fit well.
        let mut rng = Rng::new(52);
        let d = 8;
        let shared = rng.normal_vec(d);
        let mut xs = Vec::new();
        let mut ys = Vec::new();
        for _ in 0..5 {
            let scalec = 1.0 + rng.f64();
            let wt: Vec<f64> = shared.iter().map(|s| s * scalec).collect();
            let mut x = RowMat::zeros(30, d);
            for v in x.data.iter_mut() {
                *v = rng.normal();
            }
            let y: Vec<f64> = (0..30)
                .map(|i| x.row(i).iter().zip(&wt).map(|(a, b)| a * b).sum::<f64>())
                .collect();
            xs.push(x);
            ys.push(y);
        }
        let masks: Vec<Vec<f64>> = (0..5).map(|_| vec![1.0; 30]).collect();
        let tasks: Vec<TaskData> = (0..5)
            .map(|t| TaskData { x: &xs[t], y: &ys[t], mask: &masks[t], loss: Loss::Squared })
            .collect();
        let l = tasks
            .iter()
            .map(|t| task_lipschitz(Loss::Squared, t.x, &mut rng))
            .fold(0.0, f64::max);
        let mut reg = Regularizer::new(RegularizerKind::Nuclear, 0.1);
        let res = fista(&tasks, &mut reg, l, 500, 1e-10);
        let final_obj = *res.history.last().unwrap();
        assert!(final_obj < 5.0, "final objective {final_obj}");
        // Solution should be numerically low-rank.
        let svd = crate::optim::svd::Svd::jacobi(&res.w);
        assert!(svd.sigma[1] / svd.sigma[0] < 0.2, "not low rank: {:?}", svd.sigma);
    }

    #[test]
    fn early_stop_triggers() {
        let (xs, ys, masks) = make_tasks(2, 20, 4, 53);
        let tasks: Vec<TaskData> = (0..2)
            .map(|t| TaskData { x: &xs[t], y: &ys[t], mask: &masks[t], loss: Loss::Squared })
            .collect();
        let mut rng = Rng::new(54);
        let l = tasks
            .iter()
            .map(|t| task_lipschitz(Loss::Squared, t.x, &mut rng))
            .fold(0.0, f64::max);
        let mut reg = Regularizer::new(RegularizerKind::None, 0.0);
        let res = fista(&tasks, &mut reg, l, 10_000, 1e-9);
        assert!(res.iterations < 10_000, "never early-stopped");
    }

    #[test]
    fn objective_is_sum_of_losses_plus_reg() {
        let (xs, ys, masks) = make_tasks(2, 10, 3, 55);
        let tasks: Vec<TaskData> = (0..2)
            .map(|t| TaskData { x: &xs[t], y: &ys[t], mask: &masks[t], loss: Loss::Squared })
            .collect();
        let mut rng = Rng::new(56);
        let w = Mat::randn(3, 2, &mut rng);
        let reg = Regularizer::new(RegularizerKind::L1, 0.7);
        let got = objective(&tasks, &w, &reg);
        let f0 = Loss::Squared.obj(&xs[0], &ys[0], w.col(0), &masks[0]);
        let f1 = Loss::Squared.obj(&xs[1], &ys[1], w.col(1), &masks[1]);
        let g: f64 = 0.7 * w.data().iter().map(|x| x.abs()).sum::<f64>();
        assert!((got - (f0 + f1 + g)).abs() < 1e-12);
    }
}
