//! Timing + summary statistics for the bench harness (criterion is not in
//! the vendored crate set; the bench binaries use these helpers with
//! warmup/repeat protocols).

use std::time::{Duration, Instant};

/// Summary statistics over a sample of measurements.
#[derive(Clone, Debug, Default)]
pub struct Summary {
    /// Sample count.
    pub n: usize,
    /// Arithmetic mean.
    pub mean: f64,
    /// Population standard deviation.
    pub std: f64,
    /// Smallest sample.
    pub min: f64,
    /// Largest sample.
    pub max: f64,
    /// Median (midpoint average for even `n`).
    pub median: f64,
}

impl Summary {
    /// Summarize a sample (all-zeros for an empty slice).
    pub fn from(xs: &[f64]) -> Summary {
        if xs.is_empty() {
            return Summary::default();
        }
        let n = xs.len();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        let mut sorted = xs.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let median = if n % 2 == 1 {
            sorted[n / 2]
        } else {
            0.5 * (sorted[n / 2 - 1] + sorted[n / 2])
        };
        Summary {
            n,
            mean,
            std: var.sqrt(),
            min: sorted[0],
            max: sorted[n - 1],
            median,
        }
    }
}

/// Time a closure once, returning (result, elapsed).
pub fn time_once<T>(f: impl FnOnce() -> T) -> (T, Duration) {
    let t0 = Instant::now();
    let r = f();
    (r, t0.elapsed())
}

/// Bench protocol: `warmup` unmeasured runs, then `reps` measured runs.
pub fn bench_secs(warmup: usize, reps: usize, mut f: impl FnMut()) -> Summary {
    for _ in 0..warmup {
        f();
    }
    let samples: Vec<f64> = (0..reps)
        .map(|_| {
            let t0 = Instant::now();
            f();
            t0.elapsed().as_secs_f64()
        })
        .collect();
    Summary::from(&samples)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_of_constant_sample() {
        let s = Summary::from(&[2.0, 2.0, 2.0, 2.0]);
        assert_eq!(s.mean, 2.0);
        assert_eq!(s.std, 0.0);
        assert_eq!(s.median, 2.0);
        assert_eq!((s.min, s.max), (2.0, 2.0));
    }

    #[test]
    fn summary_median_odd_even() {
        assert_eq!(Summary::from(&[3.0, 1.0, 2.0]).median, 2.0);
        assert_eq!(Summary::from(&[4.0, 1.0, 2.0, 3.0]).median, 2.5);
    }

    #[test]
    fn summary_empty_is_default() {
        let s = Summary::from(&[]);
        assert_eq!(s.n, 0);
    }

    #[test]
    fn bench_runs_expected_reps() {
        let mut count = 0;
        let s = bench_secs(2, 5, || count += 1);
        assert_eq!(count, 7);
        assert_eq!(s.n, 5);
        assert!(s.mean >= 0.0);
    }

    #[test]
    fn time_once_returns_result() {
        let (v, d) = time_once(|| 41 + 1);
        assert_eq!(v, 42);
        assert!(d.as_nanos() > 0);
    }
}
