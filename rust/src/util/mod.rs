//! Small self-contained substrates: PRNG, JSON, property testing, timing.
//!
//! The build is fully offline against a minimal vendored crate set (no
//! `rand`, `serde_json`, `proptest` or `criterion`), so these are
//! implemented from scratch (the vendored-set substitutions are listed
//! in `docs/ARCHITECTURE.md`).

pub mod json;
pub mod names;
pub mod proptest;
pub mod rng;
pub mod stats;

pub use names::EnumTable;
pub use rng::{Rng, RngState};
