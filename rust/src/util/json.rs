//! Minimal JSON parser + emitter (RFC 8259 subset sufficient for the
//! artifact manifest and experiment-result files).
//!
//! Supports: objects, arrays, strings with standard escapes (incl. `\uXXXX`),
//! numbers, booleans, null. No serde is vendored in the offline crate set,
//! hence this substrate.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    /// `null`.
    Null,
    /// `true` / `false`.
    Bool(bool),
    /// A number (always carried as `f64`).
    Num(f64),
    /// A string.
    Str(String),
    /// An array.
    Arr(Vec<Json>),
    /// An object (sorted keys, so output is deterministic).
    Obj(BTreeMap<String, Json>),
}

/// A JSON parse failure, with position context.
#[derive(Debug)]
pub struct ParseError {
    /// Byte offset of the error in the input.
    pub pos: usize,
    /// What went wrong.
    pub msg: String,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for ParseError {}

impl Json {
    /// Parse a complete JSON document (trailing garbage is an error).
    pub fn parse(s: &str) -> Result<Json, ParseError> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    // ----- typed accessors ------------------------------------------------

    /// The number, if this is a `Num`.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    /// The number as a non-negative integer, if it is one exactly.
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().and_then(|x| {
            if x >= 0.0 && x.fract() == 0.0 {
                Some(x as usize)
            } else {
                None
            }
        })
    }

    /// The string, if this is a `Str`.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// The boolean, if this is a `Bool`.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// The elements, if this is an `Arr`.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    /// Object field lookup (`None` for non-objects/missing keys).
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    // ----- construction helpers ------------------------------------------

    /// Build an object from key/value pairs.
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    /// Build a numeric array.
    pub fn arr_f64(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
    }

    /// Serialize compactly.
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    out.push_str(&format!("{}", *x as i64));
                } else {
                    out.push_str(&format!("{x}"));
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\r' => out.push_str("\\r"),
                        '\t' => out.push_str("\\t"),
                        c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).write(out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> ParseError {
        ParseError { pos: self.i, msg: msg.to_string() }
    }

    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), ParseError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, ParseError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a value")),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, ParseError> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn object(&mut self) -> Result<Json, ParseError> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, ParseError> {
        self.expect(b'[')?;
        let mut a = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(a));
        }
        loop {
            self.skip_ws();
            a.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(a));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err(self.err("truncated \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 scalar.
                    let rest = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let c = rest.chars().next().unwrap();
                    s.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, ParseError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.i += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        let txt = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        txt.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-12.5e2").unwrap(), Json::Num(-1250.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parses_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x"}"#).unwrap();
        assert_eq!(v.get("c").unwrap().as_str(), Some("x"));
        let a = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(a[1].as_f64(), Some(2.0));
        assert_eq!(a[2].get("b"), Some(&Json::Null));
    }

    #[test]
    fn parses_escapes() {
        let v = Json::parse(r#""a\n\t\"\\ A""#).unwrap();
        assert_eq!(v.as_str(), Some("a\n\t\"\\ A"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("nul").is_err());
        assert!(Json::parse("\"open").is_err());
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"entries":[{"d":50,"file":"x.hlo.txt","n":128,"op":"lsq_step"}],"tile_n":128,"version":1}"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(v.to_string(), src);
        let again = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, again);
    }

    #[test]
    fn emits_escaped_strings() {
        let v = Json::Str("a\"b\\c\nd".into());
        assert_eq!(Json::parse(&v.to_string()).unwrap(), v);
    }

    #[test]
    fn handles_whitespace() {
        let v = Json::parse(" {\n \"k\" :\t[ 1 , 2 ] } ").unwrap();
        assert_eq!(v.get("k").unwrap().as_arr().unwrap().len(), 2);
    }

    #[test]
    fn usize_accessor_rejects_fractions() {
        assert_eq!(Json::parse("3").unwrap().as_usize(), Some(3));
        assert_eq!(Json::parse("3.5").unwrap().as_usize(), None);
        assert_eq!(Json::parse("-3").unwrap().as_usize(), None);
    }
}
