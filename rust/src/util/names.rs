//! One table-driven parse/name helper for every string-keyed enum.
//!
//! Before this module, `SvdMode`, `Loss`, `RegularizerKind` and
//! `TransportKind` each hand-rolled the same `parse`/`name` pair with
//! slightly different error behavior (all returned `Option`, so every call
//! site invented its own error message). An [`EnumTable`] holds the
//! canonical name, the accepted aliases and the variant in one place; the
//! enums keep their `parse`/`name` methods as one-line wrappers, and every
//! parse failure produces the same `anyhow` message shape listing the
//! valid values.

/// A static name table for one enum: `(canonical, aliases, variant)` rows.
pub struct EnumTable<T: 'static> {
    /// What the value is, for error messages (e.g. `"--svd value"`).
    pub what: &'static str,
    /// One row per variant: canonical name, accepted aliases, the variant.
    pub rows: &'static [(&'static str, &'static [&'static str], T)],
}

impl<T: Copy + PartialEq + 'static> EnumTable<T> {
    /// Parse `s` against the canonical names and aliases. The error names
    /// every valid canonical value.
    pub fn parse(&self, s: &str) -> anyhow::Result<T> {
        for (canon, aliases, v) in self.rows {
            if *canon == s || aliases.contains(&s) {
                return Ok(*v);
            }
        }
        anyhow::bail!(
            "unknown {} '{}' (expected one of {})",
            self.what,
            s,
            self.joined_names()
        )
    }

    /// The canonical name of `v`.
    pub fn name(&self, v: T) -> &'static str {
        self.rows
            .iter()
            .find(|(_, _, x)| *x == v)
            .map(|(n, _, _)| *n)
            .expect("every variant has a table row")
    }

    /// Canonical names, in table order.
    pub fn canonical_names(&self) -> Vec<&'static str> {
        self.rows.iter().map(|(n, _, _)| *n).collect()
    }

    /// `a|b|c` over the canonical names (for error/help text).
    pub fn joined_names(&self) -> String {
        self.canonical_names().join("|")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[derive(Clone, Copy, Debug, PartialEq, Eq)]
    enum Fruit {
        Apple,
        Pear,
    }

    const FRUITS: EnumTable<Fruit> = EnumTable {
        what: "fruit",
        rows: &[("apple", &["pomme"], Fruit::Apple), ("pear", &[], Fruit::Pear)],
    };

    #[test]
    fn parses_canonical_and_aliases() {
        assert_eq!(FRUITS.parse("apple").unwrap(), Fruit::Apple);
        assert_eq!(FRUITS.parse("pomme").unwrap(), Fruit::Apple);
        assert_eq!(FRUITS.parse("pear").unwrap(), Fruit::Pear);
    }

    #[test]
    fn error_lists_valid_values() {
        let err = FRUITS.parse("banana").unwrap_err();
        let msg = format!("{err}");
        assert!(msg.contains("unknown fruit 'banana'"), "{msg}");
        assert!(msg.contains("apple|pear"), "{msg}");
    }

    #[test]
    fn names_are_canonical() {
        assert_eq!(FRUITS.name(Fruit::Apple), "apple");
        assert_eq!(FRUITS.name(Fruit::Pear), "pear");
        assert_eq!(FRUITS.joined_names(), "apple|pear");
    }
}
