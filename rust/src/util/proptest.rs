//! A miniature property-based testing framework (no `proptest` is vendored
//! in the offline crate set).
//!
//! Provides seeded case generation with failure reporting and greedy
//! shrinking. Used throughout the test suite for coordinator invariants
//! (routing, batching, state convergence) and numerical operators.
//!
//! ```no_run
//! // (no_run: doctest binaries don't get the xla rpath link flags)
//! use amtl::util::proptest::forall;
//! forall(
//!     "sum is commutative",
//!     100,
//!     |g| {
//!         let a = g.f64_in(-1e3, 1e3);
//!         let b = g.f64_in(-1e3, 1e3);
//!         (a, b)
//!     },
//!     |(a, b)| a + b == b + a,
//! );
//! ```

use super::rng::Rng;

/// Generation context handed to the case generator.
pub struct Gen {
    rng: Rng,
    /// Size hint in [0,1]: early cases are "small", later cases larger —
    /// mirrors proptest's sizing so edge-ish cases come first.
    pub size: f64,
}

impl Gen {
    /// The underlying PRNG (for custom generation).
    pub fn rng(&mut self) -> &mut Rng {
        &mut self.rng
    }

    /// Uniform integer in `[lo, hi_incl]`, scaled by the case size.
    pub fn usize_in(&mut self, lo: usize, hi_incl: usize) -> usize {
        let span = (hi_incl - lo) as f64 * self.size;
        lo + self.rng.below(span as u64 + 1) as usize
    }

    /// Uniform float in `[lo, hi)`.
    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        self.rng.range_f64(lo, hi)
    }

    /// `len` uniform floats in `[lo, hi)`.
    pub fn vec_f64(&mut self, len: usize, lo: f64, hi: f64) -> Vec<f64> {
        (0..len).map(|_| self.f64_in(lo, hi)).collect()
    }

    /// `len` standard-normal samples.
    pub fn normal_vec(&mut self, len: usize) -> Vec<f64> {
        self.rng.normal_vec(len)
    }

    /// Bernoulli(`p`).
    pub fn bool(&mut self, p: f64) -> bool {
        self.rng.bool(p)
    }

    /// Uniform choice from a non-empty slice.
    pub fn choice<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.rng.below(xs.len() as u64) as usize]
    }
}

/// A value that knows how to propose smaller versions of itself.
pub trait Shrink: Sized {
    /// Candidate simplifications, in decreasing order of aggressiveness.
    fn shrink_candidates(&self) -> Vec<Self> {
        Vec::new()
    }
}

impl Shrink for f64 {
    fn shrink_candidates(&self) -> Vec<Self> {
        let mut c = Vec::new();
        if *self != 0.0 {
            c.push(0.0);
            c.push(self / 2.0);
            if self.fract() != 0.0 {
                c.push(self.trunc());
            }
        }
        c
    }
}

impl Shrink for usize {
    fn shrink_candidates(&self) -> Vec<Self> {
        let mut c = Vec::new();
        if *self > 0 {
            c.push(0);
            c.push(self / 2);
            c.push(self - 1);
        }
        c
    }
}

impl<T: Clone> Shrink for Vec<T> {
    fn shrink_candidates(&self) -> Vec<Self> {
        let mut c = Vec::new();
        let n = self.len();
        if n > 0 {
            c.push(self[..n / 2].to_vec());
            c.push(self[n / 2..].to_vec());
            c.push(self[..n - 1].to_vec());
        }
        c
    }
}

impl<A: Shrink + Clone, B: Shrink + Clone> Shrink for (A, B) {
    fn shrink_candidates(&self) -> Vec<Self> {
        let mut c: Vec<Self> = self
            .0
            .shrink_candidates()
            .into_iter()
            .map(|a| (a, self.1.clone()))
            .collect();
        c.extend(self.1.shrink_candidates().into_iter().map(|b| (self.0.clone(), b)));
        c
    }
}

impl<A: Shrink + Clone, B: Shrink + Clone, C: Shrink + Clone> Shrink for (A, B, C) {
    fn shrink_candidates(&self) -> Vec<Self> {
        let mut c: Vec<Self> = self
            .0
            .shrink_candidates()
            .into_iter()
            .map(|a| (a, self.1.clone(), self.2.clone()))
            .collect();
        c.extend(
            self.1
                .shrink_candidates()
                .into_iter()
                .map(|b| (self.0.clone(), b, self.2.clone())),
        );
        c.extend(
            self.2
                .shrink_candidates()
                .into_iter()
                .map(|x| (self.0.clone(), self.1.clone(), x)),
        );
        c
    }
}

/// Run `cases` random cases of `prop` over values built by `gen`.
/// Panics with the failing seed and (shrunk) value on the first failure.
pub fn forall<T, G, P>(name: &str, cases: u64, mut gen: G, prop: P)
where
    T: std::fmt::Debug + Clone + Shrink,
    G: FnMut(&mut Gen) -> T,
    P: Fn(&T) -> bool,
{
    let base_seed = 0xA3D1_u64 ^ fnv1a(name.as_bytes());
    for case in 0..cases {
        let seed = base_seed.wrapping_add(case.wrapping_mul(0x9E3779B97F4A7C15));
        let mut g = Gen { rng: Rng::new(seed), size: ((case + 1) as f64 / cases as f64).min(1.0) };
        let value = gen(&mut g);
        if !prop(&value) {
            let shrunk = shrink_loop(value.clone(), &prop);
            panic!(
                "property '{name}' failed (case {case}, seed {seed:#x})\n  original: {value:?}\n  shrunk:   {shrunk:?}"
            );
        }
    }
}

/// Greedy shrink: repeatedly take the first candidate that still fails.
fn shrink_loop<T: Clone + Shrink, P: Fn(&T) -> bool>(mut value: T, prop: &P) -> T {
    'outer: for _ in 0..200 {
        for cand in value.shrink_candidates() {
            if !prop(&cand) {
                value = cand;
                continue 'outer;
            }
        }
        break;
    }
    value
}

fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf29ce484222325u64;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        forall("abs is nonneg", 200, |g| g.f64_in(-100.0, 100.0), |x| x.abs() >= 0.0);
    }

    #[test]
    #[should_panic(expected = "property 'always fails'")]
    fn failing_property_panics_with_seed() {
        forall("always fails", 10, |g| g.f64_in(0.0, 1.0), |_| false);
    }

    #[test]
    fn shrinking_reaches_small_counterexample() {
        // x > 50 fails for large x; shrinker should descend toward ~50..0.
        let shrunk = shrink_loop(1000.0f64, &|x: &f64| *x <= 50.0);
        // 1000 -> 0 passes (0<=50) so first failing candidate chain: 1000->500->250->125->62.5->...
        assert!(shrunk <= 125.0, "shrunk to {shrunk}");
    }

    #[test]
    fn vec_shrink_produces_smaller_vecs() {
        let v: Vec<f64> = (0..8).map(|i| i as f64).collect();
        for c in v.shrink_candidates() {
            assert!(c.len() < v.len());
        }
    }

    #[test]
    fn sizes_grow_over_cases() {
        let mut max_early = 0usize;
        let mut max_late = 0usize;
        forall(
            "size growth probe",
            100,
            |g| {
                let v = g.usize_in(0, 1000);
                if g.size < 0.3 {
                    max_early = max_early.max(v);
                } else {
                    max_late = max_late.max(v);
                }
                v
            },
            |_| true,
        );
        assert!(max_late >= max_early);
    }
}
