//! Deterministic PRNG: xoshiro256++ seeded via SplitMix64.
//!
//! Every stochastic component in the library (data generation, Poisson
//! activation, delay jitter, property tests) draws from this generator so
//! that runs are reproducible from a single `u64` seed.

/// xoshiro256++ (Blackman & Vigna) — fast, high-quality, 256-bit state.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second output of the Box–Muller transform.
    gauss_spare: Option<f64>,
}

/// The complete serializable state of an [`Rng`] — the 256-bit xoshiro
/// state plus the cached Box–Muller spare. Captured into persist
/// snapshots so a resumed run can continue a stream mid-sequence instead
/// of restarting it from its seed.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct RngState {
    /// The xoshiro256++ state words.
    pub s: [u64; 4],
    /// Cached second Box–Muller output, if one is pending.
    pub spare: Option<f64>,
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Seed the generator; any `u64` (including 0) gives a valid state.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, gauss_spare: None }
    }

    /// Derive an independent stream (e.g. one per task node / worker).
    pub fn fork(&mut self, stream: u64) -> Rng {
        Rng::new(self.next_u64() ^ stream.wrapping_mul(0x9E3779B97F4A7C15))
    }

    /// Snapshot the generator's exact state (see [`RngState`]).
    pub fn state(&self) -> RngState {
        RngState { s: self.s, spare: self.gauss_spare }
    }

    /// Rebuild a generator from a captured state: the restored stream
    /// continues bit-for-bit where [`Rng::state`] left off.
    pub fn from_state(state: RngState) -> Rng {
        Rng { s: state.s, gauss_spare: state.spare }
    }

    /// Next raw 64-bit output (xoshiro256++).
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, 1)` with 53 bits of precision.
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in `[lo, hi)`.
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform integer in `[0, n)` (Lemire-style rejection-free enough for
    /// non-crypto use; exact via widening multiply).
    pub fn below(&mut self, n: u64) -> u64 {
        ((self.next_u64() as u128 * n as u128) >> 64) as u64
    }

    /// Uniform integer in `[lo, hi)`.
    pub fn range_u64(&mut self, lo: u64, hi: u64) -> u64 {
        lo + self.below(hi - lo)
    }

    /// Bernoulli(`p`).
    pub fn bool(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box–Muller (pair-cached).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.gauss_spare.take() {
            return z;
        }
        loop {
            let u = self.f64();
            if u <= f64::MIN_POSITIVE {
                continue;
            }
            let v = self.f64();
            let r = (-2.0 * u.ln()).sqrt();
            let (s, c) = (2.0 * std::f64::consts::PI * v).sin_cos();
            self.gauss_spare = Some(r * s);
            return r * c;
        }
    }

    /// Normal with the given mean and standard deviation.
    pub fn normal_scaled(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Exponential with rate `lambda` — inter-arrival times of the Poisson
    /// activation process (Assumption 1 in the paper).
    pub fn exponential(&mut self, lambda: f64) -> f64 {
        debug_assert!(lambda > 0.0);
        loop {
            let u = self.f64();
            if u > 0.0 {
                return -u.ln() / lambda;
            }
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// A random vector of standard normals.
    pub fn normal_vec(&mut self, n: usize) -> Vec<f64> {
        (0..n).map(|_| self.normal()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_given_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 4);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(3);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_is_in_range_and_roughly_uniform() {
        let mut r = Rng::new(4);
        let mut counts = [0usize; 8];
        for _ in 0..80_000 {
            counts[r.below(8) as usize] += 1;
        }
        for &c in &counts {
            assert!((8_000..12_000).contains(&c), "bucket count {c}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(5);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn exponential_mean_matches_rate() {
        let mut r = Rng::new(6);
        let lambda = 2.5;
        let n = 100_000;
        let mean = (0..n).map(|_| r.exponential(lambda)).sum::<f64>() / n as f64;
        assert!((mean - 1.0 / lambda).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn fork_streams_are_decorrelated() {
        let mut root = Rng::new(7);
        let mut a = root.fork(0);
        let mut b = root.fork(1);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 4);
    }

    #[test]
    fn state_roundtrip_resumes_the_stream() {
        let mut a = Rng::new(99);
        for _ in 0..37 {
            a.next_u64();
        }
        a.normal(); // leave a Box–Muller spare cached
        let snap = a.state();
        let mut b = Rng::from_state(snap);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        assert_eq!(a.normal().to_bits(), b.normal().to_bits());
    }

    #[test]
    fn shuffle_is_a_permutation() {
        let mut r = Rng::new(8);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }
}
