//! Fault injection for robustness experiments.
//!
//! The paper motivates AMTL with "high network delay **or even failure**"
//! (§III.B): when one task node fails, every other node in SMTL stalls at
//! the barrier, while AMTL keeps making progress on the remaining blocks.
//! [`FaultModel`] injects per-activation faults so that behaviour is
//! testable:
//!
//! * `DropActivation` — the node's message is lost; the activation performs
//!   no update (retry next activation).
//! * `CrashAfter` — the node dies permanently after a given number of
//!   activations (its block freezes; others continue).
//! * `CrashRestart` — the node dies *silently* for a window of
//!   activations, then comes back: no updates, no heartbeats, no polite
//!   departure — the failure mode only timeout-based eviction
//!   ([`crate::coordinator::registry::NodeRegistry`]) can detect — and on
//!   return it re-registers and resumes its budget.

use crate::util::Rng;

/// What happens to a given activation of a given node.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultOutcome {
    Ok,
    /// The update is lost in transit: skip the update, count a retry.
    Dropped,
    /// The node is dead: stop its loop.
    Crashed,
    /// The node is down for this activation (crash/restart window): no
    /// compute, no update, no heartbeat — silence, until it ends.
    Offline,
}

/// Per-node fault model.
#[derive(Clone, Debug, Default)]
pub enum FaultModel {
    #[default]
    None,
    /// Each activation's update is lost with probability `p`.
    DropActivation { p: f64 },
    /// Node `node` crashes permanently after `after` activations.
    CrashAfter { node: usize, after: u64 },
    /// Node `node` dies silently at activation `down_from` and restarts
    /// `down_for` activations later (a kill-and-resume mid-training).
    CrashRestart { node: usize, down_from: u64, down_for: u64 },
    /// General composition: children are evaluated **in order** and the
    /// first non-[`FaultOutcome::Ok`] outcome wins. Ordering matters for
    /// probabilistic children (a child that returns non-Ok short-circuits
    /// the RNG draws of every child after it), so put deterministic
    /// faults (crashes, restart windows) before random ones (drops) when
    /// reproducibility across fault-set edits matters.
    Compose(Vec<FaultModel>),
}

impl FaultModel {
    /// Outcome for activation number `k` (0-based) of `node`.
    pub fn outcome(&self, node: usize, k: u64, rng: &mut Rng) -> FaultOutcome {
        match self {
            FaultModel::None => FaultOutcome::Ok,
            FaultModel::DropActivation { p } => {
                if rng.bool(*p) {
                    FaultOutcome::Dropped
                } else {
                    FaultOutcome::Ok
                }
            }
            FaultModel::CrashAfter { node: n, after } => {
                if node == *n && k >= *after {
                    FaultOutcome::Crashed
                } else {
                    FaultOutcome::Ok
                }
            }
            FaultModel::CrashRestart { .. } => {
                if self.offline_at(node, k) {
                    FaultOutcome::Offline
                } else {
                    FaultOutcome::Ok
                }
            }
            FaultModel::Compose(children) => {
                for child in children {
                    let o = child.outcome(node, k, rng);
                    if o != FaultOutcome::Ok {
                        return o;
                    }
                }
                FaultOutcome::Ok
            }
        }
    }

    /// The old two-fault shape — a permanent crash of one node plus an
    /// i.i.d. drop storm — expressed as a [`FaultModel::Compose`] with
    /// the crash checked first (preserving the historical RNG-draw
    /// order: no drop probability is consumed on a crashed activation).
    #[deprecated(note = "use FaultModel::Compose for arbitrary fault combinations")]
    pub fn both(drop_p: f64, crash_node: usize, crash_after: u64) -> FaultModel {
        FaultModel::Compose(vec![
            FaultModel::CrashAfter { node: crash_node, after: crash_after },
            FaultModel::DropActivation { p: drop_p },
        ])
    }

    /// True when `node` is inside a silent-down window at activation `k`.
    /// Deterministic (no RNG draw), so the worker loop can check it
    /// *before* engaging schedule machinery — a down node must not
    /// heartbeat, and must not advance a staleness gate.
    pub fn offline_at(&self, node: usize, k: u64) -> bool {
        match self {
            FaultModel::CrashRestart { node: n, down_from, down_for } => {
                node == *n && k >= *down_from && k < down_from.saturating_add(*down_for)
            }
            FaultModel::Compose(children) => children.iter().any(|c| c.offline_at(node, k)),
            _ => false,
        }
    }

    /// True when the model contains a silent crash/restart window (used
    /// by schedule validation: such a window needs heartbeat eviction to
    /// avoid stalling barrier-free bounded-staleness runs).
    pub fn has_silent_window(&self) -> bool {
        match self {
            FaultModel::CrashRestart { .. } => true,
            FaultModel::Compose(children) => children.iter().any(|c| c.has_silent_window()),
            _ => false,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn none_is_always_ok() {
        let mut rng = Rng::new(300);
        for k in 0..100 {
            assert_eq!(FaultModel::None.outcome(0, k, &mut rng), FaultOutcome::Ok);
        }
    }

    #[test]
    fn drop_rate_matches_p() {
        let mut rng = Rng::new(301);
        let m = FaultModel::DropActivation { p: 0.25 };
        let drops = (0..40_000)
            .filter(|&k| m.outcome(0, k, &mut rng) == FaultOutcome::Dropped)
            .count();
        let rate = drops as f64 / 40_000.0;
        assert!((rate - 0.25).abs() < 0.02, "rate {rate}");
    }

    #[test]
    fn crash_is_permanent_and_node_specific() {
        let mut rng = Rng::new(302);
        let m = FaultModel::CrashAfter { node: 1, after: 3 };
        assert_eq!(m.outcome(1, 2, &mut rng), FaultOutcome::Ok);
        assert_eq!(m.outcome(1, 3, &mut rng), FaultOutcome::Crashed);
        assert_eq!(m.outcome(1, 10, &mut rng), FaultOutcome::Crashed);
        assert_eq!(m.outcome(0, 10, &mut rng), FaultOutcome::Ok);
    }

    #[test]
    fn crash_restart_window_is_silent_then_over() {
        let mut rng = Rng::new(304);
        let m = FaultModel::CrashRestart { node: 2, down_from: 3, down_for: 4 };
        assert_eq!(m.outcome(2, 2, &mut rng), FaultOutcome::Ok);
        for k in 3..7 {
            assert_eq!(m.outcome(2, k, &mut rng), FaultOutcome::Offline);
            assert!(m.offline_at(2, k));
        }
        assert_eq!(m.outcome(2, 7, &mut rng), FaultOutcome::Ok);
        assert!(!m.offline_at(2, 7));
        assert_eq!(m.outcome(0, 4, &mut rng), FaultOutcome::Ok, "other nodes unaffected");
        assert!(m.has_silent_window());
        assert!(!FaultModel::None.has_silent_window());
    }

    #[test]
    #[allow(deprecated)]
    fn both_constructor_composes() {
        let mut rng = Rng::new(303);
        let m = FaultModel::both(1.0, 2, 0);
        assert_eq!(m.outcome(2, 0, &mut rng), FaultOutcome::Crashed);
        assert_eq!(m.outcome(1, 0, &mut rng), FaultOutcome::Dropped);
    }

    #[test]
    fn compose_first_non_ok_wins() {
        let mut rng = Rng::new(305);
        // Crash listed before a certain drop: the crash wins on its node.
        let m = FaultModel::Compose(vec![
            FaultModel::CrashAfter { node: 0, after: 0 },
            FaultModel::DropActivation { p: 1.0 },
        ]);
        assert_eq!(m.outcome(0, 5, &mut rng), FaultOutcome::Crashed);
        assert_eq!(m.outcome(1, 5, &mut rng), FaultOutcome::Dropped);
        // Reversed order: the drop shadows the crash everywhere.
        let m = FaultModel::Compose(vec![
            FaultModel::DropActivation { p: 1.0 },
            FaultModel::CrashAfter { node: 0, after: 0 },
        ]);
        assert_eq!(m.outcome(0, 5, &mut rng), FaultOutcome::Dropped);
    }

    #[test]
    fn compose_short_circuits_rng_draws() {
        // A non-Ok child must stop evaluation before later probabilistic
        // children consume RNG state, so per-node fault targeting does
        // not perturb other nodes' drop sequences.
        let drop = FaultModel::DropActivation { p: 0.5 };
        let m = FaultModel::Compose(vec![
            FaultModel::CrashRestart { node: 0, down_from: 0, down_for: u64::MAX },
            drop.clone(),
        ]);
        let mut rng_a = Rng::new(306);
        let mut rng_b = Rng::new(306);
        for k in 0..200 {
            // Node 0 is offline: no draw happens, outcome deterministic.
            assert_eq!(m.outcome(0, k, &mut rng_a), FaultOutcome::Offline);
            // Node 1 sees exactly the plain drop model's sequence.
            assert_eq!(m.outcome(1, k, &mut rng_a), drop.outcome(1, k, &mut rng_b));
        }
    }

    #[test]
    fn compose_targets_nodes_independently() {
        let mut rng = Rng::new(307);
        let m = FaultModel::Compose(vec![
            FaultModel::CrashRestart { node: 1, down_from: 2, down_for: 3 },
            FaultModel::CrashRestart { node: 4, down_from: 0, down_for: 2 },
            FaultModel::CrashAfter { node: 7, after: 6 },
        ]);
        assert!(m.offline_at(1, 3) && !m.offline_at(1, 5));
        assert!(m.offline_at(4, 1) && !m.offline_at(4, 2));
        assert!(!m.offline_at(2, 3), "untargeted node never offline");
        assert_eq!(m.outcome(7, 6, &mut rng), FaultOutcome::Crashed);
        assert_eq!(m.outcome(7, 5, &mut rng), FaultOutcome::Ok);
        assert_eq!(m.outcome(2, 10, &mut rng), FaultOutcome::Ok);
        assert!(m.has_silent_window());
        assert!(!FaultModel::Compose(vec![FaultModel::DropActivation { p: 0.1 }])
            .has_silent_window());
        assert!(FaultModel::Compose(vec![]).outcome(0, 0, &mut rng) == FaultOutcome::Ok);
    }
}
