//! Fault injection for robustness experiments.
//!
//! The paper motivates AMTL with "high network delay **or even failure**"
//! (§III.B): when one task node fails, every other node in SMTL stalls at
//! the barrier, while AMTL keeps making progress on the remaining blocks.
//! [`FaultModel`] injects per-activation faults so that behaviour is
//! testable:
//!
//! * `DropActivation` — the node's message is lost; the activation performs
//!   no update (retry next activation).
//! * `CrashAfter` — the node dies permanently after a given number of
//!   activations (its block freezes; others continue).
//! * `CrashRestart` — the node dies *silently* for a window of
//!   activations, then comes back: no updates, no heartbeats, no polite
//!   departure — the failure mode only timeout-based eviction
//!   ([`crate::coordinator::registry::NodeRegistry`]) can detect — and on
//!   return it re-registers and resumes its budget.

use crate::util::Rng;

/// What happens to a given activation of a given node.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultOutcome {
    Ok,
    /// The update is lost in transit: skip the update, count a retry.
    Dropped,
    /// The node is dead: stop its loop.
    Crashed,
    /// The node is down for this activation (crash/restart window): no
    /// compute, no update, no heartbeat — silence, until it ends.
    Offline,
}

/// Per-node fault model.
#[derive(Clone, Debug, Default)]
pub enum FaultModel {
    #[default]
    None,
    /// Each activation's update is lost with probability `p`.
    DropActivation { p: f64 },
    /// Node `node` crashes permanently after `after` activations.
    CrashAfter { node: usize, after: u64 },
    /// Node `node` dies silently at activation `down_from` and restarts
    /// `down_for` activations later (a kill-and-resume mid-training).
    CrashRestart { node: usize, down_from: u64, down_for: u64 },
    /// Compose: first matching non-Ok outcome wins.
    Both { drop_p: f64, crash_node: usize, crash_after: u64 },
}

impl FaultModel {
    /// Outcome for activation number `k` (0-based) of `node`.
    pub fn outcome(&self, node: usize, k: u64, rng: &mut Rng) -> FaultOutcome {
        match self {
            FaultModel::None => FaultOutcome::Ok,
            FaultModel::DropActivation { p } => {
                if rng.bool(*p) {
                    FaultOutcome::Dropped
                } else {
                    FaultOutcome::Ok
                }
            }
            FaultModel::CrashAfter { node: n, after } => {
                if node == *n && k >= *after {
                    FaultOutcome::Crashed
                } else {
                    FaultOutcome::Ok
                }
            }
            FaultModel::CrashRestart { .. } => {
                if self.offline_at(node, k) {
                    FaultOutcome::Offline
                } else {
                    FaultOutcome::Ok
                }
            }
            FaultModel::Both { drop_p, crash_node, crash_after } => {
                if node == *crash_node && k >= *crash_after {
                    FaultOutcome::Crashed
                } else if rng.bool(*drop_p) {
                    FaultOutcome::Dropped
                } else {
                    FaultOutcome::Ok
                }
            }
        }
    }

    /// True when `node` is inside a silent-down window at activation `k`.
    /// Deterministic (no RNG draw), so the worker loop can check it
    /// *before* engaging schedule machinery — a down node must not
    /// heartbeat, and must not advance a staleness gate.
    pub fn offline_at(&self, node: usize, k: u64) -> bool {
        match self {
            FaultModel::CrashRestart { node: n, down_from, down_for } => {
                node == *n && k >= *down_from && k < down_from.saturating_add(*down_for)
            }
            _ => false,
        }
    }

    /// True when the model contains a silent crash/restart window (used
    /// by schedule validation: such a window needs heartbeat eviction to
    /// avoid stalling barrier-free bounded-staleness runs).
    pub fn has_silent_window(&self) -> bool {
        matches!(self, FaultModel::CrashRestart { .. })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn none_is_always_ok() {
        let mut rng = Rng::new(300);
        for k in 0..100 {
            assert_eq!(FaultModel::None.outcome(0, k, &mut rng), FaultOutcome::Ok);
        }
    }

    #[test]
    fn drop_rate_matches_p() {
        let mut rng = Rng::new(301);
        let m = FaultModel::DropActivation { p: 0.25 };
        let drops = (0..40_000)
            .filter(|&k| m.outcome(0, k, &mut rng) == FaultOutcome::Dropped)
            .count();
        let rate = drops as f64 / 40_000.0;
        assert!((rate - 0.25).abs() < 0.02, "rate {rate}");
    }

    #[test]
    fn crash_is_permanent_and_node_specific() {
        let mut rng = Rng::new(302);
        let m = FaultModel::CrashAfter { node: 1, after: 3 };
        assert_eq!(m.outcome(1, 2, &mut rng), FaultOutcome::Ok);
        assert_eq!(m.outcome(1, 3, &mut rng), FaultOutcome::Crashed);
        assert_eq!(m.outcome(1, 10, &mut rng), FaultOutcome::Crashed);
        assert_eq!(m.outcome(0, 10, &mut rng), FaultOutcome::Ok);
    }

    #[test]
    fn crash_restart_window_is_silent_then_over() {
        let mut rng = Rng::new(304);
        let m = FaultModel::CrashRestart { node: 2, down_from: 3, down_for: 4 };
        assert_eq!(m.outcome(2, 2, &mut rng), FaultOutcome::Ok);
        for k in 3..7 {
            assert_eq!(m.outcome(2, k, &mut rng), FaultOutcome::Offline);
            assert!(m.offline_at(2, k));
        }
        assert_eq!(m.outcome(2, 7, &mut rng), FaultOutcome::Ok);
        assert!(!m.offline_at(2, 7));
        assert_eq!(m.outcome(0, 4, &mut rng), FaultOutcome::Ok, "other nodes unaffected");
        assert!(m.has_silent_window());
        assert!(!FaultModel::None.has_silent_window());
    }

    #[test]
    fn both_composes() {
        let mut rng = Rng::new(303);
        let m = FaultModel::Both { drop_p: 1.0, crash_node: 2, crash_after: 0 };
        assert_eq!(m.outcome(2, 0, &mut rng), FaultOutcome::Crashed);
        assert_eq!(m.outcome(1, 0, &mut rng), FaultOutcome::Dropped);
    }
}
