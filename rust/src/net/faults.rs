//! Fault injection for robustness experiments.
//!
//! The paper motivates AMTL with "high network delay **or even failure**"
//! (§III.B): when one task node fails, every other node in SMTL stalls at
//! the barrier, while AMTL keeps making progress on the remaining blocks.
//! [`FaultModel`] injects per-activation faults so that behaviour is
//! testable:
//!
//! * `DropActivation` — the node's message is lost; the activation performs
//!   no update (retry next activation).
//! * `CrashAfter` — the node dies permanently after a given number of
//!   activations (its block freezes; others continue).

use crate::util::Rng;

/// What happens to a given activation of a given node.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultOutcome {
    Ok,
    /// The update is lost in transit: skip the update, count a retry.
    Dropped,
    /// The node is dead: stop its loop.
    Crashed,
}

/// Per-node fault model.
#[derive(Clone, Debug, Default)]
pub enum FaultModel {
    #[default]
    None,
    /// Each activation's update is lost with probability `p`.
    DropActivation { p: f64 },
    /// Node `node` crashes permanently after `after` activations.
    CrashAfter { node: usize, after: u64 },
    /// Compose: first matching non-Ok outcome wins.
    Both { drop_p: f64, crash_node: usize, crash_after: u64 },
}

impl FaultModel {
    /// Outcome for activation number `k` (0-based) of `node`.
    pub fn outcome(&self, node: usize, k: u64, rng: &mut Rng) -> FaultOutcome {
        match self {
            FaultModel::None => FaultOutcome::Ok,
            FaultModel::DropActivation { p } => {
                if rng.bool(*p) {
                    FaultOutcome::Dropped
                } else {
                    FaultOutcome::Ok
                }
            }
            FaultModel::CrashAfter { node: n, after } => {
                if node == *n && k >= *after {
                    FaultOutcome::Crashed
                } else {
                    FaultOutcome::Ok
                }
            }
            FaultModel::Both { drop_p, crash_node, crash_after } => {
                if node == *crash_node && k >= *crash_after {
                    FaultOutcome::Crashed
                } else if rng.bool(*drop_p) {
                    FaultOutcome::Dropped
                } else {
                    FaultOutcome::Ok
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn none_is_always_ok() {
        let mut rng = Rng::new(300);
        for k in 0..100 {
            assert_eq!(FaultModel::None.outcome(0, k, &mut rng), FaultOutcome::Ok);
        }
    }

    #[test]
    fn drop_rate_matches_p() {
        let mut rng = Rng::new(301);
        let m = FaultModel::DropActivation { p: 0.25 };
        let drops = (0..40_000)
            .filter(|&k| m.outcome(0, k, &mut rng) == FaultOutcome::Dropped)
            .count();
        let rate = drops as f64 / 40_000.0;
        assert!((rate - 0.25).abs() < 0.02, "rate {rate}");
    }

    #[test]
    fn crash_is_permanent_and_node_specific() {
        let mut rng = Rng::new(302);
        let m = FaultModel::CrashAfter { node: 1, after: 3 };
        assert_eq!(m.outcome(1, 2, &mut rng), FaultOutcome::Ok);
        assert_eq!(m.outcome(1, 3, &mut rng), FaultOutcome::Crashed);
        assert_eq!(m.outcome(1, 10, &mut rng), FaultOutcome::Crashed);
        assert_eq!(m.outcome(0, 10, &mut rng), FaultOutcome::Ok);
    }

    #[test]
    fn both_composes() {
        let mut rng = Rng::new(303);
        let m = FaultModel::Both { drop_p: 1.0, crash_node: 2, crash_after: 0 };
        assert_eq!(m.outcome(2, 0, &mut rng), FaultOutcome::Crashed);
        assert_eq!(m.outcome(1, 0, &mut rng), FaultOutcome::Dropped);
    }
}
