//! Per-task-node communication-delay models.

use crate::util::Rng;
use std::time::Duration;

/// How long a task node's round trip (receive model → send update) is
/// delayed by the simulated network, per activation.
#[derive(Clone, Debug)]
pub enum DelayModel {
    /// No injected delay (pure compute timing).
    None,
    /// `offset + U(0, jitter)` per activation (bounded jitter).
    OffsetJitter { offset: Duration, jitter: Duration },
    /// The paper's model: "the sum of the offset and a random value" —
    /// offset plus an exponential random component with the given mean.
    /// AMTL-k in the tables uses `offset = k` (paper: seconds; here scaled
    /// by the run's `time_scale`). The heavy-ish tail is what makes the
    /// synchronous barrier's `E[max over T nodes]` grow with T.
    OffsetExp { offset: Duration, mean: Duration },
    /// Exponential inter-activation gaps — task nodes as independent
    /// Poisson processes with a given rate (Assumption 1).
    Poisson { mean: Duration },
    /// Heterogeneous: node `i` uses `per_node[i % len]` — models a network
    /// where some hospitals sit behind slow links (used by the straggler
    /// ablation and the dynamic-step-size experiments).
    PerNode { per_node: Vec<Box<DelayModel>> },
}

/// A sampled delay plus the bookkeeping the dynamic-step-size controller
/// needs (Eq. III.6 averages the recent delays per node).
#[derive(Clone, Copy, Debug)]
pub struct DelaySample {
    /// The injected wall-clock delay for this activation.
    pub duration: Duration,
}

impl DelayModel {
    /// The paper's AMTL-k / SMTL-k network setting: offset `k` (in the
    /// scaled time unit) plus an exponential random component with mean
    /// `k/2`.
    pub fn paper_offset(offset: Duration) -> DelayModel {
        DelayModel::OffsetExp { offset, mean: offset.mul_f64(0.5) }
    }

    /// Sample the delay for task node `node` at activation `k`.
    pub fn sample(&self, node: usize, rng: &mut Rng) -> DelaySample {
        let duration = match self {
            DelayModel::None => Duration::ZERO,
            DelayModel::OffsetJitter { offset, jitter } => {
                *offset + jitter.mul_f64(rng.f64())
            }
            DelayModel::OffsetExp { offset, mean } => {
                let extra = if mean.is_zero() {
                    Duration::ZERO
                } else {
                    Duration::from_secs_f64(rng.exponential(1.0 / mean.as_secs_f64()))
                };
                *offset + extra
            }
            DelayModel::Poisson { mean } => {
                // Exponential with mean `mean`.
                Duration::from_secs_f64(rng.exponential(1.0 / mean.as_secs_f64().max(1e-12)))
            }
            DelayModel::PerNode { per_node } => {
                // An empty table means "no injected delay" rather than a
                // mod-by-zero panic: chaos plans build these tables
                // programmatically and may legitimately produce no entries.
                return match per_node.get(node % per_node.len().max(1)) {
                    Some(m) => m.sample(node, rng),
                    None => DelaySample { duration: Duration::ZERO },
                };
            }
        };
        DelaySample { duration }
    }

    /// Expected delay (for reporting/sanity checks).
    pub fn mean(&self, node: usize) -> Duration {
        match self {
            DelayModel::None => Duration::ZERO,
            DelayModel::OffsetJitter { offset, jitter } => *offset + jitter.mul_f64(0.5),
            DelayModel::OffsetExp { offset, mean } => *offset + *mean,
            DelayModel::Poisson { mean } => *mean,
            DelayModel::PerNode { per_node } => per_node
                .get(node % per_node.len().max(1))
                .map_or(Duration::ZERO, |m| m.mean(node)),
        }
    }
}

/// Rolling per-node delay history — feeds the dynamic step size
/// (Eq. III.6: mean of the last `window` delays).
#[derive(Clone, Debug)]
pub struct NodeDelays {
    window: usize,
    /// Ring buffer of the most recent delays, per node, in the *time unit*
    /// of the experiment (the paper uses seconds).
    recent: Vec<Vec<f64>>,
}

impl NodeDelays {
    /// Tracker for `nodes` nodes with a rolling `window` per node.
    pub fn new(nodes: usize, window: usize) -> NodeDelays {
        NodeDelays { window, recent: vec![Vec::new(); nodes] }
    }

    /// Record one observed delay (paper units) for `node`.
    pub fn record(&mut self, node: usize, delay_units: f64) {
        let buf = &mut self.recent[node];
        buf.push(delay_units);
        if buf.len() > self.window {
            let excess = buf.len() - self.window;
            buf.drain(..excess);
        }
    }

    /// Mean of the last `window` delays for `node` (ν̄ in Eq. III.6);
    /// zero if nothing recorded yet.
    pub fn recent_mean(&self, node: usize) -> f64 {
        let buf = &self.recent[node];
        if buf.is_empty() {
            0.0
        } else {
            buf.iter().sum::<f64>() / buf.len() as f64
        }
    }

    /// Number of delays currently in `node`'s window.
    pub fn count(&self, node: usize) -> usize {
        self.recent[node].len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn none_is_zero() {
        let mut rng = Rng::new(80);
        let d = DelayModel::None.sample(0, &mut rng);
        assert_eq!(d.duration, Duration::ZERO);
    }

    #[test]
    fn offset_jitter_within_bounds() {
        let mut rng = Rng::new(81);
        let m = DelayModel::OffsetJitter {
            offset: Duration::from_millis(50),
            jitter: Duration::from_millis(25),
        };
        for _ in 0..1000 {
            let d = m.sample(0, &mut rng).duration;
            assert!(d >= Duration::from_millis(50));
            assert!(d <= Duration::from_millis(75));
        }
    }

    #[test]
    fn paper_offset_mean_is_offset_plus_half() {
        let m = DelayModel::paper_offset(Duration::from_millis(100));
        // offset + E[Exp(offset/2)] = 100 + 50 ms
        assert_eq!(m.mean(0), Duration::from_millis(150));
    }

    #[test]
    fn offset_exp_samples_at_least_offset_with_matching_mean() {
        let mut rng = Rng::new(85);
        let m = DelayModel::paper_offset(Duration::from_millis(40));
        let n = 20_000;
        let mut total = 0.0;
        for _ in 0..n {
            let d = m.sample(0, &mut rng).duration;
            assert!(d >= Duration::from_millis(40));
            total += d.as_secs_f64();
        }
        let mean = total / n as f64;
        assert!((mean - 0.060).abs() < 0.002, "mean {mean}");
    }

    #[test]
    fn poisson_sample_mean_converges() {
        let mut rng = Rng::new(82);
        let m = DelayModel::Poisson { mean: Duration::from_millis(20) };
        let n = 20_000;
        let total: f64 = (0..n)
            .map(|_| m.sample(0, &mut rng).duration.as_secs_f64())
            .sum();
        let mean = total / n as f64;
        assert!((mean - 0.020).abs() < 0.001, "mean {mean}");
    }

    #[test]
    fn per_node_routes_by_index() {
        let m = DelayModel::PerNode {
            per_node: vec![
                Box::new(DelayModel::None),
                Box::new(DelayModel::OffsetJitter {
                    offset: Duration::from_millis(10),
                    jitter: Duration::ZERO,
                }),
            ],
        };
        let mut rng = Rng::new(83);
        assert_eq!(m.sample(0, &mut rng).duration, Duration::ZERO);
        assert_eq!(m.sample(1, &mut rng).duration, Duration::from_millis(10));
        assert_eq!(m.sample(2, &mut rng).duration, Duration::ZERO); // wraps
        assert_eq!(m.mean(1), Duration::from_millis(10));
    }

    #[test]
    fn node_delays_window_and_mean() {
        let mut nd = NodeDelays::new(2, 3);
        assert_eq!(nd.recent_mean(0), 0.0);
        for v in [1.0, 2.0, 3.0, 4.0] {
            nd.record(0, v);
        }
        // Window of 3 keeps [2,3,4].
        assert_eq!(nd.count(0), 3);
        assert!((nd.recent_mean(0) - 3.0).abs() < 1e-12);
        // Node 1 untouched.
        assert_eq!(nd.count(1), 0);
    }
}
