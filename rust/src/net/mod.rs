//! Simulated star-network substrate.
//!
//! The paper simulates the distributed environment on shared memory and
//! injects communication delays at the task nodes (§IV.A): "the amount of
//! delay was computed as the sum of the offset and a random value", where
//! the offset models the network infrastructure (AMTL-5/-10/-30 = 5/10/30 s
//! offsets). [`DelayModel`] reproduces exactly that, plus a Poisson
//! activation model matching Assumption 1, and heterogeneous/straggler
//! profiles for the robustness experiments.

mod delay;
mod faults;

pub use delay::{DelayModel, DelaySample, NodeDelays};
pub use faults::{FaultModel, FaultOutcome};
