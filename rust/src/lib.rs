//! # AMTL — Asynchronous Multi-Task Learning
//!
//! Reproduction of *"Asynchronous Multi-Task Learning"* (Baytas, Yan, Jain,
//! Zhou, 2016) as a three-layer rust + JAX + Pallas system:
//!
//! * **Layer 3 (this crate)** — the asynchronous coordinator: central server
//!   applying the proximal (backward) step, task-node workers applying
//!   forward (gradient) steps with no barrier, per Algorithm 1 / ARock.
//! * **Layer 2/1 (python, build-time only)** — the per-task compute as JAX
//!   functions over Pallas kernels, AOT-lowered to HLO text artifacts that
//!   the [`runtime`] module loads and executes via PJRT. Python is never on
//!   the update path.
//!
//! The coordinator exposes one entry point: a [`coordinator::Session`]
//! built over a shared [`coordinator::RunConfig`], a pluggable
//! [`coordinator::Schedule`] — [`coordinator::Async`] (Algorithm 1),
//! [`coordinator::Synchronized`] (§III.B barrier rounds), or
//! [`coordinator::SemiSync`] (bounded staleness) — and a pluggable
//! [`transport::Transport`] connecting task nodes to the central server.
//!
//! ## The open formulation layer
//!
//! The math is open-world ([`optim::formulation`]): the coupling
//! regularizer is a [`optim::SharedProx`] trait object (prox, value,
//! incremental hooks, persist-state hooks) and the per-task smooth loss
//! a [`optim::TaskLoss`] impl, both resolved by name through a registry
//! ([`optim::FormulationSpec`], CLI `--reg name[:k=v,...]`). Registered
//! formulations: `nuclear`, `l21`, `l1`, `elasticnet`, `none`
//! ([`optim::prox`]), plus graph-Laplacian relationship coupling and
//! mean-regularized clustering ([`optim::coupling`]) — every one runs
//! under every schedule, both transports, and survives
//! checkpoint/`--resume` through its own opaque state blob.
//!
//! ## The transport layer
//!
//! The paper's deployment premise is that task data is too large or too
//! private to move; only model vectors travel. The [`transport`] module
//! makes that edge real:
//!
//! * [`transport::InProc`] — shared-memory calls (the default; identical
//!   to the pre-transport coordinator, bit for bit).
//! * [`transport::TcpClient`] / [`transport::TcpServer`] — a versioned,
//!   checksummed, length-prefixed binary protocol ([`transport::wire`])
//!   over `std::net` TCP. `Session::builder(..).transport(Tcp)` runs any
//!   schedule over loopback sockets, and the `amtl` CLI runs the two
//!   halves as separate OS processes: `amtl --serve <addr>` hosts the
//!   central server, `amtl --node <t> --connect <addr>` runs one task
//!   node that owns only its task's data. Prox columns, update vectors,
//!   and scalars cross the wire; `(X_t, y_t)` provably cannot — the
//!   protocol has no frame type for data.
//!
//! ## The server hot path
//!
//! The backward step is where a central server melts under load, so it is
//! engineered for throughput (measured in `rust/benches/perf_step.rs`,
//! documented in `docs/PERFORMANCE.md`):
//!
//! * [`linalg`] matmul/gram kernels are blocked across a worker pool —
//!   `--threads` / `PALLAS_THREADS` — with bitwise-identical serial
//!   fallback (a chunked axpy for long spans ships alongside);
//! * the nuclear prox is **incremental by default**: Brand rank-1 column
//!   updates ([`optim::svd::OnlineSvd`]) instead of a full Jacobi SVD per
//!   prox, re-anchored exactly every `--resvd-every` commits;
//! * shared state and commit bookkeeping are sharded per task column, so
//!   concurrent `PushUpdate`/`FetchProxCol` traffic never serializes on a
//!   server-wide lock, and back-to-back commits from one task coalesce.
//!
//! ## Durability & elastic membership
//!
//! A production run must survive its own infrastructure ([`persist`],
//! [`coordinator::registry`], `docs/ARCHITECTURE.md` § "Durability &
//! membership"):
//!
//! * the central server checkpoints to disk — versioned, checksummed
//!   snapshots plus a commit WAL fsync'd before each acknowledgement —
//!   and `amtl --serve … --checkpoint-dir D` can be SIGKILL'd and
//!   restarted with `--resume`, recovering bitwise-identical state for a
//!   sequential run (snapshot + WAL replay);
//! * commits carry the node's activation counter, so at-least-once
//!   transport retries and post-restart replays are **exactly-once**;
//! * task nodes `Register`, `Heartbeat`, and `Leave` over the wire; a
//!   node that dies silently is evicted on a timeout (`--heartbeat-ms`)
//!   and stops gating every schedule, and a restarted node rejoins and
//!   catches up from its applied-commit horizon.
//!
//! ## Observability
//!
//! Every layer reports into one std-only observability subsystem
//! ([`obs`], `docs/OBSERVABILITY.md`): a process-wide
//! [`obs::MetricsRegistry`] of named counters/gauges/log₂ histograms
//! (activation timing splits, commit staleness, prox/WAL/checkpoint
//! latencies, transport retries, replica lag), a leveled logger behind
//! the `log_error!` .. `log_trace!` macros (`--log-level` / `AMTL_LOG`),
//! and an opt-in per-run JSONL trace (`--trace-out`). The registry is
//! exported over the wire by the `FetchMetrics → MetricsReport` frame
//! pair — answered by both the trainer and the replica — and rendered
//! live by `amtl top --connect <addr>`.
//!
//! ## The serving tier
//!
//! Trained models answer queries without touching the training hot path
//! ([`serve`]): a read replica bootstraps from the newest snapshot, tails
//! the trainer's WAL at byte offsets, hot-swaps across checkpoint
//! rotations, and serves `Predict { t, x } → ŷ = ⟨w_t, x⟩` over the same
//! wire codec — `amtl --replica <addr> --follow <dir>` runs one, `amtl
//! predict` queries it, and `examples/load_gen.rs` measures it under
//! load while training runs live.
//!
//! ## The chaos harness
//!
//! Fault tolerance is asserted, not assumed ([`chaos`], `docs/TESTING.md`):
//! a [`chaos::ChaosPlan`] materializes a seed-reproducible storm —
//! correlated crash/restart waves, per-activation drops, straggler links —
//! over a swarm of task nodes, runs it alongside an undisturbed reference,
//! and [`chaos::check_invariants`] machine-checks the evidence for
//! exactly-once commit application, convergence within tolerance,
//! balanced eviction/re-register bookkeeping, and the semi-sync staleness
//! bound. Every failure reproduces from one printed seed
//! (`cargo run --example chaos_run -- --quick`; `AMTL_SOAK=1` for soaks).
//!
//! Also see the `amtl` CLI (`rust/src/main.rs`), the runnable
//! `examples/`, and `docs/ARCHITECTURE.md` for the paper-to-code map.

#![warn(missing_docs)]

pub mod chaos;
pub mod config;
pub mod coordinator;
pub mod experiments;
pub mod data;
pub mod linalg;
pub mod net;
pub mod obs;
pub mod optim;
pub mod persist;
pub mod runtime;
pub mod serve;
pub mod shard;
pub mod transport;
pub mod util;
