//! # AMTL — Asynchronous Multi-Task Learning
//!
//! Reproduction of *"Asynchronous Multi-Task Learning"* (Baytas, Yan, Jain,
//! Zhou, 2016) as a three-layer rust + JAX + Pallas system:
//!
//! * **Layer 3 (this crate)** — the asynchronous coordinator: central server
//!   applying the proximal (backward) step, task-node workers applying
//!   forward (gradient) steps with no barrier, per Algorithm 1 / ARock.
//! * **Layer 2/1 (python, build-time only)** — the per-task compute as JAX
//!   functions over Pallas kernels, AOT-lowered to HLO text artifacts that
//!   the [`runtime`] module loads and executes via PJRT. Python is never on
//!   the update path.
//!
//! The coordinator exposes one entry point: a [`coordinator::Session`]
//! built over a shared [`coordinator::RunConfig`] and a pluggable
//! [`coordinator::Schedule`] — [`coordinator::Async`] (Algorithm 1),
//! [`coordinator::Synchronized`] (§III.B barrier rounds), or
//! [`coordinator::SemiSync`] (bounded staleness). The old forked drivers
//! survive as deprecated shims (`run_amtl` / `run_smtl`). Also see the
//! `amtl` CLI (`rust/src/main.rs`) and the runnable `examples/`.

pub mod config;
pub mod coordinator;
pub mod experiments;
pub mod data;
pub mod linalg;
pub mod net;
pub mod optim;
pub mod runtime;
pub mod util;
