//! # AMTL — Asynchronous Multi-Task Learning
//!
//! Reproduction of *"Asynchronous Multi-Task Learning"* (Baytas, Yan, Jain,
//! Zhou, 2016) as a three-layer rust + JAX + Pallas system:
//!
//! * **Layer 3 (this crate)** — the asynchronous coordinator: central server
//!   applying the proximal (backward) step, task-node workers applying
//!   forward (gradient) steps with no barrier, per Algorithm 1 / ARock.
//! * **Layer 2/1 (python, build-time only)** — the per-task compute as JAX
//!   functions over Pallas kernels, AOT-lowered to HLO text artifacts that
//!   the [`runtime`] module loads and executes via PJRT. Python is never on
//!   the update path.
//!
//! Entry points: [`coordinator::amtl::run_amtl`], [`coordinator::smtl::run_smtl`],
//! the `amtl` CLI (`rust/src/main.rs`), and the runnable `examples/`.

pub mod config;
pub mod coordinator;
pub mod experiments;
pub mod data;
pub mod linalg;
pub mod net;
pub mod optim;
pub mod runtime;
pub mod util;
