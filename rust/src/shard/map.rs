//! The shard map: a static, versioned assignment of contiguous task/column
//! ranges to prox shards.
//!
//! Sharding the central server partitions the shared matrix `V` (d × T)
//! **by columns**: shard `i` owns the contiguous task range
//! `starts[i] .. starts[i+1]` and runs the full `CentralServer` machinery
//! (staging, dedup, prox cache, snapshot + WAL) over its own `d × cols(i)`
//! slice. The map is the single routing truth shared by every party:
//!
//! * task-node routers ([`TcpShardRouter`](crate::shard::TcpShardRouter))
//!   fetch it over the `FetchShardMap` wire frame and direct each
//!   `FetchProxCol`/`PushUpdate` to the owning shard;
//! * shards validate incoming **global** task indices against their own
//!   range and translate to local columns;
//! * recovery validates the on-disk map against `--shard i/N` so a
//!   resumed shard cannot silently rejoin with a different partition.
//!
//! The assignment is *static* for the lifetime of a run (`version` exists
//! so a future rebalancing map can be told apart from a stale one), which
//! keeps the bitwise-reproducibility story of separable formulations
//! intact: ownership never moves, so each column's commit order is decided
//! by exactly one shard.

use crate::transport::wire::{fnv1a32, Cursor, WireError};
use anyhow::{bail, Context, Result};
use std::path::Path;

/// Magic prefix of the on-disk `SHARDMAP` file.
const FILE_MAGIC: [u8; 8] = *b"AMTLSMAP";
/// Name of the map file inside the parent checkpoint directory.
pub const SHARDMAP_FILE: &str = "SHARDMAP";

/// Versioned, contiguous-range assignment of task columns to prox shards.
///
/// Invariants (checked by [`ShardMap::validate`], enforced by every
/// constructor and decoder): `starts` has exactly `addrs.len() + 1`
/// entries, `starts[0] == 0`, and the sequence is non-decreasing. The last
/// entry is the total task count T. A shard may own an empty range (more
/// shards than tasks); routers simply never send it algorithmic traffic.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ShardMap {
    /// Map generation; a router refuses to mix replies from different map
    /// versions. Static assignment means this is 1 for every run today.
    pub version: u64,
    /// Feature dimension d of every column (shards validate it agrees).
    pub d: u32,
    /// Range boundaries: shard `i` owns global tasks
    /// `starts[i] .. starts[i+1]`. Length is shard count + 1.
    pub starts: Vec<u32>,
    /// Dial address of each shard's serve loop, index-aligned with the
    /// ranges. Empty strings for in-proc groups (nothing to dial).
    pub addrs: Vec<String>,
}

impl ShardMap {
    /// The canonical balanced partition: T tasks over `n` shards in
    /// contiguous ranges, the first `T mod n` shards taking one extra
    /// column. Addresses start empty (in-proc); fill them in for a
    /// cross-process fleet via [`ShardMap::with_addrs`].
    pub fn uniform(d: usize, tasks: usize, n: usize) -> ShardMap {
        assert!(n > 0, "shard count must be positive");
        let base = tasks / n;
        let extra = tasks % n;
        let mut starts = Vec::with_capacity(n + 1);
        let mut at = 0u32;
        starts.push(0);
        for i in 0..n {
            at += base as u32 + u32::from(i < extra);
            starts.push(at);
        }
        ShardMap { version: 1, d: d as u32, starts, addrs: vec![String::new(); n] }
    }

    /// Same map with shard dial addresses filled in (cross-process runs).
    pub fn with_addrs(mut self, addrs: Vec<String>) -> Result<ShardMap> {
        if addrs.len() != self.shards() {
            bail!("{} addresses for {} shards", addrs.len(), self.shards());
        }
        self.addrs = addrs;
        Ok(self)
    }

    /// Number of shards.
    pub fn shards(&self) -> usize {
        self.addrs.len()
    }

    /// Total task count T (the last range boundary).
    pub fn tasks(&self) -> usize {
        *self.starts.last().expect("starts is never empty") as usize
    }

    /// Global task range owned by shard `i`.
    pub fn range(&self, i: usize) -> std::ops::Range<usize> {
        self.starts[i] as usize..self.starts[i + 1] as usize
    }

    /// Column count of shard `i`'s slice.
    pub fn cols(&self, i: usize) -> usize {
        self.range(i).len()
    }

    /// Which shard owns global task `t`, if any.
    pub fn owner(&self, t: usize) -> Option<usize> {
        if t >= self.tasks() {
            return None;
        }
        // partition_point: first boundary strictly greater than t, minus
        // one, lands on the owning range even when earlier ranges are
        // empty (equal boundaries sort before the occupied range).
        let i = self.starts.partition_point(|&s| s as usize <= t) - 1;
        debug_assert!(self.range(i).contains(&t));
        Some(i)
    }

    /// Translate global task `t` to `(shard, local column)`.
    pub fn local(&self, t: usize) -> Option<(usize, usize)> {
        let i = self.owner(t)?;
        Some((i, t - self.starts[i] as usize))
    }

    /// Structural invariants; every decode path runs this.
    pub fn validate(&self) -> Result<(), &'static str> {
        if self.addrs.is_empty() {
            return Err("shard map has zero shards");
        }
        if self.starts.len() != self.addrs.len() + 1 {
            return Err("shard map boundary count does not match shard count");
        }
        if self.starts[0] != 0 {
            return Err("shard map ranges must start at task 0");
        }
        if self.starts.windows(2).any(|w| w[0] > w[1]) {
            return Err("shard map ranges must be non-decreasing");
        }
        Ok(())
    }

    // ------------------------------------------------------ wire codec

    /// Append the wire payload encoding (shared by the `ShardMap` response
    /// frame and the on-disk `SHARDMAP` file).
    pub(crate) fn push(&self, out: &mut Vec<u8>) {
        out.extend_from_slice(&self.version.to_le_bytes());
        out.extend_from_slice(&self.d.to_le_bytes());
        out.extend_from_slice(&(self.addrs.len() as u32).to_le_bytes());
        for s in &self.starts {
            out.extend_from_slice(&s.to_le_bytes());
        }
        for a in &self.addrs {
            out.extend_from_slice(&(a.len() as u32).to_le_bytes());
            out.extend_from_slice(a.as_bytes());
        }
    }

    /// Parse a wire payload (no count-based preallocation: corrupted
    /// counts must run out of payload, not memory).
    pub(crate) fn parse(c: &mut Cursor<'_>) -> Result<ShardMap, WireError> {
        let version = c.u64()?;
        let d = c.u32()?;
        let n = c.u32()?;
        let mut starts = Vec::new();
        for _ in 0..=n {
            starts.push(c.u32()?);
        }
        let mut addrs = Vec::new();
        for _ in 0..n {
            let len = c.u32()? as usize;
            let s = String::from_utf8(c.take(len)?.to_vec())
                .map_err(|_| WireError::Malformed("shard address is not utf-8"))?;
            addrs.push(s);
        }
        let map = ShardMap { version, d, starts, addrs };
        map.validate().map_err(WireError::Malformed)?;
        Ok(map)
    }

    // ------------------------------------------------------ disk format

    /// Write the map as `dir/SHARDMAP` (magic ‖ len ‖ payload ‖ fnv crc —
    /// the WAL/wire framing discipline). `dir` is the *parent* checkpoint
    /// directory whose `shard-i/` children hold the per-shard stores;
    /// `--resume` validates the resumed shard's `--shard i/N` against it.
    pub fn save(&self, dir: &Path) -> Result<()> {
        std::fs::create_dir_all(dir)
            .with_context(|| format!("creating shard-map dir {}", dir.display()))?;
        let mut payload = Vec::new();
        self.push(&mut payload);
        let len = (payload.len() as u32).to_le_bytes();
        let crc = fnv1a32(&[&len, &payload]).to_le_bytes();
        let mut out = Vec::with_capacity(16 + payload.len());
        out.extend_from_slice(&FILE_MAGIC);
        out.extend_from_slice(&len);
        out.extend_from_slice(&payload);
        out.extend_from_slice(&crc);
        let path = dir.join(SHARDMAP_FILE);
        std::fs::write(&path, &out)
            .with_context(|| format!("writing shard map {}", path.display()))?;
        Ok(())
    }

    /// Load and verify `dir/SHARDMAP`.
    pub fn load(dir: &Path) -> Result<ShardMap> {
        let path = dir.join(SHARDMAP_FILE);
        let bytes = std::fs::read(&path)
            .with_context(|| format!("reading shard map {}", path.display()))?;
        if bytes.len() < 16 || bytes[..8] != FILE_MAGIC {
            bail!("{} is not a shard-map file", path.display());
        }
        let len = u32::from_le_bytes([bytes[8], bytes[9], bytes[10], bytes[11]]) as usize;
        if bytes.len() != 16 + len {
            bail!("{}: truncated shard-map file", path.display());
        }
        let body = &bytes[12..12 + len];
        let want = u32::from_le_bytes([
            bytes[12 + len],
            bytes[13 + len],
            bytes[14 + len],
            bytes[15 + len],
        ]);
        let got = fnv1a32(&[&bytes[8..12], body]);
        if got != want {
            bail!("{}: shard-map checksum mismatch", path.display());
        }
        let mut c = Cursor::new(body);
        let map = ShardMap::parse(&mut c)
            .map_err(|e| anyhow::anyhow!("{}: {e}", path.display()))?;
        c.finish().map_err(|e| anyhow::anyhow!("{}: {e}", path.display()))?;
        Ok(map)
    }

    /// Subdirectory (under the parent checkpoint dir) holding shard `i`'s
    /// own snapshot + WAL store.
    pub fn shard_dir(dir: &Path, i: usize) -> std::path::PathBuf {
        dir.join(format!("shard-{i}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::forall;

    #[test]
    fn uniform_partition_is_balanced_and_total() {
        for (d, t, n) in [(3, 7, 2), (1, 1, 1), (4, 10, 3), (2, 5, 5), (2, 3, 4), (8, 0, 2)] {
            let m = ShardMap::uniform(d, t, n);
            m.validate().unwrap();
            assert_eq!(m.shards(), n);
            assert_eq!(m.tasks(), t);
            let total: usize = (0..n).map(|i| m.cols(i)).sum();
            assert_eq!(total, t);
            // Balanced: no shard more than one column bigger than another.
            let sizes: Vec<usize> = (0..n).map(|i| m.cols(i)).collect();
            let (lo, hi) = (sizes.iter().min().unwrap(), sizes.iter().max().unwrap());
            assert!(hi - lo <= 1, "unbalanced partition {sizes:?}");
        }
    }

    #[test]
    fn owner_and_local_cover_every_task() {
        let m = ShardMap::uniform(4, 10, 3); // ranges 0..4, 4..7, 7..10
        assert_eq!(m.range(0), 0..4);
        assert_eq!(m.range(1), 4..7);
        assert_eq!(m.range(2), 7..10);
        for t in 0..10 {
            let i = m.owner(t).unwrap();
            assert!(m.range(i).contains(&t));
            let (shard, local) = m.local(t).unwrap();
            assert_eq!(shard, i);
            assert_eq!(m.starts[i] as usize + local, t);
        }
        assert_eq!(m.owner(10), None);
        assert_eq!(m.local(11), None);
    }

    #[test]
    fn empty_ranges_route_around() {
        // 4 shards over 3 tasks: the last shard owns nothing.
        let m = ShardMap::uniform(2, 3, 4);
        assert_eq!(m.cols(3), 0);
        for t in 0..3 {
            assert_eq!(m.owner(t), Some(t)); // one task per occupied shard
        }
    }

    #[test]
    fn prop_owner_agrees_with_linear_scan() {
        forall(
            "shard-map owner matches linear range scan",
            80,
            |g| {
                let t = g.usize_in(0, 40);
                let n = g.usize_in(1, 8);
                let probe = g.usize_in(0, 45);
                (t, n, probe)
            },
            |&(t, n, probe)| {
                let m = ShardMap::uniform(3, t, n);
                let linear = (0..n).find(|&i| m.range(i).contains(&probe));
                m.owner(probe) == linear
            },
        );
    }

    #[test]
    fn save_load_roundtrip_and_corruption_detection() {
        let dir =
            std::env::temp_dir().join(format!("amtl_shardmap_{}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        let m = ShardMap::uniform(5, 9, 2)
            .with_addrs(vec!["127.0.0.1:7401".into(), "127.0.0.1:7402".into()])
            .unwrap();
        m.save(&dir).unwrap();
        assert_eq!(ShardMap::load(&dir).unwrap(), m);
        // Flip one payload byte: load must fail on the checksum.
        let path = dir.join(SHARDMAP_FILE);
        let mut bytes = std::fs::read(&path).unwrap();
        let last = bytes.len() - 5; // inside payload, before crc
        bytes[last] ^= 0x40;
        std::fs::write(&path, &bytes).unwrap();
        assert!(ShardMap::load(&dir).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn with_addrs_rejects_wrong_count() {
        assert!(ShardMap::uniform(2, 4, 2).with_addrs(vec!["a".into()]).is_err());
    }
}
