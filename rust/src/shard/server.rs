//! The shard server half: one [`ProxShard`] wraps a [`CentralServer`]
//! over a contiguous column slice of the shared model `V`, and a
//! [`ShardGroup`] assembles `N` of them into a whole-model parameter
//! server — including the coordination round that non-separable
//! formulations need (quiesce → gather → full-matrix prox → scatter).
//!
//! ## Separable vs. coordinated shards
//!
//! When the formulation's prox is column-separable
//! ([`SharedProx::is_separable`] — elementwise proxes: `l1`,
//! `elasticnet`, `none`), each shard simply runs the *same* regularizer
//! over its own slice: the slice of the full-matrix prox equals the prox
//! of the slice, so shards never need to talk to each other and the
//! merged model is bitwise identical to a single-server run.
//!
//! When it is not (`nuclear`, `l21`, `graph`, `mean` — anything whose
//! prox couples columns), each shard's *inner* regularizer is the
//! identity (`none` with the formulation's λ, so persisted state remains
//! honest), and the group periodically runs a **coordination round**:
//! every shard is quiesced through its checkpoint gate, raw slices are
//! gathered into the full `d×T` matrix, the true prox is applied once,
//! and the result is scattered back as each shard's serving cache.
//! Between rounds, fetches are answered from that cache — the sharded
//! analogue of the single server's `--prox-every` reuse window.

use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, RwLock};

use anyhow::{bail, Context, Result};

use crate::coordinator::server::CentralServer;
use crate::coordinator::state::SharedState;
use crate::linalg::Mat;
use crate::optim::prox::ZeroProx;
use crate::optim::SharedProx;
use crate::persist::{self, Checkpointer, PersistConfig};

use super::map::ShardMap;

/// Default commit stride between coordination rounds for non-separable
/// formulations (mirrors the single server's re-SVD cadence).
pub const DEFAULT_COORD_EVERY: u64 = 64;

/// The cached result of the last coordination round on a non-separable
/// shard: this shard's columns of the full-matrix prox.
struct CoordCache {
    /// `Some(W_slice)` once the first round has run.
    w: RwLock<Option<Mat>>,
    /// Round counter of the installed slice (0 = none yet).
    round: AtomicU64,
}

/// One column-partitioned prox shard: a [`CentralServer`] over
/// `cols(index)` columns of the shared model, addressed by **global**
/// task index (requests for tasks outside its range are errors, not
/// silent misroutes).
pub struct ProxShard {
    index: usize,
    start: usize,
    map: Arc<ShardMap>,
    server: Arc<CentralServer>,
    coord: Option<CoordCache>,
}

impl ProxShard {
    /// A fresh shard `index` of `map`, applying `proto`'s formulation
    /// with prox step `eta`. With `persist = Some((dir, every))` the
    /// shard checkpoints under `dir/shard-<index>/` on that snapshot
    /// stride.
    pub fn create(
        map: Arc<ShardMap>,
        index: usize,
        proto: &dyn SharedProx,
        eta: f64,
        persist: Option<(&Path, u64)>,
    ) -> Result<ProxShard> {
        ProxShard::build(map, index, proto, eta, persist, false)
    }

    /// Recover shard `index` from its own `dir/shard-<index>/`
    /// checkpoint directory (snapshot + WAL replay). Fails if the
    /// on-disk `SHARDMAP` disagrees with `map` — resuming under a
    /// different shard count would scramble column ownership.
    pub fn resume(
        map: Arc<ShardMap>,
        index: usize,
        proto: &dyn SharedProx,
        eta: f64,
        dir: &Path,
        every: u64,
    ) -> Result<ProxShard> {
        ProxShard::build(map, index, proto, eta, Some((dir, every)), true)
    }

    fn build(
        map: Arc<ShardMap>,
        index: usize,
        proto: &dyn SharedProx,
        eta: f64,
        persist: Option<(&Path, u64)>,
        resume: bool,
    ) -> Result<ProxShard> {
        if index >= map.shards() {
            bail!("shard index {index} out of range ({} shards)", map.shards());
        }
        map.validate().map_err(|e| anyhow::anyhow!("invalid shard map: {e}"))?;
        let range = map.range(index);
        let (start, cols) = (range.start, range.len());
        let d = map.d as usize;
        let separable = proto.is_separable();
        let expect_reg: &'static str = if separable { proto.id() } else { "none" };

        let server = if resume {
            let (dir, every) =
                persist.expect("resume requires a checkpoint directory");
            let disk = ShardMap::load(dir).with_context(|| {
                format!("cannot resume: no readable SHARDMAP under {}", dir.display())
            })?;
            if disk.d != map.d || disk.starts != map.starts {
                bail!(
                    "--resume shard layout mismatch: on-disk map has {} shards over \
                     {} tasks (d = {}), this run asked for {} shards over {} tasks \
                     (d = {}); restart with the original --shards value",
                    disk.shards(),
                    disk.tasks(),
                    disk.d,
                    map.shards(),
                    map.tasks(),
                    map.d
                );
            }
            let sdir = ShardMap::shard_dir(dir, index);
            if !persist::has_checkpoint(&sdir) {
                bail!("shard {index}: no checkpoint under {}", sdir.display());
            }
            let rec = persist::recover(PersistConfig::new(&sdir, every))
                .with_context(|| format!("recovering shard {index}"))?;
            let srv = rec.server;
            if srv.state().d() != d || srv.state().t() != cols {
                bail!(
                    "shard {index}: recovered state is {}×{}, shard map says {}×{}",
                    srv.state().d(),
                    srv.state().t(),
                    d,
                    cols
                );
            }
            if srv.reg_id() != expect_reg {
                bail!(
                    "shard {index}: recovered regularizer `{}` != expected `{}`",
                    srv.reg_id(),
                    expect_reg
                );
            }
            srv.with_node_base(start)
        } else {
            let inner: Box<dyn SharedProx> = if separable {
                proto.clone_box()
            } else {
                Box::new(ZeroProx::new(proto.lambda()))
            };
            let state = Arc::new(SharedState::zeros(d, cols));
            let mut srv = CentralServer::new(state, inner, eta).with_node_base(start);
            if let Some((dir, every)) = persist {
                let sdir = ShardMap::shard_dir(dir, index);
                let cp = Checkpointer::create(PersistConfig::new(&sdir, every))
                    .with_context(|| format!("creating shard {index} checkpointer"))?;
                srv = srv.with_checkpointer(Arc::new(cp))?;
            }
            srv
        };

        let coord = if separable {
            None
        } else {
            Some(CoordCache { w: RwLock::new(None), round: AtomicU64::new(0) })
        };
        Ok(ProxShard { index, start, map, server: Arc::new(server), coord })
    }

    /// This shard's index within the map.
    pub fn index(&self) -> usize {
        self.index
    }

    /// The shard map this shard was built against.
    pub fn map(&self) -> &Arc<ShardMap> {
        &self.map
    }

    /// The global task range `[start, end)` this shard owns.
    pub fn range(&self) -> std::ops::Range<usize> {
        self.map.range(self.index)
    }

    /// The wrapped per-slice central server (persist hooks, metrics,
    /// registry and wire serving all reach the shard through this).
    pub fn server(&self) -> &Arc<CentralServer> {
        &self.server
    }

    /// Whether this shard answers fetches from a coordination-round
    /// cache (non-separable formulation) rather than its own prox.
    pub fn is_coordinated(&self) -> bool {
        self.coord.is_some()
    }

    /// Coordination rounds installed on this shard so far.
    pub fn round(&self) -> u64 {
        self.coord.as_ref().map(|c| c.round.load(Ordering::Acquire)).unwrap_or(0)
    }

    /// Translate a global task index into this shard's local column,
    /// erroring on tasks owned elsewhere (the router should never send
    /// them here) or out of range.
    pub fn local(&self, t: usize) -> Result<usize> {
        match self.map.local(t) {
            Some((s, lt)) if s == self.index => Ok(lt),
            Some((s, _)) => bail!(
                "task {t} is owned by shard {s}, not shard {} — stale shard map?",
                self.index
            ),
            None => bail!("task {t} out of range ({} tasks)", self.map.tasks()),
        }
    }

    /// The backward step for global task `t`: the shard's own prox
    /// column (separable), or the latest coordination-round cache column
    /// (non-separable; the raw column before the first round).
    pub fn fetch_prox_col(&self, t: usize) -> Result<Vec<f64>> {
        let lt = self.local(t)?;
        // Always drive the inner server's fetch path so staleness and
        // fetch-version bookkeeping stay live on coordinated shards too.
        let own = self.server.prox_col(lt);
        if let Some(c) = &self.coord {
            if let Some(w) = c.w.read().unwrap().as_ref() {
                return Ok(w.col(lt).to_vec());
            }
        }
        Ok(own)
    }

    /// Commit a forward-step result for global task `t` (KM relaxation,
    /// exactly-once on the node's activation counter `k`). Returns the
    /// shard's new version (its own KM update count).
    pub fn commit(&self, t: usize, k: u64, step: f64, u: &[f64]) -> Result<u64> {
        let lt = self.local(t)?;
        self.server.commit_update(lt, k, u, step)
    }

    /// Commits already applied for global task `t` (resume horizon).
    pub fn applied_commits(&self, t: usize) -> Result<u64> {
        Ok(self.server.applied_commits(self.local(t)?))
    }

    /// Register global task `t` with this shard's membership registry.
    pub fn register(&self, t: usize) -> Result<crate::transport::RegisterAck> {
        let lt = self.local(t)?;
        Ok(self.server.register_node(lt))
    }

    /// A consistent `(version, V_slice)` snapshot of this shard's raw
    /// state for a coordination round: commits are held off through the
    /// checkpoint quiesce gate while the columns are copied (shards
    /// without durability fall back to the per-column-consistent
    /// snapshot, which the round's fixed-point semantics tolerate).
    pub fn raw_slice(&self) -> (u64, Mat) {
        let _quiesced = self.server.checkpointer().map(|cp| cp.quiesce());
        (self.server.state().version(), self.server.state().snapshot())
    }

    /// Install the result of coordination round `round`: this shard's
    /// columns of the full-matrix prox. Errors on separable shards (no
    /// cache to fill) and on shape mismatches.
    pub fn install_round(&self, round: u64, w: Mat) -> Result<()> {
        let c = self
            .coord
            .as_ref()
            .ok_or_else(|| anyhow::anyhow!("shard {} is separable: no coordination cache", self.index))?;
        let cols = self.map.cols(self.index);
        if w.rows() != self.map.d as usize || w.cols() != cols {
            bail!(
                "round slice is {}×{}, shard {} expects {}×{}",
                w.rows(),
                w.cols(),
                self.index,
                self.map.d,
                cols
            );
        }
        *c.w.write().unwrap() = Some(w);
        c.round.fetch_max(round, Ordering::AcqRel);
        Ok(())
    }

    /// This shard's final model slice after training: its own prox
    /// (separable) or its coordination cache (falling back to the raw
    /// slice before any round has run).
    pub fn final_slice(&self) -> Mat {
        if let Some(c) = &self.coord {
            if let Some(w) = c.w.read().unwrap().as_ref() {
                return w.clone();
            }
            return self.server.state().snapshot();
        }
        self.server.final_w()
    }

    /// Global task index of this shard's column 0.
    pub fn base(&self) -> usize {
        self.start
    }
}

/// An in-process group of [`ProxShard`]s acting as one whole-model
/// parameter server: routes by global task index, counts commits, and
/// runs the coordination round on its stride for non-separable
/// formulations. This is what `amtl train --shards N` drives, and the
/// reference semantics for the multi-process deployment (where each
/// shard is its own `amtl serve --shard i/N` and shard 0 drives the
/// rounds over the wire).
pub struct ShardGroup {
    map: Arc<ShardMap>,
    shards: Vec<Arc<ProxShard>>,
    eta: f64,
    separable: bool,
    full_reg: Mutex<Box<dyn SharedProx>>,
    coord_every: u64,
    commits: AtomicU64,
    rounds_run: AtomicU64,
    round_gate: Mutex<()>,
}

impl ShardGroup {
    /// An in-memory group: `n` shards uniformly partitioning `tasks`
    /// columns of a `d`-row model, applying `proto` with prox step
    /// `eta`. `coord_every` is the commit stride between coordination
    /// rounds (ignored for separable formulations).
    pub fn new(
        d: usize,
        tasks: usize,
        n: usize,
        proto: Box<dyn SharedProx>,
        eta: f64,
        coord_every: u64,
    ) -> Result<ShardGroup> {
        ShardGroup::build(Arc::new(ShardMap::uniform(d, tasks, n)), proto, eta, coord_every, None, false)
    }

    /// Like [`ShardGroup::new`] but durable: writes `SHARDMAP` under
    /// `dir` and gives every shard its own `dir/shard-<i>/`
    /// checkpoint directory with snapshot stride `every`.
    pub fn durable(
        d: usize,
        tasks: usize,
        n: usize,
        proto: Box<dyn SharedProx>,
        eta: f64,
        coord_every: u64,
        dir: &Path,
        every: u64,
    ) -> Result<ShardGroup> {
        let map = Arc::new(ShardMap::uniform(d, tasks, n));
        std::fs::create_dir_all(dir)?;
        map.save(dir)?;
        ShardGroup::build(map, proto, eta, coord_every, Some((dir, every)), false)
    }

    /// Recover a durable group from `dir`: every shard replays its own
    /// snapshot + WAL. The shard count is validated against the on-disk
    /// `SHARDMAP`.
    pub fn resume(
        d: usize,
        tasks: usize,
        n: usize,
        proto: Box<dyn SharedProx>,
        eta: f64,
        coord_every: u64,
        dir: &Path,
        every: u64,
    ) -> Result<ShardGroup> {
        let map = Arc::new(ShardMap::uniform(d, tasks, n));
        ShardGroup::build(map, proto, eta, coord_every, Some((dir, every)), true)
    }

    fn build(
        map: Arc<ShardMap>,
        proto: Box<dyn SharedProx>,
        eta: f64,
        coord_every: u64,
        persist: Option<(&Path, u64)>,
        resume: bool,
    ) -> Result<ShardGroup> {
        let separable = proto.is_separable();
        let mut shards = Vec::with_capacity(map.shards());
        for i in 0..map.shards() {
            let shard = if resume {
                let (dir, every) = persist.expect("resume requires a directory");
                ProxShard::resume(Arc::clone(&map), i, proto.as_ref(), eta, dir, every)?
            } else {
                ProxShard::create(Arc::clone(&map), i, proto.as_ref(), eta, persist)?
            };
            shards.push(Arc::new(shard));
        }
        let group = ShardGroup {
            map,
            shards,
            eta,
            separable,
            full_reg: Mutex::new(proto),
            coord_every: coord_every.max(1),
            commits: AtomicU64::new(0),
            rounds_run: AtomicU64::new(0),
            round_gate: Mutex::new(()),
        };
        if !group.separable {
            // Round 0: seed every coordination cache so the first fetch
            // already sees a true full-matrix prox (on resume this is
            // what rebuilds the serving view from the recovered slices).
            group.run_round()?;
        }
        Ok(group)
    }

    /// The group's shard map.
    pub fn map(&self) -> &Arc<ShardMap> {
        &self.map
    }

    /// The shards, index-aligned with the map.
    pub fn shards(&self) -> &[Arc<ProxShard>] {
        &self.shards
    }

    /// Shard `i`.
    pub fn shard(&self, i: usize) -> &Arc<ProxShard> {
        &self.shards[i]
    }

    /// The run's forward step size η.
    pub fn eta(&self) -> f64 {
        self.eta
    }

    /// Whether the formulation shards without coordination rounds.
    pub fn is_separable(&self) -> bool {
        self.separable
    }

    /// Total commits routed through the group.
    pub fn total_commits(&self) -> u64 {
        self.commits.load(Ordering::Acquire)
    }

    /// Coordination rounds run so far (0 for separable formulations).
    pub fn rounds(&self) -> u64 {
        self.rounds_run.load(Ordering::Acquire)
    }

    fn owner(&self, t: usize) -> Result<usize> {
        self.map
            .owner(t)
            .ok_or_else(|| anyhow::anyhow!("task {t} out of range ({} tasks)", self.map.tasks()))
    }

    /// Route a backward-step fetch to the owning shard.
    pub fn fetch_prox_col(&self, t: usize) -> Result<Vec<f64>> {
        self.shards[self.owner(t)?].fetch_prox_col(t)
    }

    /// Route a KM commit to the owning shard; crossing the coordination
    /// stride triggers a round for non-separable formulations.
    pub fn commit(&self, t: usize, k: u64, step: f64, u: &[f64]) -> Result<u64> {
        let version = self.shards[self.owner(t)?].commit(t, k, step, u)?;
        let n = self.commits.fetch_add(1, Ordering::AcqRel) + 1;
        if !self.separable && n % self.coord_every == 0 {
            self.run_round()?;
        }
        Ok(version)
    }

    /// Route a registration to the owning shard.
    pub fn register(&self, t: usize) -> Result<crate::transport::RegisterAck> {
        self.shards[self.owner(t)?].register(t)
    }

    /// Commits already applied for task `t` (resume horizon).
    pub fn applied_commits(&self, t: usize) -> Result<u64> {
        self.shards[self.owner(t)?].applied_commits(t)
    }

    /// Run one coordination round now: quiesce and gather every shard's
    /// raw slice, apply the true full-matrix prox once, scatter the
    /// result back as each shard's serving cache. Serialized — a round
    /// triggered while another is in flight waits its turn.
    pub fn run_round(&self) -> Result<()> {
        let _serialized = self.round_gate.lock().unwrap();
        let full = self.gather();
        let mut w = full;
        {
            let mut reg = self.full_reg.lock().unwrap();
            reg.prox(&mut w, self.eta);
        }
        let round = self.rounds_run.load(Ordering::Acquire) + 1;
        for (i, shard) in self.shards.iter().enumerate() {
            let range = self.map.range(i);
            let mut slice = Mat::zeros(w.rows(), range.len());
            for (local, global) in range.enumerate() {
                slice.set_col(local, w.col(global));
            }
            shard.install_round(round, slice)?;
        }
        self.rounds_run.store(round, Ordering::Release);
        Ok(())
    }

    fn gather(&self) -> Mat {
        let d = self.map.d as usize;
        let mut full = Mat::zeros(d, self.map.tasks());
        for (i, shard) in self.shards.iter().enumerate() {
            let (_version, slice) = shard.raw_slice();
            for (local, global) in self.map.range(i).enumerate() {
                full.set_col(global, slice.col(local));
            }
        }
        full
    }

    /// The merged raw iterate `V` (concatenated shard slices).
    pub fn merged_v(&self) -> Mat {
        self.gather()
    }

    /// The merged final model `W = Prox_{ηλg}(V)`: concatenated per-shard
    /// proxes when separable (bitwise the slice of the full prox), one
    /// exact full-matrix prox over the gathered `V` otherwise.
    pub fn merged_w(&self) -> Mat {
        if self.separable {
            let d = self.map.d as usize;
            let mut w = Mat::zeros(d, self.map.tasks());
            for (i, shard) in self.shards.iter().enumerate() {
                let slice = shard.final_slice();
                for (local, global) in self.map.range(i).enumerate() {
                    w.set_col(global, slice.col(local));
                }
            }
            w
        } else {
            let mut w = self.gather();
            let mut reg = self.full_reg.lock().unwrap();
            reg.prox(&mut w, self.eta);
            w
        }
    }

    /// fsync every shard's in-flight WAL writes (no-op without
    /// durability).
    pub fn sync_persist(&self) -> Result<()> {
        for shard in &self.shards {
            shard.server().sync_persist()?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optim::coupling::MeanProx;
    use crate::optim::prox::L1Prox;

    fn tmp(tag: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir()
            .join(format!("amtl_shardsrv_{}_{}", tag, std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        dir
    }

    /// Deterministic pseudo-update for task t, activation k.
    fn update(d: usize, t: usize, k: u64) -> Vec<f64> {
        (0..d).map(|r| ((t + 1) * (r + 2)) as f64 * 0.1 + k as f64 * 0.01).collect()
    }

    #[test]
    fn separable_group_matches_single_server_bitwise() {
        let (d, tasks, lambda, eta) = (4, 5, 0.3, 0.5);
        let group =
            ShardGroup::new(d, tasks, 2, Box::new(L1Prox::new(lambda)), eta, 8).unwrap();
        let single = CentralServer::new(
            Arc::new(SharedState::zeros(d, tasks)),
            Box::new(L1Prox::new(lambda)),
            eta,
        );
        for k in 0..6u64 {
            for t in 0..tasks {
                let u = update(d, t, k);
                group.commit(t, k, 0.7, &u).unwrap();
                single.commit_update(t, k, &u, 0.7).unwrap();
                assert_eq!(group.fetch_prox_col(t).unwrap(), single.prox_col(t));
            }
        }
        let merged = group.merged_w();
        let reference = single.final_w();
        assert_eq!(merged.data(), reference.data(), "separable shard merge must be bitwise");
        assert_eq!(group.rounds(), 0, "separable formulations never coordinate");
        assert_eq!(group.total_commits(), 6 * tasks as u64);
    }

    #[test]
    fn coordinated_group_runs_rounds_and_tracks_full_prox() {
        let (d, tasks, eta) = (3, 4, 0.5);
        let group =
            ShardGroup::new(d, tasks, 2, Box::new(MeanProx::new(0.4)), eta, 4).unwrap();
        assert!(!group.is_separable());
        assert_eq!(group.rounds(), 1, "construction seeds round 0");
        for k in 0..4u64 {
            for t in 0..tasks {
                group.commit(t, k, 0.9, &update(d, t, k)).unwrap();
            }
        }
        // 16 commits at stride 4 → 4 in-run rounds on top of the seed.
        assert_eq!(group.rounds(), 5);
        // The serving cache equals the exact full prox of the gathered V.
        let mut expect = group.merged_v();
        MeanProx::new(0.4).prox(&mut expect, eta);
        for t in 0..tasks {
            assert_eq!(group.fetch_prox_col(t).unwrap(), expect.col(t).to_vec());
        }
        assert_eq!(group.merged_w().data(), expect.data());
    }

    #[test]
    fn durable_group_resumes_bitwise() {
        let dir = tmp("resume");
        let (d, tasks, eta) = (3, 5, 0.5);
        let reg = || Box::new(L1Prox::new(0.2));
        {
            let group = ShardGroup::durable(d, tasks, 2, reg(), eta, 8, &dir, 64).unwrap();
            for k in 0..5u64 {
                for t in 0..tasks {
                    group.commit(t, k, 0.8, &update(d, t, k)).unwrap();
                }
            }
            group.sync_persist().unwrap();
            // Dropped without checkpoint_now: recovery must replay WALs.
        }
        let recovered = ShardGroup::resume(d, tasks, 2, reg(), eta, 8, &dir, 64).unwrap();
        let live = ShardGroup::new(d, tasks, 2, reg(), eta, 8).unwrap();
        for k in 0..5u64 {
            for t in 0..tasks {
                live.commit(t, k, 0.8, &update(d, t, k)).unwrap();
            }
        }
        assert_eq!(recovered.merged_v().data(), live.merged_v().data());
        assert_eq!(recovered.merged_w().data(), live.merged_w().data());
        for t in 0..tasks {
            assert_eq!(recovered.applied_commits(t).unwrap(), 5);
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn resume_rejects_changed_shard_count() {
        let dir = tmp("layout");
        {
            let group =
                ShardGroup::durable(3, 4, 2, Box::new(L1Prox::new(0.2)), 0.5, 8, &dir, 64)
                    .unwrap();
            group.commit(0, 0, 0.8, &[1.0, 2.0, 3.0]).unwrap();
            group.sync_persist().unwrap();
        }
        let err = ShardGroup::resume(3, 4, 3, Box::new(L1Prox::new(0.2)), 0.5, 8, &dir, 64)
            .unwrap_err();
        assert!(err.to_string().contains("layout mismatch"), "got: {err:#}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn shard_rejects_foreign_and_out_of_range_tasks() {
        let map = Arc::new(ShardMap::uniform(3, 4, 2));
        let shard =
            ProxShard::create(Arc::clone(&map), 0, &L1Prox::new(0.1), 0.5, None).unwrap();
        assert!(shard.fetch_prox_col(0).is_ok());
        assert!(shard.fetch_prox_col(2).is_err(), "task 2 belongs to shard 1");
        assert!(shard.fetch_prox_col(9).is_err(), "task 9 out of range");
        assert!(shard.commit(3, 0, 0.5, &[0.0; 3]).is_err());
    }
}
