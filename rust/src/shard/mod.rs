//! The sharded central server: column-partitioned prox shards behind a
//! shard-map router (`docs/ARCHITECTURE.md` § "Sharded server").
//!
//! One central server eventually saturates — every `PushUpdate` and
//! `FetchProxCol` of every task lands on one process. This subsystem
//! splits the shared model `V ∈ R^{d×T}` **by task column** into `N`
//! contiguous ranges, each owned by its own prox shard (a full
//! [`CentralServer`](crate::coordinator::server::CentralServer) over the
//! slice: same commit staging, dedup, WAL + snapshots, metrics — just
//! fewer columns). A versioned [`ShardMap`] records the partition and
//! each shard's address; workers fetch it once (`FetchShardMap`) and
//! route every fetch/commit **directly** to the owning shard — there is
//! no head node on the hot path.
//!
//! The regularizer decides the coupling story
//! ([`SharedProx::is_separable`](crate::optim::SharedProx::is_separable)):
//!
//! * **Separable** (elementwise proxes — `l1`, `elasticnet`, `none`):
//!   each shard applies the real regularizer to its own slice and the
//!   merged model is *bitwise* the single-server result; shards never
//!   communicate.
//! * **Non-separable** (`nuclear`, `l21`, `graph`, `mean`): shards run
//!   an identity prox locally and the group periodically executes a
//!   coordination round — quiesce every shard (through its checkpoint
//!   gate), gather slices into the full matrix, apply the true prox
//!   once, scatter the result back as each shard's serving cache.
//!
//! Module layout: [`map`] (the partition + `SHARDMAP` file), [`server`]
//! ([`ProxShard`], [`ShardGroup`]), [`router`] (worker-side
//! [`Transport`](crate::transport::Transport) impls), [`run`] (the
//! `amtl train --shards N` driver).

pub mod map;
pub mod router;
pub mod run;
pub mod server;

pub use map::{ShardMap, SHARDMAP_FILE};
pub use router::{ShardRouter, TcpShardRouter};
pub use run::{run_sharded, ShardRunConfig, ShardRunResult};
pub use server::{ProxShard, ShardGroup, DEFAULT_COORD_EVERY};
