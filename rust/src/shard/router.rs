//! The worker-side shard routers: [`Transport`] impls that consult a
//! [`ShardMap`] and send each fetch/commit straight to the shard that
//! owns the task's column — no proxy hop through a head node.
//!
//! * [`ShardRouter`] — in-process: routes into an [`Arc<ShardGroup>`];
//!   what `amtl train --shards N` wires its workers over.
//! * [`TcpShardRouter`] — multi-process: one lazily-connected
//!   [`TcpClient`] per shard (each with its own reconnect/backoff
//!   state), bootstrapped by fetching the shard map from any live
//!   shard (`FetchShardMap`). [`Transport::push_batch`] groups a batch
//!   by owning shard and issues one `PushBatch` frame per shard.

use std::sync::Arc;

use anyhow::{anyhow, bail, Result};

use crate::transport::wire::BatchUpdate;
use crate::transport::{RegisterAck, TcpClient, TcpOptions, Transport};

use super::map::ShardMap;
use super::server::ShardGroup;

/// In-process router: the worker side of a sharded `amtl train` run.
/// Cloning-by-construction — every worker gets its own `ShardRouter`
/// over the same group, mirroring how TCP workers each own a socket.
pub struct ShardRouter {
    group: Arc<ShardGroup>,
}

impl ShardRouter {
    /// A router over `group`.
    pub fn new(group: Arc<ShardGroup>) -> ShardRouter {
        ShardRouter { group }
    }
}

impl Transport for ShardRouter {
    fn eta(&self) -> f64 {
        self.group.eta()
    }

    fn fetch_prox_col(&mut self, t: usize) -> Result<Vec<f64>> {
        self.group.fetch_prox_col(t)
    }

    fn push_update(&mut self, t: usize, k: u64, step: f64, u: &[f64]) -> Result<u64> {
        self.group.commit(t, k, step, u)
    }

    fn register(&mut self, t: usize) -> Result<RegisterAck> {
        self.group.register(t)
    }
}

/// Multi-process router: connects task nodes to a fleet of `amtl serve
/// --shard i/N` processes. Connections are made lazily per shard and
/// re-established by the underlying [`TcpClient`] retry machinery, so
/// one dead shard only stalls the tasks it owns.
pub struct TcpShardRouter {
    map: ShardMap,
    opts: TcpOptions,
    clients: Vec<Option<TcpClient>>,
    eta: f64,
}

impl TcpShardRouter {
    /// Bootstrap from seed addresses (the CLI's `--connect a,b,…`):
    /// fetch the shard map from the first reachable seed, then route
    /// all traffic by ownership. When the served map carries no
    /// addresses (shards started without `--shard-peers`), the seeds
    /// themselves are taken as the per-shard addresses, in index order
    /// — so `--connect` must then list every shard.
    pub fn connect(seeds: &[String], opts: TcpOptions) -> Result<TcpShardRouter> {
        let mut last: Option<anyhow::Error> = None;
        for seed in seeds {
            let mut client = match TcpClient::connect(seed.as_str(), opts) {
                Ok(c) => c,
                Err(e) => {
                    last = Some(e);
                    continue;
                }
            };
            let eta = client.eta();
            match client.fetch_shard_map() {
                Ok(map) => {
                    let map = if map.addrs.iter().all(|a| a.is_empty()) {
                        if seeds.len() != map.shards() {
                            bail!(
                                "shard map has {} shards but {} addresses were given; \
                                 list every shard in --connect (or start shards with \
                                 --shard-peers)",
                                map.shards(),
                                seeds.len()
                            );
                        }
                        map.with_addrs(seeds.to_vec())?
                    } else {
                        map
                    };
                    return TcpShardRouter::from_map(map, opts, eta);
                }
                Err(e) => last = Some(e),
            }
        }
        Err(last.unwrap_or_else(|| anyhow!("no seed addresses given")))
    }

    /// A router over an explicit map (each `map.addrs[i]` must name
    /// shard `i`'s listening address).
    pub fn from_map(map: ShardMap, opts: TcpOptions, eta: f64) -> Result<TcpShardRouter> {
        map.validate().map_err(|e| anyhow!("invalid shard map: {e}"))?;
        if map.addrs.len() != map.shards() {
            bail!("shard map carries {} addresses for {} shards", map.addrs.len(), map.shards());
        }
        let clients = (0..map.shards()).map(|_| None).collect();
        Ok(TcpShardRouter { map, opts, clients, eta })
    }

    /// The routing table this router was bootstrapped with.
    pub fn map(&self) -> &ShardMap {
        &self.map
    }

    fn owner(&self, t: usize) -> Result<usize> {
        self.map
            .owner(t)
            .ok_or_else(|| anyhow!("task {t} out of range ({} tasks)", self.map.tasks()))
    }

    fn client_for_shard(&mut self, s: usize) -> Result<&mut TcpClient> {
        if self.clients[s].is_none() {
            let addr = &self.map.addrs[s];
            if addr.is_empty() {
                bail!("shard {s} has no address in the shard map");
            }
            self.clients[s] = Some(TcpClient::connect(addr.as_str(), self.opts)?);
        }
        Ok(self.clients[s].as_mut().expect("just connected"))
    }

    fn client_for(&mut self, t: usize) -> Result<&mut TcpClient> {
        let s = self.owner(t)?;
        self.client_for_shard(s)
    }
}

impl Transport for TcpShardRouter {
    fn eta(&self) -> f64 {
        self.eta
    }

    fn fetch_prox_col(&mut self, t: usize) -> Result<Vec<f64>> {
        self.client_for(t)?.fetch_prox_col(t)
    }

    fn push_update(&mut self, t: usize, k: u64, step: f64, u: &[f64]) -> Result<u64> {
        self.client_for(t)?.push_update(t, k, step, u)
    }

    fn push_batch(&mut self, updates: &[BatchUpdate]) -> Result<Vec<u64>> {
        // Group by owning shard, one PushBatch frame per shard, then
        // reassemble the versions in the caller's order.
        let mut by_shard: Vec<Vec<usize>> = vec![Vec::new(); self.map.shards()];
        for (i, up) in updates.iter().enumerate() {
            by_shard[self.owner(up.t as usize)?].push(i);
        }
        let mut versions = vec![0u64; updates.len()];
        for (s, idxs) in by_shard.into_iter().enumerate() {
            if idxs.is_empty() {
                continue;
            }
            let batch: Vec<BatchUpdate> = idxs.iter().map(|&i| updates[i].clone()).collect();
            let acks = self.client_for_shard(s)?.push_batch(&batch)?;
            for (&i, v) in idxs.iter().zip(acks) {
                versions[i] = v;
            }
        }
        Ok(versions)
    }

    fn register(&mut self, t: usize) -> Result<RegisterAck> {
        self.client_for(t)?.register(t)
    }

    fn heartbeat(&mut self, t: usize) -> Result<bool> {
        self.client_for(t)?.heartbeat(t)
    }

    fn leave(&mut self, t: usize) -> Result<()> {
        self.client_for(t)?.leave(t)
    }

    fn push_metrics(&mut self, t: usize, report: crate::transport::wire::MetricsReport) -> Result<()> {
        self.client_for(t)?.push_metrics(t, report)
    }

    fn close(&mut self) -> Result<()> {
        for client in self.clients.iter_mut().flatten() {
            let _ = client.close();
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::fleet;
    use crate::optim::prox::L1Prox;
    use crate::shard::ProxShard;
    use crate::transport::TcpServer;
    use std::time::Duration;

    fn quick_opts() -> TcpOptions {
        TcpOptions {
            connect_timeout: Duration::from_millis(500),
            io_timeout: Duration::from_millis(500),
            retries: 1,
            retry_backoff: Duration::from_millis(10),
        }
    }

    #[test]
    fn inproc_router_routes_across_the_shard_boundary() {
        let group =
            Arc::new(ShardGroup::new(3, 4, 2, Box::new(L1Prox::new(0.1)), 0.5, 8).unwrap());
        let mut router = ShardRouter::new(Arc::clone(&group));
        assert_eq!(router.eta(), 0.5);
        for t in 0..4 {
            router.push_update(t, 0, 1.0, &[t as f64; 3]).unwrap();
        }
        // Task 3 landed on shard 1's local column 1.
        assert_eq!(group.shard(1).server().state().read_col(1), vec![3.0; 3]);
        for t in 0..4 {
            assert_eq!(router.fetch_prox_col(t).unwrap(), group.fetch_prox_col(t).unwrap());
        }
        assert!(router.push_update(4, 0, 1.0, &[0.0; 3]).is_err(), "out of range");
    }

    #[test]
    fn tcp_router_bootstraps_from_seeds_and_routes_by_ownership() {
        // Two shard processes (in spirit): map carries no addresses, so
        // the router adopts the seed list as the per-shard addresses.
        let map = Arc::new(ShardMap::uniform(3, 5, 2));
        let reg = L1Prox::new(0.1);
        let s0 = Arc::new(ProxShard::create(Arc::clone(&map), 0, &reg, 0.5, None).unwrap());
        let s1 = Arc::new(ProxShard::create(Arc::clone(&map), 1, &reg, 0.5, None).unwrap());
        let mut h0 = TcpServer::spawn_shard("127.0.0.1:0", Arc::clone(&s0), None).unwrap();
        let mut h1 = TcpServer::spawn_shard("127.0.0.1:0", Arc::clone(&s1), None).unwrap();
        let seeds = vec![h0.addr().to_string(), h1.addr().to_string()];

        let mut router = TcpShardRouter::connect(&seeds, quick_opts()).unwrap();
        assert_eq!(router.eta(), 0.5);
        assert_eq!(router.map().addrs, seeds);

        for t in 0..5 {
            // Versions are per-shard KM counts: shard 0 sees tasks 0,1,2
            // as its commits 1,2,3; shard 1 sees tasks 3,4 as 1,2.
            let expect = if t < 3 { t as u64 + 1 } else { t as u64 - 2 };
            assert_eq!(router.push_update(t, 0, 1.0, &[t as f64; 3]).unwrap(), expect);
        }
        // Shard 0 owns tasks 0..3, shard 1 owns 3..5.
        assert_eq!(s0.server().state().read_col(2), vec![2.0; 3]);
        assert_eq!(s1.server().state().read_col(0), vec![3.0; 3]);
        for t in 0..5 {
            let got = router.fetch_prox_col(t).unwrap();
            let owner = if t < 3 { &s0 } else { &s1 };
            assert_eq!(got, owner.fetch_prox_col(t).unwrap());
        }

        // A batch spanning both shards: one frame per shard, versions
        // reassembled in caller order.
        let mk = |t: usize, k: u64| BatchUpdate {
            t: t as u32,
            k,
            span: fleet::span_id(t, k),
            step: 0.5,
            u: vec![1.0; 3],
        };
        let versions = router.push_batch(&[mk(4, 1), mk(0, 1), mk(3, 1)]).unwrap();
        assert_eq!(versions.len(), 3);
        assert_eq!(s0.applied_commits(0).unwrap(), 2, "batch commit landed on shard 0");
        assert_eq!(s1.applied_commits(3).unwrap(), 2);
        assert_eq!(s1.applied_commits(4).unwrap(), 2);
        router.close().unwrap();
        h0.shutdown();
        h1.shutdown();
    }

    #[test]
    fn connect_requires_enough_seeds_for_an_addressless_map() {
        let map = Arc::new(ShardMap::uniform(2, 4, 2));
        let s0 =
            Arc::new(ProxShard::create(Arc::clone(&map), 0, &L1Prox::new(0.1), 0.5, None).unwrap());
        let mut h0 = TcpServer::spawn_shard("127.0.0.1:0", Arc::clone(&s0), None).unwrap();
        let err = TcpShardRouter::connect(&[h0.addr().to_string()], quick_opts()).unwrap_err();
        assert!(format!("{err:#}").contains("2 shards but 1 addresses"), "{err:#}");
        h0.shutdown();
    }
}
