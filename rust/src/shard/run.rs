//! The in-process sharded training driver behind `amtl train --shards N`:
//! one free-running worker thread per task, each routed through a
//! [`ShardRouter`] to a [`ShardGroup`] of column-partitioned prox
//! shards — Algorithm 1 with the central server split `N` ways.
//!
//! Determinism contract: with a fixed KM step, no injected delay and no
//! faults, a run over a **separable** formulation produces a merged
//! model bitwise identical to the same run against one whole-model
//! server, for any shard count — per-column dynamics decouple, and each
//! worker's RNG stream is forked from the root seed in task order
//! exactly as the single-server session does. Non-separable
//! formulations converge to the same objective within tolerance via
//! coordination rounds (`rust/tests/integration_shard.rs` asserts both).

use std::path::PathBuf;
use std::sync::Arc;
use std::time::Duration;

use anyhow::{bail, Result};

use crate::coordinator::step_size::{KmSchedule, StepController};
use crate::coordinator::worker::{run_worker, WorkerCtx, WorkerStats};
use crate::coordinator::MtlProblem;
use crate::linalg::Mat;
use crate::net::{DelayModel, FaultModel};
use crate::runtime::NativeTaskCompute;
use crate::util::Rng;

use super::router::ShardRouter;
use super::server::ShardGroup;

/// Knobs for one sharded in-process run.
#[derive(Clone, Debug)]
pub struct ShardRunConfig {
    /// Number of prox shards to split the server into.
    pub shards: usize,
    /// Activations per task node.
    pub iters: usize,
    /// Fixed KM relaxation step η_k.
    pub km_step: f64,
    /// Root RNG seed; worker streams are forked from it in task order.
    pub seed: u64,
    /// Commit stride between coordination rounds (non-separable only).
    pub coord_every: u64,
    /// `Some((dir, snapshot_every))` to checkpoint every shard under
    /// `dir/shard-<i>/` (and write the `SHARDMAP` routing file).
    pub persist: Option<(PathBuf, u64)>,
    /// Recover from `persist`'s directory instead of starting fresh
    /// (workers skip the activations their shard already applied).
    pub resume: bool,
}

impl ShardRunConfig {
    /// A plain in-memory run: `shards` shards, `iters` activations per
    /// task, fixed KM step, seeded.
    pub fn new(shards: usize, iters: usize, km_step: f64, seed: u64) -> ShardRunConfig {
        ShardRunConfig {
            shards,
            iters,
            km_step,
            seed,
            coord_every: super::server::DEFAULT_COORD_EVERY,
            persist: None,
            resume: false,
        }
    }
}

/// What a sharded run produced.
pub struct ShardRunResult {
    /// Merged final model `W = Prox_{ηλg}(V)` over all shards.
    pub merged_w: Mat,
    /// Merged raw iterate `V` (concatenated shard slices).
    pub merged_v: Mat,
    /// Full objective `Σ_t ℓ_t(w_t) + λ g(W)` at `merged_w`.
    pub objective: f64,
    /// Coordination rounds run (0 for separable formulations).
    pub rounds: u64,
    /// Whether the formulation sharded without coordination.
    pub separable: bool,
    /// Total updates committed across all workers.
    pub updates: u64,
    /// Per-worker stats, task-indexed.
    pub worker_stats: Vec<WorkerStats>,
}

/// Run `problem` over `cfg.shards` column-partitioned prox shards with
/// one free-running worker per task; block until every worker's
/// activation budget is spent and return the merged model.
pub fn run_sharded(problem: &MtlProblem, cfg: &ShardRunConfig) -> Result<ShardRunResult> {
    if cfg.shards == 0 || cfg.shards > problem.t() {
        bail!(
            "--shards must be in 1..={} (one shard needs at least one task column), got {}",
            problem.t(),
            cfg.shards
        );
    }
    let proto = problem.regularizer();
    let (d, tasks, eta) = (problem.d(), problem.t(), problem.eta);
    let group = Arc::new(match (&cfg.persist, cfg.resume) {
        (None, false) => {
            ShardGroup::new(d, tasks, cfg.shards, proto, eta, cfg.coord_every)?
        }
        (Some((dir, every)), false) => {
            ShardGroup::durable(d, tasks, cfg.shards, proto, eta, cfg.coord_every, dir, *every)?
        }
        (Some((dir, every)), true) => {
            ShardGroup::resume(d, tasks, cfg.shards, proto, eta, cfg.coord_every, dir, *every)?
        }
        (None, true) => bail!("--resume requires a checkpoint directory"),
    });

    let controller =
        Arc::new(StepController::new(KmSchedule::fixed(cfg.km_step), false, tasks, 5));
    // Fork worker streams in task order — the same derivation the
    // single-server session uses, so seeded runs line up shard-for-shard.
    let mut root = Rng::new(cfg.seed);
    let rngs: Vec<Rng> = (0..tasks).map(|t| root.fork(t as u64)).collect();

    let worker_stats: Vec<WorkerStats> = std::thread::scope(|scope| {
        let mut handles = Vec::with_capacity(tasks);
        for (t, rng) in rngs.into_iter().enumerate() {
            let group = Arc::clone(&group);
            let controller = Arc::clone(&controller);
            let task = &problem.dataset.tasks[t];
            handles.push(scope.spawn(move || {
                let mut compute = NativeTaskCompute::new(task);
                let ctx = WorkerCtx {
                    t,
                    iters: cfg.iters,
                    transport: Box::new(ShardRouter::new(group)),
                    controller,
                    delay: DelayModel::None,
                    faults: FaultModel::None,
                    sgd_fraction: None,
                    time_scale: Duration::from_millis(100),
                    sink: None,
                    rng,
                    gate: None,
                    heartbeat: None,
                    resume: cfg.resume,
                    trace: None,
                    metrics_stride: None,
                };
                run_worker(ctx, &mut compute)
            }));
        }
        handles
            .into_iter()
            .map(|h| h.join().expect("worker thread panicked"))
            .collect::<Result<Vec<_>>>()
    })?;

    group.sync_persist()?;
    let merged_w = group.merged_w();
    let merged_v = group.merged_v();
    let objective = problem.objective(&merged_w);
    Ok(ShardRunResult {
        merged_w,
        merged_v,
        objective,
        rounds: group.rounds(),
        separable: group.is_separable(),
        updates: worker_stats.iter().map(|s| s.updates as u64).sum(),
        worker_stats,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;
    use crate::optim::prox::RegularizerKind;

    fn problem(reg: RegularizerKind, seed: u64) -> MtlProblem {
        let mut rng = Rng::new(seed);
        let ds = synthetic::lowrank_regression(&[25; 4], 6, 2, 0.05, &mut rng);
        MtlProblem::new(ds, reg, 0.1, 0.5, &mut rng)
    }

    #[test]
    fn sharded_l1_run_is_seed_deterministic() {
        let cfg = ShardRunConfig::new(2, 15, 0.5, 77);
        let a = run_sharded(&problem(RegularizerKind::L1, 31), &cfg).unwrap();
        let b = run_sharded(&problem(RegularizerKind::L1, 31), &cfg).unwrap();
        assert!(a.separable);
        assert_eq!(a.rounds, 0);
        assert_eq!(a.updates, 4 * 15);
        assert_eq!(a.merged_w.data(), b.merged_w.data(), "same seed, same model");
        assert!(a.objective.is_finite());
    }

    #[test]
    fn nuclear_runs_coordinate_and_stay_finite() {
        let mut cfg = ShardRunConfig::new(2, 20, 0.5, 78);
        cfg.coord_every = 10;
        let res = run_sharded(&problem(RegularizerKind::Nuclear, 32), &cfg).unwrap();
        assert!(!res.separable);
        assert!(res.rounds >= 1, "coordination rounds must fire");
        assert!(res.objective.is_finite());
    }

    #[test]
    fn shard_count_is_validated() {
        let p = problem(RegularizerKind::L1, 33);
        assert!(run_sharded(&p, &ShardRunConfig::new(0, 5, 0.5, 1)).is_err());
        assert!(run_sharded(&p, &ShardRunConfig::new(9, 5, 0.5, 1)).is_err());
    }
}
