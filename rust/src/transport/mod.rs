//! The worker↔server transport layer: how model vectors cross the
//! "network" of Algorithm 1's star topology.
//!
//! The paper's premise is that task data lives on separate nodes and only
//! model vectors travel ("it may not always be feasible to transfer the
//! data … due to high data volume and privacy"). This module makes that
//! edge explicit: a task node talks to the central server *only* through
//! the [`Transport`] trait —
//!
//! * [`Transport::eta`] — the forward step size η (a run constant),
//! * [`Transport::fetch_prox_col`] — retrieve the backward-step block
//!   `(Prox_{ηλg}(V̂))_t`,
//! * [`Transport::push_update`] — commit a forward-step result via the KM
//!   relaxation.
//!
//! Two implementations:
//!
//! * [`InProc`] — the shared-memory path: direct calls into an
//!   `Arc<CentralServer>`, no serialization, bit-identical to the
//!   pre-transport coordinator. The default.
//! * [`TcpClient`] / [`TcpServer`] — a real network path: the versioned,
//!   checksummed binary frames of [`wire`] over `std::net` TCP, one
//!   connection per task node, with client-side timeouts and reconnects.
//!   The privacy boundary stops being a simulation: the protocol has no
//!   frame type that could carry a task node's *training set* (`X_t`,
//!   `y_t`) — only prox columns, update vectors, and scalars ever cross
//!   the socket. (The serving-tier `Predict` frame carries a feature
//!   vector too, but it is the *querier's own* input sent to a read
//!   replica for scoring, never a training example leaving its node.)
//!
//! Every [`Schedule`](crate::coordinator::Schedule) routes its backward
//! fetches and KM commits through this trait, so asynchronous,
//! synchronized, and semi-synchronous runs all work over either transport
//! (select with
//! [`SessionBuilder::transport`](crate::coordinator::SessionBuilder::transport)),
//! and the `amtl --serve` / `amtl --node` CLI modes run the two halves as
//! separate OS processes.

pub mod inproc;
pub mod tcp;
pub mod wire;

pub use inproc::InProc;
pub use tcp::{TcpClient, TcpOptions, TcpServer, TcpServerHandle};
pub use wire::BatchUpdate;

use anyhow::Result;
use crate::util::EnumTable;

/// Name table for [`TransportKind`].
const TRANSPORTS: EnumTable<TransportKind> = EnumTable {
    what: "--transport value",
    rows: &[
        ("inproc", &[], TransportKind::InProc),
        ("tcp", &[], TransportKind::Tcp),
    ],
};

/// How a [`Session`](crate::coordinator::Session) wires its workers to the
/// central server.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum TransportKind {
    /// Shared-memory calls through `Arc<CentralServer>` (the default;
    /// bit-identical to the pre-transport coordinator).
    #[default]
    InProc,
    /// Spawn a loopback TCP server around the session's central server and
    /// connect every worker through its own socket: all algorithmic
    /// traffic crosses the real wire protocol.
    Tcp,
}

impl TransportKind {
    /// Parse a CLI value (`"inproc"` | `"tcp"`); the error lists the
    /// valid values.
    pub fn parse(s: &str) -> Result<TransportKind> {
        TRANSPORTS.parse(s)
    }

    /// Canonical CLI name.
    pub fn name(&self) -> &'static str {
        TRANSPORTS.name(*self)
    }
}

/// What a node learns when it registers: where its column already stands
/// (so a restarted node resumes instead of redoing finished activations)
/// and its membership generation.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct RegisterAck {
    /// Commits already applied for this node's column.
    pub col_version: u64,
    /// Membership generation (0 when no registry is attached; otherwise 1
    /// on first join, +1 per rejoin).
    pub generation: u64,
}

/// One task node's channel to the central server (the worker side of the
/// star edge). Implementations are per-node — each worker owns its own
/// transport (for TCP: its own connection and framing state), hence
/// `&mut self`.
pub trait Transport: Send {
    /// The run's forward step size η (Eq. III.4). Fixed for the lifetime
    /// of a run; TCP clients fetch it once at connect and cache it.
    fn eta(&self) -> f64;

    /// Retrieve `(Prox_{ηλg}(V̂))_t` — the backward step for task `t`,
    /// computed server-side over a fresh-enough snapshot of `V`.
    fn fetch_prox_col(&mut self, t: usize) -> Result<Vec<f64>>;

    /// Commit a forward-step result: `v_t ← v_t + step·(u − v_t)` on the
    /// server, where `k` is this node's activation counter. Returns the
    /// new global version (total KM updates).
    ///
    /// Over TCP the transport is at-least-once — a response lost to a
    /// transient failure triggers a reconnect-and-resend — but the server
    /// deduplicates on `(t, k)`, so the *commit* is exactly-once even
    /// across a server restart.
    fn push_update(&mut self, t: usize, k: u64, step: f64, u: &[f64]) -> Result<u64>;

    /// Commit several updates in one exchange. Semantically identical to
    /// calling [`Transport::push_update`] once per element (the default
    /// does exactly that); batching transports — the shard router, the
    /// TCP client's `PushBatch` frame — coalesce same-destination commits
    /// to cut per-frame overhead. Returns the new global version after
    /// each commit, index-aligned with `updates`.
    fn push_batch(&mut self, updates: &[wire::BatchUpdate]) -> Result<Vec<u64>> {
        let mut versions = Vec::with_capacity(updates.len());
        for up in updates {
            versions.push(self.push_update(up.t as usize, up.k, up.step, &up.u)?);
        }
        Ok(versions)
    }

    /// Join (or rejoin) the run as task node `t`. Without a membership
    /// registry this still reports the column's applied-commit horizon,
    /// which is what lets a restarted node catch up.
    fn register(&mut self, t: usize) -> Result<RegisterAck> {
        let _ = t;
        Ok(RegisterAck::default())
    }

    /// Prove liveness for task node `t`. `Ok(false)` means the node was
    /// evicted and must [`Transport::register`] again to rejoin; without
    /// a registry this is trivially `Ok(true)`.
    fn heartbeat(&mut self, t: usize) -> Result<bool> {
        let _ = t;
        Ok(true)
    }

    /// Politely depart the run as task node `t` (the run stops waiting
    /// for this node). No-op without a registry.
    fn leave(&mut self, t: usize) -> Result<()> {
        let _ = t;
        Ok(())
    }

    /// Export this node's metrics registry snapshot to the server, which
    /// folds it into the `NODE` rows of its own `MetricsReport` (how a
    /// multi-process fleet shows up in one `amtl top` view). Best-effort
    /// and advisory; in-proc workers share the trainer's registry, so the
    /// default is a no-op.
    fn push_metrics(&mut self, t: usize, report: wire::MetricsReport) -> Result<()> {
        let _ = (t, report);
        Ok(())
    }

    /// Graceful teardown (TCP sends a `Shutdown` frame; in-proc is a
    /// no-op). Called by the worker loop on exit; errors are advisory.
    fn close(&mut self) -> Result<()> {
        Ok(())
    }
}
